//! Minimal offline drop-in for the `anyhow` crate.
//!
//! The build image has no crates.io access, so this vendored shim provides
//! the subset of the real `anyhow` API the workspace uses:
//!
//! * [`Error`] — an opaque error carrying a message + context chain;
//! * [`Result`] — `Result<T, Error>` alias with a default error type;
//! * [`anyhow!`] / [`bail!`] — format-style construction / early return;
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`, mirroring the upstream trait (including the upstream's
//!   private-`StdError` coherence trick so `Result<T, Error>` gets context
//!   too).
//!
//! Display follows upstream semantics: `{}` prints the outermost message,
//! `{:#}` prints the whole chain joined by `: `, and `Debug` prints the
//! chain with a `Caused by:` list (what `.unwrap()` shows in tests).

use std::fmt::{self, Display};

/// Opaque error: outermost message first, then the cause chain.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: Display>(m: M) -> Error {
        Error {
            chain: vec![m.to_string()],
        }
    }

    fn wrap(mut self, ctx: String) -> Error {
        self.chain.insert(0, ctx);
        self
    }

    /// The cause chain, outermost context first (upstream: `chain()`).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// Outermost message (upstream: `root_cause`/`to_string` analogues).
    pub fn to_string_full(&self) -> String {
        self.chain.join(": ")
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that
// is what makes the blanket `From` below and the `ext::StdError` impls
// coherent (same trick as upstream anyhow).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow!("...")` — format-style error construction.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// `bail!("...")` — return early with a formatted error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// `ensure!(cond, "...")` — bail unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

mod ext {
    use super::Error;

    /// Private conversion trait: implemented for all std errors AND for
    /// [`Error`] itself, so `.context(..)` works on both kinds of Result.
    pub trait StdError {
        fn ext_context(self, ctx: String) -> Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> StdError for E {
        fn ext_context(self, ctx: String) -> Error {
            Error::from(self).wrap(ctx)
        }
    }

    impl StdError for Error {
        fn ext_context(self, ctx: String) -> Error {
            self.wrap(ctx)
        }
    }
}

/// `.context(..)` / `.with_context(..)` on `Result` and `Option`.
pub trait Context<T, E>: Sized {
    fn context<C: Display + Send + Sync + 'static>(self, ctx: C) -> Result<T, Error>;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: ext::StdError + Send + Sync + 'static> Context<T, E> for Result<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, ctx: C) -> Result<T, Error> {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.ext_context(ctx.to_string())),
        }
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.ext_context(f().to_string())),
        }
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: Display + Send + Sync + 'static>(self, ctx: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn construction_and_display() {
        let e = anyhow!("boom {}", 7);
        assert_eq!(format!("{e}"), "boom 7");
    }

    #[test]
    fn context_chains() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: missing");
        let e2: Result<(), Error> = Err(e);
        let e2 = e2.with_context(|| format!("loading {}", "x")).unwrap_err();
        assert_eq!(format!("{e2:#}"), "loading x: reading config: missing");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("no value").unwrap_err();
        assert_eq!(format!("{e}"), "no value");
        assert_eq!(Some(3).context("ok").unwrap(), 3);
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x > 10 {
                bail!("too big");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(-1).is_err());
        assert!(f(11).is_err());
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(format!("{}", f().unwrap_err()), "missing");
    }
}
