"""L1 Pallas kernel: fused logistic-regression gradient data term.

Computes g = (1/m) Aᵀ(b ∘ σ(b ∘ Ax)) with the data matrix streamed through
VMEM in (block_m × d) row tiles. Both phases of each tile are matmuls
(A_blk·x and A_blkᵀ·s), i.e. MXU work on a real TPU; the sigmoid is a VPU
elementwise pass over the block's margins.

TPU adaptation notes (DESIGN.md §Hardware-Adaptation):
  * BlockSpec tiles A by rows so each grid step holds one
    (block_m × d) f64 tile in VMEM (≤ 4 MiB for the paper's shapes);
  * the output accumulates across grid steps in the same (d,) VMEM block —
    the canonical Pallas reduction pattern (zero-init at step 0);
  * `interpret=True` everywhere here: the CPU PJRT plugin cannot execute
    Mosaic custom-calls, and correctness/artifacts are the goal; VMEM/MXU
    behaviour is *estimated* analytically in EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

jax.config.update("jax_enable_x64", True)

# Row-tile size. The wrapper zero-pads A/b up to a multiple of the tile —
# zero rows contribute nothing to Aᵀs, so the result is exact — which
# keeps the Pallas grid short (the interpret lowering emits one loop
# iteration per grid step; an awkward m like 2837 (prime) would otherwise
# degenerate to a 2837-step loop).
MAX_BLOCK_M = 512


def pick_block_m(m: int, cap: int = MAX_BLOCK_M) -> int:
    """Tile size for m rows: min(m, cap) — the wrapper pads m up to a
    multiple of this."""
    return max(1, min(m, cap))


def pad_rows(m: int, bm: int) -> int:
    """Padded row count: smallest multiple of bm ≥ m."""
    return ((m + bm - 1) // bm) * bm


def _kernel(x_ref, a_ref, b_ref, o_ref, *, m_total: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a_blk = a_ref[...]          # (bm, d) tile in VMEM
    z = a_blk @ x_ref[...]      # MXU: (bm, d) x (d,)
    s = b_ref[...] * jax.nn.sigmoid(b_ref[...] * z) / m_total  # VPU
    o_ref[...] += a_blk.T @ s   # MXU: (d, bm) x (bm,)


@functools.partial(jax.jit, static_argnames=("block_m",))
def logreg_data_grad(x, a, b, block_m=None):
    """Pallas data-term gradient. x: [d], a: [m, d], b: [m] → [d].

    Pads (A, b) with zero rows up to a multiple of the tile: a zero row
    contributes `0ᵀ·s_j = 0` to the accumulated Aᵀs whatever its label, so
    the padded result is bit-exact while the grid stays short.
    """
    m, d = a.shape
    bm = block_m or pick_block_m(m)
    mp = pad_rows(m, bm)
    if mp != m:
        a = jnp.pad(a, ((0, mp - m), (0, 0)))
        b = jnp.pad(b, (0, mp - m), constant_values=1.0)
    grid = (mp // bm,)
    return pl.pallas_call(
        functools.partial(_kernel, m_total=m),
        grid=grid,
        in_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),        # x: whole vector
            pl.BlockSpec((bm, d), lambda i: (i, 0)),   # a: row tile
            pl.BlockSpec((bm,), lambda i: (i,)),       # b: row tile
        ],
        out_specs=pl.BlockSpec((d,), lambda i: (0,)),  # accumulate in place
        out_shape=jax.ShapeDtypeStruct((d,), x.dtype),
        interpret=True,
    )(x, a, b)


def logreg_grad(x, a, b, mu, block_m=None):
    """Full local gradient ∇f_i(x): Pallas data term + μx (fused by XLA)."""
    return logreg_data_grad(x, a, b, block_m=block_m) + mu * x


def vmem_bytes(m: int, d: int, block_m=None, bytes_per_elem: int = 8) -> int:
    """Estimated VMEM residency per grid step: A tile + x + s + out."""
    bm = block_m or pick_block_m(m)
    return bytes_per_elem * (bm * d + d + 2 * bm + d)


def grid_steps(m: int, block_m=None) -> int:
    bm = block_m or pick_block_m(m)
    return pad_rows(m, bm) // bm


def mxu_flops(m: int, d: int) -> int:
    """MXU flops per full gradient: two m×d matvecs."""
    return 4 * m * d
