"""Pure-jnp oracles for the Pallas kernels (the correctness reference).

Everything is f64: the optimizer's residual curves go down to 1e-12, so the
whole pipeline (python build time + rust run time) runs in double
precision.
"""

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)


def sigmoid(t):
    return jax.nn.sigmoid(t)


def logreg_data_grad_ref(x, a, b):
    """(1/m) Aᵀ(b ∘ σ(b ∘ Ax)) — the data term of ∇f_i (paper §6.1 loss).

    x: [d], a: [m, d], b: [m] (±1 labels). Returns [d].
    """
    m = a.shape[0]
    z = a @ x
    s = b * sigmoid(b * z) / m
    return a.T @ s


def logreg_grad_ref(x, a, b, mu):
    """Full local gradient ∇f_i(x) = data term + μx."""
    return logreg_data_grad_ref(x, a, b) + mu * x


def logreg_loss_ref(x, a, b, mu):
    """f_i(x) = (1/m) Σ softplus(b_j · a_jᵀx) + (μ/2)‖x‖²."""
    z = a @ x
    return jnp.mean(jax.nn.softplus(b * z)) + 0.5 * mu * jnp.dot(x, x)


def whiten_ref(r, v):
    """Dense matvec r @ v (r = L^{†1/2}, the whitening operator)."""
    return r @ v


def whitened_diff_ref(x, a, b, mu, r, h):
    """L^{†1/2}(∇f_i(x) − h) — the worker-side compress input of eq. (7)."""
    return whiten_ref(r, logreg_grad_ref(x, a, b, mu) - h)
