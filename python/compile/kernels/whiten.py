"""L1 Pallas kernel: whitening matvec w = R v with R = L_i^{†1/2}.

This is the worker-side half of the paper's protocol (7): before
sketching, the gradient difference is multiplied by the pseudo-inverse
root of the local smoothness matrix. R is a dense d×d operator; the
kernel tiles it by (block × d) row panels so each grid step is one MXU
panel-matvec with the full v resident in VMEM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

jax.config.update("jax_enable_x64", True)

MAX_BLOCK_ROWS = 256


def pick_block(d: int, cap: int = MAX_BLOCK_ROWS) -> int:
    best = 1
    for k in range(1, min(d, cap) + 1):
        if d % k == 0:
            best = k
    return best


def _kernel(r_ref, v_ref, o_ref):
    o_ref[...] = r_ref[...] @ v_ref[...]


@functools.partial(jax.jit, static_argnames=("block_rows",))
def whiten(r, v, block_rows=None):
    """w = r @ v. r: [d, d], v: [d] → [d]."""
    d = r.shape[0]
    assert r.shape == (d, d) and v.shape == (d,)
    br = block_rows or pick_block(d)
    assert d % br == 0
    return pl.pallas_call(
        _kernel,
        grid=(d // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),  # R row panel
            pl.BlockSpec((d,), lambda i: (0,)),       # v resident
        ],
        out_specs=pl.BlockSpec((br,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d,), v.dtype),
        interpret=True,
    )(r, v)


def whitened_diff(x, a, b, mu, r, h, block_rows=None):
    """L^{†1/2}(∇f_i(x) − h) — the full worker-side compress input."""
    from . import logreg_grad as lk

    g = lk.logreg_grad(x, a, b, mu)
    return whiten(r, g - h, block_rows=block_rows)
