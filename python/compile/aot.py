"""AOT pipeline: lower the L2 model (with L1 Pallas kernels) to HLO text.

Run by `make artifacts`:

    cd python && python -m compile.aot --out-dir ../artifacts

Shapes come from `python/compile/shapes.json` (one entry per shard shape
used by the Rust tests/examples/benches) or `--shapes m:d,m:d,...`.

HLO **text** is the interchange format: jax ≥ 0.5 serializes
HloModuleProto with 64-bit instruction ids which the `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser on
the Rust side reassigns ids (see /opt/xla-example/README.md).
"""

import argparse
import json
import os
import sys

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(fn, specs) -> str:
    lowered = jax.jit(fn).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def default_shapes():
    here = os.path.dirname(os.path.abspath(__file__))
    path = os.path.join(here, "shapes.json")
    with open(path) as f:
        spec = json.load(f)
    return [(e["m"], e["d"]) for e in spec["shapes"]], spec.get(
        "kinds", ["grad", "loss"]
    )


def parse_shapes(arg: str):
    out = []
    for tok in arg.split(","):
        m, d = tok.strip().split(":")
        out.append((int(m), int(d)))
    return out


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--shapes", default=None, help="m:d,m:d,... (overrides shapes.json)")
    ap.add_argument("--kinds", default=None, help="comma list from grad,loss,wgrad")
    args = ap.parse_args()

    shapes, kinds = default_shapes()
    if args.shapes:
        shapes = parse_shapes(args.shapes)
    if args.kinds:
        kinds = [k.strip() for k in args.kinds.split(",")]

    os.makedirs(args.out_dir, exist_ok=True)
    entries = []
    for m, d in shapes:
        for kind in kinds:
            fn = model.ENTRY_POINTS[kind]
            specs = model.specs_for(kind, m, d)
            text = to_hlo_text(fn, specs)
            fname = f"{kind}_m{m}_d{d}.hlo.txt"
            with open(os.path.join(args.out_dir, fname), "w") as f:
                f.write(text)
            entries.append({"kind": kind, "m": m, "d": d, "file": fname})
            print(f"  lowered {kind} m={m} d={d} -> {fname} ({len(text)} chars)")

    manifest = {"version": 1, "dtype": "f64", "entries": entries}
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {len(entries)} artifacts + manifest.json to {args.out_dir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
