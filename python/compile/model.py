"""L2: the per-worker JAX compute graph, calling the L1 Pallas kernels.

Three entry points, each lowered to one HLO artifact per shard shape by
`aot.py`:

  grad(x, a, b, mu)                → (∇f_i(x),)
  loss(x, a, b, mu)                → (f_i(x),)
  wgrad(x, a, b, mu, r, h)         → (L^{†1/2}(∇f_i(x) − h),)

All f64; Python never runs at request time — the Rust runtime executes
these artifacts through PJRT.
"""

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from .kernels import logreg_grad as lk
from .kernels import whiten as wk


def grad(x, a, b, mu):
    """∇f_i(x) — the hot path (Pallas data term + μx)."""
    return (lk.logreg_grad(x, a, b, mu),)


def loss(x, a, b, mu):
    """f_i(x) — metrics path (pure jnp; not performance critical)."""
    z = a @ x
    val = jnp.mean(jax.nn.softplus(b * z)) + 0.5 * mu * jnp.dot(x, x)
    return (val,)


def wgrad(x, a, b, mu, r, h):
    """Whitened gradient difference L^{†1/2}(∇f_i(x) − h) (protocol (7))."""
    return (wk.whitened_diff(x, a, b, mu, r, h),)


def specs_for(kind: str, m: int, d: int):
    """Input ShapeDtypeStructs for a given artifact kind and shard shape."""
    f64 = jnp.float64
    x = jax.ShapeDtypeStruct((d,), f64)
    a = jax.ShapeDtypeStruct((m, d), f64)
    b = jax.ShapeDtypeStruct((m,), f64)
    mu = jax.ShapeDtypeStruct((), f64)
    if kind in ("grad", "loss"):
        return (x, a, b, mu)
    if kind == "wgrad":
        r = jax.ShapeDtypeStruct((d, d), f64)
        h = jax.ShapeDtypeStruct((d,), f64)
        return (x, a, b, mu, r, h)
    raise ValueError(f"unknown artifact kind {kind!r}")


ENTRY_POINTS = {"grad": grad, "loss": loss, "wgrad": wgrad}
