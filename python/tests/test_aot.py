"""AOT pipeline tests: HLO-text lowering round-trips and manifest shape.

These execute the lowered computation back through jax's own runtime to
verify that what we hand the Rust side is numerically the model.
"""

import json
import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from compile import aot, model
from compile.kernels import ref


def test_to_hlo_text_produces_parseable_module():
    specs = model.specs_for("grad", 6, 5)
    text = aot.to_hlo_text(model.grad, specs)
    assert text.startswith("HloModule")
    assert "f64" in text
    # 1-tuple output (return_tuple=True)
    assert "(f64[5]" in text.replace(" ", "")


def test_lowered_grad_matches_ref_numerically():
    """Compile the HLO text with jax's client and execute it."""
    from jax._src.lib import xla_client as xc

    m, d = 8, 5
    specs = model.specs_for("grad", m, d)
    lowered = jax.jit(model.grad).lower(*specs)
    compiled = lowered.compile()

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=d))
    a = jnp.asarray(rng.normal(size=(m, d)) * 0.5)
    b = jnp.asarray(rng.choice([-1.0, 1.0], size=m))
    mu = jnp.asarray(1e-3)
    (got,) = compiled(x, a, b, mu)
    want = ref.logreg_grad_ref(x, a, b, float(mu))
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)
    _ = xc  # silence unused-import linters


def test_parse_shapes():
    assert aot.parse_shapes("3:4, 10:20") == [(3, 4), (10, 20)]


def test_default_shapes_json_loads():
    shapes, kinds = aot.default_shapes()
    assert (30, 20) in shapes
    assert "grad" in kinds and "loss" in kinds


def test_aot_main_writes_manifest(tmp_path=None):
    out = tempfile.mkdtemp(prefix="smx_aot_test")
    env = dict(os.environ)
    repo_python = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    proc = subprocess.run(
        [
            sys.executable,
            "-m",
            "compile.aot",
            "--out-dir",
            out,
            "--shapes",
            "4:3",
            "--kinds",
            "grad,loss",
        ],
        cwd=repo_python,
        env=env,
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert proc.returncode == 0, proc.stderr
    with open(os.path.join(out, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["version"] == 1
    assert manifest["dtype"] == "f64"
    kinds = {e["kind"] for e in manifest["entries"]}
    assert kinds == {"grad", "loss"}
    for e in manifest["entries"]:
        path = os.path.join(out, e["file"])
        assert os.path.exists(path)
        with open(path) as f:
            assert f.read().startswith("HloModule")
