"""L1 kernel correctness: Pallas vs pure-jnp oracle.

Hypothesis sweeps shapes (m, d), block sizes and input scales; every case
asserts allclose against ref.py — the core correctness signal for the
compute hot path that the Rust runtime will execute via PJRT.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

from compile.kernels import logreg_grad as lk
from compile.kernels import ref
from compile.kernels import whiten as wk


def make_problem(m, d, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=d) * scale)
    a = jnp.asarray(rng.normal(size=(m, d)) * 0.5)
    b = jnp.asarray(rng.choice([-1.0, 1.0], size=m))
    return x, a, b


@settings(max_examples=60, deadline=None)
@given(
    m=st.integers(min_value=1, max_value=64),
    d=st.integers(min_value=1, max_value=48),
    seed=st.integers(min_value=0, max_value=2**31),
    scale=st.sampled_from([1e-3, 1.0, 50.0]),
)
def test_logreg_grad_matches_ref(m, d, seed, scale):
    x, a, b = make_problem(m, d, seed, scale)
    mu = 1e-3
    got = lk.logreg_grad(x, a, b, mu)
    want = ref.logreg_grad_ref(x, a, b, mu)
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


@settings(max_examples=30, deadline=None)
@given(
    m=st.integers(min_value=2, max_value=48),
    d=st.integers(min_value=1, max_value=32),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_blocking_is_invisible(m, d, seed):
    """Any valid block size gives identical results."""
    x, a, b = make_problem(m, d, seed)
    full = lk.logreg_data_grad(x, a, b, block_m=m)
    for bm in sorted({k for k in range(1, m + 1) if m % k == 0}):
        blocked = lk.logreg_data_grad(x, a, b, block_m=bm)
        np.testing.assert_allclose(blocked, full, rtol=1e-12, atol=1e-13)


def test_pick_block_m_and_padding():
    assert lk.pick_block_m(15) == 15
    assert lk.pick_block_m(2837) == 512  # prime m handled by zero-padding
    assert lk.pad_rows(2837, 512) == 3072
    assert lk.grid_steps(2837) == 6
    assert lk.pad_rows(512, 512) == 512
    assert lk.pick_block_m(30) == 30


def test_padding_is_exact_on_awkward_m():
    """m prime (no divisors): padded path must equal the unpadded one."""
    for m in [7, 13, 61]:
        x, a, b = make_problem(m, 9, m)
        padded = lk.logreg_data_grad(x, a, b, block_m=4)  # forces padding
        exact = lk.logreg_data_grad(x, a, b, block_m=m)   # single block
        np.testing.assert_allclose(padded, exact, rtol=1e-13, atol=1e-14)


def test_extreme_margins_are_stable():
    """Saturated sigmoids must not produce NaN/Inf."""
    m, d = 8, 4
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=d) * 1e4)
    a = jnp.asarray(rng.normal(size=(m, d)))
    b = jnp.asarray(rng.choice([-1.0, 1.0], size=m))
    g = lk.logreg_grad(x, a, b, 1e-3)
    assert np.all(np.isfinite(np.asarray(g)))


@settings(max_examples=40, deadline=None)
@given(
    d=st.integers(min_value=1, max_value=64),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_whiten_matches_ref(d, seed):
    rng = np.random.default_rng(seed)
    r = jnp.asarray(rng.normal(size=(d, d)))
    v = jnp.asarray(rng.normal(size=d))
    np.testing.assert_allclose(wk.whiten(r, v), ref.whiten_ref(r, v), rtol=1e-12, atol=1e-12)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(min_value=1, max_value=24),
    d=st.integers(min_value=1, max_value=24),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_whitened_diff_matches_ref(m, d, seed):
    x, a, b = make_problem(m, d, seed)
    rng = np.random.default_rng(seed + 1)
    r = jnp.asarray(rng.normal(size=(d, d)))
    h = jnp.asarray(rng.normal(size=d))
    mu = 1e-3
    got = wk.whitened_diff(x, a, b, mu, r, h)
    want = ref.whitened_diff_ref(x, a, b, mu, r, h)
    np.testing.assert_allclose(got, want, rtol=1e-11, atol=1e-11)


def test_grad_is_derivative_of_loss():
    """Cross-check the kernel against jax.grad of the loss oracle."""
    m, d = 16, 10
    x, a, b = make_problem(m, d, 7)
    mu = 1e-3
    want = jax.grad(lambda xx: ref.logreg_loss_ref(xx, a, b, mu))(x)
    got = lk.logreg_grad(x, a, b, mu)
    np.testing.assert_allclose(got, want, rtol=1e-11, atol=1e-12)


def test_f64_dtype_end_to_end():
    x, a, b = make_problem(4, 3, 1)
    g = lk.logreg_grad(x, a, b, 1e-3)
    assert g.dtype == jnp.float64


def test_vmem_estimate_monotone():
    assert lk.vmem_bytes(128, 128) < lk.vmem_bytes(256, 256)
    assert lk.mxu_flops(10, 20) == 800
