"""L2 model tests: entry points, shapes, and loss/grad consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

from compile import model
from compile.kernels import ref


def problem(m=12, d=7, seed=3):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=d))
    a = jnp.asarray(rng.normal(size=(m, d)) * 0.5)
    b = jnp.asarray(rng.choice([-1.0, 1.0], size=m))
    return x, a, b


def test_grad_entry_point_returns_tuple():
    x, a, b = problem()
    (g,) = model.grad(x, a, b, 1e-3)
    assert g.shape == x.shape
    np.testing.assert_allclose(g, ref.logreg_grad_ref(x, a, b, 1e-3), rtol=1e-12)


def test_loss_entry_point_matches_ref():
    x, a, b = problem()
    (v,) = model.loss(x, a, b, 1e-3)
    np.testing.assert_allclose(v, ref.logreg_loss_ref(x, a, b, 1e-3), rtol=1e-12)


def test_loss_grad_consistency():
    """model.grad == d(model.loss)/dx."""
    x, a, b = problem()
    want = jax.grad(lambda xx: model.loss(xx, a, b, 1e-3)[0])(x)
    (got,) = model.grad(x, a, b, 1e-3)
    np.testing.assert_allclose(got, want, rtol=1e-11, atol=1e-12)


def test_wgrad_entry_point():
    x, a, b = problem()
    d = x.shape[0]
    rng = np.random.default_rng(5)
    r = jnp.asarray(rng.normal(size=(d, d)))
    h = jnp.asarray(rng.normal(size=d))
    (w,) = model.wgrad(x, a, b, 1e-3, r, h)
    np.testing.assert_allclose(
        w, ref.whitened_diff_ref(x, a, b, 1e-3, r, h), rtol=1e-11, atol=1e-11
    )


def test_specs_for_shapes():
    specs = model.specs_for("grad", 9, 4)
    assert [s.shape for s in specs] == [(4,), (9, 4), (9,), ()]
    specs = model.specs_for("wgrad", 9, 4)
    assert [s.shape for s in specs] == [(4,), (9, 4), (9,), (), (4, 4), (4,)]
    with pytest.raises(ValueError):
        model.specs_for("nope", 1, 1)


def test_entry_points_registry():
    assert set(model.ENTRY_POINTS) == {"grad", "loss", "wgrad"}
