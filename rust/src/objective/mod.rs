//! Objectives and their smoothness structure: the paper's regularized
//! logistic regression (§6.1) plus the smoothness-matrix machinery
//! (Definition 1, Lemma 1, eqs. 8/9/14/15).

pub mod logreg;
pub mod smoothness;

pub use logreg::{LogReg, Problem};
pub use smoothness::{build_local, omega, tilde_l_independent, LocalSmoothness, Smoothness};
