//! Regularized logistic regression — the paper's experimental objective
//! (§6.1):
//!
//! ```text
//! f_i(x) = (1/m_i) Σ_j log(1 + exp(b_j · a_jᵀ x)) + (μ/2)‖x‖²
//! ```
//!
//! (the paper's sign convention; with labels b ∈ {−1,+1} this is the
//! standard logistic loss up to label flip). Each `f_i` is `L_i`-smooth
//! with `L_i = (1/4m_i) A_iᵀA_i + μI` (Lemma 1 with λ = 1/4).

use crate::data::Shard;
use crate::linalg::sparse::Csr;
use crate::linalg::vector;

/// Numerically stable softplus log(1 + e^t).
#[inline]
pub fn softplus(t: f64) -> f64 {
    if t > 0.0 {
        t + (-t).exp().ln_1p()
    } else {
        t.exp().ln_1p()
    }
}

/// Logistic sigmoid 1/(1+e^{−t}), stable for large |t|.
#[inline]
pub fn sigmoid(t: f64) -> f64 {
    if t >= 0.0 {
        1.0 / (1.0 + (-t).exp())
    } else {
        let e = t.exp();
        e / (1.0 + e)
    }
}

/// One node's local loss f_i.
#[derive(Debug)]
pub struct LogReg {
    pub a: Csr,
    pub b: Vec<f64>,
    pub mu: f64,
    /// scratch for A·x (len m); reused across calls on the hot path.
    /// A `Mutex` (uncontended; each engine owns its LogReg) rather than a
    /// `RefCell` so the problem stays `Sync` and can be shared across the
    /// parallel sweep executor's threads.
    m_scratch: std::sync::Mutex<Vec<f64>>,
}

impl Clone for LogReg {
    fn clone(&self) -> LogReg {
        LogReg {
            a: self.a.clone(),
            b: self.b.clone(),
            mu: self.mu,
            m_scratch: std::sync::Mutex::new(vec![0.0; self.a.rows]),
        }
    }
}

impl LogReg {
    pub fn new(a: Csr, b: Vec<f64>, mu: f64) -> LogReg {
        assert_eq!(a.rows, b.len());
        let m = a.rows;
        LogReg {
            a,
            b,
            mu,
            m_scratch: std::sync::Mutex::new(vec![0.0; m]),
        }
    }

    pub fn from_shard(s: &Shard, mu: f64) -> LogReg {
        LogReg::new(s.a.clone(), s.b.clone(), mu)
    }

    pub fn dim(&self) -> usize {
        self.a.cols
    }

    pub fn num_points(&self) -> usize {
        self.a.rows
    }

    /// f_i(x)
    pub fn loss(&self, x: &[f64]) -> f64 {
        let mut z = self.m_scratch.lock().unwrap();
        self.a.matvec_into(x, &mut z);
        let m = self.a.rows as f64;
        let mut s = 0.0;
        for (j, &bj) in self.b.iter().enumerate() {
            s += softplus(bj * z[j]);
        }
        s / m + 0.5 * self.mu * vector::norm2(x)
    }

    /// ∇f_i(x) = (1/m) Aᵀ(b ∘ σ(b ∘ Ax)) + μx
    pub fn grad_into(&self, x: &[f64], out: &mut [f64]) {
        let mut z = self.m_scratch.lock().unwrap();
        self.a.matvec_into(x, &mut z);
        let m = self.a.rows as f64;
        for (j, &bj) in self.b.iter().enumerate() {
            z[j] = bj * sigmoid(bj * z[j]) / m;
        }
        self.a.tmatvec_into(&z, out);
        vector::axpy(self.mu, x, out);
    }

    pub fn grad(&self, x: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.dim()];
        self.grad_into(x, &mut out);
        out
    }

    /// (f_i(x), ∇f_i(x)) with a single A·x product.
    pub fn loss_and_grad(&self, x: &[f64], grad_out: &mut [f64]) -> f64 {
        let mut z = self.m_scratch.lock().unwrap();
        self.a.matvec_into(x, &mut z);
        let m = self.a.rows as f64;
        let mut loss = 0.0;
        for (j, &bj) in self.b.iter().enumerate() {
            let t = bj * z[j];
            loss += softplus(t);
            z[j] = bj * sigmoid(t) / m;
        }
        self.a.tmatvec_into(&z, grad_out);
        vector::axpy(self.mu, x, grad_out);
        loss / m + 0.5 * self.mu * vector::norm2(x)
    }
}

/// The full distributed problem: local losses + their average.
#[derive(Clone, Debug)]
pub struct Problem {
    pub locals: Vec<LogReg>,
    pub mu: f64,
    pub dim: usize,
}

impl Problem {
    pub fn from_shards(shards: &[Shard], mu: f64) -> Problem {
        assert!(!shards.is_empty());
        let dim = shards[0].dim();
        Problem {
            locals: shards.iter().map(|s| LogReg::from_shard(s, mu)).collect(),
            mu,
            dim,
        }
    }

    pub fn n(&self) -> usize {
        self.locals.len()
    }

    /// f(x) = (1/n) Σ f_i(x)
    pub fn loss(&self, x: &[f64]) -> f64 {
        self.locals.iter().map(|l| l.loss(x)).sum::<f64>() / self.n() as f64
    }

    /// ∇f(x)
    pub fn grad(&self, x: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.dim];
        let mut tmp = vec![0.0; self.dim];
        for l in &self.locals {
            l.grad_into(x, &mut tmp);
            vector::axpy(1.0, &tmp, &mut out);
        }
        vector::scale(1.0 / self.n() as f64, &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::util::rng::Rng;

    fn toy_logreg(seed: u64) -> LogReg {
        let ds = synth::generate(&synth::tiny_spec(), seed);
        let (_, shards) = ds.prepare(3, seed);
        LogReg::from_shard(&shards[0], 1e-3)
    }

    #[test]
    fn softplus_stable_and_correct() {
        assert!((softplus(0.0) - (2.0f64).ln()).abs() < 1e-15);
        assert!((softplus(1.0) - (1.0 + 1.0f64.exp()).ln()).abs() < 1e-12);
        // large arguments must not overflow
        assert!((softplus(800.0) - 800.0).abs() < 1e-9);
        assert!(softplus(-800.0).abs() < 1e-9);
    }

    #[test]
    fn sigmoid_stable_and_symmetric() {
        assert_eq!(sigmoid(0.0), 0.5);
        assert!((sigmoid(3.0) + sigmoid(-3.0) - 1.0).abs() < 1e-15);
        assert!(sigmoid(800.0) <= 1.0);
        assert!(sigmoid(-800.0) >= 0.0);
    }

    #[test]
    fn grad_matches_finite_differences() {
        let l = toy_logreg(1);
        let mut rng = Rng::new(2);
        let x: Vec<f64> = (0..l.dim()).map(|_| rng.normal()).collect();
        let g = l.grad(&x);
        let h = 1e-6;
        for j in [0usize, 3, 7, l.dim() - 1] {
            let mut xp = x.clone();
            let mut xm = x.clone();
            xp[j] += h;
            xm[j] -= h;
            let fd = (l.loss(&xp) - l.loss(&xm)) / (2.0 * h);
            assert!(
                (fd - g[j]).abs() < 1e-6 * (1.0 + fd.abs()),
                "coordinate {j}: fd={fd} grad={}",
                g[j]
            );
        }
    }

    #[test]
    fn loss_and_grad_consistent() {
        let l = toy_logreg(3);
        let mut rng = Rng::new(4);
        let x: Vec<f64> = (0..l.dim()).map(|_| rng.normal() * 0.3).collect();
        let mut g = vec![0.0; l.dim()];
        let f = l.loss_and_grad(&x, &mut g);
        assert!((f - l.loss(&x)).abs() < 1e-14);
        let g2 = l.grad(&x);
        for i in 0..l.dim() {
            assert!((g[i] - g2[i]).abs() < 1e-14);
        }
    }

    #[test]
    fn loss_is_mu_strongly_convex_along_segments() {
        let l = toy_logreg(5);
        let mut rng = Rng::new(6);
        for _ in 0..10 {
            let x: Vec<f64> = (0..l.dim()).map(|_| rng.normal()).collect();
            let y: Vec<f64> = (0..l.dim()).map(|_| rng.normal()).collect();
            // f(y) ≥ f(x) + <∇f(x), y−x> + μ/2 ‖y−x‖²
            let g = l.grad(&x);
            let mut diff = vec![0.0; l.dim()];
            vector::sub_into(&y, &x, &mut diff);
            let lower = l.loss(&x) + vector::dot(&g, &diff) + 0.5 * l.mu * vector::norm2(&diff);
            assert!(l.loss(&y) >= lower - 1e-10);
        }
    }

    #[test]
    fn problem_grad_is_average() {
        let ds = synth::generate(&synth::tiny_spec(), 7);
        let (_, shards) = ds.prepare(4, 7);
        let p = Problem::from_shards(&shards, 1e-3);
        let x: Vec<f64> = (0..p.dim).map(|i| (i as f64 * 0.1).sin()).collect();
        let g = p.grad(&x);
        let mut manual = vec![0.0; p.dim];
        for l in &p.locals {
            vector::axpy(1.0, &l.grad(&x), &mut manual);
        }
        vector::scale(0.25, &mut manual);
        for i in 0..p.dim {
            assert!((g[i] - manual[i]).abs() < 1e-14);
        }
    }

    #[test]
    fn gradient_at_zero_nonzero() {
        // x*=0 only in degenerate cases; the synthetic data plants a model.
        let l = toy_logreg(8);
        let g = l.grad(&vec![0.0; l.dim()]);
        assert!(vector::norm(&g) > 1e-6);
    }
}
