//! Smoothness matrices and the paper's constants.
//!
//! For the logistic objective each local loss is `L_i`-smooth with
//! `L_i = (1/4m_i) A_iᵀA_i + μI` (Lemma 1). This module builds the root
//! operators `L_i^{1/2}`, `L_i^{†1/2}` (choosing dense vs low-rank+ridge
//! per shard), and computes every constant used by the theory and the
//! experiments:
//!
//! * `L_i = λ_max(L_i)`, `L_max`, `L = λ_max(L)` of the average loss;
//! * `diag(L_i)` — the inputs to importance sampling (eqs 16/19/21);
//! * `ν, ν₁, ν₂` (eq. 14), `𝓛̃_i` (eq. 15 for independent samplings),
//!   `ω_i` and `𝓛̃_max, ω_max`.

use crate::data::Shard;
use crate::linalg::dense::Mat;
use crate::linalg::eigen::power_lambda_max;
use crate::linalg::psd::PsdRoot;
use crate::linalg::sparse::Csr;

/// Smoothness data for one worker.
#[derive(Clone, Debug)]
pub struct LocalSmoothness {
    /// root operator for L_i (supports L^{1/2}, L^{†1/2}, L^{†})
    pub root: PsdRoot,
    /// diag(L_i)
    pub diag: Vec<f64>,
    /// λ_max(L_i)
    pub l_i: f64,
}

/// Smoothness data for the whole problem.
#[derive(Clone, Debug)]
pub struct Smoothness {
    pub locals: Vec<LocalSmoothness>,
    /// λ_max of L (smoothness matrix of f = (1/n)Σf_i)
    pub l: f64,
    pub l_max: f64,
    pub mu: f64,
    pub dim: usize,
    /// global smoothness root L of f — built lazily via [`Smoothness::with_global`]
    /// (needed only by DIANA++ and the single-node methods)
    pub global: Option<LocalSmoothness>,
}

/// Above this dimension the dense d×d eigendecomposition is avoided even
/// when m_i ≥ d (never triggered by the paper's datasets, where either
/// d ≤ 500 or m_i ≪ d).
const DENSE_DIM_CAP: usize = 1024;

pub fn build_local(a: &Csr, mu: f64) -> LocalSmoothness {
    let (m, d) = (a.rows, a.cols);
    let c = 1.0 / (4.0 * m as f64);
    let mut diag = a.gram_diag();
    for v in diag.iter_mut() {
        *v = *v * c + mu;
    }
    let root = if m < d || d > DENSE_DIM_CAP {
        // low-rank + ridge: L_i = c·AᵀA + μI via the m×m Gram
        let a_rows = a.to_dense();
        let gram_t = a.gram_t_dense();
        PsdRoot::from_lowrank_ridge(&a_rows, &gram_t, c, mu)
    } else {
        let mut l = a.gram_dense();
        l.scale(c);
        l.add_diag(mu);
        PsdRoot::from_dense(&l)
    };
    let l_i = root.lambda_max();
    LocalSmoothness { root, diag, l_i }
}

impl Smoothness {
    /// Build all per-shard roots + the global λ_max(L).
    ///
    /// The per-shard eigendecompositions (one `build_local` each — the
    /// dominant cost of sweep startup for n ≫ 8) run in parallel on the
    /// [`pool`](crate::experiments::pool) executor. Each shard's build is
    /// pure sequential arithmetic with no shared state, so the result is
    /// *bitwise identical* to the sequential build for every thread count
    /// (asserted in the tests below).
    pub fn build(shards: &[Shard], mu: f64) -> Smoothness {
        Smoothness::build_with_threads(shards, mu, crate::experiments::pool::default_threads())
    }

    /// [`Smoothness::build`] with an explicit thread count (≤ 1 ⇒ the
    /// sequential reference path).
    pub fn build_with_threads(shards: &[Shard], mu: f64, threads: usize) -> Smoothness {
        assert!(!shards.is_empty());
        let dim = shards[0].dim();
        let locals: Vec<LocalSmoothness> =
            crate::experiments::pool::run_cells(shards.len(), threads, |i| {
                build_local(&shards[i].a, mu)
            });
        let l_max = locals.iter().map(|l| l.l_i).fold(0.0, f64::max);

        // λ_max(L) with L = (1/(4nm)) AᵀA + μI applied implicitly over all
        // shards (equal shard sizes by construction).
        let total_points: usize = shards.iter().map(|s| s.num_points()).sum();
        let scale = 1.0 / (4.0 * total_points as f64);
        let mut shard_tmp: Vec<Vec<f64>> =
            shards.iter().map(|s| vec![0.0; s.num_points()]).collect();
        let l = power_lambda_max(
            dim,
            |x, y| {
                y.iter_mut().for_each(|v| *v = 0.0);
                for (s, tmp) in shards.iter().zip(shard_tmp.iter_mut()) {
                    s.a.matvec_into(x, tmp);
                    // y += Aᵀ(Ax) accumulated across shards
                    for r in 0..s.num_points() {
                        let (idx, val) = s.a.row_entries(r);
                        let t = tmp[r];
                        for k in 0..idx.len() {
                            y[idx[k] as usize] += t * val[k];
                        }
                    }
                }
                for (yi, xi) in y.iter_mut().zip(x.iter()) {
                    *yi = *yi * scale + mu * xi;
                }
            },
            1e-12,
            20_000,
            0xACE,
        );

        Smoothness {
            locals,
            l,
            l_max,
            mu,
            dim,
            global: None,
        }
    }

    /// Attach the global smoothness root of f = (1/n)Σf_i, built from the
    /// concatenated dataset (L = (1/(4nm))AᵀA + μI = (1/n)Σ L_i for equal
    /// shards). Needed by DIANA++ (server-side compression) and the
    /// single-node Appendix-B methods.
    pub fn with_global(mut self, global_data: &crate::linalg::sparse::Csr) -> Smoothness {
        self.global = Some(build_local(global_data, self.mu));
        self
    }

    pub fn n(&self) -> usize {
        self.locals.len()
    }

    /// ν = ΣL_i / max L_i ∈ [1, n] (eq. 14)
    pub fn nu(&self) -> f64 {
        let sum: f64 = self.locals.iter().map(|l| l.l_i).sum();
        sum / self.l_max
    }

    /// ν_s = max_i Σ_j L_{i;j}^{1/s} / max_j L_{i;j}^{1/s} ∈ [1, d] (eq. 14)
    pub fn nu_s(&self, s: f64) -> f64 {
        self.locals
            .iter()
            .map(|loc| {
                let pows: Vec<f64> = loc.diag.iter().map(|&v| v.powf(1.0 / s)).collect();
                let max = pows.iter().cloned().fold(0.0, f64::max);
                let sum: f64 = pows.iter().sum();
                if max > 0.0 {
                    sum / max
                } else {
                    1.0
                }
            })
            .fold(0.0, f64::max)
    }

    /// `L̄_max = max_{i,j} L_{i;jj}` — "bold L" of eq. (57).
    pub fn diag_max(&self) -> f64 {
        self.locals
            .iter()
            .flat_map(|l| l.diag.iter().copied())
            .fold(0.0, f64::max)
    }

    /// Condition number L_max/μ (used by Table 2 regime checks).
    pub fn kappa_max(&self) -> f64 {
        self.l_max / self.mu
    }
}

/// 𝓛̃ for an *independent* sampling with probabilities `p` and smoothness
/// diagonal `diag` (eq. 15): `max_j (1/p_j − 1)·L_jj`.
pub fn tilde_l_independent(p: &[f64], diag: &[f64]) -> f64 {
    assert_eq!(p.len(), diag.len());
    p.iter()
        .zip(diag)
        .map(|(&pj, &lj)| {
            assert!(pj > 0.0 && pj <= 1.0, "improper sampling p={pj}");
            (1.0 / pj - 1.0) * lj
        })
        .fold(0.0, f64::max)
}

/// ω for a sampling with probabilities `p`: `max_j 1/p_j − 1`.
pub fn omega(p: &[f64]) -> f64 {
    p.iter()
        .map(|&pj| 1.0 / pj - 1.0)
        .fold(0.0, f64::max)
}

/// Exact `𝓛̃ = λ_max(P̃ ∘ L)` for an independent sampling against a dense L
/// (test oracle for [`tilde_l_independent`]). `P̃` has zero diagonal and
/// off-diagonal `p_{jl}/(p_j p_l) − 1 = 0` for independent samplings, so
/// the result should equal the diagonal formula; kept as a cross-check.
pub fn tilde_l_dense_oracle(p: &[f64], l: &Mat) -> f64 {
    let d = p.len();
    let mut m = Mat::zeros(d, d);
    for j in 0..d {
        for k in 0..d {
            let pjk = if j == k { p[j] } else { p[j] * p[k] };
            let tilde = pjk / (p[j] * p[k]) - 1.0;
            m[(j, k)] = tilde * l[(j, k)];
        }
    }
    crate::linalg::eigen::lambda_max(&m, 1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::linalg::vector;
    use crate::util::rng::Rng;

    fn setup(n: usize, seed: u64) -> (Vec<Shard>, Smoothness) {
        let ds = synth::generate(&synth::tiny_spec(), seed);
        let (_, shards) = ds.prepare(n, seed);
        let sm = Smoothness::build(&shards, 1e-3);
        (shards, sm)
    }

    #[test]
    fn parallel_build_bitwise_identical_to_sequential() {
        // §Perf: Smoothness::build parallelizes the per-shard
        // eigendecompositions; every derived quantity must stay bit-for-bit.
        let ds = synth::generate(&synth::tiny_spec(), 21);
        let (_, shards) = ds.prepare(6, 21);
        let seq = Smoothness::build_with_threads(&shards, 1e-3, 1);
        let mut rng = Rng::new(99);
        let probes: Vec<Vec<f64>> = (0..3)
            .map(|_| (0..seq.dim).map(|_| rng.normal()).collect())
            .collect();
        for threads in [2, 4, 8] {
            let par = Smoothness::build_with_threads(&shards, 1e-3, threads);
            assert_eq!(par.l.to_bits(), seq.l.to_bits(), "L diverged");
            assert_eq!(par.l_max.to_bits(), seq.l_max.to_bits(), "L_max diverged");
            assert_eq!(par.locals.len(), seq.locals.len());
            for (a, b) in par.locals.iter().zip(&seq.locals) {
                assert_eq!(a.l_i.to_bits(), b.l_i.to_bits(), "l_i diverged");
                assert_eq!(a.diag.len(), b.diag.len());
                for (x, y) in a.diag.iter().zip(&b.diag) {
                    assert_eq!(x.to_bits(), y.to_bits(), "diag diverged");
                }
                // root operators agree on random probes, bit-for-bit
                let mut oa = vec![0.0; seq.dim];
                let mut ob = vec![0.0; seq.dim];
                let mut coeff = Vec::new();
                for p in &probes {
                    a.root.apply_pow_into_with(0.5, p, &mut oa, &mut coeff);
                    b.root.apply_pow_into_with(0.5, p, &mut ob, &mut coeff);
                    for (x, y) in oa.iter().zip(&ob) {
                        assert_eq!(x.to_bits(), y.to_bits(), "root apply diverged");
                    }
                }
            }
        }
    }

    #[test]
    fn local_smoothness_diag_matches_root() {
        let (_, sm) = setup(3, 1);
        for loc in &sm.locals {
            let d_from_root = loc.root.diag_pow(1.0);
            for j in 0..loc.diag.len() {
                assert!(
                    (loc.diag[j] - d_from_root[j]).abs() < 1e-9,
                    "diag mismatch {} vs {}",
                    loc.diag[j],
                    d_from_root[j]
                );
            }
        }
    }

    #[test]
    fn smoothness_inequality_holds() {
        // f_i(y) ≤ f_i(x) + <∇f_i(x), y−x> + ½‖y−x‖²_{L_i}
        let ds = synth::generate(&synth::tiny_spec(), 2);
        let (_, shards) = ds.prepare(3, 2);
        let sm = Smoothness::build(&shards, 1e-3);
        let mut rng = Rng::new(3);
        for (s, loc) in shards.iter().zip(&sm.locals) {
            let lr = crate::objective::logreg::LogReg::from_shard(s, 1e-3);
            for _ in 0..5 {
                let x: Vec<f64> = (0..lr.dim()).map(|_| rng.normal()).collect();
                let y: Vec<f64> = (0..lr.dim()).map(|_| rng.normal()).collect();
                let g = lr.grad(&x);
                let mut diff = vec![0.0; lr.dim()];
                vector::sub_into(&y, &x, &mut diff);
                let quad = loc.root.wnorm2(1.0, &diff);
                let upper = lr.loss(&x) + vector::dot(&g, &diff) + 0.5 * quad;
                assert!(lr.loss(&y) <= upper + 1e-10);
            }
        }
    }

    #[test]
    fn l_bounds() {
        let (_, sm) = setup(3, 4);
        // μ ≤ L ≤ (1/n)ΣL_i ≤ L_max
        let avg: f64 = sm.locals.iter().map(|l| l.l_i).sum::<f64>() / sm.n() as f64;
        assert!(sm.l >= sm.mu * 0.999);
        assert!(sm.l <= avg * (1.0 + 1e-6), "L={} avg={}", sm.l, avg);
        assert!(sm.l_max >= sm.locals.iter().map(|l| l.l_i).fold(0.0, f64::max) * 0.999);
    }

    #[test]
    fn nu_ranges() {
        let (_, sm) = setup(4, 5);
        let nu = sm.nu();
        assert!(nu >= 1.0 && nu <= sm.n() as f64, "nu={nu}");
        for s in [1.0, 2.0] {
            let ns = sm.nu_s(s);
            assert!(ns >= 1.0 && ns <= sm.dim as f64, "nu_{s}={ns}");
        }
    }

    #[test]
    fn tilde_l_formula_uniform() {
        // uniform p=τ/d ⇒ 𝓛̃ = (d/τ−1)·max_j L_jj
        let (_, sm) = setup(3, 6);
        let d = sm.dim;
        let tau = 2.0;
        let p = vec![tau / d as f64; d];
        for loc in &sm.locals {
            let t = tilde_l_independent(&p, &loc.diag);
            let expected =
                (d as f64 / tau - 1.0) * loc.diag.iter().cloned().fold(0.0, f64::max);
            assert!((t - expected).abs() < 1e-10);
        }
    }

    #[test]
    fn tilde_l_le_omega_lmax_diag() {
        // 𝓛̃_i ≤ ω_i · max_j L_jj always
        let (_, sm) = setup(3, 7);
        let mut rng = Rng::new(8);
        for loc in &sm.locals {
            let p: Vec<f64> = (0..sm.dim).map(|_| rng.uniform_in(0.05, 1.0)).collect();
            let t = tilde_l_independent(&p, &loc.diag);
            let bound = omega(&p) * loc.diag.iter().cloned().fold(0.0, f64::max);
            assert!(t <= bound + 1e-12);
        }
    }

    #[test]
    fn lowrank_path_used_when_m_small() {
        // shard with m < d must use the low-rank representation
        let spec = synth::SynthSpec {
            name: "mini_duke",
            points: 8,
            d: 40,
            n: 2,
            nnz_per_row: 40,
            scale_alpha: 1.0,
            noise: 0.0,
        };
        let ds = synth::generate(&spec, 1);
        let (_, shards) = ds.prepare(2, 1);
        let sm = Smoothness::build(&shards, 1e-3);
        for loc in &sm.locals {
            assert!(matches!(loc.root, PsdRoot::LowRankRidge { .. }));
            // λ_min = μ because rank(AᵀA) = m < d
            assert!((loc.root.lambda_min() - 1e-3).abs() < 1e-12);
        }
    }

    #[test]
    fn lambda_max_of_f_smaller_than_average_matrix() {
        // sanity on the implicit power iteration: compare against a dense
        // construction on a tiny problem
        let spec = synth::SynthSpec {
            name: "t",
            points: 30,
            d: 10,
            n: 3,
            nnz_per_row: 5,
            scale_alpha: 0.5,
            noise: 0.0,
        };
        let ds = synth::generate(&spec, 9);
        let (global, shards) = ds.prepare(3, 9);
        let sm = Smoothness::build(&shards, 1e-3);
        let mut l_dense = global.a.gram_dense();
        l_dense.scale(1.0 / (4.0 * global.num_points() as f64));
        l_dense.add_diag(1e-3);
        let expected = crate::linalg::eigen::lambda_max(&l_dense, 1e-12);
        assert!(
            (sm.l - expected).abs() < 1e-8 * expected,
            "L={} expected={expected}",
            sm.l
        );
    }

    #[test]
    fn dense_oracle_agrees_with_diag_formula() {
        // for independent samplings P̃∘L is diagonal ⇒ λ_max is the max entry
        let mut rng = Rng::new(10);
        let d = 8;
        let b = Mat::from_rows(
            (0..12)
                .map(|_| (0..d).map(|_| rng.normal()).collect())
                .collect(),
        );
        let mut l = b.gram();
        l.scale(1.0 / 48.0);
        l.add_diag(1e-3);
        let p: Vec<f64> = (0..d).map(|_| rng.uniform_in(0.2, 0.9)).collect();
        let fast = tilde_l_independent(&p, &l.diag());
        let oracle = tilde_l_dense_oracle(&p, &l);
        assert!(
            (fast - oracle).abs() < 1e-8 * fast.max(1.0),
            "fast={fast} oracle={oracle}"
        );
    }
}
