//! AVX-512 arm (cargo feature `avx512`, Rust ≥ 1.89 — the release that
//! stabilized the `_mm512_*` intrinsics; the crate's default MSRV stays
//! 1.70 because this module is compiled out without the feature).
//!
//! Only the *elementwise* kernels are widened to 512 bits: they are
//! order-free, so an 8-lane body stays bitwise identical to the scalar
//! arm. Reductions keep the canonical 4-lane order and therefore reuse
//! the AVX2 bodies (see the dispatch in [`super`]); widening them would
//! change the summation order and break the cross-arm bitwise contract.
//!
//! Safety contracts mirror [`super::avx2`]: the dispatch wrapper proves
//! the length relations and only routes here when `avx512f` was runtime
//! detected.

#![allow(clippy::missing_safety_doc)] // contracts are on the module + per fn below

use core::arch::x86_64::*;

/// SAFETY: AVX-512F available; `x.len() == y.len()`.
#[target_feature(enable = "avx512f")]
pub unsafe fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    let n = x.len();
    let chunks = n / 8;
    let xp = x.as_ptr();
    let yp = y.as_mut_ptr();
    let av = _mm512_set1_pd(alpha);
    for i in 0..chunks {
        let yv = _mm512_loadu_pd(yp.add(i * 8));
        let xv = _mm512_loadu_pd(xp.add(i * 8));
        _mm512_storeu_pd(yp.add(i * 8), _mm512_add_pd(yv, _mm512_mul_pd(av, xv)));
    }
    for j in chunks * 8..n {
        y[j] += alpha * x[j];
    }
}

/// SAFETY: AVX-512F available; `a.len() == b.len() == out.len()`.
#[target_feature(enable = "avx512f")]
pub unsafe fn lincomb_into(alpha: f64, a: &[f64], beta: f64, b: &[f64], out: &mut [f64]) {
    let n = a.len();
    let chunks = n / 8;
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    let op = out.as_mut_ptr();
    let av = _mm512_set1_pd(alpha);
    let bv = _mm512_set1_pd(beta);
    for i in 0..chunks {
        let ta = _mm512_mul_pd(av, _mm512_loadu_pd(ap.add(i * 8)));
        let tb = _mm512_mul_pd(bv, _mm512_loadu_pd(bp.add(i * 8)));
        _mm512_storeu_pd(op.add(i * 8), _mm512_add_pd(ta, tb));
    }
    for j in chunks * 8..n {
        out[j] = alpha * a[j] + beta * b[j];
    }
}

/// SAFETY: AVX-512F available; `a.len() == b.len()`.
#[target_feature(enable = "avx512f")]
pub unsafe fn rot2(c: f64, s: f64, a: &mut [f64], b: &mut [f64]) {
    let n = a.len();
    let chunks = n / 8;
    let ap = a.as_mut_ptr();
    let bp = b.as_mut_ptr();
    let cv = _mm512_set1_pd(c);
    let sv = _mm512_set1_pd(s);
    for i in 0..chunks {
        let va = _mm512_loadu_pd(ap.add(i * 8));
        let vb = _mm512_loadu_pd(bp.add(i * 8));
        _mm512_storeu_pd(
            ap.add(i * 8),
            _mm512_sub_pd(_mm512_mul_pd(cv, va), _mm512_mul_pd(sv, vb)),
        );
        _mm512_storeu_pd(
            bp.add(i * 8),
            _mm512_add_pd(_mm512_mul_pd(sv, va), _mm512_mul_pd(cv, vb)),
        );
    }
    for j in chunks * 8..n {
        let aj = a[j];
        let bj = b[j];
        a[j] = c * aj - s * bj;
        b[j] = s * aj + c * bj;
    }
}
