//! Portable blocked-loop arm — the dispatch fallback and the bitwise
//! reference for the SIMD arms.
//!
//! Every kernel here fixes the canonical evaluation order documented in
//! the [module docs](super): reductions run 4 independent accumulator
//! lanes over `n/4` blocks, reduce as `(s0+s1)+(s2+s3)`, and finish with
//! a sequential scalar tail; elementwise kernels are plain per-element
//! mul/add. The AVX2/AVX-512 arms replay exactly these operations on
//! wider registers, so any divergence is a bug (property-tested in
//! `tests/kernel_parity.rs`). LLVM auto-vectorizes most of these loops —
//! the explicit arms exist for the cases it does not (the CSR gather) and
//! to make the lane structure an API-level invariant instead of an
//! optimizer outcome.

/// 4-lane dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for i in 0..chunks {
        let j = i * 4;
        s0 += a[j] * b[j];
        s1 += a[j + 1] * b[j + 1];
        s2 += a[j + 2] * b[j + 2];
        s3 += a[j + 3] * b[j + 3];
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for j in chunks * 4..n {
        s += a[j] * b[j];
    }
    s
}

/// 4-lane squared distance ‖a − b‖².
#[inline]
pub fn dist2(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for i in 0..chunks {
        let j = i * 4;
        let d0 = a[j] - b[j];
        let d1 = a[j + 1] - b[j + 1];
        let d2 = a[j + 2] - b[j + 2];
        let d3 = a[j + 3] - b[j + 3];
        s0 += d0 * d0;
        s1 += d1 * d1;
        s2 += d2 * d2;
        s3 += d3 * d3;
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for j in chunks * 4..n {
        let d = a[j] - b[j];
        s += d * d;
    }
    s
}

/// 4-lane weighted squared norm Σ wᵢ·xᵢ² (each term `(w·x)·x`).
#[inline]
pub fn wnorm2_diag(x: &[f64], w: &[f64]) -> f64 {
    let n = x.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for i in 0..chunks {
        let j = i * 4;
        s0 += w[j] * x[j] * x[j];
        s1 += w[j + 1] * x[j + 1] * x[j + 1];
        s2 += w[j + 2] * x[j + 2] * x[j + 2];
        s3 += w[j + 3] * x[j + 3] * x[j + 3];
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for j in chunks * 4..n {
        s += w[j] * x[j] * x[j];
    }
    s
}

/// y += alpha·x, 4-element blocks (elementwise ⇒ order-free).
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    let n = x.len();
    let chunks = n / 4;
    for i in 0..chunks {
        let j = i * 4;
        y[j] += alpha * x[j];
        y[j + 1] += alpha * x[j + 1];
        y[j + 2] += alpha * x[j + 2];
        y[j + 3] += alpha * x[j + 3];
    }
    for j in chunks * 4..n {
        y[j] += alpha * x[j];
    }
}

/// out = alpha·a + beta·b (elementwise).
#[inline]
pub fn lincomb_into(alpha: f64, a: &[f64], beta: f64, b: &[f64], out: &mut [f64]) {
    for i in 0..a.len() {
        out[i] = alpha * a[i] + beta * b[i];
    }
}

/// Plane rotation: `(a, b) ← (c·a − s·b, s·a + c·b)` (elementwise).
#[inline]
pub fn rot2(c: f64, s: f64, a: &mut [f64], b: &mut [f64]) {
    for i in 0..a.len() {
        let ai = a[i];
        let bi = b[i];
        a[i] = c * ai - s * bi;
        b[i] = s * ai + c * bi;
    }
}

/// Dense row-major matvec: 4-row blocks, each row accumulated on the
/// canonical 4 lanes (so the remainder-row path, a plain [`dot`], and the
/// AVX2 arm all agree bitwise).
pub fn mat_matvec_into(data: &[f64], rows: usize, cols: usize, x: &[f64], out: &mut [f64]) {
    let r4 = rows / 4 * 4;
    let c4 = cols / 4 * 4;
    let mut r = 0;
    while r < r4 {
        let row0 = &data[r * cols..(r + 1) * cols];
        let row1 = &data[(r + 1) * cols..(r + 2) * cols];
        let row2 = &data[(r + 2) * cols..(r + 3) * cols];
        let row3 = &data[(r + 3) * cols..(r + 4) * cols];
        let mut s = [[0.0f64; 4]; 4];
        let mut c = 0;
        while c < c4 {
            for l in 0..4 {
                let xc = x[c + l];
                s[0][l] += row0[c + l] * xc;
                s[1][l] += row1[c + l] * xc;
                s[2][l] += row2[c + l] * xc;
                s[3][l] += row3[c + l] * xc;
            }
            c += 4;
        }
        let mut t = [
            (s[0][0] + s[0][1]) + (s[0][2] + s[0][3]),
            (s[1][0] + s[1][1]) + (s[1][2] + s[1][3]),
            (s[2][0] + s[2][1]) + (s[2][2] + s[2][3]),
            (s[3][0] + s[3][1]) + (s[3][2] + s[3][3]),
        ];
        while c < cols {
            let xc = x[c];
            t[0] += row0[c] * xc;
            t[1] += row1[c] * xc;
            t[2] += row2[c] * xc;
            t[3] += row3[c] * xc;
            c += 1;
        }
        out[r] = t[0];
        out[r + 1] = t[1];
        out[r + 2] = t[2];
        out[r + 3] = t[3];
        r += 4;
    }
    while r < rows {
        out[r] = dot(&data[r * cols..(r + 1) * cols], x);
        r += 1;
    }
}

/// CSR matvec: per-row 4-lane gather-accumulate.
pub fn csr_matvec_into(
    indptr: &[usize],
    indices: &[u32],
    values: &[f64],
    x: &[f64],
    out: &mut [f64],
) {
    for r in 0..out.len() {
        let (s, e) = (indptr[r], indptr[r + 1]);
        let idx = &indices[s..e];
        let val = &values[s..e];
        let nnz = idx.len();
        let k4 = nnz / 4 * 4;
        let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
        let mut k = 0;
        while k < k4 {
            s0 += val[k] * x[idx[k] as usize];
            s1 += val[k + 1] * x[idx[k + 1] as usize];
            s2 += val[k + 2] * x[idx[k + 2] as usize];
            s3 += val[k + 3] * x[idx[k + 3] as usize];
            k += 4;
        }
        let mut acc = (s0 + s1) + (s2 + s3);
        while k < nnz {
            acc += val[k] * x[idx[k] as usize];
            k += 1;
        }
        out[r] = acc;
    }
}

/// CSR transposed matvec (scatter), 4-wide unrolled. Zeroes `out` first.
/// Elementwise adds ⇒ bitwise identical across arms; the unroll is safe
/// because column indices are strictly increasing within a row, so the
/// four targets are distinct.
pub fn csr_tmatvec_into(
    indptr: &[usize],
    indices: &[u32],
    values: &[f64],
    y: &[f64],
    out: &mut [f64],
) {
    out.fill(0.0);
    for r in 0..y.len() {
        let yr = y[r];
        if yr == 0.0 {
            continue;
        }
        let (s, e) = (indptr[r], indptr[r + 1]);
        let idx = &indices[s..e];
        let val = &values[s..e];
        let nnz = idx.len();
        let k4 = nnz / 4 * 4;
        let mut k = 0;
        while k < k4 {
            out[idx[k] as usize] += yr * val[k];
            out[idx[k + 1] as usize] += yr * val[k + 1];
            out[idx[k + 2] as usize] += yr * val[k + 2];
            out[idx[k + 3] as usize] += yr * val[k + 3];
            k += 4;
        }
        while k < nnz {
            out[idx[k] as usize] += yr * val[k];
            k += 1;
        }
    }
}
