//! Explicit SIMD kernel layer with runtime dispatch.
//!
//! PR 1's blocked loops lean on LLVM auto-vectorization; this module makes
//! the vector lanes explicit (`std::arch` AVX2 intrinsics, AVX-512 behind
//! the off-by-default `avx512` cargo feature) behind the same safe
//! signatures the rest of the crate already calls
//! ([`vector`](crate::linalg::vector), [`Mat::matvec_into`]
//! (crate::linalg::dense::Mat::matvec_into), the CSR kernels). The CSR
//! matvec is where hand-written code wins outright: the per-row
//! `x[idx[k]]` loads become one `_mm256_i32gather_pd` per 4 nonzeros.
//!
//! # The dispatch seam
//!
//! [`active()`] picks a [`Level`] exactly once per process:
//!
//! 1. `SMX_NO_SIMD=1` (any non-empty value other than `0`) forces
//!    [`Level::Scalar`] — the portable blocked-loop fallback in
//!    [`scalar`]. This is how CI exercises both arms.
//! 2. Otherwise `is_x86_feature_detected!` selects the widest supported
//!    level: `avx512f` ⇒ [`Level::Avx512`] (only with the `avx512` cargo
//!    feature, which needs Rust ≥ 1.89), `avx2` ⇒ [`Level::Avx2`].
//! 3. Non-x86_64 targets and Miri always resolve to [`Level::Scalar`].
//!
//! Every public kernel (`dot`, `axpy`, …) reads the cached level; the
//! `*_at(level, …)` variants take it explicitly so tests and benches can
//! run *both dispatch arms in the same process* (see
//! `tests/kernel_parity.rs`).
//!
//! # Determinism contract
//!
//! All dispatch arms are **bitwise identical** for every kernel, on every
//! input — not merely ULP-close. This is what keeps `SMX_NO_SIMD=1` runs
//! bitwise reproducible against default runs, and it is cheap to provide:
//!
//! * Elementwise kernels (`axpy`, `lincomb_into`, `rot2`, the CSR
//!   `tmatvec` scatter) perform the same IEEE mul/add per element in every
//!   arm (no FMA contraction — `mul` then `add`, which is also what the
//!   scalar source expresses).
//! * Reductions (`dot`, `dist2`, `wnorm2_diag`, both matvecs) fix one
//!   canonical order: 4 independent lanes over `chunks = n/4` blocks,
//!   reduced as `(s0+s1)+(s2+s3)`, then a sequential scalar tail. The
//!   scalar arm writes that order with 4 named accumulators; the AVX2 arm
//!   holds the same 4 lanes in one `__m256d`. The AVX-512 arm deliberately
//!   reuses the AVX2 reduction bodies (8-lane accumulators would change
//!   the order) and only widens the elementwise kernels to 512 bits.
//!
//! The property suite asserts the cross-arm bitwise guarantee on
//! adversarial inputs (denormals, ±0, 1e300-scale magnitudes, remainder
//! tails 0–7, misaligned slices).
//!
//! # Safety
//!
//! All `unsafe` is cordoned here and in [`avx2`]/[`avx512`]. Two contract
//! families, each discharged *before* the `unsafe` call:
//!
//! * **CPU feature**: the safe `*_at` entry points `clamp` any level the
//!   hardware does not support down to `Scalar` before dispatching (one
//!   cached compare), so a caller-constructed [`Level`] can never reach a
//!   `#[target_feature]` body the CPU lacks — the wrappers stay sound for
//!   arbitrary safe callers, and levels from [`active()`] /
//!   [`Level::available()`] pass through unchanged.
//! * **Bounds**: the dispatch wrappers below `assert!` every slice-length
//!   relation the intrinsic bodies rely on (equal vector lengths,
//!   `data.len() == rows·cols`, CSR row ranges inside `indices`/`values`).
//!   The one data-dependent case — gather offsets in the CSR matvec —
//!   is checked per 4-chunk against `x.len()` immediately before the
//!   gather (plus a `cols ≤ i32::MAX` gate here, since the offsets ride
//!   in i32 lanes), so even a corrupted `Csr` panics like the scalar arm
//!   instead of reading out of bounds.

pub mod scalar;

#[cfg(target_arch = "x86_64")]
pub(crate) mod avx2;

#[cfg(all(target_arch = "x86_64", feature = "avx512"))]
pub(crate) mod avx512;

use std::sync::OnceLock;

/// A dispatch arm. Ordered by width (`Scalar < Avx2 < Avx512`); `Scalar`
/// is always available.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Portable blocked loops (the PR 1 kernels) — the fallback arm and
    /// the reference the SIMD arms must match bitwise.
    Scalar,
    /// 256-bit f64 lanes + `vgatherdpd` (x86_64 with AVX2).
    Avx2,
    /// 512-bit elementwise lanes; reductions share the AVX2 bodies to
    /// keep the canonical 4-lane order. Requires the `avx512` cargo
    /// feature (Rust ≥ 1.89) *and* runtime `avx512f`.
    Avx512,
}

impl Level {
    pub fn name(self) -> &'static str {
        match self {
            Level::Scalar => "scalar",
            Level::Avx2 => "avx2",
            Level::Avx512 => "avx512",
        }
    }

    /// Every level the running CPU supports (always includes `Scalar`),
    /// independent of `SMX_NO_SIMD` — this is what tests iterate to run
    /// all arms in one process.
    pub fn available() -> Vec<Level> {
        let mut v = vec![Level::Scalar];
        let top = detect();
        if top != Level::Scalar {
            v.push(Level::Avx2);
        }
        if top == Level::Avx512 {
            v.push(Level::Avx512);
        }
        v
    }
}

/// Widest level the hardware supports (ignores `SMX_NO_SIMD`).
pub fn detect() -> Level {
    if cfg!(miri) {
        return Level::Scalar;
    }
    #[cfg(target_arch = "x86_64")]
    {
        #[cfg(feature = "avx512")]
        if is_x86_feature_detected!("avx512f") {
            return Level::Avx512;
        }
        if is_x86_feature_detected!("avx2") {
            return Level::Avx2;
        }
    }
    Level::Scalar
}

/// Pure resolution rule: what [`active()`] returns given the env override
/// and the hardware level. Split out so tests can cover the override
/// without mutating process env.
pub fn resolve(no_simd: Option<&str>, hw: Level) -> Level {
    match no_simd {
        Some(v) if !v.is_empty() && v != "0" => Level::Scalar,
        _ => hw,
    }
}

static HW: OnceLock<Level> = OnceLock::new();

/// Cached hardware level (ignores `SMX_NO_SIMD`).
#[inline]
fn hw() -> Level {
    *HW.get_or_init(detect)
}

/// Soundness gate for the safe `*_at` entry points: `Level` is freely
/// constructible, so a caller could pass `Avx2` on a CPU without it —
/// clamp anything the hardware does not support down to `Scalar` before
/// the `unsafe` dispatch. One cached atomic load + compare per call;
/// levels from [`active()`]/[`Level::available()`] always pass through
/// unchanged.
#[inline]
fn clamp(level: Level) -> Level {
    if level <= hw() {
        level
    } else {
        Level::Scalar
    }
}

static ACTIVE: OnceLock<Level> = OnceLock::new();

/// The process-wide dispatch arm, selected once: `SMX_NO_SIMD` override
/// over [`detect()`].
#[inline]
pub fn active() -> Level {
    *ACTIVE.get_or_init(|| {
        let env = std::env::var("SMX_NO_SIMD").ok();
        resolve(env.as_deref(), hw())
    })
}

// ---- vector kernels ----------------------------------------------------
//
// Each wrapper asserts the length relations its unsafe arm relies on (the
// scalar arm would panic on the same violation via slice indexing, so the
// asserts change no observable behavior — they only make the bound
// explicit before the raw-pointer code runs).

#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    dot_at(active(), a, b)
}

#[inline]
pub fn dot_at(level: Level, a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    match clamp(level) {
        Level::Scalar => scalar::dot(a, b),
        // SAFETY: a non-scalar level implies AVX2 is available (module
        // contract); lengths asserted equal above.
        #[cfg(target_arch = "x86_64")]
        _ => unsafe { avx2::dot(a, b) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => scalar::dot(a, b),
    }
}

#[inline]
pub fn dist2(a: &[f64], b: &[f64]) -> f64 {
    dist2_at(active(), a, b)
}

#[inline]
pub fn dist2_at(level: Level, a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    match clamp(level) {
        Level::Scalar => scalar::dist2(a, b),
        // SAFETY: AVX2 available per level; lengths asserted equal.
        #[cfg(target_arch = "x86_64")]
        _ => unsafe { avx2::dist2(a, b) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => scalar::dist2(a, b),
    }
}

#[inline]
pub fn wnorm2_diag(x: &[f64], w: &[f64]) -> f64 {
    wnorm2_diag_at(active(), x, w)
}

#[inline]
pub fn wnorm2_diag_at(level: Level, x: &[f64], w: &[f64]) -> f64 {
    assert_eq!(x.len(), w.len());
    match clamp(level) {
        Level::Scalar => scalar::wnorm2_diag(x, w),
        // SAFETY: AVX2 available per level; lengths asserted equal.
        #[cfg(target_arch = "x86_64")]
        _ => unsafe { avx2::wnorm2_diag(x, w) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => scalar::wnorm2_diag(x, w),
    }
}

#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    axpy_at(active(), alpha, x, y)
}

#[inline]
pub fn axpy_at(level: Level, alpha: f64, x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), y.len());
    match clamp(level) {
        Level::Scalar => scalar::axpy(alpha, x, y),
        // SAFETY: AVX-512F available per level; lengths asserted equal.
        #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
        Level::Avx512 => unsafe { avx512::axpy(alpha, x, y) },
        // SAFETY: AVX2 available per level; lengths asserted equal.
        #[cfg(target_arch = "x86_64")]
        _ => unsafe { avx2::axpy(alpha, x, y) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => scalar::axpy(alpha, x, y),
    }
}

#[inline]
pub fn lincomb_into(alpha: f64, a: &[f64], beta: f64, b: &[f64], out: &mut [f64]) {
    lincomb_into_at(active(), alpha, a, beta, b, out)
}

#[inline]
pub fn lincomb_into_at(
    level: Level,
    alpha: f64,
    a: &[f64],
    beta: f64,
    b: &[f64],
    out: &mut [f64],
) {
    assert_eq!(a.len(), b.len());
    assert_eq!(a.len(), out.len());
    match clamp(level) {
        Level::Scalar => scalar::lincomb_into(alpha, a, beta, b, out),
        // SAFETY: AVX-512F available per level; lengths asserted equal.
        #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
        Level::Avx512 => unsafe { avx512::lincomb_into(alpha, a, beta, b, out) },
        // SAFETY: AVX2 available per level; lengths asserted equal.
        #[cfg(target_arch = "x86_64")]
        _ => unsafe { avx2::lincomb_into(alpha, a, beta, b, out) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => scalar::lincomb_into(alpha, a, beta, b, out),
    }
}

#[inline]
pub fn rot2(c: f64, s: f64, a: &mut [f64], b: &mut [f64]) {
    rot2_at(active(), c, s, a, b)
}

/// Plane rotation on two rows: `(a, b) ← (c·a − s·b, s·a + c·b)` —
/// the Jacobi eigensolver's inner update, elementwise so every arm is
/// bitwise identical.
#[inline]
pub fn rot2_at(level: Level, c: f64, s: f64, a: &mut [f64], b: &mut [f64]) {
    assert_eq!(a.len(), b.len());
    match clamp(level) {
        Level::Scalar => scalar::rot2(c, s, a, b),
        // SAFETY: AVX-512F available per level; lengths asserted equal.
        #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
        Level::Avx512 => unsafe { avx512::rot2(c, s, a, b) },
        // SAFETY: AVX2 available per level; lengths asserted equal.
        #[cfg(target_arch = "x86_64")]
        _ => unsafe { avx2::rot2(c, s, a, b) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => scalar::rot2(c, s, a, b),
    }
}

// ---- dense matvec ------------------------------------------------------

#[inline]
pub fn mat_matvec_into(data: &[f64], rows: usize, cols: usize, x: &[f64], out: &mut [f64]) {
    mat_matvec_into_at(active(), data, rows, cols, x, out)
}

/// `out = A·x` for a row-major `rows × cols` matrix in `data`.
pub fn mat_matvec_into_at(
    level: Level,
    data: &[f64],
    rows: usize,
    cols: usize,
    x: &[f64],
    out: &mut [f64],
) {
    assert_eq!(data.len(), rows * cols);
    assert_eq!(x.len(), cols);
    assert_eq!(out.len(), rows);
    match clamp(level) {
        Level::Scalar => scalar::mat_matvec_into(data, rows, cols, x, out),
        // SAFETY: AVX2 available per level; the three shape relations the
        // body's raw-pointer arithmetic needs are asserted above.
        #[cfg(target_arch = "x86_64")]
        _ => unsafe { avx2::mat_matvec_into(data, rows, cols, x, out) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => scalar::mat_matvec_into(data, rows, cols, x, out),
    }
}

// ---- CSR kernels -------------------------------------------------------

#[inline]
pub fn csr_matvec_into(
    indptr: &[usize],
    indices: &[u32],
    values: &[f64],
    x: &[f64],
    out: &mut [f64],
) {
    csr_matvec_into_at(active(), indptr, indices, values, x, out)
}

/// `out = A·x` for a CSR matrix (`out.len()` rows).
pub fn csr_matvec_into_at(
    level: Level,
    indptr: &[usize],
    indices: &[u32],
    values: &[f64],
    x: &[f64],
    out: &mut [f64],
) {
    assert_eq!(indptr.len(), out.len() + 1);
    assert_eq!(indices.len(), values.len());
    match clamp(level) {
        Level::Scalar => scalar::csr_matvec_into(indptr, indices, values, x, out),
        // The i32 gather lanes can only address offsets < 2^31; a larger
        // x would need i64 gathers, so fall back to scalar there.
        // SAFETY: AVX2 available per level; indptr/indices/values length
        // relations asserted above; row ranges and gather offsets are
        // re-checked inside (panic, not UB, on a corrupted matrix).
        #[cfg(target_arch = "x86_64")]
        _ if x.len() <= i32::MAX as usize => unsafe {
            avx2::csr_matvec_into(indptr, indices, values, x, out)
        },
        _ => scalar::csr_matvec_into(indptr, indices, values, x, out),
    }
}

#[inline]
pub fn csr_tmatvec_into(
    indptr: &[usize],
    indices: &[u32],
    values: &[f64],
    y: &[f64],
    out: &mut [f64],
) {
    csr_tmatvec_into_at(active(), indptr, indices, values, y, out)
}

/// `out = Aᵀ·y` scatter for a CSR matrix (`y.len()` rows); zeroes `out`
/// first.
pub fn csr_tmatvec_into_at(
    level: Level,
    indptr: &[usize],
    indices: &[u32],
    values: &[f64],
    y: &[f64],
    out: &mut [f64],
) {
    assert_eq!(indptr.len(), y.len() + 1);
    assert_eq!(indices.len(), values.len());
    match clamp(level) {
        Level::Scalar => scalar::csr_tmatvec_into(indptr, indices, values, y, out),
        // SAFETY: AVX2 available per level; length relations asserted
        // above; the scatter stores are bounds-checked slice indexing.
        #[cfg(target_arch = "x86_64")]
        _ => unsafe { avx2::csr_tmatvec_into(indptr, indices, values, y, out) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => scalar::csr_tmatvec_into(indptr, indices, values, y, out),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_env_override() {
        assert_eq!(resolve(Some("1"), Level::Avx2), Level::Scalar);
        assert_eq!(resolve(Some("yes"), Level::Avx512), Level::Scalar);
        assert_eq!(resolve(Some("0"), Level::Avx2), Level::Avx2);
        assert_eq!(resolve(Some(""), Level::Avx2), Level::Avx2);
        assert_eq!(resolve(None, Level::Avx2), Level::Avx2);
        assert_eq!(resolve(None, Level::Scalar), Level::Scalar);
    }

    #[test]
    fn unsupported_levels_clamp_to_scalar() {
        // a hand-constructed level above the hardware's must behave like
        // (and equal) the scalar arm instead of reaching unsafe code
        let a: Vec<f64> = (0..13).map(|i| i as f64 * 0.25 - 1.0).collect();
        let b: Vec<f64> = (0..13).map(|i| (i as f64).cos()).collect();
        for lvl in [Level::Avx2, Level::Avx512] {
            let d = dot_at(lvl, &a, &b);
            if !Level::available().contains(&lvl) {
                assert_eq!(d.to_bits(), dot_at(Level::Scalar, &a, &b).to_bits());
            }
            assert!(d.is_finite());
        }
    }

    #[test]
    fn available_always_starts_scalar() {
        let levels = Level::available();
        assert_eq!(levels[0], Level::Scalar);
        // whatever the hardware, the cached arm is one of the listed ones
        // unless SMX_NO_SIMD forced scalar (which is listed too)
        assert!(levels.contains(&active()));
    }

    #[test]
    fn every_available_level_runs_every_kernel() {
        // smoke: each arm executes without fault on a non-trivial shape;
        // cross-arm value identity is property-tested in kernel_parity.rs
        let a: Vec<f64> = (0..37).map(|i| (i as f64).sin()).collect();
        let b: Vec<f64> = (0..37).map(|i| (i as f64).cos()).collect();
        for lvl in Level::available() {
            let d = dot_at(lvl, &a, &b);
            assert!(d.is_finite());
            let mut y = b.clone();
            axpy_at(lvl, 0.5, &a, &mut y);
            let mut out = vec![0.0; 37];
            lincomb_into_at(lvl, 0.5, &a, -2.0, &b, &mut out);
            assert!(dist2_at(lvl, &a, &b) >= 0.0);
            assert!(wnorm2_diag_at(lvl, &a, &b).is_finite());
        }
    }
}
