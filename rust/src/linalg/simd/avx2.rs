//! AVX2 arm: 256-bit f64 lanes, hand-written gathers for the CSR matvec.
//!
//! Every function is `unsafe fn` + `#[target_feature(enable = "avx2")]`;
//! the caller (the dispatch wrappers in [`super`]) guarantees
//!
//! 1. the CPU supports AVX2 (runtime-detected [`Level`](super::Level)),
//! 2. the slice-length relations listed per function below.
//!
//! All memory access is either bounds-checked slice indexing or
//! `loadu`/`storeu` on offsets proven in-bounds by the loop structure
//! (`chunk·4 + 4 ≤ len`); the single data-dependent access — the gather —
//! is guarded by an explicit index check immediately before it. No FMA
//! anywhere: `mul` then `add`, matching the scalar arm bit-for-bit (see
//! the module's determinism contract).

#![allow(clippy::missing_safety_doc)] // contracts are on the module + per fn below

use core::arch::x86_64::*;

/// Horizontal sum in the canonical order `(s0 + s1) + (s2 + s3)`.
///
/// SAFETY: requires AVX (implied by the callers' `avx2` feature).
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn hsum(v: __m256d) -> f64 {
    let lo = _mm256_castpd256_pd128(v); // [s0, s1]
    let hi = _mm256_extractf128_pd::<1>(v); // [s2, s3]
    let lo_s = _mm_add_sd(lo, _mm_unpackhi_pd(lo, lo)); // s0 + s1
    let hi_s = _mm_add_sd(hi, _mm_unpackhi_pd(hi, hi)); // s2 + s3
    _mm_cvtsd_f64(_mm_add_sd(lo_s, hi_s))
}

/// SAFETY: AVX2 available; `a.len() == b.len()`.
#[target_feature(enable = "avx2")]
pub unsafe fn dot(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len();
    let chunks = n / 4;
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    let mut acc = _mm256_setzero_pd();
    for i in 0..chunks {
        let av = _mm256_loadu_pd(ap.add(i * 4));
        let bv = _mm256_loadu_pd(bp.add(i * 4));
        acc = _mm256_add_pd(acc, _mm256_mul_pd(av, bv));
    }
    let mut s = hsum(acc);
    for j in chunks * 4..n {
        s += a[j] * b[j];
    }
    s
}

/// SAFETY: AVX2 available; `a.len() == b.len()`.
#[target_feature(enable = "avx2")]
pub unsafe fn dist2(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len();
    let chunks = n / 4;
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    let mut acc = _mm256_setzero_pd();
    for i in 0..chunks {
        let d = _mm256_sub_pd(_mm256_loadu_pd(ap.add(i * 4)), _mm256_loadu_pd(bp.add(i * 4)));
        acc = _mm256_add_pd(acc, _mm256_mul_pd(d, d));
    }
    let mut s = hsum(acc);
    for j in chunks * 4..n {
        let d = a[j] - b[j];
        s += d * d;
    }
    s
}

/// SAFETY: AVX2 available; `x.len() == w.len()`.
#[target_feature(enable = "avx2")]
pub unsafe fn wnorm2_diag(x: &[f64], w: &[f64]) -> f64 {
    let n = x.len();
    let chunks = n / 4;
    let xp = x.as_ptr();
    let wp = w.as_ptr();
    let mut acc = _mm256_setzero_pd();
    for i in 0..chunks {
        let xv = _mm256_loadu_pd(xp.add(i * 4));
        let wv = _mm256_loadu_pd(wp.add(i * 4));
        // (w·x)·x — same association as the scalar arm's w[j]*x[j]*x[j]
        acc = _mm256_add_pd(acc, _mm256_mul_pd(_mm256_mul_pd(wv, xv), xv));
    }
    let mut s = hsum(acc);
    for j in chunks * 4..n {
        s += w[j] * x[j] * x[j];
    }
    s
}

/// SAFETY: AVX2 available; `x.len() == y.len()`.
#[target_feature(enable = "avx2")]
pub unsafe fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    let n = x.len();
    let chunks = n / 4;
    let xp = x.as_ptr();
    let yp = y.as_mut_ptr();
    let av = _mm256_set1_pd(alpha);
    for i in 0..chunks {
        let yv = _mm256_loadu_pd(yp.add(i * 4));
        let xv = _mm256_loadu_pd(xp.add(i * 4));
        _mm256_storeu_pd(yp.add(i * 4), _mm256_add_pd(yv, _mm256_mul_pd(av, xv)));
    }
    for j in chunks * 4..n {
        y[j] += alpha * x[j];
    }
}

/// SAFETY: AVX2 available; `a.len() == b.len() == out.len()`.
#[target_feature(enable = "avx2")]
pub unsafe fn lincomb_into(alpha: f64, a: &[f64], beta: f64, b: &[f64], out: &mut [f64]) {
    let n = a.len();
    let chunks = n / 4;
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    let op = out.as_mut_ptr();
    let av = _mm256_set1_pd(alpha);
    let bv = _mm256_set1_pd(beta);
    for i in 0..chunks {
        let ta = _mm256_mul_pd(av, _mm256_loadu_pd(ap.add(i * 4)));
        let tb = _mm256_mul_pd(bv, _mm256_loadu_pd(bp.add(i * 4)));
        _mm256_storeu_pd(op.add(i * 4), _mm256_add_pd(ta, tb));
    }
    for j in chunks * 4..n {
        out[j] = alpha * a[j] + beta * b[j];
    }
}

/// SAFETY: AVX2 available; `a.len() == b.len()`.
#[target_feature(enable = "avx2")]
pub unsafe fn rot2(c: f64, s: f64, a: &mut [f64], b: &mut [f64]) {
    let n = a.len();
    let chunks = n / 4;
    let ap = a.as_mut_ptr();
    let bp = b.as_mut_ptr();
    let cv = _mm256_set1_pd(c);
    let sv = _mm256_set1_pd(s);
    for i in 0..chunks {
        let va = _mm256_loadu_pd(ap.add(i * 4));
        let vb = _mm256_loadu_pd(bp.add(i * 4));
        _mm256_storeu_pd(
            ap.add(i * 4),
            _mm256_sub_pd(_mm256_mul_pd(cv, va), _mm256_mul_pd(sv, vb)),
        );
        _mm256_storeu_pd(
            bp.add(i * 4),
            _mm256_add_pd(_mm256_mul_pd(sv, va), _mm256_mul_pd(cv, vb)),
        );
    }
    for j in chunks * 4..n {
        let aj = a[j];
        let bj = b[j];
        a[j] = c * aj - s * bj;
        b[j] = s * aj + c * bj;
    }
}

/// Dense row-major matvec: 4-row blocks sharing each loaded `x` chunk,
/// one 4-lane accumulator per row.
///
/// SAFETY: AVX2 available; `data.len() == rows·cols`, `x.len() == cols`,
/// `out.len() == rows` (asserted by the dispatch wrapper).
#[target_feature(enable = "avx2")]
pub unsafe fn mat_matvec_into(data: &[f64], rows: usize, cols: usize, x: &[f64], out: &mut [f64]) {
    let r4 = rows / 4 * 4;
    let c4 = cols / 4 * 4;
    let xp = x.as_ptr();
    let mut r = 0;
    while r < r4 {
        // in-bounds: (r+3)·cols + cols ≤ rows·cols == data.len()
        let row0 = data.as_ptr().add(r * cols);
        let row1 = data.as_ptr().add((r + 1) * cols);
        let row2 = data.as_ptr().add((r + 2) * cols);
        let row3 = data.as_ptr().add((r + 3) * cols);
        let mut a0 = _mm256_setzero_pd();
        let mut a1 = _mm256_setzero_pd();
        let mut a2 = _mm256_setzero_pd();
        let mut a3 = _mm256_setzero_pd();
        let mut c = 0;
        while c < c4 {
            let xv = _mm256_loadu_pd(xp.add(c));
            a0 = _mm256_add_pd(a0, _mm256_mul_pd(_mm256_loadu_pd(row0.add(c)), xv));
            a1 = _mm256_add_pd(a1, _mm256_mul_pd(_mm256_loadu_pd(row1.add(c)), xv));
            a2 = _mm256_add_pd(a2, _mm256_mul_pd(_mm256_loadu_pd(row2.add(c)), xv));
            a3 = _mm256_add_pd(a3, _mm256_mul_pd(_mm256_loadu_pd(row3.add(c)), xv));
            c += 4;
        }
        let mut t = [hsum(a0), hsum(a1), hsum(a2), hsum(a3)];
        while c < cols {
            let xc = x[c];
            t[0] += *row0.add(c) * xc;
            t[1] += *row1.add(c) * xc;
            t[2] += *row2.add(c) * xc;
            t[3] += *row3.add(c) * xc;
            c += 1;
        }
        out[r] = t[0];
        out[r + 1] = t[1];
        out[r + 2] = t[2];
        out[r + 3] = t[3];
        r += 4;
    }
    while r < rows {
        out[r] = dot(&data[r * cols..(r + 1) * cols], x);
        r += 1;
    }
}

/// CSR matvec with `vgatherdpd`: 4 nonzeros per iteration, the `x` loads
/// done by one hardware gather.
///
/// SAFETY: AVX2 available; `indptr.len() == out.len()+1`,
/// `indices.len() == values.len()`, `x.len() ≤ i32::MAX` (all checked by
/// the dispatch wrapper). Row ranges come from bounds-checked slicing,
/// and each 4 gather offsets are checked `< x.len()` right before the
/// gather — a corrupted matrix panics exactly like the scalar arm.
#[target_feature(enable = "avx2")]
pub unsafe fn csr_matvec_into(
    indptr: &[usize],
    indices: &[u32],
    values: &[f64],
    x: &[f64],
    out: &mut [f64],
) {
    let xp = x.as_ptr();
    let xn = x.len();
    for r in 0..out.len() {
        let (s, e) = (indptr[r], indptr[r + 1]);
        let idx = &indices[s..e];
        let val = &values[s..e];
        let nnz = idx.len();
        let k4 = nnz / 4 * 4;
        let mut acc = _mm256_setzero_pd();
        let mut k = 0;
        while k < k4 {
            let (i0, i1, i2, i3) = (
                idx[k] as usize,
                idx[k + 1] as usize,
                idx[k + 2] as usize,
                idx[k + 3] as usize,
            );
            // the gather bypasses slice bounds checks — enforce them here
            assert!(
                i0.max(i1).max(i2).max(i3) < xn,
                "CSR column index out of bounds"
            );
            // offsets < x.len() ≤ i32::MAX, so the i32 lanes are non-negative
            let vidx = _mm_loadu_si128(idx.as_ptr().add(k) as *const __m128i);
            let g = _mm256_i32gather_pd::<8>(xp, vidx);
            let vv = _mm256_loadu_pd(val.as_ptr().add(k));
            acc = _mm256_add_pd(acc, _mm256_mul_pd(vv, g));
            k += 4;
        }
        let mut sacc = hsum(acc);
        while k < nnz {
            sacc += val[k] * x[idx[k] as usize];
            k += 1;
        }
        out[r] = sacc;
    }
}

/// CSR transposed matvec: the products `yr·val` run 4 per vector op, the
/// scatter stores stay scalar (AVX2 has no scatter) and bounds-checked.
/// Zeroes `out` first.
///
/// SAFETY: AVX2 available; `indptr.len() == y.len()+1`,
/// `indices.len() == values.len()` (asserted by the dispatch wrapper).
#[target_feature(enable = "avx2")]
pub unsafe fn csr_tmatvec_into(
    indptr: &[usize],
    indices: &[u32],
    values: &[f64],
    y: &[f64],
    out: &mut [f64],
) {
    out.fill(0.0);
    let mut tmp = [0.0f64; 4];
    for r in 0..y.len() {
        let yr = y[r];
        if yr == 0.0 {
            continue;
        }
        let (s, e) = (indptr[r], indptr[r + 1]);
        let idx = &indices[s..e];
        let val = &values[s..e];
        let nnz = idx.len();
        let k4 = nnz / 4 * 4;
        let yv = _mm256_set1_pd(yr);
        let mut k = 0;
        while k < k4 {
            let vv = _mm256_loadu_pd(val.as_ptr().add(k));
            _mm256_storeu_pd(tmp.as_mut_ptr(), _mm256_mul_pd(yv, vv));
            out[idx[k] as usize] += tmp[0];
            out[idx[k + 1] as usize] += tmp[1];
            out[idx[k + 2] as usize] += tmp[2];
            out[idx[k + 3] as usize] += tmp[3];
            k += 4;
        }
        while k < nnz {
            out[idx[k] as usize] += yr * val[k];
            k += 1;
        }
    }
}
