//! Dense vector operations used throughout the optimizer hot paths.
//!
//! All routines are allocation-free where possible; the coordinator's
//! steady-state round loop relies on the `*_into` / in-place variants.
//!
//! §Perf: the reductions (`dot`, `dist2`, `wnorm2_diag`) and the fused
//! update kernels (`axpy`, `lincomb_into`, `rot2`) dispatch through the
//! explicit SIMD layer ([`crate::linalg::simd`]): AVX2/AVX-512 lanes where
//! the CPU has them, the portable 4-lane blocked loops otherwise — all
//! arms bitwise identical (see the simd module's determinism contract),
//! selected once per process (`SMX_NO_SIMD=1` forces the scalar arm).
//! The pre-optimization sequential loops are retained under `#[cfg(test)]`
//! in [`self::naive`] and asserted in the tests below and in
//! `tests/kernel_parity.rs`.

use crate::linalg::simd;

#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    simd::dot(a, b)
}

#[inline]
pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a)
}

#[inline]
pub fn norm(a: &[f64]) -> f64 {
    norm2(a).sqrt()
}

/// Squared distance ‖a − b‖² (4-lane accumulators).
#[inline]
pub fn dist2(a: &[f64], b: &[f64]) -> f64 {
    simd::dist2(a, b)
}

/// y += alpha * x (elementwise, so bitwise identical to the scalar loop
/// on every dispatch arm).
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    simd::axpy(alpha, x, y)
}

/// y = x
#[inline]
pub fn copy(x: &[f64], y: &mut [f64]) {
    y.copy_from_slice(x);
}

/// x *= alpha
#[inline]
pub fn scale(alpha: f64, x: &mut [f64]) {
    for v in x.iter_mut() {
        *v *= alpha;
    }
}

/// out = a + b
#[inline]
pub fn add_into(a: &[f64], b: &[f64], out: &mut [f64]) {
    for i in 0..a.len() {
        out[i] = a[i] + b[i];
    }
}

/// out = a - b
#[inline]
pub fn sub_into(a: &[f64], b: &[f64], out: &mut [f64]) {
    for i in 0..a.len() {
        out[i] = a[i] - b[i];
    }
}

/// out = alpha*a + beta*b
#[inline]
pub fn lincomb_into(alpha: f64, a: &[f64], beta: f64, b: &[f64], out: &mut [f64]) {
    simd::lincomb_into(alpha, a, beta, b, out)
}

/// Plane rotation `(a, b) ← (c·a − s·b, s·a + c·b)` — the Jacobi
/// eigensolver's row update (elementwise).
#[inline]
pub fn rot2(c: f64, s: f64, a: &mut [f64], b: &mut [f64]) {
    simd::rot2(c, s, a, b)
}

/// Weighted squared norm ‖x‖²_w = Σ w_i x_i² for a diagonal weight
/// (4-lane canonical order, like `dot`).
#[inline]
pub fn wnorm2_diag(x: &[f64], w: &[f64]) -> f64 {
    simd::wnorm2_diag(x, w)
}

/// max_i |a_i|
#[inline]
pub fn inf_norm(a: &[f64]) -> f64 {
    a.iter().fold(0.0, |m, &v| m.max(v.abs()))
}

pub fn zeros(n: usize) -> Vec<f64> {
    vec![0.0; n]
}

/// Pre-optimization scalar reference kernels, kept for parity assertions
/// (here and in `tests/kernel_parity.rs`). `benches/hotpath.rs` carries
/// its own copies for the measurable before/after rows (cfg(test) items
/// are invisible to bench targets).
#[cfg(test)]
pub mod naive {
    pub fn dot(a: &[f64], b: &[f64]) -> f64 {
        let mut s = 0.0;
        for i in 0..a.len() {
            s += a[i] * b[i];
        }
        s
    }

    pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
        for i in 0..x.len() {
            y[i] += alpha * x[i];
        }
    }

    pub fn dist2(a: &[f64], b: &[f64]) -> f64 {
        let mut s = 0.0;
        for i in 0..a.len() {
            let d = a[i] - b[i];
            s += d * d;
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f64> = (0..37).map(|i| i as f64 * 0.5).collect();
        let b: Vec<f64> = (0..37).map(|i| (i as f64).sin()).collect();
        let reference = naive::dot(&a, &b);
        assert!((dot(&a, &b) - reference).abs() < 1e-12 * reference.abs().max(1.0));
    }

    #[test]
    fn blocked_kernels_match_naive_references() {
        let mut rng = crate::util::rng::Rng::new(0xB10C);
        for n in [0usize, 1, 3, 4, 7, 64, 123, 1000] {
            let a: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let scale = naive::dot(&a, &a).max(1.0);
            assert!((dot(&a, &b) - naive::dot(&a, &b)).abs() < 1e-12 * scale, "dot n={n}");
            assert!(
                (dist2(&a, &b) - naive::dist2(&a, &b)).abs() < 1e-12 * scale,
                "dist2 n={n}"
            );
            let mut y1 = b.clone();
            let mut y2 = b.clone();
            axpy(0.37, &a, &mut y1);
            naive::axpy(0.37, &a, &mut y2);
            assert_eq!(y1, y2, "axpy must be bitwise identical, n={n}");
        }
    }

    #[test]
    fn norms() {
        let v = [3.0, 4.0];
        assert_eq!(norm2(&v), 25.0);
        assert_eq!(norm(&v), 5.0);
        assert_eq!(inf_norm(&[-7.0, 2.0]), 7.0);
    }

    #[test]
    fn axpy_and_lincomb() {
        let x = [1.0, 2.0, 3.0];
        let mut y = [10.0, 10.0, 10.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 14.0, 16.0]);
        let mut out = [0.0; 3];
        lincomb_into(0.5, &x, 2.0, &[1.0, 1.0, 1.0], &mut out);
        assert_eq!(out, [2.5, 3.0, 3.5]);
    }

    #[test]
    fn dist2_basic() {
        assert_eq!(dist2(&[1.0, 2.0], &[4.0, 6.0]), 25.0);
    }

    #[test]
    fn weighted_norm() {
        assert_eq!(wnorm2_diag(&[1.0, 2.0], &[3.0, 0.5]), 3.0 + 2.0);
    }

    #[test]
    fn rot2_rotates_in_plane() {
        // 90° rotation: (a, b) -> (-b, a)
        let mut a = [1.0, 2.0, 3.0, 4.0, 5.0];
        let mut b = [-1.0, 0.5, 0.0, -2.0, 7.0];
        let (a0, b0) = (a, b);
        rot2(0.0, 1.0, &mut a, &mut b);
        for i in 0..5 {
            assert_eq!(a[i], -b0[i]);
            assert_eq!(b[i], a0[i]);
        }
        // identity rotation preserves both
        rot2(1.0, 0.0, &mut a, &mut b);
        for i in 0..5 {
            assert_eq!(a[i], 0.0 - b0[i]);
            assert_eq!(b[i], a0[i] + 0.0);
        }
    }

    #[test]
    fn add_sub_scale() {
        let a = [1.0, 2.0];
        let b = [3.0, 5.0];
        let mut out = [0.0; 2];
        add_into(&a, &b, &mut out);
        assert_eq!(out, [4.0, 7.0]);
        sub_into(&a, &b, &mut out);
        assert_eq!(out, [-2.0, -3.0]);
        let mut c = [2.0, 4.0];
        scale(0.5, &mut c);
        assert_eq!(c, [1.0, 2.0]);
    }
}
