//! CSR sparse matrices for LibSVM-style data.
//!
//! The data matrices `A_i` are sparse (a1a/a8a are ~11% dense, mushrooms
//! ~19%); the gradient hot path is `Aᵀ (w ∘ σ(b ∘ A x))`, i.e. one CSR
//! matvec and one CSR transposed-matvec per round per worker.

use crate::linalg::dense::Mat;

#[derive(Clone, Debug)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    pub indptr: Vec<usize>, // len rows+1
    pub indices: Vec<u32>,  // column indices per row, strictly increasing
    pub values: Vec<f64>,
}

impl Csr {
    pub fn from_triplets(rows: usize, cols: usize, mut t: Vec<(usize, usize, f64)>) -> Csr {
        t.sort_unstable_by_key(|&(r, c, _)| (r, c));
        let mut indptr = vec![0usize; rows + 1];
        let mut indices = Vec::with_capacity(t.len());
        let mut values = Vec::with_capacity(t.len());
        for &(r, c, v) in &t {
            assert!(r < rows && c < cols, "triplet ({r},{c}) out of bounds");
            indptr[r + 1] += 1;
            indices.push(c as u32);
            values.push(v);
        }
        for r in 0..rows {
            indptr[r + 1] += indptr[r];
        }
        // duplicate check
        for r in 0..rows {
            let s = &indices[indptr[r]..indptr[r + 1]];
            for w in s.windows(2) {
                assert!(w[0] < w[1], "duplicate or unsorted column in row {r}");
            }
        }
        Csr {
            rows,
            cols,
            indptr,
            indices,
            values,
        }
    }

    pub fn from_dense(m: &Mat, tol: f64) -> Csr {
        let mut t = Vec::new();
        for r in 0..m.rows {
            for c in 0..m.cols {
                let v = m[(r, c)];
                if v.abs() > tol {
                    t.push((r, c, v));
                }
            }
        }
        Csr::from_triplets(m.rows, m.cols, t)
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.rows as f64 * self.cols as f64).max(1.0)
    }

    #[inline]
    pub fn row_entries(&self, r: usize) -> (&[u32], &[f64]) {
        let (s, e) = (self.indptr[r], self.indptr[r + 1]);
        (&self.indices[s..e], &self.values[s..e])
    }

    /// out = A x
    ///
    /// §Perf: dispatches through [`crate::linalg::simd`] — per-row 4-lane
    /// reduction whose `x[idx[k]]` loads become one `vgatherdpd` per 4
    /// nonzeros on the AVX2 arm; this is half of every worker's per-round
    /// gradient.
    pub fn matvec_into(&self, x: &[f64], out: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(out.len(), self.rows);
        crate::linalg::simd::csr_matvec_into(&self.indptr, &self.indices, &self.values, x, out);
    }

    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.rows];
        self.matvec_into(x, &mut out);
        out
    }

    /// out = Aᵀ y
    ///
    /// §Perf: dispatches through [`crate::linalg::simd`] — the scatter is
    /// unrolled 4-wide (products vectorized on the AVX2 arm, stores scalar
    /// since AVX2 has no scatter), safe because column indices are
    /// strictly increasing within a row, so the four targets are distinct.
    pub fn tmatvec_into(&self, y: &[f64], out: &mut [f64]) {
        assert_eq!(y.len(), self.rows);
        assert_eq!(out.len(), self.cols);
        crate::linalg::simd::csr_tmatvec_into(&self.indptr, &self.indices, &self.values, y, out);
    }

    pub fn tmatvec(&self, y: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.cols];
        self.tmatvec_into(y, &mut out);
        out
    }

    /// ‖row r‖²
    pub fn row_norm2(&self, r: usize) -> f64 {
        let (_, val) = self.row_entries(r);
        val.iter().map(|v| v * v).sum()
    }

    /// Scale each row by a factor (used by dataset normalization).
    pub fn scale_rows(&mut self, factors: &[f64]) {
        assert_eq!(factors.len(), self.rows);
        for r in 0..self.rows {
            let (s, e) = (self.indptr[r], self.indptr[r + 1]);
            for v in &mut self.values[s..e] {
                *v *= factors[r];
            }
        }
    }

    /// diag(Aᵀ A): Σ_r a_{rj}² per column j.
    pub fn gram_diag(&self) -> Vec<f64> {
        let mut d = vec![0.0; self.cols];
        for k in 0..self.nnz() {
            let j = self.indices[k] as usize;
            d[j] += self.values[k] * self.values[k];
        }
        d
    }

    /// Dense AᵀA (cols × cols). Only for cols small enough to afford d².
    pub fn gram_dense(&self) -> Mat {
        let n = self.cols;
        let mut g = Mat::zeros(n, n);
        for r in 0..self.rows {
            let (idx, val) = self.row_entries(r);
            for a in 0..idx.len() {
                let (ia, va) = (idx[a] as usize, val[a]);
                for b in a..idx.len() {
                    let (ib, vb) = (idx[b] as usize, val[b]);
                    g.data[ia * n + ib] += va * vb;
                }
            }
        }
        for i in 0..n {
            for j in 0..i {
                g.data[i * n + j] = g.data[j * n + i];
            }
        }
        g
    }

    /// Dense AAᵀ (rows × rows). Used by the low-rank smoothness path where
    /// m_i ≪ d (e.g. duke: 11 × 7129).
    pub fn gram_t_dense(&self) -> Mat {
        let m = self.rows;
        let mut g = Mat::zeros(m, m);
        for i in 0..m {
            let (ii, iv) = self.row_entries(i);
            for j in i..m {
                let (ji, jv) = self.row_entries(j);
                // sparse-sparse dot via two-pointer merge
                let (mut a, mut b, mut s) = (0usize, 0usize, 0.0);
                while a < ii.len() && b < ji.len() {
                    match ii[a].cmp(&ji[b]) {
                        std::cmp::Ordering::Less => a += 1,
                        std::cmp::Ordering::Greater => b += 1,
                        std::cmp::Ordering::Equal => {
                            s += iv[a] * jv[b];
                            a += 1;
                            b += 1;
                        }
                    }
                }
                g.data[i * m + j] = s;
                g.data[j * m + i] = s;
            }
        }
        g
    }

    /// Extract a row-slice as a new CSR (rows [start, end)).
    pub fn slice_rows(&self, start: usize, end: usize) -> Csr {
        assert!(start <= end && end <= self.rows);
        let (s, e) = (self.indptr[start], self.indptr[end]);
        let mut indptr: Vec<usize> = self.indptr[start..=end].iter().map(|p| p - s).collect();
        if indptr.is_empty() {
            indptr = vec![0];
        }
        Csr {
            rows: end - start,
            cols: self.cols,
            indptr,
            indices: self.indices[s..e].to_vec(),
            values: self.values[s..e].to_vec(),
        }
    }

    /// Reorder rows by a permutation (row i of the result = row perm[i]).
    pub fn permute_rows(&self, perm: &[usize]) -> Csr {
        assert_eq!(perm.len(), self.rows);
        let mut t = Vec::with_capacity(self.nnz());
        for (new_r, &old_r) in perm.iter().enumerate() {
            let (idx, val) = self.row_entries(old_r);
            for k in 0..idx.len() {
                t.push((new_r, idx[k] as usize, val[k]));
            }
        }
        Csr::from_triplets(self.rows, self.cols, t)
    }

    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            let (idx, val) = self.row_entries(r);
            for k in 0..idx.len() {
                m[(r, idx[k] as usize)] = val[k];
            }
        }
        m
    }

    /// Row-major dense f64 buffer (for PJRT literals).
    pub fn to_dense_buffer(&self) -> Vec<f64> {
        self.to_dense().data
    }
}

/// Pre-optimization scalar reference kernels, asserted equal to the
/// blocked implementations (here and in `tests/kernel_parity.rs`).
#[cfg(test)]
pub mod naive {
    use super::Csr;

    pub fn matvec(a: &Csr, x: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; a.rows];
        for r in 0..a.rows {
            let (idx, val) = a.row_entries(r);
            let mut s = 0.0;
            for k in 0..idx.len() {
                s += val[k] * x[idx[k] as usize];
            }
            out[r] = s;
        }
        out
    }

    pub fn tmatvec(a: &Csr, y: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; a.cols];
        for r in 0..a.rows {
            let yr = y[r];
            if yr == 0.0 {
                continue;
            }
            let (idx, val) = a.row_entries(r);
            for k in 0..idx.len() {
                out[idx[k] as usize] += yr * val[k];
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Csr {
        // [[1, 0, 2],
        //  [0, 3, 0],
        //  [4, 0, 5]]
        Csr::from_triplets(
            3,
            3,
            vec![(0, 0, 1.0), (0, 2, 2.0), (1, 1, 3.0), (2, 0, 4.0), (2, 2, 5.0)],
        )
    }

    #[test]
    fn matvec_matches_dense() {
        let a = sample();
        let x = [1.0, 2.0, 3.0];
        assert_eq!(a.matvec(&x), a.to_dense().matvec(&x));
        assert_eq!(a.matvec(&x), vec![7.0, 6.0, 19.0]);
    }

    #[test]
    fn tmatvec_matches_dense() {
        let a = sample();
        let y = [1.0, -1.0, 2.0];
        assert_eq!(a.tmatvec(&y), a.to_dense().tmatvec(&y));
    }

    #[test]
    fn blocked_csr_kernels_match_naive() {
        let mut rng = crate::util::rng::Rng::new(0xC5A);
        for (rows, cols, density) in [(1, 8, 0.5), (9, 13, 0.3), (40, 60, 0.12), (17, 5, 0.9)] {
            let mut t = Vec::new();
            for r in 0..rows {
                for c in 0..cols {
                    if rng.uniform() < density {
                        t.push((r, c, rng.normal()));
                    }
                }
            }
            let a = Csr::from_triplets(rows, cols, t);
            let x: Vec<f64> = (0..cols).map(|_| rng.normal()).collect();
            let y: Vec<f64> = (0..rows).map(|_| rng.normal()).collect();
            let mv = a.matvec(&x);
            let mv_ref = naive::matvec(&a, &x);
            for r in 0..rows {
                assert!(
                    (mv[r] - mv_ref[r]).abs() < 1e-12 * (1.0 + mv_ref[r].abs()),
                    "matvec {rows}x{cols} row {r}"
                );
            }
            // scatter unroll is elementwise ⇒ bitwise identical
            assert_eq!(a.tmatvec(&y), naive::tmatvec(&a, &y), "tmatvec {rows}x{cols}");
        }
    }

    #[test]
    fn gram_diag_matches() {
        let a = sample();
        let g = a.gram_dense();
        assert_eq!(a.gram_diag(), g.diag());
    }

    #[test]
    fn gram_dense_matches_mat_gram() {
        let a = sample();
        assert!(a.gram_dense().max_abs_diff(&a.to_dense().gram()) < 1e-14);
        assert!(a.gram_t_dense().max_abs_diff(&a.to_dense().gram_t()) < 1e-14);
    }

    #[test]
    fn slice_rows_works() {
        let a = sample();
        let s = a.slice_rows(1, 3);
        assert_eq!(s.rows, 2);
        assert_eq!(s.matvec(&[1.0, 1.0, 1.0]), vec![3.0, 9.0]);
    }

    #[test]
    fn permute_rows_works() {
        let a = sample();
        let p = a.permute_rows(&[2, 0, 1]);
        assert_eq!(p.matvec(&[1.0, 1.0, 1.0]), vec![9.0, 3.0, 3.0]);
    }

    #[test]
    fn scale_rows_and_norms() {
        let mut a = sample();
        assert_eq!(a.row_norm2(0), 5.0);
        a.scale_rows(&[2.0, 1.0, 0.5]);
        assert_eq!(a.row_norm2(0), 20.0);
        assert_eq!(a.matvec(&[1.0, 0.0, 0.0]), vec![2.0, 0.0, 2.0]);
    }

    #[test]
    fn density_and_nnz() {
        let a = sample();
        assert_eq!(a.nnz(), 5);
        assert!((a.density() - 5.0 / 9.0).abs() < 1e-15);
    }

    #[test]
    #[should_panic]
    fn duplicate_entries_rejected() {
        Csr::from_triplets(1, 2, vec![(0, 0, 1.0), (0, 0, 2.0)]);
    }

    #[test]
    fn from_dense_roundtrip() {
        let m = Mat::from_rows(vec![vec![0.0, 1.5], vec![-2.0, 0.0]]);
        let c = Csr::from_dense(&m, 0.0);
        assert_eq!(c.nnz(), 2);
        assert!(c.to_dense().max_abs_diff(&m) == 0.0);
    }

    #[test]
    fn empty_rows_ok() {
        let a = Csr::from_triplets(3, 2, vec![(1, 0, 1.0)]);
        assert_eq!(a.matvec(&[2.0, 3.0]), vec![0.0, 2.0, 0.0]);
    }
}
