//! Symmetric eigensolvers.
//!
//! * [`eigh`] — cyclic Jacobi rotations: full eigendecomposition of a
//!   symmetric matrix. O(d³) per sweep with a handful of sweeps; used for
//!   the smoothness roots where the relevant dimension is min(m_i, d)
//!   (≤ ~700 for all paper datasets).
//! * [`power_lambda_max`] — power iteration for the top eigenvalue of an
//!   implicitly-applied symmetric PSD operator (used for λ_max(L) with
//!   L = (1/4M)AᵀA + μI without forming d×d).

use crate::linalg::dense::Mat;
use crate::linalg::vector;
use crate::util::rng::Rng;

/// Result of a symmetric eigendecomposition: `a = V diag(w) Vᵀ`,
/// eigenvalues ascending, eigenvectors as *columns* of `v`.
#[derive(Clone, Debug)]
pub struct Eigh {
    pub w: Vec<f64>,
    pub v: Mat,
}

/// Mutable views of two distinct rows `p < q` of a row-major matrix —
/// the shape [`vector::rot2`] wants.
fn rows_pair_mut(m: &mut Mat, p: usize, q: usize) -> (&mut [f64], &mut [f64]) {
    debug_assert!(p < q);
    let cols = m.cols;
    let (head, tail) = m.data.split_at_mut(q * cols);
    (&mut head[p * cols..(p + 1) * cols], &mut tail[..cols])
}

/// Cyclic Jacobi eigensolver for symmetric matrices.
///
/// Converges to machine precision for the well-conditioned PSD matrices we
/// feed it (Gram matrices + ridge). Panics if `a` is not square.
///
/// §Perf: the row halves of each rotation — `M[p,·]/M[q,·]` and the
/// eigenvector update — run through the SIMD [`vector::rot2`] kernel on
/// contiguous rows. The eigenvector matrix is therefore accumulated
/// *transposed* (`vt`, rows = eigenvectors) during the sweeps, so its
/// per-rotation update touches two contiguous rows instead of two strided
/// columns; it is transposed back once at the end. Same arithmetic per
/// element as the pre-SIMD column loops, so results are bitwise identical.
pub fn eigh(a: &Mat) -> Eigh {
    assert_eq!(a.rows, a.cols, "eigh needs a square matrix");
    let n = a.rows;
    let mut m = a.clone();
    if n == 0 {
        return Eigh {
            w: vec![],
            v: Mat::eye(n),
        };
    }
    if n == 1 {
        return Eigh {
            w: vec![m[(0, 0)]],
            v: Mat::eye(n),
        };
    }
    // vt.row(j) is eigenvector j (V's column j) during iteration
    let mut vt = Mat::eye(n);
    // kernel dispatch resolved once for all O(n³) rotations — the rotated
    // rows can be short (low-rank Gram cells have n = m_i ~ 11)
    let lvl = crate::linalg::simd::active();

    let max_sweeps = 64;
    for _sweep in 0..max_sweeps {
        // off-diagonal Frobenius norm
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        if off.sqrt() <= 1e-14 * (1.0 + m.frobenius_norm()) {
            break;
        }
        for p in 0..n - 1 {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                if apq == 0.0 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                // Stable rotation computation (Golub & Van Loan §8.5.2).
                let tau = (aqq - app) / (2.0 * apq);
                let t = if tau >= 0.0 {
                    1.0 / (tau + (1.0 + tau * tau).sqrt())
                } else {
                    -1.0 / (-tau + (1.0 + tau * tau).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;

                // Apply rotation J(p,q,θ): M ← JᵀMJ, Vᵀ ← JᵀVᵀ.
                // Column half (strided — left as scalar):
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                // Row halves (contiguous — SIMD rot2):
                let (mp, mq) = rows_pair_mut(&mut m, p, q);
                crate::linalg::simd::rot2_at(lvl, c, s, mp, mq);
                let (vp, vq) = rows_pair_mut(&mut vt, p, q);
                crate::linalg::simd::rot2_at(lvl, c, s, vp, vq);
            }
        }
    }

    // Collect eigenvalues and sort ascending; vt rows become V's columns.
    let mut order: Vec<usize> = (0..n).collect();
    let w_raw: Vec<f64> = (0..n).map(|i| m[(i, i)]).collect();
    order.sort_by(|&i, &j| w_raw[i].partial_cmp(&w_raw[j]).unwrap());
    let w: Vec<f64> = order.iter().map(|&i| w_raw[i]).collect();
    let mut vs = Mat::zeros(n, n);
    for (new_c, &old_c) in order.iter().enumerate() {
        for r in 0..n {
            vs[(r, new_c)] = vt[(old_c, r)];
        }
    }
    Eigh { w, v: vs }
}

/// Power iteration for λ_max of a symmetric PSD operator given by `apply`.
/// Deterministic given the seed; runs until relative change < tol or
/// max_iter.
pub fn power_lambda_max(
    dim: usize,
    mut apply: impl FnMut(&[f64], &mut [f64]),
    tol: f64,
    max_iter: usize,
    seed: u64,
) -> f64 {
    assert!(dim > 0);
    let mut rng = Rng::new(seed);
    let mut x: Vec<f64> = (0..dim).map(|_| rng.normal()).collect();
    let nrm = vector::norm(&x);
    vector::scale(1.0 / nrm, &mut x);
    let mut y = vec![0.0; dim];
    let mut lambda = 0.0;
    for _ in 0..max_iter {
        apply(&x, &mut y);
        let new_lambda = vector::dot(&x, &y);
        let ny = vector::norm(&y);
        if ny == 0.0 {
            return 0.0;
        }
        for i in 0..dim {
            x[i] = y[i] / ny;
        }
        if (new_lambda - lambda).abs() <= tol * new_lambda.abs().max(1e-300) {
            return new_lambda;
        }
        lambda = new_lambda;
    }
    lambda
}

/// λ_max of an explicit symmetric matrix via power iteration.
pub fn lambda_max(a: &Mat, tol: f64) -> f64 {
    power_lambda_max(a.rows, |x, y| a.matvec_into(x, y), tol, 10_000, 0xE16)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn reconstruct(e: &Eigh) -> Mat {
        // V diag(w) Vᵀ
        let n = e.w.len();
        let mut d = Mat::zeros(n, n);
        for i in 0..n {
            d[(i, i)] = e.w[i];
        }
        e.v.matmul(&d).matmul(&e.v.transpose())
    }

    fn random_sym(n: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                let v = rng.normal();
                a[(i, j)] = v;
                a[(j, i)] = v;
            }
        }
        a
    }

    #[test]
    fn eigh_diagonal() {
        let mut a = Mat::zeros(3, 3);
        a[(0, 0)] = 3.0;
        a[(1, 1)] = 1.0;
        a[(2, 2)] = 2.0;
        let e = eigh(&a);
        assert_eq!(e.w, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn eigh_2x2_analytic() {
        let a = Mat::from_rows(vec![vec![2.0, 1.0], vec![1.0, 2.0]]);
        let e = eigh(&a);
        assert!((e.w[0] - 1.0).abs() < 1e-12);
        assert!((e.w[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn eigh_reconstructs_random() {
        for seed in [1u64, 2, 3] {
            let a = random_sym(12, seed);
            let e = eigh(&a);
            let r = reconstruct(&e);
            assert!(
                r.max_abs_diff(&a) < 1e-10,
                "reconstruction error {}",
                r.max_abs_diff(&a)
            );
        }
    }

    #[test]
    fn eigh_orthonormal_eigenvectors() {
        let a = random_sym(10, 7);
        let e = eigh(&a);
        let vtv = e.v.transpose().matmul(&e.v);
        assert!(vtv.max_abs_diff(&Mat::eye(10)) < 1e-11);
    }

    #[test]
    fn eigh_psd_gram() {
        let mut rng = Rng::new(42);
        let b = Mat::from_rows(
            (0..6)
                .map(|_| (0..4).map(|_| rng.normal()).collect())
                .collect(),
        );
        let g = b.gram(); // 4x4 PSD
        let e = eigh(&g);
        assert!(e.w.iter().all(|&w| w > -1e-10), "eigs {:?}", e.w);
    }

    #[test]
    fn eigh_trace_and_det_invariants() {
        let a = random_sym(8, 11);
        let e = eigh(&a);
        let trace: f64 = (0..8).map(|i| a[(i, i)]).sum();
        let wsum: f64 = e.w.iter().sum();
        assert!((trace - wsum).abs() < 1e-10);
    }

    #[test]
    fn power_iteration_matches_eigh() {
        let a = random_sym(15, 3);
        // shift to PSD so power iteration targets the top eigenvalue robustly
        let e = eigh(&a);
        let shift = -e.w[0] + 1.0;
        let mut b = a.clone();
        b.add_diag(shift);
        let lm = lambda_max(&b, 1e-12);
        let expected = e.w[14] + shift;
        assert!(
            (lm - expected).abs() < 1e-6 * expected.abs(),
            "power {lm} vs eigh {expected}"
        );
    }

    #[test]
    fn power_on_implicit_operator() {
        // operator: diag(1, 2, 5) applied implicitly
        let lm = power_lambda_max(
            3,
            |x, y| {
                y[0] = x[0];
                y[1] = 2.0 * x[1];
                y[2] = 5.0 * x[2];
            },
            1e-14,
            10_000,
            1,
        );
        assert!((lm - 5.0).abs() < 1e-9);
    }

    #[test]
    fn eigh_size_one_and_zero() {
        let e = eigh(&Mat::from_rows(vec![vec![4.0]]));
        assert_eq!(e.w, vec![4.0]);
        let e0 = eigh(&Mat::zeros(0, 0));
        assert!(e0.w.is_empty());
    }
}
