//! PSD root operators: matrix functions `L^s` for `s ∈ {±1, ±1/2}` of a
//! positive-semidefinite smoothness matrix, with two representations:
//!
//! * **Dense** — full eigendecomposition of a `d×d` matrix; pseudo-inverse
//!   semantics (eigenvalues ≤ tol are treated as 0 and excluded from
//!   negative powers), matching `L^{†1/2}` in the paper.
//! * **Low-rank + ridge** — `L = B Bᵀ + μ I` with `B ∈ ℝ^{d×k}`, `k ≪ d`.
//!   Never forms the `d×d` matrix: from the `k×k` Gram eigendecomposition
//!   we get an orthonormal `Q ∈ ℝ^{d×k}` with
//!   `L^s v = Q ((λ+μ)^s − μ^s) Qᵀ v + μ^s v`.
//!   This is how duke (d = 7129, m_i = 11) stays cheap, and with μ > 0 the
//!   operator is positive definite so pinv = inv and Range(L) = ℝ^d.

use crate::linalg::dense::Mat;
use crate::linalg::eigen::{eigh, Eigh};
use crate::linalg::simd;
use crate::linalg::vector;

const PINV_TOL: f64 = 1e-12;

#[derive(Clone, Debug)]
pub enum PsdRoot {
    Dense {
        /// eigendecomposition of L (ascending eigenvalues)
        eig: Eigh,
        /// Vᵀ cached row-major — the `Vᵀx` half of every apply walks rows
        /// sequentially instead of striding down columns (§Perf: ~3x on
        /// the whiten hot path at d=123..500)
        vt: Mat,
        dim: usize,
    },
    LowRankRidge {
        /// orthonormal columns spanning Range(B), d×k. Both halves of the
        /// fused apply stream this one matrix row-wise (`Qᵀx` as an axpy
        /// accumulation over rows, then the output sweep as row dots), so
        /// no transposed copy is kept — see
        /// [`PsdRoot::apply_pow_fused_into`].
        q: Mat,
        /// eigenvalues of BBᵀ restricted to Range(B) (ascending, > 0)
        lam: Vec<f64>,
        /// ridge μ ≥ 0
        mu: f64,
        dim: usize,
    },
}

impl PsdRoot {
    /// Build from an explicit symmetric PSD matrix.
    pub fn from_dense(l: &Mat) -> PsdRoot {
        assert!(l.is_symmetric(1e-9), "PsdRoot requires symmetric input");
        let eig = eigh(l);
        let vt = eig.v.transpose();
        PsdRoot::Dense {
            eig,
            vt,
            dim: l.rows,
        }
    }

    /// Build from the factored form `L = c · AᵀA + μI`, where `A` is m×d
    /// given as a dense matrix of its rows (each row a data point). Uses
    /// the m×m Gram path; requires m ≤ d to be worthwhile but is correct
    /// for any m.
    ///
    /// `gram_t = A Aᵀ` must be precomputed by the caller (it may come from
    /// a sparse matrix).
    pub fn from_lowrank_ridge(a_rows: &Mat, gram_t: &Mat, c: f64, mu: f64) -> PsdRoot {
        let d = a_rows.cols;
        let m = a_rows.rows;
        assert_eq!(gram_t.rows, m);
        // B = √c · Aᵀ  (d×m), BᵀB = c·AAᵀ = c·gram_t  (m×m)
        let mut btb = gram_t.clone();
        btb.scale(c);
        let e = eigh(&btb);
        // Keep strictly positive eigenvalues; columns of Q = B W / √λ.
        let mut keep: Vec<usize> = Vec::new();
        let lmax = e.w.last().copied().unwrap_or(0.0).max(0.0);
        for (i, &w) in e.w.iter().enumerate() {
            if w > PINV_TOL * lmax.max(1.0) {
                keep.push(i);
            }
        }
        let k = keep.len();
        let mut q = Mat::zeros(d, k);
        let mut lam = Vec::with_capacity(k);
        let mut vcol = vec![0.0; m];
        let mut qcol = vec![0.0; d];
        for (col, &ei) in keep.iter().enumerate() {
            let w = e.w[ei];
            lam.push(w);
            // q_col = √c Aᵀ v / √w
            for r in 0..m {
                vcol[r] = e.v[(r, ei)];
            }
            a_rows.tmatvec_into(&vcol, &mut qcol);
            let scale = c.sqrt() / w.sqrt();
            for (r, &qv) in qcol.iter().enumerate() {
                q[(r, col)] = qv * scale;
            }
        }
        PsdRoot::LowRankRidge {
            q,
            lam,
            mu,
            dim: d,
        }
    }

    pub fn dim(&self) -> usize {
        match self {
            PsdRoot::Dense { dim, .. } => *dim,
            PsdRoot::LowRankRidge { dim, .. } => *dim,
        }
    }

    pub fn lambda_max(&self) -> f64 {
        match self {
            PsdRoot::Dense { eig, .. } => eig.w.last().copied().unwrap_or(0.0).max(0.0),
            PsdRoot::LowRankRidge { lam, mu, .. } => {
                lam.last().copied().unwrap_or(0.0).max(0.0) + mu
            }
        }
    }

    pub fn lambda_min(&self) -> f64 {
        match self {
            PsdRoot::Dense { eig, .. } => eig.w.first().copied().unwrap_or(0.0).max(0.0),
            PsdRoot::LowRankRidge { lam, mu, dim, .. } => {
                if lam.len() < *dim {
                    *mu
                } else {
                    lam.first().copied().unwrap_or(0.0) + mu
                }
            }
        }
    }

    /// `out = L^p · x` with pseudo-inverse semantics for p < 0.
    ///
    /// Allocates the eigen-coordinate scratch per call; hot paths should
    /// use [`PsdRoot::apply_pow_into_with`] with a persistent scratch.
    pub fn apply_pow_into(&self, p: f64, x: &[f64], out: &mut [f64]) {
        let mut coeff = Vec::new();
        self.apply_pow_into_with(p, x, out, &mut coeff);
    }

    /// `out = L^p · x`, writing eigen-coordinates into the caller-owned
    /// `coeff` scratch (resized on first use, then reused allocation-free
    /// — §Perf: this is on the per-round whiten path of every + method).
    /// The low-rank arm routes through [`PsdRoot::apply_pow_fused_into`].
    pub fn apply_pow_into_with(&self, p: f64, x: &[f64], out: &mut [f64], coeff: &mut Vec<f64>) {
        match self {
            PsdRoot::Dense { eig, vt, dim } => {
                assert_eq!(x.len(), *dim);
                // out = V f(w) Vᵀ x   (Vᵀx via sequential rows of vt);
                // dispatch resolved once, not per row (§Perf: rows can be
                // short, so per-call dispatch would rival the work)
                let lvl = simd::active();
                let n = *dim;
                let lmax = self.lambda_max();
                coeff.clear();
                coeff.resize(n, 0.0);
                for c in 0..n {
                    coeff[c] = simd::dot_at(lvl, vt.row(c), x) * pinv_pow(eig.w[c], p, lmax);
                }
                for r in 0..n {
                    out[r] = simd::dot_at(lvl, eig.v.row(r), coeff);
                }
            }
            PsdRoot::LowRankRidge { .. } => self.apply_pow_fused_into(p, x, out, coeff),
        }
    }

    /// Fused low-rank apply: `out = μ^p x + Q ((λ+μ)^p − μ^p) Qᵀ x`
    /// streaming the single `d×k` matrix `Q` for *both* halves — `Qᵀx`
    /// accumulated as one axpy per row, the scale folded into the
    /// eigen-coordinates, then the output sweep as one dot per row.
    ///
    /// §Perf: the pre-fusion path read two distinct `d×k` buffers (`Qᵀ`
    /// cached row-major, then `Q`), every byte cold; this reads `Q` twice,
    /// so the second sweep hits cache whenever `d·k` fits (duke:
    /// 7129×11×8 B ≈ 0.6 MB) — halving DRAM traffic on the whiten — and
    /// the transposed copy no longer exists at all.
    ///
    /// The dense arm has no second matrix to drop and simply delegates to
    /// the eigenbasis apply.
    pub fn apply_pow_fused_into(&self, p: f64, x: &[f64], out: &mut [f64], coeff: &mut Vec<f64>) {
        match self {
            PsdRoot::Dense { .. } => self.apply_pow_into_with(p, x, out, coeff),
            PsdRoot::LowRankRidge { q, lam, mu, dim } => {
                assert_eq!(x.len(), *dim);
                // rows of Q are short (length k ≪ d), so resolve the
                // kernel dispatch once for the whole apply — per-row
                // dispatch would cost as much as the k mul-adds it guards
                let lvl = simd::active();
                let mus = ridge_pow(*mu, p);
                let k = lam.len();
                // pass 1 over Q: coeff = Qᵀ x (row-wise accumulation)
                coeff.clear();
                coeff.resize(k, 0.0);
                for (r, &xr) in x.iter().enumerate() {
                    if xr != 0.0 {
                        simd::axpy_at(lvl, xr, q.row(r), coeff);
                    }
                }
                // scale: eigen-coordinates pick up ((λ+μ)^p − μ^p)
                for c in 0..k {
                    coeff[c] *= ridge_pow(lam[c] + *mu, p) - mus;
                }
                // pass 2 over Q (warm): out = μ^p x + Q coeff
                for r in 0..*dim {
                    out[r] = mus * x[r] + simd::dot_at(lvl, q.row(r), coeff);
                }
            }
        }
    }

    pub fn apply_pow(&self, p: f64, x: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.dim()];
        self.apply_pow_into(p, x, &mut out);
        out
    }

    /// `out = L^p · x` where `x` is sparse (indices + values). Cost
    /// O(dim · nnz) dense-path / O(k · nnz + dim · k) low-rank path — the
    /// decompression hot path at the server.
    ///
    /// Allocates scratch per call; hot paths should use
    /// [`PsdRoot::apply_pow_sparse_into_with`].
    pub fn apply_pow_sparse_into(&self, p: f64, idx: &[u32], val: &[f64], out: &mut [f64]) {
        let mut coeff = Vec::new();
        self.apply_pow_sparse_into_with(p, idx, val, out, &mut coeff);
    }

    /// Sparse-input apply with a caller-owned eigen-coordinate scratch
    /// (§Perf: allocation-free in the server decompression loop).
    pub fn apply_pow_sparse_into_with(
        &self,
        p: f64,
        idx: &[u32],
        val: &[f64],
        out: &mut [f64],
        coeff: &mut Vec<f64>,
    ) {
        match self {
            PsdRoot::Dense { eig, dim, .. } => {
                let lvl = simd::active();
                let n = *dim;
                let lmax = self.lambda_max();
                // coeff[c] = Σ_t V[i_t, c]·val_t — accumulate rows of V
                // sequentially (each row is the eigen-coordinates of e_i),
                // then scale by f(w) (§Perf: no column striding; dispatch
                // hoisted out of the per-nonzero loop)
                coeff.clear();
                coeff.resize(n, 0.0);
                for (t, &i) in idx.iter().enumerate() {
                    simd::axpy_at(lvl, val[t], eig.v.row(i as usize), coeff);
                }
                for c in 0..n {
                    coeff[c] *= pinv_pow(eig.w[c], p, lmax);
                }
                for r in 0..n {
                    out[r] = simd::dot_at(lvl, eig.v.row(r), coeff);
                }
            }
            PsdRoot::LowRankRidge { q, lam, mu, dim, .. } => {
                // the sparse-input face of the fused kernel: pass 1 over Q
                // touches only the nonzero rows, pass 2 is the same warm
                // output sweep as `apply_pow_fused_into` (dispatch hoisted
                // — rows of Q are length k ≪ d)
                let lvl = simd::active();
                let mus = ridge_pow(*mu, p);
                let k = lam.len();
                coeff.clear();
                coeff.resize(k, 0.0);
                for (t, &i) in idx.iter().enumerate() {
                    simd::axpy_at(lvl, val[t], q.row(i as usize), coeff);
                }
                for c in 0..k {
                    coeff[c] *= ridge_pow(lam[c] + *mu, p) - mus;
                }
                out.fill(0.0);
                for (t, &i) in idx.iter().enumerate() {
                    out[i as usize] = mus * val[t];
                }
                for r in 0..*dim {
                    out[r] += simd::dot_at(lvl, q.row(r), coeff);
                }
            }
        }
    }

    /// ‖x‖²_{L^p} = xᵀ L^p x (e.g. p = −1 for the paper's ‖·‖²_{L†}).
    pub fn wnorm2(&self, p: f64, x: &[f64]) -> f64 {
        vector::dot(&self.apply_pow(p, x), x)
    }

    /// Materialize L^p as a dense matrix (test/diagnostic use only).
    pub fn to_dense_pow(&self, p: f64) -> Mat {
        let n = self.dim();
        let mut m = Mat::zeros(n, n);
        let mut e = vec![0.0; n];
        let mut col = vec![0.0; n];
        for j in 0..n {
            e[j] = 1.0;
            self.apply_pow_into(p, &e, &mut col);
            for r in 0..n {
                m[(r, j)] = col[r];
            }
            e[j] = 0.0;
        }
        m
    }

    /// diag(L^p) without materializing the full matrix.
    pub fn diag_pow(&self, p: f64) -> Vec<f64> {
        match self {
            PsdRoot::Dense { eig, dim, .. } => {
                let n = *dim;
                let lmax = self.lambda_max();
                let mut d = vec![0.0; n];
                for r in 0..n {
                    let mut s = 0.0;
                    for c in 0..n {
                        let v = eig.v[(r, c)];
                        s += v * v * pinv_pow(eig.w[c], p, lmax);
                    }
                    d[r] = s;
                }
                d
            }
            PsdRoot::LowRankRidge { q, lam, mu, dim, .. } => {
                let mus = ridge_pow(*mu, p);
                let mut d = vec![mus; *dim];
                for r in 0..*dim {
                    for (c, &l) in lam.iter().enumerate() {
                        let v = q[(r, c)];
                        d[r] += v * v * (ridge_pow(l + *mu, p) - mus);
                    }
                }
                d
            }
        }
    }
}

#[inline]
fn pinv_pow(w: f64, p: f64, scale: f64) -> f64 {
    let w = w.max(0.0);
    if w <= PINV_TOL * scale.max(1.0) {
        // pseudo-inverse: zero eigenvalues map to zero for any power
        // (including negative); for positive powers 0^p = 0 anyway.
        0.0
    } else {
        w.powf(p)
    }
}

#[inline]
fn ridge_pow(w: f64, p: f64) -> f64 {
    if w <= 0.0 {
        0.0
    } else {
        w.powf(p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_psd(n: usize, seed: u64, ridge: f64) -> Mat {
        let mut rng = Rng::new(seed);
        let b = Mat::from_rows(
            (0..n)
                .map(|_| (0..n).map(|_| rng.normal()).collect())
                .collect(),
        );
        let mut g = b.gram();
        g.add_diag(ridge);
        g
    }

    #[test]
    fn dense_sqrt_squares_back() {
        let l = random_psd(8, 1, 0.1);
        let root = PsdRoot::from_dense(&l);
        let s = root.to_dense_pow(0.5);
        let back = s.matmul(&s);
        assert!(back.max_abs_diff(&l) < 1e-9);
    }

    #[test]
    fn dense_inverse_is_inverse() {
        let l = random_psd(6, 2, 0.5);
        let root = PsdRoot::from_dense(&l);
        let inv = root.to_dense_pow(-1.0);
        let prod = inv.matmul(&l);
        assert!(prod.max_abs_diff(&Mat::eye(6)) < 1e-9);
    }

    #[test]
    fn dense_pinv_on_singular() {
        // L = vvᵀ has rank 1; L^{1/2} L^{†1/2} should be the projector onto v.
        let v = [1.0, 2.0, 2.0];
        let mut l = Mat::zeros(3, 3);
        for i in 0..3 {
            for j in 0..3 {
                l[(i, j)] = v[i] * v[j];
            }
        }
        let root = PsdRoot::from_dense(&l);
        let half = root.to_dense_pow(0.5);
        let phalf = root.to_dense_pow(-0.5);
        let proj = half.matmul(&phalf);
        // projector: proj * v = v, proj * (orth) = 0
        let pv = proj.matvec(&v);
        for i in 0..3 {
            assert!((pv[i] - v[i]).abs() < 1e-9);
        }
        let orth = [2.0, -1.0, 0.0]; // orthogonal to v
        let po = proj.matvec(&orth);
        assert!(vector::norm(&po) < 1e-9);
    }

    #[test]
    fn lowrank_matches_dense() {
        // L = c AᵀA + μI with m < d, compare both paths.
        let mut rng = Rng::new(5);
        let (m, d) = (4, 9);
        let a = Mat::from_rows(
            (0..m)
                .map(|_| (0..d).map(|_| rng.normal()).collect())
                .collect(),
        );
        let (c, mu) = (0.25, 1e-3);
        let mut l = a.gram();
        l.scale(c);
        l.add_diag(mu);

        let dense = PsdRoot::from_dense(&l);
        let lr = PsdRoot::from_lowrank_ridge(&a, &a.gram_t(), c, mu);

        for p in [1.0, 0.5, -0.5, -1.0] {
            let md = dense.to_dense_pow(p);
            let ml = lr.to_dense_pow(p);
            assert!(
                md.max_abs_diff(&ml) < 1e-8,
                "p={p} diff={}",
                md.max_abs_diff(&ml)
            );
        }
        assert!((dense.lambda_max() - lr.lambda_max()).abs() < 1e-9);
        assert!((dense.lambda_min() - lr.lambda_min()).abs() < 1e-9);
    }

    #[test]
    fn lowrank_lambda_min_is_mu_when_rank_deficient() {
        let mut rng = Rng::new(6);
        let (m, d) = (3, 7);
        let a = Mat::from_rows(
            (0..m)
                .map(|_| (0..d).map(|_| rng.normal()).collect())
                .collect(),
        );
        let lr = PsdRoot::from_lowrank_ridge(&a, &a.gram_t(), 1.0, 0.01);
        assert!((lr.lambda_min() - 0.01).abs() < 1e-12);
    }

    #[test]
    fn sparse_apply_matches_dense_apply() {
        let l = random_psd(10, 3, 0.2);
        let root = PsdRoot::from_dense(&l);
        let idx = [2u32, 5, 9];
        let val = [1.5, -0.5, 2.0];
        let mut x = vec![0.0; 10];
        for (t, &i) in idx.iter().enumerate() {
            x[i as usize] = val[t];
        }
        for p in [0.5, -0.5] {
            let dense_out = root.apply_pow(p, &x);
            let mut sparse_out = vec![0.0; 10];
            root.apply_pow_sparse_into(p, &idx, &val, &mut sparse_out);
            for i in 0..10 {
                assert!((dense_out[i] - sparse_out[i]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn sparse_apply_lowrank_matches() {
        let mut rng = Rng::new(8);
        let (m, d) = (5, 12);
        let a = Mat::from_rows(
            (0..m)
                .map(|_| (0..d).map(|_| rng.normal()).collect())
                .collect(),
        );
        let lr = PsdRoot::from_lowrank_ridge(&a, &a.gram_t(), 0.25, 1e-3);
        let idx = [0u32, 7, 11];
        let val = [2.0, 1.0, -3.0];
        let mut x = vec![0.0; d];
        for (t, &i) in idx.iter().enumerate() {
            x[i as usize] = val[t];
        }
        let dense_out = lr.apply_pow(0.5, &x);
        let mut sparse_out = vec![0.0; d];
        lr.apply_pow_sparse_into(0.5, &idx, &val, &mut sparse_out);
        for i in 0..d {
            assert!((dense_out[i] - sparse_out[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn wnorm2_linv_positive() {
        let l = random_psd(5, 9, 0.3);
        let root = PsdRoot::from_dense(&l);
        let x = [1.0, -1.0, 0.5, 2.0, 0.0];
        assert!(root.wnorm2(-1.0, &x) > 0.0);
        // identity: ‖x‖²_{L} with L = I is ‖x‖²
        let id = PsdRoot::from_dense(&Mat::eye(5));
        assert!((id.wnorm2(1.0, &x) - vector::norm2(&x)).abs() < 1e-12);
    }

    #[test]
    fn diag_pow_matches_materialized() {
        let l = random_psd(7, 10, 0.1);
        let root = PsdRoot::from_dense(&l);
        for p in [1.0, 0.5, -1.0] {
            let d1 = root.diag_pow(p);
            let d2 = root.to_dense_pow(p).diag();
            for i in 0..7 {
                assert!((d1[i] - d2[i]).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn half_times_pinvhalf_is_identity_on_range() {
        // With ridge, L is PD so L^{1/2} L^{-1/2} = I exactly.
        let l = random_psd(6, 12, 0.05);
        let root = PsdRoot::from_dense(&l);
        let prod = root.to_dense_pow(0.5).matmul(&root.to_dense_pow(-0.5));
        assert!(prod.max_abs_diff(&Mat::eye(6)) < 1e-9);
    }
}
