//! Dense/sparse linear algebra substrate: vectors, row-major matrices,
//! CSR sparse matrices, symmetric eigensolvers, and PSD root operators
//! (`L^{1/2}`, `L^{†1/2}`) used by the matrix-smoothness-aware
//! compression protocol.
//!
//! The hot kernels (`vector::{dot, axpy, dist2, lincomb_into,
//! wnorm2_diag, rot2}`, `Mat::matvec_into`, the CSR matvecs) route
//! through [`simd`] — an explicit AVX2/AVX-512 layer with once-per-process
//! runtime dispatch and a portable blocked-scalar fallback, all arms
//! bitwise identical. `SMX_NO_SIMD=1` forces the scalar arm; see the
//! [`simd`] module docs for the dispatch seam and the safety contracts.

pub mod dense;
pub mod eigen;
pub mod psd;
pub mod simd;
pub mod sparse;
pub mod vector;

pub use dense::Mat;
pub use psd::PsdRoot;
pub use sparse::Csr;
