//! Dense/sparse linear algebra substrate: vectors, row-major matrices,
//! CSR sparse matrices, symmetric eigensolvers, and PSD root operators
//! (`L^{1/2}`, `L^{†1/2}`) used by the matrix-smoothness-aware
//! compression protocol.

pub mod dense;
pub mod eigen;
pub mod psd;
pub mod sparse;
pub mod vector;

pub use dense::Mat;
pub use psd::PsdRoot;
pub use sparse::Csr;
