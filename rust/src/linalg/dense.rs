//! Row-major dense matrices.
//!
//! Used for the smoothness-root operators (`L_i^{1/2}`, `L_i^{†1/2}`),
//! eigendecomposition workspaces, and the server-side decompression
//! algebra. Sizes are moderate (≤ a few thousand), so a straightforward
//! cache-friendly row-major kernel set suffices; the only hot routine is
//! `matvec`, which the decompressor calls per round.

use crate::linalg::vector;

#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>, // row-major: data[r * cols + c]
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: Vec<Vec<f64>>) -> Mat {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        assert!(rows.iter().all(|x| x.len() == c), "ragged rows");
        Mat {
            rows: r,
            cols: c,
            data: rows.into_iter().flatten().collect(),
        }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f64] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// out = A x
    ///
    /// §Perf: dispatches through [`crate::linalg::simd`] — 4-row blocks
    /// sharing one stream of `x`, each row on the canonical 4 accumulator
    /// lanes (explicit AVX2 where available, blocked scalar otherwise,
    /// bitwise identical either way).
    pub fn matvec_into(&self, x: &[f64], out: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(out.len(), self.rows);
        crate::linalg::simd::mat_matvec_into(&self.data, self.rows, self.cols, x, out);
    }

    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.rows];
        self.matvec_into(x, &mut out);
        out
    }

    /// out = Aᵀ x (x has length rows)
    pub fn tmatvec_into(&self, x: &[f64], out: &mut [f64]) {
        assert_eq!(x.len(), self.rows);
        assert_eq!(out.len(), self.cols);
        out.fill(0.0);
        for r in 0..self.rows {
            vector::axpy(x[r], self.row(r), out);
        }
    }

    pub fn tmatvec(&self, x: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.cols];
        self.tmatvec_into(x, &mut out);
        out
    }

    /// C = A * B
    ///
    /// §Perf: ikj loop order (stream B rows, accumulate into C rows),
    /// register-blocked two A-rows at a time so each loaded B row is used
    /// twice; the inner fused loop auto-vectorizes.
    pub fn matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows);
        let mut c = Mat::zeros(self.rows, b.cols);
        let bc = b.cols;
        let i2 = self.rows / 2 * 2;
        let mut i = 0;
        while i < i2 {
            let (head, tail) = c.data.split_at_mut((i + 1) * bc);
            let crow0 = &mut head[i * bc..];
            let crow1 = &mut tail[..bc];
            let a0 = self.row(i);
            let a1 = self.row(i + 1);
            for k in 0..self.cols {
                let (a0k, a1k) = (a0[k], a1[k]);
                if a0k == 0.0 && a1k == 0.0 {
                    continue;
                }
                let brow = b.row(k);
                for cc in 0..bc {
                    let v = brow[cc];
                    crow0[cc] += a0k * v;
                    crow1[cc] += a1k * v;
                }
            }
            i += 2;
        }
        if i < self.rows {
            let arow = self.row(i);
            let crow = &mut c.data[i * bc..(i + 1) * bc];
            for (k, &aik) in arow.iter().enumerate() {
                if aik == 0.0 {
                    continue;
                }
                vector::axpy(aik, b.row(k), crow);
            }
        }
        c
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t[(c, r)] = self[(r, c)];
            }
        }
        t
    }

    /// AᵀA (cols × cols), exploiting symmetry of the result.
    ///
    /// §Perf: the upper-triangle accumulation is expressed as a fused
    /// contiguous `axpy` over `row[i..]` (4-element blocks), instead of a
    /// scalar j-loop — same arithmetic per element, vectorizable.
    pub fn gram(&self) -> Mat {
        let n = self.cols;
        let mut g = Mat::zeros(n, n);
        for r in 0..self.rows {
            let row = self.row(r);
            for i in 0..n {
                let ri = row[i];
                if ri == 0.0 {
                    continue;
                }
                // only upper triangle: g[i, i..] += ri * row[i..]
                vector::axpy(ri, &row[i..], &mut g.data[i * n + i..i * n + n]);
            }
        }
        for i in 0..n {
            for j in 0..i {
                g.data[i * n + j] = g.data[j * n + i];
            }
        }
        g
    }

    /// AAᵀ (rows × rows).
    pub fn gram_t(&self) -> Mat {
        let n = self.rows;
        let mut g = Mat::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                let v = vector::dot(self.row(i), self.row(j));
                g.data[i * n + j] = v;
                g.data[j * n + i] = v;
            }
        }
        g
    }

    pub fn scale(&mut self, alpha: f64) {
        for v in self.data.iter_mut() {
            *v *= alpha;
        }
    }

    /// self += alpha * I (square only)
    pub fn add_diag(&mut self, alpha: f64) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            self.data[i * self.cols + i] += alpha;
        }
    }

    pub fn diag(&self) -> Vec<f64> {
        let n = self.rows.min(self.cols);
        (0..n).map(|i| self[(i, i)]).collect()
    }

    pub fn frobenius_norm(&self) -> f64 {
        vector::norm(&self.data)
    }

    /// Max |a_ij − b_ij|.
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0, |m, (a, b)| m.max((a - b).abs()))
    }

    pub fn is_symmetric(&self, tol: f64) -> bool {
        if self.rows != self.cols {
            return false;
        }
        for i in 0..self.rows {
            for j in 0..i {
                if (self[(i, j)] - self[(j, i)]).abs() > tol {
                    return false;
                }
            }
        }
        true
    }

    /// Quadratic form xᵀ A x.
    pub fn quad_form(&self, x: &[f64]) -> f64 {
        vector::dot(&self.matvec(x), x)
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f64 {
        &self.data[r * self.cols + c]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f64 {
        &mut self.data[r * self.cols + c]
    }
}

/// Pre-optimization scalar reference kernels, asserted equal to the
/// blocked implementations (here and in `tests/kernel_parity.rs`).
#[cfg(test)]
pub mod naive {
    use super::Mat;

    pub fn matvec(m: &Mat, x: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; m.rows];
        for r in 0..m.rows {
            let mut s = 0.0;
            for c in 0..m.cols {
                s += m[(r, c)] * x[c];
            }
            out[r] = s;
        }
        out
    }

    pub fn matmul(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for k in 0..a.cols {
                    s += a[(i, k)] * b[(k, j)];
                }
                c[(i, j)] = s;
            }
        }
        c
    }

    pub fn gram(a: &Mat) -> Mat {
        matmul(&a.transpose(), a)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Mat {
        Mat::from_rows(vec![vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]])
    }

    fn random_mat(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut rng = crate::util::rng::Rng::new(seed);
        Mat::from_rows(
            (0..rows)
                .map(|_| (0..cols).map(|_| rng.normal()).collect())
                .collect(),
        )
    }

    #[test]
    fn blocked_matvec_matches_naive() {
        for (rows, cols) in [(1, 5), (3, 4), (4, 1), (7, 9), (16, 16), (123, 37)] {
            let m = random_mat(rows, cols, rows as u64 * 100 + cols as u64);
            let mut rng = crate::util::rng::Rng::new(9);
            let x: Vec<f64> = (0..cols).map(|_| rng.normal()).collect();
            let fast = m.matvec(&x);
            let slow = naive::matvec(&m, &x);
            for r in 0..rows {
                assert!(
                    (fast[r] - slow[r]).abs() < 1e-12 * (1.0 + slow[r].abs()),
                    "matvec {rows}x{cols} row {r}: {} vs {}",
                    fast[r],
                    slow[r]
                );
            }
        }
    }

    #[test]
    fn blocked_matmul_and_gram_match_naive() {
        for (m, k, n) in [(1, 3, 2), (2, 2, 2), (5, 4, 3), (8, 7, 9), (13, 11, 6)] {
            let a = random_mat(m, k, 7 + m as u64);
            let b = random_mat(k, n, 11 + n as u64);
            let fast = a.matmul(&b);
            let slow = naive::matmul(&a, &b);
            assert!(
                fast.max_abs_diff(&slow) < 1e-12,
                "matmul {m}x{k}x{n} diff {}",
                fast.max_abs_diff(&slow)
            );
            let gf = a.gram();
            let gs = naive::gram(&a);
            assert!(gf.max_abs_diff(&gs) < 1e-12, "gram {m}x{k}");
        }
    }

    #[test]
    fn indexing_row_major() {
        let m = sample();
        assert_eq!(m[(0, 2)], 3.0);
        assert_eq!(m[(1, 0)], 4.0);
        assert_eq!(m.row(1), &[4.0, 5.0, 6.0]);
    }

    #[test]
    fn matvec_and_transpose_matvec() {
        let m = sample();
        assert_eq!(m.matvec(&[1.0, 1.0, 1.0]), vec![6.0, 15.0]);
        assert_eq!(m.tmatvec(&[1.0, 1.0]), vec![5.0, 7.0, 9.0]);
    }

    #[test]
    fn matmul_matches_manual() {
        let a = sample(); // 2x3
        let b = a.transpose(); // 3x2
        let c = a.matmul(&b); // 2x2 = A Aᵀ
        assert_eq!(c[(0, 0)], 14.0);
        assert_eq!(c[(0, 1)], 32.0);
        assert_eq!(c[(1, 0)], 32.0);
        assert_eq!(c[(1, 1)], 77.0);
        assert_eq!(c, a.gram_t());
    }

    #[test]
    fn gram_is_ata() {
        let a = sample();
        let g = a.gram();
        let expected = a.transpose().matmul(&a);
        assert!(g.max_abs_diff(&expected) < 1e-12);
        assert!(g.is_symmetric(0.0));
    }

    #[test]
    fn eye_and_add_diag() {
        let mut m = Mat::eye(3);
        m.add_diag(2.0);
        assert_eq!(m.diag(), vec![3.0, 3.0, 3.0]);
        assert_eq!(m[(0, 1)], 0.0);
    }

    #[test]
    fn quad_form_psd() {
        let a = sample();
        let g = a.gram();
        // Gram matrices are PSD: xᵀGx ≥ 0
        for x in [[1.0, -2.0, 0.5], [0.0, 1.0, -1.0]] {
            assert!(g.quad_form(&x) >= 0.0);
        }
    }

    #[test]
    #[should_panic]
    fn ragged_rows_panic() {
        Mat::from_rows(vec![vec![1.0], vec![1.0, 2.0]]);
    }
}
