//! # smx — Smoothness Matrices Beat Smoothness Constants
//!
//! A three-layer Rust + JAX + Pallas reproduction of
//! *"Smoothness Matrices Beat Smoothness Constants: Better Communication
//! Compression Techniques for Distributed Optimization"* (Safaryan,
//! Hanzely, Richtárik — NeurIPS 2021).
//!
//! The library implements the paper's data-dependent sparsification
//! protocol (Definition 3 / eq. (7)) and the matrix-smoothness-aware
//! redesigns DCGD+, DIANA+, ADIANA+ (Algorithms 1–3), the appendix
//! methods ISEGA+ and DIANA++ (Algorithms 7–8), the single-node family
//! SkGD/CGD+/'NSync (Algorithms 4–6), and all original baselines —
//! running on a parameter-server coordinator whose per-worker gradient
//! computation executes AOT-compiled JAX/Pallas artifacts through the
//! PJRT CPU client.
//!
//! Every run — any method × driver (sim / threaded / distributed) ×
//! payload — is composed through the [`coordinator::Session`] builder,
//! which also hosts the streaming-metrics observer seam and
//! checkpoint/resume; see [`coordinator::session`].
//!
//! See `DESIGN.md` for the system inventory and the experiment index, and
//! `EXPERIMENTS.md` for paper-vs-measured results.

// Numeric-kernel style: explicit index loops are deliberate in the hot
// paths (they are what LLVM vectorizes predictably), and the math-heavy
// constructors legitimately take many scalars.
#![allow(
    clippy::needless_range_loop,
    clippy::manual_memcpy,
    clippy::too_many_arguments,
    clippy::uninlined_format_args
)]

pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod linalg;
pub mod methods;
pub mod obs;
pub mod objective;
pub mod runtime;
pub mod sampling;
pub mod util;
pub mod wire;
