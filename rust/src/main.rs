//! `smx` — CLI for the Smoothness-Matrices distributed optimization
//! framework.
//!
//! Subcommands:
//!   train    run method(s) on one dataset, write residual curves
//!   figures  regenerate a paper figure (--figure 1|2|3|4|5|quant)
//!   tables   regenerate a paper table (--table 2|3|6|quant)
//!   solve    compute x* and problem constants for a dataset
//!   info     print dataset/smoothness diagnostics
//!   serve    distributed coordinator: accept worker processes over TCP
//!   worker   join a serve run (--connect HOST:PORT)
//!   relay    aggregation-tier relay between serve and its workers
//!   runs     inspect/compare/resume --run-dir artifacts (list|show|diff|resume)
//!
//! Common flags: --dataset --workers --tau --methods --sampling
//! --max-rounds --target-residual --seed --engine native|pjrt
//! --driver auto|sim|threaded|distributed --checkpoint-every N
//! --config file.json --out-dir results/ --data-dir data/
//! Wire flags:  --payload f64|f32|q16|q8|q4 --listen HOST:PORT
//! --wire-workers N --float-bits N
//!
//! Every run goes through the `coordinator::Session` front door, so each
//! method × driver × payload combination is reachable from this CLI.

#![allow(clippy::uninlined_format_args)]

use anyhow::{bail, Result};
use smx::config::ExperimentConfig;
use smx::experiments::{figures, runner, tables};
use smx::sampling::SamplingKind;
use smx::util::cli::Args;

const USAGE: &str = "usage: smx <train|figures|tables|solve|info|serve|worker|relay|runs> [flags]
  smx train   --dataset a1a --methods diana,diana+ --tau 1 --sampling uniform
  smx figures --figure 1 --datasets a1a,mushrooms
  smx tables  --table 2 --datasets a1a,mushrooms,phishing
  smx solve   --dataset mushrooms
  smx info    --dataset duke
  smx serve   --dataset a1a --methods diana+ --listen 127.0.0.1:4950 \\
              --wire-workers 2 --payload f32 [--check-sim] [--worker-timeout S]
              [--participation tau=K] [--min-clients M]
              [--run-dir DIR] [--fault-plan PLAN] [--no-crc]
              [--metrics-addr HOST:PORT] [--watch]
  smx worker  --connect 127.0.0.1:4950 [--pin-core N] [--die-after K]
              [--max-retries N] [--retry-base-ms MS] [--fault-plan PLAN]
  smx relay   --connect 127.0.0.1:4950 --listen 127.0.0.1:4951
              [--downstream N] [--max-retries N] [--retry-base-ms MS]
              [--die-after K] [--fault-plan PLAN]
              (aggregation tier: accepts worker/relay children on --listen,
              merges their uplink frames verbatim, forwards one combined
              frame upstream per round — bitwise identical to the flat
              topology; pair with serve --relay TIERS)
  smx runs    list [ROOT] | show DIR | diff A B | resume DIR
              (run-dir artifact store: enumerate runs, inspect one, compare
              two record streams on the deterministic columns, or resume an
              unfinished run from its stored config)
flags: --workers N --mu F --max-rounds N --target-residual F --seed N
       --engine native|pjrt --config FILE --out-dir DIR --data-dir DIR
       --record-every N --start-near-opt --jobs N (0 = all cores)
       --pin (pin threaded-driver workers to cores)
       --driver auto|sim|threaded|distributed (execution regime; auto =
       sim for native, threaded for pjrt; distributed = wire protocol
       over loopback with --wire-workers threads)
       --checkpoint-every N (observer checkpoints every N rounds; under
       serve also snapshots worker state + truncates the replay journal)
       --compressor default|sketch|matrix-aware|sa-quant|topk (uplink
       compressor family; default = the method's theory choice)
       --sa-levels N (sa-quant quantization levels s; 0 = exact
       passthrough) --sa-weighting diag|root (sa-quant weighting: the
       diagonal of L_i or its full PSD root)
wire:  --payload f64|f32|q16|q8|q4 --listen HOST:PORT --wire-workers N
       (0 = one process per shard) --float-bits N (modeled-bit override)
       --worker-timeout SECS (fault-tolerance grace window; 0 = fail fast)
       --participation tau=K (partial participation: each round an
       unbiased cohort of K of the n workers uplinks, reweighted by n/K;
       tau=n or full = every round is full participation — a strict
       no-op. Deterministic in the seed, so sim/threaded/distributed
       stay bitwise identical; diana++ is unsupported)
       --min-clients M (serve: start rounds once M worker processes are
       live; the rest join late over the snapshot + journal catch-up
       path without perturbing the trajectory; needs --worker-timeout)
       --pin-core N (pin this worker process) --die-after K (chaos: drop
       the connection after the K-th downlink, like a SIGKILL)
       --expect-restore (chaos: worker fails unless it was resumed from a
       checkpoint snapshot)
       --run-dir DIR (durable run log; a killed server restarted with the
       same config + --run-dir resumes bit-for-bit from its last
       committed snapshot — exit code 137 marks a planned kill)
       --no-crc (disable the CRC32 frame trailers; on by default)
       --metrics-addr HOST:PORT (serve Prometheus text at GET /metrics and
       a liveness probe at GET /healthz, multiplexed onto the server loop)
       --watch (live terminal dashboard on stderr: round rate, residual
       sparkline, measured-vs-modeled bytes, per-worker liveness)
       --fault-plan 'kill-server@r12;drop-uplink@r5:w1;corrupt-downlink@r9;
       delay@r7:50ms;pause@r4:w0;kill@r6:relay' (scripted faults; server
       events on serve, worker events on worker, :relay kills on relay;
       pause = the worker stops heartbeating for good but still answers
       its downlinks)
       --max-retries N --retry-base-ms MS (worker/relay reconnect backoff
       after a connection loss)
       --relay TIERS (serve: expect a relay topology instead of direct
       workers; comma-separated branch factors, e.g. --relay 2 for one
       tier of 2 relays) --downstream N (relay: children to accept)";

fn main() {
    smx::util::log::init_from_env();
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn config_from(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => ExperimentConfig::from_file(std::path::Path::new(path))?,
        None => ExperimentConfig::default(),
    };
    cfg.apply_args(args)?;
    Ok(cfg)
}

fn datasets_from(args: &Args) -> Vec<String> {
    args.list_or(
        "datasets",
        &["a1a", "mushrooms", "phishing", "madelon", "duke", "a8a"],
    )
}

fn run() -> Result<()> {
    let args = Args::from_env(true);
    let sub = match &args.subcommand {
        Some(s) => s.clone(),
        None => {
            println!("{USAGE}");
            return Ok(());
        }
    };

    match sub.as_str() {
        "train" => {
            let cfg = config_from(&args)?;
            let prep = runner::prepare(&cfg)?;
            let variants: Vec<runner::Variant> = cfg
                .methods
                .iter()
                .map(|m| {
                    let method: &'static str = smx::methods::METHOD_NAMES
                        .iter()
                        .find(|n| *n == m)
                        .copied()
                        .unwrap();
                    runner::Variant::new(
                        format!("{m}-{}", cfg.sampling.name()),
                        method,
                        cfg.sampling,
                        cfg.tau,
                    )
                })
                .collect();
            let results =
                runner::run_variants(&prep, &cfg, &variants, &format!("train_{}", cfg.dataset))?;
            println!("\nmethod                     rounds   final residual   coords_up");
            for (label, r) in &results {
                let last = r.records.last().unwrap();
                println!(
                    "{label:<26} {:>6}   {:>14.4e}   {:>9}",
                    r.rounds_run,
                    r.final_residual(),
                    last.coords_up
                );
            }
        }
        "figures" => {
            let cfg = config_from(&args)?;
            let fig = args.str_or("figure", "1");
            let datasets = datasets_from(&args);
            match fig.as_str() {
                "1" | "2" | "3" | "4" | "34" | "quant" => {
                    for ds in &datasets {
                        let mut c = cfg.clone();
                        c.dataset = ds.clone();
                        match fig.as_str() {
                            "1" => figures::fig1(&c)?,
                            "2" => figures::fig2(&c)?,
                            "quant" => figures::fig_quant(&c)?,
                            _ => figures::fig34(&c)?,
                        }
                    }
                }
                "5" => figures::fig5(&cfg)?,
                other => bail!("unknown figure '{other}' (1|2|3|4|5|quant)"),
            }
        }
        "tables" => {
            let cfg = config_from(&args)?;
            let datasets = datasets_from(&args);
            match args.str_or("table", "2").as_str() {
                "2" => {
                    tables::table2(&cfg, &datasets)?;
                }
                "3" => {
                    tables::table3(&cfg, &datasets)?;
                }
                "6" => {
                    tables::table6(&cfg, &datasets)?;
                }
                "quant" => {
                    tables::table_quant(&cfg, &datasets)?;
                }
                other => bail!("unknown table '{other}' (2|3|6|quant)"),
            }
        }
        "solve" => {
            let cfg = config_from(&args)?;
            let prep = runner::prepare(&cfg)?;
            println!(
                "dataset={} d={} n={} f*={:.12e}",
                cfg.dataset,
                prep.sm.dim,
                prep.sm.n(),
                prep.f_star
            );
        }
        "serve" => {
            let cfg = config_from(&args)?;
            if let Err(e) = smx::wire::serve(&cfg, args.bool_or("check-sim", false)) {
                // a planned --fault-plan kill mimics SIGKILL: exit 137 so
                // scripts can tell it from a real failure (exit 1)
                if format!("{e:#}").contains(smx::wire::KILLED_MARKER) {
                    eprintln!("{e:#}");
                    std::process::exit(137);
                }
                return Err(e);
            }
        }
        "worker" => {
            let addr = args
                .get("connect")
                .ok_or_else(|| anyhow::anyhow!("smx worker requires --connect HOST:PORT"))?;
            let opts = smx::wire::WorkerOpts {
                die_after: args
                    .get("die-after")
                    .map(|s| {
                        s.parse::<usize>()
                            .map_err(|_| anyhow::anyhow!("--die-after expects a round count"))
                    })
                    .transpose()?,
                pin: args
                    .get("pin-core")
                    .map(|s| {
                        s.parse::<usize>()
                            .map_err(|_| anyhow::anyhow!("--pin-core expects a core index"))
                    })
                    .transpose()?,
                expect_restore: args.bool_or("expect-restore", false),
                // worker-side fault events never use the seeded corrupt
                // bit, so the plan seed is irrelevant here
                fault: args
                    .get("fault-plan")
                    .map(|p| smx::wire::FaultPlan::parse(p, 0))
                    .transpose()?,
                max_retries: args
                    .get("max-retries")
                    .map(|s| {
                        s.parse::<usize>()
                            .map_err(|_| anyhow::anyhow!("--max-retries expects a count"))
                    })
                    .transpose()?
                    .unwrap_or_else(|| smx::wire::WorkerOpts::default().max_retries),
                retry_base_ms: args
                    .get("retry-base-ms")
                    .map(|s| {
                        s.parse::<u64>()
                            .map_err(|_| anyhow::anyhow!("--retry-base-ms expects milliseconds"))
                    })
                    .transpose()?
                    .unwrap_or_else(|| smx::wire::WorkerOpts::default().retry_base_ms),
            };
            smx::wire::worker_connect_with(addr, opts)?;
        }
        "relay" => {
            let upstream = args
                .get("connect")
                .ok_or_else(|| anyhow::anyhow!("smx relay requires --connect HOST:PORT"))?;
            let listen = args
                .get("listen")
                .ok_or_else(|| anyhow::anyhow!("smx relay requires --listen HOST:PORT"))?;
            let defaults = smx::wire::RelayOpts::default();
            let opts = smx::wire::RelayOpts {
                downstream: args
                    .get("downstream")
                    .map(|s| {
                        s.parse::<usize>()
                            .ok()
                            .filter(|&n| n > 0)
                            .ok_or_else(|| {
                                anyhow::anyhow!("--downstream expects a positive child count")
                            })
                    })
                    .transpose()?
                    .unwrap_or(defaults.downstream),
                max_retries: args
                    .get("max-retries")
                    .map(|s| {
                        s.parse::<usize>()
                            .map_err(|_| anyhow::anyhow!("--max-retries expects a count"))
                    })
                    .transpose()?
                    .unwrap_or(defaults.max_retries),
                retry_base_ms: args
                    .get("retry-base-ms")
                    .map(|s| {
                        s.parse::<u64>()
                            .map_err(|_| anyhow::anyhow!("--retry-base-ms expects milliseconds"))
                    })
                    .transpose()?
                    .unwrap_or(defaults.retry_base_ms),
                die_after: args
                    .get("die-after")
                    .map(|s| {
                        s.parse::<u64>()
                            .map_err(|_| anyhow::anyhow!("--die-after expects a round count"))
                    })
                    .transpose()?,
                // relay-side fault events never use the seeded corrupt
                // bit, so the plan seed is irrelevant here
                fault: args
                    .get("fault-plan")
                    .map(|p| smx::wire::FaultPlan::parse(p, 0))
                    .transpose()?,
            };
            smx::wire::relay_connect(upstream, listen, opts)?;
        }
        "runs" => {
            // `resume` hands back the stored config pointed at its run
            // dir; re-enter the serve path exactly as `smx serve` would
            if let Some(cfg) = smx::obs::runs::cmd(&args)? {
                if let Err(e) = smx::wire::serve(&cfg, false) {
                    if format!("{e:#}").contains(smx::wire::KILLED_MARKER) {
                        eprintln!("{e:#}");
                        std::process::exit(137);
                    }
                    return Err(e);
                }
            }
        }
        "info" => {
            let cfg = config_from(&args)?;
            let prep = runner::prepare_with(&cfg, false)?;
            let sm = &prep.sm;
            println!("dataset          {}", cfg.dataset);
            println!("points           {}", prep.dataset.num_points());
            println!("d                {}", sm.dim);
            println!("n (workers)      {}", sm.n());
            println!("m_i              {}", prep.shards[0].num_points());
            println!("density          {:.4}", prep.dataset.a.density());
            println!("mu               {:.3e}", sm.mu);
            println!("L                {:.6e}", sm.l);
            println!("L_max            {:.6e}", sm.l_max);
            println!("kappa=L_max/mu   {:.3e}", sm.kappa_max());
            println!("nu               {:.3}  (∈ [1, n])", sm.nu());
            println!("nu_1             {:.3}  (∈ [1, d])", sm.nu_s(1.0));
            println!("nu_2             {:.3}  (∈ [1, d])", sm.nu_s(2.0));
            let tau = cfg.tau;
            for (kind, label) in [
                (SamplingKind::Uniform, "uniform"),
                (SamplingKind::ImportanceDiana, "importance(19)"),
            ] {
                let mut tilde: f64 = 0.0;
                let mut om: f64 = 0.0;
                for loc in &sm.locals {
                    let s = kind.build(&loc.diag, tau, sm.mu, sm.n());
                    tilde = tilde.max(s.tilde_l(&loc.diag));
                    om = om.max(s.omega());
                }
                println!("tau={tau} {label:<15} omega_max={om:<12.3} tilde_L_max={tilde:.6e}");
            }
        }
        other => {
            bail!("unknown subcommand '{other}'\n{USAGE}");
        }
    }
    Ok(())
}
