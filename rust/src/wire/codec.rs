//! Framed binary codec for protocol messages.
//!
//! See the [module docs](crate::wire) for the format overview. Layout
//! reference (all integers little-endian, varints are LEB128):
//!
//! ```text
//! uplink frame body:    TAG_UPLINK  shard:varint  payload:u8  flags:u8
//!                       sparse(delta)  [sparse(delta2) if flags&1]
//! agg uplink body:      TAG_AGG_UPLINK  payload:u8  nwords:varint
//!                       bitmap:u64×nwords  count:varint
//!                       (len:varint  uplink-body)×count, shard-ascending
//! downlink frame body:  TAG_DOWNLINK  payload:u8  kind:u8  dense|sparse…
//! sparse block:         count:varint  [mode:u8  indices…  values…]
//!   mode 0 (sorted-gap) idx[0]:varint  (idx[k]−idx[k−1]):varint …
//!   mode 1 (raw)        idx[k]:varint …
//! dense block:          len:varint  values…
//! values (k > 0):       f64: k×8 | f32: k×4
//!                       qb:  scale:f64 then k scaled ints (q4 packs two
//!                            values per byte, low nibble first)
//! ```
//!
//! Lossy payload semantics are exact specifications, not approximations:
//! `f32` stores `v as f32`; `qb` stores `round(v/scale · qmax)` clamped to
//! `[−qmax, qmax]` with `scale = max |v|` over the block, decoding to
//! `(q/qmax)·scale`. Tests assert both the exact spec and the implied
//! error bound `|v̂ − v| ≤ scale/(2·qmax)`.

use crate::compress::{CompressorKind, QuantWeighting, SparseMsg};
use crate::methods::{Downlink, Uplink};
use crate::sampling::SamplingKind;
use crate::util::json::Json;
use std::fmt;

/// Bytes of the `u32` frame-length prefix, included in measured byte
/// counts so `bytes_up`/`bytes_down` reflect what a TCP wire carries.
pub const FRAME_PREFIX: usize = 4;

/// Frames a worker process can receive/send. First byte of every body.
pub const TAG_HELLO: u8 = 1;
pub const TAG_HELLO_ACK: u8 = 2;
pub const TAG_DOWNLINK: u8 = 3;
pub const TAG_UPLINK: u8 = 4;
pub const TAG_STOP: u8 = 5;
/// Worker → server liveness beacon (sent on downlink receipt, between
/// shards of a multi-shard round, and periodically during replay). Resets
/// the server's `--worker-timeout` grace clock; carries no payload.
pub const TAG_HEARTBEAT: u8 = 6;
/// Server → worker: "the next `count` frames are journaled downlinks —
/// replay them silently except the last, which is live". Sent right after
/// a rejoining worker's handshake ack.
pub const TAG_REPLAY: u8 = 7;
/// Server → worker: adopt orphaned shards (listed in the body), then a
/// replay block for *those shards only* follows, last frame live.
pub const TAG_ADOPT: u8 = 8;
/// Server → worker: "serialize the evolving state of every shard you
/// host, as of the round named in the body, and send one
/// [`TAG_SNAP_STATE`] frame per shard". Sent on the `checkpoint_every`
/// cadence; feeds the journal-truncating snapshot.
pub const TAG_SNAP_REQ: u8 = 9;
/// Worker → server: one shard's checkpoint blob (RNG state + the
/// [`WorkerAlgo::save_state`](crate::methods::WorkerAlgo::save_state)
/// bytes). Protocol overhead, excluded from the byte accounting like
/// heartbeats.
pub const TAG_SNAP_STATE: u8 = 10;
/// Server → worker: restore the listed shards from snapshot blobs before
/// replaying. Follows a `TAG_REPLAY`/`TAG_ADOPT` announcement whose
/// restore flag is set; the replay then covers only the journaled rounds
/// *after* the snapshot.
pub const TAG_RESTORE: u8 = 11;
/// Relay → server: one frame carrying several shards' uplink bodies
/// *verbatim* (each byte-identical to the frame its worker sent), plus a
/// contributing-shard bitmap. Aggregation stays exact — and therefore
/// topology-invariant down to the bit — because the constituents are
/// never re-encoded: the server unpacks each into its per-shard decode
/// slot exactly as if it had arrived on its own connection. See
/// [`merge_uplinks`].
pub const TAG_AGG_UPLINK: u8 = 12;

const IDX_SORTED_GAP: u8 = 0;
const IDX_RAW: u8 = 1;

const DOWN_DENSE: u8 = 0;
const DOWN_DENSE_W: u8 = 1;
const DOWN_SPARSE: u8 = 2;
const DOWN_INIT: u8 = 3;

/// Decode failure (truncated/malformed frame, unknown payload, …).
#[derive(Debug)]
pub struct WireError {
    msg: String,
}

impl WireError {
    pub fn new(msg: impl Into<String>) -> WireError {
        WireError { msg: msg.into() }
    }
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "wire: {}", self.msg)
    }
}

impl std::error::Error for WireError {}

type Result<T> = std::result::Result<T, WireError>;

/// Value payload carried by every message of a run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Payload {
    /// 8 bytes/value, lossless — the reference payload.
    F64,
    /// 4 bytes/value (`v as f32`).
    F32,
    /// 2 bytes/value, per-message scale.
    Q16,
    /// 1 byte/value, per-message scale.
    Q8,
    /// ½ byte/value, per-message scale.
    Q4,
}

impl Payload {
    pub const ALL: [Payload; 5] =
        [Payload::F64, Payload::F32, Payload::Q16, Payload::Q8, Payload::Q4];

    pub fn parse(s: &str) -> Option<Payload> {
        match s {
            "f64" => Some(Payload::F64),
            "f32" => Some(Payload::F32),
            "q16" => Some(Payload::Q16),
            "q8" => Some(Payload::Q8),
            "q4" => Some(Payload::Q4),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Payload::F64 => "f64",
            Payload::F32 => "f32",
            Payload::Q16 => "q16",
            Payload::Q8 => "q8",
            Payload::Q4 => "q4",
        }
    }

    /// Bits per value — what `RunConfig::float_bits` derives from
    /// (Appendix C.5 counts 32 bits/float; `q*` count their width).
    pub fn bits(self) -> u32 {
        match self {
            Payload::F64 => 64,
            Payload::F32 => 32,
            Payload::Q16 => 16,
            Payload::Q8 => 8,
            Payload::Q4 => 4,
        }
    }

    pub fn is_lossless(self) -> bool {
        matches!(self, Payload::F64)
    }

    fn id(self) -> u8 {
        match self {
            Payload::F64 => 0,
            Payload::F32 => 1,
            Payload::Q16 => 2,
            Payload::Q8 => 3,
            Payload::Q4 => 4,
        }
    }

    fn from_id(b: u8) -> Result<Payload> {
        Payload::ALL
            .into_iter()
            .find(|p| p.id() == b)
            .ok_or_else(|| WireError::new(format!("unknown payload id {b}")))
    }

    /// Largest representable quantization level (`q*` payloads only).
    fn qmax(self) -> f64 {
        match self {
            Payload::Q16 => 32767.0,
            Payload::Q8 => 127.0,
            Payload::Q4 => 7.0,
            Payload::F64 | Payload::F32 => unreachable!("qmax of a float payload"),
        }
    }

    /// Worst-case absolute decode error for one value in a block whose
    /// max magnitude is `scale` (0 for `f64`).
    pub fn max_abs_err(self, scale: f64) -> f64 {
        match self {
            Payload::F64 => 0.0,
            // half-ulp relative rounding, plus the smallest subnormal for
            // values that underflow the f32 range entirely
            Payload::F32 => scale * (f32::EPSILON as f64) + f64::from(f32::from_bits(1)),
            q => scale / (2.0 * q.qmax()),
        }
    }
}

// ---- varints -----------------------------------------------------------

/// Encoded length of `v` as a LEB128 varint.
pub fn varint_len(v: u64) -> usize {
    let bits = (64 - v.leading_zeros() as usize).max(1);
    bits / 7 + usize::from(bits % 7 != 0)
}

pub fn put_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            return;
        }
        out.push(b | 0x80);
    }
}

pub fn get_varint(buf: &[u8], pos: &mut usize) -> Result<u64> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let b = *buf
            .get(*pos)
            .ok_or_else(|| WireError::new("truncated varint"))?;
        *pos += 1;
        if shift > 63 || (shift == 63 && b & 0x7f > 1) {
            return Err(WireError::new("varint overflows u64"));
        }
        v |= ((b & 0x7f) as u64) << shift;
        if b & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
    }
}

fn take<'a>(buf: &'a [u8], pos: &mut usize, n: usize) -> Result<&'a [u8]> {
    let end = pos
        .checked_add(n)
        .filter(|&e| e <= buf.len())
        .ok_or_else(|| WireError::new("truncated frame"))?;
    let s = &buf[*pos..end];
    *pos = end;
    Ok(s)
}

fn take1(buf: &[u8], pos: &mut usize) -> Result<u8> {
    Ok(take(buf, pos, 1)?[0])
}

/// Tag of a frame body (its first byte).
pub fn frame_tag(body: &[u8]) -> Result<u8> {
    body.first()
        .copied()
        .ok_or_else(|| WireError::new("empty frame"))
}

// ---- value blocks ------------------------------------------------------

/// Encoded bytes of a k-value block under `payload` (0 for an empty block:
/// the scale header is skipped too).
pub fn values_len(k: usize, payload: Payload) -> usize {
    if k == 0 {
        return 0;
    }
    match payload {
        Payload::F64 => 8 * k,
        Payload::F32 => 4 * k,
        Payload::Q16 => 8 + 2 * k,
        Payload::Q8 => 8 + k,
        Payload::Q4 => 8 + k / 2 + k % 2,
    }
}

fn block_scale(vals: &[f64]) -> f64 {
    vals.iter().fold(0.0f64, |a, &v| a.max(v.abs()))
}

fn quantize(v: f64, scale: f64, qmax: f64) -> i32 {
    if scale == 0.0 {
        return 0;
    }
    (v / scale * qmax).round().clamp(-qmax, qmax) as i32
}

fn put_values(out: &mut Vec<u8>, vals: &[f64], payload: Payload) -> Result<()> {
    if vals.is_empty() {
        return Ok(());
    }
    match payload {
        Payload::F64 => {
            for &v in vals {
                out.extend_from_slice(&v.to_bits().to_le_bytes());
            }
        }
        Payload::F32 => {
            for &v in vals {
                out.extend_from_slice(&(v as f32).to_bits().to_le_bytes());
            }
        }
        Payload::Q16 | Payload::Q8 | Payload::Q4 => {
            // A NaN or ±inf poisons the whole block: block_scale becomes
            // non-finite (or NaN-skipped), and every quantize() in the
            // block silently decodes to garbage. The float payloads carry
            // non-finite values bit-transparently, so only the q-path
            // refuses them.
            if let Some(bad) = vals.iter().find(|v| !v.is_finite()) {
                return Err(WireError::new(format!(
                    "non-finite value {bad} cannot be encoded under a quantized payload ({})",
                    payload.name()
                )));
            }
            let scale = block_scale(vals);
            let qmax = payload.qmax();
            out.extend_from_slice(&scale.to_bits().to_le_bytes());
            match payload {
                Payload::Q16 => {
                    for &v in vals {
                        out.extend_from_slice(&(quantize(v, scale, qmax) as i16).to_le_bytes());
                    }
                }
                Payload::Q8 => {
                    for &v in vals {
                        out.push(quantize(v, scale, qmax) as i8 as u8);
                    }
                }
                Payload::Q4 => {
                    // two values per byte, low nibble first; nibble = q + 7
                    for pair in vals.chunks(2) {
                        let lo = (quantize(pair[0], scale, qmax) + 7) as u8;
                        let hi = if pair.len() > 1 {
                            (quantize(pair[1], scale, qmax) + 7) as u8
                        } else {
                            0
                        };
                        out.push(lo | (hi << 4));
                    }
                }
                _ => unreachable!(),
            }
        }
    }
    Ok(())
}

fn get_values(
    buf: &[u8],
    pos: &mut usize,
    k: usize,
    payload: Payload,
    out: &mut Vec<f64>,
) -> Result<()> {
    out.clear();
    if k == 0 {
        return Ok(());
    }
    // bounds-check the whole block before reserving, so a malformed count
    // cannot trigger a huge allocation
    let need = values_len(k, payload);
    if buf.len() - *pos < need {
        return Err(WireError::new("truncated value block"));
    }
    out.reserve(k);
    match payload {
        Payload::F64 => {
            let bytes = take(buf, pos, 8 * k)?;
            for c in bytes.chunks_exact(8) {
                out.push(f64::from_bits(u64::from_le_bytes(c.try_into().unwrap())));
            }
        }
        Payload::F32 => {
            let bytes = take(buf, pos, 4 * k)?;
            for c in bytes.chunks_exact(4) {
                out.push(f64::from(f32::from_bits(u32::from_le_bytes(
                    c.try_into().unwrap(),
                ))));
            }
        }
        Payload::Q16 | Payload::Q8 | Payload::Q4 => {
            let scale = f64::from_bits(u64::from_le_bytes(take(buf, pos, 8)?.try_into().unwrap()));
            let qmax = payload.qmax();
            match payload {
                Payload::Q16 => {
                    let bytes = take(buf, pos, 2 * k)?;
                    for c in bytes.chunks_exact(2) {
                        let q = i16::from_le_bytes(c.try_into().unwrap());
                        out.push(q as f64 / qmax * scale);
                    }
                }
                Payload::Q8 => {
                    let bytes = take(buf, pos, k)?;
                    for &b in bytes {
                        out.push(b as i8 as f64 / qmax * scale);
                    }
                }
                Payload::Q4 => {
                    let bytes = take(buf, pos, k / 2 + k % 2)?;
                    for (j, &b) in bytes.iter().enumerate() {
                        let lo = (b & 0x0f) as i32 - 7;
                        out.push(lo as f64 / qmax * scale);
                        if 2 * j + 1 < k {
                            let hi = (b >> 4) as i32 - 7;
                            out.push(hi as f64 / qmax * scale);
                        }
                    }
                }
                _ => unreachable!(),
            }
        }
    }
    Ok(())
}

// ---- sparse / dense blocks --------------------------------------------

/// Encoded bytes of one [`SparseMsg`] block.
pub fn sparse_len(msg: &SparseMsg, payload: Payload) -> usize {
    let k = msg.idx.len();
    let mut n = varint_len(k as u64);
    if k > 0 {
        n += 1; // index-mode byte
        if idx_sorted(&msg.idx) {
            n += varint_len(msg.idx[0] as u64);
            for w in msg.idx.windows(2) {
                n += varint_len((w[1] - w[0]) as u64);
            }
        } else {
            for &i in &msg.idx {
                n += varint_len(i as u64);
            }
        }
        n += values_len(k, payload);
    }
    n
}

fn idx_sorted(idx: &[u32]) -> bool {
    idx.windows(2).all(|w| w[0] < w[1])
}

fn put_sparse(out: &mut Vec<u8>, msg: &SparseMsg, payload: Payload) -> Result<()> {
    let k = msg.idx.len();
    put_varint(out, k as u64);
    if k == 0 {
        return Ok(());
    }
    if idx_sorted(&msg.idx) {
        out.push(IDX_SORTED_GAP);
        put_varint(out, msg.idx[0] as u64);
        for w in msg.idx.windows(2) {
            put_varint(out, (w[1] - w[0]) as u64);
        }
    } else {
        out.push(IDX_RAW);
        for &i in &msg.idx {
            put_varint(out, i as u64);
        }
    }
    put_values(out, &msg.val, payload)
}

fn get_sparse(
    buf: &[u8],
    pos: &mut usize,
    dim: usize,
    payload: Payload,
    msg: &mut SparseMsg,
) -> Result<()> {
    msg.clear();
    let k = get_varint(buf, pos)? as usize;
    if k == 0 {
        return Ok(());
    }
    // each index costs ≥ 1 byte, so k can never exceed the remaining bytes
    if k > buf.len() - *pos {
        return Err(WireError::new("sparse count exceeds frame"));
    }
    if k > dim {
        return Err(WireError::new(format!("sparse count {k} exceeds dim {dim}")));
    }
    let mode = take1(buf, pos)?;
    msg.idx.reserve(k);
    match mode {
        IDX_SORTED_GAP => {
            let mut cur = get_varint(buf, pos)?;
            for taken in 0..k {
                if cur >= dim as u64 {
                    return Err(WireError::new(format!("index {cur} out of range (d={dim})")));
                }
                msg.idx.push(cur as u32);
                if taken + 1 < k {
                    let gap = get_varint(buf, pos)?;
                    if gap == 0 {
                        // the encoder only emits this mode for strictly
                        // increasing indices; a zero gap would decode to a
                        // duplicate index that apply would double-count
                        return Err(WireError::new("zero index gap in sorted-gap mode"));
                    }
                    cur = cur
                        .checked_add(gap)
                        .ok_or_else(|| WireError::new("index gap overflow"))?;
                }
            }
        }
        IDX_RAW => {
            for _ in 0..k {
                let i = get_varint(buf, pos)?;
                if i >= dim as u64 {
                    return Err(WireError::new(format!("index {i} out of range (d={dim})")));
                }
                msg.idx.push(i as u32);
            }
        }
        other => return Err(WireError::new(format!("unknown index mode {other}"))),
    }
    get_values(buf, pos, k, payload, &mut msg.val)
}

fn dense_len(n: usize, payload: Payload) -> usize {
    varint_len(n as u64) + values_len(n, payload)
}

fn put_dense(out: &mut Vec<u8>, vals: &[f64], payload: Payload) -> Result<()> {
    put_varint(out, vals.len() as u64);
    put_values(out, vals, payload)
}

fn get_dense(
    buf: &[u8],
    pos: &mut usize,
    dim: usize,
    payload: Payload,
    out: &mut Vec<f64>,
) -> Result<()> {
    let n = get_varint(buf, pos)? as usize;
    if n != dim {
        return Err(WireError::new(format!("dense block len {n}, expected {dim}")));
    }
    get_values(buf, pos, n, payload, out)
}

// ---- uplink frames -----------------------------------------------------

/// Serialize `up` (frame body only — transports add the length prefix).
///
/// Fails without writing a decodable frame when a quantized payload meets
/// a non-finite value; callers must treat the buffer as poisoned (every
/// runtime call site clears or drops it on error).
pub fn put_uplink(out: &mut Vec<u8>, up: &Uplink, shard: usize, payload: Payload) -> Result<()> {
    out.push(TAG_UPLINK);
    put_varint(out, shard as u64);
    out.push(payload.id());
    out.push(up.delta2.is_some() as u8);
    put_sparse(out, &up.delta, payload)?;
    if let Some(d2) = &up.delta2 {
        put_sparse(out, d2, payload)?;
    }
    Ok(())
}

/// Read the shard index of an uplink frame without decoding the message —
/// the server needs it to pick the decode slot.
pub fn peek_uplink_shard(body: &[u8]) -> Result<usize> {
    let mut pos = 0usize;
    if take1(body, &mut pos)? != TAG_UPLINK {
        return Err(WireError::new("expected uplink frame"));
    }
    Ok(get_varint(body, &mut pos)? as usize)
}

/// Decode an uplink frame body into `up` (buffers reused); returns the
/// hosting shard index.
pub fn get_uplink(body: &[u8], dim: usize, up: &mut Uplink) -> Result<usize> {
    let mut pos = 0usize;
    if take1(body, &mut pos)? != TAG_UPLINK {
        return Err(WireError::new("expected uplink frame"));
    }
    let shard = get_varint(body, &mut pos)? as usize;
    let payload = Payload::from_id(take1(body, &mut pos)?)?;
    let flags = take1(body, &mut pos)?;
    get_sparse(body, &mut pos, dim, payload, &mut up.delta)?;
    if flags & 1 != 0 {
        let d2 = match &mut up.delta2 {
            Some(d2) => d2,
            slot => slot.insert(SparseMsg::new()),
        };
        get_sparse(body, &mut pos, dim, payload, d2)?;
    } else {
        up.delta2 = None;
    }
    if pos != body.len() {
        return Err(WireError::new("trailing bytes in uplink frame"));
    }
    Ok(shard)
}

/// Exact on-the-wire size of an uplink frame (length prefix included) —
/// what the in-process drivers record as measured `bytes_up`.
pub fn uplink_frame_len(up: &Uplink, shard: usize, payload: Payload) -> usize {
    FRAME_PREFIX
        + 1 // tag
        + varint_len(shard as u64)
        + 2 // payload id + flags
        + sparse_len(&up.delta, payload)
        + up.delta2.as_ref().map_or(0, |m| sparse_len(m, payload))
}

// ---- aggregated uplink frames (relay tier) -----------------------------

/// Merge sibling uplink frame bodies into one [`TAG_AGG_UPLINK`] body.
///
/// The merge is *structural*, never arithmetic: each constituent body is
/// carried verbatim (canonicalized to ascending shard order), so the
/// server decodes every shard's message from exactly the bytes its worker
/// encoded. Summing values at the relay would be wrong twice over — the
/// server applies a *per-shard* smoothness root to each uplink before
/// accumulating, and f64 addition is non-associative — whereas forwarding
/// frames intact keeps the flat and tree topologies bitwise identical for
/// every payload, lossless or quantized.
///
/// Inputs may themselves be aggregated frames (a 3-level tree's middle
/// tier): they are flattened one level. Errors on an empty input, a
/// non-uplink tag, duplicate shards, or — the failure mode worth a loud
/// message — siblings that disagree on the payload encoding (mixed
/// float-bits cannot share one aggregate header).
pub fn merge_uplinks(out: &mut Vec<u8>, frames: &[&[u8]]) -> Result<()> {
    out.clear();
    if frames.is_empty() {
        return Err(WireError::new("merging zero uplink frames"));
    }
    let mut parts: Vec<(usize, u8, &[u8])> = Vec::with_capacity(frames.len());
    let mut scratch = Vec::new();
    for &f in frames {
        match frame_tag(f)? {
            TAG_UPLINK => {
                let mut pos = 1usize;
                let shard = get_varint(f, &mut pos)? as usize;
                let pid = take1(f, &mut pos)?;
                Payload::from_id(pid)?;
                parts.push((shard, pid, f));
            }
            TAG_AGG_UPLINK => {
                let payload = get_agg_uplink(f, &mut scratch)?;
                for &(shard, start, end) in &scratch {
                    parts.push((shard, payload.id(), &f[start..end]));
                }
            }
            other => {
                return Err(WireError::new(format!(
                    "merge: frame tag {other} is not an uplink"
                )))
            }
        }
    }
    let pid = parts[0].1;
    if let Some(&(_, other, _)) = parts.iter().find(|p| p.1 != pid) {
        return Err(WireError::new(format!(
            "merge: sibling uplinks disagree on payload encoding ({} vs {}); \
             refusing to aggregate incompatible frames",
            Payload::from_id(pid)?.name(),
            Payload::from_id(other)?.name()
        )));
    }
    parts.sort_by_key(|p| p.0);
    if let Some(w) = parts.windows(2).find(|w| w[0].0 == w[1].0) {
        return Err(WireError::new(format!(
            "merge: shard {} appears in two sibling uplinks",
            w[0].0
        )));
    }
    let nwords = parts.last().unwrap().0 / 64 + 1;
    let mut words = vec![0u64; nwords];
    for &(shard, _, _) in &parts {
        words[shard / 64] |= 1u64 << (shard % 64);
    }
    out.push(TAG_AGG_UPLINK);
    out.push(pid);
    put_varint(out, nwords as u64);
    for w in &words {
        out.extend_from_slice(&w.to_le_bytes());
    }
    put_varint(out, parts.len() as u64);
    for &(_, _, body) in &parts {
        put_varint(out, body.len() as u64);
        out.extend_from_slice(body);
    }
    Ok(())
}

/// Walk a [`TAG_AGG_UPLINK`] body, filling `parts` with
/// `(shard, start, end)` such that `body[start..end]` is the constituent's
/// full [`TAG_UPLINK`] body. Returns the aggregate's payload.
///
/// Validates the envelope — bitmap/count agreement, strictly ascending
/// shards, every constituent's header matching the aggregate payload, no
/// trailing bytes — but not the constituent *values*; the caller decodes
/// each slice with [`get_uplink`], which finishes the job.
pub fn get_agg_uplink(body: &[u8], parts: &mut Vec<(usize, usize, usize)>) -> Result<Payload> {
    parts.clear();
    let mut pos = 0usize;
    if take1(body, &mut pos)? != TAG_AGG_UPLINK {
        return Err(WireError::new("expected aggregated uplink frame"));
    }
    let payload = Payload::from_id(take1(body, &mut pos)?)?;
    let nwords = get_varint(body, &mut pos)? as usize;
    if nwords == 0 {
        return Err(WireError::new("aggregated uplink with empty bitmap"));
    }
    let words = take(body, &mut pos, nwords.checked_mul(8).ok_or_else(|| {
        WireError::new("aggregated uplink bitmap overflows")
    })?)?;
    let word = |k: usize| u64::from_le_bytes(words[8 * k..8 * k + 8].try_into().unwrap());
    let popcount: u64 = (0..nwords).map(|k| word(k).count_ones() as u64).sum();
    let count = get_varint(body, &mut pos)?;
    if count == 0 {
        return Err(WireError::new("aggregated uplink carries no frames"));
    }
    if count != popcount {
        return Err(WireError::new(format!(
            "aggregated uplink bitmap names {popcount} shard(s) but carries {count} frame(s)"
        )));
    }
    let mut prev: Option<usize> = None;
    for _ in 0..count {
        let len = get_varint(body, &mut pos)? as usize;
        let start = pos;
        let sub = take(body, &mut pos, len)?;
        let mut sp = 0usize;
        if take1(sub, &mut sp)? != TAG_UPLINK {
            return Err(WireError::new("aggregated uplink constituent is not an uplink"));
        }
        let shard = get_varint(sub, &mut sp)? as usize;
        if Payload::from_id(take1(sub, &mut sp)?)? != payload {
            return Err(WireError::new(
                "aggregated uplink constituent disagrees with the aggregate payload",
            ));
        }
        if prev.is_some_and(|p| shard <= p) {
            return Err(WireError::new(
                "aggregated uplink constituents out of shard order",
            ));
        }
        prev = Some(shard);
        if shard / 64 >= nwords || (word(shard / 64) >> (shard % 64)) & 1 == 0 {
            return Err(WireError::new(format!(
                "aggregated uplink shard {shard} missing from the bitmap"
            )));
        }
        parts.push((shard, start, pos));
    }
    if pos != body.len() {
        return Err(WireError::new("trailing bytes in aggregated uplink frame"));
    }
    Ok(payload)
}

// ---- downlink frames ---------------------------------------------------

/// Serialize `down` (frame body only). Errs like [`put_uplink`] when a
/// quantized payload meets a non-finite value.
pub fn put_downlink(out: &mut Vec<u8>, down: &Downlink, payload: Payload) -> Result<()> {
    out.push(TAG_DOWNLINK);
    out.push(payload.id());
    match down {
        Downlink::Dense { x, w } => match w {
            Some(w) => {
                out.push(DOWN_DENSE_W);
                put_dense(out, x, payload)?;
                put_dense(out, w, payload)?;
            }
            None => {
                out.push(DOWN_DENSE);
                put_dense(out, x, payload)?;
            }
        },
        Downlink::Sparse { delta } => {
            out.push(DOWN_SPARSE);
            put_sparse(out, delta, payload)?;
        }
        Downlink::Init { x } => {
            out.push(DOWN_INIT);
            put_dense(out, x, payload)?;
        }
    }
    Ok(())
}

/// Decode a downlink frame body into `down`, reusing its buffers when the
/// variant matches (the steady-state case on the worker side).
pub fn get_downlink(body: &[u8], dim: usize, down: &mut Downlink) -> Result<()> {
    let mut pos = 0usize;
    if take1(body, &mut pos)? != TAG_DOWNLINK {
        return Err(WireError::new("expected downlink frame"));
    }
    let payload = Payload::from_id(take1(body, &mut pos)?)?;
    let kind = take1(body, &mut pos)?;
    match kind {
        DOWN_DENSE | DOWN_DENSE_W => {
            if !matches!(down, Downlink::Dense { .. }) {
                *down = Downlink::Dense {
                    x: Vec::new(),
                    w: None,
                };
            }
            let Downlink::Dense { x, w } = down else {
                unreachable!()
            };
            get_dense(body, &mut pos, dim, payload, x)?;
            if kind == DOWN_DENSE_W {
                let wv = match w {
                    Some(wv) => wv,
                    slot => slot.insert(Vec::new()),
                };
                get_dense(body, &mut pos, dim, payload, wv)?;
            } else {
                *w = None;
            }
        }
        DOWN_SPARSE => {
            if !matches!(down, Downlink::Sparse { .. }) {
                *down = Downlink::Sparse {
                    delta: SparseMsg::new(),
                };
            }
            let Downlink::Sparse { delta } = down else {
                unreachable!()
            };
            get_sparse(body, &mut pos, dim, payload, delta)?;
        }
        DOWN_INIT => {
            if !matches!(down, Downlink::Init { .. }) {
                *down = Downlink::Init { x: Vec::new() };
            }
            let Downlink::Init { x } = down else {
                unreachable!()
            };
            get_dense(body, &mut pos, dim, payload, x)?;
        }
        other => return Err(WireError::new(format!("unknown downlink kind {other}"))),
    }
    if pos != body.len() {
        return Err(WireError::new("trailing bytes in downlink frame"));
    }
    Ok(())
}

/// Exact on-the-wire size of a downlink frame (length prefix included).
pub fn downlink_frame_len(down: &Downlink, payload: Payload) -> usize {
    FRAME_PREFIX
        + 3 // tag + payload id + kind
        + match down {
            Downlink::Dense { x, w } => {
                dense_len(x.len(), payload) + w.as_ref().map_or(0, |w| dense_len(w.len(), payload))
            }
            Downlink::Sparse { delta } => sparse_len(delta, payload),
            Downlink::Init { x } => dense_len(x.len(), payload),
        }
}

// ---- fault-tolerance frames -------------------------------------------

fn get_flag(buf: &[u8], pos: &mut usize, what: &str) -> Result<bool> {
    match take1(buf, pos)? {
        0 => Ok(false),
        1 => Ok(true),
        other => Err(WireError::new(format!("bad {what} flag {other}"))),
    }
}

/// Serialize a replay announcement: the next `count` frames are journaled
/// downlink bodies (replay silently, answer only the last). With
/// `restore`, a [`TAG_RESTORE`] frame carrying snapshot blobs precedes
/// them — the journal was truncated at the snapshot round, so the replay
/// covers only the rounds after it.
pub fn put_replay(out: &mut Vec<u8>, count: usize, restore: bool) {
    out.push(TAG_REPLAY);
    put_varint(out, count as u64);
    out.push(restore as u8);
}

/// Decode a replay announcement → (journaled-frame count, restore flag).
pub fn get_replay(body: &[u8]) -> Result<(usize, bool)> {
    let mut pos = 0usize;
    if take1(body, &mut pos)? != TAG_REPLAY {
        return Err(WireError::new("expected replay frame"));
    }
    let count = get_varint(body, &mut pos)? as usize;
    let restore = get_flag(body, &mut pos, "replay restore")?;
    if pos != body.len() {
        return Err(WireError::new("trailing bytes in replay frame"));
    }
    Ok((count, restore))
}

/// Serialize a shard-adoption order: `shards` move to this worker, and
/// `replay_count` journaled downlink frames follow (for those shards
/// only; the last one is live). `restore` as in [`put_replay`].
pub fn put_adopt(out: &mut Vec<u8>, shards: &[usize], replay_count: usize, restore: bool) {
    out.push(TAG_ADOPT);
    put_varint(out, shards.len() as u64);
    for &s in shards {
        put_varint(out, s as u64);
    }
    put_varint(out, replay_count as u64);
    out.push(restore as u8);
}

/// Decode a shard-adoption order → (adopted shard indices, replay count,
/// restore flag).
pub fn get_adopt(body: &[u8]) -> Result<(Vec<usize>, usize, bool)> {
    let mut pos = 0usize;
    if take1(body, &mut pos)? != TAG_ADOPT {
        return Err(WireError::new("expected adopt frame"));
    }
    let k = get_varint(body, &mut pos)? as usize;
    // each index costs ≥ 1 byte, so k is bounded by the remaining bytes
    if k > body.len() - pos {
        return Err(WireError::new("adopt shard count exceeds frame"));
    }
    let mut shards = Vec::with_capacity(k);
    for _ in 0..k {
        shards.push(get_varint(body, &mut pos)? as usize);
    }
    let count = get_varint(body, &mut pos)? as usize;
    let restore = get_flag(body, &mut pos, "adopt restore")?;
    if pos != body.len() {
        return Err(WireError::new("trailing bytes in adopt frame"));
    }
    Ok((shards, count, restore))
}

// ---- checkpoint-snapshot frames ---------------------------------------

/// Serialize a snapshot request: every hosted shard's state as of the end
/// of `round`.
pub fn put_snap_req(out: &mut Vec<u8>, round: usize) {
    out.push(TAG_SNAP_REQ);
    put_varint(out, round as u64);
}

/// Decode a snapshot request → round.
pub fn get_snap_req(body: &[u8]) -> Result<usize> {
    let mut pos = 0usize;
    if take1(body, &mut pos)? != TAG_SNAP_REQ {
        return Err(WireError::new("expected snapshot-request frame"));
    }
    let round = get_varint(body, &mut pos)? as usize;
    if pos != body.len() {
        return Err(WireError::new("trailing bytes in snapshot-request frame"));
    }
    Ok(round)
}

/// Serialize one shard's snapshot blob for `round`.
pub fn put_snap_state(out: &mut Vec<u8>, shard: usize, round: usize, blob: &[u8]) {
    out.push(TAG_SNAP_STATE);
    put_varint(out, shard as u64);
    put_varint(out, round as u64);
    put_varint(out, blob.len() as u64);
    out.extend_from_slice(blob);
}

/// Decode a snapshot-state frame → (shard, round, blob).
pub fn get_snap_state(body: &[u8]) -> Result<(usize, usize, &[u8])> {
    let mut pos = 0usize;
    if take1(body, &mut pos)? != TAG_SNAP_STATE {
        return Err(WireError::new("expected snapshot-state frame"));
    }
    let shard = get_varint(body, &mut pos)? as usize;
    let round = get_varint(body, &mut pos)? as usize;
    let len = get_varint(body, &mut pos)? as usize;
    let blob = take(body, &mut pos, len)?;
    if pos != body.len() {
        return Err(WireError::new("trailing bytes in snapshot-state frame"));
    }
    Ok((shard, round, blob))
}

/// Serialize a restore order: load each `(shard, blob)` pair — state as
/// of the end of `round` — before replaying the post-snapshot journal.
pub fn put_restore(out: &mut Vec<u8>, round: usize, blobs: &[(usize, &[u8])]) {
    out.push(TAG_RESTORE);
    put_varint(out, round as u64);
    put_varint(out, blobs.len() as u64);
    for (shard, blob) in blobs {
        put_varint(out, *shard as u64);
        put_varint(out, blob.len() as u64);
        out.extend_from_slice(blob);
    }
}

/// Decode a restore order → (snapshot round, per-shard blobs).
pub fn get_restore(body: &[u8]) -> Result<(usize, Vec<(usize, Vec<u8>)>)> {
    let mut pos = 0usize;
    if take1(body, &mut pos)? != TAG_RESTORE {
        return Err(WireError::new("expected restore frame"));
    }
    let round = get_varint(body, &mut pos)? as usize;
    let k = get_varint(body, &mut pos)? as usize;
    // every entry costs ≥ 2 bytes (shard varint + length varint)
    if k > (body.len() - pos) / 2 {
        return Err(WireError::new("restore shard count exceeds frame"));
    }
    let mut blobs = Vec::with_capacity(k);
    for _ in 0..k {
        let shard = get_varint(body, &mut pos)? as usize;
        let len = get_varint(body, &mut pos)? as usize;
        blobs.push((shard, take(body, &mut pos, len)?.to_vec()));
    }
    if pos != body.len() {
        return Err(WireError::new("trailing bytes in restore frame"));
    }
    Ok((round, blobs))
}

// ---- handshake ---------------------------------------------------------

/// Everything a worker process needs to rebuild its shard-local state
/// bitwise identically to the server's reference build.
#[derive(Clone, Debug)]
pub struct Hello {
    pub dataset: String,
    pub data_dir: Option<String>,
    pub seed: u64,
    /// total shard count n (the dataset partition)
    pub workers: usize,
    pub mu: f64,
    pub tau: f64,
    pub sampling: SamplingKind,
    pub method: String,
    pub practical_adiana: bool,
    /// uplink compressor family (trajectory-defining, like `method`)
    pub compressor: CompressorKind,
    /// quantization levels s for `sa-quant`
    pub sa_levels: u32,
    /// diag-vs-root weighting for `sa-quant`
    pub sa_weighting: QuantWeighting,
    pub payload: Payload,
    pub need_global: bool,
    /// shard indices this process hosts (ascending)
    pub shards: Vec<usize>,
    /// starting point, shipped as raw f64 bits so it is exact
    pub x0: Vec<f64>,
}

/// Serialize a [`Hello`] frame body: tag, u32 JSON length, JSON header,
/// u32 dim, then `x0` as raw little-endian f64 bits (exactness matters:
/// the spec the worker rebuilds must match the server's bit-for-bit).
pub fn put_hello(out: &mut Vec<u8>, h: &Hello) {
    out.push(TAG_HELLO);
    let mut fields = vec![
        ("dataset", Json::Str(h.dataset.clone())),
        // u64 doesn't survive a f64 JSON number above 2^53; ship as text
        ("seed", Json::Str(h.seed.to_string())),
        ("workers", Json::Num(h.workers as f64)),
        ("mu", Json::Num(h.mu)),
        ("tau", Json::Num(h.tau)),
        ("sampling", Json::Str(h.sampling.name().to_string())),
        ("method", Json::Str(h.method.clone())),
        ("practical_adiana", Json::Bool(h.practical_adiana)),
        ("compressor", Json::Str(h.compressor.name().to_string())),
        ("sa_levels", Json::Num(h.sa_levels as f64)),
        ("sa_weighting", Json::Str(h.sa_weighting.name().to_string())),
        ("payload", Json::Str(h.payload.name().to_string())),
        ("need_global", Json::Bool(h.need_global)),
        (
            "shards",
            Json::Arr(h.shards.iter().map(|&s| Json::Num(s as f64)).collect()),
        ),
    ];
    if let Some(d) = &h.data_dir {
        fields.push(("data_dir", Json::Str(d.clone())));
    }
    let json = Json::obj(fields).to_string();
    out.extend_from_slice(&(json.len() as u32).to_le_bytes());
    out.extend_from_slice(json.as_bytes());
    out.extend_from_slice(&(h.x0.len() as u32).to_le_bytes());
    for &v in &h.x0 {
        out.extend_from_slice(&v.to_bits().to_le_bytes());
    }
}

pub fn get_hello(body: &[u8]) -> Result<Hello> {
    let mut pos = 0usize;
    if take1(body, &mut pos)? != TAG_HELLO {
        return Err(WireError::new("expected hello frame"));
    }
    let json_len = u32::from_le_bytes(take(body, &mut pos, 4)?.try_into().unwrap()) as usize;
    let json_bytes = take(body, &mut pos, json_len)?;
    let json_text = std::str::from_utf8(json_bytes)
        .map_err(|_| WireError::new("hello header is not UTF-8"))?;
    let j = Json::parse(json_text).map_err(|e| WireError::new(format!("hello header: {e}")))?;
    let str_field = |k: &str| -> Result<String> {
        j.get(k)
            .as_str()
            .map(|s| s.to_string())
            .ok_or_else(|| WireError::new(format!("hello: missing '{k}'")))
    };
    let num_field = |k: &str| -> Result<f64> {
        j.get(k)
            .as_f64()
            .ok_or_else(|| WireError::new(format!("hello: missing '{k}'")))
    };
    let sampling_name = str_field("sampling")?;
    let payload_name = str_field("payload")?;
    // compressor fields are absent in pre-compressor hellos: default them
    // ("default"/4/"diag") so old peers keep working, but reject garbage
    let compressor = match j.get("compressor").as_str() {
        None => CompressorKind::Default,
        Some(s) => CompressorKind::parse(s)
            .ok_or_else(|| WireError::new(format!("hello: bad compressor '{s}'")))?,
    };
    let sa_levels = match j.get("sa_levels").as_f64() {
        None => 4,
        Some(v) if v >= 0.0 && v <= u32::MAX as f64 && v.fract() == 0.0 => v as u32,
        Some(v) => return Err(WireError::new(format!("hello: bad sa_levels {v}"))),
    };
    let sa_weighting = match j.get("sa_weighting").as_str() {
        None => QuantWeighting::Diag,
        Some(s) => QuantWeighting::parse(s)
            .ok_or_else(|| WireError::new(format!("hello: bad sa_weighting '{s}'")))?,
    };
    let shards = j
        .get("shards")
        .as_arr()
        .ok_or_else(|| WireError::new("hello: missing 'shards'"))?
        .iter()
        .map(|v| v.as_usize())
        .collect::<Option<Vec<usize>>>()
        .ok_or_else(|| WireError::new("hello: bad shard index"))?;

    let dim = u32::from_le_bytes(take(body, &mut pos, 4)?.try_into().unwrap()) as usize;
    let x0_bytes = take(body, &mut pos, dim.checked_mul(8).ok_or_else(|| {
        WireError::new("hello: x0 length overflow")
    })?)?;
    let x0: Vec<f64> = x0_bytes
        .chunks_exact(8)
        .map(|c| f64::from_bits(u64::from_le_bytes(c.try_into().unwrap())))
        .collect();
    if pos != body.len() {
        return Err(WireError::new("trailing bytes in hello frame"));
    }

    Ok(Hello {
        dataset: str_field("dataset")?,
        data_dir: j.get("data_dir").as_str().map(|s| s.to_string()),
        seed: str_field("seed")?
            .parse::<u64>()
            .map_err(|_| WireError::new("hello: bad seed"))?,
        workers: num_field("workers")? as usize,
        mu: num_field("mu")?,
        tau: num_field("tau")?,
        sampling: SamplingKind::parse(&sampling_name)
            .ok_or_else(|| WireError::new(format!("hello: bad sampling '{sampling_name}'")))?,
        method: str_field("method")?,
        practical_adiana: j.get("practical_adiana").as_bool().unwrap_or(true),
        compressor,
        sa_levels,
        sa_weighting,
        payload: Payload::parse(&payload_name)
            .ok_or_else(|| WireError::new(format!("hello: bad payload '{payload_name}'")))?,
        need_global: j.get("need_global").as_bool().unwrap_or(false),
        shards,
        x0,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn msg(pairs: &[(u32, f64)]) -> SparseMsg {
        let mut m = SparseMsg::new();
        for &(i, v) in pairs {
            m.push(i, v);
        }
        m
    }

    #[test]
    fn varint_roundtrip_and_len() {
        let mut buf = Vec::new();
        for v in [0u64, 1, 127, 128, 300, 16383, 16384, u32::MAX as u64, u64::MAX] {
            buf.clear();
            put_varint(&mut buf, v);
            assert_eq!(buf.len(), varint_len(v), "len mismatch for {v}");
            let mut pos = 0;
            assert_eq!(get_varint(&buf, &mut pos).unwrap(), v);
            assert_eq!(pos, buf.len());
        }
        // a 10th byte may only carry bit 0 (u64 has 64 bits = 9·7 + 1);
        // non-canonical high bits must be rejected, not silently dropped
        let mut bad = vec![0x80u8; 9];
        bad.push(0x7f);
        let mut pos = 0;
        assert!(get_varint(&bad, &mut pos).is_err());
        // an 11th byte overflows outright
        let mut worse = vec![0x80u8; 10];
        worse.push(0x01);
        pos = 0;
        assert!(get_varint(&worse, &mut pos).is_err());
    }

    #[test]
    fn uplink_f64_roundtrip_exact() {
        let up = Uplink {
            delta: msg(&[(0, 1.5), (3, -2.25e-300), (17, f64::INFINITY), (99, -0.0)]),
            delta2: Some(msg(&[(5, 1e300)])),
        };
        let mut body = Vec::new();
        put_uplink(&mut body, &up, 42, Payload::F64).unwrap();
        assert_eq!(
            body.len() + FRAME_PREFIX,
            uplink_frame_len(&up, 42, Payload::F64)
        );
        let mut dec = Uplink::default();
        let shard = get_uplink(&body, 100, &mut dec).unwrap();
        assert_eq!(shard, 42);
        assert_eq!(dec.delta, up.delta);
        assert_eq!(dec.delta2, up.delta2);
    }

    fn uplink_body(shard: usize, payload: Payload, pairs: &[(u32, f64)]) -> Vec<u8> {
        let up = Uplink {
            delta: msg(pairs),
            delta2: None,
        };
        let mut body = Vec::new();
        put_uplink(&mut body, &up, shard, payload).unwrap();
        body
    }

    #[test]
    fn merge_uplinks_carries_constituents_verbatim() {
        let a = uplink_body(2, Payload::F64, &[(0, 1.5), (7, -0.0)]);
        let b = uplink_body(5, Payload::F64, &[(3, 1e300)]);
        let c = uplink_body(70, Payload::F64, &[]);
        let mut agg = Vec::new();
        // input order must not matter: the aggregate canonicalizes
        merge_uplinks(&mut agg, &[&c, &a, &b]).unwrap();
        let mut parts = Vec::new();
        assert_eq!(get_agg_uplink(&agg, &mut parts).unwrap(), Payload::F64);
        assert_eq!(parts.len(), 3);
        let shards: Vec<usize> = parts.iter().map(|p| p.0).collect();
        assert_eq!(shards, vec![2, 5, 70]);
        // byte-for-byte identity of each constituent is the whole point
        assert_eq!(&agg[parts[0].1..parts[0].2], &a[..]);
        assert_eq!(&agg[parts[1].1..parts[1].2], &b[..]);
        assert_eq!(&agg[parts[2].1..parts[2].2], &c[..]);
        // ...and each slice decodes exactly as the original frame would
        let mut dec = Uplink::default();
        assert_eq!(get_uplink(&agg[parts[0].1..parts[0].2], 100, &mut dec).unwrap(), 2);
        assert_eq!(dec.delta, msg(&[(0, 1.5), (7, -0.0)]));
    }

    #[test]
    fn merge_uplinks_flattens_nested_aggregates() {
        let a = uplink_body(0, Payload::F64, &[(1, 1.0)]);
        let b = uplink_body(3, Payload::F64, &[(2, 2.0)]);
        let c = uplink_body(1, Payload::F64, &[(4, 4.0)]);
        let mut inner = Vec::new();
        merge_uplinks(&mut inner, &[&a, &b]).unwrap();
        let mut outer = Vec::new();
        merge_uplinks(&mut outer, &[&inner, &c]).unwrap();
        let mut parts = Vec::new();
        get_agg_uplink(&outer, &mut parts).unwrap();
        let shards: Vec<usize> = parts.iter().map(|p| p.0).collect();
        assert_eq!(shards, vec![0, 1, 3]);
        // flattening is canonical: a 3-level tree emits the same bytes as
        // a 2-level tree over the same constituents
        let mut flat = Vec::new();
        merge_uplinks(&mut flat, &[&a, &c, &b]).unwrap();
        assert_eq!(outer, flat);
    }

    #[test]
    fn merge_uplinks_rejects_incompatible_siblings() {
        let a = uplink_body(0, Payload::F64, &[(1, 1.0)]);
        let b32 = uplink_body(1, Payload::F32, &[(2, 2.0)]);
        let dup = uplink_body(0, Payload::F64, &[(3, 3.0)]);
        let mut out = Vec::new();
        let err = merge_uplinks(&mut out, &[&a, &b32]).unwrap_err();
        assert!(err.to_string().contains("payload"), "got: {err}");
        assert!(merge_uplinks(&mut out, &[&a, &dup]).is_err(), "duplicate shard");
        assert!(merge_uplinks(&mut out, &[]).is_err(), "empty merge");
        assert!(
            merge_uplinks(&mut out, &[&[TAG_HEARTBEAT][..]]).is_err(),
            "non-uplink tag"
        );
    }

    #[test]
    fn agg_uplink_rejects_tampered_envelopes() {
        let a = uplink_body(1, Payload::F64, &[(0, 1.0)]);
        let b = uplink_body(9, Payload::F64, &[(5, -2.0)]);
        let mut agg = Vec::new();
        merge_uplinks(&mut agg, &[&a, &b]).unwrap();
        let mut parts = Vec::new();
        // truncation anywhere must error, never panic
        for cut in 0..agg.len() {
            assert!(get_agg_uplink(&agg[..cut], &mut parts).is_err(), "cut {cut}");
        }
        // trailing garbage
        let mut long = agg.clone();
        long.push(0);
        assert!(get_agg_uplink(&long, &mut parts).is_err());
        // clearing a bitmap bit breaks the popcount/count agreement
        let mut bad = agg.clone();
        bad[3] &= !(1u8 << 1);
        assert!(get_agg_uplink(&bad, &mut parts).is_err());
        get_agg_uplink(&agg, &mut parts).unwrap();
    }

    #[test]
    fn unsorted_indices_preserve_order() {
        let up = Uplink {
            delta: msg(&[(9, 1.0), (2, 2.0), (2, 3.0), (7, 4.0)]),
            delta2: None,
        };
        let mut body = Vec::new();
        put_uplink(&mut body, &up, 0, Payload::F64).unwrap();
        let mut dec = Uplink::default();
        get_uplink(&body, 10, &mut dec).unwrap();
        assert_eq!(dec.delta, up.delta);
    }

    #[test]
    fn empty_message_roundtrip() {
        for p in Payload::ALL {
            let up = Uplink::default();
            let mut body = Vec::new();
            put_uplink(&mut body, &up, 3, p).unwrap();
            assert_eq!(body.len() + FRAME_PREFIX, uplink_frame_len(&up, 3, p));
            let mut dec = Uplink {
                delta: msg(&[(1, 1.0)]),
                delta2: Some(msg(&[(0, 2.0)])),
            };
            get_uplink(&body, 4, &mut dec).unwrap();
            assert!(dec.delta.is_empty());
            assert!(dec.delta2.is_none());
        }
    }

    #[test]
    fn quantized_error_bound() {
        let vals = [0.3, -1.7, 0.0001, 2.0, -2.0, 0.9999];
        let scale = 2.0;
        for p in [Payload::Q16, Payload::Q8, Payload::Q4] {
            let pairs: Vec<(u32, f64)> =
                vals.iter().enumerate().map(|(i, &v)| (i as u32, v)).collect();
            let up = Uplink {
                delta: msg(&pairs),
                delta2: None,
            };
            let mut body = Vec::new();
            put_uplink(&mut body, &up, 0, p).unwrap();
            let mut dec = Uplink::default();
            get_uplink(&body, 10, &mut dec).unwrap();
            let bound = p.max_abs_err(scale) * (1.0 + 1e-12);
            for (orig, got) in vals.iter().zip(&dec.delta.val) {
                assert!(
                    (orig - got).abs() <= bound,
                    "{}: |{orig} - {got}| > {bound}",
                    p.name()
                );
            }
            // extremes hit the grid exactly
            assert_eq!(dec.delta.val[3], 2.0);
            assert_eq!(dec.delta.val[4], -2.0);
        }
    }

    #[test]
    fn downlink_kinds_roundtrip() {
        let dim = 5;
        let cases = [
            Downlink::Dense {
                x: vec![1.0, -2.0, 3.5e-310, 0.0, 9.0],
                w: None,
            },
            Downlink::Dense {
                x: vec![0.0; 5],
                w: Some(vec![5.0, 4.0, 3.0, 2.0, 1.0]),
            },
            Downlink::Sparse {
                delta: msg(&[(1, 0.5), (4, -0.25)]),
            },
            Downlink::Init {
                x: vec![1.0, 2.0, 3.0, 4.0, 5.0],
            },
        ];
        for orig in &cases {
            let mut body = Vec::new();
            put_downlink(&mut body, orig, Payload::F64).unwrap();
            assert_eq!(
                body.len() + FRAME_PREFIX,
                downlink_frame_len(orig, Payload::F64)
            );
            let mut dec = Downlink::Init { x: Vec::new() };
            get_downlink(&body, dim, &mut dec).unwrap();
            match (orig, &dec) {
                (Downlink::Dense { x: a, w: u }, Downlink::Dense { x: b, w: v }) => {
                    assert_eq!(a, b);
                    assert_eq!(u, v);
                }
                (Downlink::Sparse { delta: a }, Downlink::Sparse { delta: b }) => {
                    assert_eq!(a, b)
                }
                (Downlink::Init { x: a }, Downlink::Init { x: b }) => assert_eq!(a, b),
                _ => panic!("variant changed in roundtrip"),
            }
        }
    }

    #[test]
    fn malformed_frames_error_not_panic() {
        let mut body = Vec::new();
        put_uplink(
            &mut body,
            &Uplink {
                delta: msg(&[(0, 1.0), (5, 2.0)]),
                delta2: None,
            },
            1,
            Payload::F64,
        )
        .unwrap();
        // truncations at every prefix length
        for cut in 0..body.len() {
            let mut dec = Uplink::default();
            assert!(get_uplink(&body[..cut], 10, &mut dec).is_err(), "cut={cut}");
        }
        // out-of-range index vs dim
        let mut dec = Uplink::default();
        assert!(get_uplink(&body, 3, &mut dec).is_err());
        // bad tag
        let mut bad = body.clone();
        bad[0] = 99;
        assert!(get_uplink(&bad, 10, &mut dec).is_err());
    }

    #[test]
    fn hello_roundtrip() {
        let h = Hello {
            dataset: "a1a".into(),
            data_dir: Some("/tmp/data".into()),
            seed: u64::MAX - 3,
            workers: 107,
            mu: 1e-3,
            tau: 2.5,
            sampling: SamplingKind::ImportanceDiana,
            method: "diana+".into(),
            practical_adiana: false,
            compressor: CompressorKind::SaQuant,
            sa_levels: 8,
            sa_weighting: QuantWeighting::Root,
            payload: Payload::Q8,
            need_global: true,
            shards: vec![1, 54, 107 - 1],
            x0: vec![0.1, -2.3e-15, 7.0],
        };
        let mut body = Vec::new();
        put_hello(&mut body, &h);
        let d = get_hello(&body).unwrap();
        assert_eq!(d.dataset, h.dataset);
        assert_eq!(d.data_dir, h.data_dir);
        assert_eq!(d.seed, h.seed);
        assert_eq!(d.workers, h.workers);
        assert_eq!(d.mu.to_bits(), h.mu.to_bits());
        assert_eq!(d.tau.to_bits(), h.tau.to_bits());
        assert_eq!(d.sampling, h.sampling);
        assert_eq!(d.method, h.method);
        assert_eq!(d.practical_adiana, h.practical_adiana);
        assert_eq!(d.compressor, h.compressor);
        assert_eq!(d.sa_levels, h.sa_levels);
        assert_eq!(d.sa_weighting, h.sa_weighting);
        assert_eq!(d.payload, h.payload);
        assert_eq!(d.need_global, h.need_global);
        assert_eq!(d.shards, h.shards);
        assert_eq!(
            d.x0.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            h.x0.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn replay_and_adopt_roundtrip_and_reject_malformed() {
        let mut body = Vec::new();
        put_replay(&mut body, 12345, true);
        assert_eq!(get_replay(&body).unwrap(), (12345, true));
        for cut in 0..body.len() {
            assert!(get_replay(&body[..cut]).is_err(), "cut={cut}");
        }
        let mut extra = body.clone();
        extra.push(0);
        assert!(get_replay(&extra).is_err());
        // non-boolean restore flag is rejected
        let mut bad = body.clone();
        *bad.last_mut().unwrap() = 2;
        assert!(get_replay(&bad).is_err());

        let mut body = Vec::new();
        put_adopt(&mut body, &[3, 0, 1000], 77, false);
        let (shards, count, restore) = get_adopt(&body).unwrap();
        assert_eq!(shards, vec![3, 0, 1000]);
        assert_eq!(count, 77);
        assert!(!restore);
        for cut in 0..body.len() {
            assert!(get_adopt(&body[..cut]).is_err(), "cut={cut}");
        }
        // empty adoption is representable (degenerate but well-formed)
        body.clear();
        put_adopt(&mut body, &[], 0, true);
        assert_eq!(get_adopt(&body).unwrap(), (Vec::new(), 0, true));
        // wrong tags cross-reject
        assert!(get_replay(&body).is_err());
        assert!(get_adopt(&[TAG_REPLAY, 1, 0]).is_err());
    }

    #[test]
    fn snapshot_frames_roundtrip_and_reject_malformed() {
        let mut body = Vec::new();
        put_snap_req(&mut body, 4096);
        assert_eq!(get_snap_req(&body).unwrap(), 4096);
        for cut in 0..body.len() {
            assert!(get_snap_req(&body[..cut]).is_err(), "cut={cut}");
        }

        let blob: Vec<u8> = (0..200u8).collect();
        body.clear();
        put_snap_state(&mut body, 5, 4096, &blob);
        let (shard, round, got) = get_snap_state(&body).unwrap();
        assert_eq!((shard, round), (5, 4096));
        assert_eq!(got, &blob[..]);
        for cut in 0..body.len() {
            assert!(get_snap_state(&body[..cut]).is_err(), "cut={cut}");
        }

        let b0: &[u8] = &[1, 2, 3];
        let b1: &[u8] = &[];
        body.clear();
        put_restore(&mut body, 30, &[(0, b0), (7, b1)]);
        let (round, blobs) = get_restore(&body).unwrap();
        assert_eq!(round, 30);
        assert_eq!(blobs, vec![(0usize, b0.to_vec()), (7usize, Vec::new())]);
        for cut in 0..body.len() {
            assert!(get_restore(&body[..cut]).is_err(), "cut={cut}");
        }
        let mut extra = body.clone();
        extra.push(9);
        assert!(get_restore(&extra).is_err());
        // cross-tag rejection
        assert!(get_restore(&[TAG_SNAP_REQ, 1]).is_err());
        assert!(get_snap_req(&[TAG_RESTORE, 1, 0]).is_err());
    }

    #[test]
    fn hello_without_compressor_fields_defaults() {
        // a pre-compressor peer's hello header omits the three new keys;
        // decode must fall back to the historical behaviour, not error
        let json = concat!(
            r#"{"dataset":"tiny","seed":"7","workers":4,"mu":0.001,"tau":2,"#,
            r#""sampling":"uniform","method":"dcgd","practical_adiana":true,"#,
            r#""payload":"f64","need_global":false,"shards":[0]}"#
        );
        let mut body = vec![TAG_HELLO];
        body.extend_from_slice(&(json.len() as u32).to_le_bytes());
        body.extend_from_slice(json.as_bytes());
        body.extend_from_slice(&2u32.to_le_bytes());
        for v in [0.5f64, -1.0] {
            body.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        let h = get_hello(&body).unwrap();
        assert_eq!(h.compressor, CompressorKind::Default);
        assert_eq!(h.sa_levels, 4);
        assert_eq!(h.sa_weighting, QuantWeighting::Diag);
    }

    #[test]
    fn non_finite_values_reject_quantized_payloads() {
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let up = Uplink {
                delta: msg(&[(0, 1.0), (4, bad), (9, -2.0)]),
                delta2: None,
            };
            for p in [Payload::Q16, Payload::Q8, Payload::Q4] {
                let mut body = Vec::new();
                let err = put_uplink(&mut body, &up, 0, p).unwrap_err();
                assert!(
                    err.to_string().contains("non-finite"),
                    "{}: unexpected error {err}",
                    p.name()
                );
                let mut dbody = Vec::new();
                assert!(put_downlink(
                    &mut dbody,
                    &Downlink::Sparse {
                        delta: up.delta.clone()
                    },
                    p
                )
                .is_err());
            }
            // the float payloads stay transparent: f64 bit-exact (NaN
            // included), f32 via the `v as f32` cast
            for (p, expect) in [
                (Payload::F64, bad.to_bits()),
                (Payload::F32, f64::from(bad as f32).to_bits()),
            ] {
                let mut body = Vec::new();
                put_uplink(&mut body, &up, 0, p).unwrap();
                let mut dec = Uplink::default();
                get_uplink(&body, 10, &mut dec).unwrap();
                assert_eq!(dec.delta.val[1].to_bits(), expect, "{}", p.name());
            }
        }
    }

    #[test]
    fn payload_parse_names() {
        for p in Payload::ALL {
            assert_eq!(Payload::parse(p.name()), Some(p));
        }
        assert_eq!(Payload::parse("f16"), None);
        assert_eq!(Payload::F64.bits(), 64);
        assert_eq!(Payload::Q4.bits(), 4);
    }
}
