//! Scriptable fault injection for the elastic wire runtime
//! (`--fault-plan`).
//!
//! PR 4 hard-coded one failure mode (`--die-after N`: a worker exits
//! after round *N*). The chaos matrix in `tests/chaos_matrix.rs` needs
//! the full menagerie — server SIGKILL, dropped uplinks, corrupted
//! frames, slow workers — on a *deterministic schedule*, so faults are
//! described by a tiny plan grammar instead of a pile of one-off flags:
//!
//! ```text
//! plan    := event (';' event)*
//! event   := action '@r' ROUND suffix*
//! suffix  := ':w' SHARD | ':' MILLIS 'ms' | ':relay'
//! action  := 'kill' | 'drop-uplink' | 'delay' | 'pause' | 'kill-server'
//!          | 'corrupt-downlink'
//! ```
//!
//! For example `kill-server@r12;drop-uplink@r5:w1;corrupt-downlink@r9;delay@r7:50ms`
//! kills the server after round 12, makes the worker hosting shard 1
//! sever instead of sending its round-5 uplink, flips one seeded bit in
//! a round-9 downlink frame, and sleeps 50 ms before stepping round 7.
//!
//! Who executes what:
//!
//! * **Worker side** (`kill`, `drop-uplink`, `delay`, `pause`): passed
//!   via `WorkerOpts::fault`. A `:wK` suffix restricts the event to the
//!   worker hosting shard *K*; unqualified events apply to every
//!   worker (useful single-worker, chaotic multi-worker). `pause` is
//!   sticky: from its round on the worker never heartbeats again (it
//!   still answers the downlinks addressed to it).
//! * **Server side** (`kill-server`, `corrupt-downlink`): passed via
//!   the config's `wire.fault_plan`. `corrupt-downlink` flips one bit —
//!   chosen by a [`SplitMix64`] stream over `(seed, round)` so every
//!   rerun corrupts the same bit — in the CRC trailer'd frame sent to
//!   one connection (`:wK` picks the worker hosting shard *K*, default
//!   the first live connection), and therefore requires `wire.crc`.
//! * **Relay side** (`kill` with the `:relay` suffix): passed via
//!   `RelayOpts::fault`. The relay vanishes on receiving that round's
//!   downlink, before forwarding it — its whole subtree is orphaned at
//!   once, the chaos case `tests/chaos_matrix.rs` pins. Relay events
//!   never fire on workers and vice versa.
//!
//! The plan is *descriptive*, not imperative: parsing never touches the
//! network, and a plan whose rounds are never reached simply never
//! fires. Determinism is the point — the chaos tests assert that runs
//! under faults finish bitwise identical to undisturbed ones.

use crate::util::rng::SplitMix64;
use anyhow::{anyhow, bail, ensure, Result};
use std::time::Duration;

/// Error string the server surfaces when a `kill-server` event fires.
/// `main` matches on it to exit with status 137 (mimicking SIGKILL) so
/// scripts and tests can tell a planned death from a real failure.
pub const KILLED_MARKER: &str = "server killed by fault plan";

/// One scheduled fault.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultAction {
    /// worker: vanish on receiving this round's downlink, without
    /// replying (≡ old `--die-after`: the OS closing the socket is
    /// observably a SIGKILL at that instant)
    Kill,
    /// worker: compute the round but sever the connection instead of
    /// sending the uplink
    DropUplink,
    /// worker: sleep this long before stepping the round
    Delay(u64),
    /// worker: from this round on, stop sending heartbeats while staying
    /// connected and still answering cohort downlinks — models a client
    /// whose keepalive path wedges. Used with partial participation to
    /// prove a sampled-out idler is not declared dead inside the grace
    /// window (the server must only police shards it is gathering).
    Pause,
    /// server: abort the run loop after the round, skipping the clean
    /// shutdown (workers see EOF, as under SIGKILL)
    KillServer,
    /// server: flip one seeded bit in this round's downlink frame to
    /// one connection
    CorruptDownlink,
}

/// One parsed `action@rN[:wK][:MSms][:relay]` event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultEvent {
    pub round: u64,
    /// `:wK` — restrict to the worker/connection hosting this shard
    pub shard: Option<usize>,
    /// `:relay` — the event targets the relay tier, not a worker
    pub relay: bool,
    pub action: FaultAction,
}

/// A parsed, seeded fault schedule. Cheap to clone; carried by both the
/// server config and `WorkerOpts` (each side only acts on its own
/// events).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
    /// seeds the corrupted-bit choice so reruns are identical
    pub seed: u64,
}

impl FaultPlan {
    /// Parse a plan string. Empty/whitespace specs parse to an empty
    /// plan (no events ever fire).
    pub fn parse(spec: &str, seed: u64) -> Result<FaultPlan> {
        let mut events = Vec::new();
        for tok in spec.split(';').map(str::trim).filter(|t| !t.is_empty()) {
            events.push(parse_event(tok)?);
        }
        Ok(FaultPlan { events, seed })
    }

    /// Does any event target the server side?
    pub fn has_server_events(&self) -> bool {
        self.events.iter().any(|e| {
            matches!(e.action, FaultAction::KillServer | FaultAction::CorruptDownlink)
        })
    }

    /// server: should the run loop abort after `round`?
    pub fn kill_server_after(&self, round: u64) -> bool {
        self.events
            .iter()
            .any(|e| e.round == round && e.action == FaultAction::KillServer)
    }

    /// server: corrupt this round's downlink? Returns the target shard
    /// (None ⇒ first live connection) and the seeded bit index to flip
    /// (the transport reduces it modulo the frame length).
    pub fn corrupt_downlink_at(&self, round: u64) -> Option<(Option<usize>, u64)> {
        let e = self
            .events
            .iter()
            .find(|e| e.round == round && e.action == FaultAction::CorruptDownlink)?;
        Some((e.shard, seeded_bit(self.seed, round)))
    }

    /// worker: exit after completing `round`? (`shards` = the shard ids
    /// this worker hosts)
    pub fn kill_worker_after(&self, round: u64, shards: &[usize]) -> bool {
        self.worker_event(round, shards, |a| a == FaultAction::Kill)
            .is_some()
    }

    /// relay: vanish on receiving this round's downlink, before
    /// forwarding it (`kill@rN:relay`)?
    pub fn kill_relay_after(&self, round: u64) -> bool {
        self.events
            .iter()
            .any(|e| e.relay && e.round == round && e.action == FaultAction::Kill)
    }

    /// worker: sever instead of sending this round's uplink?
    pub fn drop_uplink_at(&self, round: u64, shards: &[usize]) -> bool {
        self.worker_event(round, shards, |a| a == FaultAction::DropUplink)
            .is_some()
    }

    /// worker: latch heartbeat silence starting at this round?
    pub fn pause_at(&self, round: u64, shards: &[usize]) -> bool {
        self.worker_event(round, shards, |a| a == FaultAction::Pause)
            .is_some()
    }

    /// worker: sleep before stepping this round?
    pub fn delay_at(&self, round: u64, shards: &[usize]) -> Option<Duration> {
        self.worker_event(round, shards, |a| matches!(a, FaultAction::Delay(_)))
            .and_then(|e| match e.action {
                FaultAction::Delay(ms) => Some(Duration::from_millis(ms)),
                _ => None,
            })
    }

    fn worker_event(
        &self,
        round: u64,
        shards: &[usize],
        pred: impl Fn(FaultAction) -> bool,
    ) -> Option<&FaultEvent> {
        self.events.iter().find(|e| {
            !e.relay
                && e.round == round
                && pred(e.action)
                && e.shard.map_or(true, |s| shards.contains(&s))
        })
    }
}

/// Deterministic bit choice for `corrupt-downlink`: a SplitMix64 draw
/// over the plan seed mixed with the round (golden-ratio stride keeps
/// nearby rounds uncorrelated).
fn seeded_bit(seed: u64, round: u64) -> u64 {
    SplitMix64::new(seed ^ round.wrapping_mul(0x9E37_79B9_7F4A_7C15)).next_u64()
}

fn parse_event(tok: &str) -> Result<FaultEvent> {
    let (action_s, rest) = tok
        .split_once("@r")
        .ok_or_else(|| anyhow!("fault event `{tok}`: expected `action@rROUND`"))?;
    let mut parts = rest.split(':');
    let round: u64 = parts
        .next()
        .unwrap_or("")
        .parse()
        .map_err(|_| anyhow!("fault event `{tok}`: bad round number"))?;
    let mut shard = None;
    let mut ms = None;
    let mut relay = false;
    for p in parts {
        if p == "relay" {
            ensure!(!relay, "fault event `{tok}`: duplicate :relay suffix");
            relay = true;
        } else if let Some(w) = p.strip_prefix('w') {
            ensure!(shard.is_none(), "fault event `{tok}`: duplicate :w suffix");
            shard = Some(
                w.parse::<usize>()
                    .map_err(|_| anyhow!("fault event `{tok}`: bad shard in `:{p}`"))?,
            );
        } else if let Some(m) = p.strip_suffix("ms") {
            ensure!(ms.is_none(), "fault event `{tok}`: duplicate delay suffix");
            ms = Some(
                m.parse::<u64>()
                    .map_err(|_| anyhow!("fault event `{tok}`: bad delay in `:{p}`"))?,
            );
        } else {
            bail!(
                "fault event `{tok}`: unknown suffix `:{p}` (want `:wK`, `:MSms` \
                 or `:relay`)"
            );
        }
    }
    let action = match action_s {
        "kill" => FaultAction::Kill,
        "drop-uplink" => FaultAction::DropUplink,
        "delay" => FaultAction::Delay(
            ms.take()
                .ok_or_else(|| anyhow!("fault event `{tok}`: delay needs a `:MSms` suffix"))?,
        ),
        "kill-server" => {
            ensure!(
                shard.is_none(),
                "fault event `{tok}`: kill-server takes no `:wK` suffix"
            );
            FaultAction::KillServer
        }
        "pause" => FaultAction::Pause,
        "corrupt-downlink" => FaultAction::CorruptDownlink,
        other => bail!(
            "fault event `{tok}`: unknown action `{other}` (want kill, drop-uplink, \
             delay, pause, kill-server or corrupt-downlink)"
        ),
    };
    ensure!(
        ms.is_none() || matches!(action, FaultAction::Delay(_)),
        "fault event `{tok}`: only delay takes a `:MSms` suffix"
    );
    ensure!(
        !relay || action == FaultAction::Kill,
        "fault event `{tok}`: only kill takes a `:relay` suffix"
    );
    ensure!(
        !(relay && shard.is_some()),
        "fault event `{tok}`: `:relay` and `:wK` are mutually exclusive"
    );
    Ok(FaultEvent { round, shard, relay, action })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_full_grammar() {
        let p = FaultPlan::parse(
            "kill-server@r12; drop-uplink@r5:w1 ;corrupt-downlink@r9;delay@r7:50ms;\
             kill@r3:w2;kill@r6:relay;pause@r4:w0",
            99,
        )
        .unwrap();
        assert_eq!(p.events.len(), 7);
        assert_eq!(
            p.events[0],
            FaultEvent { round: 12, shard: None, relay: false, action: FaultAction::KillServer }
        );
        assert_eq!(
            p.events[1],
            FaultEvent { round: 5, shard: Some(1), relay: false, action: FaultAction::DropUplink }
        );
        assert_eq!(
            p.events[3],
            FaultEvent { round: 7, shard: None, relay: false, action: FaultAction::Delay(50) }
        );
        assert_eq!(
            p.events[5],
            FaultEvent { round: 6, shard: None, relay: true, action: FaultAction::Kill }
        );
        assert!(p.has_server_events());
        assert!(p.kill_server_after(12) && !p.kill_server_after(11));
        assert!(p.kill_worker_after(3, &[2, 5]));
        assert!(!p.kill_worker_after(3, &[0, 1]), ":w2 must not fire elsewhere");
        assert!(p.kill_relay_after(6) && !p.kill_relay_after(5));
        assert!(
            !p.kill_worker_after(6, &[0, 1, 2]),
            ":relay events must never fire on workers"
        );
        assert!(p.drop_uplink_at(5, &[1]) && !p.drop_uplink_at(5, &[0]));
        assert_eq!(p.delay_at(7, &[0]), Some(Duration::from_millis(50)));
        assert_eq!(p.delay_at(8, &[0]), None);
        assert!(p.pause_at(4, &[0, 3]));
        assert!(!p.pause_at(4, &[1]), ":w0 must not pause other workers");
        assert!(!p.pause_at(5, &[0]), "pause fires at its own round only");

        let empty = FaultPlan::parse("  ", 0).unwrap();
        assert!(empty.events.is_empty() && !empty.has_server_events());
    }

    #[test]
    fn corrupt_bit_is_seeded_and_stable() {
        let p = FaultPlan::parse("corrupt-downlink@r9:w1", 42).unwrap();
        let (target, bit) = p.corrupt_downlink_at(9).unwrap();
        assert_eq!(target, Some(1));
        // same seed + round → same bit on every rerun
        let p2 = FaultPlan::parse("corrupt-downlink@r9:w1", 42).unwrap();
        assert_eq!(p2.corrupt_downlink_at(9), Some((target, bit)));
        // different seed or round → (almost surely) a different bit
        let p3 = FaultPlan::parse("corrupt-downlink@r9;corrupt-downlink@r10", 43).unwrap();
        assert_ne!(p3.corrupt_downlink_at(9), Some((None, bit)));
        assert!(p.corrupt_downlink_at(8).is_none());
    }

    #[test]
    fn rejects_malformed_events() {
        for bad in [
            "kill",                    // no @r
            "kill@rX",                 // bad round
            "explode@r3",              // unknown action
            "delay@r3",                // delay without ms
            "kill@r3:50ms",            // ms on a non-delay action
            "kill-server@r3:w1",       // kill-server is not per-shard
            "kill@r3:q9",              // unknown suffix
            "kill@r3:w1:w2",           // duplicate suffix
            "delay@r3:10ms:20ms",      // duplicate delay
            "kill@r3:relay:relay",     // duplicate relay
            "kill@r3:w1:relay",        // relay is not per-shard
            "delay@r3:50ms:relay",     // only kill targets the relay
            "pause@r3:50ms",           // ms on a non-delay action
            "pause@r3:relay",          // only kill targets the relay
        ] {
            assert!(FaultPlan::parse(bad, 0).is_err(), "`{bad}` must not parse");
        }
    }
}
