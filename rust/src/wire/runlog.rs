//! Durable on-disk run log for the elastic server (`--run-dir`).
//!
//! PR 4/5 made *workers* expendable: the server's in-memory downlink
//! journal + committed state snapshots replay any worker into a
//! bitwise-identical trajectory. This module is the server-side
//! analogue — it persists exactly those artifacts so a coordinator that
//! is SIGKILLed mid-run can restart and **resume bit-for-bit**:
//!
//! * **`base.bin`** — atomically rotated (tmp + rename + fsync, the
//!   idiom of `coordinator::session::write_checkpoint`) at every
//!   committed snapshot. Holds the run header (config hash + seed), the
//!   full config JSON (so `smx runs show`/`resume` can reconstruct the
//!   run without the original command line), the committed [`Snapshot`]
//!   (server method state via
//!   [`ServerAlgo::save_state`](crate::methods::ServerAlgo::save_state),
//!   server RNG, cumulative [`RoundTotals`], and the per-shard worker
//!   blobs the rejoin path restores over `TAG_RESTORE`), and every
//!   [`RoundRecord`] emitted up to the snapshot round. When a run ends
//!   cleanly, [`RunLog::finish`] rotates one final time with *every*
//!   record (snapshot-gated or not) plus a `finished` marker, turning
//!   the run dir into a complete, diffable artifact for `smx runs`.
//! * **`journal.bin`** — append-only journal *suffix*: the encoded
//!   downlink bodies broadcast after the last committed snapshot, in
//!   round order. Truncated at each rotation, appended per round
//!   without fsync (a lost tail is harmless — those rounds re-run
//!   deterministically from the snapshot).
//!
//! Every record in both files is framed by the wire transport's
//! CRC-guarded [`encode_frame`]/[`decode_frame`], so a flipped bit on
//! disk is *detected* at load instead of silently diverging the resumed
//! trajectory. A torn tail in `journal.bin` (crash mid-append) parses as
//! "incomplete" and is dropped; a CRC mismatch anywhere is a hard error.
//! `base.bin` is never torn because it is only ever replaced whole.
//!
//! Restart semantics: [`RunLog::load`] hands back the committed state.
//! The server refuses to resume when the config hash or seed disagree
//! (a resumed run must be *the same* run), restores its method/RNG/
//! totals state at snapshot round `s`, replays the persisted records
//! into the observer stream, and continues from round `s + 1`. The
//! loaded journal suffix is kept only as a *verification queue*: the
//! resumed rounds regenerate their downlinks deterministically, and
//! each regenerated body must equal the persisted one byte-for-byte
//! (any mismatch means nondeterminism and aborts loudly rather than
//! silently forking the trajectory). Reconnecting workers are brought
//! to round `s` over the existing rejoin catch-up (`TAG_RESTORE` with
//! the snapshot's shard blobs), so the run's final model and per-round
//! records are bitwise identical to an uninterrupted one — asserted by
//! `tests/chaos_matrix.rs` and the smoke script's restart leg.

use crate::coordinator::{RoundRecord, RoundTotals};
use crate::wire::transport::{decode_frame, encode_frame};
use std::fs::{self, File};
use std::io::{self, Write};
use std::path::{Path, PathBuf};

// v2 (SMXRLOG2): records carry the per-phase time columns, and base.bin
// gained the RL_CONFIG / RL_FINISHED frames. v1 dirs fail the magic
// check at load — a clean refusal, never a silent misparse.
const MAGIC: &[u8; 8] = b"SMXRLOG2";
/// `base.bin` inside a run dir — the atomically-rotated committed state.
pub const BASE_FILE: &str = "base.bin";
const JOURNAL_FILE: &str = "journal.bin";

const RL_HEADER: u8 = 1;
const RL_SNAPSHOT: u8 = 2;
const RL_RECORD: u8 = 3;
const RL_DOWNLINK: u8 = 4;
/// Full config JSON (UTF-8 body), written right after the header.
const RL_CONFIG: u8 = 5;
/// Marker frame: the run completed cleanly (records are exhaustive).
const RL_FINISHED: u8 = 6;
/// One membership transition (join, late join, suspicion, eviction,
/// epoch roll) as seen by the coordinator's membership state machine.
const RL_MEMBERSHIP: u8 = 7;

/// FNV-1a over the canonical config JSON: cheap, dependency-free, and
/// stable across platforms — enough to refuse resuming under a changed
/// configuration (not a cryptographic commitment).
pub fn config_hash(canonical_json: &str) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64;
    for b in canonical_json.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// One committed checkpoint: everything the server needs to stand back
/// up at round `round` exactly as it stood when the snapshot committed.
#[derive(Clone, Debug, Default)]
pub struct Snapshot {
    /// the round whose post-`apply` state this is
    pub round: u64,
    /// [`ServerAlgo::save_state`](crate::methods::ServerAlgo::save_state) bytes
    pub server_blob: Vec<u8>,
    /// server [`Rng::save_state`](crate::util::rng::Rng::save_state) bytes
    pub rng_blob: Vec<u8>,
    /// cumulative communication totals through `round`
    pub totals: RoundTotals,
    /// per-shard worker blobs (`Rng` state ++ `WorkerAlgo` state), the
    /// same bytes `TAG_RESTORE` ships to rejoining workers
    pub shard_blobs: Vec<Vec<u8>>,
}

/// One logged membership transition. `kind` is
/// [`MembershipEvent::kind_code`](crate::coordinator::MembershipEvent::kind_code);
/// decode the name with `MembershipEvent::kind_name`. Only *structural*
/// events are logged (joins, suspicions, evictions, epoch rolls) — the
/// per-round cohort itself is a pure function of `(seed, n, τ, round)`
/// and regenerates, so logging it would only bloat the base.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MembershipRecord {
    pub round: u64,
    pub epoch: u64,
    pub kind: u8,
    pub member: u64,
}

/// Everything [`RunLog::load`] recovers from disk.
#[derive(Debug, Default)]
pub struct LoadedRun {
    pub config_hash: u64,
    pub seed: u64,
    /// full config JSON as persisted at create time (`None` only for a
    /// log created with an empty config string)
    pub config_json: Option<String>,
    /// the run completed cleanly ([`RunLog::finish`] rotated the base);
    /// its records are exhaustive and `smx serve` refuses to resume it
    pub finished: bool,
    /// `None` ⇒ the run died before its first committed snapshot; the
    /// restart simply re-runs from round 0 (everything regenerates)
    pub snapshot: Option<Snapshot>,
    /// records emitted up to the snapshot round (all records when
    /// `finished`), in round order
    pub records: Vec<RoundRecord>,
    /// journal suffix: `(round, downlink body)` for rounds after the
    /// snapshot, in round order
    pub journal: Vec<(u64, Vec<u8>)>,
    /// membership transitions through the snapshot round (full history
    /// when `finished`), in emission order
    pub membership: Vec<MembershipRecord>,
}

/// Open handle on a run directory; owns the journal append stream and
/// the in-memory record history that each rotation makes durable.
pub struct RunLog {
    dir: PathBuf,
    config_hash: u64,
    seed: u64,
    config_json: String,
    records: Vec<RoundRecord>,
    membership: Vec<MembershipRecord>,
    /// last committed snapshot, kept so [`RunLog::finish`] can rotate a
    /// base that still carries it
    last_snap: Option<Snapshot>,
    journal: File,
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn get_u64(buf: &[u8], pos: &mut usize) -> io::Result<u64> {
    let b = buf
        .get(*pos..*pos + 8)
        .ok_or_else(|| corrupt("truncated u64"))?;
    *pos += 8;
    Ok(u64::from_le_bytes(b.try_into().unwrap()))
}

fn put_bytes(out: &mut Vec<u8>, b: &[u8]) {
    out.extend_from_slice(&(b.len() as u32).to_le_bytes());
    out.extend_from_slice(b);
}

fn get_bytes(buf: &[u8], pos: &mut usize) -> io::Result<Vec<u8>> {
    let hdr = buf
        .get(*pos..*pos + 4)
        .ok_or_else(|| corrupt("truncated length"))?;
    let n = u32::from_le_bytes(hdr.try_into().unwrap()) as usize;
    let body = buf
        .get(*pos + 4..*pos + 4 + n)
        .ok_or_else(|| corrupt("truncated bytes"))?;
    *pos += 4 + n;
    Ok(body.to_vec())
}

fn corrupt(what: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, format!("corrupt run log: {what}"))
}

fn put_record(out: &mut Vec<u8>, r: &RoundRecord) {
    put_u64(out, r.round as u64);
    put_u64(out, r.residual.to_bits());
    put_u64(out, r.coords_up);
    put_u64(out, r.bits_up);
    put_u64(out, r.coords_down);
    put_u64(out, r.bytes_up);
    put_u64(out, r.bytes_down);
    put_u64(out, r.wall_secs.to_bits());
    put_u64(out, r.compute_secs.to_bits());
    put_u64(out, r.encode_secs.to_bits());
    put_u64(out, r.wire_secs.to_bits());
}

fn get_record(buf: &[u8], pos: &mut usize) -> io::Result<RoundRecord> {
    Ok(RoundRecord {
        round: get_u64(buf, pos)? as usize,
        residual: f64::from_bits(get_u64(buf, pos)?),
        coords_up: get_u64(buf, pos)?,
        bits_up: get_u64(buf, pos)?,
        coords_down: get_u64(buf, pos)?,
        bytes_up: get_u64(buf, pos)?,
        bytes_down: get_u64(buf, pos)?,
        wall_secs: f64::from_bits(get_u64(buf, pos)?),
        compute_secs: f64::from_bits(get_u64(buf, pos)?),
        encode_secs: f64::from_bits(get_u64(buf, pos)?),
        wire_secs: f64::from_bits(get_u64(buf, pos)?),
    })
}

impl RunLog {
    /// Start a fresh run log in `dir` (created if missing): writes the
    /// header + config `base.bin` atomically and truncates the journal.
    /// Any previous run's files in `dir` are replaced. `config_json` is
    /// the full experiment config, persisted verbatim so the dir is a
    /// self-contained artifact (`smx runs show`/`resume`).
    pub fn create(dir: &Path, config_hash: u64, seed: u64, config_json: &str) -> io::Result<RunLog> {
        fs::create_dir_all(dir)?;
        let mut log = RunLog {
            dir: dir.to_path_buf(),
            config_hash,
            seed,
            config_json: config_json.to_string(),
            records: Vec::new(),
            membership: Vec::new(),
            last_snap: None,
            journal: File::create(dir.join(JOURNAL_FILE))?,
        };
        log.write_base(None, false)?;
        Ok(log)
    }

    /// Reopen a run directory after [`RunLog::load`], seeding the record
    /// history. The on-disk journal is truncated: the resumed server
    /// re-runs every post-snapshot round and re-appends the identical
    /// downlink bodies (it verifies them against the loaded suffix), so
    /// keeping the old bytes would only duplicate entries.
    pub fn reopen(dir: &Path, loaded: &LoadedRun) -> io::Result<RunLog> {
        Ok(RunLog {
            dir: dir.to_path_buf(),
            config_hash: loaded.config_hash,
            seed: loaded.seed,
            config_json: loaded.config_json.clone().unwrap_or_default(),
            records: loaded.records.clone(),
            membership: loaded.membership.clone(),
            last_snap: loaded.snapshot.clone(),
            journal: File::create(dir.join(JOURNAL_FILE))?,
        })
    }

    /// Remember an emitted record. In-memory until the next rotation —
    /// a lost tail of records re-emerges identically when the rounds
    /// past the last snapshot re-run.
    pub fn record(&mut self, rec: &RoundRecord) {
        self.records.push(rec.clone());
    }

    /// Remember a membership transition. Durability follows the same
    /// rotation rule as records: a crash loses the tail past the last
    /// snapshot, and the resumed run logs its own (possibly different)
    /// membership history for the re-run rounds — which is exactly what
    /// happened in the resumed trajectory.
    pub fn membership(&mut self, rec: MembershipRecord) {
        self.membership.push(rec);
    }

    /// Append one broadcast downlink body to the journal suffix. No
    /// fsync here (see the module docs): the snapshot commit is the
    /// durability point.
    pub fn append_downlink(&mut self, round: u64, body: &[u8]) -> io::Result<()> {
        let mut rec = Vec::with_capacity(1 + 8 + body.len());
        rec.push(RL_DOWNLINK);
        put_u64(&mut rec, round);
        rec.extend_from_slice(body);
        self.journal.write_all(&encode_frame(&rec, true))
    }

    /// Commit a snapshot: rotate `base.bin` (tmp + rename + fsync, with
    /// the directory entry fsynced too) to hold the header, `snap`, and
    /// all records through `snap.round`, then truncate the journal. If
    /// the process dies between the two steps, stale journal entries
    /// (round ≤ `snap.round`) are dropped at load by the round check.
    pub fn commit(&mut self, snap: &Snapshot) -> io::Result<()> {
        self.write_base(Some(snap), false)?;
        self.last_snap = Some(snap.clone());
        self.journal = File::create(self.dir.join(JOURNAL_FILE))?;
        self.journal.sync_all()
    }

    /// Mark the run as cleanly completed: rotate `base.bin` one final
    /// time carrying the last committed snapshot (if any), *every*
    /// record — including those past the snapshot round, which a crash
    /// would have regenerated but a finished run never re-runs — and an
    /// `RL_FINISHED` marker, then truncate the journal (nothing is left
    /// to replay). `smx runs` treats such a dir as a complete artifact;
    /// `smx serve` refuses to resume it.
    pub fn finish(&mut self) -> io::Result<()> {
        self.write_base(self.last_snap.clone().as_ref(), true)?;
        self.journal = File::create(self.dir.join(JOURNAL_FILE))?;
        self.journal.sync_all()
    }

    fn write_base(&self, snap: Option<&Snapshot>, finished: bool) -> io::Result<()> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        let mut body = vec![RL_HEADER];
        put_u64(&mut body, self.config_hash);
        put_u64(&mut body, self.seed);
        out.extend_from_slice(&encode_frame(&body, true));
        if !self.config_json.is_empty() {
            body.clear();
            body.push(RL_CONFIG);
            body.extend_from_slice(self.config_json.as_bytes());
            out.extend_from_slice(&encode_frame(&body, true));
        }
        if let Some(s) = snap {
            body.clear();
            body.push(RL_SNAPSHOT);
            put_u64(&mut body, s.round);
            put_bytes(&mut body, &s.server_blob);
            put_bytes(&mut body, &s.rng_blob);
            put_u64(&mut body, s.totals.coords_up);
            put_u64(&mut body, s.totals.bits_up);
            put_u64(&mut body, s.totals.coords_down);
            put_u64(&mut body, s.totals.bytes_up);
            put_u64(&mut body, s.totals.bytes_down);
            put_u64(&mut body, s.shard_blobs.len() as u64);
            for blob in &s.shard_blobs {
                put_bytes(&mut body, blob);
            }
            out.extend_from_slice(&encode_frame(&body, true));
        }
        if snap.is_some() || finished {
            // crash-resume keeps only snapshot-gated records (later ones
            // regenerate); a finished run persists the full history
            let cutoff = if finished {
                u64::MAX
            } else {
                snap.map(|s| s.round).unwrap_or(0)
            };
            for rec in self.records.iter().filter(|r| r.round as u64 <= cutoff) {
                body.clear();
                body.push(RL_RECORD);
                put_record(&mut body, rec);
                out.extend_from_slice(&encode_frame(&body, true));
            }
            for m in self.membership.iter().filter(|m| m.round <= cutoff) {
                body.clear();
                body.push(RL_MEMBERSHIP);
                put_u64(&mut body, m.round);
                put_u64(&mut body, m.epoch);
                body.push(m.kind);
                put_u64(&mut body, m.member);
                out.extend_from_slice(&encode_frame(&body, true));
            }
        }
        if finished {
            out.extend_from_slice(&encode_frame(&[RL_FINISHED], true));
        }
        let tmp = self.dir.join("base.tmp");
        let mut f = File::create(&tmp)?;
        f.write_all(&out)?;
        f.sync_all()?;
        fs::rename(&tmp, self.dir.join(BASE_FILE))?;
        #[cfg(unix)]
        File::open(&self.dir)?.sync_all()?;
        Ok(())
    }

    /// Load whatever a previous process left in `dir`. `Ok(None)` when
    /// no run log exists there yet (fresh start). A leftover `base.tmp`
    /// from a crash mid-rotation is ignored: the rename never happened,
    /// so `base.bin` is still the previous consistent state.
    pub fn load(dir: &Path) -> io::Result<Option<LoadedRun>> {
        let data = match fs::read(dir.join(BASE_FILE)) {
            Ok(d) => d,
            Err(e) if e.kind() == io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(e),
        };
        if data.len() < MAGIC.len() || &data[..MAGIC.len()] != MAGIC {
            return Err(corrupt("bad magic in base.bin"));
        }
        let mut loaded = LoadedRun::default();
        let mut pos = MAGIC.len();
        let mut body = Vec::new();
        let mut saw_header = false;
        while pos < data.len() {
            // base.bin is rotated whole, so an incomplete frame here is
            // corruption, not a torn append
            let (consumed, _) = decode_frame(&data[pos..], &mut body)?
                .ok_or_else(|| corrupt("truncated record in base.bin"))?;
            pos += consumed;
            let mut p = 1;
            match body.first() {
                Some(&RL_HEADER) => {
                    loaded.config_hash = get_u64(&body, &mut p)?;
                    loaded.seed = get_u64(&body, &mut p)?;
                    saw_header = true;
                }
                Some(&RL_SNAPSHOT) => {
                    let mut s = Snapshot {
                        round: get_u64(&body, &mut p)?,
                        server_blob: get_bytes(&body, &mut p)?,
                        rng_blob: get_bytes(&body, &mut p)?,
                        ..Snapshot::default()
                    };
                    s.totals = RoundTotals {
                        coords_up: get_u64(&body, &mut p)?,
                        bits_up: get_u64(&body, &mut p)?,
                        coords_down: get_u64(&body, &mut p)?,
                        bytes_up: get_u64(&body, &mut p)?,
                        bytes_down: get_u64(&body, &mut p)?,
                    };
                    let n = get_u64(&body, &mut p)? as usize;
                    for _ in 0..n {
                        s.shard_blobs.push(get_bytes(&body, &mut p)?);
                    }
                    loaded.snapshot = Some(s);
                }
                Some(&RL_RECORD) => loaded.records.push(get_record(&body, &mut p)?),
                Some(&RL_MEMBERSHIP) => {
                    let round = get_u64(&body, &mut p)?;
                    let epoch = get_u64(&body, &mut p)?;
                    let kind = *body.get(p).ok_or_else(|| corrupt("truncated membership"))?;
                    p += 1;
                    let member = get_u64(&body, &mut p)?;
                    loaded.membership.push(MembershipRecord { round, epoch, kind, member });
                }
                Some(&RL_CONFIG) => {
                    let json = std::str::from_utf8(&body[1..])
                        .map_err(|_| corrupt("non-UTF8 config in base.bin"))?;
                    loaded.config_json = Some(json.to_string());
                }
                Some(&RL_FINISHED) => loaded.finished = true,
                _ => return Err(corrupt("unknown record tag in base.bin")),
            }
        }
        if !saw_header {
            return Err(corrupt("base.bin has no header record"));
        }

        let jdata = match fs::read(dir.join(JOURNAL_FILE)) {
            Ok(d) => d,
            Err(e) if e.kind() == io::ErrorKind::NotFound => Vec::new(),
            Err(e) => return Err(e),
        };
        let snap_round = loaded.snapshot.as_ref().map(|s| s.round);
        pos = 0;
        while pos < jdata.len() {
            match decode_frame(&jdata[pos..], &mut body)? {
                Some((consumed, _)) => {
                    pos += consumed;
                    if body.first() != Some(&RL_DOWNLINK) {
                        return Err(corrupt("unknown record tag in journal.bin"));
                    }
                    let mut p = 1;
                    let round = get_u64(&body, &mut p)?;
                    // stale entries from before a commit that died between
                    // rotation and truncation
                    if snap_round.is_some_and(|s| round <= s) {
                        continue;
                    }
                    loaded.journal.push((round, body[p..].to_vec()));
                }
                // torn tail from a crash mid-append: the unfinished round
                // re-runs from the snapshot, so drop it
                None => break,
            }
        }
        Ok(Some(loaded))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(round: usize) -> RoundRecord {
        RoundRecord {
            round,
            residual: 1.0 / (round as f64 + 1.0),
            coords_up: round as u64 * 10,
            bits_up: round as u64 * 640,
            coords_down: round as u64 * 100,
            bytes_up: round as u64 * 90,
            bytes_down: round as u64 * 800,
            wall_secs: round as f64 * 0.25,
            compute_secs: round as f64 * 0.125,
            encode_secs: round as f64 * 0.03125,
            wire_secs: round as f64 * 0.0625,
        }
    }

    fn snap(round: u64) -> Snapshot {
        Snapshot {
            round,
            server_blob: vec![1, 2, 3],
            rng_blob: vec![9; 41],
            totals: RoundTotals {
                coords_up: 7,
                bits_up: 448,
                coords_down: 70,
                bytes_up: 63,
                bytes_down: 560,
            },
            shard_blobs: vec![vec![5; 10], vec![], vec![6, 7]],
        }
    }

    fn tmp_dir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("smx_runlog_{name}"));
        let _ = fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn create_commit_load_roundtrip_is_exact() {
        let dir = tmp_dir("roundtrip");
        let mut log = RunLog::create(&dir, 0xABCD, 77, "{\"dataset\":\"tiny\"}").unwrap();
        // fresh log: loadable, empty, config carried
        let l0 = RunLog::load(&dir).unwrap().unwrap();
        assert_eq!((l0.config_hash, l0.seed), (0xABCD, 77));
        assert_eq!(l0.config_json.as_deref(), Some("{\"dataset\":\"tiny\"}"));
        assert!(!l0.finished);
        assert!(l0.snapshot.is_none() && l0.records.is_empty() && l0.journal.is_empty());

        for r in [0usize, 1, 2, 3] {
            log.record(&rec(r));
            if r > 0 {
                log.append_downlink(r as u64, &[r as u8; 5]).unwrap();
            }
        }
        log.commit(&snap(3)).unwrap();
        // journal truncated at commit; suffix entries follow
        log.append_downlink(4, &[0xE4; 6]).unwrap();
        log.append_downlink(5, &[0xE5; 6]).unwrap();
        log.journal.flush().unwrap();

        let l = RunLog::load(&dir).unwrap().unwrap();
        assert_eq!((l.config_hash, l.seed), (0xABCD, 77));
        let s = l.snapshot.unwrap();
        assert_eq!(s.round, 3);
        assert_eq!(s.server_blob, vec![1, 2, 3]);
        assert_eq!(s.rng_blob, vec![9; 41]);
        assert_eq!(s.totals.bytes_down, 560);
        assert_eq!(s.shard_blobs, vec![vec![5; 10], vec![], vec![6, 7]]);
        assert_eq!(l.records.len(), 4);
        for (i, r) in l.records.iter().enumerate() {
            assert_eq!(r.round, i);
            assert_eq!(r.residual.to_bits(), rec(i).residual.to_bits());
            assert_eq!(r.bytes_up, rec(i).bytes_up);
            assert_eq!(r.compute_secs.to_bits(), rec(i).compute_secs.to_bits());
            assert_eq!(r.encode_secs.to_bits(), rec(i).encode_secs.to_bits());
            assert_eq!(r.wire_secs.to_bits(), rec(i).wire_secs.to_bits());
        }
        assert_eq!(
            l.journal,
            vec![(4, vec![0xE4; 6]), (5, vec![0xE5; 6])],
            "journal suffix must survive in round order"
        );
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn reopen_truncates_the_journal_and_next_commit_rotates() {
        let dir = tmp_dir("reopen");
        let mut log = RunLog::create(&dir, 1, 2, "").unwrap();
        log.record(&rec(0));
        log.record(&rec(2));
        log.commit(&snap(2)).unwrap();
        log.append_downlink(3, &[3]).unwrap();
        drop(log);

        // load hands the suffix back for verification...
        let l = RunLog::load(&dir).unwrap().unwrap();
        assert_eq!(l.journal, vec![(3, vec![3])]);
        // ...and reopen truncates it on disk: the resumed rounds re-append
        // the same bodies, so nothing may linger from the previous process
        let mut log = RunLog::reopen(&dir, &l).unwrap();
        let empty = RunLog::load(&dir).unwrap().unwrap();
        assert!(empty.journal.is_empty(), "reopen must truncate journal.bin");
        assert_eq!(empty.records.len(), 2, "record history survives reopen");

        log.append_downlink(3, &[3]).unwrap();
        log.append_downlink(4, &[4]).unwrap();
        log.record(&rec(4));
        let l2 = RunLog::load(&dir).unwrap().unwrap();
        assert_eq!(l2.journal, vec![(3, vec![3]), (4, vec![4])]);
        // a later commit carries the grown record history and drops the
        // now-stale journal suffix
        log.commit(&snap(4)).unwrap();
        let l3 = RunLog::load(&dir).unwrap().unwrap();
        assert_eq!(l3.records.len(), 3);
        assert!(l3.journal.is_empty());
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corruption_is_detected_and_torn_tail_tolerated() {
        let dir = tmp_dir("corrupt");
        let mut log = RunLog::create(&dir, 5, 6, "").unwrap();
        log.record(&rec(0));
        log.commit(&snap(0)).unwrap();
        log.append_downlink(1, &[1, 1, 1]).unwrap();
        log.journal.flush().unwrap();

        // flip one bit inside base.bin → hard InvalidData at load
        let base = dir.join(BASE_FILE);
        let mut data = fs::read(&base).unwrap();
        let mid = data.len() / 2;
        data[mid] ^= 0x40;
        fs::write(&base, &data).unwrap();
        let e = RunLog::load(&dir).unwrap_err();
        assert_eq!(e.kind(), io::ErrorKind::InvalidData);
        data[mid] ^= 0x40;
        fs::write(&base, &data).unwrap();

        // flip a bit in a *complete* journal record → hard error too
        let jpath = dir.join(JOURNAL_FILE);
        let jdata = fs::read(&jpath).unwrap();
        let mut bad = jdata.clone();
        bad[6] ^= 0x01;
        fs::write(&jpath, &bad).unwrap();
        assert_eq!(
            RunLog::load(&dir).unwrap_err().kind(),
            io::ErrorKind::InvalidData
        );

        // a torn tail (partial append) is dropped, not an error
        let mut torn = jdata.clone();
        torn.extend_from_slice(&encode_frame(&[RL_DOWNLINK], true)[..3]);
        fs::write(&jpath, &torn).unwrap();
        let l = RunLog::load(&dir).unwrap().unwrap();
        assert_eq!(l.journal, vec![(1, vec![1, 1, 1])]);

        // stale entries at or before the snapshot round are dropped
        fs::write(&jpath, &jdata).unwrap();
        let mut log = RunLog::reopen(&dir, &l).unwrap();
        log.commit(&snap(1)).unwrap();
        drop(log);
        let mut with_stale = Vec::new();
        let mut body = vec![RL_DOWNLINK];
        put_u64(&mut body, 1); // == snapshot round → stale
        body.push(0xAA);
        with_stale.extend_from_slice(&encode_frame(&body, true));
        let mut body2 = vec![RL_DOWNLINK];
        put_u64(&mut body2, 2);
        body2.push(0xBB);
        with_stale.extend_from_slice(&encode_frame(&body2, true));
        fs::write(&jpath, &with_stale).unwrap();
        let l = RunLog::load(&dir).unwrap().unwrap();
        assert_eq!(l.journal, vec![(2, vec![0xBB])]);

        // missing dir → clean None
        assert!(RunLog::load(&tmp_dir("never_created")).unwrap().is_none());
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn finish_persists_full_history_and_marks_complete() {
        let dir = tmp_dir("finish");
        let mut log = RunLog::create(&dir, 0xF1, 9, "{\"seed\":9}").unwrap();
        for r in 0..5usize {
            log.record(&rec(r));
        }
        // commit mid-run: only rounds ≤ 2 are persisted by the rotation
        log.commit(&snap(2)).unwrap();
        log.append_downlink(3, &[0xD3]).unwrap();
        log.journal.flush().unwrap();
        let mid = RunLog::load(&dir).unwrap().unwrap();
        assert_eq!(mid.records.len(), 3);
        assert!(!mid.finished);

        // finish(): every record is persisted, past the snapshot round too,
        // the completion marker lands, and the journal is truncated
        log.finish().unwrap();
        let l = RunLog::load(&dir).unwrap().unwrap();
        assert!(l.finished, "RL_FINISHED marker must survive a reload");
        assert_eq!(l.records.len(), 5, "finish persists records past the snapshot");
        assert_eq!(l.records[4].round, 4);
        assert_eq!(l.config_json.as_deref(), Some("{\"seed\":9}"));
        let s = l.snapshot.expect("last committed snapshot survives finish");
        assert_eq!(s.round, 2);
        assert!(l.journal.is_empty(), "finish truncates the journal");
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn membership_records_follow_the_rotation_rule() {
        let dir = tmp_dir("membership");
        let mut log = RunLog::create(&dir, 0xBEEF, 3, "").unwrap();
        let ev = |round, epoch, kind, member| MembershipRecord { round, epoch, kind, member };
        log.membership(ev(0, 1, 1, 10)); // two joins at activation
        log.membership(ev(0, 1, 1, 11));
        log.record(&rec(0));
        log.record(&rec(1));
        log.commit(&snap(1)).unwrap();
        // events past the snapshot round stay in memory only...
        log.membership(ev(2, 2, 2, 12)); // late join rolls the epoch
        log.membership(ev(2, 2, 7, 12));
        let mid = RunLog::load(&dir).unwrap().unwrap();
        assert_eq!(mid.membership, vec![ev(0, 1, 1, 10), ev(0, 1, 1, 11)]);
        // ...reopen carries the loaded history forward...
        let mut log = RunLog::reopen(&dir, &mid).unwrap();
        log.membership(ev(2, 2, 2, 12));
        // ...and finish persists everything
        log.finish().unwrap();
        let l = RunLog::load(&dir).unwrap().unwrap();
        assert_eq!(
            l.membership,
            vec![ev(0, 1, 1, 10), ev(0, 1, 1, 11), ev(2, 2, 2, 12)]
        );
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn config_hash_is_stable_and_discriminating() {
        let a = config_hash("{\"seed\":1}");
        assert_eq!(a, config_hash("{\"seed\":1}"));
        assert_ne!(a, config_hash("{\"seed\":2}"));
        // FNV-1a known answer for the empty string
        assert_eq!(config_hash(""), 0xCBF2_9CE4_8422_2325);
    }
}
