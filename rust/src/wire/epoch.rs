//! Epoch/cohort announcement frames for partial participation.
//!
//! Under `--participation tau=K` the server prefixes every round with a
//! tiny `TAG_EPOCH` frame broadcast to **all** live connections — cohort
//! members and sampled-out idlers alike. The frame names the round, the
//! membership epoch, and the cohort as a shard bitmap; the downlink that
//! follows on the same connection is sent only to connections hosting at
//! least one cohort shard. Sampled-out workers therefore still see one
//! frame per round, answer it with a heartbeat, and stay inside the
//! `--worker-timeout` grace window while owing no uplink. Relays forward
//! the frame verbatim to every child (pass-through, like downlinks).
//!
//! Like all membership state, the cohort itself is a pure function of
//! `(seed, n, τ, round)` (see `coordinator::membership`), so this frame
//! is an announcement, not a negotiation — workers could recompute it,
//! and do exactly that when replaying journaled rounds after a rejoin.

use super::codec::{frame_tag, get_varint, put_varint, WireError};

/// Epoch/cohort announcement (body tag). Keep clear of codec's tags
/// (1..=12).
pub const TAG_EPOCH: u8 = 13;

type Result<T> = std::result::Result<T, WireError>;

/// Serialize an epoch announcement: round, membership epoch, and the
/// cohort bitmap over `n = mask.len()` shards (LSB-first within each
/// byte).
pub fn put_epoch(out: &mut Vec<u8>, round: usize, epoch: u64, mask: &[bool]) {
    out.clear();
    out.push(TAG_EPOCH);
    put_varint(out, round as u64);
    put_varint(out, epoch);
    put_varint(out, mask.len() as u64);
    let mut byte = 0u8;
    for (i, &m) in mask.iter().enumerate() {
        if m {
            byte |= 1 << (i % 8);
        }
        if i % 8 == 7 {
            out.push(byte);
            byte = 0;
        }
    }
    if mask.len() % 8 != 0 {
        out.push(byte);
    }
}

/// Decode an epoch announcement into `mask` (resized to the frame's n)
/// → `(round, epoch)`.
pub fn get_epoch(body: &[u8], mask: &mut Vec<bool>) -> Result<(usize, u64)> {
    let mut pos = 0usize;
    if frame_tag(body)? != TAG_EPOCH {
        return Err(WireError::new("expected epoch frame"));
    }
    pos += 1;
    let round = get_varint(body, &mut pos)? as usize;
    let epoch = get_varint(body, &mut pos)?;
    let n = get_varint(body, &mut pos)? as usize;
    let bytes = (n + 7) / 8; // div_ceil needs Rust 1.73; MSRV is 1.70
    if body.len() - pos != bytes {
        return Err(WireError::new(format!(
            "epoch bitmap length mismatch: {} shards need {} byte(s), frame has {}",
            n,
            bytes,
            body.len() - pos
        )));
    }
    mask.clear();
    mask.reserve(n);
    for i in 0..n {
        let b = body[pos + i / 8];
        mask.push(b & (1 << (i % 8)) != 0);
    }
    // bits past n must be zero: a decode/re-encode must be byte-identical
    if n % 8 != 0 && body[pos + bytes - 1] >> (n % 8) != 0 {
        return Err(WireError::new("epoch bitmap has bits set past n"));
    }
    Ok((round, epoch))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(round: usize, epoch: u64, mask: &[bool]) {
        let mut buf = Vec::new();
        put_epoch(&mut buf, round, epoch, mask);
        assert_eq!(frame_tag(&buf).unwrap(), TAG_EPOCH);
        let mut got = Vec::new();
        let (r, e) = get_epoch(&buf, &mut got).unwrap();
        assert_eq!((r, e, got.as_slice()), (round, epoch, mask));
    }

    #[test]
    fn epoch_frame_roundtrips() {
        roundtrip(0, 1, &[true]);
        roundtrip(7, 3, &[true, false, true, false, false, true, true, false]);
        roundtrip(1_000_000, 42, &(0..19).map(|i| i % 3 == 0).collect::<Vec<_>>());
        roundtrip(5, 2, &vec![true; 64]);
        roundtrip(5, 2, &vec![false; 9]);
    }

    #[test]
    fn epoch_frame_rejects_garbage() {
        let mut buf = Vec::new();
        put_epoch(&mut buf, 3, 1, &[true, false, true]);
        let mut mask = Vec::new();
        // wrong tag
        let mut bad = buf.clone();
        bad[0] = 99;
        assert!(get_epoch(&bad, &mut mask).is_err());
        // truncated bitmap
        let bad = &buf[..buf.len() - 1];
        assert!(get_epoch(bad, &mut mask).is_err());
        // trailing bytes
        let mut bad = buf.clone();
        bad.push(0);
        assert!(get_epoch(&bad, &mut mask).is_err());
        // stray high bits past n
        let mut bad = buf.clone();
        *bad.last_mut().unwrap() |= 0x80;
        assert!(get_epoch(&bad, &mut mask).is_err());
    }
}
