//! Hierarchical aggregation relay (`smx relay`).
//!
//! A relay sits between the server and a group of workers, turning the
//! server's O(workers) fan-in into O(branch factor): it joins the run
//! like one big worker, re-fans its assigned shard group out to the
//! `downstream` worker processes that connect to it, and per round
//! merges their uplink frames into a single [`TAG_AGG_UPLINK`]
//! (`codec::merge_uplinks`) before forwarding upstream. Relays stack —
//! a relay's "worker" may itself be another relay (the merge flattens
//! nested aggregates), giving arbitrary tree depths.
//!
//! # Exactness and topology invariance
//!
//! The relay never decodes a message to dense and never re-encodes a
//! value: constituent uplink bodies travel verbatim inside the
//! aggregate, and downlinks/stop/snapshot traffic is fanned out
//! byte-identically. The server therefore decodes exactly the bytes
//! each worker produced, in its usual per-shard slots — which is why
//! flat, 2-level and 3-level topologies produce bitwise-identical
//! trajectories for *every* payload (f64 through q4) and every method.
//! `tests/topology_matrix.rs` pins that guarantee.
//!
//! # Fault model
//!
//! The relay is deliberately stateless: it holds no journal and no
//! model state, so its failure domain is "this subtree, for one rejoin
//! round-trip". Any connection loss — upstream or any child — tears the
//! whole session down and retries it from scratch (capped backoff, like
//! `smx worker`): the server orphans the relay's shard group into the
//! PR-4 grace window, the children's own retry loops reconnect to the
//! relay's listen address, and the rejoined session is caught up via
//! the server's snapshot + journal replay, bitwise identically. A
//! SIGKILLed relay is recovered the same way by just starting a new
//! `smx relay` on the same address.

use crate::wire::codec::{self, Hello};
use crate::wire::epoch;
use crate::wire::fault::FaultPlan;
use crate::wire::poll::Poller;
use crate::wire::runtime::{fd_of_tcp, is_connection_error, retry_backoff};
use crate::wire::transport::{Tcp, Transport};
use anyhow::{bail, ensure, Context, Result};
use std::collections::BTreeSet;
use std::net::TcpListener;
use std::time::{Duration, Instant};

/// One kernel wait per loop iteration; mirrors the elastic server.
const WAIT_SLICE: Duration = Duration::from_millis(25);
/// Idle upstream heartbeat cadence — insurance for the server's grace
/// clock while children compute long rounds.
const IDLE_HEARTBEAT: Duration = Duration::from_secs(1);
/// Poller token for the upstream socket (children use their index).
const UPSTREAM_TOKEN: u64 = u64::MAX;

/// Knobs for [`relay_connect`]: fan-out, resilience, chaos injection.
#[derive(Clone, Debug)]
pub struct RelayOpts {
    /// Worker (or next-tier relay) connections to accept and fan the
    /// shard group across. Capped at the group size.
    pub downstream: usize,
    /// Session retries after connection-class failures (either side).
    pub max_retries: usize,
    /// Base backoff between retries, milliseconds.
    pub retry_base_ms: u64,
    /// Chaos: vanish (without forwarding) on receiving this many live
    /// downlinks — the relay-tier `--die-after`.
    pub die_after: Option<u64>,
    /// Chaos: a parsed `--fault-plan`; the relay honors `kill@rN:relay`.
    pub fault: Option<FaultPlan>,
}

impl Default for RelayOpts {
    fn default() -> RelayOpts {
        RelayOpts {
            downstream: 2,
            max_retries: 0,
            retry_base_ms: 250,
            die_after: None,
            fault: None,
        }
    }
}

/// `smx relay --connect UP --listen ADDR`: bind the downstream listener
/// and run relay sessions (with reconnect/retry) until the run stops.
pub fn relay_connect(upstream: &str, listen: &str, opts: RelayOpts) -> Result<()> {
    let listener =
        TcpListener::bind(listen).with_context(|| format!("relay binding {listen}"))?;
    relay_on(listener, upstream, opts)
}

/// [`relay_connect`] against an already-bound listener (tests bind port
/// 0 and hand the address to their worker threads). Retries the whole
/// session on connection-class errors, exactly like `smx worker`.
pub fn relay_on(listener: TcpListener, upstream: &str, opts: RelayOpts) -> Result<()> {
    ensure!(opts.downstream >= 1, "relay needs --downstream >= 1");
    let mut attempt: usize = 0;
    loop {
        match relay_session(&listener, upstream, &opts) {
            Ok(()) => return Ok(()),
            Err(e) => {
                let msg = format!("{e:#}");
                if attempt >= opts.max_retries || !is_connection_error(&msg) {
                    return Err(e);
                }
                attempt += 1;
                let wait = retry_backoff(opts.retry_base_ms, attempt);
                crate::info!(
                    "wire",
                    "relay session lost ({msg}); retrying {attempt}/{} in {wait:?}",
                    opts.max_retries
                );
                std::thread::sleep(wait);
            }
        }
    }
}

/// A downstream connection and the shards currently homed through it.
struct Child {
    tcp: Tcp,
    shards: BTreeSet<usize>,
    peer: String,
}

/// Per-round uplink collection: which shards still owe an uplink, which
/// are already covered by a buffered frame, and the frames themselves
/// (kept verbatim for the merge).
#[derive(Default)]
struct Gather {
    pending: BTreeSet<usize>,
    covered: BTreeSet<usize>,
    frames: Vec<Vec<u8>>,
}

impl Gather {
    /// Start a fresh collection over `shards` (a live downlink went out).
    fn arm(&mut self, shards: impl IntoIterator<Item = usize>) {
        self.pending = shards.into_iter().collect();
        self.covered.clear();
        self.frames.clear();
    }

    fn disarm(&mut self) {
        self.pending.clear();
        self.covered.clear();
        self.frames.clear();
    }

    /// Adopted shards answer the catch-up's live frame too.
    fn extend(&mut self, shards: &[usize]) {
        self.pending.extend(shards.iter().copied());
    }

    /// Record one child uplink frame claiming `shards`.
    fn absorb(&mut self, shards: &[usize], frame: &[u8]) -> Result<()> {
        for &s in shards {
            ensure!(
                self.pending.contains(&s),
                "relay: unexpected uplink for shard {s} (not owed this round)"
            );
            ensure!(
                !self.covered.contains(&s),
                "relay: duplicate uplink for shard {s}"
            );
            self.covered.insert(s);
        }
        self.frames.push(frame.to_vec());
        Ok(())
    }

    fn complete(&self) -> bool {
        !self.pending.is_empty() && self.covered == self.pending
    }
}

fn relay_session(listener: &TcpListener, upstream: &str, opts: &RelayOpts) -> Result<()> {
    let mut up = Tcp::connect_retry(upstream, 60, Duration::from_millis(250))
        .with_context(|| format!("connecting to {upstream}"))?;
    let mut body = Vec::new();
    up.recv(&mut body).context("waiting for hello")?;
    // mirror the server's frame-integrity mode on both faces
    let crc = up.crc_seen();
    up.set_crc(crc);
    if codec::frame_tag(&body)? == codec::TAG_STOP {
        crate::info!("wire", "server finished without needing this relay");
        release_waiting_children(listener);
        return Ok(());
    }
    let hello = codec::get_hello(&body)?;
    ensure!(!hello.shards.is_empty(), "server assigned the relay no shards");
    let group = hello.shards.clone();
    let fanout = opts.downstream.min(group.len());
    crate::info!(
        "wire",
        "relay assigned {} shard(s); fanning out to {fanout} downstream connection(s)",
        group.len()
    );

    // accept the children and hand each its slice of the group (ascending
    // round-robin, the same deterministic rule the server uses)
    let mut children = accept_children(listener, &hello, &group, fanout, crc)?;
    for ch in children.iter_mut() {
        ch.tcp.recv(&mut body).context("relay child ack recv")?;
        ensure!(
            codec::frame_tag(&body)? == codec::TAG_HELLO_ACK,
            "relay: child {} answered the hello with tag {} instead of an ack",
            ch.peer,
            codec::frame_tag(&body)?
        );
    }
    up.send(&[codec::TAG_HELLO_ACK]).context("relay upstream send")?;

    // event loop: everything nonblocking under one poller
    let mut poller = Poller::new().context("relay poller")?;
    up.set_nonblocking(true).context("relay upstream socket")?;
    poller
        .register(fd_of_tcp(&up), UPSTREAM_TOKEN)
        .context("relay poller")?;
    for (k, ch) in children.iter_mut().enumerate() {
        ch.tcp.set_nonblocking(true).context("relay child socket")?;
        poller
            .register(fd_of_tcp(&ch.tcp), k as u64)
            .context("relay poller")?;
    }

    let mut gather = Gather::default();
    let mut parts = Vec::new();
    let mut merged = Vec::new();
    let mut ready = Vec::new();
    let mut rounds_seen: u64 = 0;
    let mut last_up_send = Instant::now();
    // current round's cohort mask from the upstream `TAG_EPOCH` stream;
    // empty = full participation (no epoch frames seen)
    let mut cohort: Vec<bool> = Vec::new();
    loop {
        poller.wait(WAIT_SLICE, &mut ready).context("relay poller")?;

        // upstream frames: broadcasts to fan out, catch-up streams to route
        loop {
            match up.try_recv(&mut body).context("relay upstream recv")? {
                false => break,
                true => {}
            }
            match codec::frame_tag(&body)? {
                epoch::TAG_EPOCH => {
                    // partial participation: learn this round's cohort and
                    // pass the announcement to every child (sampled-out
                    // workers must hear they are idle; their heartbeat
                    // replies pump upstream and keep the grace clock warm)
                    epoch::get_epoch(&body, &mut cohort)?;
                    for ch in children.iter_mut() {
                        ch.tcp.send(&body).context("relay child send")?;
                    }
                }
                codec::TAG_DOWNLINK => {
                    rounds_seen += 1;
                    let planned_kill = opts
                        .fault
                        .as_ref()
                        .is_some_and(|p| p.kill_relay_after(rounds_seen));
                    if opts.die_after == Some(rounds_seen) || planned_kill {
                        // injected fault: vanish without forwarding — the
                        // sockets closing is a SIGKILL as far as both the
                        // server and the children can observe
                        return Ok(());
                    }
                    // only children with a sampled-in shard take part in
                    // this round; the rest already idled on the epoch frame
                    let in_cohort =
                        |s: usize| cohort.is_empty() || cohort.get(s).copied().unwrap_or(false);
                    for ch in children.iter_mut() {
                        if ch.shards.iter().any(|&s| in_cohort(s)) {
                            ch.tcp.send(&body).context("relay child send")?;
                        }
                    }
                    gather.arm(
                        children
                            .iter()
                            .flat_map(|c| c.shards.iter().copied())
                            .filter(|&s| in_cohort(s)),
                    );
                }
                codec::TAG_STOP => {
                    for ch in children.iter_mut() {
                        ch.tcp.send(&body).context("relay child send")?;
                    }
                    crate::info!("wire", "relay done after {rounds_seen} round(s)");
                    return Ok(());
                }
                codec::TAG_SNAP_REQ => {
                    for ch in children.iter_mut() {
                        ch.tcp.send(&body).context("relay child send")?;
                    }
                }
                codec::TAG_REPLAY => {
                    // rejoin catch-up: every child restores its own slice
                    // and replays the same journaled stream; only the
                    // final (live) frame is answered with uplinks
                    let (count, restore) = codec::get_replay(&body)?;
                    for ch in children.iter_mut() {
                        ch.tcp.send(&body).context("relay child send")?;
                    }
                    if restore {
                        forward_restore_split(&mut up, &mut children, &mut body)?;
                    }
                    forward_replay_stream(
                        &mut up,
                        &mut children,
                        &mut body,
                        count,
                        None,
                        LiveArm::Rejoin,
                        &mut gather,
                        &mut parts,
                        &mut cohort,
                    )?;
                    last_up_send = Instant::now();
                }
                codec::TAG_ADOPT => {
                    // another connection's orphans were reassigned to us:
                    // home them on the least-loaded child (every worker
                    // keeps reserve runners for the full shard universe)
                    let (shards, count, restore) = codec::get_adopt(&body)?;
                    let k = (0..children.len())
                        .min_by_key(|&k| (children[k].shards.len(), k))
                        .expect("relay has children");
                    crate::info!(
                        "wire",
                        "relay adopting {} orphaned shard(s) via child {}",
                        shards.len(),
                        children[k].peer
                    );
                    children[k].tcp.send(&body).context("relay child send")?;
                    if restore {
                        // adopt restores name exactly the adopted shards,
                        // so the frame forwards verbatim
                        up.recv(&mut body).context("restore recv")?;
                        ensure!(
                            codec::frame_tag(&body)? == codec::TAG_RESTORE,
                            "relay: adopt restore interrupted by tag {}",
                            codec::frame_tag(&body)?
                        );
                        children[k].tcp.send(&body).context("relay child send")?;
                    }
                    children[k].shards.extend(shards.iter().copied());
                    forward_replay_stream(
                        &mut up,
                        &mut children,
                        &mut body,
                        count,
                        Some(k),
                        LiveArm::Adopt(&shards),
                        &mut gather,
                        &mut parts,
                        &mut cohort,
                    )?;
                    last_up_send = Instant::now();
                }
                other => bail!("relay: unexpected upstream frame tag {other}"),
            }
        }

        // child frames: uplinks to merge, liveness + snapshots to forward
        for ch in children.iter_mut() {
            while ch
                .tcp
                .try_recv(&mut body)
                .with_context(|| format!("relay child recv ({})", ch.peer))?
            {
                child_frame(&mut up, ch, &body, &mut gather, &mut parts, &mut last_up_send)?;
            }
        }

        if gather.complete() {
            let frames: Vec<&[u8]> = gather.frames.iter().map(|f| f.as_slice()).collect();
            codec::merge_uplinks(&mut merged, &frames)
                .map_err(|e| anyhow::anyhow!("relay merge: {e}"))?;
            up.send(&merged).context("relay upstream send")?;
            last_up_send = Instant::now();
            gather.disarm();
        }

        if last_up_send.elapsed() >= IDLE_HEARTBEAT {
            up.send(&[codec::TAG_HEARTBEAT]).context("relay upstream send")?;
            last_up_send = Instant::now();
        }
    }
}

/// Handle one frame from a child: heartbeats and snapshot blobs pump
/// upstream; uplinks (plain or already-aggregated by a deeper tier) are
/// collected for the merge. Shared by the main loop and the replay
/// forwarder so no child frame is ever dropped on the floor.
fn child_frame(
    up: &mut Tcp,
    ch: &mut Child,
    body: &[u8],
    gather: &mut Gather,
    parts: &mut Vec<(usize, usize, usize)>,
    last_up_send: &mut Instant,
) -> Result<()> {
    match codec::frame_tag(body)? {
        codec::TAG_HEARTBEAT | codec::TAG_SNAP_STATE => {
            up.send(body).context("relay upstream send")?;
            *last_up_send = Instant::now();
        }
        codec::TAG_UPLINK => {
            let shard = codec::peek_uplink_shard(body)?;
            ensure!(
                ch.shards.contains(&shard),
                "relay: child {} sent an uplink for shard {shard} it does not own",
                ch.peer
            );
            gather.absorb(&[shard], body)?;
        }
        codec::TAG_AGG_UPLINK => {
            // a deeper tier already merged: flattens on re-merge
            codec::get_agg_uplink(body, parts)?;
            let shards: Vec<usize> = parts.iter().map(|p| p.0).collect();
            ensure!(
                shards.iter().all(|s| ch.shards.contains(s)),
                "relay: child {} aggregated shards it does not own",
                ch.peer
            );
            gather.absorb(&shards, body)?;
        }
        other => bail!("relay: unexpected child frame tag {other}"),
    }
    Ok(())
}

/// Accept `fanout` downstream connections and send each a re-encoded
/// hello covering its ascending round-robin slice of `group`.
fn accept_children(
    listener: &TcpListener,
    hello: &Hello,
    group: &[usize],
    fanout: usize,
    crc: bool,
) -> Result<Vec<Child>> {
    let mut body = Vec::new();
    let mut children = Vec::with_capacity(fanout);
    for k in 0..fanout {
        let (stream, addr) = listener.accept().context("relay accept")?;
        let mut tcp = Tcp::new(stream).context("relay accept")?;
        tcp.set_crc(crc);
        let shards: Vec<usize> = group.iter().copied().skip(k).step_by(fanout).collect();
        let mut child_hello = hello.clone();
        child_hello.shards = shards.clone();
        body.clear();
        codec::put_hello(&mut body, &child_hello);
        tcp.send(&body).context("relay child send")?;
        children.push(Child {
            tcp,
            shards: shards.into_iter().collect(),
            peer: addr.to_string(),
        });
    }
    Ok(children)
}

/// Forward the [`TAG_RESTORE`] frame that follows a restore-flagged
/// replay announcement, splitting its blobs per child: each worker's
/// restore must name exactly the shards that worker hosts.
fn forward_restore_split(
    up: &mut Tcp,
    children: &mut [Child],
    body: &mut Vec<u8>,
) -> Result<()> {
    up.recv(body).context("restore recv")?;
    ensure!(
        codec::frame_tag(body)? == codec::TAG_RESTORE,
        "relay: replay restore interrupted by tag {}",
        codec::frame_tag(body)?
    );
    let (round, blobs) = codec::get_restore(body)?;
    let mut out = Vec::new();
    for ch in children.iter_mut() {
        let slice: Vec<(usize, &[u8])> = blobs
            .iter()
            .filter(|(s, _)| ch.shards.contains(s))
            .map(|(s, b)| (*s, b.as_slice()))
            .collect();
        ensure!(
            slice.len() == ch.shards.len(),
            "relay: restore covers {} of child {}'s {} shard(s)",
            slice.len(),
            ch.peer,
            ch.shards.len()
        );
        out.clear();
        codec::put_restore(&mut out, round, &slice);
        ch.tcp.send(&out).context("relay child send")?;
    }
    Ok(())
}

/// How the gather gets (re)armed at a replay stream's final — live —
/// frame. Arming must happen *there*, not before the stream: under
/// partial participation the live round's cohort is announced by the
/// last interleaved epoch frame, and arming early would gate the gather
/// on a stale mask.
#[derive(Clone, Copy)]
enum LiveArm<'a> {
    /// A rejoin replay: every child re-answers the live round, so the
    /// gather restarts over all (sampled-in) shards.
    Rejoin,
    /// An adoption: the adopted shards join the in-flight gather.
    Adopt(&'a [usize]),
}

/// Forward `count` journaled downlink frames from upstream — to every
/// child (`target = None`, a rejoin replay) or to one adopter. Each
/// downlink may be preceded by a `TAG_EPOCH` announcement (partial
/// participation), forwarded on the same route so a replaying worker
/// re-applies the historical per-round cohort gating. Child traffic
/// (replay heartbeats, and uplinks once the live last frame lands) is
/// pumped through [`child_frame`] between frames so neither side's
/// socket backs up and nothing is dropped.
#[allow(clippy::too_many_arguments)]
fn forward_replay_stream(
    up: &mut Tcp,
    children: &mut [Child],
    body: &mut Vec<u8>,
    count: usize,
    target: Option<usize>,
    arm: LiveArm<'_>,
    gather: &mut Gather,
    parts: &mut Vec<(usize, usize, usize)>,
    cohort: &mut Vec<bool>,
) -> Result<()> {
    let mut child_body = Vec::new();
    let mut last_up_send = Instant::now();
    for i in 0..count {
        up.recv(body).context("replay recv")?;
        if codec::frame_tag(body)? == epoch::TAG_EPOCH {
            epoch::get_epoch(body, cohort)?;
            match target {
                Some(k) => children[k].tcp.send(body).context("relay child send")?,
                None => {
                    for ch in children.iter_mut() {
                        ch.tcp.send(body).context("relay child send")?;
                    }
                }
            }
            up.recv(body).context("replay recv")?;
        }
        ensure!(
            codec::frame_tag(body)? == codec::TAG_DOWNLINK,
            "relay: replay stream interrupted by a non-downlink frame"
        );
        if i + 1 == count {
            // the live frame: arm under the cohort it was drawn with
            let in_cohort =
                |s: usize| cohort.is_empty() || cohort.get(s).copied().unwrap_or(false);
            match arm {
                LiveArm::Rejoin => gather.arm(
                    children
                        .iter()
                        .flat_map(|c| c.shards.iter().copied())
                        .filter(|&s| in_cohort(s)),
                ),
                LiveArm::Adopt(shards) => {
                    let add: Vec<usize> =
                        shards.iter().copied().filter(|&s| in_cohort(s)).collect();
                    gather.extend(&add);
                }
            }
        }
        match target {
            Some(k) => children[k].tcp.send(body).context("relay child send")?,
            None => {
                for ch in children.iter_mut() {
                    ch.tcp.send(body).context("relay child send")?;
                }
            }
        }
        for ch in children.iter_mut() {
            while ch
                .tcp
                .try_recv(&mut child_body)
                .with_context(|| format!("relay child recv ({})", ch.peer))?
            {
                child_frame(up, ch, &child_body, gather, parts, &mut last_up_send)?;
            }
        }
    }
    Ok(())
}

/// Standby release: the server stopped before needing this relay. Pass
/// the release on to any child already parked on our listener.
fn release_waiting_children(listener: &TcpListener) {
    if listener.set_nonblocking(true).is_err() {
        return;
    }
    while let Ok((stream, _)) = listener.accept() {
        if let Ok(mut t) = Tcp::new(stream) {
            let _ = t.send(&[codec::TAG_STOP]);
        }
    }
}
