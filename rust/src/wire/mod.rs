//! Wire subsystem: binary message codec + multi-process transport runtime.
//!
//! Everything the rest of the crate sends between server and workers
//! ([`SparseMsg`](crate::compress::SparseMsg) uplinks, dense/sparse
//! [`Downlink`](crate::methods::Downlink)s) stays an in-memory struct under
//! the sim and threaded drivers; this module is where those structs become
//! *bytes*, so the paper's communication claims can be measured instead of
//! modeled.
//!
//! Two halves:
//!
//! * [`codec`] — a framed binary encoding with pluggable value payloads
//!   ([`Payload`]): `f64` (lossless, the reference), `f32`, and `q16`/`q8`/
//!   `q4` scaled-integer quantization (per-message scale = max |v|, so the
//!   quantization error is *relative* to the message magnitude and shrinks
//!   as the method converges). The float payloads carry non-finite values
//!   transparently (`f64` bit-for-bit); the quantized payloads *refuse*
//!   them — `put_uplink`/`put_downlink` return a [`WireError`] rather
//!   than let one NaN/±inf poison the block's scale and decode to silent
//!   garbage. Sparse indices use **delta-varint** coding:
//!   strictly-increasing index sequences (what the sketches and Top-k
//!   emit) are stored as LEB128 gaps, beating the modeled
//!   `coords · (float_bits + ⌈log₂ d⌉)` bit account for large-d uplinks;
//!   non-monotone sequences fall back to raw varints so decoding always
//!   reproduces the exact original order (required for the bitwise-identity
//!   guarantee below). Exact `*_frame_len` helpers predict encoded sizes
//!   without serializing, which is how the in-process drivers record
//!   measured `bytes_up`/`bytes_down` allocation-free.
//!
//! * [`transport`] + [`poll`] + [`runtime`] — a [`Transport`] trait (one
//!   framed, bidirectional byte channel per worker process) with an
//!   in-process loopback implementation and a length-prefixed TCP
//!   implementation (`std::net`, no new dependencies) that also supports
//!   nonblocking frame reassembly; a minimal readiness shim over
//!   epoll/kqueue with a portable short-deadline-polling fallback; and
//!   the coordinator runtimes on top: the fixed-membership
//!   [`run_distributed_observed`] (loopback tests/benches) and the
//!   **elastic, fault-tolerant multiplexed server** behind `smx serve` —
//!   worker heartbeats, a replay journal with checkpoint snapshots +
//!   truncation, deterministic rejoin/snapshot-resume, and grace-window
//!   shard reassignment (see the [`runtime`] module docs for the
//!   connection state machine and the snapshot protocol).
//!   Shards run in worker *processes* (`smx serve` / `smx worker
//!   --connect`), each process hosting one or more shards round-robin.
//!
//! Both runtimes are reached from one front door: the
//! [`Session`](crate::coordinator::Session) builder with
//! [`Driver::Distributed`](crate::coordinator::Driver) selects loopback
//! or TCP via [`DistTransport`](crate::coordinator::DistTransport), and
//! `--driver distributed` does the same from the CLI.
//!
//! Two robustness layers complete the picture: [`fault`] parses the
//! scriptable `--fault-plan` schedule (worker kills, dropped uplinks,
//! frame corruption, delays, server and relay kills) that the chaos tests
//! drive recovery with, and [`runlog`] persists the journal + committed
//! snapshots to disk (`--run-dir`) so even the *server* process is
//! expendable — a SIGKILLed `smx serve` restarts and resumes bit-for-bit.
//!
//! For scale-out, [`relay`] adds an optional aggregation tier (`smx relay`)
//! between server and workers: each relay merges its children's uplink
//! frames *structurally* (verbatim constituent bodies, never arithmetic)
//! into one `TAG_AGG_UPLINK` frame per round, so a tree of relays produces
//! bit-for-bit the same final model as the flat topology — asserted by
//! `rust/tests/topology_matrix.rs` across 1/2/3-level trees.
//!
//! # Guarantees
//!
//! * Under the `f64` payload, the distributed driver (loopback or TCP)
//!   produces iterates **bitwise identical** to
//!   [`run_sim_observed`](crate::coordinator::run_sim_observed): the codec
//!   round-trips every
//!   finite, subnormal and infinite value exactly (NaN payloads survive
//!   bit-for-bit too), preserves message order, and the drivers derive
//!   identical per-shard RNG streams. Asserted in
//!   `rust/tests/wire_distributed.rs` and by `smx serve --check-sim`.
//! * The identity survives **worker failures**: a worker process that
//!   dies mid-run is replaced (rejoin) or absorbed (shard reassignment to
//!   survivors) by replaying the journaled downlinks through the same
//!   deterministic `round_into` calls, so the final model is still
//!   bit-for-bit equal to the sim driver's — asserted by the chaos tests
//!   and the `--die-after` smoke leg. With `checkpoint_every` set the
//!   replay starts from a committed worker-state snapshot instead of
//!   round 0 (journal truncated, state blobs restored bit-exactly) and
//!   the identity still holds — asserted by the snapshot-resume chaos
//!   test. Heartbeats, replay and snapshot retransmissions are protocol
//!   overhead, excluded from the `bytes_up`/`bytes_down` accounting
//!   (which counts the frames the round logically applies, so the
//!   accounting stays comparable across drivers and failures).
//! * The identity also survives **server failures** and **frame
//!   corruption**: with `--run-dir`, a killed-and-restarted server
//!   resumes from its durable snapshot + journal (each regenerated
//!   downlink verified byte-for-byte against the persisted copy), and
//!   every TCP frame carries a CRC32 trailer (`--no-crc` opts out) that
//!   turns silent bit flips into detected connection errors recovered
//!   through the rejoin + journal-retransmit path. Asserted by
//!   `rust/tests/chaos_matrix.rs` and the smoke script's restart leg.
//! * Lossy payloads quantize what the *server* sees; each worker's local
//!   state (e.g. DIANA shifts) still integrates its exact values, so
//!   server and worker shift estimates drift by a zero-mean error
//!   proportional to the current message magnitude — which itself decays,
//!   preserving linear convergence. Documented tracking tolerances versus
//!   the `f64` run (squared relative residual, a few hundred rounds):
//!   `f32` ≤ ~1e-6, `q16` ≤ ~1e-4, `q8` ≤ ~1e-2; `q4` is provided for
//!   bit-accounting experiments and validated at the codec level only.
//!   `diana++` (sparse downlink, worker-side model replicas) is only
//!   supported losslessly.
//!
//! # Frame format
//!
//! Every frame is `u32 LE body length` + body; the body starts with a
//! 1-byte tag (`TAG_*`). The top bit of the length prefix is a CRC flag:
//! when set, the body is followed by a CRC32 trailer covering it (the
//! flag bit doubles as the codec version marker, so CRC and plain peers
//! interoperate frame-by-frame). Uplink bodies carry the hosting shard
//! index so a process can multiplex several shards over one connection.
//! The 4-byte length prefix is included in all measured byte counts;
//! CRC trailers are integrity overhead and are not.

pub mod codec;
pub mod epoch;
pub mod fault;
pub mod journal;
pub mod poll;
pub mod relay;
pub mod runlog;
pub mod runtime;
pub mod transport;

pub use codec::{Payload, WireError};
pub use fault::{FaultAction, FaultPlan, KILLED_MARKER};
pub use relay::{relay_connect, relay_on, RelayOpts};
pub use runlog::{config_hash, LoadedRun, MembershipRecord, RunLog, Snapshot};
pub use runtime::{
    run_distributed_loopback_observed, run_distributed_observed, serve, serve_on, worker_connect,
    worker_connect_with, FaultConfig, WorkerHost, WorkerOpts,
};
pub use transport::{loopback_pair, Loopback, Tcp, Transport};
