//! The in-memory replay journal, keyed per member.
//!
//! PR 6 bounded the journal window (`MAX_JOURNAL_BYTES`, truncation at
//! snapshot commits) but kept it a flat `Vec<Vec<u8>>`: every catchup
//! cloned nothing but *conceptually* owed the whole window, and there
//! was no notion of which member had already been delivered what. This
//! module is the per-client sharding groundwork flagged in ROADMAP
//! §Scale-out: entries are stored **once** behind `Arc`, and a
//! low-water `mark` per member id records the last round that member
//! has durably applied. Catch-up for member `m` streams only
//! `tail_for(m)` — the suffix past its own mark — so an idle
//! (sampled-out or late-joining) connection no longer implies
//! re-streaming the full window, and [`JournalWindow::floor`] exposes
//! the round below which *no* live member needs entries (the future
//! per-member truncation point; today truncation still happens only at
//! snapshot commits, which is always ≤ safe).
//!
//! With partial participation each entry carries the round's epoch
//! announcement next to its downlink body, so a replayed member sees
//! exactly the frame sequence a live one did and can skip the rounds
//! its shards sat out.

use std::collections::{BTreeMap, VecDeque};
use std::sync::Arc;

/// One journaled round: the optional epoch/cohort announcement (present
/// iff partial participation is active) and the encoded downlink body.
#[derive(Debug)]
pub struct RoundEntry {
    pub round: usize,
    pub epoch: Option<Vec<u8>>,
    pub down: Vec<u8>,
}

impl RoundEntry {
    pub fn bytes(&self) -> usize {
        self.down.len() + self.epoch.as_ref().map(Vec::len).unwrap_or(0)
    }
}

/// Bounded window of recent rounds plus per-member delivery marks.
#[derive(Debug, Default)]
pub struct JournalWindow {
    /// rounds ≤ `base` are truncated (the committed snapshot's round)
    base: usize,
    /// entries for rounds `base+1 ..= base+entries.len()`, in order
    entries: VecDeque<Arc<RoundEntry>>,
    bytes: usize,
    /// member id → last round delivered to (and applied by) that member
    marks: BTreeMap<u64, usize>,
}

impl JournalWindow {
    pub fn new() -> JournalWindow {
        JournalWindow::default()
    }

    /// The committed-snapshot round the window starts after.
    pub fn base(&self) -> usize {
        self.base
    }

    /// Retained rounds.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Bytes held by retained entries (each counted once, however many
    /// members still reference it).
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// Append round `round`'s frames. Rounds must arrive in order,
    /// contiguously after the window's end.
    pub fn push(&mut self, round: usize, epoch: Option<Vec<u8>>, down: Vec<u8>) {
        debug_assert_eq!(round, self.base + self.entries.len() + 1);
        let entry = Arc::new(RoundEntry { round, epoch, down });
        self.bytes += entry.bytes();
        self.entries.push_back(entry);
    }

    /// Record that `member` has applied everything through `round`.
    /// Marks never move backward.
    pub fn mark(&mut self, member: u64, round: usize) {
        let m = self.marks.entry(member).or_insert(round);
        *m = (*m).max(round);
    }

    pub fn mark_of(&self, member: u64) -> Option<usize> {
        self.marks.get(&member).copied()
    }

    /// Forget a member (evicted): its mark must not pin the floor.
    pub fn release(&mut self, member: u64) {
        self.marks.remove(&member);
    }

    /// The round below which no retained mark needs entries: the
    /// per-member truncation point a future PR can drop the window to.
    /// With no members it is the window's end (everything droppable).
    pub fn floor(&self) -> usize {
        self.marks
            .values()
            .copied()
            .min()
            .unwrap_or(self.base + self.entries.len())
    }

    /// Entries `member` still needs: everything past its mark (or the
    /// whole window for an unknown/late-joining member, which restores
    /// from the snapshot at `base` first). Returns `(needs_restore,
    /// entries)`; `needs_restore` is true when the member's mark lies at
    /// or before `base`, i.e. part of its gap was truncated into the
    /// snapshot.
    pub fn tail_for(&self, member: u64) -> (bool, Vec<Arc<RoundEntry>>) {
        let from = self.mark_of(member).unwrap_or(0).max(self.base);
        let needs_restore = self.mark_of(member).map(|m| m <= self.base).unwrap_or(true);
        let tail = self
            .entries
            .iter()
            .filter(|e| e.round > from)
            .cloned()
            .collect();
        (needs_restore, tail)
    }

    /// All retained entries, oldest first (full-window catch-up).
    pub fn entries(&self) -> impl Iterator<Item = &Arc<RoundEntry>> {
        self.entries.iter()
    }

    /// Truncate through `round` (a committed snapshot): drop entries
    /// the snapshot supersedes.
    pub fn truncate_to(&mut self, round: usize) {
        debug_assert!(round >= self.base);
        while let Some(front) = self.entries.front() {
            if front.round > round {
                break;
            }
            self.bytes -= front.bytes();
            self.entries.pop_front();
        }
        self.base = round;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn window_with(rounds: std::ops::RangeInclusive<usize>) -> JournalWindow {
        let mut w = JournalWindow::new();
        for r in rounds {
            w.push(r, None, vec![r as u8; 4]);
        }
        w
    }

    #[test]
    fn push_truncate_and_bytes() {
        let mut w = window_with(1..=5);
        assert_eq!((w.base(), w.len(), w.bytes()), (0, 5, 20));
        w.truncate_to(3);
        assert_eq!((w.base(), w.len(), w.bytes()), (3, 2, 8));
        let rounds: Vec<usize> = w.entries().map(|e| e.round).collect();
        assert_eq!(rounds, vec![4, 5]);
        w.truncate_to(5);
        assert!(w.is_empty());
        w.push(6, Some(vec![0; 3]), vec![0; 4]);
        assert_eq!(w.bytes(), 7);
    }

    #[test]
    fn marks_key_the_window_per_member() {
        let mut w = window_with(1..=6);
        w.mark(10, 4);
        w.mark(11, 2);
        // member 10 only needs rounds 5..=6, no restore
        let (restore, tail) = w.tail_for(10);
        assert!(!restore);
        assert_eq!(tail.iter().map(|e| e.round).collect::<Vec<_>>(), vec![5, 6]);
        // unknown member needs a restore plus the whole window
        let (restore, tail) = w.tail_for(99);
        assert!(restore);
        assert_eq!(tail.len(), 6);
        // floor is the laggiest mark; releasing it advances the floor
        assert_eq!(w.floor(), 2);
        w.release(11);
        assert_eq!(w.floor(), 4);
        // marks never regress
        w.mark(10, 1);
        assert_eq!(w.mark_of(10), Some(4));
    }

    #[test]
    fn truncation_past_a_mark_forces_restore() {
        let mut w = window_with(1..=6);
        w.mark(7, 2);
        w.truncate_to(4); // snapshot at round 4 supersedes member 7's mark
        let (restore, tail) = w.tail_for(7);
        assert!(restore);
        assert_eq!(tail.iter().map(|e| e.round).collect::<Vec<_>>(), vec![5, 6]);
    }
}
