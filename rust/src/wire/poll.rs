//! Minimal readiness shim for the multiplexed server loop.
//!
//! The elastic runtime needs one thing from the OS: "which of these
//! sockets *may* have bytes (or a pending accept) right now?". The image
//! is offline (no `mio`/`libc` crates), so this module declares the two
//! well-known kernel interfaces directly — `epoll` on Linux and `kqueue`
//! on macOS — against the libc that `std` already links, and falls back
//! to **short-deadline polling** everywhere else (and under
//! `SMX_NO_EPOLL=1`, which CI uses to exercise the fallback on Linux).
//!
//! # Contract
//!
//! [`Poller::wait`] fills `out` with the tokens of sources that *may* be
//! ready and returns. Readiness is a hint, never a promise: the epoll and
//! kqueue backends report kernel-observed readiness, while the fallback
//! backend sleeps a short interval (≤ ~1 ms, capped by `timeout`) and
//! reports **every** registered token. Callers therefore must use
//! nonblocking operations ([`Tcp::try_recv`](crate::wire::transport::
//! Tcp::try_recv), nonblocking `accept`) and treat `WouldBlock` as "not
//! this one" — which makes spurious wakeups, level-triggered re-reports
//! and the fallback's blanket report all correct by construction.
//!
//! Error/hangup conditions (`EPOLLERR`/`EPOLLHUP`/`EV_EOF`) are reported
//! as plain readiness: the next nonblocking read observes the EOF or
//! error and the connection state machine handles it.

use std::io;
use std::time::Duration;

/// Upper bound on one kernel wait; the elastic loop re-checks its own
/// deadlines (worker grace windows, rejoin windows) at least this often.
const MAX_WAIT: Duration = Duration::from_millis(25);

/// Forces the portable fallback backend even where epoll/kqueue exist.
pub const NO_EPOLL_ENV: &str = "SMX_NO_EPOLL";

fn fallback_forced() -> bool {
    std::env::var_os(NO_EPOLL_ENV).is_some_and(|v| v == "1")
}

/// Readiness monitor over raw socket fds. Tokens are caller-chosen `u64`s
/// (the elastic server uses connection-slot indices plus a listener
/// sentinel) and come back verbatim from [`Poller::wait`].
pub struct Poller {
    imp: Imp,
}

enum Imp {
    #[cfg(target_os = "linux")]
    Epoll(epoll::Epoll),
    #[cfg(target_os = "macos")]
    Kqueue(kqueue::Kqueue),
    /// fds are irrelevant to the fallback: it reports every registration
    Fallback { tokens: Vec<(i32, u64)> },
}

#[cfg(target_os = "linux")]
fn new_native() -> io::Result<Imp> {
    Ok(Imp::Epoll(epoll::Epoll::new()?))
}

#[cfg(target_os = "macos")]
fn new_native() -> io::Result<Imp> {
    Ok(Imp::Kqueue(kqueue::Kqueue::new()?))
}

#[cfg(not(any(target_os = "linux", target_os = "macos")))]
fn new_native() -> io::Result<Imp> {
    Ok(Imp::Fallback { tokens: Vec::new() })
}

impl Poller {
    pub fn new() -> io::Result<Poller> {
        let imp = if fallback_forced() {
            Imp::Fallback { tokens: Vec::new() }
        } else {
            new_native()?
        };
        Ok(Poller { imp })
    }

    /// Watch `fd` for readability, tagging events with `token`. Tokens
    /// must be unique per registration: the fallback backend keys on the
    /// token (its fds may all be the -1 placeholder off unix).
    pub fn register(&mut self, fd: i32, token: u64) -> io::Result<()> {
        match &mut self.imp {
            #[cfg(target_os = "linux")]
            Imp::Epoll(e) => e.add(fd, token),
            #[cfg(target_os = "macos")]
            Imp::Kqueue(k) => k.add(fd, token),
            Imp::Fallback { tokens } => {
                tokens.retain(|(_, t)| *t != token);
                tokens.push((fd, token));
                Ok(())
            }
        }
    }

    /// Stop watching a registration. The kernel backends key on the raw
    /// fd (call this *before* closing it); the fallback keys on `token`.
    pub fn deregister(&mut self, fd: i32, token: u64) -> io::Result<()> {
        match &mut self.imp {
            #[cfg(target_os = "linux")]
            Imp::Epoll(e) => e.del(fd),
            #[cfg(target_os = "macos")]
            Imp::Kqueue(k) => k.del(fd),
            Imp::Fallback { tokens } => {
                let _ = fd; // kernel backends key on it; the fallback doesn't
                tokens.retain(|(_, t)| *t != token);
                Ok(())
            }
        }
    }

    /// Block for at most `min(timeout, ~25ms)` and append the tokens of
    /// possibly-ready sources to `out` (cleared first). An empty `out` is
    /// a pure timeout.
    pub fn wait(&mut self, timeout: Duration, out: &mut Vec<u64>) -> io::Result<()> {
        out.clear();
        let capped = timeout.min(MAX_WAIT);
        match &mut self.imp {
            #[cfg(target_os = "linux")]
            Imp::Epoll(e) => e.wait(capped, out),
            #[cfg(target_os = "macos")]
            Imp::Kqueue(k) => k.wait(capped, out),
            Imp::Fallback { tokens } => {
                // short-deadline polling: sleep a beat, then tell the
                // caller to try everything (nonblocking ops make this
                // correct; the beat bounds the busy-poll rate)
                std::thread::sleep(capped.min(Duration::from_millis(1)));
                out.extend(tokens.iter().map(|(_, t)| *t));
                Ok(())
            }
        }
    }
}

#[cfg(target_os = "linux")]
mod epoll {
    use std::io;
    use std::time::Duration;

    // The kernel ABI packs epoll_event on x86-64 only; aarch64 and
    // friends use natural (8-byte) alignment. Mirrors libc's definition.
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    const EPOLL_CLOEXEC: i32 = 0o2000000;
    const EPOLL_CTL_ADD: i32 = 1;
    const EPOLL_CTL_DEL: i32 = 2;
    const EPOLLIN: u32 = 0x1;
    const EPOLLRDHUP: u32 = 0x2000;

    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32) -> i32;
        fn close(fd: i32) -> i32;
    }

    pub struct Epoll {
        epfd: i32,
        buf: Vec<EpollEvent>,
    }

    impl Epoll {
        pub fn new() -> io::Result<Epoll> {
            // SAFETY: plain syscall, no pointers involved.
            let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if epfd < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Epoll {
                epfd,
                buf: vec![EpollEvent { events: 0, data: 0 }; 64],
            })
        }

        pub fn add(&mut self, fd: i32, token: u64) -> io::Result<()> {
            let mut ev = EpollEvent {
                events: EPOLLIN | EPOLLRDHUP,
                data: token,
            };
            // SAFETY: `ev` is a valid epoll_event for the duration of the
            // call; the kernel copies it before returning.
            if unsafe { epoll_ctl(self.epfd, EPOLL_CTL_ADD, fd, &mut ev) } < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        pub fn del(&mut self, fd: i32) -> io::Result<()> {
            let mut ev = EpollEvent { events: 0, data: 0 };
            // SAFETY: as in `add`; DEL ignores the event but old kernels
            // require a non-null pointer.
            if unsafe { epoll_ctl(self.epfd, EPOLL_CTL_DEL, fd, &mut ev) } < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        pub fn wait(&mut self, timeout: Duration, out: &mut Vec<u64>) -> io::Result<()> {
            let ms = timeout.as_millis().min(i32::MAX as u128) as i32;
            // SAFETY: `buf` is valid for `buf.len()` events and outlives
            // the call; the kernel writes at most `maxevents` entries.
            let n = unsafe {
                epoll_wait(self.epfd, self.buf.as_mut_ptr(), self.buf.len() as i32, ms)
            };
            if n < 0 {
                let e = io::Error::last_os_error();
                if e.kind() == io::ErrorKind::Interrupted {
                    return Ok(()); // EINTR: report a pure timeout
                }
                return Err(e);
            }
            for ev in &self.buf[..n as usize] {
                // copy out of the (possibly packed) struct before use
                let data = ev.data;
                out.push(data);
            }
            Ok(())
        }
    }

    impl Drop for Epoll {
        fn drop(&mut self) {
            // SAFETY: closing the fd we created; nothing else owns it.
            unsafe { close(self.epfd) };
        }
    }
}

#[cfg(target_os = "macos")]
mod kqueue {
    use std::io;
    use std::ptr;
    use std::time::Duration;

    #[repr(C)]
    #[derive(Clone, Copy)]
    struct Kevent {
        ident: usize,
        filter: i16,
        flags: u16,
        fflags: u32,
        data: isize,
        udata: usize,
    }

    #[repr(C)]
    struct Timespec {
        tv_sec: i64,
        tv_nsec: i64,
    }

    const EVFILT_READ: i16 = -1;
    const EV_ADD: u16 = 0x1;
    const EV_DELETE: u16 = 0x2;

    extern "C" {
        fn kqueue() -> i32;
        fn kevent(
            kq: i32,
            changelist: *const Kevent,
            nchanges: i32,
            eventlist: *mut Kevent,
            nevents: i32,
            timeout: *const Timespec,
        ) -> i32;
        fn close(fd: i32) -> i32;
    }

    pub struct Kqueue {
        kq: i32,
        buf: Vec<Kevent>,
    }

    impl Kqueue {
        pub fn new() -> io::Result<Kqueue> {
            // SAFETY: plain syscall.
            let kq = unsafe { kqueue() };
            if kq < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Kqueue {
                kq,
                buf: vec![
                    Kevent {
                        ident: 0,
                        filter: 0,
                        flags: 0,
                        fflags: 0,
                        data: 0,
                        udata: 0,
                    };
                    64
                ],
            })
        }

        fn change(&mut self, fd: i32, flags: u16, token: u64) -> io::Result<()> {
            let ch = Kevent {
                ident: fd as usize,
                filter: EVFILT_READ,
                flags,
                fflags: 0,
                data: 0,
                udata: token as usize,
            };
            // SAFETY: one valid change entry, no event list, no timeout.
            if unsafe { kevent(self.kq, &ch, 1, ptr::null_mut(), 0, ptr::null()) } < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        pub fn add(&mut self, fd: i32, token: u64) -> io::Result<()> {
            self.change(fd, EV_ADD, token)
        }

        pub fn del(&mut self, fd: i32) -> io::Result<()> {
            self.change(fd, EV_DELETE, 0)
        }

        pub fn wait(&mut self, timeout: Duration, out: &mut Vec<u64>) -> io::Result<()> {
            let ts = Timespec {
                tv_sec: timeout.as_secs() as i64,
                tv_nsec: timeout.subsec_nanos() as i64,
            };
            // SAFETY: `buf` valid for `buf.len()` events; `ts` outlives
            // the call.
            let n = unsafe {
                kevent(
                    self.kq,
                    ptr::null(),
                    0,
                    self.buf.as_mut_ptr(),
                    self.buf.len() as i32,
                    &ts,
                )
            };
            if n < 0 {
                let e = io::Error::last_os_error();
                if e.kind() == io::ErrorKind::Interrupted {
                    return Ok(());
                }
                return Err(e);
            }
            for ev in &self.buf[..n as usize] {
                out.push(ev.udata as u64);
            }
            Ok(())
        }
    }

    impl Drop for Kqueue {
        fn drop(&mut self) {
            // SAFETY: closing the fd we created.
            unsafe { close(self.kq) };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::net::{TcpListener, TcpStream};
    #[cfg(unix)]
    use std::os::unix::io::AsRawFd;

    #[cfg(unix)]
    #[test]
    fn reports_readable_socket_and_pure_timeout() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server_side, _) = listener.accept().unwrap();
        server_side.set_nonblocking(true).unwrap();

        let mut p = Poller::new().unwrap();
        p.register(server_side.as_raw_fd(), 7).unwrap();

        let mut out = Vec::new();
        // nothing written yet: kernel backends report a pure timeout (the
        // fallback reports token 7 as a may-be-ready hint — both valid)
        p.wait(Duration::from_millis(5), &mut out).unwrap();

        client.write_all(b"x").unwrap();
        client.flush().unwrap();
        // now token 7 must show up within a bounded number of waits
        let mut seen = false;
        for _ in 0..200 {
            p.wait(Duration::from_millis(25), &mut out).unwrap();
            if out.contains(&7) {
                seen = true;
                break;
            }
        }
        assert!(seen, "readable socket never reported");

        p.deregister(server_side.as_raw_fd(), 7).unwrap();
        p.wait(Duration::from_millis(1), &mut out).unwrap();
        assert!(!out.contains(&7), "deregistered fd still reported");
    }
}
