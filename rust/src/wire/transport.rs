//! Framed byte transports: in-process loopback and length-prefixed TCP.
//!
//! A [`Transport`] is one bidirectional, ordered channel between the
//! coordinator and a worker *process*; frames are whole message bodies
//! (see [`codec`](crate::wire::codec) for their layout). The TCP
//! implementation prefixes each body with its `u32` little-endian length —
//! the same [`FRAME_PREFIX`](crate::wire::codec::FRAME_PREFIX) bytes the
//! measured-byte accounting includes, so `bytes_up`/`bytes_down` equal
//! what actually crosses the socket.

use std::io::{self, Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::mpsc;
use std::time::Duration;

/// Refuse frames above this size (a corrupt length prefix must not drive
/// a huge allocation). Far above any real message: a dense f64 downlink
/// at d = 10⁷ is 80 MB.
const MAX_FRAME: usize = 1 << 30;

/// One framed, ordered, bidirectional byte channel.
pub trait Transport: Send {
    /// Send one frame body.
    fn send(&mut self, body: &[u8]) -> io::Result<()>;

    /// Receive one frame body into `body` (cleared and refilled, capacity
    /// reused). Errors with `UnexpectedEof` when the peer is gone.
    fn recv(&mut self, body: &mut Vec<u8>) -> io::Result<()>;
}

// ---- loopback ----------------------------------------------------------

/// In-process transport endpoint: a pair of mpsc channels moving owned
/// frame buffers. The reference transport for tests and benches — same
/// protocol, zero I/O noise.
pub struct Loopback {
    tx: mpsc::Sender<Vec<u8>>,
    rx: mpsc::Receiver<Vec<u8>>,
}

/// Two connected [`Loopback`] endpoints.
pub fn loopback_pair() -> (Loopback, Loopback) {
    let (atx, brx) = mpsc::channel();
    let (btx, arx) = mpsc::channel();
    (Loopback { tx: atx, rx: arx }, Loopback { tx: btx, rx: brx })
}

impl Transport for Loopback {
    fn send(&mut self, body: &[u8]) -> io::Result<()> {
        self.tx
            .send(body.to_vec())
            .map_err(|_| io::Error::new(io::ErrorKind::BrokenPipe, "loopback peer gone"))
    }

    fn recv(&mut self, body: &mut Vec<u8>) -> io::Result<()> {
        match self.rx.recv() {
            Ok(frame) => {
                // the channel hands over an owned buffer — move it, don't copy
                *body = frame;
                Ok(())
            }
            Err(_) => Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "loopback peer gone",
            )),
        }
    }
}

// ---- TCP ---------------------------------------------------------------

/// Length-prefixed TCP transport (`std::net`, `TCP_NODELAY`, buffered
/// writes flushed per frame).
pub struct Tcp {
    reader: io::BufReader<TcpStream>,
    writer: io::BufWriter<TcpStream>,
}

impl Tcp {
    /// Wrap an accepted/connected stream.
    pub fn new(stream: TcpStream) -> io::Result<Tcp> {
        stream.set_nodelay(true)?;
        let write_half = stream.try_clone()?;
        Ok(Tcp {
            reader: io::BufReader::new(stream),
            writer: io::BufWriter::new(write_half),
        })
    }

    pub fn connect<A: ToSocketAddrs>(addr: A) -> io::Result<Tcp> {
        Tcp::new(TcpStream::connect(addr)?)
    }

    /// Connect with retries — workers typically race the server's bind.
    pub fn connect_retry<A: ToSocketAddrs + Clone>(
        addr: A,
        attempts: u32,
        delay: Duration,
    ) -> io::Result<Tcp> {
        let attempts = attempts.max(1);
        let mut last = None;
        for attempt in 0..attempts {
            match Tcp::connect(addr.clone()) {
                Ok(t) => return Ok(t),
                Err(e) => {
                    last = Some(e);
                    if attempt + 1 < attempts {
                        std::thread::sleep(delay);
                    }
                }
            }
        }
        Err(last.unwrap_or_else(|| io::Error::new(io::ErrorKind::TimedOut, "no attempts")))
    }
}

impl Transport for Tcp {
    fn send(&mut self, body: &[u8]) -> io::Result<()> {
        let len = u32::try_from(body.len())
            .map_err(|_| io::Error::new(io::ErrorKind::InvalidInput, "frame too large"))?;
        self.writer.write_all(&len.to_le_bytes())?;
        self.writer.write_all(body)?;
        self.writer.flush()
    }

    fn recv(&mut self, body: &mut Vec<u8>) -> io::Result<()> {
        let mut len_bytes = [0u8; 4];
        self.reader.read_exact(&mut len_bytes)?;
        let len = u32::from_le_bytes(len_bytes) as usize;
        if len > MAX_FRAME {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("frame length {len} exceeds cap"),
            ));
        }
        // resize alone suffices: read_exact overwrites body[..len], so the
        // zero-fill only touches growth beyond the previous length
        body.resize(len, 0);
        self.reader.read_exact(body)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loopback_roundtrip_and_eof() {
        let (mut a, mut b) = loopback_pair();
        a.send(&[1, 2, 3]).unwrap();
        a.send(&[]).unwrap();
        let mut buf = vec![9; 16];
        b.recv(&mut buf).unwrap();
        assert_eq!(buf, vec![1, 2, 3]);
        b.recv(&mut buf).unwrap();
        assert!(buf.is_empty());
        drop(a);
        assert_eq!(
            b.recv(&mut buf).unwrap_err().kind(),
            io::ErrorKind::UnexpectedEof
        );
    }

    #[test]
    fn tcp_roundtrip_over_localhost() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut t = Tcp::new(stream).unwrap();
            let mut buf = Vec::new();
            t.recv(&mut buf).unwrap();
            // echo twice to exercise framing boundaries
            t.send(&buf).unwrap();
            t.send(&[0xAB]).unwrap();
        });
        let mut c = Tcp::connect_retry(addr, 20, Duration::from_millis(50)).unwrap();
        let payload: Vec<u8> = (0..300).map(|i| (i % 251) as u8).collect();
        c.send(&payload).unwrap();
        let mut buf = Vec::new();
        c.recv(&mut buf).unwrap();
        assert_eq!(buf, payload);
        c.recv(&mut buf).unwrap();
        assert_eq!(buf, vec![0xAB]);
        // peer closed → EOF
        assert!(c.recv(&mut buf).is_err());
        server.join().unwrap();
    }
}
