//! Framed byte transports: in-process loopback and length-prefixed TCP.
//!
//! A [`Transport`] is one bidirectional, ordered channel between the
//! coordinator and a worker *process*; frames are whole message bodies
//! (see [`codec`](crate::wire::codec) for their layout). The TCP
//! implementation prefixes each body with its `u32` little-endian length —
//! the same [`FRAME_PREFIX`](crate::wire::codec::FRAME_PREFIX) bytes the
//! measured-byte accounting includes, so `bytes_up`/`bytes_down` equal
//! what actually crosses the socket.
//!
//! # Frame integrity (CRC32 trailer)
//!
//! With [`Tcp::set_crc`] enabled (the default for `smx serve` runs via
//! `wire.crc`), each sent frame sets [`FRAME_CRC_FLAG`] — the top bit of
//! the length prefix, which a plain length can never carry because
//! [`MAX_FRAME`] caps real lengths well below it — and appends a 4-byte
//! little-endian [`crc32`] of the body. Receivers are self-describing:
//! a flagged frame is always verified and stripped, an unflagged frame
//! is passed through, so old and new senders interoperate frame by
//! frame. A pre-CRC receiver sees a flagged prefix as an over-cap length
//! and fails with `InvalidData` — the deliberate version bump. A CRC
//! mismatch also surfaces as `InvalidData`: the elastic server treats it
//! like a connection death, and the reconnect path retransmits the
//! journaled frames, turning silent corruption into a detected,
//! replayable event. The trailer (like the heartbeats) is protocol
//! overhead, excluded from the `bytes_up`/`bytes_down` accounting.
//!
//! The pure [`encode_frame`]/[`decode_frame`] helpers implement exactly
//! the on-wire framing without touching a socket; they are what the fuzz
//! suite (and Miri) exercise, and what the durable run log reuses for
//! its CRC-guarded records.
//!
//! [`Tcp`] owns its reassembly state (a rolling receive buffer instead of
//! a `BufReader`), which lets the same endpoint serve both blocking use
//! (workers, the loopback-style drivers) and the elastic server's
//! **nonblocking** use: after [`Tcp::set_nonblocking`], [`Tcp::try_recv`]
//! consumes whatever bytes the kernel has — possibly a partial frame,
//! possibly several frames — and reports complete frames one at a time
//! without ever blocking, which is what the
//! [`poll`](crate::wire::poll) readiness loop needs.

use std::io::{self, Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::mpsc;
use std::time::Duration;

/// Refuse frames above this size (a corrupt length prefix must not drive
/// a huge allocation). Far above any real message: a dense f64 downlink
/// at d = 10⁷ is 80 MB. Doubles as the guarantee that real lengths never
/// collide with [`FRAME_CRC_FLAG`] in the prefix.
pub const MAX_FRAME: usize = 1 << 30;

/// Top bit of the `u32` length prefix: set ⇔ the frame carries a 4-byte
/// CRC32 trailer after the body.
pub const FRAME_CRC_FLAG: u32 = 1 << 31;

/// Retain at most this much receive-buffer capacity once fully drained;
/// one oversized frame (a dense downlink at large d) must not pin its
/// peak footprint for the rest of the run (bounded per connection, not
/// per run).
const RBUF_RETAIN: usize = 256 * 1024;

/// CRC-32 lookup table (IEEE 802.3, reflected polynomial `0xEDB88320`),
/// generated at compile time.
const CRC_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// CRC-32 (IEEE 802.3) of `data`; `crc32(b"123456789") == 0xCBF43926`.
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// Encode one frame exactly as [`Tcp`] puts it on the wire: `u32` LE
/// length prefix (with [`FRAME_CRC_FLAG`] set when `crc`), the body, and
/// — when `crc` — the 4-byte LE [`crc32`] trailer of the body.
///
/// Panics if `body` exceeds [`MAX_FRAME`] (callers frame codec bodies,
/// which are bounded far below it).
pub fn encode_frame(body: &[u8], crc: bool) -> Vec<u8> {
    assert!(body.len() <= MAX_FRAME, "frame body exceeds MAX_FRAME");
    let mut out = Vec::with_capacity(4 + body.len() + if crc { 4 } else { 0 });
    let mut prefix = body.len() as u32;
    if crc {
        prefix |= FRAME_CRC_FLAG;
    }
    out.extend_from_slice(&prefix.to_le_bytes());
    out.extend_from_slice(body);
    if crc {
        out.extend_from_slice(&crc32(body).to_le_bytes());
    }
    out
}

/// Parse one frame from the front of `buf`. Returns
/// `Ok(Some((consumed, had_crc)))` with `body` refilled when a complete
/// frame is present — CRC verified and stripped if flagged — and
/// `Ok(None)` when more bytes are needed. `Err(InvalidData)` on an
/// over-[`MAX_FRAME`] length or a CRC mismatch (a truncation can never
/// be mistaken for success: it parses as "more bytes needed").
pub fn decode_frame(buf: &[u8], body: &mut Vec<u8>) -> io::Result<Option<(usize, bool)>> {
    if buf.len() < 4 {
        return Ok(None);
    }
    let prefix = u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]);
    let had_crc = prefix & FRAME_CRC_FLAG != 0;
    let len = (prefix & !FRAME_CRC_FLAG) as usize;
    if len > MAX_FRAME {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame length {len} exceeds cap"),
        ));
    }
    let total = 4 + len + if had_crc { 4 } else { 0 };
    if buf.len() < total {
        return Ok(None);
    }
    let data = &buf[4..4 + len];
    if had_crc {
        let want = u32::from_le_bytes([buf[4 + len], buf[5 + len], buf[6 + len], buf[7 + len]]);
        let got = crc32(data);
        if got != want {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("frame CRC mismatch: computed {got:#010x}, trailer {want:#010x}"),
            ));
        }
    }
    body.clear();
    body.extend_from_slice(data);
    Ok(Some((total, had_crc)))
}

/// Give up on a nonblocking send that makes no progress for this long
/// (peer alive-but-stalled: SIGSTOPped, wedged, or reading nothing while
/// its receive window fills). Surfaces as `TimedOut`, which the elastic
/// server treats like any other connection death — bounding how long one
/// stalled worker can wedge the single-threaded server loop.
const SEND_STALL_TIMEOUT: Duration = Duration::from_secs(30);

/// One framed, ordered, bidirectional byte channel.
pub trait Transport: Send {
    /// Send one frame body.
    fn send(&mut self, body: &[u8]) -> io::Result<()>;

    /// Receive one frame body into `body` (cleared and refilled, capacity
    /// reused). Errors with `UnexpectedEof` when the peer is gone.
    fn recv(&mut self, body: &mut Vec<u8>) -> io::Result<()>;
}

// ---- loopback ----------------------------------------------------------

/// In-process transport endpoint: a pair of mpsc channels moving owned
/// frame buffers. The reference transport for tests and benches — same
/// protocol, zero I/O noise.
pub struct Loopback {
    tx: mpsc::Sender<Vec<u8>>,
    rx: mpsc::Receiver<Vec<u8>>,
}

/// Two connected [`Loopback`] endpoints.
pub fn loopback_pair() -> (Loopback, Loopback) {
    let (atx, brx) = mpsc::channel();
    let (btx, arx) = mpsc::channel();
    (Loopback { tx: atx, rx: arx }, Loopback { tx: btx, rx: brx })
}

impl Transport for Loopback {
    fn send(&mut self, body: &[u8]) -> io::Result<()> {
        self.tx
            .send(body.to_vec())
            .map_err(|_| io::Error::new(io::ErrorKind::BrokenPipe, "loopback peer gone"))
    }

    fn recv(&mut self, body: &mut Vec<u8>) -> io::Result<()> {
        match self.rx.recv() {
            Ok(frame) => {
                // the channel hands over an owned buffer — move it, don't copy
                *body = frame;
                Ok(())
            }
            Err(_) => Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "loopback peer gone",
            )),
        }
    }
}

// ---- TCP ---------------------------------------------------------------

/// Length-prefixed TCP transport (`std::net`, `TCP_NODELAY`).
///
/// Reads accumulate in an internal rolling buffer; a frame is surfaced
/// once its 4-byte length prefix *and* full body have arrived. In
/// blocking mode `recv` loops on the socket until that happens; in
/// nonblocking mode `try_recv` returns `Ok(false)` instead of waiting.
/// Writes always complete the whole frame: in nonblocking mode a
/// `WouldBlock` from a full socket buffer is retried after a short yield
/// (broadcast frames are small relative to socket buffers, so this path
/// is cold).
pub struct Tcp {
    stream: TcpStream,
    /// received-but-unparsed bytes; `rpos..` is the live region
    rbuf: Vec<u8>,
    rpos: usize,
    /// fixed scratch for one kernel read
    chunk: Box<[u8; 64 * 1024]>,
    /// append a CRC32 trailer (+ prefix flag) to every sent frame
    crc_send: bool,
    /// a CRC-flagged frame has been received — workers mirror the
    /// server's choice from this
    crc_seen: bool,
    /// fault injection: XOR this bit into the next sent frame's body
    /// *after* the CRC is computed (on-wire corruption the receiver's
    /// check genuinely detects)
    corrupt_next: Option<u64>,
}

impl Tcp {
    /// Wrap an accepted/connected stream (blocking mode).
    pub fn new(stream: TcpStream) -> io::Result<Tcp> {
        stream.set_nodelay(true)?;
        Ok(Tcp {
            stream,
            rbuf: Vec::new(),
            rpos: 0,
            chunk: Box::new([0u8; 64 * 1024]),
            crc_send: false,
            crc_seen: false,
            corrupt_next: None,
        })
    }

    /// Enable/disable the CRC32 trailer on *sent* frames. Reception is
    /// self-describing (the prefix flag), so this only shapes what the
    /// peer sees.
    pub fn set_crc(&mut self, on: bool) {
        self.crc_send = on;
    }

    /// Whether any received frame carried the CRC flag — the worker's
    /// cue to mirror the server and CRC its own uplinks.
    pub fn crc_seen(&self) -> bool {
        self.crc_seen
    }

    /// Fault injection ([`FaultPlan`](crate::wire::FaultPlan)): flip one
    /// bit of the next sent frame's body on the wire, *after* the CRC
    /// trailer is computed. `bit` selects position (mod body length), so
    /// a seeded plan corrupts a reproducible bit.
    pub fn corrupt_next_frame(&mut self, bit: u64) {
        self.corrupt_next = Some(bit);
    }

    pub fn connect<A: ToSocketAddrs>(addr: A) -> io::Result<Tcp> {
        Tcp::new(TcpStream::connect(addr)?)
    }

    /// Connect with retries — workers typically race the server's bind.
    pub fn connect_retry<A: ToSocketAddrs + Clone>(
        addr: A,
        attempts: u32,
        delay: Duration,
    ) -> io::Result<Tcp> {
        let attempts = attempts.max(1);
        let mut last = None;
        for attempt in 0..attempts {
            match Tcp::connect(addr.clone()) {
                Ok(t) => return Ok(t),
                Err(e) => {
                    last = Some(e);
                    if attempt + 1 < attempts {
                        std::thread::sleep(delay);
                    }
                }
            }
        }
        Err(last.unwrap_or_else(|| io::Error::new(io::ErrorKind::TimedOut, "no attempts")))
    }

    /// Switch the socket between blocking and nonblocking mode. The
    /// elastic server flips its connections to nonblocking and drives
    /// them through [`Tcp::try_recv`] under the readiness poller.
    pub fn set_nonblocking(&mut self, nonblocking: bool) -> io::Result<()> {
        self.stream.set_nonblocking(nonblocking)
    }

    /// Raw socket fd for readiness registration (unix only).
    #[cfg(unix)]
    pub fn raw_fd(&self) -> i32 {
        use std::os::unix::io::AsRawFd;
        self.stream.as_raw_fd()
    }

    /// Peer address (diagnostics).
    pub fn peer_addr(&self) -> io::Result<std::net::SocketAddr> {
        self.stream.peer_addr()
    }

    /// Extract one complete frame from the rolling buffer, if present
    /// (CRC verified + stripped when the prefix is flagged).
    fn take_frame(&mut self, body: &mut Vec<u8>) -> io::Result<bool> {
        match decode_frame(&self.rbuf[self.rpos..], body)? {
            Some((consumed, had_crc)) => {
                if had_crc {
                    self.crc_seen = true;
                }
                self.rpos += consumed;
                if self.rpos == self.rbuf.len() {
                    // buffer fully drained: reset in place, keeping at
                    // most RBUF_RETAIN of capacity so one oversized frame
                    // doesn't pin its footprint for the rest of the run
                    self.rbuf.clear();
                    self.rpos = 0;
                    if self.rbuf.capacity() > RBUF_RETAIN {
                        self.rbuf.shrink_to(RBUF_RETAIN);
                    }
                }
                Ok(true)
            }
            None => Ok(false),
        }
    }

    /// One kernel read into the rolling buffer. `Ok(0)` is EOF; maps a
    /// clean-shutdown reset to `UnexpectedEof` like the blocking path.
    fn fill(&mut self) -> io::Result<usize> {
        // compact lazily so the buffer doesn't creep when frames straddle
        // reads (cheap: the live region is at most one partial frame)
        if self.rpos > 0 {
            let len = self.rbuf.len();
            self.rbuf.copy_within(self.rpos..len, 0);
            self.rbuf.truncate(len - self.rpos);
            self.rpos = 0;
        }
        let n = self.stream.read(&mut self.chunk[..])?;
        self.rbuf.extend_from_slice(&self.chunk[..n]);
        Ok(n)
    }

    /// Nonblocking receive: `Ok(true)` with `body` filled when a complete
    /// frame was available, `Ok(false)` when the socket has no complete
    /// frame yet (`WouldBlock` is absorbed). EOF from the peer surfaces
    /// as `UnexpectedEof`.
    pub fn try_recv(&mut self, body: &mut Vec<u8>) -> io::Result<bool> {
        loop {
            if self.take_frame(body)? {
                return Ok(true);
            }
            match self.fill() {
                Ok(0) => {
                    return Err(io::Error::new(
                        io::ErrorKind::UnexpectedEof,
                        "peer closed connection",
                    ))
                }
                Ok(_) => continue,
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return Ok(false),
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
    }
}

impl Transport for Tcp {
    fn send(&mut self, body: &[u8]) -> io::Result<()> {
        if body.len() > MAX_FRAME {
            return Err(io::Error::new(io::ErrorKind::InvalidInput, "frame too large"));
        }
        let mut len = body.len() as u32;
        if self.crc_send {
            len |= FRAME_CRC_FLAG;
        }
        let prefix = len.to_le_bytes();
        // trailer computed from the *uncorrupted* body: an injected
        // bit-flip below is on-wire corruption the peer's check detects
        let trailer = crc32(body).to_le_bytes();
        let flipped;
        let wire_body: &[u8] = match self.corrupt_next.take() {
            Some(bit) if !body.is_empty() => {
                let mut c = body.to_vec();
                let pos = (bit / 8) as usize % c.len();
                c[pos] ^= 1 << (bit % 8);
                flipped = c;
                &flipped
            }
            _ => body,
        };
        let tail: &[u8] = if self.crc_send { &trailer } else { &[] };
        // write prefix + body (+ trailer) fully, absorbing WouldBlock in
        // nonblocking mode (the readiness loop never leaves a frame
        // half-sent) — but only while the peer keeps draining: a
        // no-progress stall past SEND_STALL_TIMEOUT errors out so the
        // server can declare the connection dead instead of wedging
        // forever
        let mut last_progress = std::time::Instant::now();
        for part in [&prefix[..], wire_body, tail] {
            let mut off = 0usize;
            while off < part.len() {
                match self.stream.write(&part[off..]) {
                    Ok(0) => {
                        return Err(io::Error::new(
                            io::ErrorKind::WriteZero,
                            "socket accepted no bytes",
                        ))
                    }
                    Ok(n) => {
                        off += n;
                        last_progress = std::time::Instant::now();
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                        if last_progress.elapsed() > SEND_STALL_TIMEOUT {
                            return Err(io::Error::new(
                                io::ErrorKind::TimedOut,
                                "peer stopped draining its socket",
                            ));
                        }
                        std::thread::sleep(Duration::from_micros(200));
                    }
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                    Err(e) => return Err(e),
                }
            }
        }
        self.stream.flush()
    }

    fn recv(&mut self, body: &mut Vec<u8>) -> io::Result<()> {
        loop {
            if self.take_frame(body)? {
                return Ok(());
            }
            match self.fill() {
                Ok(0) => {
                    return Err(io::Error::new(
                        io::ErrorKind::UnexpectedEof,
                        "peer closed connection",
                    ))
                }
                Ok(_) => {}
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    // blocking recv on a nonblocking socket: degrade to a
                    // short-deadline poll instead of spinning
                    std::thread::sleep(Duration::from_micros(200));
                }
                Err(e) => return Err(e),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loopback_roundtrip_and_eof() {
        let (mut a, mut b) = loopback_pair();
        a.send(&[1, 2, 3]).unwrap();
        a.send(&[]).unwrap();
        let mut buf = vec![9; 16];
        b.recv(&mut buf).unwrap();
        assert_eq!(buf, vec![1, 2, 3]);
        b.recv(&mut buf).unwrap();
        assert!(buf.is_empty());
        drop(a);
        assert_eq!(
            b.recv(&mut buf).unwrap_err().kind(),
            io::ErrorKind::UnexpectedEof
        );
    }

    #[test]
    fn tcp_roundtrip_over_localhost() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut t = Tcp::new(stream).unwrap();
            let mut buf = Vec::new();
            t.recv(&mut buf).unwrap();
            // echo twice to exercise framing boundaries
            t.send(&buf).unwrap();
            t.send(&[0xAB]).unwrap();
        });
        let mut c = Tcp::connect_retry(addr, 20, Duration::from_millis(50)).unwrap();
        let payload: Vec<u8> = (0..300).map(|i| (i % 251) as u8).collect();
        c.send(&payload).unwrap();
        let mut buf = Vec::new();
        c.recv(&mut buf).unwrap();
        assert_eq!(buf, payload);
        c.recv(&mut buf).unwrap();
        assert_eq!(buf, vec![0xAB]);
        // peer closed → EOF
        assert!(c.recv(&mut buf).is_err());
        server.join().unwrap();
    }

    #[test]
    fn tcp_try_recv_reassembles_split_and_batched_frames() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.set_nodelay(true).unwrap();
            // frame 1 split across two writes at an awkward boundary,
            // then frames 2+3 coalesced into a single write
            let f1: Vec<u8> = (0..100u8).collect();
            let mut w1 = (f1.len() as u32).to_le_bytes().to_vec();
            w1.extend_from_slice(&f1[..37]);
            s.write_all(&w1).unwrap();
            s.flush().unwrap();
            std::thread::sleep(Duration::from_millis(30));
            let mut w2 = f1[37..].to_vec();
            w2.extend_from_slice(&3u32.to_le_bytes());
            w2.extend_from_slice(&[9, 8, 7]);
            w2.extend_from_slice(&0u32.to_le_bytes()); // empty frame
            s.write_all(&w2).unwrap();
            s.flush().unwrap();
            // hold the socket open until the server is done reading
            let mut ack = [0u8; 1];
            let _ = s.read(&mut ack);
        });

        let (stream, _) = listener.accept().unwrap();
        let mut t = Tcp::new(stream).unwrap();
        t.set_nonblocking(true).unwrap();
        let mut body = Vec::new();
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        let mut frames: Vec<Vec<u8>> = Vec::new();
        while frames.len() < 3 {
            assert!(std::time::Instant::now() < deadline, "timed out");
            match t.try_recv(&mut body).unwrap() {
                true => frames.push(body.clone()),
                false => std::thread::sleep(Duration::from_millis(1)),
            }
        }
        assert_eq!(frames[0], (0..100u8).collect::<Vec<u8>>());
        assert_eq!(frames[1], vec![9, 8, 7]);
        assert!(frames[2].is_empty());
        // nothing further: try_recv idles without blocking
        assert!(!t.try_recv(&mut body).unwrap());
        t.send(&[1]).unwrap(); // release the client
        client.join().unwrap();
    }

    #[test]
    fn crc32_known_answer_and_frame_helpers() {
        // the IEEE 802.3 check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);

        for crc in [false, true] {
            let body: Vec<u8> = (0..57u8).collect();
            let frame = encode_frame(&body, crc);
            assert_eq!(frame.len(), 4 + body.len() + if crc { 4 } else { 0 });
            let mut dec = Vec::new();
            let (consumed, had_crc) = decode_frame(&frame, &mut dec).unwrap().unwrap();
            assert_eq!((consumed, had_crc), (frame.len(), crc));
            assert_eq!(dec, body);
            // every strict prefix is "need more bytes", never success
            for cut in 0..frame.len() {
                assert!(decode_frame(&frame[..cut], &mut dec).unwrap().is_none());
            }
        }
        // a flagged frame with any body bit flipped is *detected*
        let frame = encode_frame(&[1, 2, 3, 4], true);
        for byte in 4..8 {
            for bit in 0..8 {
                let mut bad = frame.clone();
                bad[byte] ^= 1 << bit;
                let mut dec = Vec::new();
                let e = decode_frame(&bad, &mut dec).unwrap_err();
                assert_eq!(e.kind(), io::ErrorKind::InvalidData);
            }
        }
    }

    #[test]
    fn tcp_crc_roundtrip_mirroring_and_corruption_detection() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut t = Tcp::new(stream).unwrap();
            t.set_crc(true);
            t.send(&[10, 20, 30]).unwrap();
            let mut buf = Vec::new();
            t.recv(&mut buf).unwrap(); // mirrored (CRC'd) echo
            assert_eq!(buf, vec![10, 20, 30]);
            assert!(t.crc_seen(), "client should have mirrored the CRC flag");
            // now corrupt a frame on the wire: the peer must detect it
            t.corrupt_next_frame(0x1D);
            t.send(&[7; 64]).unwrap();
        });
        let mut c = Tcp::connect_retry(addr, 20, Duration::from_millis(50)).unwrap();
        let mut buf = Vec::new();
        c.recv(&mut buf).unwrap();
        assert_eq!(buf, vec![10, 20, 30]);
        // worker-style mirroring: enable CRC once the server shows it
        assert!(c.crc_seen());
        c.set_crc(true);
        c.send(&[10, 20, 30]).unwrap();
        let e = c.recv(&mut buf).unwrap_err();
        assert_eq!(e.kind(), io::ErrorKind::InvalidData, "bit flip must be detected: {e}");
        server.join().unwrap();
    }

    #[test]
    fn tcp_huge_length_prefix_is_rejected() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(&u32::MAX.to_le_bytes()).unwrap();
            s.flush().unwrap();
            std::thread::sleep(Duration::from_millis(100));
        });
        let (stream, _) = listener.accept().unwrap();
        let mut t = Tcp::new(stream).unwrap();
        let mut body = Vec::new();
        let e = t.recv(&mut body).unwrap_err();
        assert_eq!(e.kind(), io::ErrorKind::InvalidData);
        client.join().unwrap();
    }
}
