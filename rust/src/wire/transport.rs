//! Framed byte transports: in-process loopback and length-prefixed TCP.
//!
//! A [`Transport`] is one bidirectional, ordered channel between the
//! coordinator and a worker *process*; frames are whole message bodies
//! (see [`codec`](crate::wire::codec) for their layout). The TCP
//! implementation prefixes each body with its `u32` little-endian length —
//! the same [`FRAME_PREFIX`](crate::wire::codec::FRAME_PREFIX) bytes the
//! measured-byte accounting includes, so `bytes_up`/`bytes_down` equal
//! what actually crosses the socket.
//!
//! [`Tcp`] owns its reassembly state (a rolling receive buffer instead of
//! a `BufReader`), which lets the same endpoint serve both blocking use
//! (workers, the loopback-style drivers) and the elastic server's
//! **nonblocking** use: after [`Tcp::set_nonblocking`], [`Tcp::try_recv`]
//! consumes whatever bytes the kernel has — possibly a partial frame,
//! possibly several frames — and reports complete frames one at a time
//! without ever blocking, which is what the
//! [`poll`](crate::wire::poll) readiness loop needs.

use std::io::{self, Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::mpsc;
use std::time::Duration;

/// Refuse frames above this size (a corrupt length prefix must not drive
/// a huge allocation). Far above any real message: a dense f64 downlink
/// at d = 10⁷ is 80 MB.
const MAX_FRAME: usize = 1 << 30;

/// Give up on a nonblocking send that makes no progress for this long
/// (peer alive-but-stalled: SIGSTOPped, wedged, or reading nothing while
/// its receive window fills). Surfaces as `TimedOut`, which the elastic
/// server treats like any other connection death — bounding how long one
/// stalled worker can wedge the single-threaded server loop.
const SEND_STALL_TIMEOUT: Duration = Duration::from_secs(30);

/// One framed, ordered, bidirectional byte channel.
pub trait Transport: Send {
    /// Send one frame body.
    fn send(&mut self, body: &[u8]) -> io::Result<()>;

    /// Receive one frame body into `body` (cleared and refilled, capacity
    /// reused). Errors with `UnexpectedEof` when the peer is gone.
    fn recv(&mut self, body: &mut Vec<u8>) -> io::Result<()>;
}

// ---- loopback ----------------------------------------------------------

/// In-process transport endpoint: a pair of mpsc channels moving owned
/// frame buffers. The reference transport for tests and benches — same
/// protocol, zero I/O noise.
pub struct Loopback {
    tx: mpsc::Sender<Vec<u8>>,
    rx: mpsc::Receiver<Vec<u8>>,
}

/// Two connected [`Loopback`] endpoints.
pub fn loopback_pair() -> (Loopback, Loopback) {
    let (atx, brx) = mpsc::channel();
    let (btx, arx) = mpsc::channel();
    (Loopback { tx: atx, rx: arx }, Loopback { tx: btx, rx: brx })
}

impl Transport for Loopback {
    fn send(&mut self, body: &[u8]) -> io::Result<()> {
        self.tx
            .send(body.to_vec())
            .map_err(|_| io::Error::new(io::ErrorKind::BrokenPipe, "loopback peer gone"))
    }

    fn recv(&mut self, body: &mut Vec<u8>) -> io::Result<()> {
        match self.rx.recv() {
            Ok(frame) => {
                // the channel hands over an owned buffer — move it, don't copy
                *body = frame;
                Ok(())
            }
            Err(_) => Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "loopback peer gone",
            )),
        }
    }
}

// ---- TCP ---------------------------------------------------------------

/// Length-prefixed TCP transport (`std::net`, `TCP_NODELAY`).
///
/// Reads accumulate in an internal rolling buffer; a frame is surfaced
/// once its 4-byte length prefix *and* full body have arrived. In
/// blocking mode `recv` loops on the socket until that happens; in
/// nonblocking mode `try_recv` returns `Ok(false)` instead of waiting.
/// Writes always complete the whole frame: in nonblocking mode a
/// `WouldBlock` from a full socket buffer is retried after a short yield
/// (broadcast frames are small relative to socket buffers, so this path
/// is cold).
pub struct Tcp {
    stream: TcpStream,
    /// received-but-unparsed bytes; `rpos..` is the live region
    rbuf: Vec<u8>,
    rpos: usize,
    /// fixed scratch for one kernel read
    chunk: Box<[u8; 64 * 1024]>,
}

impl Tcp {
    /// Wrap an accepted/connected stream (blocking mode).
    pub fn new(stream: TcpStream) -> io::Result<Tcp> {
        stream.set_nodelay(true)?;
        Ok(Tcp {
            stream,
            rbuf: Vec::new(),
            rpos: 0,
            chunk: Box::new([0u8; 64 * 1024]),
        })
    }

    pub fn connect<A: ToSocketAddrs>(addr: A) -> io::Result<Tcp> {
        Tcp::new(TcpStream::connect(addr)?)
    }

    /// Connect with retries — workers typically race the server's bind.
    pub fn connect_retry<A: ToSocketAddrs + Clone>(
        addr: A,
        attempts: u32,
        delay: Duration,
    ) -> io::Result<Tcp> {
        let attempts = attempts.max(1);
        let mut last = None;
        for attempt in 0..attempts {
            match Tcp::connect(addr.clone()) {
                Ok(t) => return Ok(t),
                Err(e) => {
                    last = Some(e);
                    if attempt + 1 < attempts {
                        std::thread::sleep(delay);
                    }
                }
            }
        }
        Err(last.unwrap_or_else(|| io::Error::new(io::ErrorKind::TimedOut, "no attempts")))
    }

    /// Switch the socket between blocking and nonblocking mode. The
    /// elastic server flips its connections to nonblocking and drives
    /// them through [`Tcp::try_recv`] under the readiness poller.
    pub fn set_nonblocking(&mut self, nonblocking: bool) -> io::Result<()> {
        self.stream.set_nonblocking(nonblocking)
    }

    /// Raw socket fd for readiness registration (unix only).
    #[cfg(unix)]
    pub fn raw_fd(&self) -> i32 {
        use std::os::unix::io::AsRawFd;
        self.stream.as_raw_fd()
    }

    /// Peer address (diagnostics).
    pub fn peer_addr(&self) -> io::Result<std::net::SocketAddr> {
        self.stream.peer_addr()
    }

    /// Extract one complete frame from the rolling buffer, if present.
    fn take_frame(&mut self, body: &mut Vec<u8>) -> io::Result<bool> {
        let avail = self.rbuf.len() - self.rpos;
        if avail < 4 {
            return Ok(false);
        }
        let p = &self.rbuf[self.rpos..self.rpos + 4];
        let len = u32::from_le_bytes([p[0], p[1], p[2], p[3]]) as usize;
        if len > MAX_FRAME {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("frame length {len} exceeds cap"),
            ));
        }
        if avail < 4 + len {
            return Ok(false);
        }
        body.clear();
        body.extend_from_slice(&self.rbuf[self.rpos + 4..self.rpos + 4 + len]);
        self.rpos += 4 + len;
        if self.rpos == self.rbuf.len() {
            // buffer fully drained: reset in place, keep the capacity
            self.rbuf.clear();
            self.rpos = 0;
        }
        Ok(true)
    }

    /// One kernel read into the rolling buffer. `Ok(0)` is EOF; maps a
    /// clean-shutdown reset to `UnexpectedEof` like the blocking path.
    fn fill(&mut self) -> io::Result<usize> {
        // compact lazily so the buffer doesn't creep when frames straddle
        // reads (cheap: the live region is at most one partial frame)
        if self.rpos > 0 {
            let len = self.rbuf.len();
            self.rbuf.copy_within(self.rpos..len, 0);
            self.rbuf.truncate(len - self.rpos);
            self.rpos = 0;
        }
        let n = self.stream.read(&mut self.chunk[..])?;
        self.rbuf.extend_from_slice(&self.chunk[..n]);
        Ok(n)
    }

    /// Nonblocking receive: `Ok(true)` with `body` filled when a complete
    /// frame was available, `Ok(false)` when the socket has no complete
    /// frame yet (`WouldBlock` is absorbed). EOF from the peer surfaces
    /// as `UnexpectedEof`.
    pub fn try_recv(&mut self, body: &mut Vec<u8>) -> io::Result<bool> {
        loop {
            if self.take_frame(body)? {
                return Ok(true);
            }
            match self.fill() {
                Ok(0) => {
                    return Err(io::Error::new(
                        io::ErrorKind::UnexpectedEof,
                        "peer closed connection",
                    ))
                }
                Ok(_) => continue,
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return Ok(false),
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
    }
}

impl Transport for Tcp {
    fn send(&mut self, body: &[u8]) -> io::Result<()> {
        let len = u32::try_from(body.len())
            .map_err(|_| io::Error::new(io::ErrorKind::InvalidInput, "frame too large"))?;
        let prefix = len.to_le_bytes();
        // write prefix + body fully, absorbing WouldBlock in nonblocking
        // mode (the readiness loop never leaves a frame half-sent) — but
        // only while the peer keeps draining: a no-progress stall past
        // SEND_STALL_TIMEOUT errors out so the server can declare the
        // connection dead instead of wedging forever
        let mut last_progress = std::time::Instant::now();
        for part in [&prefix[..], body] {
            let mut off = 0usize;
            while off < part.len() {
                match self.stream.write(&part[off..]) {
                    Ok(0) => {
                        return Err(io::Error::new(
                            io::ErrorKind::WriteZero,
                            "socket accepted no bytes",
                        ))
                    }
                    Ok(n) => {
                        off += n;
                        last_progress = std::time::Instant::now();
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                        if last_progress.elapsed() > SEND_STALL_TIMEOUT {
                            return Err(io::Error::new(
                                io::ErrorKind::TimedOut,
                                "peer stopped draining its socket",
                            ));
                        }
                        std::thread::sleep(Duration::from_micros(200));
                    }
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                    Err(e) => return Err(e),
                }
            }
        }
        self.stream.flush()
    }

    fn recv(&mut self, body: &mut Vec<u8>) -> io::Result<()> {
        loop {
            if self.take_frame(body)? {
                return Ok(());
            }
            match self.fill() {
                Ok(0) => {
                    return Err(io::Error::new(
                        io::ErrorKind::UnexpectedEof,
                        "peer closed connection",
                    ))
                }
                Ok(_) => {}
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    // blocking recv on a nonblocking socket: degrade to a
                    // short-deadline poll instead of spinning
                    std::thread::sleep(Duration::from_micros(200));
                }
                Err(e) => return Err(e),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loopback_roundtrip_and_eof() {
        let (mut a, mut b) = loopback_pair();
        a.send(&[1, 2, 3]).unwrap();
        a.send(&[]).unwrap();
        let mut buf = vec![9; 16];
        b.recv(&mut buf).unwrap();
        assert_eq!(buf, vec![1, 2, 3]);
        b.recv(&mut buf).unwrap();
        assert!(buf.is_empty());
        drop(a);
        assert_eq!(
            b.recv(&mut buf).unwrap_err().kind(),
            io::ErrorKind::UnexpectedEof
        );
    }

    #[test]
    fn tcp_roundtrip_over_localhost() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut t = Tcp::new(stream).unwrap();
            let mut buf = Vec::new();
            t.recv(&mut buf).unwrap();
            // echo twice to exercise framing boundaries
            t.send(&buf).unwrap();
            t.send(&[0xAB]).unwrap();
        });
        let mut c = Tcp::connect_retry(addr, 20, Duration::from_millis(50)).unwrap();
        let payload: Vec<u8> = (0..300).map(|i| (i % 251) as u8).collect();
        c.send(&payload).unwrap();
        let mut buf = Vec::new();
        c.recv(&mut buf).unwrap();
        assert_eq!(buf, payload);
        c.recv(&mut buf).unwrap();
        assert_eq!(buf, vec![0xAB]);
        // peer closed → EOF
        assert!(c.recv(&mut buf).is_err());
        server.join().unwrap();
    }

    #[test]
    fn tcp_try_recv_reassembles_split_and_batched_frames() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.set_nodelay(true).unwrap();
            // frame 1 split across two writes at an awkward boundary,
            // then frames 2+3 coalesced into a single write
            let f1: Vec<u8> = (0..100u8).collect();
            let mut w1 = (f1.len() as u32).to_le_bytes().to_vec();
            w1.extend_from_slice(&f1[..37]);
            s.write_all(&w1).unwrap();
            s.flush().unwrap();
            std::thread::sleep(Duration::from_millis(30));
            let mut w2 = f1[37..].to_vec();
            w2.extend_from_slice(&3u32.to_le_bytes());
            w2.extend_from_slice(&[9, 8, 7]);
            w2.extend_from_slice(&0u32.to_le_bytes()); // empty frame
            s.write_all(&w2).unwrap();
            s.flush().unwrap();
            // hold the socket open until the server is done reading
            let mut ack = [0u8; 1];
            let _ = s.read(&mut ack);
        });

        let (stream, _) = listener.accept().unwrap();
        let mut t = Tcp::new(stream).unwrap();
        t.set_nonblocking(true).unwrap();
        let mut body = Vec::new();
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        let mut frames: Vec<Vec<u8>> = Vec::new();
        while frames.len() < 3 {
            assert!(std::time::Instant::now() < deadline, "timed out");
            match t.try_recv(&mut body).unwrap() {
                true => frames.push(body.clone()),
                false => std::thread::sleep(Duration::from_millis(1)),
            }
        }
        assert_eq!(frames[0], (0..100u8).collect::<Vec<u8>>());
        assert_eq!(frames[1], vec![9, 8, 7]);
        assert!(frames[2].is_empty());
        // nothing further: try_recv idles without blocking
        assert!(!t.try_recv(&mut body).unwrap());
        t.send(&[1]).unwrap(); // release the client
        client.join().unwrap();
    }

    #[test]
    fn tcp_huge_length_prefix_is_rejected() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(&u32::MAX.to_le_bytes()).unwrap();
            s.flush().unwrap();
            std::thread::sleep(Duration::from_millis(100));
        });
        let (stream, _) = listener.accept().unwrap();
        let mut t = Tcp::new(stream).unwrap();
        let mut body = Vec::new();
        let e = t.recv(&mut body).unwrap_err();
        assert_eq!(e.kind(), io::ErrorKind::InvalidData);
        client.join().unwrap();
    }
}
