//! Distributed coordinator: [`run_distributed`] drives a
//! [`ServerAlgo`](crate::methods::ServerAlgo) against worker *processes*
//! over [`Transport`]s, plus the `smx serve` / `smx worker --connect`
//! entry points and the in-process loopback harness.
//!
//! Protocol per round (after the TCP handshake):
//!
//! 1. the server encodes this round's downlink **once** and sends the
//!    frame to every worker process;
//! 2. each process decodes it and runs every shard it hosts (round-robin
//!    assignment, ascending), sending one uplink frame per shard tagged
//!    with the shard index;
//! 3. the server decodes uplinks into per-shard slots (order on the wire
//!    is irrelevant; apply order equals `run_sim`'s) and advances.
//!
//! RNG streams are derived exactly as in
//! [`run_sim`](crate::coordinator::run_sim) — `base.derive(i)` per shard
//! `i`, `base.derive(u64::MAX)` for the server — which together with the
//! lossless `f64` codec gives the bitwise-identity guarantee in the
//! [module docs](crate::wire).

use crate::config::ExperimentConfig;
use crate::coordinator::{run_sim, EngineFactory, RoundRecord, RunConfig, RunResult};
use crate::experiments::runner;
use crate::linalg::vector;
use crate::methods::{build, Downlink, Method, MethodSpec, ServerAlgo, Uplink, WorkerAlgo};
use crate::objective::Smoothness;
use crate::runtime::native::NativeEngine;
use crate::runtime::{EngineKind, GradEngine};
use crate::util::rng::Rng;
use crate::util::timer::PhaseTimer;
use crate::wire::codec::{self, Hello, Payload};
use crate::wire::transport::{loopback_pair, Tcp, Transport};
use anyhow::{bail, ensure, Context, Result};
use std::time::{Duration, Instant};

/// One worker process from the server's perspective: a transport plus the
/// shard indices it hosts.
pub struct WorkerHost {
    pub transport: Box<dyn Transport>,
    pub shards: Vec<usize>,
}

/// The `(shard index, worker half)` pairs hosted by one worker process.
pub type HostedShards = Vec<(usize, Box<dyn WorkerAlgo + Send>)>;

/// Per-round communication totals of [`server_round`].
#[derive(Clone, Copy, Debug, Default)]
pub struct RoundTotals {
    pub coords_up: u64,
    pub bits_up: u64,
    pub coords_down: u64,
    pub bytes_up: u64,
    pub bytes_down: u64,
}

/// Reused server-side buffers: per-shard uplink slots, the downlink and
/// its encoding, and one receive scratch buffer.
pub struct ServerRoundState {
    pub ups: Vec<Uplink>,
    down: Downlink,
    down_buf: Vec<u8>,
    up_buf: Vec<u8>,
    seen: Vec<bool>,
}

impl ServerRoundState {
    pub fn new(n_shards: usize) -> ServerRoundState {
        ServerRoundState {
            ups: (0..n_shards).map(|_| Uplink::default()).collect(),
            down: Downlink::Init { x: Vec::new() },
            down_buf: Vec::new(),
            up_buf: Vec::new(),
            seen: vec![false; n_shards],
        }
    }
}

/// One synchronous distributed round: broadcast the downlink, gather one
/// uplink per shard, apply. Public so the bench harness can time a single
/// steady-state round against live worker threads.
pub fn server_round(
    server: &mut dyn ServerAlgo,
    hosts: &mut [WorkerHost],
    st: &mut ServerRoundState,
    server_rng: &mut Rng,
    payload: Payload,
    float_bits: u32,
) -> Result<RoundTotals> {
    let n = st.ups.len();
    let dim = server.dim();
    let mut t = RoundTotals::default();

    server.downlink_into(&mut st.down);
    st.down_buf.clear();
    codec::put_downlink(&mut st.down_buf, &st.down, payload);
    t.coords_down = (st.down.coords() * n) as u64;
    t.bytes_down = ((codec::FRAME_PREFIX + st.down_buf.len()) * hosts.len()) as u64;
    for h in hosts.iter_mut() {
        h.transport.send(&st.down_buf).context("sending downlink")?;
    }

    st.seen.fill(false);
    for h in hosts.iter_mut() {
        for _ in 0..h.shards.len() {
            h.transport.recv(&mut st.up_buf).context("receiving uplink")?;
            let shard = codec::peek_uplink_shard(&st.up_buf)?;
            ensure!(shard < n, "uplink for shard {shard}, but n = {n}");
            ensure!(!st.seen[shard], "duplicate uplink for shard {shard}");
            st.seen[shard] = true;
            let up = &mut st.ups[shard];
            codec::get_uplink(&st.up_buf, dim, up)?;
            t.coords_up += up.coords() as u64;
            t.bits_up += crate::coordinator::bits_of(up, dim, float_bits);
            t.bytes_up += (codec::FRAME_PREFIX + st.up_buf.len()) as u64;
        }
    }

    server.apply(&st.ups, server_rng);
    Ok(t)
}

/// Distributed driver: same stopping/recording policy as
/// [`run_sim`](crate::coordinator::run_sim), with *measured* byte counts
/// from the frames actually sent. Always releases the worker processes
/// with a `Stop` frame, even on error.
pub fn run_distributed(
    server: &mut dyn ServerAlgo,
    name: &str,
    hosts: &mut [WorkerHost],
    x_star: &[f64],
    cfg: &RunConfig,
) -> Result<RunResult> {
    let n: usize = hosts.iter().map(|h| h.shards.len()).sum();
    ensure!(n > 0, "no shards hosted");
    let record_every = cfg.record_every.max(1);
    let mut server_rng = Rng::new(cfg.seed).derive(u64::MAX);
    let denom = vector::dist2(server.iterate(), x_star).max(1e-300);
    let mut st = ServerRoundState::new(n);
    let mut acc = RoundTotals::default();
    let mut phases = PhaseTimer::new();
    let mut records = Vec::with_capacity(cfg.max_rounds / record_every + 3);
    records.push(RoundRecord {
        round: 0,
        residual: 1.0,
        coords_up: 0,
        bits_up: 0,
        coords_down: 0,
        bytes_up: 0,
        bytes_down: 0,
        wall_secs: 0.0,
    });
    let t0 = Instant::now();
    let mut reached = false;
    let mut rounds_run = 0;
    let mut failure = None;

    for round in 1..=cfg.max_rounds {
        rounds_run = round;
        let totals = phases.time("dist_round", || {
            server_round(
                server,
                hosts,
                &mut st,
                &mut server_rng,
                cfg.payload,
                cfg.float_bits,
            )
        });
        let totals = match totals {
            Ok(t) => t,
            Err(e) => {
                failure = Some(e);
                break;
            }
        };
        acc.coords_up += totals.coords_up;
        acc.bits_up += totals.bits_up;
        acc.coords_down += totals.coords_down;
        acc.bytes_up += totals.bytes_up;
        acc.bytes_down += totals.bytes_down;

        let res = vector::dist2(server.iterate(), x_star) / denom;
        let hit_target = cfg.target_residual > 0.0 && res <= cfg.target_residual;
        if round % record_every == 0 || round == cfg.max_rounds || hit_target {
            records.push(RoundRecord {
                round,
                residual: res,
                coords_up: acc.coords_up,
                bits_up: acc.bits_up,
                coords_down: acc.coords_down,
                bytes_up: acc.bytes_up,
                bytes_down: acc.bytes_down,
                wall_secs: t0.elapsed().as_secs_f64(),
            });
        }
        if hit_target {
            reached = true;
            break;
        }
    }

    for h in hosts.iter_mut() {
        let _ = h.transport.send(&[codec::TAG_STOP]);
    }
    if let Some(e) = failure {
        return Err(e);
    }
    Ok(RunResult {
        method: name.to_string(),
        records,
        final_x: server.iterate().to_vec(),
        rounds_run,
        reached_target: reached,
        phases,
    })
}

/// Worker-process main loop: for every downlink frame, run each hosted
/// shard and send its uplink; exit cleanly on `Stop`.
pub fn worker_loop(
    workers: &mut [(usize, Box<dyn WorkerAlgo + Send>)],
    engines: &mut [Box<dyn GradEngine>],
    rngs: &mut [Rng],
    transport: &mut dyn Transport,
    payload: Payload,
) -> Result<()> {
    ensure!(!workers.is_empty(), "worker process hosts no shards");
    assert_eq!(workers.len(), engines.len());
    assert_eq!(workers.len(), rngs.len());
    let dim = workers[0].1.dim();
    let mut body = Vec::new();
    let mut down = Downlink::Init { x: Vec::new() };
    let mut ups: Vec<Uplink> = workers.iter().map(|_| Uplink::default()).collect();
    let mut out = Vec::new();
    loop {
        transport.recv(&mut body).context("worker recv")?;
        match codec::frame_tag(&body)? {
            codec::TAG_DOWNLINK => {
                codec::get_downlink(&body, dim, &mut down)?;
                for (k, (shard, algo)) in workers.iter_mut().enumerate() {
                    let up = &mut ups[k];
                    algo.round_into(&down, engines[k].as_mut(), &mut rngs[k], up);
                    out.clear();
                    codec::put_uplink(&mut out, up, *shard, payload);
                    transport.send(&out).context("worker send")?;
                }
            }
            codec::TAG_STOP => return Ok(()),
            other => bail!("worker: unexpected frame tag {other}"),
        }
    }
}

/// Run the full distributed protocol in-process: the server on the
/// calling thread, `procs` worker threads (each hosting `n/procs` shards
/// round-robin) connected by loopback transports. `procs = 0` means one
/// process per shard. Engines are built inside each worker thread via
/// `engine_factory`, mirroring [`run_threaded`](crate::coordinator::run_threaded).
pub fn run_distributed_loopback(
    method: Method,
    engine_factory: EngineFactory,
    x_star: &[f64],
    cfg: &RunConfig,
    procs: usize,
) -> Result<RunResult> {
    let Method {
        mut server,
        workers,
        name,
    } = method;
    let n = workers.len();
    ensure!(n > 0, "method has no workers");
    ensure!(
        cfg.payload.is_lossless() || name != "diana++",
        "diana++ requires the lossless f64 payload: its incremental sparse \
         downlinks never re-sync the worker model replicas, so quantization \
         error would accumulate unboundedly (got payload {})",
        cfg.payload.name()
    );
    let procs = if procs == 0 { n } else { procs.min(n) };
    let base = Rng::new(cfg.seed);

    let mut groups: Vec<HostedShards> = (0..procs).map(|_| Vec::new()).collect();
    for (i, w) in workers.into_iter().enumerate() {
        groups[i % procs].push((i, w));
    }
    let mut hosts: Vec<WorkerHost> = Vec::with_capacity(procs);
    let mut ends = Vec::with_capacity(procs);
    for g in &groups {
        let (a, b) = loopback_pair();
        hosts.push(WorkerHost {
            transport: Box::new(a),
            shards: g.iter().map(|(i, _)| *i).collect(),
        });
        ends.push(b);
    }
    let payload = cfg.payload;

    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(procs);
        for (mut end, mut group) in ends.into_iter().zip(groups.into_iter()) {
            let factory = engine_factory.clone();
            let base = base.clone();
            handles.push(scope.spawn(move || -> Result<()> {
                let mut engines: Vec<Box<dyn GradEngine>> =
                    group.iter().map(|(i, _)| factory(*i)).collect();
                let mut rngs: Vec<Rng> =
                    group.iter().map(|(i, _)| base.derive(*i as u64)).collect();
                worker_loop(&mut group, &mut engines, &mut rngs, &mut end, payload)
            }));
        }
        let result = run_distributed(server.as_mut(), &name, &mut hosts, x_star, cfg);
        for h in handles {
            match h.join() {
                Ok(r) => r?,
                Err(_) => bail!("loopback worker thread panicked"),
            }
        }
        result
    })
}

/// `smx serve`: prepare the problem, accept the configured number of
/// worker-process connections, hand each its shard assignment via the
/// `Hello` handshake, run [`run_distributed`] and write the residual
/// curve CSV. With `check_sim`, re-run the identical configuration under
/// [`run_sim`] and fail unless the iterates are bitwise identical
/// (requires the lossless `f64` payload) — the CI smoke's assertion.
pub fn serve(cfg: &ExperimentConfig, check_sim: bool) -> Result<()> {
    let listener = std::net::TcpListener::bind(&cfg.wire.listen)
        .with_context(|| format!("binding {}", cfg.wire.listen))?;
    serve_on(listener, cfg, check_sim)
}

/// [`serve`] against an already-bound listener (tests bind port 0 and
/// hand the ephemeral address to their worker threads).
pub fn serve_on(
    listener: std::net::TcpListener,
    cfg: &ExperimentConfig,
    check_sim: bool,
) -> Result<()> {
    ensure!(
        cfg.methods.len() == 1,
        "smx serve drives exactly one method; got {:?}",
        cfg.methods
    );
    ensure!(
        cfg.engine == EngineKind::Native,
        "smx serve supports the native engine only"
    );
    let method_name = cfg.methods[0].clone();
    let payload = cfg.wire.payload;
    ensure!(
        payload.is_lossless() || method_name != "diana++",
        "diana++ requires the lossless f64 payload (worker model replicas \
         are updated by incremental sparse downlinks; quantization error \
         would accumulate unboundedly)"
    );
    if check_sim {
        ensure!(
            payload.is_lossless(),
            "--check-sim requires the f64 payload (got {})",
            payload.name()
        );
    }
    let prep = runner::prepare(cfg)?;
    let n = prep.shards.len();
    let procs = cfg.wire.effective_procs(n);
    let mut spec = MethodSpec::new(&method_name, cfg.tau, cfg.sampling, cfg.mu, prep.x0(cfg));
    spec.practical_adiana = cfg.practical_adiana;
    let mut method = build(&spec, &prep.sm)?;
    // server half only; the workers live in their own processes
    method.workers.clear();
    let run_cfg = runner::run_config(cfg);

    crate::info!(
        "wire",
        "serving {} on {} — {} worker process(es), {} shards, payload {}",
        method_name,
        cfg.wire.listen,
        procs,
        n,
        payload.name()
    );
    let mut assignment: Vec<Vec<usize>> = vec![Vec::new(); procs];
    for i in 0..n {
        assignment[i % procs].push(i);
    }
    // Phase 1: accept every process and send its Hello immediately, so all
    // workers rebuild their dataset + smoothness state concurrently; acks
    // are collected in phase 2 (a sequential accept→ack loop would cost
    // procs × build-time instead of max(build-time)).
    let mut pending: Vec<Tcp> = Vec::with_capacity(procs);
    let mut body = Vec::new();
    for p in 0..procs {
        let (stream, peer) = listener.accept().context("accepting worker")?;
        let mut t = Tcp::new(stream)?;
        let hello = Hello {
            dataset: cfg.dataset.clone(),
            // only ship data_dir when the dataset file actually resolved on
            // this side — otherwise the server trained on synthetic data and
            // the worker must synthesize too (it rejects a dangling data_dir)
            data_dir: cfg
                .data_dir
                .as_ref()
                .filter(|d| {
                    d.join(&cfg.dataset).is_file()
                        || d.join(format!("{}.txt", cfg.dataset)).is_file()
                })
                .map(|d| d.display().to_string()),
            seed: cfg.seed,
            workers: n,
            mu: cfg.mu,
            tau: cfg.tau,
            sampling: cfg.sampling,
            method: method_name.clone(),
            practical_adiana: cfg.practical_adiana,
            payload,
            need_global: method_name == "diana++",
            shards: assignment[p].clone(),
            x0: spec.x0.clone(),
        };
        body.clear();
        codec::put_hello(&mut body, &hello);
        t.send(&body)?;
        crate::info!(
            "wire",
            "  worker process {p} connected from {peer} ({} shard(s))",
            assignment[p].len()
        );
        pending.push(t);
    }
    // Phase 2: collect acks (each worker sends one once its state is built).
    let mut hosts: Vec<WorkerHost> = Vec::with_capacity(procs);
    for (p, mut t) in pending.into_iter().enumerate() {
        t.recv(&mut body).context("waiting for worker ack")?;
        ensure!(
            codec::frame_tag(&body)? == codec::TAG_HELLO_ACK,
            "worker process {p} did not acknowledge the handshake"
        );
        hosts.push(WorkerHost {
            transport: Box::new(t),
            shards: assignment[p].clone(),
        });
    }

    let result = run_distributed(
        method.server.as_mut(),
        &method.name,
        &mut hosts,
        &prep.x_star,
        &run_cfg,
    )?;
    let last = result.records.last().unwrap();
    println!(
        "distributed {method_name} on {}: {} rounds, residual {:.6e}",
        cfg.dataset,
        result.rounds_run,
        result.final_residual()
    );
    println!(
        "  measured bytes_up {} (modeled bits_up/8 = {}), bytes_down {}",
        last.bytes_up,
        last.bits_up / 8,
        last.bytes_down
    );
    let path = cfg.out_dir.join(format!("distributed_{}.csv", cfg.dataset));
    crate::util::write_csv(&path, &RunResult::csv_header(), &result.csv_rows())?;
    crate::info!("wire", "wrote {}", path.display());

    if check_sim {
        let mut method2 = build(&spec, &prep.sm)?;
        let mut engines = prep.native_engines(cfg.mu);
        let r_sim = run_sim(&mut method2, &mut engines, &prep.x_star, &run_cfg);
        // bit-level comparison: value equality would let a -0.0/+0.0
        // regression slip through the "bitwise identical" guarantee
        let bits = |xs: &[f64]| xs.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        ensure!(
            bits(&r_sim.final_x) == bits(&result.final_x),
            "check-sim FAILED: distributed iterates diverged from run_sim \
             (residual {:.6e} vs {:.6e})",
            result.final_residual(),
            r_sim.final_residual()
        );
        ensure!(
            r_sim.records.last().unwrap().coords_up == last.coords_up,
            "check-sim FAILED: communication accounting diverged"
        );
        println!(
            "check-sim OK: bitwise identical to run_sim over {} rounds",
            result.rounds_run
        );
    }
    Ok(())
}

/// `smx worker --connect ADDR`: join a serve run, rebuild the assigned
/// shards' state from the `Hello` handshake (deterministic, so worker
/// state matches the server's reference build bit-for-bit), and run the
/// round loop until `Stop`.
pub fn worker_connect(addr: &str) -> Result<()> {
    let mut t = Tcp::connect_retry(addr, 60, Duration::from_millis(250))
        .with_context(|| format!("connecting to {addr}"))?;
    let mut body = Vec::new();
    t.recv(&mut body).context("waiting for hello")?;
    let hello = codec::get_hello(&body)?;
    ensure!(!hello.shards.is_empty(), "server assigned no shards");
    crate::info!(
        "wire",
        "assigned {} shard(s) of {} (method {}, payload {})",
        hello.shards.len(),
        hello.dataset,
        hello.method,
        hello.payload.name()
    );

    let data_dir = hello.data_dir.as_ref().map(std::path::PathBuf::from);
    if let Some(dir) = &data_dir {
        // The server resolved a real dataset file; silently falling back to
        // the synthetic generator here would train on *different data* than
        // the server's x*/smoothness build and diverge without any error.
        ensure!(
            dir.join(&hello.dataset).is_file()
                || dir.join(format!("{}.txt", hello.dataset)).is_file(),
            "server set data_dir {} but dataset '{}' is not there on this \
             machine (refusing to fall back to synthetic data)",
            dir.display(),
            hello.dataset
        );
    }
    let raw = crate::data::load_or_synth(&hello.dataset, data_dir.as_deref(), hello.seed)
        .with_context(|| format!("loading dataset {}", hello.dataset))?;
    let (global, shards) = raw.prepare(hello.workers, hello.seed);
    let mut sm = Smoothness::build(&shards, hello.mu);
    if hello.need_global {
        sm = sm.with_global(&global.a);
    }
    let mut spec = MethodSpec::new(
        &hello.method,
        hello.tau,
        hello.sampling,
        hello.mu,
        hello.x0.clone(),
    );
    spec.practical_adiana = hello.practical_adiana;
    let method = build(&spec, &sm)?;
    ensure!(
        hello.shards.iter().all(|&i| i < method.workers.len()),
        "assigned shard index out of range"
    );
    let assigned: std::collections::BTreeSet<usize> = hello.shards.iter().copied().collect();
    let mut workers: HostedShards = method
        .workers
        .into_iter()
        .enumerate()
        .filter(|(i, _)| assigned.contains(i))
        .collect();
    let mut engines: Vec<Box<dyn GradEngine>> = workers
        .iter()
        .map(|(i, _)| {
            Box::new(NativeEngine::from_shard(&shards[*i], hello.mu)) as Box<dyn GradEngine>
        })
        .collect();
    let base = Rng::new(hello.seed);
    let mut rngs: Vec<Rng> = workers.iter().map(|(i, _)| base.derive(*i as u64)).collect();

    t.send(&[codec::TAG_HELLO_ACK])?;
    worker_loop(&mut workers, &mut engines, &mut rngs, &mut t, hello.payload)
}
