//! Distributed coordinator runtime: the elastic multiplexed TCP server
//! behind `smx serve`, the worker-process round loop behind `smx worker`,
//! and the in-process loopback harness.
//!
//! # Round protocol (after the `Hello` handshake)
//!
//! 1. the server encodes this round's downlink **once**, appends it to the
//!    replay journal, and sends the frame to every live worker process;
//! 2. each process decodes it, sends a heartbeat, and runs every shard it
//!    hosts (round-robin assignment, ascending), sending one uplink frame
//!    per shard tagged with the shard index;
//! 3. the server decodes uplinks into per-shard slots (order on the wire
//!    is irrelevant; apply order equals the sim driver's) and advances.
//!
//! RNG streams are derived exactly as in
//! [`run_sim_observed`](crate::coordinator::run_sim_observed) —
//! `base.derive(i)` per shard `i`, `base.derive(u64::MAX)` for the server
//! — which together with the lossless `f64` codec gives the
//! bitwise-identity guarantee in the [module docs](crate::wire).
//!
//! # Connection lifecycle (server side)
//!
//! ```text
//!            accept (nonblocking listener, readiness-polled)
//!              │
//!              ▼
//!   ┌── work available? ──no──▶ STANDBY ──(shards orphaned later)──┐
//!   │       (yes)                                                  │
//!   ▼                                                              │
//! AWAITING-ACK ◀───────────────────────────────────────────────────┘
//!   │  Hello sent (shard set = initial assignment, or the orphan
//!   │  pool for a rejoiner); worker rebuilds dataset + method state
//!   │  deterministically and acks
//!   ▼
//! LIVE ── receives downlinks / replay journal, sends heartbeats and
//!   │      uplinks; `last_seen` refreshed by every frame
//!   ▼
//! DEAD ── socket EOF/error, or silence past `--worker-timeout` while
//!          owing uplinks, or handshake-ack deadline exceeded
//! ```
//!
//! A connection's death **orphans** its shard set. Orphans are re-homed in
//! two stages, both inside the current round's gather loop:
//!
//! * **rejoin** — the next accepted (or parked standby) connection gets a
//!   `Hello` naming the orphaned shards; after its ack the server streams
//!   `TAG_REPLAY` (+ `TAG_RESTORE` with the latest checkpoint's state
//!   blobs, when one is committed) + the retained journal of downlinks up
//!   to and including the in-flight round. The worker restores each
//!   shard's evolving state and RNG stream from the blobs (or builds at
//!   round 0 when no checkpoint exists), replays all but the last frame
//!   silently through the exact same `round_into` calls the dead worker
//!   made, and answers the last — landing bit-for-bit where the dead
//!   worker would have been.
//! * **reassignment** — if no replacement acks within the grace window
//!   (`--worker-timeout` after the death), the orphans are dealt
//!   round-robin to the surviving live connections via `TAG_ADOPT` + the
//!   same restore/journal stream; survivors promote their reserve worker
//!   halves (every worker process builds all n halves and keeps the
//!   unassigned ones at round-0 state precisely for this) and replay
//!   likewise.
//!
//! Both paths preserve the bitwise-identity guarantee: restore is
//! bit-exact and replay is deterministic, and the round's accounting only
//! counts the uplink frame that is finally applied per shard (recovery
//! retransmissions — journal replays, snapshot/restore frames — are
//! excluded, so `coords_up` still matches the sim driver — asserted by
//! the chaos tests and `--check-sim`).
//!
//! # Replay journal + checkpoint snapshots
//!
//! The journal holds the encoded downlink bodies the recovery paths
//! replay. Unbounded, it grows O(rounds × frame size); with
//! [`RunConfig::checkpoint_every`] set (`--checkpoint-every`, or
//! [`Session::checkpoint_every`](crate::coordinator::Session::checkpoint_every)),
//! the server bounds it: every k-th round it broadcasts `TAG_SNAP_REQ`,
//! each worker answers with one `TAG_SNAP_STATE` blob per hosted shard
//! (its [`WorkerAlgo::save_state`] bytes + RNG state — a consistent
//! end-of-round cut, since frames are processed in order), and once every
//! shard's blob has landed the snapshot **commits**: the blobs are kept
//! for future rejoiners/adopters and the journal is truncated up to the
//! snapshot round. Recovery then means "restore from the snapshot, replay
//! the suffix" instead of "replay from round 0" — same bitwise result,
//! bounded memory, O(k) catch-up. A death while blobs are in flight
//! abandons that collection (the next cadence retries); the committed
//! snapshot is only ever replaced by a newer complete one.
//!
//! # Liveness
//!
//! Workers heartbeat when a downlink arrives and every few replayed
//! frames; uplinks refresh liveness too. The grace window must therefore
//! exceed the slowest *single-shard* round computation — the worker is
//! single-threaded and cannot beacon mid-`round_into`. `--worker-timeout
//! 0` disables fault handling entirely: any worker failure aborts the run
//! (the pre-elastic behavior).
//!
//! # Failure model
//!
//! What each failure class does to a run, and what recovers it — every
//! path preserves the bitwise-identity guarantee:
//!
//! * **Worker crash** (SIGKILL, OOM, network partition): the server sees
//!   EOF or grace-window silence, orphans the shards, and recovers via
//!   the rejoin/reassignment paths above. The worker *process* itself
//!   retries with seeded exponential backoff (`--max-retries`,
//!   `--retry-base-ms`) whenever its connection drops, so a restarted or
//!   momentarily unreachable server is rejoined automatically.
//! * **Server crash** (SIGKILL mid-round): without `--run-dir`, the run
//!   is lost. With `--run-dir`, the committed snapshot + journal suffix
//!   persisted by [`runlog`](crate::wire::runlog) let a restarted
//!   `smx serve --run-dir DIR` refuse-or-resume: the config identity and
//!   seed must match, the server restores method/RNG/totals state at the
//!   snapshot round, replays the recorded history into its observers,
//!   and re-runs the suffix — verifying each regenerated downlink
//!   byte-for-byte against the persisted journal. Workers ride the
//!   restart out via their retry loop and are restored over the rejoin
//!   path (`TAG_RESTORE` with the snapshot's shard blobs).
//! * **Frame corruption** (flipped bit on the wire or on disk): every
//!   frame carries a CRC32 trailer (unless `--no-crc`); a mismatch
//!   surfaces as a connection error, the affected worker severs and
//!   rejoins, and the journal retransmits the *clean* copy of the
//!   corrupted downlink. Run-log records are CRC-framed the same way —
//!   a torn journal tail is dropped, anything else corrupt refuses to
//!   load rather than silently diverging.
//! * **Slowness** (GC pause, CPU contention): heartbeats + the grace
//!   window distinguish slow from dead; a worker declared dead while
//!   merely slow simply reconnects and rejoins — its stale uplinks are
//!   discarded by the per-round slot table.
//!
//! Faults of every class can be injected deterministically with
//! [`FaultPlan`](crate::wire::fault::FaultPlan) (`--fault-plan`); the
//! chaos matrix in `tests/chaos_matrix.rs` drives each recovery path and
//! asserts bitwise identity against the sim driver.
//!
//! # Observability: the `/metrics` HTTP listener
//!
//! With `--metrics-addr HOST:PORT` (`wire.metrics_addr`) the server
//! multiplexes a second listening socket onto the **same** poller loop
//! that drives worker traffic: no extra thread touches server state, so
//! the lock-free [`Registry`](crate::obs::Registry) the round loop
//! writes (rounds, per-worker liveness, journal depth, CRC errors,
//! rejoin/replay counts, and a seqlock-guarded copy of the latest
//! [`RoundRecord`]) can be scraped at any moment without perturbing the
//! trajectory. Token space keeps the two listeners apart: worker
//! connections use small slot indices, the worker listener is
//! `u64::MAX`, the metrics listener
//! [`METRICS_LISTENER_TOKEN`](crate::obs::METRICS_LISTENER_TOKEN)
//! (`u64::MAX - 1`), and HTTP connections live at
//! [`HTTP_CONN_TOKEN_BASE`](crate::obs::HTTP_CONN_TOKEN_BASE) and up.
//! `pump` routes those tokens to [`HttpEndpoint`](crate::obs::HttpEndpoint)
//! before the worker dispatch, so a scrape costs one poll wake-up.
//! `GET /metrics` serves Prometheus text format; `GET /healthz` answers
//! `ok` while the loop is alive. The byte counters in the round block
//! come from the same cumulative totals the record stream is cut from —
//! `smx_bytes_up_total` agrees exactly with the `bytes_up` CSV column at
//! every recorded round (asserted by `tests/obs_endpoint.rs`).

use crate::config::ExperimentConfig;
use crate::coordinator::session::{Tick, Ticker};
use crate::coordinator::membership::{self, Membership, MembershipEvent, Participation};
use crate::coordinator::{
    DistTransport, Driver, EngineFactory, RoundObserver, RoundRecord, RunConfig, RunOutcome,
    RunResult, Session,
};
use crate::experiments::runner::{self, Prepared};
use crate::linalg::vector;
use crate::methods::{build, Downlink, Method, MethodSpec, ServerAlgo, Uplink, WorkerAlgo};
use crate::obs::{HttpEndpoint, HTTP_CONN_TOKEN_BASE, METRICS_LISTENER_TOKEN};
use crate::objective::Smoothness;
use crate::runtime::native::NativeEngine;
use crate::runtime::{EngineKind, GradEngine};
use crate::util::rng::{Rng, SplitMix64};
use crate::util::timer::PhaseTimer;
use crate::wire::codec::{self, Hello, Payload};
use crate::wire::epoch::{self, TAG_EPOCH};
use crate::wire::fault::{FaultPlan, KILLED_MARKER};
use crate::wire::journal::JournalWindow;
use crate::wire::poll::Poller;
use crate::wire::runlog::{self, MembershipRecord, RunLog};
use crate::wire::transport::{loopback_pair, Tcp, Transport};
use anyhow::{bail, ensure, Context, Result};
use std::collections::VecDeque;
use std::net::TcpListener;
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Per-round communication totals — the shared accounting struct, re-
/// exported from [`coordinator::metrics`](crate::coordinator::metrics).
pub use crate::coordinator::RoundTotals;

/// One worker process from the server's perspective: a transport plus the
/// shard indices it hosts. Used by the fixed-membership
/// [`run_distributed_observed`] driver core (loopback tests and benches).
pub struct WorkerHost {
    pub transport: Box<dyn Transport>,
    pub shards: Vec<usize>,
}

/// The `(shard index, worker half)` pairs hosted by one worker process.
pub type HostedShards = Vec<(usize, Box<dyn WorkerAlgo + Send>)>;

/// Reused server-side buffers: per-shard uplink slots, the downlink and
/// its encoding, and one receive scratch buffer.
pub struct ServerRoundState {
    pub ups: Vec<Uplink>,
    down: Downlink,
    down_buf: Vec<u8>,
    up_buf: Vec<u8>,
    seen: Vec<bool>,
    /// encoded `TAG_EPOCH` announcement, reused across sampled rounds
    epoch_buf: Vec<u8>,
}

impl ServerRoundState {
    pub fn new(n_shards: usize) -> ServerRoundState {
        ServerRoundState {
            ups: (0..n_shards).map(|_| Uplink::default()).collect(),
            down: Downlink::Init { x: Vec::new() },
            down_buf: Vec::new(),
            up_buf: Vec::new(),
            seen: vec![false; n_shards],
            epoch_buf: Vec::new(),
        }
    }
}

/// One synchronous distributed round against a *fixed* set of hosts:
/// broadcast the downlink, gather one uplink per shard, apply. Public so
/// the bench harness can time a single steady-state round against live
/// worker threads. (The elastic TCP server has its own gather loop with
/// fault handling; this one is the minimal reference.)
pub fn server_round(
    server: &mut dyn ServerAlgo,
    hosts: &mut [WorkerHost],
    st: &mut ServerRoundState,
    server_rng: &mut Rng,
    payload: Payload,
    float_bits: u32,
) -> Result<RoundTotals> {
    server_round_sampled(server, hosts, st, server_rng, payload, float_bits, None, 0)
}

/// [`server_round`] with optional partial participation: when
/// `participation` is set, the round opens with a `TAG_EPOCH` frame to
/// *every* host naming the cohort (epoch is the constant 1 — this
/// fixed-membership driver never rolls it), the downlink goes only to
/// hosts owning at least one cohort shard, exactly one uplink per cohort
/// shard is gathered, and cohort uplinks are reweighted by n/τ before
/// `apply` so the aggregate stays unbiased. Sampled-out shards' slots are
/// cleared. Epoch frames are control plane — excluded from `bytes_down`,
/// like heartbeats. `round` seeds the cohort draw and is otherwise
/// unused; pass 0 under full participation.
#[allow(clippy::too_many_arguments)]
pub fn server_round_sampled(
    server: &mut dyn ServerAlgo,
    hosts: &mut [WorkerHost],
    st: &mut ServerRoundState,
    server_rng: &mut Rng,
    payload: Payload,
    float_bits: u32,
    participation: Option<&mut Participation>,
    round: usize,
) -> Result<RoundTotals> {
    let n = st.ups.len();
    let dim = server.dim();
    let mut t = RoundTotals::default();

    server.downlink_into(&mut st.down);
    st.down_buf.clear();
    codec::put_downlink(&mut st.down_buf, &st.down, payload)?;

    let (tau, weight) = match participation.as_deref() {
        Some(p) => (p.tau(), p.weight()),
        None => (n, 1.0),
    };
    let mask: Option<&[bool]> = match participation {
        Some(p) => Some(p.draw(round as u64)),
        None => None,
    };
    let in_cohort = |s: usize| mask.map_or(true, |m| m[s]);

    if let Some(m) = mask {
        epoch::put_epoch(&mut st.epoch_buf, round, 1, m);
        for h in hosts.iter_mut() {
            h.transport.send(&st.epoch_buf).context("sending epoch frame")?;
        }
    }

    t.coords_down = (st.down.coords() * tau) as u64;
    for h in hosts.iter_mut() {
        if h.shards.iter().any(|&s| in_cohort(s)) {
            h.transport.send(&st.down_buf).context("sending downlink")?;
            t.bytes_down += (codec::FRAME_PREFIX + st.down_buf.len()) as u64;
        }
    }

    st.seen.fill(false);
    for s in 0..n {
        // a sampled-out shard owes no uplink: mark it seen and clear its
        // slot so a stale previous-round delta can never reach `apply`
        if !in_cohort(s) {
            st.seen[s] = true;
            membership::clear_uplink(&mut st.ups[s]);
        }
    }
    let mut pending = tau;
    for h in hosts.iter_mut() {
        let expect = h.shards.iter().filter(|&&s| in_cohort(s)).count();
        let mut got = 0;
        while got < expect {
            h.transport.recv(&mut st.up_buf).context("receiving uplink")?;
            // workers may interleave heartbeats with uplinks
            if codec::frame_tag(&st.up_buf)? == codec::TAG_HEARTBEAT {
                continue;
            }
            let shard = codec::peek_uplink_shard(&st.up_buf)?;
            ensure!(shard < n, "uplink for shard {shard}, but n = {n}");
            ensure!(!st.seen[shard], "duplicate uplink for shard {shard}");
            st.seen[shard] = true;
            let up = &mut st.ups[shard];
            codec::get_uplink(&st.up_buf, dim, up)?;
            t.coords_up += up.coords() as u64;
            t.bits_up += crate::coordinator::bits_of(up, dim, float_bits);
            t.bytes_up += (codec::FRAME_PREFIX + st.up_buf.len()) as u64;
            got += 1;
            pending -= 1;
        }
    }
    debug_assert_eq!(pending, 0);

    if let Some(m) = mask {
        // unbiased estimator: scale the τ cohort uplinks by n/τ, after
        // accounting (counts are what was sent) and before apply
        for s in 0..n {
            if m[s] {
                membership::reweight_uplink(&mut st.ups[s], weight);
            }
        }
    }
    server.apply(&st.ups, server_rng);
    Ok(t)
}

/// Fixed-membership distributed driver core: same stopping/recording
/// policy as the other drivers (metrics stream through `obs`), with
/// *measured* byte counts from the frames actually sent. Always releases
/// the worker processes with a `Stop` frame, even on error. No fault
/// tolerance — this is the loopback/bench reference; the TCP path goes
/// through [`serve_on`]. Prefer
/// [`Session`](crate::coordinator::Session) with
/// [`Driver::Distributed`](crate::coordinator::Driver).
pub fn run_distributed_observed(
    server: &mut dyn ServerAlgo,
    name: &str,
    hosts: &mut [WorkerHost],
    x_star: &[f64],
    cfg: &RunConfig,
    obs: &mut dyn RoundObserver,
) -> Result<RunOutcome> {
    let n: usize = hosts.iter().map(|h| h.shards.len()).sum();
    ensure!(n > 0, "no shards hosted");
    let mut participation =
        Participation::from_run(cfg.participation, cfg.seed, n)?.filter(|p| !p.is_full());
    ensure!(
        !(participation.is_some() && name.contains("diana++")),
        "diana++ keeps per-worker model replicas stepped by every downlink; \
         partial participation would let them diverge — use diana+ or tau=n"
    );
    let mut server_rng = Rng::new(cfg.seed).derive(u64::MAX);
    let denom = vector::dist2(server.iterate(), x_star).max(1e-300);
    let mut st = ServerRoundState::new(n);
    let mut acc = RoundTotals::default();
    let mut phases = PhaseTimer::new();
    let ticker = Ticker::new(cfg);
    let mut stopped = ticker.start(obs);
    let mut reached = false;
    let mut rounds_run = 0;
    let mut failure = None;

    if !stopped {
        for round in 1..=cfg.max_rounds {
            rounds_run = round;
            let totals = phases.time("dist_round", || {
                server_round_sampled(
                    server,
                    hosts,
                    &mut st,
                    &mut server_rng,
                    cfg.payload,
                    cfg.float_bits,
                    participation.as_mut(),
                    round,
                )
            });
            let totals = match totals {
                Ok(t) => t,
                Err(e) => {
                    failure = Some(e);
                    break;
                }
            };
            acc.accumulate(&totals);

            let res = vector::dist2(server.iterate(), x_star) / denom;
            match ticker.tick(round, res, &acc, server.iterate(), &phases, obs) {
                Tick::Continue => {}
                Tick::ReachedTarget => {
                    reached = true;
                    break;
                }
                Tick::Stopped => {
                    stopped = true;
                    break;
                }
            }
        }
    }

    for h in hosts.iter_mut() {
        let _ = h.transport.send(&[codec::TAG_STOP]);
    }
    if let Some(e) = failure {
        return Err(e);
    }
    Ok(RunOutcome {
        method: name.to_string(),
        final_x: server.iterate().to_vec(),
        rounds_run,
        reached_target: reached,
        stopped_by_observer: stopped,
        phases,
    })
}

// ---- worker side -------------------------------------------------------

/// Everything one shard needs to run rounds on a worker process.
pub struct ShardRunner {
    shard: usize,
    algo: Box<dyn WorkerAlgo + Send>,
    engine: Box<dyn GradEngine>,
    rng: Rng,
    up: Uplink,
}

impl ShardRunner {
    pub fn new(
        shard: usize,
        algo: Box<dyn WorkerAlgo + Send>,
        engine: Box<dyn GradEngine>,
        rng: Rng,
    ) -> ShardRunner {
        ShardRunner {
            shard,
            algo,
            engine,
            rng,
            up: Uplink::default(),
        }
    }

    /// Advance this shard one round; optionally encode + send the uplink.
    fn step(
        &mut self,
        down: &Downlink,
        live: bool,
        payload: Payload,
        out: &mut Vec<u8>,
        transport: &mut dyn Transport,
    ) -> Result<()> {
        self.algo
            .round_into(down, self.engine.as_mut(), &mut self.rng, &mut self.up);
        if live {
            out.clear();
            codec::put_uplink(out, &self.up, self.shard, payload)?;
            transport.send(out).context("worker send")?;
        }
        Ok(())
    }

    /// Append this shard's checkpoint blob: RNG state first (fixed size),
    /// then the algorithm's evolving state. [`ShardRunner::load_blob`]
    /// inverts it bit-exactly — the snapshot-resume identity rests on
    /// this pair.
    fn save_blob(&self, out: &mut Vec<u8>) {
        self.rng.save_state(out);
        self.algo.save_state(out);
    }

    /// Restore state saved by [`ShardRunner::save_blob`].
    fn load_blob(&mut self, blob: &[u8]) -> Result<()> {
        let rng = Rng::load_state(blob)
            .with_context(|| format!("shard {}: malformed snapshot RNG state", self.shard))?;
        ensure!(
            self.algo.load_state(&blob[Rng::STATE_BYTES..]),
            "shard {}: malformed or wrong-shape snapshot state",
            self.shard
        );
        self.rng = rng;
        Ok(())
    }
}

/// Context a TCP worker keeps so it can *adopt* orphaned shards later:
/// the dataset shards (to build gradient engines) and the reserve worker
/// halves at round-0 state.
struct AdoptCtx {
    shards: Vec<crate::data::Shard>,
    mu: f64,
}

/// Chaos / deployment knobs for [`worker_connect_with`].
#[derive(Clone, Debug)]
pub struct WorkerOpts {
    /// Fault-injection hook (chaos tests, `smx worker --die-after N`):
    /// drop the connection immediately after receiving the N-th live
    /// downlink, without replying — observably identical to the process
    /// being SIGKILLed at that instant (the OS closes the socket).
    pub die_after: Option<usize>,
    /// Pin this worker process to the given core before the round loop
    /// (`sched_setaffinity`; no-op off Linux).
    pub pin: Option<usize>,
    /// Chaos-test assertion (`smx worker --expect-restore`): fail unless
    /// this worker was handed a snapshot restore (`TAG_RESTORE`) during
    /// its run — proves the journal-truncating checkpoint path was
    /// actually exercised, rather than a silent full-journal replay.
    pub expect_restore: bool,
    /// Scriptable worker-side fault schedule (`smx worker --fault-plan`;
    /// grammar in [`crate::wire::fault`]): `kill`, `drop-uplink` and
    /// `delay` events, counted in live downlinks this process has seen
    /// (like `die_after`). Server-side events in the plan are ignored
    /// here.
    pub fault: Option<FaultPlan>,
    /// Connection-loss resilience: how many times to retry the whole
    /// session (reconnect, re-handshake, rejoin) after a connection
    /// error before giving up. Rides out a `--run-dir` server restart.
    pub max_retries: usize,
    /// Base backoff delay in milliseconds; attempt `k` waits
    /// `base * 2^min(k,5)` (capped at 10 s) plus deterministic jitter.
    pub retry_base_ms: u64,
}

impl Default for WorkerOpts {
    fn default() -> WorkerOpts {
        WorkerOpts {
            die_after: None,
            pin: None,
            expect_restore: false,
            fault: None,
            max_retries: 5,
            retry_base_ms: 250,
        }
    }
}

/// Worker-process state: active shard runners, reserve halves for
/// adoption, and the round loop bookkeeping.
pub struct WorkerState {
    active: Vec<ShardRunner>,
    /// round-0 worker halves for shards this process does NOT host —
    /// promoted by `TAG_ADOPT` (TCP workers only; empty under loopback)
    reserve: HostedShards,
    adopt_ctx: Option<AdoptCtx>,
    seed: u64,
    payload: Payload,
    dim: usize,
    die_after: Option<usize>,
    /// worker-side scriptable fault schedule (see [`WorkerOpts::fault`])
    fault: Option<FaultPlan>,
    rounds_seen: usize,
    /// chaos assertion: fail unless a `TAG_RESTORE` arrived (see
    /// [`WorkerOpts::expect_restore`])
    expect_restore: bool,
    restored: bool,
    /// latest cohort mask from a `TAG_EPOCH` frame (`None` until one
    /// arrives, i.e. under full participation): runners whose shard is
    /// outside it skip the round entirely — no `round_into`, no RNG
    /// draw, no uplink — keeping them bitwise aligned with the sim
    /// driver's sampled-out workers
    cohort: Option<Vec<bool>>,
    /// scripted `pause` fault latched: never heartbeat again (cohort
    /// uplinks still flow), proving the server's grace window tolerates
    /// a silent idler
    paused: bool,
}

impl WorkerState {
    /// State for an in-process loopback worker (fixed membership: no
    /// reserve halves, no adoption).
    pub fn for_loopback(active: Vec<ShardRunner>, payload: Payload, seed: u64) -> WorkerState {
        let dim = active.first().map(|r| r.algo.dim()).unwrap_or(0);
        WorkerState {
            active,
            reserve: Vec::new(),
            adopt_ctx: None,
            seed,
            payload,
            dim,
            die_after: None,
            fault: None,
            rounds_seen: 0,
            expect_restore: false,
            restored: false,
            cohort: None,
            paused: false,
        }
    }
}

/// Heartbeat cadence while replaying a long journal.
const REPLAY_HEARTBEAT_EVERY: usize = 16;

fn send_heartbeat(transport: &mut dyn Transport) -> Result<()> {
    transport
        .send(&[codec::TAG_HEARTBEAT])
        .context("worker heartbeat")
}

/// Worker-process main loop: run every hosted shard per downlink, answer
/// snapshot requests, replay journaled rounds (restoring from a snapshot
/// first when the server says so), adopt orphaned shards, exit on `Stop`.
pub fn worker_loop(state: &mut WorkerState, transport: &mut dyn Transport) -> Result<()> {
    ensure!(!state.active.is_empty(), "worker process hosts no shards");
    let mut body = Vec::new();
    let mut out = Vec::new();
    let mut down = Downlink::Init { x: Vec::new() };
    let payload = state.payload;
    let dim = state.dim;
    loop {
        transport.recv(&mut body).context("worker recv")?;
        match codec::frame_tag(&body)? {
            codec::TAG_DOWNLINK => {
                state.rounds_seen += 1;
                if state.die_after == Some(state.rounds_seen) {
                    // injected fault: vanish without replying — the OS
                    // closes the socket, exactly like a SIGKILL here
                    return Ok(());
                }
                // scripted worker-side faults, counted like --die-after in
                // live downlinks this process has seen
                let mut live = true;
                if let Some(plan) = &state.fault {
                    let round = state.rounds_seen as u64;
                    let shards: Vec<usize> = state.active.iter().map(|r| r.shard).collect();
                    if plan.kill_worker_after(round, &shards) {
                        return Ok(());
                    }
                    if let Some(d) = plan.delay_at(round, &shards) {
                        std::thread::sleep(d);
                    }
                    if plan.pause_at(round, &shards) {
                        state.paused = true;
                    }
                    if plan.drop_uplink_at(round, &shards) {
                        // compute the round but sever before the uplink: the
                        // server re-homes the shards and the replacement
                        // replays a clean copy
                        live = false;
                    }
                }
                if !state.paused {
                    send_heartbeat(transport)?;
                }
                codec::get_downlink(&body, dim, &mut down)?;
                for k in 0..state.active.len() {
                    let s = state.active[k].shard;
                    if state.cohort.as_ref().map_or(false, |m| !m.get(s).copied().unwrap_or(false)) {
                        continue; // sampled out: skip the round entirely
                    }
                    state.active[k].step(&down, live, payload, &mut out, transport)?;
                }
                if !live {
                    return Ok(());
                }
            }
            TAG_EPOCH => {
                // partial participation: the cohort announcement reaches
                // every worker each round; the downlink follows only when
                // one of our shards is in the cohort. Answering it with a
                // heartbeat is what keeps a sampled-out idler alive.
                let mut mask = state.cohort.take().unwrap_or_default();
                let (eround, _epoch) = epoch::get_epoch(&body, &mut mask)?;
                if let Some(plan) = &state.fault {
                    let shards: Vec<usize> = state.active.iter().map(|r| r.shard).collect();
                    // pause keys on the server's round (the epoch frame
                    // carries it), so chaos plans can target the exact
                    // round a shard sits out
                    if plan.pause_at(eround as u64, &shards) {
                        state.paused = true;
                    }
                }
                if !state.paused {
                    send_heartbeat(transport)?;
                }
                state.cohort = Some(mask);
            }
            codec::TAG_SNAP_REQ => {
                // checkpoint: ship every hosted shard's evolving state;
                // the request arrives between rounds, so the blobs are a
                // consistent end-of-round cut
                let round = codec::get_snap_req(&body)?;
                let mut blob = Vec::new();
                for r in state.active.iter() {
                    blob.clear();
                    r.save_blob(&mut blob);
                    out.clear();
                    codec::put_snap_state(&mut out, r.shard, round, &blob);
                    transport.send(&out).context("worker snapshot send")?;
                }
            }
            codec::TAG_REPLAY => {
                // rejoin catch-up: every active shard restores from the
                // snapshot (if one exists) and replays the remaining
                // journal; only the last frame is answered
                let (count, restore) = codec::get_replay(&body)?;
                let all: Vec<usize> = (0..state.active.len()).collect();
                if restore {
                    restore_from_snapshot(state, transport, &mut body, &all)?;
                }
                replay_rounds(state, transport, &mut body, &mut out, &mut down, count, &all)?;
            }
            codec::TAG_ADOPT => {
                let (shards, count, restore) = codec::get_adopt(&body)?;
                let fresh = adopt_shards(state, &shards)?;
                if restore {
                    restore_from_snapshot(state, transport, &mut body, &fresh)?;
                }
                replay_rounds(state, transport, &mut body, &mut out, &mut down, count, &fresh)?;
            }
            codec::TAG_STOP => {
                ensure!(
                    !state.expect_restore || state.restored,
                    "--expect-restore: run finished without a snapshot restore \
                     (the journal-truncating checkpoint path was not exercised)"
                );
                return Ok(());
            }
            other => bail!("worker: unexpected frame tag {other}"),
        }
    }
}

/// Receive the `TAG_RESTORE` frame that follows a restore-flagged
/// announcement and load each blob into the matching runner among
/// `targets` (indices into `state.active`). Blob state is the end of the
/// snapshot round; the replay that follows covers only later rounds.
fn restore_from_snapshot(
    state: &mut WorkerState,
    transport: &mut dyn Transport,
    body: &mut Vec<u8>,
    targets: &[usize],
) -> Result<()> {
    transport.recv(body).context("restore recv")?;
    let (round, blobs) = codec::get_restore(body)?;
    crate::info!(
        "wire",
        "restoring {} shard(s) from the round-{round} snapshot",
        blobs.len()
    );
    ensure!(
        blobs.len() == targets.len(),
        "restore names {} shard(s), expected {}",
        blobs.len(),
        targets.len()
    );
    for (shard, blob) in &blobs {
        let k = targets
            .iter()
            .copied()
            .find(|&k| state.active[k].shard == *shard)
            .with_context(|| format!("restore for shard {shard}, which is not a target here"))?;
        state.active[k].load_blob(blob)?;
    }
    state.restored = true;
    Ok(())
}

/// Promote `shards` from the reserve pool to active runners (round-0
/// state). Returns the indices of the new runners within `state.active`.
fn adopt_shards(state: &mut WorkerState, shards: &[usize]) -> Result<Vec<usize>> {
    let ctx = state
        .adopt_ctx
        .as_ref()
        .ok_or_else(|| anyhow::anyhow!("adoption unsupported on this worker (loopback)"))?;
    let base = Rng::new(state.seed);
    let mut fresh = Vec::with_capacity(shards.len());
    for &s in shards {
        let pos = state
            .reserve
            .iter()
            .position(|(i, _)| *i == s)
            .with_context(|| format!("shard {s} not in reserve (already active or unknown)"))?;
        let (i, algo) = state.reserve.swap_remove(pos);
        let engine = Box::new(NativeEngine::from_shard(&ctx.shards[i], ctx.mu));
        crate::info!("wire", "adopting orphaned shard {i}");
        fresh.push(state.active.len());
        state
            .active
            .push(ShardRunner::new(i, algo, engine, base.derive(i as u64)));
    }
    Ok(fresh)
}

/// Consume `count` journaled rounds: advance the runners at `targets`
/// through all of them, answering only the last (live) frame. Under
/// partial participation each journaled round opens with its `TAG_EPOCH`
/// announcement; replayed runners honor it exactly like live ones —
/// sampled-out rounds are skipped, so the replayed trajectory (RNG
/// stream included) is bitwise the one a survivor walked.
fn replay_rounds(
    state: &mut WorkerState,
    transport: &mut dyn Transport,
    body: &mut Vec<u8>,
    out: &mut Vec<u8>,
    down: &mut Downlink,
    count: usize,
    targets: &[usize],
) -> Result<()> {
    if count == 0 {
        return Ok(());
    }
    crate::info!(
        "wire",
        "replaying {count} journaled round(s) over {} shard(s)",
        targets.len()
    );
    if !state.paused {
        send_heartbeat(transport)?;
    }
    for f in 0..count {
        transport.recv(body).context("replay recv")?;
        if codec::frame_tag(body)? == TAG_EPOCH {
            let mut mask = state.cohort.take().unwrap_or_default();
            epoch::get_epoch(body, &mut mask)?;
            state.cohort = Some(mask);
            transport.recv(body).context("replay recv")?;
        }
        ensure!(
            codec::frame_tag(body)? == codec::TAG_DOWNLINK,
            "replay stream interrupted by a non-downlink frame"
        );
        codec::get_downlink(body, state.dim, down)?;
        let live = f + 1 == count;
        let payload = state.payload;
        for &k in targets {
            let s = state.active[k].shard;
            if state.cohort.as_ref().map_or(false, |m| !m.get(s).copied().unwrap_or(false)) {
                continue;
            }
            state.active[k].step(down, live, payload, out, transport)?;
        }
        if (f + 1) % REPLAY_HEARTBEAT_EVERY == 0 && !live && !state.paused {
            send_heartbeat(transport)?;
        }
    }
    Ok(())
}

/// Run the full distributed protocol in-process: the server on the
/// calling thread, `procs` worker threads (each hosting `n/procs` shards
/// round-robin) connected by loopback transports. `procs = 0` means one
/// process per shard. Engines are built inside each worker thread via
/// `engine_factory`, mirroring the threaded driver. Prefer
/// [`Session`](crate::coordinator::Session) with
/// [`DistTransport::Loopback`](crate::coordinator::DistTransport).
pub fn run_distributed_loopback_observed(
    method: Method,
    engine_factory: EngineFactory,
    x_star: &[f64],
    cfg: &RunConfig,
    procs: usize,
    obs: &mut dyn RoundObserver,
) -> Result<RunOutcome> {
    let Method {
        mut server,
        workers,
        name,
    } = method;
    let n = workers.len();
    ensure!(n > 0, "method has no workers");
    ensure!(
        cfg.payload.is_lossless() || name != "diana++",
        "diana++ requires the lossless f64 payload: its incremental sparse \
         downlinks never re-sync the worker model replicas, so quantization \
         error would accumulate unboundedly (got payload {})",
        cfg.payload.name()
    );
    let procs = if procs == 0 { n } else { procs.min(n) };
    let base = Rng::new(cfg.seed);

    let mut groups: Vec<HostedShards> = (0..procs).map(|_| Vec::new()).collect();
    for (i, w) in workers.into_iter().enumerate() {
        groups[i % procs].push((i, w));
    }
    let mut hosts: Vec<WorkerHost> = Vec::with_capacity(procs);
    let mut ends = Vec::with_capacity(procs);
    for g in &groups {
        let (a, b) = loopback_pair();
        hosts.push(WorkerHost {
            transport: Box::new(a),
            shards: g.iter().map(|(i, _)| *i).collect(),
        });
        ends.push(b);
    }
    let payload = cfg.payload;
    let seed = cfg.seed;

    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(procs);
        for (mut end, group) in ends.into_iter().zip(groups.into_iter()) {
            let factory = engine_factory.clone();
            let base = base.clone();
            handles.push(scope.spawn(move || -> Result<()> {
                let runners: Vec<ShardRunner> = group
                    .into_iter()
                    .map(|(i, algo)| {
                        ShardRunner::new(i, algo, factory(i), base.derive(i as u64))
                    })
                    .collect();
                let mut state = WorkerState::for_loopback(runners, payload, seed);
                worker_loop(&mut state, &mut end)
            }));
        }
        let result =
            run_distributed_observed(server.as_mut(), &name, &mut hosts, x_star, cfg, obs);
        for h in handles {
            match h.join() {
                Ok(r) => r?,
                Err(_) => bail!("loopback worker thread panicked"),
            }
        }
        result
    })
}

// ---- elastic TCP server ------------------------------------------------

/// Fault-handling policy of the elastic server.
#[derive(Clone, Copy, Debug)]
pub struct FaultConfig {
    /// Grace window: how long a live worker may stay silent while owing
    /// uplinks before being declared dead, and how long the server waits
    /// for a rejoining replacement before reassigning orphaned shards.
    /// `Duration::ZERO` disables fault handling (any failure aborts).
    pub worker_timeout: Duration,
}

impl FaultConfig {
    fn enabled(&self) -> bool {
        self.worker_timeout > Duration::ZERO
    }

    /// A rejoiner rebuilds the dataset + method state before acking; that
    /// build cannot heartbeat, so it gets a generous multiple.
    fn ack_grace(&self) -> Duration {
        (self.worker_timeout * 10).max(Duration::from_secs(30))
    }
}

/// Poller token reserved for the listening socket.
const LISTENER_TOKEN: u64 = u64::MAX;

/// Kernel-wait slice; deadlines are re-checked at least this often.
const WAIT_SLICE: Duration = Duration::from_millis(25);

enum Phase {
    /// `Hello` sent, worker is rebuilding state. Rejoiners carry an ack
    /// deadline and owe a journal replay after acking.
    AwaitingAck {
        deadline: Option<Instant>,
        replay_on_ack: bool,
    },
    Live,
}

struct Conn {
    tcp: Tcp,
    shards: Vec<usize>,
    phase: Phase,
    last_seen: Instant,
    peer: String,
    /// stable member id keying the [`Membership`] machine and the
    /// journal's per-member delivery marks; monotonic for the run's
    /// lifetime, so a reconnecting process re-enters as a *new* member
    member: u64,
}

/// Per-round gather scratch (server side).
struct Scratch {
    down: Downlink,
    down_buf: Vec<u8>,
    ups: Vec<Uplink>,
    seen: Vec<bool>,
    /// length-prefixed size of the uplink frame finally applied per shard
    up_bytes: Vec<u64>,
    /// partial participation was active for the last drawn round
    sampled: bool,
    /// last drawn cohort mask, one flag per shard (meaningful only while
    /// `sampled`)
    cohort: Vec<bool>,
    /// encoded `TAG_EPOCH` announcement for the current round, reused
    /// across rounds and cloned into the journal
    epoch_buf: Vec<u8>,
}

struct ElasticServer {
    listener: TcpListener,
    poller: Poller,
    conns: Vec<Option<Conn>>,
    /// accepted connections with no work yet (no Hello sent); promoted
    /// when shards orphan
    standby: Vec<Tcp>,
    /// `Hello` template; `shards` is filled per installation
    hello: Hello,
    fault: FaultConfig,
    payload: Payload,
    n_shards: usize,
    dim: usize,
    /// replay journal: one entry per round since the last committed
    /// snapshot (optional epoch announcement + downlink body), stored
    /// once behind `Arc` with per-member delivery marks so catch-up
    /// retransmits can be sized per member
    journal: JournalWindow,
    /// last committed checkpoint: `(round, per-shard state blobs)`;
    /// rejoiners and adopters restore from it instead of replaying from
    /// round 0
    snapshot: Option<(usize, Vec<Vec<u8>>)>,
    /// snapshot round whose blobs are still being collected, with the
    /// per-shard slots; committed (journal truncated) when all arrive
    pending_snap: Option<(usize, Vec<Option<Vec<u8>>>)>,
    /// snapshot cadence in rounds (0 disables; from
    /// [`RunConfig::checkpoint_every`])
    checkpoint_every: usize,
    /// shards whose owner died, awaiting a rejoiner or reassignment
    orphans: Vec<usize>,
    orphan_deadline: Option<Instant>,
    /// initial shard assignments not yet handed to a connection
    pending_assignments: Vec<Vec<usize>>,
    /// fatal condition recorded where `Result` cannot flow (fault
    /// handling disabled, or an unrecoverable membership state)
    fatal: Option<String>,
    st: Scratch,
    body: Vec<u8>,
    events: Vec<u64>,
    /// CRC32-trailer frames on every connection (`wire.crc`; `--no-crc`
    /// disables)
    crc: bool,
    /// server-side scripted faults (`kill-server`, `corrupt-downlink`)
    fault_plan: Option<FaultPlan>,
    /// durable on-disk run log (`--run-dir`); mirrors the in-memory
    /// journal + committed snapshot so a killed server can resume
    runlog: Option<RunLog>,
    /// server-side snapshot cut (method + RNG + totals state) staged when
    /// the cadence round completes, committed together with the worker
    /// blobs once they all land
    staged_snap: Option<runlog::Snapshot>,
    /// resuming from a run log: initial assignments are handed out as
    /// *rejoins* so reconnecting workers get restore + replay
    resume_mode: bool,
    /// journal suffix loaded from the run log, kept as a verification
    /// queue: each regenerated downlink must byte-equal its persisted
    /// counterpart or the resume aborts loudly
    resume_check: VecDeque<(u64, Vec<u8>)>,
    /// per-round client sampling (`--participation tau=K`); `None` or a
    /// full draw means every shard uplinks every round
    participation: Option<Participation>,
    /// the explicit epoch/membership state machine; every join, ack,
    /// sampling verdict, suspicion and eviction below flows through it
    membership: Membership,
    /// `--min-clients M`: start rounds once `M` processes are live and
    /// let the remaining assignments join late (0 = wait for all)
    min_clients: usize,
    /// the round loop has begun — connections arriving from here on are
    /// late joiners and take the rejoin/catch-up path
    started: bool,
    /// monotonic member-id source for [`Conn::member`]
    next_member: u64,
    /// lock-free metrics fed by every loop below; shared with the
    /// `/metrics` endpoint and any `--watch` dashboard. Always present
    /// (a zero-shard placeholder when observability is off) so the hot
    /// paths stay branch-free.
    registry: Arc<crate::obs::Registry>,
    /// `--metrics-addr` HTTP endpoint multiplexed onto `self.poller`;
    /// see the module docs
    metrics_http: Option<HttpEndpoint>,
}

/// Hard cap on the in-memory replay journal. Without checkpoints the
/// journal grows O(rounds × frame size); past this bound the run aborts
/// with a clean protocol error advising `--checkpoint-every` instead of
/// consuming the host's memory.
const MAX_JOURNAL_BYTES: usize = 256 * 1024 * 1024;

/// Server-side state recovered from a durable run log, threaded into
/// [`ElasticServer::run`] to continue a crashed run from its last
/// committed snapshot.
struct ResumeState {
    /// the snapshot round; the loop resumes at `round + 1`
    round: usize,
    /// server RNG stream as of the end of `round`
    server_rng: Rng,
    /// cumulative communication totals through `round`
    acc: RoundTotals,
    /// records the crashed process emitted through `round`, replayed
    /// into the observer stream before the loop continues
    records: Vec<RoundRecord>,
}

pub(crate) fn fd_of_tcp(t: &Tcp) -> i32 {
    #[cfg(unix)]
    {
        t.raw_fd()
    }
    #[cfg(not(unix))]
    {
        // the fallback poller (the only backend off unix) ignores fds
        -1
    }
}

impl ElasticServer {
    fn new(
        listener: TcpListener,
        hello: Hello,
        fault: FaultConfig,
        payload: Payload,
        n_shards: usize,
        dim: usize,
        assignments: Vec<Vec<usize>>,
        checkpoint_every: usize,
    ) -> Result<ElasticServer> {
        listener
            .set_nonblocking(true)
            .context("nonblocking listener")?;
        let mut poller = Poller::new().context("creating poller")?;
        #[cfg(unix)]
        {
            use std::os::unix::io::AsRawFd;
            poller
                .register(listener.as_raw_fd(), LISTENER_TOKEN)
                .context("registering listener")?;
        }
        #[cfg(not(unix))]
        {
            poller
                .register(-1, LISTENER_TOKEN)
                .context("registering listener")?;
        }
        Ok(ElasticServer {
            listener,
            poller,
            conns: Vec::new(),
            standby: Vec::new(),
            hello,
            fault,
            payload,
            n_shards,
            dim,
            journal: JournalWindow::new(),
            snapshot: None,
            pending_snap: None,
            checkpoint_every,
            orphans: Vec::new(),
            orphan_deadline: None,
            membership: Membership::new(assignments.len()),
            pending_assignments: assignments,
            fatal: None,
            st: Scratch {
                down: Downlink::Init { x: Vec::new() },
                down_buf: Vec::new(),
                ups: (0..n_shards).map(|_| Uplink::default()).collect(),
                seen: vec![false; n_shards],
                up_bytes: vec![0; n_shards],
                sampled: false,
                cohort: vec![false; n_shards],
                epoch_buf: Vec::new(),
            },
            body: Vec::new(),
            events: Vec::new(),
            crc: true,
            fault_plan: None,
            runlog: None,
            staged_snap: None,
            resume_mode: false,
            resume_check: VecDeque::new(),
            participation: None,
            min_clients: 0,
            started: false,
            next_member: 0,
            registry: Arc::new(crate::obs::Registry::new(0)),
            metrics_http: None,
        })
    }

    fn live_tokens(&self) -> Vec<usize> {
        self.conns
            .iter()
            .enumerate()
            .filter(|(_, c)| matches!(c, Some(Conn { phase: Phase::Live, .. })))
            .map(|(t, _)| t)
            .collect()
    }

    /// Accept every pending connection; hand out work (initial
    /// assignments first, then the orphan pool) or park as standby.
    fn accept_pending(&mut self) -> Result<()> {
        loop {
            match self.listener.accept() {
                Ok((stream, peer)) => {
                    let tcp = Tcp::new(stream).context("wrapping accepted stream")?;
                    crate::info!("wire", "accepted connection from {peer}");
                    self.registry.worker_connects.inc();
                    self.place(tcp)?;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return Ok(()),
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e).context("accepting worker"),
            }
        }
    }

    /// Give `tcp` work if any is waiting, else park it.
    fn place(&mut self, tcp: Tcp) -> Result<()> {
        if let Some(shards) = self.pending_assignments.pop() {
            // on a run-log resume the "initial" assignments are really
            // rejoins: the worker must restore from the snapshot and
            // replay the journal suffix to land mid-run. Likewise once
            // the round loop has started, a pending assignment handed
            // out now is a *late join* and must catch up the same way.
            let rejoin = self.resume_mode || self.started;
            self.install(tcp, shards, rejoin)?;
        } else if !self.orphans.is_empty() {
            let shards = std::mem::take(&mut self.orphans);
            self.orphan_deadline = None;
            self.install(tcp, shards, true)?;
        } else {
            self.standby.push(tcp);
        }
        Ok(())
    }

    /// Promote parked standby connections while work is waiting.
    fn try_promote(&mut self) -> Result<()> {
        while (!self.pending_assignments.is_empty() || !self.orphans.is_empty())
            && !self.standby.is_empty()
        {
            let tcp = self.standby.pop().expect("checked non-empty");
            self.place(tcp)?;
        }
        Ok(())
    }

    /// Send the `Hello` and start waiting for the ack. A send failure
    /// returns the shards to their queue (the connection was dead on
    /// arrival) instead of erroring the run.
    fn install(&mut self, mut tcp: Tcp, shards: Vec<usize>, rejoin: bool) -> Result<()> {
        tcp.set_nonblocking(true).context("nonblocking conn")?;
        // the Hello (first frame out) already carries the CRC flag bit, so
        // the worker learns the mode from it and mirrors
        tcp.set_crc(self.crc);
        self.hello.shards = shards;
        self.body.clear();
        codec::put_hello(&mut self.body, &self.hello);
        if let Err(e) = tcp.send(&self.body) {
            crate::info!("wire", "handshake send failed ({e}); dropping connection");
            let shards = std::mem::take(&mut self.hello.shards);
            self.requeue(shards, rejoin);
            return Ok(());
        }
        let shards = std::mem::take(&mut self.hello.shards);
        let peer = tcp
            .peer_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "?".into());
        crate::info!(
            "wire",
            "handshake sent to {peer} ({} shard(s){})",
            shards.len(),
            if rejoin { ", rejoin + replay" } else { "" }
        );
        let tok = self
            .conns
            .iter()
            .position(|c| c.is_none())
            .unwrap_or_else(|| {
                self.conns.push(None);
                self.conns.len() - 1
            });
        if let Err(e) = self.poller.register(fd_of_tcp(&tcp), tok as u64) {
            // fd exhaustion or similar: drop this connection but keep the
            // shards recoverable instead of hanging the gather forever
            crate::info!("wire", "poller registration failed ({e}); dropping connection");
            self.requeue(shards, rejoin);
            return Ok(());
        }
        let deadline = if rejoin {
            Some(Instant::now() + self.fault.ack_grace())
        } else {
            None
        };
        let member = self.next_member;
        self.next_member += 1;
        self.conns[tok] = Some(Conn {
            tcp,
            shards,
            phase: Phase::AwaitingAck {
                deadline,
                replay_on_ack: rejoin,
            },
            last_seen: Instant::now(),
            peer,
            member,
        });
        self.membership
            .join(member)
            .context("membership: joining new connection")?;
        Ok(())
    }

    fn requeue(&mut self, shards: Vec<usize>, orphaned: bool) {
        if shards.is_empty() {
            return;
        }
        if orphaned {
            self.orphans.extend(shards);
            self.orphan_deadline = Some(Instant::now() + self.fault.worker_timeout);
        } else {
            self.pending_assignments.push(shards);
        }
    }

    /// Declare connection `tok` dead: discard its partial uplinks for the
    /// in-flight round and queue its shards for recovery. With fault
    /// handling disabled this records a fatal error instead.
    fn mark_dead(&mut self, tok: usize, why: &str) {
        let Some(conn) = self.conns.get_mut(tok).and_then(|c| c.take()) else {
            return;
        };
        let _ = self.poller.deregister(fd_of_tcp(&conn.tcp), tok as u64);
        self.registry.worker_deaths.inc();
        for &s in &conn.shards {
            self.registry.set_live(s, false);
        }
        crate::info!(
            "wire",
            "worker {} ({} shard(s)) lost: {why}",
            conn.peer,
            conn.shards.len()
        );
        if !self.fault.enabled() {
            self.fatal = Some(format!(
                "worker {} failed ({why}) and fault handling is disabled \
                 (--worker-timeout 0)",
                conn.peer
            ));
            return;
        }
        // the machine tolerates deaths in any phase: a member that never
        // acked is still Joined, which suspect() accepts
        if self.membership.suspect(conn.member).is_ok() {
            let _ = self.membership.evict(conn.member);
        }
        self.journal.release(conn.member);
        for &s in &conn.shards {
            // a sampled-out shard was pre-marked seen with a cleared
            // uplink slot; resetting it would stall the gather forever,
            // because a replacement's replay only answers cohort shards
            if self.st.sampled && !self.st.cohort[s] {
                continue;
            }
            self.st.seen[s] = false;
            self.st.up_bytes[s] = 0;
        }
        // a dead worker's shards can no longer report snapshot blobs;
        // abandon the in-flight collection (the next cadence retries)
        self.pending_snap = None;
        let initial = matches!(
            conn.phase,
            Phase::AwaitingAck {
                replay_on_ack: false,
                ..
            }
        );
        self.requeue(conn.shards, !initial);
    }

    /// Catch a connection up to the in-flight round: an announcement
    /// (`TAG_REPLAY` for a rejoiner over its own shards, `TAG_ADOPT` for
    /// `adopt` shards), then — when a snapshot is committed — a
    /// `TAG_RESTORE` frame with the targets' state blobs, then the
    /// retained journal (which starts right after the snapshot round).
    /// Marks the connection dead on any send failure.
    fn send_catchup(&mut self, tok: usize, adopt: Option<&[usize]>) {
        let member = self.conns[tok].as_ref().expect("catchup to live conn").member;
        // adopters splice fresh shards into an already-current process, so
        // they always take the full retained window; a rejoiner's tail is
        // sized by the journal's per-member delivery mark (today a rejoin
        // is always a fresh member, so the tail is the full window too —
        // the mark machinery is the groundwork for per-client sharding)
        let (needs_restore, entries) = match adopt {
            Some(_) => (true, self.journal.entries().cloned().collect::<Vec<_>>()),
            None => self.journal.tail_for(member),
        };
        let count = entries.len();
        let mut announce = Vec::new();
        let restore = needs_restore && self.snapshot.is_some();
        if adopt.is_none() {
            self.registry.worker_rejoins.inc();
        }
        self.registry.journal_replays.add(count as u64);
        if restore {
            self.registry.state_restores.inc();
        }
        match adopt {
            Some(shards) => codec::put_adopt(&mut announce, shards, count, restore),
            None => codec::put_replay(&mut announce, count, restore),
        }
        let mut restore_frame = Vec::new();
        if restore {
            let (round, blobs) = self.snapshot.as_ref().expect("restore implies snapshot");
            let targets: &[usize] = match adopt {
                Some(shards) => shards,
                None => &self.conns[tok].as_ref().expect("catchup to live conn").shards,
            };
            let pairs: Vec<(usize, &[u8])> =
                targets.iter().map(|&s| (s, blobs[s].as_slice())).collect();
            codec::put_restore(&mut restore_frame, *round, &pairs);
        }
        let res = (|| -> std::io::Result<()> {
            let conn = self.conns[tok].as_mut().expect("catchup to live conn");
            conn.tcp.send(&announce)?;
            if !restore_frame.is_empty() {
                conn.tcp.send(&restore_frame)?;
            }
            for entry in &entries {
                if let Some(epoch) = &entry.epoch {
                    conn.tcp.send(epoch)?;
                }
                conn.tcp.send(&entry.down)?;
            }
            Ok(())
        })();
        if let Err(e) = res {
            self.mark_dead(tok, &format!("catch-up send failed: {e}"));
        }
    }

    /// Commit the fully collected snapshot: keep the blobs for future
    /// rejoiners/adopters and truncate the journal up to the snapshot
    /// round — the memory bound the §Perf follow-up asked for.
    fn commit_snapshot(&mut self) {
        let Some((round, slots)) = self.pending_snap.take() else {
            return;
        };
        let blobs: Vec<Vec<u8>> = slots
            .into_iter()
            .map(|s| s.expect("commit only on a complete slot table"))
            .collect();
        debug_assert!(round >= self.journal.base());
        self.journal.truncate_to(round);
        // durable commit: marry the worker blobs to the server-side cut
        // staged when the cadence round finished, and rotate the on-disk
        // base. An IO failure here is fatal — a run log that silently
        // stopped updating would resume from stale state later.
        if let Some(rl) = &mut self.runlog {
            if let Some(mut snap) = self.staged_snap.take() {
                debug_assert_eq!(snap.round, round as u64);
                snap.shard_blobs = blobs.clone();
                if let Err(e) = rl.commit(&snap) {
                    self.fatal = Some(format!("run log: snapshot commit failed: {e}"));
                }
            }
        }
        self.snapshot = Some((round, blobs));
        self.registry.snapshots_committed.inc();
        self.registry.journal_rounds.set(self.journal.len() as u64);
        self.registry.journal_bytes.set(self.journal.bytes() as u64);
        crate::info!(
            "wire",
            "snapshot committed at round {round}; journal truncated to {} frame(s)",
            self.journal.len()
        );
    }

    /// Reassign the orphan pool round-robin across surviving live
    /// connections (grace window expired with no rejoiner).
    fn reassign_orphans(&mut self) -> Result<()> {
        let live = self.live_tokens();
        ensure!(
            !live.is_empty(),
            "all worker processes lost with {} shard(s) orphaned and no \
             replacement within the grace window",
            self.orphans.len()
        );
        let orphans = std::mem::take(&mut self.orphans);
        self.orphan_deadline = None;
        crate::info!(
            "wire",
            "grace window expired: reassigning {} shard(s) across {} survivor(s)",
            orphans.len(),
            live.len()
        );
        let mut groups: Vec<Vec<usize>> = vec![Vec::new(); live.len()];
        for (k, s) in orphans.into_iter().enumerate() {
            groups[k % live.len()].push(s);
        }
        for (tok, extra) in live.into_iter().zip(groups) {
            if extra.is_empty() {
                continue;
            }
            // record ownership first so a send failure orphans the
            // adopted shards together with the rest of the connection
            self.conns[tok]
                .as_mut()
                .expect("live conn")
                .shards
                .extend(extra.iter().copied());
            self.send_catchup(tok, Some(&extra));
        }
        Ok(())
    }

    /// Drain every complete frame currently buffered on connection `tok`.
    /// `gathering` enables uplink decoding (false during the initial
    /// handshake phase, where an uplink is a protocol violation).
    fn drain_conn(&mut self, tok: usize, gathering: bool) -> Result<()> {
        loop {
            if self.conns.get(tok).and_then(|c| c.as_ref()).is_none() {
                return Ok(());
            }
            let got = {
                let conn = self.conns[tok].as_mut().expect("checked above");
                conn.tcp.try_recv(&mut self.body)
            };
            match got {
                Ok(false) => return Ok(()),
                Err(e) => {
                    // a CRC-trailer mismatch surfaces as InvalidData; its
                    // count is split out so a flaky link is diagnosable
                    // from /metrics without grepping logs
                    if e.kind() == std::io::ErrorKind::InvalidData {
                        self.registry.crc_errors.inc();
                    } else {
                        self.registry.conn_errors.inc();
                    }
                    self.mark_dead(tok, &format!("connection error: {e}"));
                    return Ok(());
                }
                Ok(true) => {}
            }
            let now = Instant::now();
            let tag = codec::frame_tag(&self.body)?;
            match tag {
                codec::TAG_HEARTBEAT => {
                    self.conns[tok].as_mut().expect("live conn").last_seen = now;
                }
                codec::TAG_HELLO_ACK => {
                    let conn = self.conns[tok].as_mut().expect("live conn");
                    conn.last_seen = now;
                    let replay = match conn.phase {
                        Phase::AwaitingAck { replay_on_ack, .. } => replay_on_ack,
                        Phase::Live => bail!("worker {} acked twice", conn.peer),
                    };
                    conn.phase = Phase::Live;
                    let member = conn.member;
                    for &s in &conn.shards {
                        self.registry.set_live(s, true);
                    }
                    crate::info!("wire", "worker {} is live", conn.peer);
                    self.membership
                        .activate_member(member)
                        .context("membership: acking worker")?;
                    if replay && (!self.journal.is_empty() || self.snapshot.is_some()) {
                        self.send_catchup(tok, None);
                    }
                }
                codec::TAG_SNAP_STATE => {
                    let (shard, round, blob) = codec::get_snap_state(&self.body)?;
                    ensure!(
                        shard < self.n_shards,
                        "snapshot state for shard {shard}, but n = {}",
                        self.n_shards
                    );
                    {
                        let conn = self.conns[tok].as_mut().expect("live conn");
                        conn.last_seen = now;
                        ensure!(
                            conn.shards.contains(&shard),
                            "worker {} sent snapshot state for shard {shard} it \
                             does not own",
                            conn.peer
                        );
                    }
                    let mut complete = false;
                    if let Some((pr, slots)) = &mut self.pending_snap {
                        if round == *pr && slots[shard].is_none() {
                            slots[shard] = Some(blob.to_vec());
                            complete = slots.iter().all(|s| s.is_some());
                        }
                        // blobs for a superseded round are stale; dropped
                    }
                    if complete {
                        self.commit_snapshot();
                    }
                }
                codec::TAG_UPLINK => {
                    ensure!(gathering, "uplink before the first round started");
                    let shard = codec::peek_uplink_shard(&self.body)?;
                    ensure!(
                        shard < self.n_shards,
                        "uplink for shard {shard}, but n = {}",
                        self.n_shards
                    );
                    {
                        let conn = self.conns[tok].as_mut().expect("live conn");
                        conn.last_seen = now;
                        ensure!(
                            conn.shards.contains(&shard),
                            "worker {} sent an uplink for shard {shard} it does \
                             not own",
                            conn.peer
                        );
                        ensure!(
                            !self.st.seen[shard],
                            "duplicate uplink for shard {shard} from worker {}",
                            conn.peer
                        );
                    }
                    codec::get_uplink(&self.body, self.dim, &mut self.st.ups[shard])?;
                    self.st.seen[shard] = true;
                    self.st.up_bytes[shard] =
                        (codec::FRAME_PREFIX + self.body.len()) as u64;
                }
                codec::TAG_AGG_UPLINK => {
                    // a relay merged its shard group's uplinks into one
                    // frame; the constituents are the workers' bodies
                    // verbatim, so each decodes into its per-shard slot
                    // exactly as if it had arrived on its own connection
                    ensure!(gathering, "uplink before the first round started");
                    let frame_bytes = (codec::FRAME_PREFIX + self.body.len()) as u64;
                    let mut parts = Vec::new();
                    codec::get_agg_uplink(&self.body, &mut parts)?;
                    {
                        let conn = self.conns[tok].as_mut().expect("live conn");
                        conn.last_seen = now;
                        for &(shard, _, _) in &parts {
                            ensure!(
                                shard < self.n_shards,
                                "aggregated uplink for shard {shard}, but n = {}",
                                self.n_shards
                            );
                            ensure!(
                                conn.shards.contains(&shard),
                                "relay {} aggregated an uplink for shard {shard} \
                                 it does not own",
                                conn.peer
                            );
                            ensure!(
                                !self.st.seen[shard],
                                "duplicate uplink for shard {shard} from relay {}",
                                conn.peer
                            );
                        }
                    }
                    let mut constituent_bytes = 0u64;
                    for &(shard, start, end) in &parts {
                        codec::get_uplink(
                            &self.body[start..end],
                            self.dim,
                            &mut self.st.ups[shard],
                        )?;
                        self.st.seen[shard] = true;
                        self.st.up_bytes[shard] = (end - start) as u64;
                        constituent_bytes += (end - start) as u64;
                    }
                    // the shared envelope (prefix, bitmap, lengths) lands
                    // on the group's first shard so the per-round total
                    // matches what the wire actually carried
                    self.st.up_bytes[parts[0].0] += frame_bytes - constituent_bytes;
                    self.registry.relay_merged_frames.inc();
                    self.registry.relay_fan_in.add(parts.len() as u64);
                    self.registry.relay_forwarded_bytes.add(frame_bytes);
                }
                other => bail!("server: unexpected frame tag {other}"),
            }
        }
    }

    /// Fault bookkeeping: silence timeouts, ack deadlines, standby
    /// promotion and grace-window reassignment. `gathering` scopes the
    /// silence check to connections that still owe uplinks.
    fn police(&mut self, gathering: bool) -> Result<()> {
        if let Some(msg) = self.fatal.take() {
            bail!("{msg}");
        }
        if !self.fault.enabled() {
            return Ok(());
        }
        let now = Instant::now();
        for tok in 0..self.conns.len() {
            let verdict = match &self.conns[tok] {
                Some(conn) => match &conn.phase {
                    Phase::AwaitingAck {
                        deadline: Some(d), ..
                    } if now > *d => Some("handshake ack deadline exceeded"),
                    Phase::Live
                        if gathering
                            && conn.shards.iter().any(|&s| !self.st.seen[s])
                            && now.duration_since(conn.last_seen) > self.fault.worker_timeout =>
                    {
                        Some("silent past the grace window while owing uplinks")
                    }
                    _ => None,
                },
                None => None,
            };
            if let Some(why) = verdict {
                self.mark_dead(tok, why);
            }
        }
        self.try_promote()?;
        if !self.orphans.is_empty() {
            match self.orphan_deadline {
                Some(d) if now > d => self.reassign_orphans()?,
                _ => {}
            }
        }
        Ok(())
    }

    /// One multiplexed wait-and-dispatch step.
    fn pump(&mut self, gathering: bool) -> Result<()> {
        self.police(gathering)?;
        let mut events = std::mem::take(&mut self.events);
        self.poller
            .wait(WAIT_SLICE, &mut events)
            .context("poller wait")?;
        // the listener is polled opportunistically as well: the fallback
        // backend reports everything, and a pending connect is cheap to
        // test for (one nonblocking accept)
        self.accept_pending()?;
        for &tok in events.iter().filter(|&&t| t != LISTENER_TOKEN) {
            // HTTP scrape traffic shares the poller but never reaches the
            // worker dispatch: the token space is partitioned (see the
            // module docs) and endpoint failures are absorbed — a broken
            // scraper must not kill the run
            if tok == METRICS_LISTENER_TOKEN || tok >= HTTP_CONN_TOKEN_BASE {
                if let Some(ep) = self.metrics_http.as_mut() {
                    ep.on_token(tok, &mut self.poller);
                }
                continue;
            }
            self.drain_conn(tok as usize, gathering)?;
        }
        self.events = events;
        Ok(())
    }

    /// Accept + handshake until every initial assignment is live. `Hello`s
    /// go out the moment a connection arrives, so all workers rebuild
    /// their dataset + smoothness state concurrently (cost = max build
    /// time, not the sum); acks are collected multiplexed. A connection
    /// that dies mid-handshake returns its assignment to the queue for
    /// the next accept.
    fn accept_initial(&mut self) -> Result<()> {
        let want = self.pending_assignments.len();
        crate::info!(
            "wire",
            "waiting for {want} worker process(es) ({} shards total)",
            self.n_shards
        );
        // Completion is *shard coverage*, not a fixed connection count:
        // a startup-phase death whose shards get reassigned to survivors
        // can make the run viable with fewer than `want` processes, and
        // waiting on the count would hang forever. With `--min-clients M`
        // the floor relaxes further: rounds may start once M processes
        // are live — the remaining assignments stay queued for late
        // joiners, whose cohort shards simply block the gather until
        // they arrive and catch up.
        let need = if self.min_clients > 0 {
            self.min_clients.min(want)
        } else {
            want
        };
        loop {
            let total = self.conns.iter().flatten().count();
            let all_live = self
                .conns
                .iter()
                .flatten()
                .all(|c| matches!(c.phase, Phase::Live));
            let done = if self.min_clients > 0 {
                self.orphans.is_empty() && total >= need && all_live
            } else {
                self.pending_assignments.is_empty()
                    && self.orphans.is_empty()
                    && total > 0
                    && all_live
            };
            if done {
                break;
            }
            self.pump(false)?;
        }
        crate::info!(
            "wire",
            "{} live worker process(es); {} assignment(s) left for late joiners",
            self.live_tokens().len(),
            self.pending_assignments.len()
        );
        self.membership
            .warmup()
            .context("membership: entering warmup")?;
        self.membership
            .activate()
            .context("membership: activating round loop")?;
        self.flush_membership(0);
        Ok(())
    }

    /// One elastic round: journal + broadcast, fault-tolerant gather,
    /// apply, and — on the `checkpoint_every` cadence — a snapshot
    /// request. Accounting counts only the uplink frame finally applied
    /// per shard and the live broadcast fan-out — recovery
    /// retransmissions (journal replays, snapshot frames) are excluded,
    /// so `coords_up` matches the sim driver.
    fn round(
        &mut self,
        round: usize,
        server: &mut dyn ServerAlgo,
        server_rng: &mut Rng,
        float_bits: u32,
        phases: &mut PhaseTimer,
    ) -> Result<RoundTotals> {
        let mut t = RoundTotals::default();
        let t_down = Instant::now();
        server.downlink_into(&mut self.st.down);
        self.st.down_buf.clear();
        codec::put_downlink(&mut self.st.down_buf, &self.st.down, self.payload)?;
        phases.add("server_downlink", t_down.elapsed());

        // resume verification: the downlink regenerated for this round
        // must byte-equal the copy the crashed run persisted, or the
        // "resume is bitwise identical" guarantee is already broken —
        // abort loudly rather than silently diverge
        if let Some((jr, expect)) = self.resume_check.pop_front() {
            ensure!(
                jr == round as u64 && expect == self.st.down_buf,
                "resume verification failed at round {round}: the \
                 regenerated downlink does not match the persisted journal \
                 (round-log entry is for round {jr}); refusing to continue \
                 a diverged run"
            );
        }

        // draw this round's cohort (deterministic in seed + round, so sim,
        // threaded and distributed agree bitwise) and move the membership
        // machine's sampling verdicts before anything hits the wire
        let sampled = self.participation.is_some();
        self.st.sampled = sampled;
        if let Some(p) = &mut self.participation {
            let mask = p.draw(round as u64);
            self.st.cohort.clear();
            self.st.cohort.extend_from_slice(mask);
        }
        if sampled {
            let mut in_cohort: Vec<u64> = Vec::new();
            for conn in self.conns.iter().flatten() {
                if conn.shards.iter().any(|&s| self.st.cohort[s]) {
                    in_cohort.push(conn.member);
                }
            }
            self.membership
                .begin_round(|m| in_cohort.contains(&m))
                .context("membership: beginning round")?;
            epoch::put_epoch(
                &mut self.st.epoch_buf,
                round,
                self.membership.epoch(),
                &self.st.cohort,
            );
        }

        if self.fault.enabled() {
            // the journal only exists to feed rejoin/adoption replays;
            // fail-fast mode can never consume it, so don't grow it
            let entry_epoch = if sampled {
                Some(self.st.epoch_buf.clone())
            } else {
                None
            };
            self.journal.push(round, entry_epoch, self.st.down_buf.clone());
            ensure!(
                self.journal.bytes() <= MAX_JOURNAL_BYTES,
                "replay journal exceeds {} MiB with no committed snapshot \
                 to truncate it; set --checkpoint-every to bound recovery \
                 memory",
                MAX_JOURNAL_BYTES / (1024 * 1024)
            );
            self.registry.journal_rounds.set(self.journal.len() as u64);
            self.registry.journal_bytes.set(self.journal.bytes() as u64);
        }
        if let Some(rl) = &mut self.runlog {
            rl.append_downlink(round as u64, &self.st.down_buf)
                .context("run log: persisting downlink")?;
        }
        let tau = self
            .participation
            .as_ref()
            .map(|p| p.tau())
            .unwrap_or(self.n_shards);
        t.coords_down = (self.st.down.coords() * tau) as u64;
        let frame_len = (codec::FRAME_PREFIX + self.st.down_buf.len()) as u64;

        // scripted corruption: flip one seeded bit in the frame sent to
        // one connection. The worker's CRC check turns it into a
        // connection error; the rejoin path retransmits the clean journal
        // copy. Accounting is untouched — the corrupted frame was sent.
        let corrupt = self
            .fault_plan
            .as_ref()
            .and_then(|p| p.corrupt_downlink_at(round as u64));
        let corrupt_tok = corrupt.map(|(shard, _)| {
            let live = self.live_tokens();
            shard
                .and_then(|s| {
                    live.iter()
                        .copied()
                        .find(|&t| self.conns[t].as_ref().is_some_and(|c| c.shards.contains(&s)))
                })
                .or_else(|| live.first().copied())
        });

        self.st.seen.fill(false);
        self.st.up_bytes.fill(0);
        if sampled {
            // sampled-out shards owe nothing this round: pre-mark them
            // seen with cleared uplink slots so the gather (and police's
            // silence check) never waits on an idle worker, and skip
            // their downlink entirely — that is the bandwidth the paper's
            // partial participation buys
            for s in 0..self.n_shards {
                if !self.st.cohort[s] {
                    self.st.seen[s] = true;
                    membership::clear_uplink(&mut self.st.ups[s]);
                }
            }
            // the epoch announcement goes to *every* live connection
            // (sampled-out workers must learn they are idle); it is
            // protocol overhead, excluded from bytes_down
            for tok in self.live_tokens() {
                let res = {
                    let conn = self.conns[tok].as_mut().expect("live conn");
                    let r = conn.tcp.send(&self.st.epoch_buf);
                    // grace-window fix: a fully sampled-out worker owes
                    // nothing this round — restart its silence clock so K
                    // consecutive idle rounds cannot masquerade as K
                    // rounds of deadly silence the moment it re-enters
                    // the cohort
                    if r.is_ok() && !conn.shards.iter().any(|&s| self.st.cohort[s]) {
                        conn.last_seen = Instant::now();
                    }
                    r
                };
                if let Err(e) = res {
                    self.mark_dead(tok, &format!("epoch broadcast failed: {e}"));
                }
            }
        }
        for tok in self.live_tokens() {
            let owes = {
                let conn = self.conns[tok].as_ref().expect("live conn");
                !sampled || conn.shards.iter().any(|&s| self.st.cohort[s])
            };
            if !owes {
                continue;
            }
            let res = {
                let conn = self.conns[tok].as_mut().expect("live conn");
                if corrupt_tok == Some(Some(tok)) {
                    let bit = corrupt.expect("corrupt_tok implies corrupt").1;
                    crate::info!(
                        "wire",
                        "fault plan: corrupting round-{round} downlink to {} (bit {bit})",
                        conn.peer
                    );
                    conn.tcp.corrupt_next_frame(bit);
                }
                conn.tcp.send(&self.st.down_buf)
            };
            match res {
                Ok(()) => t.bytes_down += frame_len,
                Err(e) => self.mark_dead(tok, &format!("broadcast failed: {e}")),
            }
        }

        // gather: complete when every shard's uplink (from its *current*
        // owner) has been applied to the slot table
        let t_wait = Instant::now();
        while !self.st.seen.iter().all(|&s| s) {
            self.pump(true)?;
        }
        phases.add("wire_wait", t_wait.elapsed());

        for i in 0..self.n_shards {
            t.coords_up += self.st.ups[i].coords() as u64;
            t.bits_up += crate::coordinator::bits_of(&self.st.ups[i], self.dim, float_bits);
            t.bytes_up += self.st.up_bytes[i];
        }
        // reweight cohort uplinks by n/τ *after* accounting (the wire
        // carried the unweighted values) and *before* apply, exactly as
        // the sim and threaded drivers do — keeping the estimator
        // unbiased and the trajectories bitwise aligned
        if let Some(p) = &self.participation {
            let w = p.weight();
            for s in 0..self.n_shards {
                if self.st.cohort[s] {
                    membership::reweight_uplink(&mut self.st.ups[s], w);
                }
            }
        }
        let t_apply = Instant::now();
        server.apply(&self.st.ups, server_rng);
        phases.add("server_apply", t_apply.elapsed());
        if self.fault.enabled() {
            // every connection live at apply time has consumed (or been
            // excused from) everything through this round
            for conn in self.conns.iter().flatten() {
                if matches!(conn.phase, Phase::Live) {
                    self.journal.mark(conn.member, round);
                }
            }
        }

        // checkpoint cadence: ask every live worker for its shards' state
        // as of the end of this round. Workers answer before touching the
        // next downlink (frames are processed in order), so the blobs are
        // a consistent cut; they are collected during the next gather and
        // committed when the last one lands. Snapshots matter when fault
        // handling can consume them OR a durable run log persists them.
        if self.checkpoint_every > 0
            && (self.fault.enabled() || self.runlog.is_some())
            && round % self.checkpoint_every == 0
        {
            let mut req = Vec::new();
            codec::put_snap_req(&mut req, round);
            self.pending_snap = Some((round, vec![None; self.n_shards]));
            for tok in self.live_tokens() {
                let res = {
                    let conn = self.conns[tok].as_mut().expect("live conn");
                    conn.tcp.send(&req)
                };
                if let Err(e) = res {
                    self.mark_dead(tok, &format!("snapshot request failed: {e}"));
                }
            }
        }
        self.flush_membership(round);
        Ok(t)
    }

    /// Drain the membership machine's events into the run log (structural
    /// events only — per-round sampling verdicts would bloat it), the
    /// registry gauges and the info log.
    fn flush_membership(&mut self, round: usize) {
        let events = self.membership.drain_events();
        for ev in &events {
            let code = ev.kind_code();
            crate::info!(
                "wire",
                "membership: {} {} (epoch {})",
                MembershipEvent::kind_name(code),
                ev.member(),
                self.membership.epoch()
            );
            // SampledIn/SampledOut (codes 3, 4) recur every round; the
            // run log keeps only the structural history
            if code != 3 && code != 4 {
                if let Some(rl) = &mut self.runlog {
                    rl.membership(MembershipRecord {
                        round: round as u64,
                        epoch: self.membership.epoch(),
                        kind: code,
                        member: ev.member(),
                    });
                }
            }
        }
        self.registry.epoch.set(self.membership.epoch());
        let tau = self
            .participation
            .as_ref()
            .map(|p| p.tau())
            .unwrap_or(self.n_shards);
        self.registry.cohort_size.set(tau as u64);
        use crate::coordinator::membership::MemberState as MS;
        for s in [
            MS::Joined,
            MS::Active,
            MS::SampledOut,
            MS::Suspected,
            MS::Evicted,
        ] {
            self.registry
                .set_members(s.name(), self.membership.count(s) as u64);
        }
    }

    /// Full run: same stopping/recording policy as every other driver,
    /// metrics through `obs`. `denom` is the residual normalizer
    /// `‖x0 − x*‖²` — passed in (rather than read off the iterate)
    /// because a resumed server stands up mid-run, where the iterate is
    /// no longer `x0`. With `resume` set, the run continues from the
    /// recovered round: loaded records replay into the observer stream
    /// and the loop picks up at the next round.
    fn run(
        &mut self,
        server: &mut dyn ServerAlgo,
        name: &str,
        x_star: &[f64],
        denom: f64,
        cfg: &RunConfig,
        resume: Option<ResumeState>,
        obs: &mut dyn RoundObserver,
    ) -> Result<RunOutcome> {
        let mut acc = RoundTotals::default();
        let mut phases = PhaseTimer::new();
        let ticker = Ticker::new(cfg);
        let mut reached = false;
        let (start_round, mut server_rng, mut stopped) = match resume {
            Some(rs) => {
                acc = rs.acc;
                let stopped = ticker.replay(&rs.records, obs);
                if let Some(last) = rs.records.last() {
                    self.registry.round.write(last);
                }
                (rs.round, rs.server_rng, stopped)
            }
            None => {
                let (stopped, rec0) = ticker.start_with_record(obs);
                if let Some(rl) = &mut self.runlog {
                    rl.record(&rec0);
                }
                // seed the scrapeable round block so /metrics shows the
                // starting residual before the first recorded round lands
                self.registry.round.write(&rec0);
                (0, Rng::new(cfg.seed).derive(u64::MAX), stopped)
            }
        };
        let mut rounds_run = start_round;
        let mut failure = None;
        // from here on, a freshly placed assignment is a late join
        self.started = true;

        if !stopped {
            for round in (start_round + 1)..=cfg.max_rounds {
                rounds_run = round;
                // timed explicitly (not via `phases.time`) because
                // `round` itself records sub-spans into the same timer
                let t_round = Instant::now();
                let totals =
                    self.round(round, server, &mut server_rng, cfg.float_bits, &mut phases);
                let round_elapsed = t_round.elapsed();
                phases.add("dist_round", round_elapsed);
                let totals = match totals {
                    Ok(t) => t,
                    Err(e) => {
                        failure = Some(e);
                        break;
                    }
                };
                self.registry.rounds.inc();
                self.registry
                    .round_duration
                    .observe(round_elapsed.as_secs_f64());
                acc.accumulate(&totals);

                // stage the server-side snapshot cut *now*, while the state
                // is exactly end-of-round: the worker blobs complete during
                // the next round's gather, by which time `downlink_into`
                // has already mutated the server again
                if self.runlog.is_some()
                    && self.pending_snap.as_ref().is_some_and(|(r, _)| *r == round)
                {
                    let mut server_blob = Vec::new();
                    server.save_state(&mut server_blob);
                    let mut rng_blob = Vec::new();
                    server_rng.save_state(&mut rng_blob);
                    self.staged_snap = Some(runlog::Snapshot {
                        round: round as u64,
                        server_blob,
                        rng_blob,
                        totals: acc,
                        shard_blobs: Vec::new(),
                    });
                }

                let res = vector::dist2(server.iterate(), x_star) / denom;
                let (tick, rec) =
                    ticker.tick_with_record(round, res, &acc, server.iterate(), &phases, obs);
                if let Some(rec) = rec.as_ref() {
                    // the block and the record are cut from the same
                    // `acc`, giving the exact-equality guarantee between
                    // /metrics byte counters and the CSV columns
                    self.registry.round.write(rec);
                }
                if let (Some(rl), Some(rec)) = (self.runlog.as_mut(), rec.as_ref()) {
                    rl.record(rec);
                }
                match tick {
                    Tick::Continue => {}
                    Tick::ReachedTarget => {
                        reached = true;
                        break;
                    }
                    Tick::Stopped => {
                        stopped = true;
                        break;
                    }
                }

                // planned server death: abort WITHOUT the clean shutdown.
                // Workers must see a closed socket (as under SIGKILL), not
                // a Stop frame — the chaos tests rely on them riding the
                // restart out through their retry loop.
                if self
                    .fault_plan
                    .as_ref()
                    .is_some_and(|p| p.kill_server_after(round as u64))
                {
                    crate::info!("wire", "fault plan: killing server after round {round}");
                    bail!("{KILLED_MARKER} after round {round}");
                }
            }
        }

        // the machine's terminal transition; tolerated on failure paths
        // where the state may be mid-transition
        if self.membership.cooldown().is_ok() {
            self.flush_membership(rounds_run);
        }
        self.shutdown();
        if let Some(e) = failure {
            return Err(e);
        }
        // clean completion: seal the run log (full history into the base,
        // finished marker, journal truncated). Failure/kill paths return
        // above, leaving the log resumable.
        if let Some(rl) = &mut self.runlog {
            rl.finish().context("run log: finishing")?;
        }
        Ok(RunOutcome {
            method: name.to_string(),
            final_x: server.iterate().to_vec(),
            rounds_run,
            reached_target: reached,
            stopped_by_observer: stopped,
            phases,
        })
    }

    /// Release every connection — live, handshaking and parked — with a
    /// `Stop` frame (standby replacements would otherwise wait forever
    /// for a `Hello`).
    fn shutdown(&mut self) {
        for conn in self.conns.iter_mut().flatten() {
            let _ = conn.tcp.send(&[codec::TAG_STOP]);
        }
        for tcp in self.standby.iter_mut() {
            let _ = tcp.send(&[codec::TAG_STOP]);
        }
    }
}

// ---- entry points ------------------------------------------------------

/// The elastic TCP server core behind [`Driver::Distributed`] +
/// [`DistTransport::Tcp`]: build the server half, accept `cfg.wire.workers`
/// worker processes, survive their deaths, stream metrics through `obs`.
/// Called by [`Session::run`](crate::coordinator::Session::run); `spec` /
/// `prep` / `run_cfg` are the Session's resolved parts.
pub(crate) fn serve_observed(
    listener: TcpListener,
    cfg: &ExperimentConfig,
    spec: &MethodSpec,
    prep: &Prepared,
    run_cfg: &RunConfig,
    metrics: Option<Arc<crate::obs::Registry>>,
    obs: &mut dyn RoundObserver,
) -> Result<RunOutcome> {
    let method_name = spec.name.clone();
    let payload = run_cfg.payload;
    // the Hello's single seed feeds both the worker's dataset synthesis
    // and its RNG stream derivation, and its mu feeds the worker's
    // smoothness rebuild — they must match what the server side used
    ensure!(
        run_cfg.seed == cfg.seed,
        "the TCP driver cannot override the seed per run (workers rebuild \
         the dataset from it); set cfg.seed instead"
    );
    ensure!(
        spec.mu.to_bits() == cfg.mu.to_bits(),
        "the TCP driver needs spec.mu == cfg.mu (workers rebuild smoothness \
         from the config recipe)"
    );
    ensure!(
        payload.is_lossless() || method_name != "diana++",
        "diana++ requires the lossless f64 payload (worker model replicas \
         are updated by incremental sparse downlinks; quantization error \
         would accumulate unboundedly)"
    );
    let n = prep.shards.len();
    // direct peers: worker processes in the flat topology, or the first
    // relay tier when --relay is set (each relay fans the rest of the
    // tree out and merges its subtree's uplinks into TAG_AGG_UPLINK
    // frames — the server decodes each constituent exactly as if it had
    // arrived alone, so the topology cannot perturb the trajectory)
    let procs = cfg.wire.direct_peers(n)?;
    let mut method = build(spec, &prep.sm)?;
    // server half only; the workers live in their own processes
    method.workers.clear();
    let fault = FaultConfig {
        worker_timeout: Duration::from_secs_f64(cfg.wire.worker_timeout.max(0.0)),
    };
    let participation =
        Participation::from_run(run_cfg.participation, cfg.seed, n)?.filter(|p| !p.is_full());
    ensure!(
        !(participation.is_some() && method_name == "diana++"),
        "diana++ keeps per-worker model replicas stepped by every downlink; \
         partial participation would let them diverge — use diana+ or tau=n"
    );
    let min_clients = cfg.wire.min_clients;
    ensure!(
        min_clients <= procs,
        "--min-clients {min_clients} exceeds the worker process count {procs}"
    );
    ensure!(
        min_clients == 0 || fault.enabled(),
        "--min-clients needs fault handling for late joiners; set \
         --worker-timeout > 0"
    );

    crate::info!(
        "wire",
        "serving {} on {} — {} direct peer(s){}, {} shards, payload {}, \
         worker-timeout {:?}, checkpoint-every {}",
        method_name,
        cfg.wire.listen,
        procs,
        match cfg.wire.relays.as_deref() {
            Some(t) => format!(" (relay topology {t})"),
            None => String::new(),
        },
        n,
        payload.name(),
        fault.worker_timeout,
        run_cfg.checkpoint_every
    );
    // round-robin shard assignment, ascending within each process
    let mut assignment: Vec<Vec<usize>> = vec![Vec::new(); procs];
    for i in 0..n {
        assignment[i % procs].push(i);
    }
    let hello = Hello {
        dataset: cfg.dataset.clone(),
        // only ship data_dir when the dataset file actually resolved on
        // this side — otherwise the server trained on synthetic data and
        // the worker must synthesize too (it rejects a dangling data_dir)
        data_dir: cfg
            .data_dir
            .as_ref()
            .filter(|d| {
                d.join(&cfg.dataset).is_file()
                    || d.join(format!("{}.txt", cfg.dataset)).is_file()
            })
            .map(|d| d.display().to_string()),
        seed: run_cfg.seed,
        workers: n,
        mu: spec.mu,
        tau: spec.tau,
        sampling: spec.sampling,
        method: method_name.clone(),
        practical_adiana: spec.practical_adiana,
        compressor: spec.compressor,
        sa_levels: spec.sa_levels,
        sa_weighting: spec.sa_weighting,
        payload,
        need_global: method_name == "diana++",
        shards: Vec::new(),
        x0: spec.x0.clone(),
    };
    let dim = spec.x0.len();

    let fault_plan = match cfg.wire.fault_plan.as_deref() {
        Some(spec) => {
            let plan = FaultPlan::parse(spec, cfg.seed)?;
            ensure!(
                !plan.has_server_events() || fault.enabled(),
                "--fault-plan server events (kill-server, corrupt-downlink) \
                 need fault handling; set --worker-timeout > 0"
            );
            Some(plan)
        }
        None => None,
    };

    // durable run log: load (and resume) or create, refusing to marry a
    // log to a different experiment. The identity hash covers only the
    // trajectory-determining fields, so a restart may legitimately drop
    // an already-fired --fault-plan or change plumbing like --listen.
    let chash = runlog::config_hash(&cfg.canonical_identity());
    let mut resume: Option<ResumeState> = None;
    let mut resume_snapshot: Option<(usize, Vec<Vec<u8>>)> = None;
    let mut resume_check: VecDeque<(u64, Vec<u8>)> = VecDeque::new();
    let mut runlog_handle: Option<RunLog> = None;
    if let Some(dir) = cfg.wire.run_dir.as_deref() {
        let dir = Path::new(dir);
        match RunLog::load(dir).with_context(|| format!("run log: loading {}", dir.display()))? {
            Some(loaded) => {
                ensure!(
                    !loaded.finished,
                    "run log in {} is a finished run; refusing to overwrite or \
                     resume it (inspect with `smx runs show`, or point \
                     --run-dir at a fresh directory)",
                    dir.display()
                );
                ensure!(
                    loaded.config_hash == chash,
                    "run log in {} belongs to a different experiment \
                     (config identity {:#018x}, ours {:#018x}); refusing to resume",
                    dir.display(),
                    loaded.config_hash,
                    chash
                );
                ensure!(
                    loaded.seed == cfg.seed,
                    "run log in {} was seeded with {}, not {}; refusing to resume",
                    dir.display(),
                    loaded.seed,
                    cfg.seed
                );
                if let Some(snap) = &loaded.snapshot {
                    ensure!(
                        snap.shard_blobs.len() == n,
                        "run log snapshot holds {} shard blob(s), expected {n}",
                        snap.shard_blobs.len()
                    );
                    ensure!(
                        method.server.load_state(&snap.server_blob),
                        "run log snapshot: malformed or wrong-shape server state"
                    );
                    let server_rng = Rng::load_state(&snap.rng_blob)
                        .context("run log snapshot: malformed server RNG state")?;
                    crate::info!(
                        "wire",
                        "resuming from {} at round {} ({} record(s), {} journaled \
                         round(s) to verify)",
                        dir.display(),
                        snap.round,
                        loaded.records.len(),
                        loaded.journal.len()
                    );
                    resume = Some(ResumeState {
                        round: snap.round as usize,
                        server_rng,
                        acc: snap.totals,
                        records: loaded.records.clone(),
                    });
                    resume_snapshot = Some((snap.round as usize, snap.shard_blobs.clone()));
                } else {
                    crate::info!(
                        "wire",
                        "run log in {} has no committed snapshot; restarting from \
                         round 0 ({} journaled round(s) to verify)",
                        dir.display(),
                        loaded.journal.len()
                    );
                }
                resume_check = loaded.journal.iter().cloned().collect();
                runlog_handle =
                    Some(RunLog::reopen(dir, &loaded).context("run log: reopening")?);
            }
            None => {
                // the stored config JSON is what lets `smx runs resume`
                // stand the run back up without the original command line
                runlog_handle = Some(
                    RunLog::create(dir, chash, cfg.seed, &cfg.to_json().to_string())
                        .with_context(|| format!("run log: creating {}", dir.display()))?,
                );
            }
        }
    }

    let mut es = ElasticServer::new(
        listener,
        hello,
        fault,
        payload,
        n,
        dim,
        assignment,
        run_cfg.checkpoint_every,
    )?;
    es.crc = cfg.wire.crc;
    es.fault_plan = fault_plan;
    es.runlog = runlog_handle;
    es.resume_check = resume_check;
    es.participation = participation;
    es.min_clients = min_clients;
    if min_clients > 0 {
        // the machine's member floor is the relaxed one; the remaining
        // assignments are handed to late joiners mid-run
        es.membership = Membership::new(min_clients);
    }
    // observability: adopt the Session's registry (sized per shard) or
    // make one if only --metrics-addr asked for it, then multiplex the
    // HTTP listener onto the server's poller
    es.registry = metrics.unwrap_or_else(|| Arc::new(crate::obs::Registry::new(n)));
    if let Some(addr) = cfg.wire.metrics_addr.as_deref() {
        let ep = HttpEndpoint::bind(addr, es.registry.clone())
            .with_context(|| format!("binding metrics endpoint {addr}"))?;
        ep.register(&mut es.poller)
            .context("registering metrics listener")?;
        if let Ok(local) = ep.local_addr() {
            crate::info!("wire", "metrics endpoint on http://{local}/metrics");
        }
        es.metrics_http = Some(ep);
    }
    if let Some((round, blobs)) = resume_snapshot {
        // initial assignments become rejoins: every connecting worker is
        // restored to the snapshot round over the existing catch-up path
        es.resume_mode = true;
        es.journal.truncate_to(round);
        es.snapshot = Some((round, blobs));
    }
    // the residual normalizer is ‖x0 − x*‖², NOT distance-from-current-
    // iterate: a resumed server stands up mid-run where they differ
    let denom = vector::dist2(&spec.x0, &prep.x_star).max(1e-300);
    es.accept_initial()?;
    es.run(
        method.server.as_mut(),
        &method.name,
        &prep.x_star,
        denom,
        run_cfg,
        resume,
        obs,
    )
}

/// `smx serve`: prepare the problem, run the elastic server (accept
/// workers, survive their deaths, accept rejoiners), write the residual
/// curve CSV. With `check_sim`, re-run the identical configuration under
/// [`Driver::Sim`] and fail unless the iterates are bitwise identical
/// (requires the lossless `f64` payload) — the CI smoke's assertion,
/// which holds even across worker deaths, rejoins and snapshot-resumes.
pub fn serve(cfg: &ExperimentConfig, check_sim: bool) -> Result<()> {
    let listener = TcpListener::bind(&cfg.wire.listen)
        .with_context(|| format!("binding {}", cfg.wire.listen))?;
    serve_on(listener, cfg, check_sim)
}

/// [`serve`] against an already-bound listener (tests bind port 0 and
/// hand the ephemeral address to their worker threads). Both the
/// distributed run and the `check_sim` reference go through [`Session`].
pub fn serve_on(listener: TcpListener, cfg: &ExperimentConfig, check_sim: bool) -> Result<()> {
    ensure!(
        cfg.methods.len() == 1,
        "smx serve drives exactly one method; got {:?}",
        cfg.methods
    );
    ensure!(
        cfg.engine == EngineKind::Native,
        "smx serve supports the native engine only"
    );
    let method_name = cfg.methods[0].clone();
    let payload = cfg.wire.payload;
    if check_sim {
        ensure!(
            payload.is_lossless(),
            "--check-sim requires the f64 payload (got {})",
            payload.name()
        );
    }
    let prep = runner::prepare(cfg)?;
    let mut session = Session::from_config(cfg)
        .prepared(&prep)
        .driver(Driver::Distributed {
            transport: DistTransport::Tcp {
                listen: cfg.wire.listen.clone(),
                workers: cfg.wire.workers,
                relays: cfg.wire.relays.clone(),
            },
        })
        .tcp_listener(listener);
    // one registry serves both consumers: the /metrics endpoint (inside
    // serve_observed) and the --watch dashboard's liveness row
    if cfg.watch || cfg.wire.metrics_addr.is_some() {
        let reg = Arc::new(crate::obs::Registry::new(prep.shards.len()));
        if cfg.watch {
            session = session.observer(crate::obs::WatchObserver::new().registry(reg.clone()));
        }
        session = session.metrics_registry(reg);
    }
    let result = session.run()?;

    let last = result.records.last().unwrap();
    println!(
        "distributed {method_name} on {}: {} rounds, residual {:.6e}",
        cfg.dataset,
        result.rounds_run,
        result.final_residual()
    );
    println!(
        "  measured bytes_up {} (modeled bits_up/8 = {}), bytes_down {}",
        last.bytes_up,
        last.bits_up / 8,
        last.bytes_down
    );
    let path = cfg.out_dir.join(format!("distributed_{}.csv", cfg.dataset));
    crate::util::write_csv(&path, &RunResult::csv_header(), &result.csv_rows())?;
    crate::info!("wire", "wrote {}", path.display());

    if check_sim {
        let r_sim = Session::from_config(cfg)
            .prepared(&prep)
            .driver(Driver::Sim)
            .run()?;
        // bit-level comparison: value equality would let a -0.0/+0.0
        // regression slip through the "bitwise identical" guarantee
        let bits = |xs: &[f64]| xs.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        ensure!(
            bits(&r_sim.final_x) == bits(&result.final_x),
            "check-sim FAILED: distributed iterates diverged from the sim \
             driver (residual {:.6e} vs {:.6e})",
            result.final_residual(),
            r_sim.final_residual()
        );
        ensure!(
            r_sim.records.last().unwrap().coords_up == last.coords_up,
            "check-sim FAILED: communication accounting diverged"
        );
        println!(
            "check-sim OK: bitwise identical to the sim driver over {} rounds",
            result.rounds_run
        );
    }
    Ok(())
}

/// `smx worker --connect ADDR`: join (or rejoin) a serve run.
pub fn worker_connect(addr: &str) -> Result<()> {
    worker_connect_with(addr, WorkerOpts::default())
}

/// [`worker_connect`] with chaos/pinning/resilience options: run
/// [`worker_session`] and, whenever it fails with a *connection*-class
/// error (server restarted, socket reset, CRC-detected corruption),
/// retry the whole session — reconnect, re-handshake, rejoin — with
/// capped exponential backoff. Protocol violations and chaos assertions
/// propagate immediately; they would only recur on retry.
pub fn worker_connect_with(addr: &str, opts: WorkerOpts) -> Result<()> {
    if let Some(core) = opts.pin {
        let ok = crate::util::affinity::pin_to_core(core);
        crate::info!(
            "wire",
            "pinning to core {core}: {}",
            if ok { "ok" } else { "unsupported (running unpinned)" }
        );
    }
    let mut attempt: usize = 0;
    loop {
        match worker_session(addr, &opts) {
            Ok(()) => return Ok(()),
            Err(e) => {
                let msg = format!("{e:#}");
                if attempt >= opts.max_retries || !is_connection_error(&msg) {
                    return Err(e);
                }
                attempt += 1;
                let wait = retry_backoff(opts.retry_base_ms, attempt);
                crate::info!(
                    "wire",
                    "connection lost ({msg}); retrying {attempt}/{} in {wait:?}",
                    opts.max_retries
                );
                std::thread::sleep(wait);
            }
        }
    }
}

/// Is this session failure worth a reconnect? The vendored `anyhow` shim
/// flattens causes to strings, so classification matches on the context
/// markers *our own* transport call sites attach (all of them wrap
/// socket IO). Anything else — protocol violations, shape mismatches,
/// the `--expect-restore` assertion — is deterministic and must NOT be
/// swallowed by a retry.
pub(crate) fn is_connection_error(msg: &str) -> bool {
    const MARKERS: [&str; 11] = [
        "connecting to",
        "waiting for hello",
        "worker recv",
        "worker send",
        "worker heartbeat",
        "worker snapshot send",
        "replay recv",
        "restore recv",
        "relay upstream",
        "relay child",
        "relay accept",
    ];
    MARKERS.iter().any(|m| msg.contains(m))
}

/// Backoff for retry `attempt` (1-based): `base · 2^min(attempt,5)`
/// capped at 10 s, plus sub-`base` jitter (seeded by pid ⊕ attempt so a
/// worker fleet killed together does not reconnect in lockstep, yet each
/// process backs off reproducibly).
pub(crate) fn retry_backoff(base_ms: u64, attempt: usize) -> Duration {
    let exp = base_ms.saturating_mul(1u64 << attempt.min(5));
    let jitter =
        SplitMix64::new(std::process::id() as u64 ^ attempt as u64).next_u64() % base_ms.max(1);
    Duration::from_millis(exp.min(10_000) + jitter)
}

/// One worker session: connect, handshake, rebuild the assigned shards'
/// state from the `Hello` (deterministic, so worker state matches the
/// server's reference build bit-for-bit), keep the unassigned worker
/// halves in reserve for later adoption, and run the round loop until
/// `Stop`.
fn worker_session(addr: &str, opts: &WorkerOpts) -> Result<()> {
    let mut t = Tcp::connect_retry(addr, 60, Duration::from_millis(250))
        .with_context(|| format!("connecting to {addr}"))?;
    let mut body = Vec::new();
    t.recv(&mut body).context("waiting for hello")?;
    // mirror the server's frame-integrity mode: the Hello just told us
    // whether frames carry CRC32 trailers
    t.set_crc(t.crc_seen());
    // a standby replacement that was never needed is released with a Stop
    // instead of a Hello — that is a clean no-op exit
    if codec::frame_tag(&body)? == codec::TAG_STOP {
        crate::info!("wire", "server finished without needing this worker");
        return Ok(());
    }
    let hello = codec::get_hello(&body)?;
    ensure!(!hello.shards.is_empty(), "server assigned no shards");
    crate::info!(
        "wire",
        "assigned {} shard(s) of {} (method {}, payload {})",
        hello.shards.len(),
        hello.dataset,
        hello.method,
        hello.payload.name()
    );

    let data_dir = hello.data_dir.as_ref().map(std::path::PathBuf::from);
    if let Some(dir) = &data_dir {
        // The server resolved a real dataset file; silently falling back to
        // the synthetic generator here would train on *different data* than
        // the server's x*/smoothness build and diverge without any error.
        ensure!(
            dir.join(&hello.dataset).is_file()
                || dir.join(format!("{}.txt", hello.dataset)).is_file(),
            "server set data_dir {} but dataset '{}' is not there on this \
             machine (refusing to fall back to synthetic data)",
            dir.display(),
            hello.dataset
        );
    }
    let raw = crate::data::load_or_synth(&hello.dataset, data_dir.as_deref(), hello.seed)
        .with_context(|| format!("loading dataset {}", hello.dataset))?;
    let (global, shards) = raw.prepare(hello.workers, hello.seed);
    let mut sm = Smoothness::build(&shards, hello.mu);
    if hello.need_global {
        sm = sm.with_global(&global.a);
    }
    let mut spec = MethodSpec::new(
        &hello.method,
        hello.tau,
        hello.sampling,
        hello.mu,
        hello.x0.clone(),
    );
    spec.practical_adiana = hello.practical_adiana;
    spec.compressor = hello.compressor;
    spec.sa_levels = hello.sa_levels;
    spec.sa_weighting = hello.sa_weighting;
    let method = build(&spec, &sm)?;
    ensure!(
        hello.shards.iter().all(|&i| i < method.workers.len()),
        "assigned shard index out of range"
    );
    let assigned: std::collections::BTreeSet<usize> = hello.shards.iter().copied().collect();
    let base = Rng::new(hello.seed);
    let mut active = Vec::with_capacity(assigned.len());
    let mut reserve = Vec::new();
    for (i, algo) in method.workers.into_iter().enumerate() {
        if assigned.contains(&i) {
            let engine = Box::new(NativeEngine::from_shard(&shards[i], hello.mu));
            active.push(ShardRunner::new(i, algo, engine, base.derive(i as u64)));
        } else {
            // keep the round-0 half: the server may hand us this shard if
            // its owner dies and no replacement rejoins in time
            reserve.push((i, algo));
        }
    }
    let mut state = WorkerState {
        active,
        reserve,
        adopt_ctx: Some(AdoptCtx {
            shards,
            mu: hello.mu,
        }),
        seed: hello.seed,
        payload: hello.payload,
        dim: hello.x0.len(),
        die_after: opts.die_after,
        fault: opts.fault.clone(),
        rounds_seen: 0,
        expect_restore: opts.expect_restore,
        restored: false,
        cohort: None,
        paused: false,
    };

    t.send(&[codec::TAG_HELLO_ACK])?;
    worker_loop(&mut state, &mut t)
}
