//! DIANA++ (Algorithm 8, Appendix G) — bidirectional matrix-smoothness-
//! aware compression with twofold variance reduction.
//!
//! On top of DIANA+'s worker shifts `h_i`, the *server* also sparsifies
//! its aggregated update with a sketch `C` against the global smoothness
//! matrix `L` of f, maintaining a control vector `H`. Workers keep model
//! and `H` replicas and reconstruct `x^{k+1}` from the sparse server
//! message δ, so **both** directions of communication are sparse.
//!
//! Theorem 23 provides the parameters; with no server compression it
//! degrades exactly to DIANA+ (Remark 8), which is verified in the tests.

use crate::compress::{MatrixAware, SparseMsg};
use crate::linalg::psd::PsdRoot;
use crate::methods::prox::Prox;
use crate::methods::{stepsize, Downlink, MethodSpec, ServerAlgo, Uplink, WorkerAlgo};
use crate::objective::Smoothness;
use crate::runtime::GradEngine;
use crate::sampling::IndependentSampling;
use crate::util::rng::Rng;
use std::sync::Arc;

pub struct DianaPpWorker {
    compressor: MatrixAware,
    root: Arc<PsdRoot>,
    global_root: Arc<PsdRoot>,
    alpha: f64,
    beta: f64,
    gamma: f64,
    prox: Prox,
    /// model replica
    x: Vec<f64>,
    /// server-control replica
    hh: Vec<f64>,
    h: Vec<f64>,
    grad: Vec<f64>,
    diff: Vec<f64>,
    scratch: Vec<f64>,
    coeff: Vec<f64>,
}

impl WorkerAlgo for DianaPpWorker {
    fn round(&mut self, down: &Downlink, engine: &mut dyn GradEngine, rng: &mut Rng) -> Uplink {
        let mut up = Uplink::default();
        self.round_into(down, engine, rng, &mut up);
        up
    }

    fn round_into(
        &mut self,
        down: &Downlink,
        engine: &mut dyn GradEngine,
        rng: &mut Rng,
        up: &mut Uplink,
    ) {
        match down {
            Downlink::Init { x } => {
                self.x.copy_from_slice(x);
                self.hh.fill(0.0);
            }
            Downlink::Sparse { delta } => {
                // reconstruct: ĝ = H + L^{1/2}δ ; x ← prox(x − γĝ) ; H += βL^{1/2}δ
                self.global_root.apply_pow_sparse_into_with(
                    0.5,
                    &delta.idx,
                    &delta.val,
                    &mut self.scratch,
                    &mut self.coeff,
                );
                for j in 0..self.x.len() {
                    let ghat = self.hh[j] + self.scratch[j];
                    self.x[j] -= self.gamma * ghat;
                    self.hh[j] += self.beta * self.scratch[j];
                }
                self.prox.apply(self.gamma, &mut self.x);
            }
            Downlink::Dense { .. } => unreachable!("diana++ downlinks are sparse"),
        }

        engine.grad_into(&self.x, &mut self.grad);
        for j in 0..self.diff.len() {
            self.diff[j] = self.grad[j] - self.h[j];
        }
        self.compressor
            .compress(&self.root, &self.diff, rng, &mut up.delta);
        // h_i ← h_i + α L_i^{1/2} Δ_i
        self.root.apply_pow_sparse_into_with(
            0.5,
            &up.delta.idx,
            &up.delta.val,
            &mut self.scratch,
            &mut self.coeff,
        );
        for j in 0..self.h.len() {
            self.h[j] += self.alpha * self.scratch[j];
        }
        up.delta2 = None;
    }

    fn dim(&self) -> usize {
        self.x.len()
    }

    fn save_state(&self, out: &mut Vec<u8>) {
        crate::methods::state::put_vec(out, &self.x);
        crate::methods::state::put_vec(out, &self.hh);
        crate::methods::state::put_vec(out, &self.h);
    }

    fn load_state(&mut self, buf: &[u8]) -> bool {
        let mut pos = 0;
        crate::methods::state::get_vec(buf, &mut pos, &mut self.x)
            && crate::methods::state::get_vec(buf, &mut pos, &mut self.hh)
            && crate::methods::state::get_vec(buf, &mut pos, &mut self.h)
            && pos == buf.len()
    }
}

pub struct DianaPpServer {
    x: Vec<f64>,
    h: Vec<f64>,
    hh: Vec<f64>,
    gamma: f64,
    alpha: f64,
    beta: f64,
    prox: Prox,
    roots: Vec<Arc<PsdRoot>>,
    global_root: Arc<PsdRoot>,
    server_compressor: MatrixAware,
    /// next round's δ; ping-pongs with the coordinator's downlink buffer
    /// through `downlink_into` so both retain their capacity (§Perf)
    pending: SparseMsg,
    /// set by `apply`, consumed by `downlink*` — guards the protocol
    /// ordering (a downlink without a preceding apply is a driver bug)
    pending_valid: bool,
    first: bool,
    dbar: Vec<f64>,
    gvec: Vec<f64>,
    scratch: Vec<f64>,
    coeff: Vec<f64>,
}

impl ServerAlgo for DianaPpServer {
    fn downlink(&mut self) -> Downlink {
        let mut down = Downlink::Init { x: Vec::new() };
        self.downlink_into(&mut down);
        down
    }

    fn downlink_into(&mut self, down: &mut Downlink) {
        if self.first {
            self.first = false;
            match down {
                Downlink::Init { x } if x.len() == self.x.len() => x.copy_from_slice(&self.x),
                _ => *down = Downlink::Init { x: self.x.clone() },
            }
            return;
        }
        assert!(self.pending_valid, "δ pending from previous apply");
        self.pending_valid = false;
        match down {
            Downlink::Sparse { delta } => std::mem::swap(delta, &mut self.pending),
            _ => {
                *down = Downlink::Sparse {
                    delta: std::mem::take(&mut self.pending),
                }
            }
        }
    }

    fn apply(&mut self, ups: &[Uplink], rng: &mut Rng) {
        // Δ̄ = (1/n)Σ L_i^{1/2}Δ_i ;  g = Δ̄ + h ;  h += αΔ̄
        self.dbar.fill(0.0);
        for (i, u) in ups.iter().enumerate() {
            self.roots[i].apply_pow_sparse_into_with(
                0.5,
                &u.delta.idx,
                &u.delta.val,
                &mut self.scratch,
                &mut self.coeff,
            );
            for j in 0..self.dbar.len() {
                self.dbar[j] += self.scratch[j];
            }
        }
        let inv_n = 1.0 / ups.len() as f64;
        for j in 0..self.x.len() {
            let db = self.dbar[j] * inv_n;
            self.gvec[j] = db + self.h[j] - self.hh[j]; // g − H (to compress)
            self.h[j] += self.alpha * db;
        }

        // δ = C L^{†1/2}(g − H), compressed into the persistent buffer
        self.server_compressor
            .compress(&self.global_root, &self.gvec, rng, &mut self.pending);

        // ĝ = H + L^{1/2}δ ; x ← prox(x − γĝ) ; H += βL^{1/2}δ
        self.global_root.apply_pow_sparse_into_with(
            0.5,
            &self.pending.idx,
            &self.pending.val,
            &mut self.scratch,
            &mut self.coeff,
        );
        for j in 0..self.x.len() {
            let ghat = self.hh[j] + self.scratch[j];
            self.x[j] -= self.gamma * ghat;
            self.hh[j] += self.beta * self.scratch[j];
        }
        self.prox.apply(self.gamma, &mut self.x);
        self.pending_valid = true;
    }

    fn iterate(&self) -> &[f64] {
        &self.x
    }

    fn dim(&self) -> usize {
        self.x.len()
    }

    fn name(&self) -> &'static str {
        "diana++"
    }

    fn save_state(&self, out: &mut Vec<u8>) {
        crate::methods::state::put_vec(out, &self.x);
        crate::methods::state::put_vec(out, &self.h);
        crate::methods::state::put_vec(out, &self.hh);
        // the un-broadcast δ and the protocol-ordering flags are part of
        // the round-boundary state: a restart between apply and the next
        // downlink must re-emit the identical sparse message
        crate::methods::state::put_msg(out, &self.pending);
        crate::methods::state::put_flag(out, self.pending_valid);
        crate::methods::state::put_flag(out, self.first);
    }

    fn load_state(&mut self, buf: &[u8]) -> bool {
        let mut pos = 0;
        crate::methods::state::get_vec(buf, &mut pos, &mut self.x)
            && crate::methods::state::get_vec(buf, &mut pos, &mut self.h)
            && crate::methods::state::get_vec(buf, &mut pos, &mut self.hh)
            && crate::methods::state::get_msg(buf, &mut pos, &mut self.pending)
            && crate::methods::state::get_flag(buf, &mut pos, &mut self.pending_valid)
            && crate::methods::state::get_flag(buf, &mut pos, &mut self.first)
            && pos == buf.len()
    }
}

/// diag of M_i = L_i^{1/2} L^† L_i^{1/2}, exactly (O(d²·rank) — used when
/// d is moderate), for the 𝓛̃'_max constant of Theorem 23.
fn tilde_l_prime(
    root_i: &PsdRoot,
    global: &PsdRoot,
    p: &[f64],
    dim: usize,
) -> f64 {
    if dim <= 768 {
        let mut e = vec![0.0; dim];
        let mut col = vec![0.0; dim];
        let mut worst: f64 = 0.0;
        for j in 0..dim {
            e[j] = 1.0;
            root_i.apply_pow_into(0.5, &e, &mut col);
            let mjj = global.wnorm2(-1.0, &col);
            worst = worst.max((1.0 / p[j] - 1.0) * mjj);
            e[j] = 0.0;
        }
        worst
    } else {
        // conservative bound: ω_i · λ_max(M_i) via power iteration
        let omega = crate::objective::smoothness::omega(p);
        let mut t1 = vec![0.0; dim];
        let mut t2 = vec![0.0; dim];
        let lmax = crate::linalg::eigen::power_lambda_max(
            dim,
            |v, out| {
                root_i.apply_pow_into(0.5, v, &mut t1);
                global.apply_pow_into(-1.0, &t1, &mut t2);
                root_i.apply_pow_into(0.5, &t2, out);
            },
            1e-10,
            5_000,
            0xD1A,
        );
        omega * lmax
    }
}

pub fn build(
    spec: &MethodSpec,
    sm: &Smoothness,
) -> (Box<dyn ServerAlgo>, Vec<Box<dyn WorkerAlgo + Send>>) {
    let dim = sm.dim;
    let global = sm
        .global
        .as_ref()
        .expect("diana++ needs Smoothness::with_global(shards) to have been called");
    let global_root = Arc::new(global.root.clone());
    let roots: Vec<Arc<PsdRoot>> = sm.locals.iter().map(|l| Arc::new(l.root.clone())).collect();

    let mut tilde_l_max: f64 = 0.0;
    let mut omega_max: f64 = 0.0;
    let mut samplings = Vec::with_capacity(sm.n());
    for loc in &sm.locals {
        let s = spec.sampling.build(&loc.diag, spec.tau, spec.mu, sm.n());
        tilde_l_max = tilde_l_max.max(s.tilde_l(&loc.diag));
        omega_max = omega_max.max(s.omega());
        samplings.push(s);
    }

    // server sketch: uniform with the same expected size τ
    let server_sampling = IndependentSampling::uniform(dim, spec.tau);
    let omega_server = server_sampling.omega();
    let tilde_l_server = server_sampling.tilde_l(&global.diag);
    let tilde_l_prime_max = samplings
        .iter()
        .zip(&roots)
        .map(|(s, r)| tilde_l_prime(r, &global_root, &s.p, dim))
        .fold(0.0, f64::max);

    let params = stepsize::diana_pp_params(
        sm,
        tilde_l_max,
        omega_max,
        tilde_l_server,
        tilde_l_prime_max,
        omega_server,
    );

    let workers: Vec<Box<dyn WorkerAlgo + Send>> = samplings
        .into_iter()
        .zip(&roots)
        .map(|(s, root)| {
            Box::new(DianaPpWorker {
                compressor: MatrixAware::new(s),
                root: root.clone(),
                global_root: global_root.clone(),
                alpha: params.alpha,
                beta: params.beta,
                gamma: params.gamma,
                prox: Prox::None,
                x: spec.x0.clone(),
                hh: vec![0.0; dim],
                h: vec![0.0; dim],
                grad: vec![0.0; dim],
                diff: vec![0.0; dim],
                scratch: vec![0.0; dim],
                coeff: Vec::new(),
            }) as Box<dyn WorkerAlgo + Send>
        })
        .collect();

    let server = Box::new(DianaPpServer {
        x: spec.x0.clone(),
        h: vec![0.0; dim],
        hh: vec![0.0; dim],
        gamma: params.gamma,
        alpha: params.alpha,
        beta: params.beta,
        prox: Prox::None,
        roots,
        global_root,
        server_compressor: MatrixAware::new(server_sampling),
        pending: SparseMsg::new(),
        pending_valid: false,
        first: true,
        dbar: vec![0.0; dim],
        gvec: vec![0.0; dim],
        scratch: vec![0.0; dim],
        coeff: Vec::new(),
    });
    (server, workers)
}
