//! ISEGA+ (Algorithm 7, Appendix F) — variance reduction à la SEGA with
//! the matrix-aware protocol. Identical uplink to DIANA+, but the control
//! vectors are updated by *projection*:
//!
//!   `h_i^{k+1} = h_i^k + L_i^{1/2} Diag(P_i) Δ_i`
//!
//! i.e. the sketch values are rescaled by p_j (undoing the 1/p_j of the
//! unbiased sketch) before decompression — the aggressive update that
//! makes ISEGA+ outperform DIANA+ in practice (Remark 1) at the same
//! worst-case complexity (Theorem 22).

use crate::compress::{MatrixAware, SparseMsg};
use crate::linalg::psd::PsdRoot;
use crate::methods::prox::Prox;
use crate::methods::{
    dense_downlink_into, stepsize, Downlink, MethodSpec, ServerAlgo, Uplink, WorkerAlgo,
};
use crate::objective::Smoothness;
use crate::runtime::GradEngine;
use crate::util::rng::Rng;
use std::sync::Arc;

pub struct IsegaPlusWorker {
    compressor: MatrixAware,
    root: Arc<PsdRoot>,
    h: Vec<f64>,
    diff: Vec<f64>,
    grad: Vec<f64>,
    scratch: Vec<f64>,
    coeff: Vec<f64>,
    proj: SparseMsg,
}

impl WorkerAlgo for IsegaPlusWorker {
    fn round(&mut self, down: &Downlink, engine: &mut dyn GradEngine, rng: &mut Rng) -> Uplink {
        let mut up = Uplink::default();
        self.round_into(down, engine, rng, &mut up);
        up
    }

    fn round_into(
        &mut self,
        down: &Downlink,
        engine: &mut dyn GradEngine,
        rng: &mut Rng,
        up: &mut Uplink,
    ) {
        let x = match down {
            Downlink::Dense { x, .. } => x,
            _ => unreachable!("isega+ uses dense downlinks"),
        };
        engine.grad_into(x, &mut self.grad);
        for j in 0..self.diff.len() {
            self.diff[j] = self.grad[j] - self.h[j];
        }
        self.compressor
            .compress(&self.root, &self.diff, rng, &mut up.delta);

        // h_i ← h_i + L^{1/2} Diag(P) Δ_i  (projection update)
        self.proj.clear();
        for (k, &i) in up.delta.idx.iter().enumerate() {
            self.proj
                .push(i, up.delta.val[k] * self.compressor.sampling.p[i as usize]);
        }
        self.root.apply_pow_sparse_into_with(
            0.5,
            &self.proj.idx,
            &self.proj.val,
            &mut self.scratch,
            &mut self.coeff,
        );
        for j in 0..self.h.len() {
            self.h[j] += self.scratch[j];
        }

        up.delta2 = None;
    }

    fn dim(&self) -> usize {
        self.h.len()
    }

    fn save_state(&self, out: &mut Vec<u8>) {
        crate::methods::state::put_vec(out, &self.h);
    }

    fn load_state(&mut self, buf: &[u8]) -> bool {
        let mut pos = 0;
        crate::methods::state::get_vec(buf, &mut pos, &mut self.h) && pos == buf.len()
    }
}

pub struct IsegaPlusServer {
    x: Vec<f64>,
    h: Vec<f64>,
    gamma: f64,
    prox: Prox,
    roots: Vec<Arc<PsdRoot>>,
    /// per-worker sampling probabilities for the projection rescale
    probs: Vec<Vec<f64>>,
    g: Vec<f64>,
    hupd: Vec<f64>,
    scratch: Vec<f64>,
    coeff: Vec<f64>,
    proj: SparseMsg,
}

impl ServerAlgo for IsegaPlusServer {
    fn downlink(&mut self) -> Downlink {
        let mut down = Downlink::Init { x: Vec::new() };
        self.downlink_into(&mut down);
        down
    }

    fn downlink_into(&mut self, down: &mut Downlink) {
        dense_downlink_into(&self.x, None, down);
    }

    fn apply(&mut self, ups: &[Uplink], _rng: &mut Rng) {
        self.g.fill(0.0);
        self.hupd.fill(0.0);
        for (i, u) in ups.iter().enumerate() {
            // gradient estimator contribution: L^{1/2} Δ_i
            self.roots[i].apply_pow_sparse_into_with(
                0.5,
                &u.delta.idx,
                &u.delta.val,
                &mut self.scratch,
                &mut self.coeff,
            );
            for j in 0..self.g.len() {
                self.g[j] += self.scratch[j];
            }
            // shift update contribution: L^{1/2} Diag(P_i) Δ_i
            self.proj.clear();
            for (k, &idx) in u.delta.idx.iter().enumerate() {
                self.proj
                    .push(idx, u.delta.val[k] * self.probs[i][idx as usize]);
            }
            self.roots[i].apply_pow_sparse_into_with(
                0.5,
                &self.proj.idx,
                &self.proj.val,
                &mut self.scratch,
                &mut self.coeff,
            );
            for j in 0..self.hupd.len() {
                self.hupd[j] += self.scratch[j];
            }
        }
        let inv_n = 1.0 / ups.len() as f64;
        for j in 0..self.x.len() {
            let g = self.g[j] * inv_n + self.h[j];
            self.x[j] -= self.gamma * g;
            self.h[j] += self.hupd[j] * inv_n;
        }
        self.prox.apply(self.gamma, &mut self.x);
    }

    fn iterate(&self) -> &[f64] {
        &self.x
    }

    fn dim(&self) -> usize {
        self.x.len()
    }

    fn name(&self) -> &'static str {
        "isega+"
    }

    fn save_state(&self, out: &mut Vec<u8>) {
        crate::methods::state::put_vec(out, &self.x);
        crate::methods::state::put_vec(out, &self.h);
    }

    fn load_state(&mut self, buf: &[u8]) -> bool {
        let mut pos = 0;
        crate::methods::state::get_vec(buf, &mut pos, &mut self.x)
            && crate::methods::state::get_vec(buf, &mut pos, &mut self.h)
            && pos == buf.len()
    }
}

pub fn build(
    spec: &MethodSpec,
    sm: &Smoothness,
) -> (Box<dyn ServerAlgo>, Vec<Box<dyn WorkerAlgo + Send>>) {
    let dim = sm.dim;
    let roots: Vec<Arc<PsdRoot>> = sm.locals.iter().map(|l| Arc::new(l.root.clone())).collect();

    let mut tilde_l_max: f64 = 0.0;
    let mut omega_max: f64 = 0.0;
    let mut samplings = Vec::with_capacity(sm.n());
    for loc in &sm.locals {
        let s = spec.sampling.build(&loc.diag, spec.tau, spec.mu, sm.n());
        tilde_l_max = tilde_l_max.max(s.tilde_l(&loc.diag));
        omega_max = omega_max.max(s.omega());
        samplings.push(s);
    }
    let gamma = stepsize::isega_plus_gamma(sm, tilde_l_max, omega_max);
    let probs: Vec<Vec<f64>> = samplings.iter().map(|s| s.p.clone()).collect();

    let workers: Vec<Box<dyn WorkerAlgo + Send>> = samplings
        .into_iter()
        .zip(&roots)
        .map(|(s, root)| {
            Box::new(IsegaPlusWorker {
                compressor: MatrixAware::new(s),
                root: root.clone(),
                h: vec![0.0; dim],
                diff: vec![0.0; dim],
                grad: vec![0.0; dim],
                scratch: vec![0.0; dim],
                coeff: Vec::new(),
                proj: SparseMsg::new(),
            }) as Box<dyn WorkerAlgo + Send>
        })
        .collect();

    let server = Box::new(IsegaPlusServer {
        x: spec.x0.clone(),
        h: vec![0.0; dim],
        gamma,
        prox: Prox::None,
        roots,
        probs,
        g: vec![0.0; dim],
        hupd: vec![0.0; dim],
        scratch: vec![0.0; dim],
        coeff: Vec::new(),
        proj: SparseMsg::new(),
    });
    (server, workers)
}
