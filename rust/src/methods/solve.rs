//! High-precision solver for x\* (the residual reference point of all
//! figures): Nesterov's accelerated gradient method for μ-strongly-convex
//! L-smooth objectives, run until ‖∇f(x)‖ ≤ tol. With the paper's setup
//! (rows normalized to ‖a‖ = 1/2, μ = 1e-3) the condition number is small
//! (≲ 100) and this converges to f64 precision in a few hundred
//! iterations.

use crate::linalg::vector;
use crate::objective::logreg::Problem;
use crate::objective::Smoothness;

pub struct Solution {
    pub x_star: Vec<f64>,
    pub f_star: f64,
    pub grad_norm: f64,
    pub iterations: usize,
}

pub fn solve_opt(problem: &Problem, sm: &Smoothness, tol: f64, max_iter: usize) -> Solution {
    let d = problem.dim;
    let l = sm.l;
    let mu = sm.mu;
    let kappa = (l / mu).max(1.0);
    let sq = kappa.sqrt();
    let momentum = (sq - 1.0) / (sq + 1.0);
    let step = 1.0 / l;

    let mut x = vec![0.0; d];
    let mut y = vec![0.0; d];
    let mut x_prev = vec![0.0; d];
    let mut g = vec![0.0; d];
    let mut iterations = 0;

    for it in 0..max_iter {
        iterations = it + 1;
        g = problem.grad(&y);
        let gn = vector::norm(&g);
        if gn <= tol {
            // y is our converged point
            x.copy_from_slice(&y);
            break;
        }
        x_prev.copy_from_slice(&x);
        for j in 0..d {
            x[j] = y[j] - step * g[j];
        }
        for j in 0..d {
            y[j] = x[j] + momentum * (x[j] - x_prev[j]);
        }
        if it == max_iter - 1 {
            // fall back to last x
        }
    }

    // polish with a few plain gradient steps (kills momentum overshoot)
    for _ in 0..50 {
        g = problem.grad(&x);
        if vector::norm(&g) <= tol {
            break;
        }
        for j in 0..d {
            x[j] -= step * g[j];
        }
    }

    let g_final = problem.grad(&x);
    Solution {
        f_star: problem.loss(&x),
        grad_norm: vector::norm(&g_final),
        x_star: x,
        iterations,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::objective::Smoothness;

    #[test]
    fn solves_tiny_problem_to_high_precision() {
        let ds = synth::generate(&synth::tiny_spec(), 1);
        let (_, shards) = ds.prepare(4, 1);
        let problem = Problem::from_shards(&shards, 1e-3);
        let sm = Smoothness::build(&shards, 1e-3);
        let sol = solve_opt(&problem, &sm, 1e-13, 20_000);
        assert!(
            sol.grad_norm <= 1e-12,
            "grad norm {} too large",
            sol.grad_norm
        );
        // optimality: f(x*) ≤ f(x* + εv) for random perturbations
        let f0 = sol.f_star;
        let mut rng = crate::util::rng::Rng::new(2);
        for _ in 0..5 {
            let mut xp = sol.x_star.clone();
            for v in xp.iter_mut() {
                *v += 1e-4 * rng.normal();
            }
            assert!(problem.loss(&xp) >= f0 - 1e-12);
        }
    }

    #[test]
    fn solution_is_deterministic() {
        let ds = synth::generate(&synth::tiny_spec(), 3);
        let (_, shards) = ds.prepare(3, 3);
        let problem = Problem::from_shards(&shards, 1e-3);
        let sm = Smoothness::build(&shards, 1e-3);
        let s1 = solve_opt(&problem, &sm, 1e-12, 10_000);
        let s2 = solve_opt(&problem, &sm, 1e-12, 10_000);
        assert_eq!(s1.x_star, s2.x_star);
    }
}
