//! DIANA+ (Algorithm 2) — variance reduction with matrix-smoothness-aware
//! sparsification.
//!
//! Worker i: `Δ_i = C_i L_i^{†1/2}(∇f_i(x^k) − h_i^k)` (sparse uplink),
//!           `h_i ← h_i + α L_i^{1/2} Δ_i` (dense local update).
//! Server:   `Δ̄ = (1/n) Σ L_i^{1/2} Δ_i`, `g = Δ̄ + h`,
//!           `x⁺ = prox_{γR}(x − γg)`, `h ← h + αΔ̄`.
//!
//! Theory parameters (Theorem 3): γ = 1/(L + 6𝓛̃_max/n), α = 1/(1+ω_max).

use crate::compress::MatrixAware;
use crate::linalg::psd::PsdRoot;
use crate::methods::prox::Prox;
use crate::methods::{
    dense_downlink_into, stepsize, Downlink, MethodSpec, ServerAlgo, Uplink, WorkerAlgo,
};
use crate::objective::Smoothness;
use crate::runtime::GradEngine;
use crate::util::rng::Rng;
use std::sync::Arc;

pub struct DianaPlusWorker {
    compressor: MatrixAware,
    root: Arc<PsdRoot>,
    alpha: f64,
    h: Vec<f64>,
    diff: Vec<f64>,
    grad: Vec<f64>,
    dbar: Vec<f64>,
    coeff: Vec<f64>,
}

impl WorkerAlgo for DianaPlusWorker {
    fn round(&mut self, down: &Downlink, engine: &mut dyn GradEngine, rng: &mut Rng) -> Uplink {
        let mut up = Uplink::default();
        self.round_into(down, engine, rng, &mut up);
        up
    }

    fn round_into(
        &mut self,
        down: &Downlink,
        engine: &mut dyn GradEngine,
        rng: &mut Rng,
        up: &mut Uplink,
    ) {
        let x = match down {
            Downlink::Dense { x, .. } => x,
            _ => unreachable!("diana+ uses dense downlinks"),
        };
        engine.grad_into(x, &mut self.grad);
        for j in 0..self.diff.len() {
            self.diff[j] = self.grad[j] - self.h[j];
        }
        self.compressor
            .compress(&self.root, &self.diff, rng, &mut up.delta);
        // h_i ← h_i + α L_i^{1/2} Δ_i
        self.root.apply_pow_sparse_into_with(
            0.5,
            &up.delta.idx,
            &up.delta.val,
            &mut self.dbar,
            &mut self.coeff,
        );
        for j in 0..self.h.len() {
            self.h[j] += self.alpha * self.dbar[j];
        }
        up.delta2 = None;
    }

    fn dim(&self) -> usize {
        self.h.len()
    }

    fn save_state(&self, out: &mut Vec<u8>) {
        crate::methods::state::put_vec(out, &self.h);
    }

    fn load_state(&mut self, buf: &[u8]) -> bool {
        let mut pos = 0;
        crate::methods::state::get_vec(buf, &mut pos, &mut self.h) && pos == buf.len()
    }
}

pub struct DianaPlusServer {
    x: Vec<f64>,
    h: Vec<f64>,
    gamma: f64,
    alpha: f64,
    prox: Prox,
    roots: Vec<Arc<PsdRoot>>,
    dbar: Vec<f64>,
    scratch: Vec<f64>,
    coeff: Vec<f64>,
    name: &'static str,
}

impl ServerAlgo for DianaPlusServer {
    fn downlink(&mut self) -> Downlink {
        let mut down = Downlink::Init { x: Vec::new() };
        self.downlink_into(&mut down);
        down
    }

    fn downlink_into(&mut self, down: &mut Downlink) {
        dense_downlink_into(&self.x, None, down);
    }

    fn apply(&mut self, ups: &[Uplink], _rng: &mut Rng) {
        self.dbar.fill(0.0);
        for (i, u) in ups.iter().enumerate() {
            self.roots[i].apply_pow_sparse_into_with(
                0.5,
                &u.delta.idx,
                &u.delta.val,
                &mut self.scratch,
                &mut self.coeff,
            );
            for j in 0..self.dbar.len() {
                self.dbar[j] += self.scratch[j];
            }
        }
        let inv_n = 1.0 / ups.len() as f64;
        for j in 0..self.x.len() {
            let db = self.dbar[j] * inv_n;
            let g = db + self.h[j];
            self.x[j] -= self.gamma * g;
            self.h[j] += self.alpha * db;
        }
        self.prox.apply(self.gamma, &mut self.x);
    }

    fn iterate(&self) -> &[f64] {
        &self.x
    }

    fn dim(&self) -> usize {
        self.x.len()
    }

    fn name(&self) -> &'static str {
        self.name
    }

    fn save_state(&self, out: &mut Vec<u8>) {
        crate::methods::state::put_vec(out, &self.x);
        crate::methods::state::put_vec(out, &self.h);
    }

    fn load_state(&mut self, buf: &[u8]) -> bool {
        let mut pos = 0;
        crate::methods::state::get_vec(buf, &mut pos, &mut self.x)
            && crate::methods::state::get_vec(buf, &mut pos, &mut self.h)
            && pos == buf.len()
    }
}

pub fn build(
    spec: &MethodSpec,
    sm: &Smoothness,
) -> (Box<dyn ServerAlgo>, Vec<Box<dyn WorkerAlgo + Send>>) {
    let dim = sm.dim;
    let roots: Vec<Arc<PsdRoot>> = sm.locals.iter().map(|l| Arc::new(l.root.clone())).collect();

    let mut tilde_l_max: f64 = 0.0;
    let mut omega_max: f64 = 0.0;
    let mut samplings = Vec::with_capacity(sm.n());
    for loc in &sm.locals {
        let s = spec.sampling.build(&loc.diag, spec.tau, spec.mu, sm.n());
        tilde_l_max = tilde_l_max.max(s.tilde_l(&loc.diag));
        omega_max = omega_max.max(s.omega());
        samplings.push(s);
    }

    let gamma = stepsize::diana_plus_gamma(sm, tilde_l_max);
    let alpha = stepsize::diana_alpha(omega_max);

    let workers: Vec<Box<dyn WorkerAlgo + Send>> = samplings
        .into_iter()
        .zip(&roots)
        .map(|(s, root)| {
            Box::new(DianaPlusWorker {
                compressor: MatrixAware::new(s),
                root: root.clone(),
                alpha,
                h: vec![0.0; dim],
                diff: vec![0.0; dim],
                grad: vec![0.0; dim],
                dbar: vec![0.0; dim],
                coeff: Vec::new(),
            }) as Box<dyn WorkerAlgo + Send>
        })
        .collect();

    let server = Box::new(DianaPlusServer {
        x: spec.x0.clone(),
        h: vec![0.0; dim],
        gamma,
        alpha,
        prox: Prox::None,
        roots,
        dbar: vec![0.0; dim],
        scratch: vec![0.0; dim],
        coeff: Vec::new(),
        name: "diana+",
    });
    (server, workers)
}
