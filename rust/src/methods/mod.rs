//! Distributed optimization methods (the paper's Algorithms 1–3, 7, 8 and
//! their baselines), expressed as *server/worker state machines* so the
//! same implementation runs under both coordinator drivers (in-process
//! simulator and threaded runtime).
//!
//! Per round:
//! 1. the server produces a [`Downlink`] (dense model broadcast, or the
//!    sparse δ message for bidirectionally-compressed DIANA++);
//! 2. every worker consumes it, evaluates its local gradient through a
//!    [`GradEngine`] (native or PJRT), compresses, and returns an
//!    [`Uplink`];
//! 3. the server aggregates the uplinks, decompresses with the stored
//!    `L_i^{1/2}` roots, and advances the model.
//!
//! Method catalogue:
//!
//! | method    | compression      | variance reduction | acceleration |
//! |-----------|------------------|--------------------|--------------|
//! | `dgd`     | none             | –                  | –            |
//! | `dcgd`    | standard sketch  | –                  | –            |
//! | `dcgd+`   | matrix-aware (7) | –                  | –            |
//! | `diana`   | standard sketch  | DIANA shifts       | –            |
//! | `diana+`  | matrix-aware (7) | DIANA shifts       | –            |
//! | `isega+`  | matrix-aware (7) | ISEGA projection   | –            |
//! | `adiana`  | standard sketch  | DIANA shifts       | Nesterov     |
//! | `adiana+` | matrix-aware (7) | DIANA shifts       | Nesterov     |
//! | `diana++` | matrix-aware, both directions | twofold | –          |

pub mod adiana;
pub mod adiana_plus;
pub mod dcgd;
pub mod dcgd_plus;
pub mod dgd;
pub mod diana;
pub mod diana_plus;
pub mod diana_pp;
pub mod isega_plus;
pub mod prox;
pub mod single;
pub mod solve;
pub mod stepsize;

use crate::compress::{QuantWeighting, SaQuant, SparseMsg, UplinkDecompressor};
use crate::objective::Smoothness;
use crate::runtime::GradEngine;
use crate::util::rng::Rng;
use std::sync::Arc;

/// Server → workers payload.
#[derive(Clone, Debug)]
pub enum Downlink {
    /// Dense broadcast of the current model (and ADIANA's anchor w).
    Dense { x: Vec<f64>, w: Option<Vec<f64>> },
    /// DIANA++: sparse server message δ; workers maintain model replicas.
    Sparse { delta: SparseMsg },
    /// Initial round of DIANA++: dense model to seed replicas.
    Init { x: Vec<f64> },
}

impl Downlink {
    /// Coordinates carried server→worker (communication accounting).
    pub fn coords(&self) -> usize {
        match self {
            Downlink::Dense { x, w } => x.len() + w.as_ref().map(|v| v.len()).unwrap_or(0),
            Downlink::Sparse { delta } => delta.coords(),
            Downlink::Init { x } => x.len(),
        }
    }
}

/// Worker → server payload.
#[derive(Clone, Debug, Default)]
pub struct Uplink {
    /// primary sparse update (Δ_i in the paper's notation)
    pub delta: SparseMsg,
    /// ADIANA's second sparse update (δ_i, the shift-learning message)
    pub delta2: Option<SparseMsg>,
}

impl Uplink {
    pub fn coords(&self) -> usize {
        self.delta.coords() + self.delta2.as_ref().map(|m| m.coords()).unwrap_or(0)
    }
}

/// Worker-side half of a method: owns local state (h_i, sampling, roots)
/// and the gradient engine is passed in per call.
pub trait WorkerAlgo {
    /// Process one round: consume the downlink, produce the uplink.
    fn round(&mut self, down: &Downlink, engine: &mut dyn GradEngine, rng: &mut Rng) -> Uplink;

    /// Buffer-reusing round: write the uplink into `up`, reusing its
    /// `SparseMsg` capacity across rounds (§Perf: the coordinator's
    /// steady-state loop is allocation-free through this path). The
    /// default falls back to [`WorkerAlgo::round`], so existing
    /// implementations keep working unchanged.
    fn round_into(
        &mut self,
        down: &Downlink,
        engine: &mut dyn GradEngine,
        rng: &mut Rng,
        up: &mut Uplink,
    ) {
        *up = self.round(down, engine, rng);
    }

    fn dim(&self) -> usize;

    /// Append the *round-evolving* local state (DIANA shifts, DIANA++'s
    /// model/control replicas, …) to `out`. Static configuration — roots,
    /// sampling tables, stepsizes — is rebuilt deterministically from the
    /// [`MethodSpec`], so it does not belong in the snapshot. Stateless
    /// workers (DGD, DCGD, DCGD+) write nothing, which the default
    /// provides. Paired with [`WorkerAlgo::load_state`]; the wire
    /// runtime's checkpoint snapshots are built from exactly these bytes
    /// (see [`crate::wire::runtime`]).
    fn save_state(&self, _out: &mut Vec<u8>) {}

    /// Restore the state written by [`WorkerAlgo::save_state`]. Returns
    /// `false` on a malformed or wrong-shape buffer (the caller treats
    /// that as a protocol error). The default accepts only the empty
    /// buffer a stateless worker saves.
    fn load_state(&mut self, buf: &[u8]) -> bool {
        buf.is_empty()
    }
}

/// Length-prefixed `f64`-vector (de)serialization for
/// [`WorkerAlgo::save_state`]/[`WorkerAlgo::load_state`] implementations:
/// values travel as raw little-endian bits, so a save/load round trip is
/// bit-exact — the precondition for checkpoint-resume equalling an
/// uninterrupted run.
pub mod state {
    /// Append `v` as a little-endian `u32` length plus raw f64 bits.
    pub fn put_vec(out: &mut Vec<u8>, v: &[f64]) {
        out.extend_from_slice(&(v.len() as u32).to_le_bytes());
        for &x in v {
            out.extend_from_slice(&x.to_bits().to_le_bytes());
        }
    }

    /// Read a vector written by [`put_vec`] into `v`, which must already
    /// have the expected length (state shapes are fixed at build time).
    /// Advances `pos`; returns `false` on truncation or length mismatch.
    pub fn get_vec(buf: &[u8], pos: &mut usize, v: &mut [f64]) -> bool {
        let Some(hdr) = buf.get(*pos..*pos + 4) else {
            return false;
        };
        let n = u32::from_le_bytes(hdr.try_into().unwrap()) as usize;
        if n != v.len() {
            return false;
        }
        let Some(body) = buf.get(*pos + 4..*pos + 4 + 8 * n) else {
            return false;
        };
        for (x, c) in v.iter_mut().zip(body.chunks_exact(8)) {
            *x = f64::from_bits(u64::from_le_bytes(c.try_into().unwrap()));
        }
        *pos += 4 + 8 * n;
        true
    }

    /// Append a sparse message as a little-endian `u32` count, the raw
    /// `u32` indices, then the raw f64 value bits. Unlike [`put_vec`] the
    /// length is *not* shape-checked on read — sketch sizes vary round to
    /// round — so [`get_msg`] resizes the target.
    pub fn put_msg(out: &mut Vec<u8>, m: &crate::compress::SparseMsg) {
        out.extend_from_slice(&(m.idx.len() as u32).to_le_bytes());
        for &i in &m.idx {
            out.extend_from_slice(&i.to_le_bytes());
        }
        for &x in &m.val {
            out.extend_from_slice(&x.to_bits().to_le_bytes());
        }
    }

    /// Read a message written by [`put_msg`] into `m` (cleared first,
    /// capacity reused). Advances `pos`; returns `false` on truncation.
    pub fn get_msg(buf: &[u8], pos: &mut usize, m: &mut crate::compress::SparseMsg) -> bool {
        let Some(hdr) = buf.get(*pos..*pos + 4) else {
            return false;
        };
        let n = u32::from_le_bytes(hdr.try_into().unwrap()) as usize;
        let need = 4 + 12 * n;
        let Some(body) = buf.get(*pos + 4..*pos + need) else {
            return false;
        };
        m.clear();
        for k in 0..n {
            let i = u32::from_le_bytes(body[4 * k..4 * k + 4].try_into().unwrap());
            let vb = &body[4 * n + 8 * k..4 * n + 8 * k + 8];
            m.push(i, f64::from_bits(u64::from_le_bytes(vb.try_into().unwrap())));
        }
        *pos += need;
        true
    }

    /// Append a boolean as one byte (0 or 1).
    pub fn put_flag(out: &mut Vec<u8>, b: bool) {
        out.push(b as u8);
    }

    /// Read a boolean written by [`put_flag`]; rejects any byte other
    /// than 0/1 so corrupted state never loads silently.
    pub fn get_flag(buf: &[u8], pos: &mut usize, b: &mut bool) -> bool {
        match buf.get(*pos) {
            Some(&0) => {
                *b = false;
                *pos += 1;
                true
            }
            Some(&1) => {
                *b = true;
                *pos += 1;
                true
            }
            _ => false,
        }
    }
}

/// Server-side half of a method.
pub trait ServerAlgo {
    /// Produce this round's downlink.
    fn downlink(&mut self) -> Downlink;

    /// Buffer-reusing downlink: overwrite `down` in place, reusing its
    /// dense/sparse buffers when the shape matches (§Perf). The default
    /// falls back to [`ServerAlgo::downlink`].
    fn downlink_into(&mut self, down: &mut Downlink) {
        *down = self.downlink();
    }

    /// Consume all workers' uplinks, advance the model.
    fn apply(&mut self, ups: &[Uplink], rng: &mut Rng);

    /// Current iterate the convergence metric is computed on
    /// (`z^k` for ADIANA per Theorem 4; `x^k` otherwise).
    fn iterate(&self) -> &[f64];

    fn dim(&self) -> usize;

    fn name(&self) -> &'static str;

    /// Append the *round-evolving* server state (model iterate, shift
    /// estimates, ADIANA's y/z/w triple, DIANA++'s pending δ, …) to
    /// `out`, the server-side analogue of [`WorkerAlgo::save_state`].
    /// Static configuration — roots, stepsizes, samplings — is rebuilt
    /// deterministically from the [`MethodSpec`] and does not belong in
    /// the blob. The wire runtime's durable run log persists exactly
    /// these bytes at each committed snapshot so a restarted `smx serve`
    /// resumes bit-for-bit (see [`crate::wire::runtime`]).
    fn save_state(&self, _out: &mut Vec<u8>) {}

    /// Restore the state written by [`ServerAlgo::save_state`]. Returns
    /// `false` on a malformed or wrong-shape buffer (the caller treats
    /// that as a corrupt run log and refuses to resume). The default
    /// accepts only the empty buffer a stateless server saves.
    fn load_state(&mut self, buf: &[u8]) -> bool {
        buf.is_empty()
    }
}

/// Overwrite `down` with a dense broadcast, reusing its buffers when the
/// shapes line up (the steady-state case). Shared by every dense-downlink
/// server's `downlink_into`.
pub(crate) fn dense_downlink_into(src_x: &[f64], src_w: Option<&[f64]>, down: &mut Downlink) {
    match down {
        Downlink::Dense { x, w } if x.len() == src_x.len() => {
            x.copy_from_slice(src_x);
            match src_w {
                Some(sw) => match w {
                    Some(dw) if dw.len() == sw.len() => dw.copy_from_slice(sw),
                    _ => *w = Some(sw.to_vec()),
                },
                None => *w = None,
            }
        }
        _ => {
            *down = Downlink::Dense {
                x: src_x.to_vec(),
                w: src_w.map(<[f64]>::to_vec),
            }
        }
    }
}

/// A constructed method: one server + n workers.
pub struct Method {
    pub server: Box<dyn ServerAlgo>,
    pub workers: Vec<Box<dyn WorkerAlgo + Send>>,
    pub name: String,
}

/// Names accepted by [`build`], in paper order.
pub const METHOD_NAMES: [&str; 9] = [
    "dgd", "dcgd", "dcgd+", "diana", "diana+", "adiana", "adiana+", "isega+", "diana++",
];

/// One [`SaQuant`] per worker (from its local L_i), the matching
/// server-side decompressors, and the effective variance bound
/// 𝓛̃ = ω_q·λ_max(W_i²) the `+`-family stepsizes take (ω_q is stated in
/// the whitened geometry, so un-whitening scales it by the largest
/// eigenvalue of W² — max_j L_jj for Diag weighting, λ_max(L_i) for Root).
pub(crate) fn sa_quant_family(
    sm: &Smoothness,
    levels: u32,
    weighting: QuantWeighting,
) -> (Vec<SaQuant>, Vec<UplinkDecompressor>, f64) {
    let omega_q = SaQuant::omega(sm.dim, levels);
    let mut quants = Vec::with_capacity(sm.n());
    let mut scale_max = 0.0f64;
    for loc in &sm.locals {
        match weighting {
            QuantWeighting::Diag => {
                scale_max = scale_max.max(loc.diag.iter().cloned().fold(0.0, f64::max));
                quants.push(SaQuant::diag(levels, &loc.diag));
            }
            QuantWeighting::Root => {
                let root = Arc::new(loc.root.clone());
                scale_max = scale_max.max(root.lambda_max());
                quants.push(SaQuant::root(levels, root));
            }
        }
    }
    let decomp = quants.iter().map(|q| q.decompressor()).collect();
    (quants, decomp, omega_q * scale_max)
}

pub use builder::{build, MethodSpec};

mod builder {
    use super::*;
    use crate::compress::CompressorKind;
    use crate::sampling::SamplingKind;

    /// Everything needed to instantiate a method.
    #[derive(Clone, Debug)]
    pub struct MethodSpec {
        pub name: String,
        /// expected sampling size τ
        pub tau: f64,
        pub sampling: SamplingKind,
        pub mu: f64,
        pub x0: Vec<f64>,
        /// relax ADIANA(+) constants as the paper's §6.1 does
        pub practical_adiana: bool,
        /// uplink compressor family (`Default` = what the method's theory
        /// prescribes — the diagonal sketch for baselines, matrix-aware
        /// for the `+` family)
        pub compressor: CompressorKind,
        /// sa-quant dither levels `s` (0 = exact passthrough sentinel)
        pub sa_levels: u32,
        /// sa-quant weighting `W` (diag = Diag(L_i)^{1/2}, root = L_i^{1/2})
        pub sa_weighting: QuantWeighting,
    }

    impl MethodSpec {
        pub fn new(name: &str, tau: f64, sampling: SamplingKind, mu: f64, x0: Vec<f64>) -> Self {
            MethodSpec {
                name: name.to_string(),
                tau,
                sampling,
                mu,
                x0,
                practical_adiana: true,
                compressor: CompressorKind::Default,
                sa_levels: 4,
                sa_weighting: QuantWeighting::Diag,
            }
        }
    }

    /// Which methods each non-default compressor family applies to: the
    /// baselines own the smoothness-*unaware* families (sketch, sa-quant's
    /// whitening replaces their sketch; top-k is the DCGD-only biased
    /// heuristic), while the `+` family is matrix-aware by construction.
    fn check_compressor(name: &str, spec: &MethodSpec) -> anyhow::Result<()> {
        let ok = match spec.compressor {
            CompressorKind::Default => true,
            CompressorKind::Sketch | CompressorKind::SaQuant => {
                matches!(name, "dcgd" | "diana" | "adiana")
            }
            CompressorKind::MatrixAware => {
                matches!(name, "dcgd+" | "diana+" | "adiana+" | "isega+" | "diana++")
            }
            CompressorKind::TopK => name == "dcgd",
        };
        if !ok {
            anyhow::bail!(
                "compressor '{}' is not applicable to method '{name}' \
                 (sketch/sa-quant: dcgd|diana|adiana; matrix-aware: \
                 dcgd+|diana+|adiana+|isega+|diana++; topk: dcgd; \
                 default: any method)",
                spec.compressor.name()
            );
        }
        Ok(())
    }

    /// Build a method instance from its spec and the problem smoothness.
    pub fn build(spec: &MethodSpec, sm: &Smoothness) -> anyhow::Result<Method> {
        let name = spec.name.as_str();
        check_compressor(name, spec)?;
        let (server, workers): (Box<dyn ServerAlgo>, Vec<Box<dyn WorkerAlgo + Send>>) = match name
        {
            "dgd" => dgd::build(spec, sm),
            "dcgd" => dcgd::build(spec, sm),
            "dcgd+" => dcgd_plus::build(spec, sm),
            "diana" => diana::build(spec, sm),
            "diana+" => diana_plus::build(spec, sm),
            "adiana" => adiana::build(spec, sm),
            "adiana+" => adiana_plus::build(spec, sm),
            "isega+" => isega_plus::build(spec, sm),
            "diana++" => diana_pp::build(spec, sm),
            other => anyhow::bail!("unknown method '{other}' (expected one of {METHOD_NAMES:?})"),
        };
        Ok(Method {
            server,
            workers,
            name: spec.name.clone(),
        })
    }
}

/// Persistent per-round message buffers: one [`Downlink`] and one
/// [`Uplink`] per worker, reused across every round so the steady-state
/// protocol performs zero heap allocations (§Perf).
pub struct RoundBuffers {
    pub down: Downlink,
    pub ups: Vec<Uplink>,
}

impl RoundBuffers {
    pub fn new(n_workers: usize) -> RoundBuffers {
        RoundBuffers {
            // placeholder; the first `downlink_into` replaces it
            down: Downlink::Init { x: Vec::new() },
            ups: (0..n_workers).map(|_| Uplink::default()).collect(),
        }
    }
}

/// Drive a method for one synchronous round against in-process engines,
/// reusing `bufs` across calls (no per-round `Vec<Uplink>` construction).
/// Returns coordinates sent up (Σ over workers) and down.
pub fn sync_round(
    method: &mut Method,
    engines: &mut [Box<dyn GradEngine>],
    server_rng: &mut Rng,
    worker_rngs: &mut [Rng],
    bufs: &mut RoundBuffers,
) -> (usize, usize) {
    debug_assert_eq!(bufs.ups.len(), method.workers.len());
    let RoundBuffers { down, ups } = bufs;
    method.server.downlink_into(down);
    let down_coords = down.coords() * method.workers.len();
    let mut up_coords = 0usize;
    for (((w, e), rng), up) in method
        .workers
        .iter_mut()
        .zip(engines.iter_mut())
        .zip(worker_rngs.iter_mut())
        .zip(ups.iter_mut())
    {
        w.round_into(down, e.as_mut(), rng, up);
        up_coords += up.coords();
    }
    method.server.apply(ups, server_rng);
    (up_coords, down_coords)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_vec_roundtrip_is_bit_exact_and_shape_checked() {
        let src = [1.5f64, -0.0, 3.7e-310, f64::INFINITY, -2.25];
        let mut buf = Vec::new();
        state::put_vec(&mut buf, &src);
        let mut dst = [0.0f64; 5];
        let mut pos = 0;
        assert!(state::get_vec(&buf, &mut pos, &mut dst));
        assert_eq!(pos, buf.len());
        for (a, b) in src.iter().zip(&dst) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // wrong shape and truncation are rejected, not silently accepted
        let mut wrong = [0.0f64; 4];
        pos = 0;
        assert!(!state::get_vec(&buf, &mut pos, &mut wrong));
        pos = 0;
        assert!(!state::get_vec(&buf[..buf.len() - 1], &mut pos, &mut dst));
    }

    #[test]
    fn stateful_workers_save_load_roundtrip() {
        // Drive a diana+ worker a few rounds, snapshot it, drive a clone
        // forward: the restored worker must follow bit-for-bit. (The
        // distributed chaos tests cover the full wire path; this is the
        // unit-level contract.)
        use crate::data::synth;
        use crate::objective::Smoothness;
        use crate::runtime::native::NativeEngine;
        use crate::sampling::SamplingKind;
        use crate::util::rng::Rng;

        let ds = synth::generate(&synth::tiny_spec(), 5);
        let (_, shards) = ds.prepare(2, 5);
        let sm = Smoothness::build(&shards, 1e-3);
        let spec = MethodSpec::new("diana+", 2.0, SamplingKind::Uniform, 1e-3, vec![0.0; sm.dim]);
        let mut m = build(&spec, &sm).unwrap();
        let mut m2 = build(&spec, &sm).unwrap();
        let mut engine = NativeEngine::from_shard(&shards[0], 1e-3);
        let mut rng = Rng::new(9);
        let down = Downlink::Dense {
            x: vec![0.01; sm.dim],
            w: None,
        };
        let w = &mut m.workers[0];
        for _ in 0..5 {
            w.round(&down, &mut engine, &mut rng);
        }
        let mut blob = Vec::new();
        w.save_state(&mut blob);
        assert!(!blob.is_empty(), "diana+ worker state must not be empty");

        let w2 = &mut m2.workers[0];
        assert!(w2.load_state(&blob), "snapshot must load into a fresh build");
        let mut rng2 = rng.clone();
        let up_a = w.round(&down, &mut engine, &mut rng);
        let up_b = w2.round(&down, &mut engine, &mut rng2);
        assert_eq!(up_a.delta, up_b.delta, "restored worker diverged");
        // malformed blobs are rejected
        assert!(!w2.load_state(&blob[..blob.len() - 1]));
    }

    #[test]
    fn compressor_applicability_is_enforced() {
        use crate::compress::CompressorKind;
        use crate::data::synth;
        use crate::sampling::SamplingKind;

        let ds = synth::generate(&synth::tiny_spec(), 5);
        let (global, shards) = ds.prepare(2, 5);
        let sm = Smoothness::build(&shards, 1e-3).with_global(&global.a);
        let mk = |name: &str, c: CompressorKind| {
            let mut s = MethodSpec::new(name, 2.0, SamplingKind::Uniform, 1e-3, vec![0.0; sm.dim]);
            s.compressor = c;
            s
        };
        // allowed combinations build
        for (name, c) in [
            ("dcgd", CompressorKind::Sketch),
            ("dcgd", CompressorKind::SaQuant),
            ("dcgd", CompressorKind::TopK),
            ("diana", CompressorKind::SaQuant),
            ("adiana", CompressorKind::SaQuant),
            ("diana+", CompressorKind::MatrixAware),
            ("dgd", CompressorKind::Default),
        ] {
            assert!(build(&mk(name, c), &sm).is_ok(), "{name} + {}", c.name());
        }
        // disallowed combinations bail with a clear message
        for (name, c) in [
            ("dgd", CompressorKind::Sketch),
            ("dcgd+", CompressorKind::SaQuant),
            ("diana", CompressorKind::TopK),
            ("diana+", CompressorKind::Sketch),
            ("adiana+", CompressorKind::SaQuant),
        ] {
            let err = build(&mk(name, c), &sm).unwrap_err().to_string();
            assert!(
                err.contains("not applicable"),
                "{name} + {} gave: {err}",
                c.name()
            );
        }
    }

    #[test]
    fn sa_quant_methods_run_and_snapshot_roundtrip() {
        // the diana worker/server state machinery must survive sa-quant's
        // whitened messages (shift updates route through the decompressor)
        use crate::compress::{CompressorKind, QuantWeighting};
        use crate::data::synth;
        use crate::runtime::native::NativeEngine;
        use crate::sampling::SamplingKind;

        let ds = synth::generate(&synth::tiny_spec(), 5);
        let (_, shards) = ds.prepare(2, 5);
        let sm = Smoothness::build(&shards, 1e-3);
        for name in ["dcgd", "diana", "adiana"] {
            for weighting in [QuantWeighting::Diag, QuantWeighting::Root] {
                let mut spec =
                    MethodSpec::new(name, 2.0, SamplingKind::Uniform, 1e-3, vec![0.0; sm.dim]);
                spec.compressor = CompressorKind::SaQuant;
                spec.sa_levels = 4;
                spec.sa_weighting = weighting;
                let mut m = build(&spec, &sm).unwrap();
                let mut m2 = build(&spec, &sm).unwrap();
                let mut engines: Vec<Box<dyn GradEngine>> = shards
                    .iter()
                    .map(|s| Box::new(NativeEngine::from_shard(s, 1e-3)) as Box<dyn GradEngine>)
                    .collect();
                let mut server_rng = Rng::new(3).derive(u64::MAX);
                let mut worker_rngs: Vec<Rng> =
                    (0..shards.len() as u64).map(|i| Rng::new(3).derive(i)).collect();
                let mut bufs = RoundBuffers::new(shards.len());
                for _ in 0..4 {
                    sync_round(&mut m, &mut engines, &mut server_rng, &mut worker_rngs, &mut bufs);
                }
                assert!(
                    m.server.iterate().iter().all(|v| v.is_finite()),
                    "{name}/{:?}: non-finite iterate",
                    weighting
                );
                let mut blob = Vec::new();
                m.server.save_state(&mut blob);
                assert!(m2.server.load_state(&blob), "{name}: server blob must load");
                for (w, w2) in m.workers.iter().zip(m2.workers.iter_mut()) {
                    let mut wb = Vec::new();
                    w.save_state(&mut wb);
                    assert!(w2.load_state(&wb), "{name}: worker blob must load");
                }
                let mut rng_b = server_rng.clone();
                let mut wr_b = worker_rngs.clone();
                let mut bufs_b = RoundBuffers::new(shards.len());
                sync_round(&mut m, &mut engines, &mut server_rng, &mut worker_rngs, &mut bufs);
                sync_round(&mut m2, &mut engines, &mut rng_b, &mut wr_b, &mut bufs_b);
                let a: Vec<u64> = m.server.iterate().iter().map(|v| v.to_bits()).collect();
                let b: Vec<u64> = m2.server.iterate().iter().map(|v| v.to_bits()).collect();
                assert_eq!(a, b, "{name}/{weighting:?}: restored server diverged");
            }
        }
    }

    #[test]
    fn stateful_servers_save_load_roundtrip() {
        // Server-side analogue of the worker test above: drive a method a
        // few joint rounds, snapshot the server (and workers, so the next
        // joint round is comparable), restore into a fresh build, and
        // assert the next iterate is bit-identical. diana++ exercises the
        // trickiest blob (pending δ message + protocol flags), adiana+
        // the accelerated y/z/w triple plus the rng-coupled w update.
        use crate::data::synth;
        use crate::objective::Smoothness;
        use crate::runtime::native::NativeEngine;
        use crate::runtime::GradEngine;
        use crate::sampling::SamplingKind;
        use crate::util::rng::Rng;

        let ds = synth::generate(&synth::tiny_spec(), 5);
        let (global, shards) = ds.prepare(2, 5);
        let sm = Smoothness::build(&shards, 1e-3).with_global(&global.a);
        for name in METHOD_NAMES {
            let spec = MethodSpec::new(name, 2.0, SamplingKind::Uniform, 1e-3, vec![0.0; sm.dim]);
            let mut m = build(&spec, &sm).unwrap();
            let mut m2 = build(&spec, &sm).unwrap();
            let mut engines: Vec<Box<dyn GradEngine>> = shards
                .iter()
                .map(|s| Box::new(NativeEngine::from_shard(s, 1e-3)) as Box<dyn GradEngine>)
                .collect();
            let mut server_rng = Rng::new(3).derive(u64::MAX);
            let mut worker_rngs: Vec<Rng> =
                (0..shards.len() as u64).map(|i| Rng::new(3).derive(i)).collect();
            let mut bufs = RoundBuffers::new(shards.len());
            for _ in 0..4 {
                sync_round(&mut m, &mut engines, &mut server_rng, &mut worker_rngs, &mut bufs);
            }
            let mut blob = Vec::new();
            m.server.save_state(&mut blob);
            assert!(!blob.is_empty(), "{name}: server state must not be empty");
            assert!(m2.server.load_state(&blob), "{name}: server blob must load");
            for (w, w2) in m.workers.iter().zip(m2.workers.iter_mut()) {
                let mut wb = Vec::new();
                w.save_state(&mut wb);
                assert!(w2.load_state(&wb), "{name}: worker blob must load");
            }
            let mut rng_b = server_rng.clone();
            let mut wr_b = worker_rngs.clone();
            let mut bufs_b = RoundBuffers::new(shards.len());
            sync_round(&mut m, &mut engines, &mut server_rng, &mut worker_rngs, &mut bufs);
            sync_round(&mut m2, &mut engines, &mut rng_b, &mut wr_b, &mut bufs_b);
            let a: Vec<u64> = m.server.iterate().iter().map(|v| v.to_bits()).collect();
            let b: Vec<u64> = m2.server.iterate().iter().map(|v| v.to_bits()).collect();
            assert_eq!(a, b, "{name}: restored server diverged");
            // malformed blobs are rejected, not silently accepted
            assert!(
                !m2.server.load_state(&blob[..blob.len() - 1]),
                "{name}: truncated server blob must be rejected"
            );
        }
    }
}
