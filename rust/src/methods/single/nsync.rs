//! 'NSync (Algorithm 4, Richtárik & Takáč 2016a): arbitrary-sampling
//! coordinate descent `x⁺ = x − (1/v) ∘ ∇f(x)_S` with ESO parameters
//! `v = λ·p` (Lemma 9 shows this matches SkGD's rate), or the classical
//! serial choice `v_j = L_jj` when |S| = 1.

use crate::methods::single::{eso_lambda, SingleMethod};
use crate::objective::logreg::LogReg;
use crate::objective::smoothness::LocalSmoothness;
use crate::sampling::IndependentSampling;
use crate::util::rng::Rng;

pub struct NSync {
    pub x: Vec<f64>,
    /// per-coordinate ESO stepsizes 1/v_j
    pub inv_v: Vec<f64>,
    sampling: IndependentSampling,
    grad: Vec<f64>,
}

impl NSync {
    /// Generic arbitrary-sampling variant with v = λ·p (Lemma 9).
    pub fn new(sm: &LocalSmoothness, sampling: IndependentSampling, x0: Vec<f64>) -> NSync {
        let lam = eso_lambda(&sm.root, &sm.diag, &sampling.p);
        let inv_v = sampling.p.iter().map(|&pj| 1.0 / (lam * pj)).collect();
        NSync {
            grad: vec![0.0; x0.len()],
            x: x0,
            inv_v,
            sampling,
        }
    }

    /// Serial variant (|S| = 1 in expectation structure): v_j = L_jj with
    /// the optimal probabilities p_j = L_jj / Σ_l L_ll (Appendix B.1).
    pub fn serial_optimal(sm: &LocalSmoothness, x0: Vec<f64>) -> NSync {
        let total: f64 = sm.diag.iter().sum();
        let p: Vec<f64> = sm.diag.iter().map(|&l| (l / total).max(1e-12)).collect();
        let inv_v = sm.diag.iter().map(|&l| 1.0 / l).collect();
        NSync {
            grad: vec![0.0; x0.len()],
            x: x0,
            inv_v,
            sampling: IndependentSampling::new(p),
        }
    }
}

impl SingleMethod for NSync {
    fn step(&mut self, obj: &LogReg, rng: &mut Rng) {
        obj.grad_into(&self.x, &mut self.grad);
        for (j, &pj) in self.sampling.p.iter().enumerate() {
            if pj >= 1.0 || rng.bernoulli(pj) {
                // biased direction: no 1/p_j rescale (contrast with SkGD)
                self.x[j] -= self.inv_v[j] * self.grad[j];
            }
        }
    }

    fn x(&self) -> &[f64] {
        &self.x
    }

    fn name(&self) -> &'static str {
        "nsync"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::objective::smoothness::build_local;

    fn setup() -> (LogReg, LocalSmoothness, usize) {
        let ds = synth::generate(&synth::tiny_spec(), 5);
        let (global, _) = ds.prepare(1, 5);
        let d = global.dim();
        let obj = LogReg::new(global.a.clone(), global.b.clone(), 1e-3);
        let loc = build_local(&global.a, 1e-3);
        (obj, loc, d)
    }

    #[test]
    fn nsync_converges() {
        let (obj, loc, d) = setup();
        let sampling = IndependentSampling::uniform(d, 4.0);
        let mut m = NSync::new(&loc, sampling, vec![0.0; d]);
        let f0 = obj.loss(&m.x);
        let mut rng = Rng::new(1);
        for _ in 0..4000 {
            m.step(&obj, &mut rng);
        }
        assert!(obj.loss(&m.x) < f0, "no descent");
        let g = obj.grad(&m.x);
        assert!(crate::linalg::vector::norm(&g) < 0.2 * crate::linalg::vector::norm(&obj.grad(&vec![0.0; d])));
    }

    #[test]
    fn serial_optimal_converges() {
        let (obj, loc, d) = setup();
        let mut m = NSync::serial_optimal(&loc, vec![0.0; d]);
        let f0 = obj.loss(&m.x);
        let mut rng = Rng::new(2);
        for _ in 0..6000 {
            m.step(&obj, &mut rng);
        }
        assert!(obj.loss(&m.x) < f0);
    }
}
