//! Single-node methods (Appendix B): randomized coordinate descent viewed
//! as sketched compressed gradient descent.
//!
//! * `'NSync` (Algorithm 4, Richtárik & Takáč 2016a) — arbitrary-sampling
//!   coordinate descent with ESO stepsizes `v = λ·p`;
//! * `SkGD` (Algorithm 5) — x⁺ = x − γC∇f(x) with the unbiased diagonal
//!   sketch, γ = 1/λ_max(P̄∘L) (Theorem 8);
//! * `CGD+` (Algorithm 6) — x⁺ = prox_{γR}(x − γ C̄∇f(x)) with the
//!   matrix-aware sketch C̄ = L^{1/2}CL^{†1/2}, γ = 1/(2𝓛̄) (Theorem 12).
//!
//! Lemma 9: 'NSync and SkGD share the same ESO constant
//! λ = λ_max(P̄∘L); for an independent sampling
//! `P̄∘L = L + Diag((1/p_j − 1)L_jj)`, computed here by power iteration.

pub mod cgd_plus;
pub mod greedy;
pub mod nsync;
pub mod skgd;

use crate::linalg::psd::PsdRoot;
use crate::objective::logreg::LogReg;
use crate::util::rng::Rng;

/// Common interface: one stochastic step; `x` is the iterate.
pub trait SingleMethod {
    fn step(&mut self, obj: &LogReg, rng: &mut Rng);
    fn x(&self) -> &[f64];
    fn name(&self) -> &'static str;
}

/// λ_max(P̄ ∘ L) = λ_max(L + Diag((1/p − 1) ∘ diag L)) for an independent
/// sampling (ESO constant shared by 'NSync/SkGD/CGD+; Lemma 9 / Lemma 11).
pub fn eso_lambda(root: &PsdRoot, diag: &[f64], p: &[f64]) -> f64 {
    let d = diag.len();
    let add: Vec<f64> = p
        .iter()
        .zip(diag)
        .map(|(&pj, &lj)| (1.0 / pj - 1.0) * lj)
        .collect();
    let mut tmp = vec![0.0; d];
    crate::linalg::eigen::power_lambda_max(
        d,
        |x, y| {
            root.apply_pow_into(1.0, x, &mut tmp);
            for j in 0..d {
                y[j] = tmp[j] + add[j] * x[j];
            }
        },
        1e-12,
        20_000,
        0xE50,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::objective::smoothness::build_local;

    #[test]
    fn eso_lambda_bounds_lemma11() {
        // L ≤ 𝓛̄ ≤ L + 𝓛̃ (Lemma 11)
        let ds = synth::generate(&synth::tiny_spec(), 1);
        let (global, _) = ds.prepare(1, 1);
        let loc = build_local(&global.a, 1e-3);
        let p = vec![0.25; global.dim()];
        let lam = eso_lambda(&loc.root, &loc.diag, &p);
        let l = loc.root.lambda_max();
        let tilde = crate::objective::smoothness::tilde_l_independent(&p, &loc.diag);
        assert!(lam >= l * 0.999, "lambda={lam} < L={l}");
        assert!(lam <= l + tilde + 1e-9, "lambda={lam} > L+tilde={}", l + tilde);
    }

    #[test]
    fn eso_lambda_full_sampling_is_l() {
        let ds = synth::generate(&synth::tiny_spec(), 2);
        let (global, _) = ds.prepare(1, 2);
        let loc = build_local(&global.a, 1e-3);
        let p = vec![1.0; global.dim()];
        let lam = eso_lambda(&loc.root, &loc.diag, &p);
        assert!((lam - loc.root.lambda_max()).abs() < 1e-8 * lam);
    }
}
