//! SkGD (Algorithm 5): sketched gradient descent
//! `x⁺ = x − γ C ∇f(x)` with γ = 1/λ_max(P̄∘L) (Theorem 8).

use crate::methods::single::{eso_lambda, SingleMethod};
use crate::objective::logreg::LogReg;
use crate::objective::smoothness::LocalSmoothness;
use crate::sampling::IndependentSampling;
use crate::util::rng::Rng;

pub struct SkGd {
    pub x: Vec<f64>,
    pub gamma: f64,
    sampling: IndependentSampling,
    grad: Vec<f64>,
}

impl SkGd {
    pub fn new(sm: &LocalSmoothness, sampling: IndependentSampling, x0: Vec<f64>) -> SkGd {
        let lam = eso_lambda(&sm.root, &sm.diag, &sampling.p);
        SkGd {
            grad: vec![0.0; x0.len()],
            x: x0,
            gamma: 1.0 / lam,
            sampling,
        }
    }
}

impl SingleMethod for SkGd {
    fn step(&mut self, obj: &LogReg, rng: &mut Rng) {
        obj.grad_into(&self.x, &mut self.grad);
        for (j, &pj) in self.sampling.p.iter().enumerate() {
            if pj >= 1.0 || rng.bernoulli(pj) {
                self.x[j] -= self.gamma * self.grad[j] / pj;
            }
        }
    }

    fn x(&self) -> &[f64] {
        &self.x
    }

    fn name(&self) -> &'static str {
        "skgd"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::objective::smoothness::build_local;

    #[test]
    fn skgd_converges_in_function_value() {
        let ds = synth::generate(&synth::tiny_spec(), 1);
        let (global, _) = ds.prepare(1, 1);
        let obj = LogReg::new(global.a.clone(), global.b.clone(), 1e-3);
        let loc = build_local(&global.a, 1e-3);
        let sampling = IndependentSampling::uniform(global.dim(), 4.0);
        let mut m = SkGd::new(&loc, sampling, vec![0.0; global.dim()]);
        let f0 = obj.loss(&m.x);
        // reference optimum via plain full-gradient descent
        let mut xg = vec![0.0; global.dim()];
        for _ in 0..20_000 {
            let g = obj.grad(&xg);
            for j in 0..xg.len() {
                xg[j] -= g[j] / loc.root.lambda_max();
            }
        }
        let fstar_approx = obj.loss(&xg);

        let mut rng = Rng::new(7);
        for _ in 0..20_000 {
            m.step(&obj, &mut rng);
        }
        let f1 = obj.loss(&m.x);
        // SkGD must close ≥ 90% of the optimality gap
        assert!(
            f1 - fstar_approx < 0.1 * (f0 - fstar_approx),
            "f0={f0} f1={f1} f*≈{fstar_approx}"
        );
    }
}
