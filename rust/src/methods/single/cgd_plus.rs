//! CGD+ (Algorithm 6): proximal compressed gradient descent with the
//! non-diagonal matrix-aware sketch `C̄ = L^{1/2} C L^{†1/2}`,
//! γ = 1/(2𝓛̄) (Theorem 12). In the unregularized single-node case
//! ∇f(x*) = 0, so the Theorem-12 neighborhood vanishes and the method
//! converges to x* exactly.

use crate::compress::{MatrixAware, SparseMsg};
use crate::methods::prox::Prox;
use crate::methods::single::{eso_lambda, SingleMethod};
use crate::objective::logreg::LogReg;
use crate::objective::smoothness::LocalSmoothness;
use crate::sampling::IndependentSampling;
use crate::util::rng::Rng;

pub struct CgdPlus {
    pub x: Vec<f64>,
    pub gamma: f64,
    pub prox: Prox,
    compressor: MatrixAware,
    root: crate::linalg::psd::PsdRoot,
    grad: Vec<f64>,
    g: Vec<f64>,
    msg: SparseMsg,
}

impl CgdPlus {
    pub fn new(
        sm: &LocalSmoothness,
        sampling: IndependentSampling,
        prox: Prox,
        x0: Vec<f64>,
    ) -> CgdPlus {
        let lbar = eso_lambda(&sm.root, &sm.diag, &sampling.p);
        CgdPlus {
            grad: vec![0.0; x0.len()],
            g: vec![0.0; x0.len()],
            x: x0,
            gamma: 1.0 / (2.0 * lbar),
            prox,
            compressor: MatrixAware::new(sampling),
            root: sm.root.clone(),
            msg: SparseMsg::new(),
        }
    }
}

impl SingleMethod for CgdPlus {
    fn step(&mut self, obj: &LogReg, rng: &mut Rng) {
        obj.grad_into(&self.x, &mut self.grad);
        self.compressor
            .compress(&self.root, &self.grad, rng, &mut self.msg);
        self.root
            .apply_pow_sparse_into(0.5, &self.msg.idx, &self.msg.val, &mut self.g);
        for j in 0..self.x.len() {
            self.x[j] -= self.gamma * self.g[j];
        }
        self.prox.apply(self.gamma, &mut self.x);
    }

    fn x(&self) -> &[f64] {
        &self.x
    }

    fn name(&self) -> &'static str {
        "cgd+"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::linalg::vector;
    use crate::objective::smoothness::build_local;

    #[test]
    fn cgd_plus_converges_to_near_stationarity() {
        let ds = synth::generate(&synth::tiny_spec(), 9);
        let (global, _) = ds.prepare(1, 9);
        let d = global.dim();
        let obj = LogReg::new(global.a.clone(), global.b.clone(), 1e-3);
        let loc = build_local(&global.a, 1e-3);
        let sampling = IndependentSampling::uniform(d, 4.0);
        let mut m = CgdPlus::new(&loc, sampling, Prox::None, vec![0.0; d]);
        let g0 = vector::norm(&obj.grad(&m.x));
        let mut rng = Rng::new(3);
        for _ in 0..8000 {
            m.step(&obj, &mut rng);
        }
        let g1 = vector::norm(&obj.grad(&m.x));
        assert!(g1 < 0.05 * g0, "‖∇f‖ {g0} → {g1}");
    }

    #[test]
    fn cgd_plus_with_l1_prox_produces_sparse_iterate() {
        let ds = synth::generate(&synth::tiny_spec(), 10);
        let (global, _) = ds.prepare(1, 10);
        let d = global.dim();
        let obj = LogReg::new(global.a.clone(), global.b.clone(), 1e-3);
        let loc = build_local(&global.a, 1e-3);
        let sampling = IndependentSampling::uniform(d, 8.0);
        let mut m = CgdPlus::new(&loc, sampling, Prox::L1 { lambda: 0.05 }, vec![0.5; d]);
        let mut rng = Rng::new(4);
        for _ in 0..4000 {
            m.step(&obj, &mut rng);
        }
        let zeros = m.x.iter().filter(|&&v| v == 0.0).count();
        assert!(zeros > 0, "L1 prox should zero out some coordinates");
    }
}
