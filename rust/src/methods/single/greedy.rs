//! Greedy sparsification (paper §7 "Extensions and Future Work"):
//! the paper asks whether *greedy* coordinate selection — Nutini et al.'s
//! Gauss-Southwell rule, which beats randomized coordinate descent in
//! certain regimes — can replace the randomized sketch in the
//! matrix-aware protocol.
//!
//! We implement the single-node variant as an extension of CGD+
//! (Algorithm 6): instead of a random diagonal sketch `C`, pick the τ
//! **largest-magnitude coordinates of the whitened gradient**
//! `w = L^{†1/2}∇f(x)` (a matrix-smoothness Gauss-Southwell-L rule), then
//! decompress with `L^{1/2}`. The update is biased but monotone; we run
//! it with the SkGD stepsize 1/𝓛̄ restricted to the selected block.

use crate::compress::{topk_compress, SparseMsg};
use crate::linalg::psd::PsdRoot;
use crate::methods::single::SingleMethod;
use crate::objective::logreg::LogReg;
use crate::objective::smoothness::LocalSmoothness;
use crate::util::rng::Rng;

pub struct GreedyCgdPlus {
    pub x: Vec<f64>,
    pub gamma: f64,
    pub tau: usize,
    root: PsdRoot,
    grad: Vec<f64>,
    whitened: Vec<f64>,
    g: Vec<f64>,
    msg: SparseMsg,
}

impl GreedyCgdPlus {
    pub fn new(sm: &LocalSmoothness, tau: usize, x0: Vec<f64>) -> GreedyCgdPlus {
        // Greedy selection concentrates on the dominant eigendirections;
        // γ = 1/λ_max(L) is the safe (smoothness-exact) choice since the
        // decompressed step L^{1/2}·top-τ·L^{†1/2}∇f stays in a subspace
        // where L bounds curvature.
        let d = x0.len();
        GreedyCgdPlus {
            gamma: 1.0 / sm.root.lambda_max(),
            tau,
            root: sm.root.clone(),
            grad: vec![0.0; d],
            whitened: vec![0.0; d],
            g: vec![0.0; d],
            msg: SparseMsg::new(),
            x: x0,
        }
    }
}

impl SingleMethod for GreedyCgdPlus {
    fn step(&mut self, obj: &LogReg, _rng: &mut Rng) {
        obj.grad_into(&self.x, &mut self.grad);
        self.root
            .apply_pow_into(-0.5, &self.grad, &mut self.whitened);
        topk_compress(&self.whitened, self.tau, &mut self.msg);
        self.root
            .apply_pow_sparse_into(0.5, &self.msg.idx, &self.msg.val, &mut self.g);
        for j in 0..self.x.len() {
            self.x[j] -= self.gamma * self.g[j];
        }
    }

    fn x(&self) -> &[f64] {
        &self.x
    }

    fn name(&self) -> &'static str {
        "greedy-cgd+"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::linalg::vector;
    use crate::objective::smoothness::build_local;
    use crate::sampling::IndependentSampling;

    fn setup() -> (LogReg, LocalSmoothness, usize) {
        let ds = synth::generate(&synth::tiny_spec(), 31);
        let (global, _) = ds.prepare(1, 31);
        let d = global.dim();
        let obj = LogReg::new(global.a.clone(), global.b.clone(), 1e-3);
        let loc = build_local(&global.a, 1e-3);
        (obj, loc, d)
    }

    #[test]
    fn greedy_converges() {
        let (obj, loc, d) = setup();
        let mut m = GreedyCgdPlus::new(&loc, 4, vec![0.0; d]);
        let mut rng = Rng::new(1);
        let g0 = vector::norm(&obj.grad(&m.x));
        for _ in 0..4000 {
            m.step(&obj, &mut rng);
        }
        let g1 = vector::norm(&obj.grad(&m.x));
        assert!(g1 < 0.02 * g0, "‖∇f‖ {g0:.3e} → {g1:.3e}");
    }

    #[test]
    fn greedy_decreases_loss_steadily() {
        // not strictly monotone (the unwhitened top-τ direction can
        // overshoot slightly), but every 50-step window must decrease
        let (obj, loc, d) = setup();
        let mut m = GreedyCgdPlus::new(&loc, 4, vec![0.0; d]);
        let mut rng = Rng::new(2);
        let mut prev = obj.loss(&m.x);
        for _ in 0..6 {
            for _ in 0..50 {
                m.step(&obj, &mut rng);
            }
            let f = obj.loss(&m.x);
            assert!(f < prev, "window did not decrease: {prev} -> {f}");
            prev = f;
        }
    }

    #[test]
    fn greedy_beats_randomized_at_same_budget() {
        // the §7 question: greedy should need no more gradient-norm
        // progress per selected coordinate than the randomized sketch
        let (obj, loc, d) = setup();
        let tau = 2usize;
        let steps = 2500;

        let mut greedy = GreedyCgdPlus::new(&loc, tau, vec![0.0; d]);
        let mut rng = Rng::new(3);
        for _ in 0..steps {
            greedy.step(&obj, &mut rng);
        }
        let g_greedy = vector::norm(&obj.grad(&greedy.x));

        let sampling = IndependentSampling::uniform(d, tau as f64);
        let mut random = crate::methods::single::cgd_plus::CgdPlus::new(
            &loc,
            sampling,
            crate::methods::prox::Prox::None,
            vec![0.0; d],
        );
        let mut rng2 = Rng::new(3);
        for _ in 0..steps {
            random.step(&obj, &mut rng2);
        }
        let g_random = vector::norm(&obj.grad(&random.x));
        assert!(
            g_greedy <= g_random * 1.2,
            "greedy {g_greedy:.3e} vs randomized {g_random:.3e}"
        );
    }
}
