//! ADIANA+ (Algorithm 3) — accelerated DIANA with matrix-smoothness-aware
//! sparsification. Also hosts the shared accelerated server/worker
//! machinery reused by the original-ADIANA baseline (identity smoothness,
//! standard sketches).
//!
//! Per round (server): broadcast `x^k = θ₁z^k + θ₂w^k + (1−θ₁−θ₂)y^k` and
//! `w^k`; on uplinks compute
//!   `g^k = (1/n)Σ L_i^{1/2}Δ_i + h^k`,   `h^{k+1} = h^k + α(1/n)Σ L_i^{1/2}δ_i`,
//!   `y^{k+1} = prox_{ηR}(x^k − ηg^k)`,
//!   `z^{k+1} = βz^k + (1−β)x^k + (γ/η)(y^{k+1} − x^k)`,
//!   `w^{k+1} = y^k  w.p. q, else w^k`.
//! Workers send `Δ_i = C_i L_i^{†1/2}(∇f_i(x^k) − h_i)` and
//! `δ_i = C_i' L_i^{†1/2}(∇f_i(w^k) − h_i)` (independent sketches), and
//! shift `h_i ← h_i + α L_i^{1/2} δ_i`.

use crate::compress::{
    sketch_compress, CompressorKind, MatrixAware, SaQuant, SparseMsg, UplinkDecompressor,
};
use crate::linalg::psd::PsdRoot;
use crate::methods::prox::Prox;
use crate::methods::stepsize::{self, AdianaParams};
use crate::methods::{
    dense_downlink_into, sa_quant_family, Downlink, MethodSpec, ServerAlgo, Uplink, WorkerAlgo,
};
use crate::objective::Smoothness;
use crate::runtime::GradEngine;
use crate::sampling::IndependentSampling;
use crate::util::rng::Rng;
use std::sync::Arc;

/// Worker: matrix-aware if `root` is Some, sa-quant if `quant` is Some,
/// standard sketch otherwise.
pub struct AccelWorker {
    sampling: IndependentSampling,
    root: Option<Arc<PsdRoot>>,
    alpha: f64,
    h: Vec<f64>,
    grad_x: Vec<f64>,
    grad_w: Vec<f64>,
    diff: Vec<f64>,
    dbar: Vec<f64>,
    coeff: Vec<f64>,
    compressor: Option<MatrixAware>,
    quant: Option<SaQuant>,
    /// sa-quant's unwhitener for the worker-local shift update
    quant_dec: Option<UplinkDecompressor>,
}

impl AccelWorker {
    fn compress(&mut self, v_is_x: bool, rng: &mut Rng, out: &mut SparseMsg) {
        // self.diff already holds (∇f(·) − h)
        let _ = v_is_x;
        if let Some(q) = &mut self.quant {
            q.compress(&self.diff, rng, out);
            return;
        }
        match (&mut self.compressor, &self.root) {
            (Some(c), Some(root)) => c.compress(root, &self.diff, rng, out),
            _ => sketch_compress(&self.diff, &self.sampling, rng, out),
        }
    }
}

impl WorkerAlgo for AccelWorker {
    fn round(&mut self, down: &Downlink, engine: &mut dyn GradEngine, rng: &mut Rng) -> Uplink {
        let mut up = Uplink::default();
        self.round_into(down, engine, rng, &mut up);
        up
    }

    fn round_into(
        &mut self,
        down: &Downlink,
        engine: &mut dyn GradEngine,
        rng: &mut Rng,
        up: &mut Uplink,
    ) {
        let (x, w) = match down {
            Downlink::Dense { x, w: Some(w) } => (x, w),
            _ => unreachable!("adiana needs dense downlink with anchor w"),
        };
        engine.grad_into(x, &mut self.grad_x);
        engine.grad_into(w, &mut self.grad_w);

        // Δ_i from x^k
        for j in 0..self.diff.len() {
            self.diff[j] = self.grad_x[j] - self.h[j];
        }
        self.compress(true, rng, &mut up.delta);

        // δ_i from w^k (independent sketch draw), reusing the persistent
        // second-message buffer
        for j in 0..self.diff.len() {
            self.diff[j] = self.grad_w[j] - self.h[j];
        }
        let delta2 = up.delta2.get_or_insert_with(SparseMsg::new);
        self.compress(false, rng, delta2);

        // h_i ← h_i + α·decompress(δ_i)
        if let Some(qd) = &mut self.quant_dec {
            qd.accumulate_scaled(delta2, self.alpha, &mut self.h);
            return;
        }
        match &self.root {
            Some(root) => {
                root.apply_pow_sparse_into_with(
                    0.5,
                    &delta2.idx,
                    &delta2.val,
                    &mut self.dbar,
                    &mut self.coeff,
                );
                for j in 0..self.h.len() {
                    self.h[j] += self.alpha * self.dbar[j];
                }
            }
            None => {
                for (k, &i) in delta2.idx.iter().enumerate() {
                    self.h[i as usize] += self.alpha * delta2.val[k];
                }
            }
        }
    }

    fn dim(&self) -> usize {
        self.h.len()
    }

    fn save_state(&self, out: &mut Vec<u8>) {
        crate::methods::state::put_vec(out, &self.h);
    }

    fn load_state(&mut self, buf: &[u8]) -> bool {
        let mut pos = 0;
        crate::methods::state::get_vec(buf, &mut pos, &mut self.h) && pos == buf.len()
    }
}

pub struct AccelServer {
    params: AdianaParams,
    prox: Prox,
    x: Vec<f64>,
    y: Vec<f64>,
    /// previous y^k, persisted for the probabilistic w update (§Perf:
    /// replaces a per-round clone)
    y_prev: Vec<f64>,
    z: Vec<f64>,
    w: Vec<f64>,
    h: Vec<f64>,
    /// None ⇒ standard sketches (original ADIANA)
    roots: Option<Vec<Arc<PsdRoot>>>,
    /// Some ⇒ sa-quant: per-worker unwhiteners (takes precedence over
    /// `roots`, which is None in that mode)
    quant_decomp: Option<Vec<UplinkDecompressor>>,
    dbar: Vec<f64>,
    delta_bar: Vec<f64>,
    scratch: Vec<f64>,
    coeff: Vec<f64>,
    name: &'static str,
}

impl AccelServer {
    fn aggregate(&mut self, ups: &[Uplink], second: bool) {
        // accumulate into self.dbar (Δ̄ or δ̄)
        self.dbar.fill(0.0);
        for (i, u) in ups.iter().enumerate() {
            let msg = if second {
                u.delta2.as_ref().expect("adiana uplink needs δ")
            } else {
                &u.delta
            };
            if let Some(decomp) = &mut self.quant_decomp {
                decomp[i].accumulate(msg, &mut self.dbar);
                continue;
            }
            match &self.roots {
                Some(roots) => {
                    roots[i].apply_pow_sparse_into_with(
                        0.5,
                        &msg.idx,
                        &msg.val,
                        &mut self.scratch,
                        &mut self.coeff,
                    );
                    for j in 0..self.dbar.len() {
                        self.dbar[j] += self.scratch[j];
                    }
                }
                None => {
                    for (k, &idx) in msg.idx.iter().enumerate() {
                        self.dbar[idx as usize] += msg.val[k];
                    }
                }
            }
        }
        let inv_n = 1.0 / ups.len() as f64;
        for v in self.dbar.iter_mut() {
            *v *= inv_n;
        }
    }
}

impl ServerAlgo for AccelServer {
    fn downlink(&mut self) -> Downlink {
        let mut down = Downlink::Init { x: Vec::new() };
        self.downlink_into(&mut down);
        down
    }

    fn downlink_into(&mut self, down: &mut Downlink) {
        let p = &self.params;
        for j in 0..self.x.len() {
            self.x[j] = p.theta1 * self.z[j]
                + p.theta2 * self.w[j]
                + (1.0 - p.theta1 - p.theta2) * self.y[j];
        }
        dense_downlink_into(&self.x, Some(&self.w), down);
    }

    fn apply(&mut self, ups: &[Uplink], rng: &mut Rng) {
        let p = self.params;

        // g^k = Δ̄ + h ; y^{k+1} = prox_η(x − ηg)
        self.aggregate(ups, false);
        for j in 0..self.dbar.len() {
            self.delta_bar[j] = self.dbar[j];
        }
        // δ̄ for the shift update
        self.aggregate(ups, true);

        self.y_prev.copy_from_slice(&self.y);
        for j in 0..self.x.len() {
            let g = self.delta_bar[j] + self.h[j];
            self.y[j] = self.x[j] - p.eta * g;
        }
        self.prox.apply(p.eta, &mut self.y);

        // z^{k+1} = βz + (1−β)x + (γ/η)(y^{k+1} − x)
        for j in 0..self.z.len() {
            self.z[j] = p.beta * self.z[j]
                + (1.0 - p.beta) * self.x[j]
                + (p.gamma / p.eta) * (self.y[j] - self.x[j]);
        }

        // h^{k+1} = h + αδ̄
        for j in 0..self.h.len() {
            self.h[j] += p.alpha * self.dbar[j];
        }

        // w^{k+1} = y^k with probability q
        if rng.bernoulli(p.q) {
            self.w.copy_from_slice(&self.y_prev);
        }
    }

    fn iterate(&self) -> &[f64] {
        &self.y
    }

    fn dim(&self) -> usize {
        self.x.len()
    }

    fn name(&self) -> &'static str {
        self.name
    }

    fn save_state(&self, out: &mut Vec<u8>) {
        // y_prev and the aggregation scratch are transient within apply;
        // x is recomputed by the next downlink from (z, w, y)
        crate::methods::state::put_vec(out, &self.y);
        crate::methods::state::put_vec(out, &self.z);
        crate::methods::state::put_vec(out, &self.w);
        crate::methods::state::put_vec(out, &self.h);
    }

    fn load_state(&mut self, buf: &[u8]) -> bool {
        let mut pos = 0;
        crate::methods::state::get_vec(buf, &mut pos, &mut self.y)
            && crate::methods::state::get_vec(buf, &mut pos, &mut self.z)
            && crate::methods::state::get_vec(buf, &mut pos, &mut self.w)
            && crate::methods::state::get_vec(buf, &mut pos, &mut self.h)
            && pos == buf.len()
    }
}

/// Shared constructor for ADIANA / ADIANA+.
pub fn build_accel(
    spec: &MethodSpec,
    sm: &Smoothness,
    matrix_aware: bool,
    name: &'static str,
) -> (Box<dyn ServerAlgo>, Vec<Box<dyn WorkerAlgo + Send>>) {
    let dim = sm.dim;
    let n = sm.n();
    // sa-quant replaces the sketch on the original-ADIANA baseline only
    // (the builder's applicability check upholds this)
    let sa_quant = !matrix_aware && spec.compressor == CompressorKind::SaQuant;

    let (samplings, roots): (Vec<IndependentSampling>, Option<Vec<Arc<PsdRoot>>>) =
        if matrix_aware {
            let roots: Vec<Arc<PsdRoot>> =
                sm.locals.iter().map(|l| Arc::new(l.root.clone())).collect();
            let samplings = sm
                .locals
                .iter()
                .map(|loc| spec.sampling.build(&loc.diag, spec.tau, spec.mu, n))
                .collect();
            (samplings, Some(roots))
        } else {
            let s = IndependentSampling::uniform(dim, spec.tau);
            ((0..n).map(|_| s.clone()).collect(), None)
        };

    let (mut quants, quant_decomp, quant_tilde) = if sa_quant {
        let (q, d, t) = sa_quant_family(sm, spec.sa_levels, spec.sa_weighting);
        (q, Some(d), t)
    } else {
        (Vec::new(), None, 0.0)
    };

    let omega_max = if sa_quant {
        SaQuant::omega(dim, spec.sa_levels)
    } else {
        samplings.iter().map(|s| s.omega()).fold(0.0, f64::max)
    };
    let variance_scale = if sa_quant {
        quant_tilde
    } else if matrix_aware {
        samplings
            .iter()
            .zip(&sm.locals)
            .map(|(s, loc)| s.tilde_l(&loc.diag))
            .fold(0.0, f64::max)
    } else {
        omega_max * sm.l_max
    };
    let params = stepsize::adiana_params(sm, omega_max, variance_scale, spec.practical_adiana);

    let workers: Vec<Box<dyn WorkerAlgo + Send>> = samplings
        .into_iter()
        .enumerate()
        .map(|(i, s)| {
            let root = roots.as_ref().map(|r| r[i].clone());
            let quant = if sa_quant {
                Some(std::mem::replace(
                    &mut quants[i],
                    SaQuant::diag(0, &[]),
                ))
            } else {
                None
            };
            Box::new(AccelWorker {
                compressor: root.as_ref().map(|_| MatrixAware::new(s.clone())),
                sampling: s,
                root,
                alpha: params.alpha,
                h: vec![0.0; dim],
                grad_x: vec![0.0; dim],
                grad_w: vec![0.0; dim],
                diff: vec![0.0; dim],
                dbar: vec![0.0; dim],
                coeff: Vec::new(),
                quant_dec: quant.as_ref().map(|q| q.decompressor()),
                quant,
            }) as Box<dyn WorkerAlgo + Send>
        })
        .collect();

    let server = Box::new(AccelServer {
        params,
        prox: Prox::None,
        x: spec.x0.clone(),
        y: spec.x0.clone(),
        y_prev: spec.x0.clone(),
        z: spec.x0.clone(),
        w: spec.x0.clone(),
        h: vec![0.0; dim],
        roots,
        quant_decomp,
        dbar: vec![0.0; dim],
        delta_bar: vec![0.0; dim],
        scratch: vec![0.0; dim],
        coeff: Vec::new(),
        name,
    });
    (server, workers)
}

pub fn build(
    spec: &MethodSpec,
    sm: &Smoothness,
) -> (Box<dyn ServerAlgo>, Vec<Box<dyn WorkerAlgo + Send>>) {
    build_accel(spec, sm, true, "adiana+")
}
