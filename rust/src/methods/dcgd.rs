//! DCGD — Distributed Compressed Gradient Descent (Khirirat et al., 2018):
//! the original baseline with *standard* (smoothness-unaware) unbiased
//! sparsification `C_i ∇f_i(x^k)`. Converges linearly only to a
//! neighborhood of x* (Theorem 2 analogue with 𝓛̃ → ωL_max).

use crate::compress::sketch_compress;
use crate::methods::prox::Prox;
use crate::methods::{
    dense_downlink_into, stepsize, Downlink, MethodSpec, ServerAlgo, Uplink, WorkerAlgo,
};
use crate::objective::Smoothness;
use crate::runtime::GradEngine;
use crate::sampling::IndependentSampling;
use crate::util::rng::Rng;

pub struct DcgdWorker {
    sampling: IndependentSampling,
    grad: Vec<f64>,
}

impl WorkerAlgo for DcgdWorker {
    fn round(&mut self, down: &Downlink, engine: &mut dyn GradEngine, rng: &mut Rng) -> Uplink {
        let mut up = Uplink::default();
        self.round_into(down, engine, rng, &mut up);
        up
    }

    fn round_into(
        &mut self,
        down: &Downlink,
        engine: &mut dyn GradEngine,
        rng: &mut Rng,
        up: &mut Uplink,
    ) {
        let x = match down {
            Downlink::Dense { x, .. } => x,
            _ => unreachable!("dcgd uses dense downlinks"),
        };
        engine.grad_into(x, &mut self.grad);
        sketch_compress(&self.grad, &self.sampling, rng, &mut up.delta);
        up.delta2 = None;
    }

    fn dim(&self) -> usize {
        self.grad.len()
    }
}

pub struct DcgdServer {
    x: Vec<f64>,
    gamma: f64,
    prox: Prox,
    g: Vec<f64>,
}

impl ServerAlgo for DcgdServer {
    fn downlink(&mut self) -> Downlink {
        let mut down = Downlink::Init { x: Vec::new() };
        self.downlink_into(&mut down);
        down
    }

    fn downlink_into(&mut self, down: &mut Downlink) {
        dense_downlink_into(&self.x, None, down);
    }

    fn apply(&mut self, ups: &[Uplink], _rng: &mut Rng) {
        self.g.fill(0.0);
        for u in ups {
            for (k, &i) in u.delta.idx.iter().enumerate() {
                self.g[i as usize] += u.delta.val[k];
            }
        }
        let step = self.gamma / ups.len() as f64;
        for j in 0..self.x.len() {
            self.x[j] -= step * self.g[j];
        }
        self.prox.apply(self.gamma, &mut self.x);
    }

    fn iterate(&self) -> &[f64] {
        &self.x
    }

    fn dim(&self) -> usize {
        self.x.len()
    }

    fn name(&self) -> &'static str {
        "dcgd"
    }

    fn save_state(&self, out: &mut Vec<u8>) {
        crate::methods::state::put_vec(out, &self.x);
    }

    fn load_state(&mut self, buf: &[u8]) -> bool {
        let mut pos = 0;
        crate::methods::state::get_vec(buf, &mut pos, &mut self.x) && pos == buf.len()
    }
}

pub fn build(
    spec: &MethodSpec,
    sm: &Smoothness,
) -> (Box<dyn ServerAlgo>, Vec<Box<dyn WorkerAlgo + Send>>) {
    let dim = sm.dim;
    // the original method always uses uniform (smoothness-unaware) sampling
    let sampling = IndependentSampling::uniform(dim, spec.tau);
    let omega = sampling.omega();
    let gamma = stepsize::dcgd_gamma(sm, omega);
    let server = Box::new(DcgdServer {
        x: spec.x0.clone(),
        gamma,
        prox: Prox::None,
        g: vec![0.0; dim],
    });
    let workers = (0..sm.n())
        .map(|_| {
            Box::new(DcgdWorker {
                sampling: sampling.clone(),
                grad: vec![0.0; dim],
            }) as Box<dyn WorkerAlgo + Send>
        })
        .collect();
    (server, workers)
}
