//! DCGD — Distributed Compressed Gradient Descent (Khirirat et al., 2018):
//! the original baseline with *standard* (smoothness-unaware) unbiased
//! sparsification `C_i ∇f_i(x^k)`. Converges linearly only to a
//! neighborhood of x* (Theorem 2 analogue with 𝓛̃ → ωL_max).
//!
//! Also the host for the alternative uplink families selectable via
//! `MethodSpec::compressor`:
//! * `sa-quant` — smoothness-aware quantization (arXiv:2106.03524),
//!   stepsize from Theorem 2's 𝓛̃ with 𝓛̃ = ω_q·λ_max(W_i²);
//! * `topk` — greedy top-k (biased; stepsize heuristic treats it like an
//!   ω = d/k − 1 unbiased sketch, a documented baseline convention).

use crate::compress::{UplinkCompressor, UplinkDecompressor};
use crate::methods::prox::Prox;
use crate::methods::{
    dense_downlink_into, sa_quant_family, stepsize, Downlink, MethodSpec, ServerAlgo, Uplink,
    WorkerAlgo,
};
use crate::objective::Smoothness;
use crate::runtime::GradEngine;
use crate::sampling::IndependentSampling;
use crate::util::rng::Rng;

pub struct DcgdWorker {
    compressor: UplinkCompressor,
    grad: Vec<f64>,
}

impl WorkerAlgo for DcgdWorker {
    fn round(&mut self, down: &Downlink, engine: &mut dyn GradEngine, rng: &mut Rng) -> Uplink {
        let mut up = Uplink::default();
        self.round_into(down, engine, rng, &mut up);
        up
    }

    fn round_into(
        &mut self,
        down: &Downlink,
        engine: &mut dyn GradEngine,
        rng: &mut Rng,
        up: &mut Uplink,
    ) {
        let x = match down {
            Downlink::Dense { x, .. } => x,
            _ => unreachable!("dcgd uses dense downlinks"),
        };
        engine.grad_into(x, &mut self.grad);
        self.compressor.compress(&self.grad, rng, &mut up.delta);
        up.delta2 = None;
    }

    fn dim(&self) -> usize {
        self.grad.len()
    }
}

pub struct DcgdServer {
    x: Vec<f64>,
    gamma: f64,
    prox: Prox,
    g: Vec<f64>,
    /// one per worker, in shard order (sa-quant unwhitens with that
    /// worker's W_i; Identity for the sketch/top-k families)
    decomp: Vec<UplinkDecompressor>,
}

impl ServerAlgo for DcgdServer {
    fn downlink(&mut self) -> Downlink {
        let mut down = Downlink::Init { x: Vec::new() };
        self.downlink_into(&mut down);
        down
    }

    fn downlink_into(&mut self, down: &mut Downlink) {
        dense_downlink_into(&self.x, None, down);
    }

    fn apply(&mut self, ups: &[Uplink], _rng: &mut Rng) {
        self.g.fill(0.0);
        for (u, dec) in ups.iter().zip(self.decomp.iter_mut()) {
            dec.accumulate(&u.delta, &mut self.g);
        }
        let step = self.gamma / ups.len() as f64;
        for j in 0..self.x.len() {
            self.x[j] -= step * self.g[j];
        }
        self.prox.apply(self.gamma, &mut self.x);
    }

    fn iterate(&self) -> &[f64] {
        &self.x
    }

    fn dim(&self) -> usize {
        self.x.len()
    }

    fn name(&self) -> &'static str {
        "dcgd"
    }

    fn save_state(&self, out: &mut Vec<u8>) {
        crate::methods::state::put_vec(out, &self.x);
    }

    fn load_state(&mut self, buf: &[u8]) -> bool {
        let mut pos = 0;
        crate::methods::state::get_vec(buf, &mut pos, &mut self.x) && pos == buf.len()
    }
}

pub fn build(
    spec: &MethodSpec,
    sm: &Smoothness,
) -> (Box<dyn ServerAlgo>, Vec<Box<dyn WorkerAlgo + Send>>) {
    use crate::compress::CompressorKind;

    let dim = sm.dim;
    let n = sm.n();
    let (compressors, decomp, gamma): (Vec<UplinkCompressor>, Vec<UplinkDecompressor>, f64) =
        match spec.compressor {
            CompressorKind::SaQuant => {
                let (quants, decomp, tilde_max) =
                    sa_quant_family(sm, spec.sa_levels, spec.sa_weighting);
                let gamma = stepsize::dcgd_plus_gamma(sm, tilde_max);
                (
                    quants.into_iter().map(UplinkCompressor::SaQuant).collect(),
                    decomp,
                    gamma,
                )
            }
            CompressorKind::TopK => {
                let k = (spec.tau.round() as usize).clamp(1, dim);
                // top-k is biased; the unified theory has no γ for it, so
                // take the ω an unbiased sketch of the same budget has
                let omega = dim as f64 / k as f64 - 1.0;
                (
                    (0..n).map(|_| UplinkCompressor::TopK(k)).collect(),
                    (0..n).map(|_| UplinkDecompressor::Identity).collect(),
                    stepsize::dcgd_gamma(sm, omega),
                )
            }
            _ => {
                // the original method always uses the uniform
                // (smoothness-unaware) sketch
                let sampling = IndependentSampling::uniform(dim, spec.tau);
                let omega = sampling.omega();
                let gamma = stepsize::dcgd_gamma(sm, omega);
                (
                    (0..n)
                        .map(|_| UplinkCompressor::Sketch(sampling.clone()))
                        .collect(),
                    (0..n).map(|_| UplinkDecompressor::Identity).collect(),
                    gamma,
                )
            }
        };
    let server = Box::new(DcgdServer {
        x: spec.x0.clone(),
        gamma,
        prox: Prox::None,
        g: vec![0.0; dim],
        decomp,
    });
    let workers = compressors
        .into_iter()
        .map(|c| {
            Box::new(DcgdWorker {
                compressor: c,
                grad: vec![0.0; dim],
            }) as Box<dyn WorkerAlgo + Send>
        })
        .collect();
    (server, workers)
}
