//! DIANA (Mishchenko et al., 2019) — the original variance-reduced method
//! with *standard* sparsification. Each worker maintains a shift h_i and
//! compresses the gradient *difference* `C_i(∇f_i(x^k) − h_i^k)`, which
//! drives the compression variance to zero and restores linear
//! convergence to x* (unlike DCGD).
//!
//! Theory parameters: γ = 1/(L + 6ωL_max/n), α = 1/(1+ω).

use crate::compress::sketch_compress;
use crate::methods::prox::Prox;
use crate::methods::{
    dense_downlink_into, stepsize, Downlink, MethodSpec, ServerAlgo, Uplink, WorkerAlgo,
};
use crate::objective::Smoothness;
use crate::runtime::GradEngine;
use crate::sampling::IndependentSampling;
use crate::util::rng::Rng;

pub struct DianaWorker {
    sampling: IndependentSampling,
    alpha: f64,
    h: Vec<f64>,
    diff: Vec<f64>,
    grad: Vec<f64>,
}

impl WorkerAlgo for DianaWorker {
    fn round(&mut self, down: &Downlink, engine: &mut dyn GradEngine, rng: &mut Rng) -> Uplink {
        let mut up = Uplink::default();
        self.round_into(down, engine, rng, &mut up);
        up
    }

    fn round_into(
        &mut self,
        down: &Downlink,
        engine: &mut dyn GradEngine,
        rng: &mut Rng,
        up: &mut Uplink,
    ) {
        let x = match down {
            Downlink::Dense { x, .. } => x,
            _ => unreachable!("diana uses dense downlinks"),
        };
        engine.grad_into(x, &mut self.grad);
        for j in 0..self.diff.len() {
            self.diff[j] = self.grad[j] - self.h[j];
        }
        sketch_compress(&self.diff, &self.sampling, rng, &mut up.delta);
        // h_i ← h_i + α·Ĉ(∇f_i − h_i)  (same compressed message)
        for (k, &i) in up.delta.idx.iter().enumerate() {
            self.h[i as usize] += self.alpha * up.delta.val[k];
        }
        up.delta2 = None;
    }

    fn dim(&self) -> usize {
        self.h.len()
    }

    fn save_state(&self, out: &mut Vec<u8>) {
        crate::methods::state::put_vec(out, &self.h);
    }

    fn load_state(&mut self, buf: &[u8]) -> bool {
        let mut pos = 0;
        crate::methods::state::get_vec(buf, &mut pos, &mut self.h) && pos == buf.len()
    }
}

pub struct DianaServer {
    x: Vec<f64>,
    h: Vec<f64>,
    gamma: f64,
    alpha: f64,
    prox: Prox,
    dbar: Vec<f64>,
}

impl ServerAlgo for DianaServer {
    fn downlink(&mut self) -> Downlink {
        let mut down = Downlink::Init { x: Vec::new() };
        self.downlink_into(&mut down);
        down
    }

    fn downlink_into(&mut self, down: &mut Downlink) {
        dense_downlink_into(&self.x, None, down);
    }

    fn apply(&mut self, ups: &[Uplink], _rng: &mut Rng) {
        self.dbar.fill(0.0);
        for u in ups {
            for (k, &i) in u.delta.idx.iter().enumerate() {
                self.dbar[i as usize] += u.delta.val[k];
            }
        }
        let inv_n = 1.0 / ups.len() as f64;
        for j in 0..self.x.len() {
            let db = self.dbar[j] * inv_n;
            let g = db + self.h[j];
            self.x[j] -= self.gamma * g;
            self.h[j] += self.alpha * db;
        }
        self.prox.apply(self.gamma, &mut self.x);
    }

    fn iterate(&self) -> &[f64] {
        &self.x
    }

    fn dim(&self) -> usize {
        self.x.len()
    }

    fn name(&self) -> &'static str {
        "diana"
    }

    fn save_state(&self, out: &mut Vec<u8>) {
        crate::methods::state::put_vec(out, &self.x);
        crate::methods::state::put_vec(out, &self.h);
    }

    fn load_state(&mut self, buf: &[u8]) -> bool {
        let mut pos = 0;
        crate::methods::state::get_vec(buf, &mut pos, &mut self.x)
            && crate::methods::state::get_vec(buf, &mut pos, &mut self.h)
            && pos == buf.len()
    }
}

pub fn build(
    spec: &MethodSpec,
    sm: &Smoothness,
) -> (Box<dyn ServerAlgo>, Vec<Box<dyn WorkerAlgo + Send>>) {
    let dim = sm.dim;
    let sampling = IndependentSampling::uniform(dim, spec.tau);
    let omega = sampling.omega();
    let gamma = stepsize::diana_gamma(sm, omega);
    let alpha = stepsize::diana_alpha(omega);
    let server = Box::new(DianaServer {
        x: spec.x0.clone(),
        h: vec![0.0; dim],
        gamma,
        alpha,
        prox: Prox::None,
        dbar: vec![0.0; dim],
    });
    let workers = (0..sm.n())
        .map(|_| {
            Box::new(DianaWorker {
                sampling: sampling.clone(),
                alpha,
                h: vec![0.0; dim],
                diff: vec![0.0; dim],
                grad: vec![0.0; dim],
            }) as Box<dyn WorkerAlgo + Send>
        })
        .collect();
    (server, workers)
}
