//! DIANA (Mishchenko et al., 2019) — the original variance-reduced method
//! with *standard* sparsification. Each worker maintains a shift h_i and
//! compresses the gradient *difference* `C_i(∇f_i(x^k) − h_i^k)`, which
//! drives the compression variance to zero and restores linear
//! convergence to x* (unlike DCGD).
//!
//! Theory parameters: γ = 1/(L + 6ωL_max/n), α = 1/(1+ω).
//!
//! With `MethodSpec::compressor = sa-quant` the sketch is replaced by
//! smoothness-aware quantization (arXiv:2106.03524): the message lives in
//! the whitened geometry, so both the server's aggregation *and* the
//! worker's own shift update route through the matching decompressor,
//! and the stepsize takes Theorem 3's 𝓛̃ form with 𝓛̃ = ω_q·λ_max(W_i²).

use crate::compress::{UplinkCompressor, UplinkDecompressor};
use crate::methods::prox::Prox;
use crate::methods::{
    dense_downlink_into, sa_quant_family, stepsize, Downlink, MethodSpec, ServerAlgo, Uplink,
    WorkerAlgo,
};
use crate::objective::Smoothness;
use crate::runtime::GradEngine;
use crate::sampling::IndependentSampling;
use crate::util::rng::Rng;

pub struct DianaWorker {
    compressor: UplinkCompressor,
    /// this worker's own unwhitener — the shift h_i lives in gradient
    /// space while the message is whitened (Identity under the sketch)
    decomp: UplinkDecompressor,
    alpha: f64,
    h: Vec<f64>,
    diff: Vec<f64>,
    grad: Vec<f64>,
}

impl WorkerAlgo for DianaWorker {
    fn round(&mut self, down: &Downlink, engine: &mut dyn GradEngine, rng: &mut Rng) -> Uplink {
        let mut up = Uplink::default();
        self.round_into(down, engine, rng, &mut up);
        up
    }

    fn round_into(
        &mut self,
        down: &Downlink,
        engine: &mut dyn GradEngine,
        rng: &mut Rng,
        up: &mut Uplink,
    ) {
        let x = match down {
            Downlink::Dense { x, .. } => x,
            _ => unreachable!("diana uses dense downlinks"),
        };
        engine.grad_into(x, &mut self.grad);
        for j in 0..self.diff.len() {
            self.diff[j] = self.grad[j] - self.h[j];
        }
        self.compressor.compress(&self.diff, rng, &mut up.delta);
        // h_i ← h_i + α·Ĉ(∇f_i − h_i)  (same compressed message)
        self.decomp
            .accumulate_scaled(&up.delta, self.alpha, &mut self.h);
        up.delta2 = None;
    }

    fn dim(&self) -> usize {
        self.h.len()
    }

    fn save_state(&self, out: &mut Vec<u8>) {
        crate::methods::state::put_vec(out, &self.h);
    }

    fn load_state(&mut self, buf: &[u8]) -> bool {
        let mut pos = 0;
        crate::methods::state::get_vec(buf, &mut pos, &mut self.h) && pos == buf.len()
    }
}

pub struct DianaServer {
    x: Vec<f64>,
    h: Vec<f64>,
    gamma: f64,
    alpha: f64,
    prox: Prox,
    dbar: Vec<f64>,
    /// one per worker, in shard order
    decomp: Vec<UplinkDecompressor>,
}

impl ServerAlgo for DianaServer {
    fn downlink(&mut self) -> Downlink {
        let mut down = Downlink::Init { x: Vec::new() };
        self.downlink_into(&mut down);
        down
    }

    fn downlink_into(&mut self, down: &mut Downlink) {
        dense_downlink_into(&self.x, None, down);
    }

    fn apply(&mut self, ups: &[Uplink], _rng: &mut Rng) {
        self.dbar.fill(0.0);
        for (u, dec) in ups.iter().zip(self.decomp.iter_mut()) {
            dec.accumulate(&u.delta, &mut self.dbar);
        }
        let inv_n = 1.0 / ups.len() as f64;
        for j in 0..self.x.len() {
            let db = self.dbar[j] * inv_n;
            let g = db + self.h[j];
            self.x[j] -= self.gamma * g;
            self.h[j] += self.alpha * db;
        }
        self.prox.apply(self.gamma, &mut self.x);
    }

    fn iterate(&self) -> &[f64] {
        &self.x
    }

    fn dim(&self) -> usize {
        self.x.len()
    }

    fn name(&self) -> &'static str {
        "diana"
    }

    fn save_state(&self, out: &mut Vec<u8>) {
        crate::methods::state::put_vec(out, &self.x);
        crate::methods::state::put_vec(out, &self.h);
    }

    fn load_state(&mut self, buf: &[u8]) -> bool {
        let mut pos = 0;
        crate::methods::state::get_vec(buf, &mut pos, &mut self.x)
            && crate::methods::state::get_vec(buf, &mut pos, &mut self.h)
            && pos == buf.len()
    }
}

pub fn build(
    spec: &MethodSpec,
    sm: &Smoothness,
) -> (Box<dyn ServerAlgo>, Vec<Box<dyn WorkerAlgo + Send>>) {
    use crate::compress::{CompressorKind, SaQuant};

    let dim = sm.dim;
    let n = sm.n();
    let (compressors, worker_decomp, server_decomp, gamma, alpha): (
        Vec<UplinkCompressor>,
        Vec<UplinkDecompressor>,
        Vec<UplinkDecompressor>,
        f64,
        f64,
    ) = match spec.compressor {
        CompressorKind::SaQuant => {
            let (quants, server_decomp, tilde_max) =
                sa_quant_family(sm, spec.sa_levels, spec.sa_weighting);
            let omega_q = SaQuant::omega(dim, spec.sa_levels);
            let worker_decomp = quants.iter().map(|q| q.decompressor()).collect();
            (
                quants.into_iter().map(UplinkCompressor::SaQuant).collect(),
                worker_decomp,
                server_decomp,
                stepsize::diana_plus_gamma(sm, tilde_max),
                stepsize::diana_alpha(omega_q),
            )
        }
        _ => {
            let sampling = IndependentSampling::uniform(dim, spec.tau);
            let omega = sampling.omega();
            (
                (0..n)
                    .map(|_| UplinkCompressor::Sketch(sampling.clone()))
                    .collect(),
                (0..n).map(|_| UplinkDecompressor::Identity).collect(),
                (0..n).map(|_| UplinkDecompressor::Identity).collect(),
                stepsize::diana_gamma(sm, omega),
                stepsize::diana_alpha(omega),
            )
        }
    };
    let server = Box::new(DianaServer {
        x: spec.x0.clone(),
        h: vec![0.0; dim],
        gamma,
        alpha,
        prox: Prox::None,
        dbar: vec![0.0; dim],
        decomp: server_decomp,
    });
    let workers = compressors
        .into_iter()
        .zip(worker_decomp)
        .map(|(c, d)| {
            Box::new(DianaWorker {
                compressor: c,
                decomp: d,
                alpha,
                h: vec![0.0; dim],
                diff: vec![0.0; dim],
                grad: vec![0.0; dim],
            }) as Box<dyn WorkerAlgo + Send>
        })
        .collect();
    (server, workers)
}
