//! DCGD+ (Algorithm 1) — DCGD with the matrix-smoothness-aware
//! sparsification protocol (Definition 3 / eq. 7):
//!
//! * worker i sends `Δ_i = C_i L_i^{†1/2} ∇f_i(x^k)` (sparse);
//! * the server decompresses `L_i^{1/2} Δ_i`, averages, prox-steps.
//!
//! Theory step size γ = 1/(L + 2𝓛̃_max/n) (Theorem 2).

use crate::compress::MatrixAware;
use crate::linalg::psd::PsdRoot;
use crate::methods::prox::Prox;
use crate::methods::{
    dense_downlink_into, stepsize, Downlink, MethodSpec, ServerAlgo, Uplink, WorkerAlgo,
};
use crate::objective::Smoothness;
use crate::runtime::GradEngine;
use crate::util::rng::Rng;
use std::sync::Arc;

pub struct DcgdPlusWorker {
    compressor: MatrixAware,
    root: Arc<PsdRoot>,
    grad: Vec<f64>,
}

impl WorkerAlgo for DcgdPlusWorker {
    fn round(&mut self, down: &Downlink, engine: &mut dyn GradEngine, rng: &mut Rng) -> Uplink {
        let mut up = Uplink::default();
        self.round_into(down, engine, rng, &mut up);
        up
    }

    fn round_into(
        &mut self,
        down: &Downlink,
        engine: &mut dyn GradEngine,
        rng: &mut Rng,
        up: &mut Uplink,
    ) {
        let x = match down {
            Downlink::Dense { x, .. } => x,
            _ => unreachable!("dcgd+ uses dense downlinks"),
        };
        engine.grad_into(x, &mut self.grad);
        self.compressor
            .compress(&self.root, &self.grad, rng, &mut up.delta);
        up.delta2 = None;
    }

    fn dim(&self) -> usize {
        self.grad.len()
    }
}

pub struct DcgdPlusServer {
    x: Vec<f64>,
    gamma: f64,
    prox: Prox,
    roots: Vec<Arc<PsdRoot>>,
    g: Vec<f64>,
    scratch: Vec<f64>,
    coeff: Vec<f64>,
}

impl ServerAlgo for DcgdPlusServer {
    fn downlink(&mut self) -> Downlink {
        let mut down = Downlink::Init { x: Vec::new() };
        self.downlink_into(&mut down);
        down
    }

    fn downlink_into(&mut self, down: &mut Downlink) {
        dense_downlink_into(&self.x, None, down);
    }

    fn apply(&mut self, ups: &[Uplink], _rng: &mut Rng) {
        self.g.fill(0.0);
        for (i, u) in ups.iter().enumerate() {
            // decompress: L_i^{1/2} Δ_i
            self.roots[i].apply_pow_sparse_into_with(
                0.5,
                &u.delta.idx,
                &u.delta.val,
                &mut self.scratch,
                &mut self.coeff,
            );
            for j in 0..self.g.len() {
                self.g[j] += self.scratch[j];
            }
        }
        let step = self.gamma / ups.len() as f64;
        for j in 0..self.x.len() {
            self.x[j] -= step * self.g[j];
        }
        self.prox.apply(self.gamma, &mut self.x);
    }

    fn iterate(&self) -> &[f64] {
        &self.x
    }

    fn dim(&self) -> usize {
        self.x.len()
    }

    fn name(&self) -> &'static str {
        "dcgd+"
    }

    fn save_state(&self, out: &mut Vec<u8>) {
        crate::methods::state::put_vec(out, &self.x);
    }

    fn load_state(&mut self, buf: &[u8]) -> bool {
        let mut pos = 0;
        crate::methods::state::get_vec(buf, &mut pos, &mut self.x) && pos == buf.len()
    }
}

pub fn build(
    spec: &MethodSpec,
    sm: &Smoothness,
) -> (Box<dyn ServerAlgo>, Vec<Box<dyn WorkerAlgo + Send>>) {
    let dim = sm.dim;
    let roots: Vec<Arc<PsdRoot>> = sm.locals.iter().map(|l| Arc::new(l.root.clone())).collect();

    let mut tilde_l_max: f64 = 0.0;
    let workers: Vec<Box<dyn WorkerAlgo + Send>> = sm
        .locals
        .iter()
        .zip(&roots)
        .map(|(loc, root)| {
            let sampling = spec.sampling.build(&loc.diag, spec.tau, spec.mu, sm.n());
            tilde_l_max = tilde_l_max.max(sampling.tilde_l(&loc.diag));
            Box::new(DcgdPlusWorker {
                compressor: MatrixAware::new(sampling),
                root: root.clone(),
                grad: vec![0.0; dim],
            }) as Box<dyn WorkerAlgo + Send>
        })
        .collect();

    let gamma = stepsize::dcgd_plus_gamma(sm, tilde_l_max);
    let server = Box::new(DcgdPlusServer {
        x: spec.x0.clone(),
        gamma,
        prox: Prox::None,
        roots,
        g: vec![0.0; dim],
        scratch: vec![0.0; dim],
        coeff: Vec::new(),
    });
    (server, workers)
}
