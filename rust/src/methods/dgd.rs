//! DGD — uncompressed Distributed Gradient Descent baseline (Remark 7).
//! Workers send dense gradients; the server averages and takes a proximal
//! step with γ = 2/(L+μ).

use crate::linalg::vector;
use crate::methods::prox::Prox;
use crate::methods::{
    dense_downlink_into, stepsize, Downlink, MethodSpec, ServerAlgo, Uplink, WorkerAlgo,
};
use crate::objective::Smoothness;
use crate::runtime::GradEngine;
use crate::util::rng::Rng;

pub struct DgdWorker {
    dim: usize,
    grad: Vec<f64>,
}

impl WorkerAlgo for DgdWorker {
    fn round(&mut self, down: &Downlink, engine: &mut dyn GradEngine, rng: &mut Rng) -> Uplink {
        let mut up = Uplink::default();
        self.round_into(down, engine, rng, &mut up);
        up
    }

    fn round_into(
        &mut self,
        down: &Downlink,
        engine: &mut dyn GradEngine,
        _rng: &mut Rng,
        up: &mut Uplink,
    ) {
        let x = match down {
            Downlink::Dense { x, .. } => x,
            _ => unreachable!("dgd uses dense downlinks"),
        };
        engine.grad_into(x, &mut self.grad);
        up.delta.clear();
        for (j, &v) in self.grad.iter().enumerate() {
            up.delta.push(j as u32, v);
        }
        up.delta2 = None;
    }

    fn dim(&self) -> usize {
        self.dim
    }
}

pub struct DgdServer {
    x: Vec<f64>,
    gamma: f64,
    prox: Prox,
    g: Vec<f64>,
}

impl ServerAlgo for DgdServer {
    fn downlink(&mut self) -> Downlink {
        let mut down = Downlink::Init { x: Vec::new() };
        self.downlink_into(&mut down);
        down
    }

    fn downlink_into(&mut self, down: &mut Downlink) {
        dense_downlink_into(&self.x, None, down);
    }

    fn apply(&mut self, ups: &[Uplink], _rng: &mut Rng) {
        self.g.fill(0.0);
        for u in ups {
            for (k, &i) in u.delta.idx.iter().enumerate() {
                self.g[i as usize] += u.delta.val[k];
            }
        }
        let step = -self.gamma / ups.len() as f64;
        vector::axpy(step, &self.g, &mut self.x);
        self.prox.apply(self.gamma, &mut self.x);
    }

    fn iterate(&self) -> &[f64] {
        &self.x
    }

    fn dim(&self) -> usize {
        self.x.len()
    }

    fn name(&self) -> &'static str {
        "dgd"
    }

    fn save_state(&self, out: &mut Vec<u8>) {
        crate::methods::state::put_vec(out, &self.x);
    }

    fn load_state(&mut self, buf: &[u8]) -> bool {
        let mut pos = 0;
        crate::methods::state::get_vec(buf, &mut pos, &mut self.x) && pos == buf.len()
    }
}

pub fn build(
    spec: &MethodSpec,
    sm: &Smoothness,
) -> (Box<dyn ServerAlgo>, Vec<Box<dyn WorkerAlgo + Send>>) {
    let dim = sm.dim;
    let gamma = stepsize::dgd_gamma(sm);
    let server = Box::new(DgdServer {
        x: spec.x0.clone(),
        gamma,
        prox: Prox::None,
        g: vec![0.0; dim],
    });
    let workers = (0..sm.n())
        .map(|_| {
            Box::new(DgdWorker {
                dim,
                grad: vec![0.0; dim],
            }) as Box<dyn WorkerAlgo + Send>
        })
        .collect();
    (server, workers)
}
