//! DGD — uncompressed Distributed Gradient Descent baseline (Remark 7).
//! Workers send dense gradients; the server averages and takes a proximal
//! step with γ = 2/(L+μ).

use crate::compress::SparseMsg;
use crate::linalg::vector;
use crate::methods::prox::Prox;
use crate::methods::{stepsize, Downlink, MethodSpec, ServerAlgo, Uplink, WorkerAlgo};
use crate::objective::Smoothness;
use crate::runtime::GradEngine;
use crate::util::rng::Rng;

pub struct DgdWorker {
    dim: usize,
    grad: Vec<f64>,
}

impl WorkerAlgo for DgdWorker {
    fn round(&mut self, down: &Downlink, engine: &mut dyn GradEngine, _rng: &mut Rng) -> Uplink {
        let x = match down {
            Downlink::Dense { x, .. } => x,
            _ => unreachable!("dgd uses dense downlinks"),
        };
        engine.grad_into(x, &mut self.grad);
        let mut delta = SparseMsg::with_capacity(self.dim);
        for (j, &v) in self.grad.iter().enumerate() {
            delta.push(j as u32, v);
        }
        Uplink {
            delta,
            delta2: None,
        }
    }

    fn dim(&self) -> usize {
        self.dim
    }
}

pub struct DgdServer {
    x: Vec<f64>,
    gamma: f64,
    prox: Prox,
    g: Vec<f64>,
}

impl ServerAlgo for DgdServer {
    fn downlink(&mut self) -> Downlink {
        Downlink::Dense {
            x: self.x.clone(),
            w: None,
        }
    }

    fn apply(&mut self, ups: &[Uplink], _rng: &mut Rng) {
        self.g.fill(0.0);
        for u in ups {
            for (k, &i) in u.delta.idx.iter().enumerate() {
                self.g[i as usize] += u.delta.val[k];
            }
        }
        let inv_n = 1.0 / ups.len() as f64;
        vector::axpy(-self.gamma * inv_n, &self.g.clone(), &mut self.x);
        self.prox.apply(self.gamma, &mut self.x);
    }

    fn iterate(&self) -> &[f64] {
        &self.x
    }

    fn dim(&self) -> usize {
        self.x.len()
    }

    fn name(&self) -> &'static str {
        "dgd"
    }
}

pub fn build(
    spec: &MethodSpec,
    sm: &Smoothness,
) -> (Box<dyn ServerAlgo>, Vec<Box<dyn WorkerAlgo + Send>>) {
    let dim = sm.dim;
    let gamma = stepsize::dgd_gamma(sm);
    let server = Box::new(DgdServer {
        x: spec.x0.clone(),
        gamma,
        prox: Prox::None,
        g: vec![0.0; dim],
    });
    let workers = (0..sm.n())
        .map(|_| {
            Box::new(DgdWorker {
                dim,
                grad: vec![0.0; dim],
            }) as Box<dyn WorkerAlgo + Send>
        })
        .collect();
    (server, workers)
}
