//! Proximal operators for the regularizer R in problem (1).
//!
//! The paper's experiments use R ≡ 0 (the ℓ2 term is folded into the
//! smooth part), but all "+" methods are proximal (Table 1), so we
//! implement the standard proximable choices.

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Prox {
    /// R ≡ 0
    None,
    /// R(x) = λ‖x‖₁ → soft thresholding
    L1 { lambda: f64 },
    /// R(x) = (λ/2)‖x‖² → shrinkage
    L2 { lambda: f64 },
}

impl Prox {
    /// x ← prox_{γR}(x) in place.
    pub fn apply(&self, gamma: f64, x: &mut [f64]) {
        match *self {
            Prox::None => {}
            Prox::L1 { lambda } => {
                let t = gamma * lambda;
                for v in x.iter_mut() {
                    *v = if *v > t {
                        *v - t
                    } else if *v < -t {
                        *v + t
                    } else {
                        0.0
                    };
                }
            }
            Prox::L2 { lambda } => {
                let c = 1.0 / (1.0 + gamma * lambda);
                for v in x.iter_mut() {
                    *v *= c;
                }
            }
        }
    }

    pub fn parse(s: &str) -> Option<Prox> {
        if s == "none" {
            return Some(Prox::None);
        }
        if let Some(rest) = s.strip_prefix("l1:") {
            return rest.parse().ok().map(|lambda| Prox::L1 { lambda });
        }
        if let Some(rest) = s.strip_prefix("l2:") {
            return rest.parse().ok().map(|lambda| Prox::L2 { lambda });
        }
        None
    }

    /// R(x) for metrics.
    pub fn value(&self, x: &[f64]) -> f64 {
        match *self {
            Prox::None => 0.0,
            Prox::L1 { lambda } => lambda * x.iter().map(|v| v.abs()).sum::<f64>(),
            Prox::L2 { lambda } => 0.5 * lambda * crate::linalg::vector::norm2(x),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_identity() {
        let mut x = [1.0, -2.0];
        Prox::None.apply(0.5, &mut x);
        assert_eq!(x, [1.0, -2.0]);
    }

    #[test]
    fn l1_soft_threshold() {
        let mut x = [3.0, -3.0, 0.5, -0.5];
        Prox::L1 { lambda: 2.0 }.apply(0.5, &mut x); // t = 1
        assert_eq!(x, [2.0, -2.0, 0.0, 0.0]);
    }

    #[test]
    fn l2_shrinkage() {
        let mut x = [2.0, -4.0];
        Prox::L2 { lambda: 1.0 }.apply(1.0, &mut x);
        assert_eq!(x, [1.0, -2.0]);
    }

    #[test]
    fn prox_minimizes_objective() {
        // prox_{γR}(v) = argmin_u R(u) + (1/2γ)‖u−v‖²: check optimality for L1
        // by comparing against small perturbations.
        let v = [1.5, -0.3, 0.0, 4.0];
        let gamma = 0.7;
        let p = Prox::L1 { lambda: 1.0 };
        let mut u = v;
        p.apply(gamma, &mut u);
        let obj = |u: &[f64]| {
            p.value(u)
                + u.iter()
                    .zip(&v)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum::<f64>()
                    / (2.0 * gamma)
        };
        let base = obj(&u);
        for j in 0..4 {
            for eps in [-1e-4, 1e-4] {
                let mut u2 = u;
                u2[j] += eps;
                assert!(obj(&u2) >= base - 1e-12);
            }
        }
    }

    #[test]
    fn parsing() {
        assert_eq!(Prox::parse("none"), Some(Prox::None));
        assert_eq!(Prox::parse("l1:0.5"), Some(Prox::L1 { lambda: 0.5 }));
        assert_eq!(Prox::parse("l2:2"), Some(Prox::L2 { lambda: 2.0 }));
        assert_eq!(Prox::parse("huh"), None);
    }

    #[test]
    fn values() {
        assert_eq!(Prox::L1 { lambda: 2.0 }.value(&[1.0, -3.0]), 8.0);
        assert_eq!(Prox::L2 { lambda: 2.0 }.value(&[3.0, 4.0]), 25.0);
        assert_eq!(Prox::None.value(&[9.9]), 0.0);
    }
}
