//! ADIANA (Li et al., 2020) — the original accelerated baseline: DIANA
//! shift-learning + Nesterov acceleration with *standard* (smoothness-
//! unaware) sparsification. Shares the accelerated machinery with
//! [`crate::methods::adiana_plus`]; the only differences are identity
//! decompression and the ωL_max variance scale in the parameters.

use crate::methods::{adiana_plus, MethodSpec, ServerAlgo, WorkerAlgo};
use crate::objective::Smoothness;

pub fn build(
    spec: &MethodSpec,
    sm: &Smoothness,
) -> (Box<dyn ServerAlgo>, Vec<Box<dyn WorkerAlgo + Send>>) {
    adiana_plus::build_accel(spec, sm, false, "adiana")
}
