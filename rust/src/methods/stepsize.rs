//! Theory-dictated step sizes and parameters (paper §6.1 runs everything
//! "with stepsizes as dictated by theory").
//!
//! * DCGD:    γ = 1/(L + 2ωL_max/n)           (unified theory, Khirirat et al.)
//! * DCGD+:   γ = 1/(L + 2𝓛̃_max/n)            (Theorem 2)
//! * DIANA:   γ = 1/(L + 6ωL_max/n), α = 1/(1+ω)
//! * DIANA+:  γ = 1/(L + 6𝓛̃_max/n), α = 1/(1+ω_max)   (Theorem 3)
//! * ADIANA(+): the Theorem-4 parameter system, with the variance scale
//!   V = ωL_max (original) or V = 𝓛̃_max (+); the `practical` flag drops
//!   the large constants exactly as the paper's experiments do ("we have
//!   omitted several constant factors for the sake of practicality").
//! * ISEGA+:  γ = 1/(4𝓛̃_max/n + 2L + μ(ω_max+1))      (Theorem 22)
//! * DIANA++: the Theorem-23 parameter system.

use crate::objective::Smoothness;

/// DGD on a μ-strongly-convex L-smooth f: γ = 2/(L + μ).
pub fn dgd_gamma(sm: &Smoothness) -> f64 {
    2.0 / (sm.l + sm.mu)
}

pub fn dcgd_gamma(sm: &Smoothness, omega: f64) -> f64 {
    1.0 / (sm.l + 2.0 * omega * sm.l_max / sm.n() as f64)
}

/// Theorem 2.
pub fn dcgd_plus_gamma(sm: &Smoothness, tilde_l_max: f64) -> f64 {
    1.0 / (sm.l + 2.0 * tilde_l_max / sm.n() as f64)
}

pub fn diana_gamma(sm: &Smoothness, omega: f64) -> f64 {
    1.0 / (sm.l + 6.0 * omega * sm.l_max / sm.n() as f64)
}

/// Theorem 3.
pub fn diana_plus_gamma(sm: &Smoothness, tilde_l_max: f64) -> f64 {
    1.0 / (sm.l + 6.0 * tilde_l_max / sm.n() as f64)
}

pub fn diana_alpha(omega_max: f64) -> f64 {
    1.0 / (1.0 + omega_max)
}

/// Theorem 22 (ISEGA+).
pub fn isega_plus_gamma(sm: &Smoothness, tilde_l_max: f64, omega_max: f64) -> f64 {
    1.0 / (4.0 * tilde_l_max / sm.n() as f64 + 2.0 * sm.l + sm.mu * (omega_max + 1.0))
}

/// The ADIANA parameter system (proof of Theorem 4).
#[derive(Clone, Copy, Debug)]
pub struct AdianaParams {
    pub eta: f64,
    pub gamma: f64,
    pub alpha: f64,
    pub beta: f64,
    pub theta1: f64,
    pub theta2: f64,
    pub q: f64,
}

/// `variance_scale` V = 𝓛̃_max for ADIANA+ (Theorem 4) or ωL_max for the
/// original ADIANA baseline. `practical` drops the 64(2q(ω+1)+1)² constant
/// to 8(1+ω) — the paper's own experimental relaxation.
pub fn adiana_params(
    sm: &Smoothness,
    omega_max: f64,
    variance_scale: f64,
    practical: bool,
) -> AdianaParams {
    let n = sm.n() as f64;
    let (l, mu) = (sm.l, sm.mu);
    let v = variance_scale.max(f64::MIN_POSITIVE);

    // q from the proof of Theorem 4
    let q = (1.0f64)
        .min(((n * l / (32.0 * v)).sqrt() - 1.0).max(1.0) / (2.0 * (1.0 + omega_max)));

    // η from the proof (64·V·(2q(ω+1)+1)²); the practical mode keeps the
    // structure but drops the constant 64 → 8, mirroring the paper's
    // "omitted several constant factors for the sake of practicality"
    let c = 2.0 * q * (omega_max + 1.0) + 1.0;
    let denom_const = if practical { 8.0 } else { 64.0 };
    let eta = (1.0 / (2.0 * l)).min(n / (denom_const * v * c * c));

    let theta2 = 0.5;
    let theta1 = (0.25f64).min((eta * mu / q).sqrt());
    let gamma = eta / (2.0 * (theta1 + eta * mu));
    let beta = 1.0 - gamma * mu;
    let alpha = 1.0 / (1.0 + omega_max);

    AdianaParams {
        eta,
        gamma,
        alpha,
        beta,
        theta1,
        theta2,
        q,
    }
}

/// The DIANA++ parameter system (Theorem 23).
#[derive(Clone, Copy, Debug)]
pub struct DianaPpParams {
    pub gamma: f64,
    /// worker shift step
    pub alpha: f64,
    /// server shift step
    pub beta: f64,
}

/// `tilde_l_server` = 𝓛̃ = λ_max(P̃∘L) for the server sketch;
/// `tilde_l_prime_max` = 𝓛̃'_max = max_i λ_max(P̃_i∘(L_i^{1/2}L†L_i^{1/2}));
/// `omega_server` = server sketch variance; `tilde_l_max`, `omega_max` as
/// usual.
pub fn diana_pp_params(
    sm: &Smoothness,
    tilde_l_max: f64,
    omega_max: f64,
    tilde_l_server: f64,
    tilde_l_prime_max: f64,
    omega_server: f64,
) -> DianaPpParams {
    let n = sm.n() as f64;
    let (l, mu) = (sm.l, sm.mu);
    let _ = mu;
    let alpha = 1.0 / (1.0 + omega_max);
    let mut beta = 1.0 / (1.0 + omega_server);

    let b = (4.0 * tilde_l_server * tilde_l_prime_max + 2.0 * tilde_l_max) / n;
    let a = l + 2.0 * tilde_l_server + b;
    // θ, θ' (guarding the no-server-compression limit 𝓛̃ → 0)
    let denom = tilde_l_max + 2.0 * tilde_l_server * tilde_l_prime_max;
    let theta = if denom > 0.0 {
        n * tilde_l_server / denom
    } else {
        0.0
    };
    let theta_p = 2.0 * theta * tilde_l_prime_max / n;
    // ensure ρ = min(α − βθ', β) > 0
    if theta_p > 0.0 && beta * theta_p >= alpha {
        beta = 0.5 * alpha / theta_p;
    }
    let rho = (alpha - beta * theta_p).min(beta).max(f64::MIN_POSITIVE);
    let c = alpha + beta * theta + beta * theta_p;
    let m = 2.0 * b / rho;
    let gamma = 1.0 / (a + c * m);

    DianaPpParams { gamma, alpha, beta }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::objective::Smoothness;

    fn sm() -> Smoothness {
        let ds = synth::generate(&synth::tiny_spec(), 1);
        let (_, shards) = ds.prepare(4, 1);
        Smoothness::build(&shards, 1e-3)
    }

    #[test]
    fn plus_stepsize_dominates_baseline() {
        // 𝓛̃_max ≤ ω·max_j L_jj ≤ ω·L_max ⇒ DCGD+ allows γ at least as large.
        let s = sm();
        let d = s.dim as f64;
        let tau = 1.0;
        let omega = d / tau - 1.0;
        // uniform sampling tilde value
        let tilde: f64 = s
            .locals
            .iter()
            .map(|l| omega * l.diag.iter().cloned().fold(0.0, f64::max))
            .fold(0.0, f64::max);
        assert!(dcgd_plus_gamma(&s, tilde) >= dcgd_gamma(&s, omega) * 0.999);
        assert!(diana_plus_gamma(&s, tilde) >= diana_gamma(&s, omega) * 0.999);
    }

    #[test]
    fn gamma_mu_below_one() {
        let s = sm();
        for g in [
            dgd_gamma(&s),
            dcgd_gamma(&s, 19.0),
            dcgd_plus_gamma(&s, 1.0),
            diana_gamma(&s, 19.0),
            diana_plus_gamma(&s, 1.0),
            isega_plus_gamma(&s, 1.0, 19.0),
        ] {
            assert!(g > 0.0 && g * s.mu < 1.0, "gamma={g}");
        }
    }

    #[test]
    fn adiana_params_sane() {
        let s = sm();
        for practical in [false, true] {
            let p = adiana_params(&s, 19.0, 0.5, practical);
            assert!(p.eta > 0.0 && p.eta <= 1.0 / (2.0 * s.l) + 1e-15);
            assert!(p.q > 0.0 && p.q <= 1.0);
            assert!(p.alpha > 0.0 && p.alpha <= 1.0);
            assert!(p.theta1 > 0.0 && p.theta1 <= 0.25);
            assert!((p.theta2 - 0.5).abs() < 1e-15);
            assert!(p.beta < 1.0 && p.beta > 0.0);
            assert!(p.gamma > 0.0);
            // 1 − θ1 − θ2 ≥ 0 so the x-combination is convex
            assert!(1.0 - p.theta1 - p.theta2 >= -1e-12);
        }
    }

    #[test]
    fn adiana_practical_at_least_as_large_eta() {
        let s = sm();
        let strict = adiana_params(&s, 19.0, 0.5, false);
        let practical = adiana_params(&s, 19.0, 0.5, true);
        assert!(practical.eta >= strict.eta * 0.999);
    }

    #[test]
    fn diana_pp_reduces_to_diana_plus_without_server_compression() {
        let s = sm();
        let tilde_max = 0.3;
        let p = diana_pp_params(&s, tilde_max, 19.0, 0.0, 0.0, 0.0);
        // γ = 1/(L + 6𝓛̃_max/n) exactly in this limit (A + CM telescopes)
        let expected = diana_plus_gamma(&s, tilde_max);
        assert!(
            (p.gamma - expected).abs() < 1e-12 * expected,
            "{} vs {expected}",
            p.gamma
        );
        assert!((p.beta - 1.0).abs() < 1e-12);
    }

    #[test]
    fn diana_pp_params_positive_with_compression() {
        let s = sm();
        let p = diana_pp_params(&s, 0.3, 19.0, 0.1, 2.0, 9.0);
        assert!(p.gamma > 0.0);
        assert!(p.alpha > 0.0 && p.alpha <= 1.0);
        assert!(p.beta > 0.0 && p.beta <= 1.0);
    }
}
