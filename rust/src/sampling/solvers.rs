//! Water-filling probability solvers for importance sampling
//! (paper eqs. (16), (19), (21) and Appendix E).
//!
//! Each rule has the form `p_j = g(L_j, ρ)` with `Σ_j p_j(ρ) = τ` and
//! `p_j` strictly decreasing in ρ, so ρ is found by bisection on a
//! bracketing interval derived from the paper's own bounds
//! (eq. 53: ρ ≤ Σ_j L_j / τ; eq. 64 for the ADIANA+ variant).

/// Generic bisection for a strictly decreasing `f` with `f(0) ≥ 0` and a
/// bracketing `hi` with `f(hi) ≤ 0`. Returns ρ with |f(ρ)| ≤ tol.
fn bisect(mut f: impl FnMut(f64) -> f64, mut hi: f64, tol: f64) -> f64 {
    let mut lo = 0.0_f64;
    let f0 = f(0.0);
    if f0 <= 0.0 {
        // already at or below target with ρ = 0 ⇒ all p at their max
        return 0.0;
    }
    // ensure bracketing (hi may be slightly under due to rounding)
    let mut fh = f(hi);
    let mut guard = 0;
    while fh > 0.0 {
        hi *= 2.0;
        fh = f(hi);
        guard += 1;
        assert!(guard < 200, "failed to bracket water-filling root");
    }
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        let fm = f(mid);
        if fm.abs() <= tol {
            return mid;
        }
        if fm > 0.0 {
            lo = mid;
        } else {
            hi = mid;
        }
        if (hi - lo) <= 1e-15 * hi.max(1.0) {
            break;
        }
    }
    0.5 * (lo + hi)
}

/// eq. (16): `p_j = L_j/(L_j + ρ)` with `Σ p_j = τ`.
/// `diag` are the diagonal entries `L_{i;jj}` (all > 0 thanks to the μ
/// ridge). If τ ≥ d, all probabilities are 1.
pub fn probs_dcgd_plus(diag: &[f64], tau: f64) -> Vec<f64> {
    water_fill(diag, tau, |l, rho| l / (l + rho))
}

/// eq. (19): `p_j = L'_j/(L'_j + ρ)` with `L'_j = L_j/(μn) + 1`.
pub fn probs_diana_plus(diag: &[f64], tau: f64, mu: f64, n: usize) -> Vec<f64> {
    let lp: Vec<f64> = diag.iter().map(|&l| l / (mu * n as f64) + 1.0).collect();
    water_fill(&lp, tau, |l, rho| l / (l + rho))
}

/// eq. (21): `p_j = √(L'_j/(L'_j + ρ))` with `L'_j = L_j/(μn) + 1`.
pub fn probs_adiana_plus(diag: &[f64], tau: f64, mu: f64, n: usize) -> Vec<f64> {
    let lp: Vec<f64> = diag.iter().map(|&l| l / (mu * n as f64) + 1.0).collect();
    water_fill(&lp, tau, |l, rho| (l / (l + rho)).sqrt())
}

/// Shared water-filling: find ρ ≥ 0 with Σ_j shape(L_j, ρ) = τ, return the
/// per-coordinate probabilities. `shape(·, 0) = 1` and `shape` is strictly
/// decreasing in ρ for L > 0.
fn water_fill(vals: &[f64], tau: f64, shape: impl Fn(f64, f64) -> f64 + Copy) -> Vec<f64> {
    let d = vals.len();
    assert!(d > 0);
    assert!(tau > 0.0, "expected batch size must be positive");
    assert!(
        vals.iter().all(|&l| l > 0.0),
        "water-filling requires strictly positive diagonal (μ ridge guarantees this)"
    );
    if tau >= d as f64 {
        return vec![1.0; d];
    }
    // Bracket: for the rational shapes used here, Σ shape(L_j, ρ) ≤ Σ L_j/ρ
    // (eq. 53) and ≤ Σ √(L_j/ρ) (eq. 64) respectively, so
    // hi = max(Σ L_j/τ, (Σ √L_j / τ)²) brackets both; bisect() doubles if not.
    let sum: f64 = vals.iter().sum();
    let sum_sqrt: f64 = vals.iter().map(|l| l.sqrt()).sum();
    let hi = (sum / tau).max((sum_sqrt / tau) * (sum_sqrt / tau)) + 1.0;
    let rho = bisect(
        |rho| vals.iter().map(|&l| shape(l, rho)).sum::<f64>() - tau,
        hi,
        1e-12 * tau,
    );
    vals.iter()
        .map(|&l| shape(l, rho).clamp(f64::MIN_POSITIVE, 1.0))
        .collect()
}

/// ρ for eq. (16) — exposed for tests/diagnostics (`𝓛̃_i = ρ_i` at the
/// optimum, eq. 54).
pub fn rho_dcgd_plus(diag: &[f64], tau: f64) -> f64 {
    let p = probs_dcgd_plus(diag, tau);
    // (1/p_j − 1) L_j is constant = ρ across non-saturated coordinates
    p.iter()
        .zip(diag)
        .filter(|(p, _)| **p < 1.0)
        .map(|(&pj, &lj)| (1.0 / pj - 1.0) * lj)
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_diag(d: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..d)
            .map(|_| 1e-3 + rng.uniform() * rng.uniform() * 2.0)
            .collect()
    }

    #[test]
    fn dcgd_probs_sum_to_tau() {
        for seed in [1, 2, 3] {
            let diag = rand_diag(40, seed);
            for tau in [1.0, 4.0, 20.0] {
                let p = probs_dcgd_plus(&diag, tau);
                let sum: f64 = p.iter().sum();
                assert!((sum - tau).abs() < 1e-8, "sum={sum} tau={tau}");
                assert!(p.iter().all(|&x| x > 0.0 && x <= 1.0));
            }
        }
    }

    #[test]
    fn dcgd_equalizes_tilde_terms() {
        // at the optimum (1/p_j − 1) L_j = ρ for all j (eq. 16)
        let diag = rand_diag(25, 4);
        let p = probs_dcgd_plus(&diag, 5.0);
        let terms: Vec<f64> = p
            .iter()
            .zip(&diag)
            .map(|(&pj, &lj)| (1.0 / pj - 1.0) * lj)
            .collect();
        let max = terms.iter().cloned().fold(0.0, f64::max);
        let min = terms.iter().cloned().fold(f64::MAX, f64::min);
        assert!((max - min) < 1e-8 * max.max(1e-30), "max={max} min={min}");
    }

    #[test]
    fn dcgd_importance_beats_uniform_tilde_l() {
        // Proposition 5: the optimal probabilities minimize 𝓛̃.
        use crate::objective::smoothness::tilde_l_independent;
        let diag = rand_diag(30, 5);
        let tau = 3.0;
        let p_imp = probs_dcgd_plus(&diag, tau);
        let p_uni = vec![tau / 30.0; 30];
        let t_imp = tilde_l_independent(&p_imp, &diag);
        let t_uni = tilde_l_independent(&p_uni, &diag);
        assert!(t_imp <= t_uni + 1e-12, "imp={t_imp} uni={t_uni}");
    }

    #[test]
    fn dcgd_rho_bound_eq53() {
        let diag = rand_diag(20, 6);
        let tau = 4.0;
        let rho = rho_dcgd_plus(&diag, tau);
        let bound: f64 = diag.iter().sum::<f64>() / tau;
        assert!(rho <= bound + 1e-9, "rho={rho} bound={bound}");
    }

    #[test]
    fn diana_probs_sum_to_tau_and_exceed_dcgd_floor() {
        let diag = rand_diag(40, 7);
        let (mu, n) = (1e-3, 10);
        let p = probs_diana_plus(&diag, 2.0, mu, n);
        let sum: f64 = p.iter().sum();
        assert!((sum - 2.0).abs() < 1e-8);
        // L' ≥ 1 uniformly ⇒ no probability can be arbitrarily small
        // relative to the largest (ratio bounded by L'_max/L'_min · 1)
        assert!(p.iter().all(|&x| x > 0.0 && x <= 1.0));
    }

    #[test]
    fn diana_equalizes_modified_terms() {
        // (1/p_j − 1) L'_j constant (eq. 18/19)
        let diag = rand_diag(15, 8);
        let (mu, n) = (1e-3, 5);
        let p = probs_diana_plus(&diag, 3.0, mu, n);
        let lp: Vec<f64> = diag.iter().map(|&l| l / (mu * n as f64) + 1.0).collect();
        let terms: Vec<f64> = p
            .iter()
            .zip(&lp)
            .map(|(&pj, &lj)| (1.0 / pj - 1.0) * lj)
            .collect();
        let max = terms.iter().cloned().fold(0.0, f64::max);
        let min = terms.iter().cloned().fold(f64::MAX, f64::min);
        assert!((max - min) < 1e-7 * max.max(1e-30));
    }

    #[test]
    fn adiana_probs_sum_to_tau() {
        let diag = rand_diag(35, 9);
        let p = probs_adiana_plus(&diag, 4.0, 1e-3, 8);
        let sum: f64 = p.iter().sum();
        assert!((sum - 4.0).abs() < 1e-8);
        assert!(p.iter().all(|&x| x > 0.0 && x <= 1.0));
    }

    #[test]
    fn adiana_sqrt_shape() {
        // p_j² (L'_j + ρ) = L'_j ⇒ (1/p_j² − 1)·L'_j = ρ constant
        let diag = rand_diag(12, 10);
        let (mu, n) = (1e-3, 4);
        let p = probs_adiana_plus(&diag, 3.0, mu, n);
        let lp: Vec<f64> = diag.iter().map(|&l| l / (mu * n as f64) + 1.0).collect();
        let terms: Vec<f64> = p
            .iter()
            .zip(&lp)
            .map(|(&pj, &lj)| (1.0 / (pj * pj) - 1.0) * lj)
            .collect();
        let max = terms.iter().cloned().fold(0.0, f64::max);
        let min = terms.iter().cloned().fold(f64::MAX, f64::min);
        assert!((max - min) < 1e-6 * max.max(1e-30));
    }

    #[test]
    fn tau_ge_d_gives_all_ones() {
        let diag = rand_diag(6, 11);
        for p in [
            probs_dcgd_plus(&diag, 6.0),
            probs_diana_plus(&diag, 10.0, 1e-3, 3),
            probs_adiana_plus(&diag, 7.0, 1e-3, 3),
        ] {
            assert!(p.iter().all(|&x| x == 1.0));
        }
    }

    #[test]
    fn higher_smoothness_gets_higher_probability() {
        let diag = vec![0.001, 0.01, 0.1, 1.0];
        let p = probs_dcgd_plus(&diag, 1.0);
        for w in p.windows(2) {
            assert!(w[0] <= w[1] + 1e-12);
        }
    }

    #[test]
    fn uniform_diag_gives_uniform_probs() {
        let diag = vec![0.25; 10];
        for p in [
            probs_dcgd_plus(&diag, 2.0),
            probs_diana_plus(&diag, 2.0, 1e-3, 4),
            probs_adiana_plus(&diag, 2.0, 1e-3, 4),
        ] {
            for &x in &p {
                assert!((x - 0.2).abs() < 1e-9, "p={x}");
            }
        }
    }
}
