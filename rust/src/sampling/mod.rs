//! Samplings (paper §3.1, §5): proper random subsets S ⊆ [d] driving the
//! diagonal sketches C (eq. 6).
//!
//! All paper experiments use *independent* samplings (`p_{jl} = p_j p_l`),
//! for which `𝓛̃_i = max_j (1/p_{i;j} − 1) L_{i;jj}` (eq. 15) and the
//! optimal probabilities have the water-filling form solved here:
//!
//! * eq. (16) — DCGD+:   `p_j = L_j/(L_j + ρ)`,
//! * eq. (19) — DIANA+:  `p_j = L'_j/(L'_j + ρ')`, `L'_j = L_j/(μn) + 1`,
//! * eq. (21) — ADIANA+: `p_j = √(L'_j/(L'_j + ρ''))`,
//!
//! with ρ ≥ 0 the unique root of `Σ_j p_j(ρ) = τ` (strictly monotone; no
//! closed form — we bisect, as the paper prescribes "one dimensional
//! solvers").

pub mod solvers;

use crate::util::rng::Rng;

/// An independent Bernoulli sampling: coordinate j enters S with
/// probability `p[j]`, independently.
#[derive(Clone, Debug)]
pub struct IndependentSampling {
    pub p: Vec<f64>,
}

impl IndependentSampling {
    pub fn new(p: Vec<f64>) -> IndependentSampling {
        assert!(
            p.iter().all(|&x| x > 0.0 && x <= 1.0),
            "sampling must be proper: p ∈ (0,1]"
        );
        IndependentSampling { p }
    }

    /// Uniform sampling with expected size τ: p_j = τ/d (clamped to 1).
    pub fn uniform(d: usize, tau: f64) -> IndependentSampling {
        assert!(tau > 0.0);
        let p = (tau / d as f64).min(1.0);
        IndependentSampling::new(vec![p; d])
    }

    pub fn dim(&self) -> usize {
        self.p.len()
    }

    /// E|S| = Σ p_j
    pub fn expected_size(&self) -> f64 {
        self.p.iter().sum()
    }

    /// ω = max_j 1/p_j − 1 — the compression variance of the sketch.
    pub fn omega(&self) -> f64 {
        crate::objective::smoothness::omega(&self.p)
    }

    /// 𝓛̃ for this sampling against a smoothness diagonal (eq. 15).
    pub fn tilde_l(&self, diag: &[f64]) -> f64 {
        crate::objective::smoothness::tilde_l_independent(&self.p, diag)
    }

    /// Draw S: sorted coordinate indices.
    pub fn sample(&self, rng: &mut Rng) -> Vec<u32> {
        let mut s = Vec::new();
        self.sample_into(rng, &mut s);
        s
    }

    /// Draw S into a reusable buffer (hot path).
    pub fn sample_into(&self, rng: &mut Rng, out: &mut Vec<u32>) {
        out.clear();
        for (j, &pj) in self.p.iter().enumerate() {
            if pj >= 1.0 || rng.bernoulli(pj) {
                out.push(j as u32);
            }
        }
    }
}

/// Which probability rule a method uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SamplingKind {
    /// p_j = τ/d
    Uniform,
    /// eq. (16) — minimizes 𝓛̃ (DCGD+; Proposition 5)
    ImportanceDcgd,
    /// eq. (19) — minimizes ω + 𝓛̃/(μn) (DIANA+; Proposition 6)
    ImportanceDiana,
    /// eq. (21) — ADIANA+ (Remark 5)
    ImportanceAdiana,
}

impl SamplingKind {
    pub fn parse(s: &str) -> Option<SamplingKind> {
        match s {
            "uniform" => Some(SamplingKind::Uniform),
            "importance-dcgd" => Some(SamplingKind::ImportanceDcgd),
            "importance" | "importance-diana" => Some(SamplingKind::ImportanceDiana),
            "importance-adiana" => Some(SamplingKind::ImportanceAdiana),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            SamplingKind::Uniform => "uniform",
            SamplingKind::ImportanceDcgd => "importance-dcgd",
            SamplingKind::ImportanceDiana => "importance-diana",
            SamplingKind::ImportanceAdiana => "importance-adiana",
        }
    }

    /// Build the sampling for one worker from its smoothness diagonal.
    pub fn build(self, diag: &[f64], tau: f64, mu: f64, n: usize) -> IndependentSampling {
        let d = diag.len();
        match self {
            SamplingKind::Uniform => IndependentSampling::uniform(d, tau),
            SamplingKind::ImportanceDcgd => {
                IndependentSampling::new(solvers::probs_dcgd_plus(diag, tau))
            }
            SamplingKind::ImportanceDiana => {
                IndependentSampling::new(solvers::probs_diana_plus(diag, tau, mu, n))
            }
            SamplingKind::ImportanceAdiana => {
                IndependentSampling::new(solvers::probs_adiana_plus(diag, tau, mu, n))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_probs() {
        let s = IndependentSampling::uniform(10, 2.0);
        assert!((s.expected_size() - 2.0).abs() < 1e-12);
        assert!((s.omega() - 4.0).abs() < 1e-12); // 1/(0.2) − 1
    }

    #[test]
    fn uniform_tau_ge_d_clamps() {
        let s = IndependentSampling::uniform(5, 10.0);
        assert!(s.p.iter().all(|&p| p == 1.0));
        assert_eq!(s.omega(), 0.0);
    }

    #[test]
    fn sample_expected_size() {
        let s = IndependentSampling::uniform(100, 20.0);
        let mut rng = Rng::new(1);
        let trials = 2000;
        let total: usize = (0..trials).map(|_| s.sample(&mut rng).len()).sum();
        let avg = total as f64 / trials as f64;
        assert!((avg - 20.0).abs() < 0.5, "avg={avg}");
    }

    #[test]
    fn sample_sorted_and_in_range() {
        let s = IndependentSampling::uniform(50, 10.0);
        let mut rng = Rng::new(2);
        for _ in 0..20 {
            let v = s.sample(&mut rng);
            for w in v.windows(2) {
                assert!(w[0] < w[1]);
            }
            assert!(v.iter().all(|&j| (j as usize) < 50));
        }
    }

    #[test]
    fn per_coordinate_rates() {
        let s = IndependentSampling::new(vec![0.9, 0.1, 0.5]);
        let mut rng = Rng::new(3);
        let mut counts = [0usize; 3];
        let trials = 20_000;
        for _ in 0..trials {
            for j in s.sample(&mut rng) {
                counts[j as usize] += 1;
            }
        }
        for (j, &pj) in s.p.iter().enumerate() {
            let emp = counts[j] as f64 / trials as f64;
            assert!((emp - pj).abs() < 0.02, "coord {j}: {emp} vs {pj}");
        }
    }

    #[test]
    fn kind_parsing() {
        assert_eq!(SamplingKind::parse("uniform"), Some(SamplingKind::Uniform));
        assert_eq!(
            SamplingKind::parse("importance"),
            Some(SamplingKind::ImportanceDiana)
        );
        assert_eq!(SamplingKind::parse("bogus"), None);
    }

    #[test]
    #[should_panic]
    fn improper_sampling_rejected() {
        IndependentSampling::new(vec![0.5, 0.0]);
    }

    #[test]
    fn sample_into_matches_sample() {
        let s = IndependentSampling::uniform(30, 5.0);
        let mut r1 = Rng::new(7);
        let mut r2 = Rng::new(7);
        let mut buf = Vec::new();
        for _ in 0..10 {
            let a = s.sample(&mut r1);
            s.sample_into(&mut r2, &mut buf);
            assert_eq!(a, buf);
        }
    }
}
