//! Appendix C: sketches as linear compression operators and the
//! variance-vs-communication trade-off (Theorem 14, eq. (36), Figure 5).
//!
//! For a linear compressor `C(x) = D(Sx)` the paper proves
//! `α + E[b]/(32d) ≥ 1`, exponentially stronger than the general
//! uncertainty principle `α · 4^{b/d} ≥ 1` of Safaryan et al. (2020).
//! This module measures empirical (α, b) points for:
//!
//! * random q-sparsification (the *optimal* linear scheme, Theorem 15):
//!   keep each coordinate with probability q, decode by identity;
//! * greedy top-k sparsification (nonlinear comparator).

use crate::compress::topk::topk_alpha;
use crate::util::rng::Rng;

/// ln C(n, k) by direct summation (n ≤ ~1e6 is instant).
pub fn ln_binomial(n: usize, k: usize) -> f64 {
    if k > n {
        return f64::NEG_INFINITY;
    }
    let k = k.min(n - k);
    let mut s = 0.0;
    for i in 0..k {
        s += ((n - i) as f64).ln() - ((i + 1) as f64).ln();
    }
    s
}

pub fn log2_binomial(n: usize, k: usize) -> f64 {
    ln_binomial(n, k) / std::f64::consts::LN_2
}

/// Bits to transmit a k-sparse vector of dimension d with `float_bits` per
/// value (paper §C.5: b = 32k + log₂ C(d,k)).
pub fn sparse_vector_bits(d: usize, k: usize, float_bits: u32) -> f64 {
    float_bits as f64 * k as f64 + log2_binomial(d, k)
}

/// Binary entropy H₂(t) in bits.
pub fn h2(t: f64) -> f64 {
    if t <= 0.0 || t >= 1.0 {
        0.0
    } else {
        -t * t.log2() - (1.0 - t) * (1.0 - t).log2()
    }
}

/// One measured point of the trade-off diagram.
#[derive(Clone, Debug)]
pub struct TradeoffPoint {
    pub scheme: &'static str,
    /// target sparsity parameter (q for random, k/d for top-k)
    pub param: f64,
    /// empirical squared error fraction ‖C(x) − x‖²/‖x‖²
    pub alpha: f64,
    /// bits used
    pub bits: f64,
    /// β = bits/(32 d) — the paper's normalized communication
    pub beta: f64,
    /// α·4^{b/d} (general uncertainty principle; ≥ 1 required)
    pub general_up: f64,
    /// α + β (linear lower bound; ≥ 1 required)
    pub linear_lb: f64,
}

fn point(scheme: &'static str, param: f64, alpha: f64, d: usize, k: usize) -> TradeoffPoint {
    let bits = sparse_vector_bits(d, k, 32);
    let beta = bits / (32.0 * d as f64);
    TradeoffPoint {
        scheme,
        param,
        alpha,
        bits,
        beta,
        general_up: alpha * 4f64.powf(bits / d as f64),
        linear_lb: alpha + beta,
    }
}

/// Random q-sparsification of one Gaussian vector (identity decoder, as in
/// the optimal construction of §C.3 with B = I).
pub fn random_sparsification_point(d: usize, q: f64, rng: &mut Rng) -> TradeoffPoint {
    let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
    let mut kept = 0usize;
    let mut err = 0.0;
    let mut total = 0.0;
    for &v in &x {
        total += v * v;
        if rng.bernoulli(q) {
            kept += 1;
        } else {
            err += v * v;
        }
    }
    point("random", q, err / total, d, kept)
}

/// Top-k sparsification of one Gaussian vector.
pub fn topk_point(d: usize, k: usize, rng: &mut Rng) -> TradeoffPoint {
    let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
    point("topk", k as f64 / d as f64, topk_alpha(&x, k), d, k)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ln_binomial_small_values() {
        assert!((ln_binomial(5, 2) - 10f64.ln()).abs() < 1e-12);
        assert!((ln_binomial(10, 0)).abs() < 1e-12);
        assert_eq!(ln_binomial(3, 5), f64::NEG_INFINITY);
        // symmetry
        assert!((ln_binomial(100, 30) - ln_binomial(100, 70)).abs() < 1e-9);
    }

    #[test]
    fn entropy_bound_on_binomial() {
        // (1/d)·log₂ C(d, τd) ≤ H₂(τ)   (paper §C.5)
        let d = 500;
        for &t in &[0.1, 0.3, 0.5, 0.8] {
            let k = (t * d as f64) as usize;
            assert!(log2_binomial(d, k) / d as f64 <= h2(t) + 1e-9);
        }
    }

    #[test]
    fn random_points_respect_linear_lower_bound() {
        let mut rng = Rng::new(1);
        for &q in &[0.05, 0.2, 0.5, 0.8, 0.95] {
            let p = random_sparsification_point(1000, q, &mut rng);
            assert!(
                p.linear_lb >= 0.97,
                "α+β = {} < 1 violates Theorem 14 (q={q})",
                p.linear_lb
            );
            // near-optimality: α+β ≤ 1 + H₂(q)/32 + sampling noise
            assert!(
                p.linear_lb <= 1.0 + h2(q) / 32.0 + 0.05,
                "α+β = {} too large",
                p.linear_lb
            );
            // α ≈ 1 − q
            assert!((p.alpha - (1.0 - q)).abs() < 0.08);
        }
    }

    #[test]
    fn topk_beats_random_in_alpha_at_same_k() {
        let mut rng = Rng::new(2);
        let d = 1000;
        let k = 200;
        let t = topk_point(d, k, &mut rng);
        let r = random_sparsification_point(d, 0.2, &mut rng);
        assert!(t.alpha < r.alpha, "topk α={} random α={}", t.alpha, r.alpha);
        // but top-k still respects the *general* bound's direction of
        // improvement: it can go below α+β = 1 since it is nonlinear as a
        // map chosen from data (uses x to pick S); the general UP must hold.
        assert!(t.general_up >= 1.0 - 1e-9 || t.alpha < 1e-12);
    }

    #[test]
    fn beta_in_unit_range() {
        let mut rng = Rng::new(3);
        let p = random_sparsification_point(512, 0.5, &mut rng);
        assert!(p.beta > 0.0 && p.beta < 1.2);
        assert!(p.bits > 0.0);
    }
}
