//! Sparse wire message + communication accounting.
//!
//! Workers send `(index, value)` pairs; the paper's Figure 4 x-axis counts
//! *coordinates sent to the server*, and Appendix C.5 counts bits
//! (32 bits/float there; we default to 64 since the pipeline is f64, and
//! expose both). Index cost is ⌈log₂ d⌉ bits per coordinate.

#[derive(Clone, Debug, Default, PartialEq)]
pub struct SparseMsg {
    pub idx: Vec<u32>,
    pub val: Vec<f64>,
}

impl SparseMsg {
    pub fn new() -> SparseMsg {
        SparseMsg::default()
    }

    pub fn with_capacity(cap: usize) -> SparseMsg {
        SparseMsg {
            idx: Vec::with_capacity(cap),
            val: Vec::with_capacity(cap),
        }
    }

    pub fn clear(&mut self) {
        self.idx.clear();
        self.val.clear();
    }

    pub fn push(&mut self, i: u32, v: f64) {
        self.idx.push(i);
        self.val.push(v);
    }

    /// Number of coordinates carried (Figure 4's unit).
    pub fn coords(&self) -> usize {
        self.idx.len()
    }

    /// Bits on the wire: one value (float_bits) + one index (⌈log₂ d⌉)
    /// per coordinate.
    pub fn bits(&self, dim: usize, float_bits: u32) -> u64 {
        let idx_bits = index_bits(dim);
        self.coords() as u64 * (float_bits as u64 + idx_bits as u64)
    }

    /// Densify into a zeroed output buffer.
    pub fn scatter_into(&self, out: &mut [f64]) {
        out.fill(0.0);
        for (k, &i) in self.idx.iter().enumerate() {
            out[i as usize] = self.val[k];
        }
    }

    pub fn to_dense(&self, dim: usize) -> Vec<f64> {
        let mut out = vec![0.0; dim];
        self.scatter_into(&mut out);
        out
    }

    pub fn is_empty(&self) -> bool {
        self.idx.is_empty()
    }
}

/// Bits to address one coordinate of a d-dimensional vector: ⌈log₂ d⌉.
pub fn index_bits(dim: usize) -> u32 {
    if dim <= 2 {
        1
    } else {
        usize::BITS - (dim - 1).leading_zeros()
    }
}

/// Running totals for an experiment — a standalone aggregator the *caller*
/// feeds (the coordinator drivers keep their own internal accounting and
/// surface it via [`RoundRecord`](crate::coordinator::RoundRecord)).
///
/// `bits_up` is the *modeled* account (`coords · (float_bits + ⌈log₂ d⌉)`);
/// `bytes_up`/`bytes_down` are *measured* encoded frame sizes — pass what
/// [`crate::wire::codec::uplink_frame_len`] (or a real encode) reports via
/// the `*_measured` recorders; they stay 0 otherwise.
#[derive(Clone, Debug, Default)]
pub struct CommStats {
    pub coords_up: u64,
    pub bits_up: u64,
    pub msgs_up: u64,
    /// dense broadcast volume (server→workers), coords
    pub coords_down: u64,
    /// measured encoded bytes worker→server
    pub bytes_up: u64,
    /// measured encoded bytes server→workers
    pub bytes_down: u64,
}

impl CommStats {
    pub fn record_up(&mut self, msg: &SparseMsg, dim: usize, float_bits: u32) {
        self.coords_up += msg.coords() as u64;
        self.bits_up += msg.bits(dim, float_bits);
        self.msgs_up += 1;
    }

    /// [`CommStats::record_up`] plus the measured encoded size of the frame
    /// that carried the message.
    pub fn record_up_measured(
        &mut self,
        msg: &SparseMsg,
        dim: usize,
        float_bits: u32,
        encoded_bytes: u64,
    ) {
        self.record_up(msg, dim, float_bits);
        self.bytes_up += encoded_bytes;
    }

    pub fn record_down(&mut self, dim: usize) {
        self.coords_down += dim as u64;
    }

    /// [`CommStats::record_down`] plus the measured encoded frame size.
    pub fn record_down_measured(&mut self, dim: usize, encoded_bytes: u64) {
        self.record_down(dim);
        self.bytes_down += encoded_bytes;
    }

    pub fn merge(&mut self, other: &CommStats) {
        self.coords_up += other.coords_up;
        self.bits_up += other.bits_up;
        self.msgs_up += other.msgs_up;
        self.coords_down += other.coords_down;
        self.bytes_up += other.bytes_up;
        self.bytes_down += other.bytes_down;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_scatter() {
        let mut m = SparseMsg::new();
        m.push(1, 2.0);
        m.push(4, -1.0);
        assert_eq!(m.coords(), 2);
        assert_eq!(m.to_dense(6), vec![0.0, 2.0, 0.0, 0.0, -1.0, 0.0]);
    }

    #[test]
    fn index_bits_values() {
        assert_eq!(index_bits(2), 1);
        assert_eq!(index_bits(123), 7);
        assert_eq!(index_bits(128), 7);
        assert_eq!(index_bits(129), 8);
        assert_eq!(index_bits(7129), 13);
    }

    #[test]
    fn bits_accounting() {
        let mut m = SparseMsg::new();
        m.push(0, 1.0);
        m.push(1, 1.0);
        m.push(2, 1.0);
        // 3 coords, d=123 ⇒ 3·(64+7) bits
        assert_eq!(m.bits(123, 64), 3 * 71);
        assert_eq!(m.bits(123, 32), 3 * 39);
    }

    #[test]
    fn comm_stats_accumulate_and_merge() {
        let mut s = CommStats::default();
        let mut m = SparseMsg::new();
        m.push(0, 1.0);
        s.record_up(&m, 16, 64);
        s.record_up(&m, 16, 64);
        s.record_down(16);
        assert_eq!(s.coords_up, 2);
        assert_eq!(s.msgs_up, 2);
        assert_eq!(s.coords_down, 16);
        let mut t = CommStats::default();
        t.merge(&s);
        t.merge(&s);
        assert_eq!(t.coords_up, 4);
    }

    #[test]
    fn measured_bytes_accumulate_and_merge() {
        let mut s = CommStats::default();
        let mut m = SparseMsg::new();
        m.push(2, 1.0);
        s.record_up_measured(&m, 16, 64, 19);
        s.record_down_measured(16, 140);
        assert_eq!(s.bytes_up, 19);
        assert_eq!(s.bytes_down, 140);
        assert_eq!(s.coords_up, 1);
        assert_eq!(s.coords_down, 16);
        let mut t = CommStats::default();
        t.merge(&s);
        t.merge(&s);
        assert_eq!((t.bytes_up, t.bytes_down), (38, 280));
    }

    #[test]
    fn clear_resets() {
        let mut m = SparseMsg::with_capacity(4);
        m.push(3, 1.0);
        m.clear();
        assert!(m.is_empty());
    }
}
