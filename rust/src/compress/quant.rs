//! Smoothness-aware stochastic quantization (Wang, Safaryan, Richtárik —
//! arXiv:2106.03524), the direct sequel to the source paper's
//! matrix-aware *sparsification*.
//!
//! The compressor is `g = W · Q_s(W⁻¹ x)` where `Q_s` is QSGD-style
//! random dithering with `s` levels and `W` is driven by the worker's
//! local smoothness matrix **L_i**:
//!
//! * [`QuantWeighting::Diag`] — `W = Diag(L_i)^{1/2}` (cheap, sparse
//!   decompression);
//! * [`QuantWeighting::Root`] — `W = L_i^{1/2}` via the shared
//!   [`PsdRoot`] (full matrix whitening, like [`MatrixAware`]).
//!
//! `Q_s` is unbiased and `W·W⁻¹ = I` on the relevant range, so the whole
//! operator is unbiased with variance factor `ω_q = min(d/s², √d/s)`
//! *in the whitened geometry* — which is exactly where the smoothness
//! matrices make the variance cheap. `levels = 0` is the exact-passthrough
//! sentinel (`ω_q = 0`), used by the lossless tests and as the "max
//! levels" limit.
//!
//! [`UplinkCompressor`]/[`UplinkDecompressor`] are the runtime seam the
//! methods build against: the sketch family, sa-quant, and top-k all fit
//! behind the same `compress` / `accumulate` pair, so DCGD/DIANA/ADIANA
//! pick any of them up from `MethodSpec` with zero driver changes.
//! `UplinkDecompressor::Identity` reproduces the historical sparse
//! scatter loops op-for-op, preserving bitwise identity for the sketch
//! methods.
//!
//! [`MatrixAware`]: crate::compress::MatrixAware

use std::sync::Arc;

use crate::compress::message::SparseMsg;
use crate::compress::ops::sketch_compress;
use crate::compress::topk::topk_compress;
use crate::linalg::psd::PsdRoot;
use crate::sampling::IndependentSampling;
use crate::util::rng::Rng;

/// Which uplink compressor family a run uses (`--compressor`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CompressorKind {
    /// Whatever the method's theory prescribes: the diagonal sketch for
    /// the baselines, the matrix-aware protocol for the `+` family.
    Default,
    /// Standard unbiased diagonal sketch (eq. 6) — baselines only.
    Sketch,
    /// The source paper's matrix-aware sparsification (Def. 3 / eq. 7).
    MatrixAware,
    /// Smoothness-aware quantization (arXiv:2106.03524).
    SaQuant,
    /// Greedy top-k (biased; DCGD-only heuristic baseline).
    TopK,
}

impl CompressorKind {
    pub fn parse(s: &str) -> Option<CompressorKind> {
        match s {
            "default" => Some(CompressorKind::Default),
            "sketch" => Some(CompressorKind::Sketch),
            "matrix-aware" => Some(CompressorKind::MatrixAware),
            "sa-quant" => Some(CompressorKind::SaQuant),
            "topk" => Some(CompressorKind::TopK),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            CompressorKind::Default => "default",
            CompressorKind::Sketch => "sketch",
            CompressorKind::MatrixAware => "matrix-aware",
            CompressorKind::SaQuant => "sa-quant",
            CompressorKind::TopK => "topk",
        }
    }
}

/// The `W` in `g = W·Q_s(W⁻¹x)` (`--sa-weighting`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantWeighting {
    /// `W = Diag(L_i)^{1/2}` — sparse decompression, the paper's cheap
    /// variant.
    Diag,
    /// `W = L_i^{1/2}` via the PSD root — full-matrix whitening.
    Root,
}

impl QuantWeighting {
    pub fn parse(s: &str) -> Option<QuantWeighting> {
        match s {
            "diag" => Some(QuantWeighting::Diag),
            "root" => Some(QuantWeighting::Root),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            QuantWeighting::Diag => "diag",
            QuantWeighting::Root => "root",
        }
    }
}

#[derive(Clone, Debug)]
enum SaWeights {
    /// Pre-inverted diagonal weights 1/w_j (w_j = √L_jj, or 1 where
    /// L_jj = 0 so the coordinate passes through untouched).
    Diag { inv: Vec<f64> },
    Root { root: Arc<PsdRoot> },
}

/// One worker's smoothness-aware quantizer: owns the whitening scratch
/// so the per-round compress path stays allocation-free.
#[derive(Clone, Debug)]
pub struct SaQuant {
    /// Dither levels `s`; 0 is the exact-passthrough sentinel.
    pub levels: u32,
    weights: SaWeights,
    whiten_scratch: Vec<f64>,
    coeff_scratch: Vec<f64>,
}

impl SaQuant {
    /// Diagonal weighting from the worker's local `diag(L_i)`.
    pub fn diag(levels: u32, ldiag: &[f64]) -> SaQuant {
        let inv = ldiag
            .iter()
            .map(|&l| if l > 0.0 { 1.0 / l.sqrt() } else { 1.0 })
            .collect::<Vec<f64>>();
        SaQuant {
            levels,
            whiten_scratch: vec![0.0; inv.len()],
            coeff_scratch: Vec::new(),
            weights: SaWeights::Diag { inv },
        }
    }

    /// Full-matrix weighting via the worker's shared PSD root.
    pub fn root(levels: u32, root: Arc<PsdRoot>) -> SaQuant {
        SaQuant {
            levels,
            whiten_scratch: vec![0.0; root.dim()],
            coeff_scratch: Vec::new(),
            weights: SaWeights::Root { root },
        }
    }

    /// QSGD variance factor `ω_q = min(d/s², √d/s)` (the sequel paper's
    /// ω expression); 0 for the exact sentinel.
    pub fn omega(dim: usize, levels: u32) -> f64 {
        if levels == 0 {
            return 0.0;
        }
        let d = dim as f64;
        let s = levels as f64;
        (d / (s * s)).min(d.sqrt() / s)
    }

    /// Worker side: msg = Q_s(W⁻¹x) in the whitened coordinates (sparse,
    /// ascending indices; *not* unbiased on its own — pair with the
    /// matching [`UplinkDecompressor`]).
    pub fn compress(&mut self, x: &[f64], rng: &mut Rng, out: &mut SparseMsg) {
        match &self.weights {
            SaWeights::Diag { inv } => {
                for (j, &w) in inv.iter().enumerate() {
                    self.whiten_scratch[j] = x[j] * w;
                }
            }
            SaWeights::Root { root } => {
                root.apply_pow_into_with(-0.5, x, &mut self.whiten_scratch, &mut self.coeff_scratch);
            }
        }
        quantize_into(&self.whiten_scratch, self.levels, rng, out);
    }

    /// The server-side inverse of this worker's whitening.
    pub fn decompressor(&self) -> UplinkDecompressor {
        match &self.weights {
            SaWeights::Diag { inv } => UplinkDecompressor::Diag(
                inv.iter()
                    .map(|&w| if w != 0.0 { 1.0 / w } else { 0.0 })
                    .collect(),
            ),
            SaWeights::Root { root } => UplinkDecompressor::Root {
                root: root.clone(),
                scratch: vec![0.0; root.dim()],
                coeff: Vec::new(),
            },
        }
    }
}

/// QSGD random dithering with `levels` levels (`levels = 0` ⇒ exact
/// nonzero passthrough). One uniform draw per coordinate keeps the RNG
/// consumption independent of the values, so the three drivers stay
/// bitwise-aligned.
fn quantize_into(w: &[f64], levels: u32, rng: &mut Rng, out: &mut SparseMsg) {
    out.clear();
    if levels == 0 {
        for (j, &v) in w.iter().enumerate() {
            if v != 0.0 {
                out.push(j as u32, v);
            }
        }
        return;
    }
    let norm = w.iter().map(|v| v * v).sum::<f64>().sqrt();
    if norm == 0.0 {
        return;
    }
    let s = levels as f64;
    for (j, &v) in w.iter().enumerate() {
        let u = v.abs() / norm * s;
        let base = u.floor();
        let level = base + if rng.bernoulli(u - base) { 1.0 } else { 0.0 };
        if level > 0.0 {
            out.push(j as u32, v.signum() * norm * level / s);
        }
    }
}

/// The uplink-compression seam the methods build against.
#[derive(Clone, Debug)]
pub enum UplinkCompressor {
    Sketch(IndependentSampling),
    SaQuant(SaQuant),
    TopK(usize),
}

impl UplinkCompressor {
    pub fn compress(&mut self, x: &[f64], rng: &mut Rng, out: &mut SparseMsg) {
        match self {
            UplinkCompressor::Sketch(s) => sketch_compress(x, s, rng, out),
            UplinkCompressor::SaQuant(q) => q.compress(x, rng, out),
            UplinkCompressor::TopK(k) => topk_compress(x, *k, out),
        }
    }
}

/// Server-side accumulation of one worker's uplink into a dense buffer.
///
/// `Identity` is the historical sparse scatter (`acc[i] += val`) op-for-op
/// — the sketch and top-k paths route through it unchanged, so their
/// trajectories stay bitwise identical to before this seam existed.
#[derive(Clone, Debug)]
pub enum UplinkDecompressor {
    Identity,
    /// Sparse unwhiten: `acc[i] += w_i · val` with `w = diag(L)^{1/2}`.
    Diag(Vec<f64>),
    /// Dense unwhiten: `acc += L^{1/2} · msg`.
    Root {
        root: Arc<PsdRoot>,
        scratch: Vec<f64>,
        coeff: Vec<f64>,
    },
}

impl UplinkDecompressor {
    pub fn accumulate(&mut self, msg: &SparseMsg, acc: &mut [f64]) {
        self.accumulate_scaled(msg, 1.0, acc);
    }

    /// `acc += alpha · W · msg` (alpha folded in so DIANA's shift update
    /// stays a single pass).
    pub fn accumulate_scaled(&mut self, msg: &SparseMsg, alpha: f64, acc: &mut [f64]) {
        match self {
            UplinkDecompressor::Identity => {
                if alpha == 1.0 {
                    for (k, &i) in msg.idx.iter().enumerate() {
                        acc[i as usize] += msg.val[k];
                    }
                } else {
                    for (k, &i) in msg.idx.iter().enumerate() {
                        acc[i as usize] += alpha * msg.val[k];
                    }
                }
            }
            UplinkDecompressor::Diag(w) => {
                for (k, &i) in msg.idx.iter().enumerate() {
                    acc[i as usize] += alpha * w[i as usize] * msg.val[k];
                }
            }
            UplinkDecompressor::Root {
                root,
                scratch,
                coeff,
            } => {
                root.apply_pow_sparse_into_with(0.5, &msg.idx, &msg.val, scratch, coeff);
                for (j, &v) in scratch.iter().enumerate() {
                    acc[j] += alpha * v;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dense::Mat;
    use crate::linalg::vector;

    fn toy_root(d: usize, seed: u64) -> PsdRoot {
        let mut rng = Rng::new(seed);
        let b = Mat::from_rows(
            (0..d + 2)
                .map(|_| (0..d).map(|_| rng.normal()).collect())
                .collect(),
        );
        let mut l = b.gram();
        l.scale(0.1);
        l.add_diag(1e-3);
        PsdRoot::from_dense(&l)
    }

    fn roundtrip(q: &mut SaQuant, x: &[f64], rng: &mut Rng, g: &mut [f64]) {
        let mut msg = SparseMsg::new();
        q.compress(x, rng, &mut msg);
        let mut dec = q.decompressor();
        g.fill(0.0);
        dec.accumulate(&msg, g);
    }

    #[test]
    fn parse_and_name_roundtrip() {
        for k in [
            CompressorKind::Default,
            CompressorKind::Sketch,
            CompressorKind::MatrixAware,
            CompressorKind::SaQuant,
            CompressorKind::TopK,
        ] {
            assert_eq!(CompressorKind::parse(k.name()), Some(k));
        }
        assert_eq!(CompressorKind::parse("bogus"), None);
        for w in [QuantWeighting::Diag, QuantWeighting::Root] {
            assert_eq!(QuantWeighting::parse(w.name()), Some(w));
        }
        assert_eq!(QuantWeighting::parse("bogus"), None);
    }

    #[test]
    fn diag_quantizer_is_unbiased() {
        let d = 10;
        let mut rng = Rng::new(11);
        let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let ldiag: Vec<f64> = (0..d).map(|_| 0.1 + rng.uniform()).collect();
        let mut q = SaQuant::diag(4, &ldiag);
        let trials = 60_000;
        let mut mean = vec![0.0; d];
        let mut g = vec![0.0; d];
        for _ in 0..trials {
            roundtrip(&mut q, &x, &mut rng, &mut g);
            vector::axpy(1.0, &g, &mut mean);
        }
        for j in 0..d {
            let m = mean[j] / trials as f64;
            assert!(
                (m - x[j]).abs() < 0.05 * (1.0 + x[j].abs()),
                "E[g]_{j}={m} x_{j}={}",
                x[j]
            );
        }
    }

    #[test]
    fn root_quantizer_is_unbiased() {
        let d = 8;
        let root = Arc::new(toy_root(d, 12));
        let mut rng = Rng::new(13);
        let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let mut q = SaQuant::root(4, root);
        let trials = 60_000;
        let mut mean = vec![0.0; d];
        let mut g = vec![0.0; d];
        for _ in 0..trials {
            roundtrip(&mut q, &x, &mut rng, &mut g);
            vector::axpy(1.0, &g, &mut mean);
        }
        for j in 0..d {
            let m = mean[j] / trials as f64;
            assert!(
                (m - x[j]).abs() < 0.06 * (1.0 + x[j].abs()),
                "E[g]_{j}={m} x_{j}={}",
                x[j]
            );
        }
    }

    #[test]
    fn dither_variance_within_omega_bound() {
        // E‖Q_s(w) − w‖² ≤ ω_q‖w‖² with ω_q = min(d/s², √d/s) — checked in
        // the whitened geometry where the QSGD bound is stated.
        let d = 12;
        let mut rng = Rng::new(14);
        let w: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        for levels in [1u32, 2, 4, 8] {
            let omega = SaQuant::omega(d, levels);
            let trials = 40_000;
            let mut acc = 0.0;
            let mut msg = SparseMsg::new();
            let mut dense = vec![0.0; d];
            for _ in 0..trials {
                quantize_into(&w, levels, &mut rng, &mut msg);
                msg.scatter_into(&mut dense);
                acc += vector::dist2(&dense, &w);
            }
            let emp = acc / trials as f64;
            assert!(
                emp <= omega * vector::norm2(&w) * 1.05,
                "levels={levels} emp={emp} bound={}",
                omega * vector::norm2(&w)
            );
        }
    }

    #[test]
    fn exact_sentinel_is_lossless() {
        let d = 9;
        let mut rng = Rng::new(15);
        let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let ldiag: Vec<f64> = (0..d).map(|_| 0.2 + rng.uniform()).collect();
        let mut g = vec![0.0; d];
        let mut q = SaQuant::diag(0, &ldiag);
        roundtrip(&mut q, &x, &mut rng, &mut g);
        for j in 0..d {
            assert!((g[j] - x[j]).abs() < 1e-12, "diag lossless failed at {j}");
        }
        let root = Arc::new(toy_root(d, 16));
        let mut q = SaQuant::root(0, root);
        roundtrip(&mut q, &x, &mut rng, &mut g);
        for j in 0..d {
            assert!((g[j] - x[j]).abs() < 1e-9, "root lossless failed at {j}");
        }
    }

    #[test]
    fn quantized_levels_shrink_the_message() {
        // coarse dithering sends strictly fewer coordinates than the exact
        // sentinel on a generic dense vector
        let d = 64;
        let mut rng = Rng::new(17);
        let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let ldiag = vec![1.0; d];
        let mut coarse = SaQuant::diag(1, &ldiag);
        let mut exact = SaQuant::diag(0, &ldiag);
        let mut m1 = SparseMsg::new();
        let mut m0 = SparseMsg::new();
        coarse.compress(&x, &mut rng, &mut m1);
        exact.compress(&x, &mut rng, &mut m0);
        assert_eq!(m0.coords(), d);
        assert!(m1.coords() < d, "s=1 dither kept all {d} coords");
        // ascending indices (the codec's sorted-gap wire mode)
        assert!(m1.idx.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn identity_decompressor_matches_sparse_scatter() {
        let mut msg = SparseMsg::new();
        msg.push(1, 2.0);
        msg.push(3, -4.0);
        let mut acc = vec![1.0; 5];
        UplinkDecompressor::Identity.accumulate(&msg, &mut acc);
        assert_eq!(acc, vec![1.0, 3.0, 1.0, -3.0, 1.0]);
        UplinkDecompressor::Identity.accumulate_scaled(&msg, 0.5, &mut acc);
        assert_eq!(acc, vec![1.0, 4.0, 1.0, -5.0, 1.0]);
    }

    #[test]
    fn omega_expression() {
        // small s: d/s² dominates is false — min picks √d/s; large s: d/s²
        let d = 16;
        assert!((SaQuant::omega(d, 1) - 4.0).abs() < 1e-12); // min(16, 4)
        assert!((SaQuant::omega(d, 8) - 0.25).abs() < 1e-12); // min(0.25, 0.5)
        assert_eq!(SaQuant::omega(d, 0), 0.0);
    }
}
