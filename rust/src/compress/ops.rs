//! Compression operators.
//!
//! * [`sketch_compress`] — the unbiased diagonal sketch `C x` (eq. 6):
//!   keep sampled coordinates scaled by 1/p_j. This is the *standard*
//!   sparsification the original DCGD/DIANA/ADIANA baselines use.
//! * [`MatrixAware`] — the paper's data-dependent protocol (Def. 3):
//!   the worker sends `C L^{†1/2} x` (sparse), the server decompresses
//!   with `L^{1/2}·`, so the estimator `g = L^{1/2} C L^{†1/2} x` is
//!   unbiased (eq. 7).

use crate::compress::message::SparseMsg;
use crate::linalg::psd::PsdRoot;
use crate::sampling::IndependentSampling;
use crate::util::rng::Rng;

/// Standard sketch: sample S ~ sampling, emit (j, x_j/p_j) for j ∈ S.
pub fn sketch_compress(
    x: &[f64],
    sampling: &IndependentSampling,
    rng: &mut Rng,
    out: &mut SparseMsg,
) {
    out.clear();
    for (j, &pj) in sampling.p.iter().enumerate() {
        if pj >= 1.0 || rng.bernoulli(pj) {
            out.push(j as u32, x[j] / pj);
        }
    }
}

/// Apply a pre-drawn sample (when the sketch must be reused on two vectors
/// with the *same* C, e.g. ADIANA's Δ and δ use independent draws but
/// DIANA++'s reconstruction must match the server's draw).
pub fn sketch_apply(x: &[f64], sample: &[u32], p: &[f64], out: &mut SparseMsg) {
    out.clear();
    for &j in sample {
        out.push(j, x[j as usize] / p[j as usize]);
    }
}

/// The matrix-smoothness-aware compressor for one worker: owns the
/// whitening scratch and exposes the two halves of protocol (7).
#[derive(Clone, Debug)]
pub struct MatrixAware {
    pub sampling: IndependentSampling,
    whiten_scratch: Vec<f64>,
    /// eigen-coordinate scratch for the whiten apply (§Perf: keeps the
    /// per-round compress path allocation-free)
    coeff_scratch: Vec<f64>,
}

impl MatrixAware {
    pub fn new(sampling: IndependentSampling) -> MatrixAware {
        let d = sampling.dim();
        MatrixAware {
            sampling,
            whiten_scratch: vec![0.0; d],
            coeff_scratch: Vec::new(),
        }
    }

    /// Worker side: msg = C L^{†1/2} x (sparse, *not* unbiased on its own).
    pub fn compress(&mut self, root: &PsdRoot, x: &[f64], rng: &mut Rng, out: &mut SparseMsg) {
        root.apply_pow_into_with(-0.5, x, &mut self.whiten_scratch, &mut self.coeff_scratch);
        sketch_compress(&self.whiten_scratch, &self.sampling, rng, out);
    }

    /// Server side: g = L^{1/2} · msg (dense). Unbiased: E[g] = x.
    pub fn decompress_into(root: &PsdRoot, msg: &SparseMsg, out: &mut [f64]) {
        root.apply_pow_sparse_into(0.5, &msg.idx, &msg.val, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dense::Mat;
    use crate::linalg::vector;

    fn toy_root(d: usize, seed: u64) -> PsdRoot {
        let mut rng = Rng::new(seed);
        let b = Mat::from_rows(
            (0..d + 2)
                .map(|_| (0..d).map(|_| rng.normal()).collect())
                .collect(),
        );
        let mut l = b.gram();
        l.scale(0.1);
        l.add_diag(1e-3);
        PsdRoot::from_dense(&l)
    }

    #[test]
    fn sketch_is_unbiased() {
        let d = 12;
        let mut rng = Rng::new(1);
        let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let s = IndependentSampling::uniform(d, 3.0);
        let trials = 60_000;
        let mut mean = vec![0.0; d];
        let mut msg = SparseMsg::new();
        for _ in 0..trials {
            sketch_compress(&x, &s, &mut rng, &mut msg);
            for (k, &i) in msg.idx.iter().enumerate() {
                mean[i as usize] += msg.val[k];
            }
        }
        for j in 0..d {
            let m = mean[j] / trials as f64;
            assert!((m - x[j]).abs() < 0.05 * (1.0 + x[j].abs()), "E[Cx]_{j}={m} x_{j}={}", x[j]);
        }
    }

    #[test]
    fn sketch_variance_bound() {
        // E‖Cx − x‖² ≤ ω‖x‖² (eq. 25)
        let d = 10;
        let mut rng = Rng::new(2);
        let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let s = IndependentSampling::uniform(d, 2.0);
        let omega = s.omega();
        let trials = 40_000;
        let mut acc = 0.0;
        let mut msg = SparseMsg::new();
        let mut dense = vec![0.0; d];
        for _ in 0..trials {
            sketch_compress(&x, &s, &mut rng, &mut msg);
            msg.scatter_into(&mut dense);
            acc += vector::dist2(&dense, &x);
        }
        let emp = acc / trials as f64;
        assert!(
            emp <= omega * vector::norm2(&x) * 1.05,
            "emp={emp} bound={}",
            omega * vector::norm2(&x)
        );
    }

    #[test]
    fn matrix_aware_is_unbiased() {
        let d = 8;
        let root = toy_root(d, 3);
        let mut rng = Rng::new(4);
        // x in Range(L) — guaranteed here since L is PD
        let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let mut ma = MatrixAware::new(IndependentSampling::uniform(d, 2.0));
        let trials = 60_000;
        let mut mean = vec![0.0; d];
        let mut msg = SparseMsg::new();
        let mut g = vec![0.0; d];
        for _ in 0..trials {
            ma.compress(&root, &x, &mut rng, &mut msg);
            MatrixAware::decompress_into(&root, &msg, &mut g);
            vector::axpy(1.0, &g, &mut mean);
        }
        for j in 0..d {
            let m = mean[j] / trials as f64;
            assert!(
                (m - x[j]).abs() < 0.06 * (1.0 + x[j].abs()),
                "E[g]_{j}={m} x_{j}={}",
                x[j]
            );
        }
    }

    #[test]
    fn matrix_aware_variance_decomposition() {
        // E‖g − x‖²  =  ‖L^{†1/2}x‖²_{P̃∘L}  =  Σ_j (1/p_j − 1) L_jj w_j²
        // for independent samplings, where w = L^{†1/2}x (eq. 11 inner term).
        let d = 6;
        let root = toy_root(d, 5);
        let mut rng = Rng::new(6);
        let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let s = IndependentSampling::new(vec![0.3, 0.5, 0.9, 0.2, 0.7, 1.0]);
        let mut ma = MatrixAware::new(s.clone());
        let w = root.apply_pow(-0.5, &x);
        let ldiag = root.diag_pow(1.0);
        let mut expected = 0.0;
        for j in 0..d {
            expected += (1.0 / s.p[j] - 1.0) * ldiag[j] * w[j] * w[j];
        }
        let trials = 60_000;
        let mut acc = 0.0;
        let mut msg = SparseMsg::new();
        let mut g = vec![0.0; d];
        for _ in 0..trials {
            ma.compress(&root, &x, &mut rng, &mut msg);
            MatrixAware::decompress_into(&root, &msg, &mut g);
            acc += vector::dist2(&g, &x);
        }
        let emp = acc / trials as f64;
        assert!(
            (emp - expected).abs() < 0.08 * expected.max(1e-12),
            "emp={emp} expected={expected}"
        );
    }

    #[test]
    fn full_sampling_is_lossless() {
        let d = 7;
        let root = toy_root(d, 7);
        let mut rng = Rng::new(8);
        let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let mut ma = MatrixAware::new(IndependentSampling::uniform(d, d as f64));
        let mut msg = SparseMsg::new();
        let mut g = vec![0.0; d];
        ma.compress(&root, &x, &mut rng, &mut msg);
        MatrixAware::decompress_into(&root, &msg, &mut g);
        for j in 0..d {
            assert!((g[j] - x[j]).abs() < 1e-9, "lossless failed at {j}");
        }
    }

    #[test]
    fn sketch_apply_uses_given_sample() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let p = [0.5, 0.5, 0.5, 0.5];
        let mut msg = SparseMsg::new();
        sketch_apply(&x, &[1, 3], &p, &mut msg);
        assert_eq!(msg.idx, vec![1, 3]);
        assert_eq!(msg.val, vec![4.0, 8.0]);
    }
}
