//! Compression: sparse messages with communication accounting, the
//! standard diagonal sketch, the paper's matrix-smoothness-aware protocol
//! (Definition 3 / eq. 7), smoothness-aware quantization (the sequel
//! paper, arXiv:2106.03524), greedy top-k, and the Appendix-C
//! lower-bound laboratory.

pub mod lowerbound;
pub mod message;
pub mod ops;
pub mod quant;
pub mod topk;

pub use message::{index_bits, CommStats, SparseMsg};
pub use ops::{sketch_apply, sketch_compress, MatrixAware};
pub use quant::{CompressorKind, QuantWeighting, SaQuant, UplinkCompressor, UplinkDecompressor};
pub use topk::{topk_alpha, topk_compress};
