//! Compression: sparse messages with communication accounting, the
//! standard diagonal sketch, the paper's matrix-smoothness-aware protocol
//! (Definition 3 / eq. 7), greedy top-k, and the Appendix-C lower-bound
//! laboratory.

pub mod lowerbound;
pub mod message;
pub mod ops;
pub mod topk;

pub use message::{index_bits, CommStats, SparseMsg};
pub use ops::{sketch_apply, sketch_compress, MatrixAware};
pub use topk::{topk_alpha, topk_compress};
