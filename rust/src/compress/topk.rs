//! Greedy (Top-k) sparsification — a *biased* contractive compressor.
//!
//! Used by the Appendix C lower-bound experiment (Figure 5) as the greedy
//! comparator, and available as the "greedy sparsification" the paper's
//! §7 lists as future work.

use crate::compress::message::SparseMsg;

/// Keep the k largest-magnitude coordinates (unscaled).
pub fn topk_compress(x: &[f64], k: usize, out: &mut SparseMsg) {
    out.clear();
    if k == 0 {
        return;
    }
    let k = k.min(x.len());
    // Partial selection: indices sorted by |x| descending, take k.
    // total_cmp instead of partial_cmp().unwrap(): NaN input must not
    // panic, and the total order makes tie-breaking deterministic across
    // platforms (total_cmp ranks |NaN| above +inf, so NaNs are "largest").
    let mut order: Vec<u32> = (0..x.len() as u32).collect();
    order.select_nth_unstable_by(k - 1, |&a, &b| {
        x[b as usize].abs().total_cmp(&x[a as usize].abs())
    });
    let mut sel: Vec<u32> = order[..k].to_vec();
    sel.sort_unstable();
    for &j in &sel {
        out.push(j, x[j as usize]);
    }
}

/// Squared relative error 1 − ‖x_S‖²/‖x‖² of the top-k approximation.
pub fn topk_alpha(x: &[f64], k: usize) -> f64 {
    let mut msg = SparseMsg::new();
    topk_compress(x, k, &mut msg);
    let kept: f64 = msg.val.iter().map(|v| v * v).sum();
    let total: f64 = x.iter().map(|v| v * v).sum();
    if total == 0.0 {
        0.0
    } else {
        (1.0 - kept / total).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selects_largest_magnitudes() {
        let x = [0.1, -5.0, 3.0, 0.0, 4.0];
        let mut m = SparseMsg::new();
        topk_compress(&x, 2, &mut m);
        assert_eq!(m.idx, vec![1, 4]);
        assert_eq!(m.val, vec![-5.0, 4.0]);
    }

    #[test]
    fn k_zero_and_k_full() {
        let x = [1.0, 2.0];
        let mut m = SparseMsg::new();
        topk_compress(&x, 0, &mut m);
        assert!(m.is_empty());
        topk_compress(&x, 5, &mut m);
        assert_eq!(m.coords(), 2);
    }

    #[test]
    fn alpha_decreases_with_k() {
        let x: Vec<f64> = (0..50).map(|i| ((i * 7919) % 101) as f64 - 50.0).collect();
        let mut prev = 1.0;
        for k in [1, 5, 10, 25, 50] {
            let a = topk_alpha(&x, k);
            assert!(a <= prev + 1e-12);
            assert!((0.0..=1.0).contains(&a));
            prev = a;
        }
        assert_eq!(topk_alpha(&x, 50), 0.0);
    }

    #[test]
    fn non_finite_inputs_do_not_panic() {
        // regression: the old partial_cmp(..).unwrap() comparator panicked
        // on NaN. total_cmp ranks |NaN| above +inf, so the pathological
        // coordinates are *selected* (visible downstream) rather than
        // silently dropped or fatal.
        let x = [1.0, f64::NAN, -3.0, f64::INFINITY, f64::NEG_INFINITY, 0.5];
        let mut m = SparseMsg::new();
        topk_compress(&x, 3, &mut m);
        assert_eq!(m.coords(), 3);
        assert_eq!(m.idx, vec![1, 3, 4]);
        assert!(m.val[0].is_nan());
        assert_eq!(m.val[1], f64::INFINITY);
        assert_eq!(m.val[2], f64::NEG_INFINITY);
        // all-NaN input: still no panic, deterministic selection
        let y = [f64::NAN; 4];
        topk_compress(&y, 2, &mut m);
        assert_eq!(m.coords(), 2);
    }

    #[test]
    fn contraction_property() {
        // ‖C(x) − x‖² ≤ (1 − k/d)‖x‖² holds for top-k
        let x: Vec<f64> = (0..20).map(|i| (i as f64 * 1.3).sin()).collect();
        let d = x.len();
        for k in [1usize, 4, 10, 19] {
            let a = topk_alpha(&x, k);
            assert!(a <= 1.0 - k as f64 / d as f64 + 1e-12, "k={k} alpha={a}");
        }
    }
}
