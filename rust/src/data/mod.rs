//! Datasets: LibSVM parsing, synthetic LibSVM-like generation (Table 3
//! shapes), normalization and sharding.

pub mod dataset;
pub mod libsvm;
pub mod synth;

pub use dataset::{Dataset, Shard};
pub use synth::{generate, spec_by_name, SynthSpec, PAPER_DATASETS};

use anyhow::{bail, Result};

/// Load dataset `name`: if `data_dir` contains a genuine LibSVM file named
/// `name` (or `name.txt`), parse it; otherwise fall back to the synthetic
/// generator with the paper's Table 3 shape.
pub fn load_or_synth(name: &str, data_dir: Option<&std::path::Path>, seed: u64) -> Result<Dataset> {
    if let Some(dir) = data_dir {
        for cand in [dir.join(name), dir.join(format!("{name}.txt"))] {
            if cand.is_file() {
                let forced_dim = spec_by_name(name).map(|s| s.d);
                return libsvm::load_file(&cand, forced_dim);
            }
        }
    }
    match spec_by_name(name) {
        Some(spec) => Ok(synth::generate(spec, seed)),
        None if name == "tiny" => Ok(synth::generate(&synth::tiny_spec(), seed)),
        None => bail!("unknown dataset '{name}' and no file found"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synth_fallback_works() {
        let ds = load_or_synth("tiny", None, 1).unwrap();
        assert_eq!(ds.name, "tiny");
        assert!(load_or_synth("nonexistent", None, 1).is_err());
    }

    #[test]
    fn file_takes_precedence() {
        let dir = std::env::temp_dir().join("smx_data_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("tiny.txt"), "+1 1:1.0\n-1 2:0.5\n").unwrap();
        let ds = load_or_synth("tiny", Some(&dir), 1).unwrap();
        assert_eq!(ds.num_points(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }
}
