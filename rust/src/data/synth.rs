//! Synthetic LibSVM-like dataset generators.
//!
//! The image is offline (no LibSVM downloads), so we synthesize datasets
//! that match the paper's Table 3 shapes exactly (#datapoints, d, n, m_i)
//! and — crucially for this paper — have the *heterogeneous smoothness
//! structure* the matrix-aware methods exploit:
//!
//! * per-feature scales follow a power law, so `diag(L_i)` is highly
//!   non-uniform (ν₁ ≪ d ⇒ importance sampling wins);
//! * feature sparsity mimics one-hot-encoded categorical data (a1a/a8a/
//!   mushrooms are one-hot encodings);
//! * labels come from a planted linear model with flip noise, so the
//!   logistic problem is realistic (x* ≠ 0, interpolation does not hold).
//!
//! Real LibSVM files, when present, take precedence (see
//! [`crate::data::load_or_synth`]).

use crate::data::dataset::Dataset;
use crate::linalg::sparse::Csr;
use crate::util::rng::Rng;

/// Shape + heterogeneity knobs of a synthetic dataset.
#[derive(Clone, Debug)]
pub struct SynthSpec {
    pub name: &'static str,
    /// number of datapoints (Table 3 "# datapoints")
    pub points: usize,
    /// model dimension (Table 3 "d")
    pub d: usize,
    /// default number of workers (Table 3 "n")
    pub n: usize,
    /// expected nonzeros per row
    pub nnz_per_row: usize,
    /// power-law exponent for per-feature scales: scale_j ∝ (j+1)^{−α}
    pub scale_alpha: f64,
    /// label flip probability
    pub noise: f64,
}

/// The six paper datasets (Table 3).
pub const PAPER_DATASETS: [SynthSpec; 6] = [
    SynthSpec { name: "a1a",       points: 1_605,  d: 123,   n: 107, nnz_per_row: 14,  scale_alpha: 0.8, noise: 0.05 },
    SynthSpec { name: "mushrooms", points: 8_124,  d: 112,   n: 12,  nnz_per_row: 22,  scale_alpha: 0.7, noise: 0.02 },
    SynthSpec { name: "phishing",  points: 11_055, d: 68,    n: 11,  nnz_per_row: 30,  scale_alpha: 0.6, noise: 0.05 },
    SynthSpec { name: "madelon",   points: 2_000,  d: 500,   n: 4,   nnz_per_row: 500, scale_alpha: 1.0, noise: 0.10 },
    SynthSpec { name: "duke",      points: 44,     d: 7_129, n: 4,   nnz_per_row: 7_129, scale_alpha: 1.2, noise: 0.02 },
    SynthSpec { name: "a8a",       points: 22_696, d: 123,   n: 8,   nnz_per_row: 14,  scale_alpha: 0.8, noise: 0.05 },
];

pub fn spec_by_name(name: &str) -> Option<&'static SynthSpec> {
    PAPER_DATASETS.iter().find(|s| s.name == name)
}

/// Small spec for tests/examples.
pub fn tiny_spec() -> SynthSpec {
    SynthSpec {
        name: "tiny",
        points: 120,
        d: 20,
        n: 4,
        nnz_per_row: 6,
        scale_alpha: 0.9,
        noise: 0.05,
    }
}

/// Generate a dataset from a spec. Deterministic in (spec, seed).
pub fn generate(spec: &SynthSpec, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ fxhash(spec.name));
    let d = spec.d;
    // Per-feature scales: power law, shuffled so importance is not
    // correlated with index order.
    let mut scales: Vec<f64> = (0..d).map(|j| (j as f64 + 1.0).powf(-spec.scale_alpha)).collect();
    rng.shuffle(&mut scales);

    // Planted ground-truth weights (dense, moderate norm).
    let w_true: Vec<f64> = (0..d).map(|_| rng.normal()).collect();

    let dense_row = spec.nnz_per_row >= d;
    let mut triplets: Vec<(usize, usize, f64)> = Vec::new();
    let mut zs: Vec<f64> = Vec::with_capacity(spec.points);

    for r in 0..spec.points {
        let cols: Vec<usize> = if dense_row {
            (0..d).collect()
        } else {
            // one deterministic "bias-like" always-on feature plus random ones,
            // mimicking the one-hot structure of a1a/a8a
            let mut cols = rng.sample_indices(d, spec.nnz_per_row.min(d));
            if !cols.contains(&0) {
                cols[0] = 0;
                cols.sort_unstable();
                cols.dedup();
            }
            cols
        };
        let mut z = 0.0;
        for &c in &cols {
            // one-hot-like values in {1} scaled per feature, with a bit of
            // jitter for the dense datasets
            let v = if dense_row {
                scales[c] * rng.normal()
            } else {
                scales[c] * (1.0 + 0.1 * rng.normal())
            };
            if v != 0.0 {
                triplets.push((r, c, v));
                z += v * w_true[c];
            }
        }
        zs.push(z);
    }

    // Median-center the planted margins so label classes are balanced
    // (sparse rows with positive-ish values otherwise bias all margins to
    // one side), then draw logistic labels with flip noise.
    let mut sorted = zs.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = sorted[sorted.len() / 2];
    let spread = (sorted[(sorted.len() * 9) / 10] - sorted[sorted.len() / 10]).max(1e-9);
    let mut labels: Vec<f64> = Vec::with_capacity(spec.points);
    for &z in &zs {
        let t = 4.0 * (z - median) / spread;
        let p = 1.0 / (1.0 + (-t).exp());
        let mut y = if rng.uniform() < p { 1.0 } else { -1.0 };
        if rng.uniform() < spec.noise {
            y = -y;
        }
        labels.push(y);
    }

    let a = Csr::from_triplets(spec.points, d, triplets);
    Dataset::new(spec.name.to_string(), a, labels)
}

fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_match_table3() {
        for spec in &PAPER_DATASETS {
            // don't generate the big ones in unit tests; just check spec sanity
            assert!(spec.points / spec.n >= 1, "{}", spec.name);
        }
        let a1a = spec_by_name("a1a").unwrap();
        assert_eq!((a1a.points, a1a.d, a1a.n), (1_605, 123, 107));
        assert_eq!(a1a.points / a1a.n, 15); // m_i = 15 per Table 3
        let duke = spec_by_name("duke").unwrap();
        assert_eq!(duke.points / duke.n, 11);
    }

    #[test]
    fn generate_tiny_is_deterministic() {
        let s = tiny_spec();
        let d1 = generate(&s, 7);
        let d2 = generate(&s, 7);
        assert_eq!(d1.a.values, d2.a.values);
        assert_eq!(d1.b, d2.b);
        let d3 = generate(&s, 8);
        assert_ne!(d1.a.values, d3.a.values);
    }

    #[test]
    fn generate_has_both_labels_and_requested_shape() {
        let s = tiny_spec();
        let ds = generate(&s, 1);
        assert_eq!(ds.num_points(), 120);
        assert_eq!(ds.dim(), 20);
        let pos = ds.b.iter().filter(|&&l| l > 0.0).count();
        assert!(pos > 10 && pos < 110, "pos={pos}");
    }

    #[test]
    fn sparse_rows_have_expected_density() {
        let s = tiny_spec();
        let ds = generate(&s, 2);
        let avg_nnz = ds.a.nnz() as f64 / ds.num_points() as f64;
        assert!(avg_nnz <= s.nnz_per_row as f64 + 0.5);
        assert!(avg_nnz >= s.nnz_per_row as f64 * 0.5);
    }

    #[test]
    fn feature_scales_are_heterogeneous() {
        // ν₁ ≪ d requires a non-uniform diag; verify via column norms.
        let s = tiny_spec();
        let ds = generate(&s, 3);
        let gd = ds.a.gram_diag();
        let max = gd.iter().cloned().fold(0.0, f64::max);
        let nonzero_min = gd.iter().cloned().filter(|&v| v > 0.0).fold(f64::MAX, f64::min);
        assert!(max / nonzero_min > 3.0, "max/min = {}", max / nonzero_min);
    }

    #[test]
    fn duke_like_lowrank_shape() {
        // small analogue of duke: m << d
        let spec = SynthSpec {
            name: "duke_mini",
            points: 12,
            d: 200,
            n: 4,
            nnz_per_row: 200,
            scale_alpha: 1.2,
            noise: 0.02,
        };
        let ds = generate(&spec, 5);
        assert_eq!(ds.num_points(), 12);
        assert_eq!(ds.dim(), 200);
        assert!(ds.a.density() > 0.9);
    }
}
