//! LibSVM text format parser.
//!
//! The paper's experiments use LibSVM binary-classification datasets
//! (Chang & Lin, 2011). This image has no network access, so experiments
//! default to the synthetic generators in [`crate::data::synth`] — but any
//! genuine LibSVM file dropped under `data/` is parsed by this module and
//! used instead (`smx ... --data-dir data/`).
//!
//! Format: one example per line, `label idx:val idx:val ...` with 1-based
//! feature indices; labels are mapped to ±1.

use crate::data::dataset::Dataset;
use crate::linalg::sparse::Csr;
use anyhow::{bail, Context, Result};

/// Parse LibSVM text. `num_features` may force a dimension (otherwise the
/// max index seen defines it).
pub fn parse_libsvm(text: &str, num_features: Option<usize>) -> Result<Dataset> {
    let mut triplets: Vec<(usize, usize, f64)> = Vec::new();
    let mut labels: Vec<f64> = Vec::new();
    let mut max_idx = 0usize;

    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let row = labels.len();
        let mut parts = line.split_whitespace();
        let label_tok = parts.next().context("missing label")?;
        let label: f64 = label_tok
            .parse()
            .with_context(|| format!("line {}: bad label '{label_tok}'", lineno + 1))?;
        labels.push(normalize_label(label)?);

        let mut last_idx = 0usize;
        for tok in parts {
            let (i_str, v_str) = tok
                .split_once(':')
                .with_context(|| format!("line {}: bad feature '{tok}'", lineno + 1))?;
            let idx: usize = i_str
                .parse()
                .with_context(|| format!("line {}: bad index '{i_str}'", lineno + 1))?;
            if idx == 0 {
                bail!("line {}: libsvm indices are 1-based", lineno + 1);
            }
            if idx <= last_idx {
                bail!("line {}: indices must be strictly increasing", lineno + 1);
            }
            last_idx = idx;
            let val: f64 = v_str
                .parse()
                .with_context(|| format!("line {}: bad value '{v_str}'", lineno + 1))?;
            max_idx = max_idx.max(idx);
            if val != 0.0 {
                triplets.push((row, idx - 1, val));
            }
        }
    }

    let d = match num_features {
        Some(d) => {
            if max_idx > d {
                bail!("feature index {max_idx} exceeds forced dimension {d}");
            }
            d
        }
        None => max_idx,
    };
    let rows = labels.len();
    if rows == 0 {
        bail!("empty libsvm file");
    }
    let a = Csr::from_triplets(rows, d, triplets);
    Ok(Dataset::new("libsvm".to_string(), a, labels))
}

/// Map arbitrary binary labels to ±1 (LibSVM files use {−1,+1}, {0,1} or
/// {1,2} depending on the dataset).
fn normalize_label(l: f64) -> Result<f64> {
    match l {
        x if x == 1.0 => Ok(1.0),
        x if x == -1.0 => Ok(-1.0),
        x if x == 0.0 => Ok(-1.0),
        x if x == 2.0 => Ok(-1.0),
        other => bail!("unsupported label {other}"),
    }
}

pub fn load_file(path: &std::path::Path, num_features: Option<usize>) -> Result<Dataset> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading libsvm file {}", path.display()))?;
    let mut ds = parse_libsvm(&text, num_features)?;
    ds.name = path
        .file_stem()
        .map(|s| s.to_string_lossy().to_string())
        .unwrap_or_else(|| "libsvm".to_string());
    Ok(ds)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
+1 1:0.5 3:1.0
-1 2:2.0
+1 1:1.0 2:-1.0 3:0.25
";

    #[test]
    fn parses_basic() {
        let ds = parse_libsvm(SAMPLE, None).unwrap();
        assert_eq!(ds.a.rows, 3);
        assert_eq!(ds.a.cols, 3);
        assert_eq!(ds.b, vec![1.0, -1.0, 1.0]);
        assert_eq!(ds.a.to_dense()[(0, 0)], 0.5);
        assert_eq!(ds.a.to_dense()[(1, 1)], 2.0);
        assert_eq!(ds.a.to_dense()[(2, 2)], 0.25);
    }

    #[test]
    fn forced_dimension() {
        let ds = parse_libsvm(SAMPLE, Some(10)).unwrap();
        assert_eq!(ds.a.cols, 10);
        assert!(parse_libsvm(SAMPLE, Some(2)).is_err());
    }

    #[test]
    fn label_normalization() {
        let ds = parse_libsvm("0 1:1\n1 1:1\n2 1:1\n", None).unwrap();
        assert_eq!(ds.b, vec![-1.0, 1.0, -1.0]);
        assert!(parse_libsvm("3 1:1\n", None).is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_libsvm("+1 0:1\n", None).is_err()); // 0-based index
        assert!(parse_libsvm("+1 2:1 1:1\n", None).is_err()); // decreasing
        assert!(parse_libsvm("+1 a:b\n", None).is_err());
        assert!(parse_libsvm("", None).is_err());
        assert!(parse_libsvm("+1 1\n", None).is_err()); // no colon
    }

    #[test]
    fn skips_comments_and_blanks() {
        let ds = parse_libsvm("# header\n\n+1 1:1\n", None).unwrap();
        assert_eq!(ds.a.rows, 1);
    }
}
