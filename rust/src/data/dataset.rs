//! Dataset container: sparse feature matrix + ±1 labels, with the paper's
//! preprocessing (row normalization to ‖a_j‖ = 1/2, random reshuffle,
//! equal-chunk sharding across `n` workers; §6.1).

use crate::linalg::sparse::Csr;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub a: Csr,
    pub b: Vec<f64>, // labels in {−1, +1}
}

/// One worker's shard.
#[derive(Clone, Debug)]
pub struct Shard {
    pub worker: usize,
    pub a: Csr,
    pub b: Vec<f64>,
}

impl Dataset {
    pub fn new(name: String, a: Csr, b: Vec<f64>) -> Dataset {
        assert_eq!(a.rows, b.len(), "labels/rows mismatch");
        assert!(b.iter().all(|&l| l == 1.0 || l == -1.0), "labels must be ±1");
        Dataset { name, a, b }
    }

    pub fn num_points(&self) -> usize {
        self.a.rows
    }

    pub fn dim(&self) -> usize {
        self.a.cols
    }

    /// Normalize every datapoint to norm `target` (paper uses 1/2).
    /// Rows that are entirely zero are left untouched.
    pub fn normalize_rows(&mut self, target: f64) {
        let factors: Vec<f64> = (0..self.a.rows)
            .map(|r| {
                let n2 = self.a.row_norm2(r);
                if n2 > 0.0 {
                    target / n2.sqrt()
                } else {
                    1.0
                }
            })
            .collect();
        self.a.scale_rows(&factors);
    }

    /// Randomly reshuffle the datapoints (paper: "randomly reshuffled
    /// datasets ... split into equal chunks").
    pub fn shuffle(&mut self, rng: &mut Rng) {
        let perm = rng.permutation(self.a.rows);
        self.a = self.a.permute_rows(&perm);
        self.b = perm.iter().map(|&i| self.b[i]).collect();
    }

    /// Split into `n` equal shards of `m_i = floor(N/n)` points each;
    /// trailing remainder points are dropped so that `m_i = m_j` exactly
    /// as in the paper's setup.
    pub fn split_equal(&self, n: usize) -> Vec<Shard> {
        assert!(n >= 1);
        let m = self.a.rows / n;
        assert!(m >= 1, "not enough points ({}) for {} workers", self.a.rows, n);
        (0..n)
            .map(|i| Shard {
                worker: i,
                a: self.a.slice_rows(i * m, (i + 1) * m),
                b: self.b[i * m..(i + 1) * m].to_vec(),
            })
            .collect()
    }

    /// Full preprocessing pipeline used by all experiments.
    pub fn prepare(mut self, n: usize, seed: u64) -> (Dataset, Vec<Shard>) {
        let mut rng = Rng::new(seed);
        self.shuffle(&mut rng);
        self.normalize_rows(0.5);
        // keep only the points that survive equal sharding so the "global"
        // objective f = (1/n)Σ f_i matches the shards exactly
        let m = self.a.rows / n;
        let kept = Dataset {
            name: self.name.clone(),
            a: self.a.slice_rows(0, m * n),
            b: self.b[..m * n].to_vec(),
        };
        let shards = kept.split_equal(n);
        (kept, shards)
    }
}

impl Shard {
    pub fn num_points(&self) -> usize {
        self.a.rows
    }

    pub fn dim(&self) -> usize {
        self.a.cols
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::sparse::Csr;

    fn toy(n_rows: usize, d: usize) -> Dataset {
        let mut t = Vec::new();
        for r in 0..n_rows {
            t.push((r, r % d, 1.0 + r as f64));
            t.push((r, (r + 1) % d, 0.5));
        }
        // dedup when d small enough that the two columns collide
        t.sort_unstable_by_key(|&(r, c, _)| (r, c));
        t.dedup_by_key(|&mut (r, c, _)| (r, c));
        let b = (0..n_rows).map(|r| if r % 2 == 0 { 1.0 } else { -1.0 }).collect();
        Dataset::new("toy".into(), Csr::from_triplets(n_rows, d, t), b)
    }

    #[test]
    fn normalize_rows_to_half() {
        let mut ds = toy(6, 5);
        ds.normalize_rows(0.5);
        for r in 0..6 {
            assert!((ds.a.row_norm2(r).sqrt() - 0.5).abs() < 1e-12);
        }
    }

    #[test]
    fn shuffle_preserves_pairing() {
        let mut ds = toy(10, 7);
        let before: Vec<(f64, f64)> = (0..10)
            .map(|r| (ds.a.row_norm2(r), ds.b[r]))
            .collect();
        let mut rng = Rng::new(3);
        ds.shuffle(&mut rng);
        let mut after: Vec<(f64, f64)> = (0..10)
            .map(|r| (ds.a.row_norm2(r), ds.b[r]))
            .collect();
        let mut b_sorted = before.clone();
        b_sorted.sort_by(|x, y| x.partial_cmp(y).unwrap());
        after.sort_by(|x, y| x.partial_cmp(y).unwrap());
        assert_eq!(b_sorted, after);
    }

    #[test]
    fn split_equal_shapes() {
        let ds = toy(10, 4);
        let shards = ds.split_equal(3);
        assert_eq!(shards.len(), 3);
        for s in &shards {
            assert_eq!(s.num_points(), 3);
            assert_eq!(s.dim(), 4);
        }
    }

    #[test]
    fn prepare_consistency() {
        let ds = toy(11, 4);
        let (global, shards) = ds.prepare(3, 42);
        assert_eq!(global.num_points(), 9);
        let total: usize = shards.iter().map(|s| s.num_points()).sum();
        assert_eq!(total, 9);
        // rows normalized
        for r in 0..9 {
            assert!((global.a.row_norm2(r).sqrt() - 0.5).abs() < 1e-12);
        }
        // shard rows equal global rows
        let g = global.a.to_dense();
        let mut row = 0;
        for s in &shards {
            let sd = s.a.to_dense();
            for r in 0..s.num_points() {
                for c in 0..4 {
                    assert_eq!(sd[(r, c)], g[(row, c)]);
                }
                assert_eq!(s.b[r], global.b[row]);
                row += 1;
            }
        }
    }

    #[test]
    #[should_panic]
    fn bad_labels_rejected() {
        let a = Csr::from_triplets(1, 1, vec![(0, 0, 1.0)]);
        Dataset::new("bad".into(), a, vec![0.5]);
    }
}
