//! Figure drivers — one per paper figure (see DESIGN.md §3).
//!
//! Each driver writes long-format CSV curves under `out_dir` and prints a
//! compact summary comparing the *shape* of the result against the
//! paper's qualitative claims (who wins, by how much).
//!
//! Sweep cells (fig1's three variants, fig2's six methods, fig3/4's
//! τ-grid) run concurrently on the [`pool`](crate::experiments::pool)
//! executor via `runner::run_variants` — deterministic per-cell seeds
//! keep the CSVs bitwise identical to a sequential run.

use crate::compress::lowerbound;
use crate::config::ExperimentConfig;
use crate::experiments::runner::{self, Variant};
use crate::sampling::SamplingKind;
use crate::util::rng::Rng;
use anyhow::Result;

/// Figure 1: DIANA+ importance vs DIANA+ uniform vs DIANA uniform (τ = 1).
pub fn fig1(cfg: &ExperimentConfig) -> Result<()> {
    let mut c = cfg.clone();
    c.methods = vec!["diana+".into(), "diana".into()];
    let prep = runner::prepare(&c)?;
    let variants = vec![
        Variant::new("diana+-importance", "diana+", SamplingKind::ImportanceDiana, c.tau),
        Variant::new("diana+-uniform", "diana+", SamplingKind::Uniform, c.tau),
        Variant::new("diana-uniform", "diana", SamplingKind::Uniform, c.tau),
    ];
    let results = runner::run_variants(&prep, &c, &variants, &format!("fig1_{}", c.dataset))?;
    summarize_ordering(
        &c.dataset,
        &results,
        1e-6,
        &["diana+-importance", "diana+-uniform", "diana-uniform"],
    );
    Ok(())
}

/// Figure 2: the 3 originals vs the 3 "+" methods, uniform τ = 1, started
/// near the optimum.
pub fn fig2(cfg: &ExperimentConfig) -> Result<()> {
    let mut c = cfg.clone();
    c.start_near_opt = true;
    c.methods = vec![
        "dcgd".into(),
        "dcgd+".into(),
        "diana".into(),
        "diana+".into(),
        "adiana".into(),
        "adiana+".into(),
    ];
    let prep = runner::prepare(&c)?;
    let variants: Vec<Variant> = c
        .methods
        .iter()
        .map(|m| {
            let method = match m.as_str() {
                "dcgd" => "dcgd",
                "dcgd+" => "dcgd+",
                "diana" => "diana",
                "diana+" => "diana+",
                "adiana" => "adiana",
                "adiana+" => "adiana+",
                _ => unreachable!(),
            };
            Variant::new(m.clone(), method, SamplingKind::Uniform, c.tau)
        })
        .collect();
    let results = runner::run_variants(&prep, &c, &variants, &format!("fig2_{}", c.dataset))?;
    // paper claim (i): each + method beats its baseline
    for (plus, base) in [("dcgd+", "dcgd"), ("diana+", "diana"), ("adiana+", "adiana")] {
        compare_pair(&c.dataset, &results, plus, base);
    }
    Ok(())
}

/// Figures 3 & 4: τ-sweep for DIANA+ (importance and uniform sampling).
/// One CSV serves both figures (Figure 4 re-plots vs `coords_up`).
pub fn fig34(cfg: &ExperimentConfig) -> Result<()> {
    let mut c = cfg.clone();
    c.methods = vec!["diana+".into()];
    let prep = runner::prepare(&c)?;
    let d = prep.sm.dim as f64;
    let mut taus: Vec<f64> = vec![1.0, 2.0, 4.0, 8.0]
        .into_iter()
        .filter(|&t| t < d)
        .collect();
    for frac in [d / 16.0, d / 4.0, d] {
        let t = frac.max(1.0).floor();
        if !taus.iter().any(|&x| (x - t).abs() < 0.5) {
            taus.push(t);
        }
    }
    taus.sort_by(|a, b| a.partial_cmp(b).unwrap());

    let mut variants = Vec::new();
    for &tau in &taus {
        for (skind, sname) in [
            (SamplingKind::ImportanceDiana, "importance"),
            (SamplingKind::Uniform, "uniform"),
        ] {
            variants.push(Variant::new(
                format!("tau{}-{}", tau as usize, sname),
                "diana+",
                skind,
                tau,
            ));
        }
    }
    let results = runner::run_variants(&prep, &c, &variants, &format!("fig34_{}", c.dataset))?;

    // paper claim: sparsification hurts iteration complexity only below a
    // threshold; report rounds-to-target per τ
    println!("\n[fig3/4 {}] rounds (coords) to residual ≤ {:.0e}:", c.dataset, 1e-6);
    for (label, r) in &results {
        match (r.rounds_to(1e-6), r.coords_to(1e-6)) {
            (Some(it), Some(cc)) => println!("  {label:<22} {it:>8} rounds  {cc:>12} coords"),
            _ => println!("  {label:<22} (target not reached in {} rounds)", r.rounds_run),
        }
    }
    Ok(())
}

/// Quantization-vs-sparsification sweep (the sequel paper's comparison,
/// arXiv:2106.03524 §experiments): for DCGD and DIANA, race the
/// smoothness-aware quantizer (diag and root weightings) against the
/// uniform sketch and the matrix-aware sparsifier (which runs via the
/// corresponding `+` method), and report *measured* uplink bytes to a
/// target residual — bytes, not coordinates, are the currency that makes
/// a 4-level quantized coordinate comparable to an f64 sparse one.
pub fn fig_quant(cfg: &ExperimentConfig) -> Result<()> {
    use crate::compress::{CompressorKind, QuantWeighting};

    let mut c = cfg.clone();
    c.methods = vec!["dcgd".into(), "dcgd+".into(), "diana".into(), "diana+".into()];
    let prep = runner::prepare(&c)?;
    let s = c.sa_levels.max(1);
    let mut variants = Vec::new();
    for (base, plus) in [("dcgd", "dcgd+"), ("diana", "diana+")] {
        variants.push(
            Variant::new(format!("{base}-sketch"), base, SamplingKind::Uniform, c.tau)
                .with_compressor(CompressorKind::Sketch),
        );
        variants.push(
            Variant::new(format!("{plus}-matrix-aware"), plus, SamplingKind::Uniform, c.tau)
                .with_compressor(CompressorKind::Default),
        );
        for (w, wname) in [(QuantWeighting::Diag, "diag"), (QuantWeighting::Root, "root")] {
            variants.push(
                Variant::new(format!("{base}-sa-quant-{wname}-s{s}"), base, SamplingKind::Uniform, c.tau)
                    .with_sa_quant(s, w),
            );
        }
    }
    let results =
        runner::run_variants(&prep, &c, &variants, &format!("fig_quant_{}", c.dataset))?;

    // bytes-to-ε table: what the sequel paper's comparison turns on
    let eps = 1e-6;
    println!(
        "\n[quant {}] measured uplink bytes (and rounds) to residual ≤ {eps:.0e}:",
        c.dataset
    );
    for (label, r) in &results {
        match (r.bytes_to(eps), r.rounds_to(eps)) {
            (Some(by), Some(it)) => {
                println!("  {label:<28} {by:>14} bytes  {it:>8} rounds")
            }
            _ => println!(
                "  {label:<28} (target not reached in {} rounds; final {:.3e})",
                r.rounds_run,
                r.final_residual()
            ),
        }
    }
    Ok(())
}

/// Figure 5: variance-vs-communication trade-off for linear compressors
/// (Appendix C): random q-sparsification and greedy top-k on Gaussian
/// vectors, against both lower bounds.
pub fn fig5(cfg: &ExperimentConfig) -> Result<()> {
    let d = 1000;
    let mut rng = Rng::new(cfg.seed);
    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut violations = 0usize;

    for rep in 0..8 {
        for &q in &[0.02, 0.05, 0.1, 0.2, 0.3, 0.5, 0.7, 0.9] {
            let p = lowerbound::random_sparsification_point(d, q, &mut rng);
            if p.linear_lb < 1.0 - 0.05 {
                violations += 1;
            }
            rows.push(point_row(rep, &p));
        }
        for &k in &[10usize, 30, 100, 200, 300, 500, 700, 900] {
            let p = lowerbound::topk_point(d, k, &mut rng);
            rows.push(point_row(rep, &p));
        }
    }
    let path = cfg.out_dir.join("fig5.csv");
    crate::util::write_csv(
        &path,
        &["rep", "scheme", "param", "alpha", "bits", "beta", "general_up", "linear_lb"],
        &rows,
    )?;
    println!(
        "[fig5] wrote {} ({} points, {} linear-bound violations for the linear scheme — expect 0)",
        path.display(),
        rows.len(),
        violations
    );
    Ok(())
}

fn point_row(rep: usize, p: &lowerbound::TradeoffPoint) -> Vec<String> {
    vec![
        rep.to_string(),
        p.scheme.to_string(),
        format!("{:.4}", p.param),
        format!("{:.6}", p.alpha),
        format!("{:.1}", p.bits),
        format!("{:.6}", p.beta),
        format!("{:.6}", p.general_up),
        format!("{:.6}", p.linear_lb),
    ]
}

/// Print "A beats B" style summary using rounds-to-threshold (falls back
/// to final residual if neither reaches it).
fn compare_pair(ds: &str, results: &[(String, crate::coordinator::RunResult)], a: &str, b: &str) {
    let ra = results.iter().find(|(l, _)| l == a);
    let rb = results.iter().find(|(l, _)| l == b);
    if let (Some((_, ra)), Some((_, rb))) = (ra, rb) {
        let eps = 1e-6;
        match (ra.rounds_to(eps), rb.rounds_to(eps)) {
            (Some(ia), Some(ib)) => println!(
                "[{ds}] {a} vs {b}: {ia} vs {ib} rounds to {eps:.0e} ({}x)",
                ib as f64 / ia as f64
            ),
            _ => println!(
                "[{ds}] {a} vs {b}: final residual {:.3e} vs {:.3e}",
                ra.final_residual(),
                rb.final_residual()
            ),
        }
    }
}

fn summarize_ordering(
    ds: &str,
    results: &[(String, crate::coordinator::RunResult)],
    eps: f64,
    expected_order: &[&str],
) {
    println!("\n[{ds}] rounds to residual ≤ {eps:.0e} (expected fastest → slowest: {expected_order:?}):");
    for (label, r) in results {
        match r.rounds_to(eps) {
            Some(it) => println!("  {label:<22} {it:>8}"),
            None => println!(
                "  {label:<22} not reached (final {:.3e})",
                r.final_residual()
            ),
        }
    }
}
