//! Parallel sweep executor: a scoped-thread work queue that runs the
//! independent cells of a figure sweep — one (method, τ, sampling, seed)
//! combination each — concurrently across all cores.
//!
//! Design constraints:
//!
//! * **Determinism.** A cell's RNG seed is a pure function of the
//!   experiment config and the *cell index* — never of thread identity or
//!   scheduling order — and results are returned in input order. The
//!   parallel executor is therefore bitwise identical to the sequential
//!   fallback (`threads = 1`), asserted in the tests below and exercised
//!   end-to-end by `runner::run_variants` (which keeps the shared
//!   `cfg.seed` for every cell, preserving common random numbers across
//!   variants; [`cell_seed`] is for sweeps that want distinct streams,
//!   e.g. seed-replicate grids).
//! * **No dependencies.** Plain `std::thread::scope` + an atomic cursor;
//!   the image has no rayon/crossbeam.
//! * **Work stealing lite.** Cells are claimed from a shared atomic
//!   counter, so uneven cell durations (e.g. τ=1 vs τ=d in a fig3/4
//!   sweep) balance automatically.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use when the config says "auto".
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Deterministic per-cell seed: mixes the experiment base seed with the
/// cell index through SplitMix64. Independent of execution order, so the
/// sequential and parallel paths see identical streams.
pub fn cell_seed(base: u64, idx: u64) -> u64 {
    let mut sm = crate::util::rng::SplitMix64::new(
        base ^ idx.wrapping_mul(0x9E3779B97F4A7C15).rotate_left(17),
    );
    sm.next_u64()
}

/// Run `n` cells `f(0..n)` on up to `threads` threads and return the
/// results in input order. `threads <= 1` (or `n <= 1`) runs inline on
/// the calling thread — the sequential reference path.
///
/// Panics in a cell propagate after all threads join (via
/// `std::thread::scope`), so a failing sweep cell fails the sweep.
pub fn run_cells<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }

    let next = AtomicUsize::new(0);
    let results: Mutex<Vec<Option<T>>> = Mutex::new((0..n).map(|_| None).collect());
    let f = &f;
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let out = f(i);
                results.lock().unwrap()[i] = Some(out);
            });
        }
    });
    results
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|o| o.expect("every cell completes"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// A cell whose output depends only on (base seed, index).
    fn cell(base: u64, i: usize) -> Vec<u64> {
        let mut rng = Rng::new(cell_seed(base, i as u64));
        (0..16).map(|_| rng.next_u64()).collect()
    }

    #[test]
    fn parallel_matches_sequential_bitwise() {
        let n = 37;
        let seq = run_cells(n, 1, |i| cell(42, i));
        for threads in [2, 4, 8] {
            let par = run_cells(n, threads, |i| cell(42, i));
            assert_eq!(seq, par, "threads={threads} diverged from sequential");
        }
    }

    #[test]
    fn results_in_input_order() {
        let out = run_cells(100, 4, |i| i * i);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn cell_seeds_are_distinct() {
        let mut seen = std::collections::HashSet::new();
        for i in 0..1000u64 {
            assert!(seen.insert(cell_seed(7, i)), "seed collision at cell {i}");
        }
        // different base seeds give different streams
        assert_ne!(cell_seed(1, 0), cell_seed(2, 0));
    }

    #[test]
    fn handles_empty_and_single() {
        assert!(run_cells(0, 8, |i| i).is_empty());
        assert_eq!(run_cells(1, 8, |i| i + 1), vec![1]);
    }

    #[test]
    fn more_threads_than_cells_is_fine() {
        assert_eq!(run_cells(3, 64, |i| i), vec![0, 1, 2]);
    }
}
