//! Shared experiment runner: dataset preparation, x* solving, method
//! construction and execution, CSV output.

use crate::compress::{CompressorKind, QuantWeighting};
use crate::config::ExperimentConfig;
use crate::coordinator::{DriverKind, EngineFactory, RunConfig, RunResult, Session};
use crate::data::{self, Dataset, Shard};
use crate::methods::{solve, MethodSpec};
use crate::objective::{Problem, Smoothness};
use crate::runtime::artifact::Manifest;
use crate::runtime::native::NativeEngine;
use crate::runtime::{EngineKind, GradEngine};
use crate::sampling::SamplingKind;
use crate::util::rng::Rng;
use anyhow::{Context, Result};
use std::sync::{Arc, OnceLock};

/// A fully prepared problem instance, reused across methods of one figure.
pub struct Prepared {
    pub dataset: Dataset,
    pub shards: Vec<Shard>,
    pub sm: Smoothness,
    pub problem: Problem,
    pub x_star: Vec<f64>,
    pub f_star: f64,
    /// lazily loaded PJRT artifact manifest, cached for the whole sweep
    /// (it used to be re-parsed from disk inside every cell)
    manifest: OnceLock<Arc<Manifest>>,
}

pub fn prepare(cfg: &ExperimentConfig) -> Result<Prepared> {
    prepare_with(cfg, cfg.methods.iter().any(|m| m == "diana++"))
}

pub fn prepare_with(cfg: &ExperimentConfig, need_global: bool) -> Result<Prepared> {
    let n = cfg.effective_workers();
    let raw = data::load_or_synth(&cfg.dataset, cfg.data_dir.as_deref(), cfg.seed)
        .with_context(|| format!("loading dataset {}", cfg.dataset))?;
    let (global, shards) = raw.prepare(n, cfg.seed);
    let mut sm = Smoothness::build(&shards, cfg.mu);
    if need_global {
        sm = sm.with_global(&global.a);
    }
    let problem = Problem::from_shards(&shards, cfg.mu);
    let sol = solve::solve_opt(&problem, &sm, 1e-14, 50_000);
    crate::info!(
        "runner",
        "prepared {}: N={} d={} n={} m_i={} | L={:.4e} L_max={:.4e} ‖∇f(x*)‖={:.2e}",
        cfg.dataset,
        global.num_points(),
        global.dim(),
        n,
        shards[0].num_points(),
        sm.l,
        sm.l_max,
        sol.grad_norm
    );
    Ok(Prepared {
        dataset: global,
        shards,
        sm,
        problem,
        x_star: sol.x_star,
        f_star: sol.f_star,
        manifest: OnceLock::new(),
    })
}

impl Prepared {
    /// Starting point: zero, or a small perturbation of x* (Figure 2).
    pub fn x0(&self, cfg: &ExperimentConfig) -> Vec<f64> {
        if !cfg.start_near_opt {
            return vec![0.0; self.sm.dim];
        }
        let mut rng = Rng::new(cfg.seed ^ 0x57A7);
        let scale = 1e-3 * (crate::linalg::vector::norm(&self.x_star) + 1.0)
            / (self.sm.dim as f64).sqrt();
        self.x_star
            .iter()
            .map(|&v| v + scale * rng.normal())
            .collect()
    }

    pub fn native_engines(&self, mu: f64) -> Vec<Box<dyn GradEngine>> {
        self.shards
            .iter()
            .map(|s| Box::new(NativeEngine::from_shard(s, mu)) as Box<dyn GradEngine>)
            .collect()
    }

    /// The PJRT artifact manifest, loaded from disk once per `Prepared`
    /// and shared by every sweep cell thereafter.
    pub fn pjrt_manifest(&self) -> Result<Arc<Manifest>> {
        if let Some(m) = self.manifest.get() {
            return Ok(m.clone());
        }
        let loaded = Arc::new(Manifest::load(&crate::runtime::artifact::default_dir())?);
        // a concurrent cell may have won the race; either value is the
        // same on-disk manifest
        Ok(self.manifest.get_or_init(|| loaded).clone())
    }

    /// Engine factory for the given engine kind — what
    /// [`Session`](crate::coordinator::Session) installs when a prepared
    /// problem is supplied without explicit engines.
    pub fn engine_factory(&self, engine: EngineKind, mu: f64) -> Result<EngineFactory> {
        match engine {
            EngineKind::Native => {
                let shards = self.shards.clone();
                Ok(Arc::new(move |i| {
                    Box::new(NativeEngine::from_shard(&shards[i], mu)) as Box<dyn GradEngine>
                }))
            }
            EngineKind::Pjrt => {
                let manifest = self.pjrt_manifest()?;
                let shards = self.shards.clone();
                Ok(Arc::new(move |i| {
                    Box::new(
                        crate::runtime::pjrt::PjrtEngine::from_shard(&manifest, &shards[i], mu)
                            .expect("building PJRT engine"),
                    ) as Box<dyn GradEngine>
                }))
            }
        }
    }
}

/// Run one method on a prepared problem. `sampling`/`tau` override the
/// config (figures sweep them).
pub fn run_one(
    prep: &Prepared,
    cfg: &ExperimentConfig,
    method_name: &str,
    sampling: SamplingKind,
    tau: f64,
) -> Result<RunResult> {
    run_one_seeded(prep, cfg, method_name, sampling, tau, cfg.seed)
}

/// Translate an experiment config into a coordinator [`RunConfig`].
///
/// `float_bits` comes from
/// [`WireConfig::effective_float_bits`](crate::config::WireConfig::effective_float_bits)
/// — the single home of the payload→bits derivation rules.
pub fn run_config(cfg: &ExperimentConfig) -> RunConfig {
    RunConfig {
        max_rounds: cfg.max_rounds,
        target_residual: cfg.target_residual,
        record_every: cfg.record_every,
        seed: cfg.seed,
        float_bits: cfg.wire.effective_float_bits(),
        payload: cfg.wire.payload,
        pin: cfg.pin,
        checkpoint_every: cfg.checkpoint_every,
        // validate() already proved the spec parses and τ ≤ n
        participation: cfg.wire.participation_tau().ok().flatten(),
    }
}

/// [`run_one`] with an explicit coordinator seed — for sweeps that want
/// distinct streams per cell (e.g. seed-replicate grids via
/// [`pool::cell_seed`](crate::experiments::pool::cell_seed)); the figure
/// sweeps keep `cfg.seed` for every cell. One [`Session`] per cell: the
/// driver comes from `cfg.driver` (auto → sim for native, threaded for
/// PJRT), the engines from the prepared problem per `cfg.engine`.
pub fn run_one_seeded(
    prep: &Prepared,
    cfg: &ExperimentConfig,
    method_name: &str,
    sampling: SamplingKind,
    tau: f64,
    seed: u64,
) -> Result<RunResult> {
    let mut spec = MethodSpec::new(method_name, tau, sampling, cfg.mu, prep.x0(cfg));
    spec.practical_adiana = cfg.practical_adiana;
    spec.compressor = cfg.compressor;
    spec.sa_levels = cfg.sa_levels;
    spec.sa_weighting = cfg.sa_weighting;
    let run_cfg = RunConfig {
        seed,
        ..run_config(cfg)
    };
    Session::from_config(cfg)
        .prepared(prep)
        .method(spec)
        .run_config(run_cfg)
        .run()
}

/// A labeled variant in a figure sweep.
pub struct Variant {
    pub label: String,
    pub method: &'static str,
    pub sampling: SamplingKind,
    pub tau: f64,
    /// uplink compressor override for this cell (None ⇒ `cfg.compressor`)
    pub compressor: Option<CompressorKind>,
    /// `sa-quant` level count override (None ⇒ `cfg.sa_levels`)
    pub sa_levels: Option<u32>,
    /// `sa-quant` weighting override (None ⇒ `cfg.sa_weighting`)
    pub sa_weighting: Option<QuantWeighting>,
}

impl Variant {
    pub fn new(
        label: impl Into<String>,
        method: &'static str,
        sampling: SamplingKind,
        tau: f64,
    ) -> Variant {
        Variant {
            label: label.into(),
            method,
            sampling,
            tau,
            compressor: None,
            sa_levels: None,
            sa_weighting: None,
        }
    }

    /// Pin this cell to a compressor family (figures compare families
    /// side by side within one sweep CSV).
    pub fn with_compressor(mut self, kind: CompressorKind) -> Variant {
        self.compressor = Some(kind);
        self
    }

    pub fn with_sa_quant(mut self, levels: u32, weighting: QuantWeighting) -> Variant {
        self.compressor = Some(CompressorKind::SaQuant);
        self.sa_levels = Some(levels);
        self.sa_weighting = Some(weighting);
        self
    }

    /// The experiment config this cell actually runs under: the shared
    /// sweep config with this variant's compressor overrides applied.
    pub fn cell_config(&self, cfg: &ExperimentConfig) -> ExperimentConfig {
        let mut c = cfg.clone();
        if let Some(k) = self.compressor {
            c.compressor = k;
        }
        if let Some(s) = self.sa_levels {
            c.sa_levels = s;
        }
        if let Some(w) = self.sa_weighting {
            c.sa_weighting = w;
        }
        c
    }
}

/// Run a set of variants and write one CSV (long format with a `label`
/// column) to `out_dir/name.csv`. Returns (label, result) pairs.
///
/// Independent cells run on the [`pool`](crate::experiments::pool)
/// executor (all cores by default; `cfg.jobs = 1` forces sequential).
/// Every cell keeps the experiment seed `cfg.seed` (cells own disjoint
/// RNGs, so results are bitwise independent of the thread count — and
/// identical to the pre-pool sequential sweeps and to `run_one`; the
/// shared seed also gives common random numbers across variants, which
/// the fig1-style paired comparisons rely on). Asserted in the tests
/// below.
pub fn run_variants(
    prep: &Prepared,
    cfg: &ExperimentConfig,
    variants: &[Variant],
    out_name: &str,
) -> Result<Vec<(String, RunResult)>> {
    // Threaded/distributed cells spawn one OS thread per worker (the
    // PJRT engine path always does); keep such cells sequential so the
    // sweep does not oversubscribe the machine.
    let jobs = match (cfg.engine, cfg.driver) {
        (EngineKind::Native, DriverKind::Auto | DriverKind::Sim) => cfg.effective_jobs(),
        _ => 1,
    };
    crate::info!(
        "runner",
        "  sweep: {} cells on {} thread(s)",
        variants.len(),
        jobs.min(variants.len().max(1))
    );
    let cells: Vec<Result<RunResult>> =
        crate::experiments::pool::run_cells(variants.len(), jobs, |i| {
            let v = &variants[i];
            run_one(prep, &v.cell_config(cfg), v.method, v.sampling, v.tau)
        });
    let mut results = Vec::new();
    for (v, r) in variants.iter().zip(cells) {
        let r = r?;
        crate::info!(
            "runner",
            "  {} ({}): {} rounds, final residual {:.3e}",
            v.label,
            v.method,
            r.rounds_run,
            r.final_residual()
        );
        results.push((v.label.clone(), r));
    }

    let mut rows: Vec<Vec<String>> = Vec::new();
    for (label, r) in &results {
        for rec in &r.records {
            rows.push(vec![
                label.clone(),
                rec.round.to_string(),
                format!("{:.6e}", rec.residual),
                rec.coords_up.to_string(),
                rec.bits_up.to_string(),
                rec.coords_down.to_string(),
                rec.bytes_up.to_string(),
                rec.bytes_down.to_string(),
                format!("{:.6}", rec.wall_secs),
            ]);
        }
    }
    let path = cfg.out_dir.join(format!("{out_name}.csv"));
    crate::util::write_csv(
        &path,
        &[
            "label",
            "round",
            "residual",
            "coords_up",
            "bits_up",
            "coords_down",
            "bytes_up",
            "bytes_down",
            "wall_secs",
        ],
        &rows,
    )?;
    crate::info!("runner", "wrote {}", path.display());
    Ok(results)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ExperimentConfig {
        ExperimentConfig {
            dataset: "tiny".into(),
            workers: 4,
            max_rounds: 300,
            target_residual: 1e-6,
            record_every: 10,
            out_dir: std::env::temp_dir().join("smx_runner_test"),
            ..Default::default()
        }
    }

    #[test]
    fn float_bits_derived_from_wire_payload() {
        use crate::wire::Payload;
        let mut cfg = tiny_cfg();
        assert_eq!(run_config(&cfg).float_bits, 64);
        cfg.wire.payload = Payload::F32;
        assert_eq!(run_config(&cfg).float_bits, 32);
        assert_eq!(run_config(&cfg).payload, Payload::F32);
        cfg.wire.payload = Payload::Q8;
        assert_eq!(run_config(&cfg).float_bits, 8);
        // explicit override wins over the payload width
        cfg.wire.float_bits = Some(32);
        assert_eq!(run_config(&cfg).float_bits, 32);
        // checkpoint cadence flows through
        cfg.checkpoint_every = 7;
        assert_eq!(run_config(&cfg).checkpoint_every, 7);
    }

    #[test]
    fn driver_distributed_matches_sim_through_run_one() {
        // `--driver distributed` sends every sweep cell through the full
        // wire codec over loopback; under the f64 payload the result must
        // stay bitwise identical to the sim driver.
        let mut cfg = tiny_cfg();
        cfg.target_residual = 0.0;
        cfg.max_rounds = 25;
        let prep = prepare(&cfg).unwrap();
        let r_sim = run_one(&prep, &cfg, "diana+", SamplingKind::Uniform, 2.0).unwrap();

        cfg.driver = DriverKind::Distributed;
        cfg.wire.workers = 2;
        let r_dist = run_one(&prep, &cfg, "diana+", SamplingKind::Uniform, 2.0).unwrap();
        let bits = |xs: &[f64]| xs.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&r_sim.final_x), bits(&r_dist.final_x));
        assert_eq!(
            r_sim.records.last().unwrap().coords_up,
            r_dist.records.last().unwrap().coords_up
        );
    }

    #[test]
    fn prepare_and_run_diana_plus() {
        let cfg = tiny_cfg();
        let prep = prepare(&cfg).unwrap();
        assert!(prep.f_star.is_finite());
        let r = run_one(&prep, &cfg, "diana+", SamplingKind::ImportanceDiana, 2.0).unwrap();
        assert!(r.final_residual() < 1.0, "no progress");
    }

    #[test]
    fn start_near_opt_starts_close() {
        let mut cfg = tiny_cfg();
        cfg.start_near_opt = true;
        let prep = prepare(&cfg).unwrap();
        let x0 = prep.x0(&cfg);
        let rel = crate::linalg::vector::dist2(&x0, &prep.x_star).sqrt()
            / crate::linalg::vector::norm(&prep.x_star).max(1e-9);
        assert!(rel < 0.1, "x0 too far: rel={rel}");
    }

    #[test]
    fn parallel_sweep_bitwise_identical_to_sequential() {
        let prep = prepare(&tiny_cfg()).unwrap();
        let cells: [(&'static str, f64); 4] =
            [("dcgd+", 1.0), ("diana+", 2.0), ("diana+", 4.0), ("dcgd", 1.0)];
        let variants: Vec<Variant> = cells
            .iter()
            .enumerate()
            .map(|(i, &(method, tau))| {
                Variant::new(format!("v{i}"), method, SamplingKind::Uniform, tau)
            })
            .collect();

        let mut cfg_seq = tiny_cfg();
        cfg_seq.jobs = 1;
        cfg_seq.out_dir = std::env::temp_dir().join("smx_pool_seq");
        let seq = run_variants(&prep, &cfg_seq, &variants, "seq").unwrap();

        let mut cfg_par = tiny_cfg();
        cfg_par.jobs = 4;
        cfg_par.out_dir = std::env::temp_dir().join("smx_pool_par");
        let par = run_variants(&prep, &cfg_par, &variants, "par").unwrap();

        assert_eq!(seq.len(), par.len());
        for ((ls, rs), (lp, rp)) in seq.iter().zip(&par) {
            assert_eq!(ls, lp, "label order changed");
            assert_eq!(rs.final_x, rp.final_x, "{ls}: trajectories diverged");
            assert_eq!(
                rs.records.last().unwrap().coords_up,
                rp.records.last().unwrap().coords_up,
                "{ls}: accounting diverged"
            );
        }
        std::fs::remove_dir_all(&cfg_seq.out_dir).ok();
        std::fs::remove_dir_all(&cfg_par.out_dir).ok();
    }

    #[test]
    fn run_variants_writes_csv() {
        let cfg = tiny_cfg();
        let prep = prepare(&cfg).unwrap();
        let variants = vec![Variant::new(
            "dcgd-uniform",
            "dcgd",
            SamplingKind::Uniform,
            1.0,
        )];
        let results = run_variants(&prep, &cfg, &variants, "test_out").unwrap();
        assert_eq!(results.len(), 1);
        let csv = std::fs::read_to_string(cfg.out_dir.join("test_out.csv")).unwrap();
        assert!(csv.starts_with("label,round,residual"));
        assert!(csv.lines().count() > 2);
        std::fs::remove_dir_all(&cfg.out_dir).ok();
    }
}
