//! Table drivers: Table 2 (complexity constants + predicted iteration
//! complexities), Table 3 (dataset statistics), Table 6 (single-node
//! complexities).

use crate::config::ExperimentConfig;
use crate::experiments::runner;
use crate::methods::single::eso_lambda;
use crate::objective::smoothness::build_local;
use crate::sampling::SamplingKind;
use anyhow::Result;

/// Table 2: per-dataset constants and the predicted iteration complexities
/// of all six methods (original vs "+"), with τ = d/n as in the paper's
/// ω = 𝒪(n) regime, plus the ν/ν₁/ν₂ distribution parameters of eq. (14).
pub fn table2(cfg: &ExperimentConfig, datasets: &[String]) -> Result<Vec<Vec<String>>> {
    let header = [
        "dataset", "n", "d", "mu", "L", "L_max", "nu", "nu1", "nu2", "omega", "omega_max_imp",
        "tilde_l_max_uni", "tilde_l_max_imp", "k_dcgd", "k_dcgd+", "k_diana", "k_diana+",
        "k_adiana", "k_adiana+", "speedup_dcgd", "speedup_diana",
    ];
    println!("{}", header.join(","));
    let mut rows = Vec::new();

    for ds in datasets {
        let mut c = cfg.clone();
        c.dataset = ds.clone();
        let prep = runner::prepare_with(&c, false)?;
        let sm = &prep.sm;
        let n = sm.n() as f64;
        let d = sm.dim as f64;
        let mu = sm.mu;
        // paper regime: τ = d/n ⇒ ω = d/τ − 1 = n − 1
        let tau = (d / n).max(1.0);
        let omega = d / tau - 1.0;

        let mut tilde_uni: f64 = 0.0;
        let mut tilde_imp: f64 = 0.0;
        let mut omega_imp: f64 = 0.0;
        for loc in &sm.locals {
            let s_uni = SamplingKind::Uniform.build(&loc.diag, tau, mu, sm.n());
            let s_imp = SamplingKind::ImportanceDiana.build(&loc.diag, tau, mu, sm.n());
            tilde_uni = tilde_uni.max(s_uni.tilde_l(&loc.diag));
            tilde_imp = tilde_imp.max(s_imp.tilde_l(&loc.diag));
            omega_imp = omega_imp.max(s_imp.omega());
        }

        // predicted iteration complexities (Table 2 rows, log factors dropped)
        let k_dcgd = sm.l / mu + omega * sm.l_max / (n * mu);
        let k_dcgd_p = sm.l / mu + tilde_imp / (n * mu);
        let k_diana = omega + sm.l_max / mu + omega * sm.l_max / (n * mu);
        let k_diana_p = omega_imp + sm.l / mu + tilde_imp / (n * mu);
        let k_adiana = adiana_complexity(n, mu, sm.l, omega, omega * sm.l_max);
        let k_adiana_p = adiana_complexity(n, mu, sm.l, omega_imp, tilde_imp);

        let row = vec![
            ds.clone(),
            format!("{}", sm.n()),
            format!("{}", sm.dim),
            format!("{mu:.0e}"),
            format!("{:.4e}", sm.l),
            format!("{:.4e}", sm.l_max),
            format!("{:.2}", sm.nu()),
            format!("{:.2}", sm.nu_s(1.0)),
            format!("{:.2}", sm.nu_s(2.0)),
            format!("{omega:.1}"),
            format!("{omega_imp:.1}"),
            format!("{tilde_uni:.4e}"),
            format!("{tilde_imp:.4e}"),
            format!("{k_dcgd:.3e}"),
            format!("{k_dcgd_p:.3e}"),
            format!("{k_diana:.3e}"),
            format!("{k_diana_p:.3e}"),
            format!("{k_adiana:.3e}"),
            format!("{k_adiana_p:.3e}"),
            format!("{:.2}", k_dcgd / k_dcgd_p),
            format!("{:.2}", k_diana / k_diana_p),
        ];
        println!("{}", row.join(","));
        rows.push(row);
    }
    crate::util::write_csv(
        &cfg.out_dir.join("table2.csv"),
        &header,
        &rows,
    )?;
    Ok(rows)
}

/// Predicted ADIANA complexity (eq. 13 shape, constants dropped).
fn adiana_complexity(n: f64, mu: f64, l: f64, omega: f64, variance: f64) -> f64 {
    if n * l <= variance {
        omega + (omega * variance / (mu * n)).sqrt()
    } else {
        omega + (l / mu).sqrt() + (omega * (variance / (mu * n)).sqrt() * (l / mu).sqrt()).sqrt()
    }
}

/// Quantization-constants table (arXiv:2106.03524's Table-1 analogue):
/// per dataset, the sketch's variance constants (ω, 𝓛̃) next to the
/// smoothness-aware quantizer's (ω_q = min(d/s², √d/s) and
/// 𝓛̃_q = ω_q·max_j L_jj under diag weighting, ω_q·λ_max(L_i) under
/// root), plus the predicted DCGD iteration complexity under each — the
/// theory side of the measured `smx figures --figure quant` race.
pub fn table_quant(cfg: &ExperimentConfig, datasets: &[String]) -> Result<Vec<Vec<String>>> {
    use crate::compress::{QuantWeighting, SaQuant};
    use crate::methods::sa_quant_family;

    let s = cfg.sa_levels.max(1);
    let header = [
        "dataset", "d", "s", "omega_sketch", "omega_q", "tilde_l_sketch_uni", "tilde_lq_diag",
        "tilde_lq_root", "k_dcgd_sketch", "k_dcgd_saq_diag", "k_dcgd_saq_root",
    ];
    println!("{}", header.join(","));
    let mut rows = Vec::new();
    for ds in datasets {
        let mut c = cfg.clone();
        c.dataset = ds.clone();
        let prep = runner::prepare_with(&c, false)?;
        let sm = &prep.sm;
        let n = sm.n() as f64;
        let d = sm.dim as f64;
        let mu = sm.mu;
        let tau = (d / n).max(1.0);
        let omega = d / tau - 1.0;

        let mut tilde_uni: f64 = 0.0;
        for loc in &sm.locals {
            let s_uni = SamplingKind::Uniform.build(&loc.diag, tau, mu, sm.n());
            tilde_uni = tilde_uni.max(s_uni.tilde_l(&loc.diag));
        }

        let omega_q = SaQuant::omega(sm.dim, s);
        let (_, _, tilde_diag) = sa_quant_family(sm, s, QuantWeighting::Diag);
        let (_, _, tilde_root) = sa_quant_family(sm, s, QuantWeighting::Root);

        let k_sketch = sm.l / mu + omega * sm.l_max / (n * mu);
        let k_diag = sm.l / mu + tilde_diag / (n * mu);
        let k_root = sm.l / mu + tilde_root / (n * mu);

        let row = vec![
            ds.clone(),
            format!("{}", sm.dim),
            format!("{s}"),
            format!("{omega:.1}"),
            format!("{omega_q:.3}"),
            format!("{tilde_uni:.4e}"),
            format!("{tilde_diag:.4e}"),
            format!("{tilde_root:.4e}"),
            format!("{k_sketch:.3e}"),
            format!("{k_diag:.3e}"),
            format!("{k_root:.3e}"),
        ];
        println!("{}", row.join(","));
        rows.push(row);
    }
    crate::util::write_csv(&cfg.out_dir.join("table_quant.csv"), &header, &rows)?;
    Ok(rows)
}

/// Table 3: dataset statistics (ours vs the paper's shapes — identical by
/// construction for the synthetic generators).
pub fn table3(cfg: &ExperimentConfig, datasets: &[String]) -> Result<Vec<Vec<String>>> {
    let header = ["dataset", "points", "d", "n", "m_i", "nnz_frac"];
    println!("{}", header.join(","));
    let mut rows = Vec::new();
    for ds in datasets {
        let raw = crate::data::load_or_synth(ds, cfg.data_dir.as_deref(), cfg.seed)?;
        let n = crate::data::spec_by_name(ds).map(|s| s.n).unwrap_or(4);
        let row = vec![
            ds.clone(),
            raw.num_points().to_string(),
            raw.dim().to_string(),
            n.to_string(),
            (raw.num_points() / n).to_string(),
            format!("{:.4}", raw.a.density()),
        ];
        println!("{}", row.join(","));
        rows.push(row);
    }
    crate::util::write_csv(&cfg.out_dir.join("table3.csv"), &header, &rows)?;
    Ok(rows)
}

/// Table 6: single-node complexity constants — 𝓛̄ = λ_max(P̄∘L) (SkGD/CGD+)
/// and 𝓛̃ for uniform and serial-optimal samplings.
pub fn table6(cfg: &ExperimentConfig, datasets: &[String]) -> Result<Vec<Vec<String>>> {
    let header = [
        "dataset", "d", "L", "k_skgd_uni", "k_cgd+_uni", "k_nsync_serial", "k_gd",
    ];
    println!("{}", header.join(","));
    let mut rows = Vec::new();
    for ds in datasets {
        let mut c = cfg.clone();
        c.dataset = ds.clone();
        c.workers = 1;
        let raw = crate::data::load_or_synth(ds, c.data_dir.as_deref(), c.seed)?;
        let (global, _) = raw.prepare(1, c.seed);
        let loc = build_local(&global.a, c.mu);
        let d = global.dim();
        let tau = (d as f64 / 8.0).max(1.0);
        let p_uni = vec![(tau / d as f64).min(1.0); d];
        let lbar = eso_lambda(&loc.root, &loc.diag, &p_uni);
        // complexities (Table 6): SkGD 𝓛̄/μ ; CGD+ 𝓛̄/μ (+ neighborhood);
        // 'NSync serial ΣL_jj/μ ; GD L/μ
        let k_skgd = lbar / c.mu;
        let k_nsync = loc.diag.iter().sum::<f64>() / c.mu;
        let k_gd = loc.root.lambda_max() / c.mu;
        let row = vec![
            ds.clone(),
            d.to_string(),
            format!("{:.4e}", loc.root.lambda_max()),
            format!("{k_skgd:.3e}"),
            format!("{:.3e}", 2.0 * k_skgd),
            format!("{k_nsync:.3e}"),
            format!("{k_gd:.3e}"),
        ];
        println!("{}", row.join(","));
        rows.push(row);
    }
    crate::util::write_csv(&cfg.out_dir.join("table6.csv"), &header, &rows)?;
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_run_on_tiny() {
        let cfg = ExperimentConfig {
            dataset: "tiny".into(),
            workers: 4,
            out_dir: std::env::temp_dir().join("smx_tables_test"),
            ..Default::default()
        };
        let ds = vec!["tiny".to_string()];
        let t2 = table2(&cfg, &ds).unwrap();
        assert_eq!(t2.len(), 1);
        // speedup factors must be ≥ 1 (the + methods never lose in theory)
        let speedup_dcgd: f64 = t2[0][t2[0].len() - 2].parse().unwrap();
        assert!(speedup_dcgd >= 0.99, "speedup {speedup_dcgd}");
        let t3 = table3(&cfg, &ds).unwrap();
        assert_eq!(t3[0][1], "120");
        let t6 = table6(&cfg, &ds).unwrap();
        assert_eq!(t6.len(), 1);
        let tq = table_quant(&cfg, &ds).unwrap();
        assert_eq!(tq.len(), 1);
        // ω_q, 𝓛̃ and both 𝓛̃_q constants must come out finite and positive
        for col in 4..8 {
            let v: f64 = tq[0][col].parse().unwrap();
            assert!(v.is_finite() && v > 0.0, "col {col} = {v}");
        }
        std::fs::remove_dir_all(&cfg.out_dir).ok();
    }
}
