//! Experiment drivers regenerating every table and figure of the paper's
//! evaluation (see DESIGN.md §3 for the index).

pub mod figures;
pub mod pool;
pub mod runner;
pub mod tables;
