//! `--watch`: a live terminal dashboard for any run, implemented as a
//! plain [`RoundObserver`].
//!
//! The dashboard hangs off the same observer seam as the CSV/JSONL
//! writers: it receives each recorded [`RoundRecord`] *after* the
//! server step has been applied, by shared reference, and returns
//! [`ObserverControl::Continue`] unconditionally. It therefore cannot
//! perturb the trajectory by construction — `tests/obs_endpoint.rs`
//! additionally asserts bitwise-identical residuals with and without a
//! watcher attached.
//!
//! Rendering is plain ANSI (cursor-up + erase-line redraw, a Unicode
//! sparkline) on stderr, so it composes with `--csv`/`--jsonl` on
//! stdout and needs no terminal library. Redraws are throttled to
//! ~10 Hz; the record stream itself is already throttled by
//! `record_every`.

use crate::coordinator::{ObserverControl, RoundObserver, RoundRecord, RunResult};
use crate::obs::registry::Registry;
use std::collections::VecDeque;
use std::io::{self, Write};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Residuals kept for the sparkline.
const RING: usize = 48;
/// Worker liveness cells rendered before eliding.
const MAX_WORKER_CELLS: usize = 32;

const SPARK_LEVELS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];

/// Map residuals to sparkline characters on a log scale across the
/// window's own min..max range.
fn spark(vals: &[f64]) -> String {
    if vals.is_empty() {
        return String::new();
    }
    let logs: Vec<f64> = vals.iter().map(|v| v.max(1e-300).log10()).collect();
    let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
    for &l in &logs {
        lo = lo.min(l);
        hi = hi.max(l);
    }
    let span = (hi - lo).max(1e-12);
    logs.iter()
        .map(|&l| {
            let t = (l - lo) / span; // 0 = window min, 1 = window max
            let idx = (t * (SPARK_LEVELS.len() - 1) as f64).round() as usize;
            SPARK_LEVELS[idx.min(SPARK_LEVELS.len() - 1)]
        })
        .collect()
}

/// `1536 → "1.5 KiB"` — scrape-time formatting only.
fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 4] = ["B", "KiB", "MiB", "GiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.1} {}", UNITS[u])
    }
}

/// Live terminal dashboard; see the module docs. Build with
/// [`WatchObserver::new`] (stderr, throttled) or
/// [`WatchObserver::to_sink`] (tests: unthrottled, any writer), then
/// optionally attach a [`Registry`] for the per-worker liveness row.
pub struct WatchObserver {
    sink: Box<dyn Write + Send>,
    registry: Option<Arc<Registry>>,
    min_redraw: Duration,
    last_draw: Option<Instant>,
    /// lines the previous frame occupied (for the cursor-up rewind)
    frame_lines: usize,
    ring: VecDeque<f64>,
    last: Option<RoundRecord>,
    frames: u64,
}

impl WatchObserver {
    /// Dashboard on stderr, redrawn at most every 100 ms.
    pub fn new() -> WatchObserver {
        WatchObserver {
            sink: Box::new(io::stderr()),
            registry: None,
            min_redraw: Duration::from_millis(100),
            last_draw: None,
            frame_lines: 0,
            ring: VecDeque::with_capacity(RING),
            last: None,
            frames: 0,
        }
    }

    /// Dashboard into an arbitrary writer with no redraw throttle —
    /// every recorded round produces a frame. Used by tests.
    pub fn to_sink(sink: Box<dyn Write + Send>) -> WatchObserver {
        WatchObserver {
            sink,
            min_redraw: Duration::ZERO,
            ..WatchObserver::new()
        }
    }

    /// Attach a metrics registry; adds the per-worker liveness row.
    pub fn registry(mut self, registry: Arc<Registry>) -> WatchObserver {
        self.registry = Some(registry);
        self
    }

    /// Frames actually written (post-throttle).
    pub fn frames_drawn(&self) -> u64 {
        self.frames
    }

    fn worker_row(&self) -> Option<String> {
        let reg = self.registry.as_ref()?;
        let n = reg.n_shards();
        if n == 0 {
            return None;
        }
        let mut cells = String::with_capacity(n.min(MAX_WORKER_CELLS) + 8);
        for s in 0..n.min(MAX_WORKER_CELLS) {
            cells.push(if reg.is_live(s) { '#' } else { '.' });
        }
        if n > MAX_WORKER_CELLS {
            cells.push('…');
        }
        Some(format!(
            "workers {}/{} live  [{}]  deaths {}  rejoins {}",
            reg.live_count(),
            n,
            cells,
            reg.worker_deaths.get(),
            reg.worker_rejoins.get(),
        ))
    }

    fn draw(&mut self) {
        let Some(rec) = self.last.clone() else {
            return;
        };
        // rounds/s from the run's own cumulative wall clock, so the
        // number matches what the CSV wall_secs column implies
        let rate = if rec.wall_secs > 0.0 {
            rec.round as f64 / rec.wall_secs
        } else {
            0.0
        };
        let modeled = (rec.bits_up + 7) / 8; // div_ceil needs Rust 1.73; MSRV is 1.70
        let ratio = if modeled > 0 {
            rec.bytes_up as f64 / modeled as f64
        } else {
            0.0
        };
        let residuals: Vec<f64> = self.ring.iter().copied().collect();

        let mut lines: Vec<String> = Vec::with_capacity(4);
        lines.push(format!(
            "smx watch · round {} · residual {:.3e} · {:.1} rounds/s",
            rec.round, rec.residual, rate
        ));
        lines.push(format!("resid  {}", spark(&residuals)));
        lines.push(format!(
            "bytes  up {} measured · {} modeled (x{:.2}) · down {}",
            human_bytes(rec.bytes_up),
            human_bytes(modeled),
            ratio,
            human_bytes(rec.bytes_down),
        ));
        if let Some(row) = self.worker_row() {
            lines.push(row);
        }

        let mut frame = String::new();
        if self.frame_lines > 0 {
            frame.push_str(&format!("\x1b[{}A", self.frame_lines));
        }
        for l in &lines {
            frame.push_str("\x1b[2K");
            frame.push_str(l);
            frame.push('\n');
        }
        if self.sink.write_all(frame.as_bytes()).is_ok() {
            let _ = self.sink.flush();
            self.frame_lines = lines.len();
            self.frames += 1;
        }
        self.last_draw = Some(Instant::now());
    }
}

impl Default for WatchObserver {
    fn default() -> Self {
        WatchObserver::new()
    }
}

impl RoundObserver for WatchObserver {
    fn on_round(&mut self, rec: &RoundRecord) -> ObserverControl {
        if self.ring.len() == RING {
            self.ring.pop_front();
        }
        self.ring.push_back(rec.residual);
        self.last = Some(rec.clone());
        let due = match self.last_draw {
            None => true,
            Some(t) => t.elapsed() >= self.min_redraw,
        };
        if due {
            self.draw();
        }
        ObserverControl::Continue
    }

    fn on_done(&mut self, result: &RunResult) {
        self.draw(); // final state, even if the throttle just fired
        let verdict = if result.reached_target {
            "reached target"
        } else if result.stopped_by_observer {
            "stopped by observer"
        } else {
            "round budget exhausted"
        };
        let _ = writeln!(
            self.sink,
            "smx watch · done: {} after {} rounds ({})",
            verdict, result.rounds_run, result.method
        );
        let _ = self.sink.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::RunResult;
    use crate::util::timer::PhaseTimer;
    use std::sync::Mutex;

    /// `Write` into a shared buffer the test can inspect after the
    /// observer (which owns its sink) is done with it.
    struct SharedBuf(Arc<Mutex<Vec<u8>>>);
    impl Write for SharedBuf {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            self.0.lock().unwrap().extend_from_slice(buf);
            Ok(buf.len())
        }
        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }

    fn rec(round: usize, residual: f64) -> RoundRecord {
        RoundRecord {
            round,
            residual,
            coords_up: 10 * round as u64,
            bits_up: 640 * round as u64,
            coords_down: 5 * round as u64,
            bytes_up: 80 * round as u64,
            bytes_down: 40 * round as u64,
            wall_secs: 0.01 * round as f64,
            compute_secs: 0.0,
            encode_secs: 0.0,
            wire_secs: 0.0,
        }
    }

    fn result(rounds: usize) -> RunResult {
        RunResult {
            method: "diana+".to_string(),
            final_x: vec![0.0],
            rounds_run: rounds,
            reached_target: true,
            stopped_by_observer: false,
            phases: PhaseTimer::new(),
        }
    }

    #[test]
    fn sparkline_is_log_scaled_and_spans_the_window() {
        let s = spark(&[1.0, 1e-2, 1e-4, 1e-6]);
        let chars: Vec<char> = s.chars().collect();
        assert_eq!(chars.len(), 4);
        assert_eq!(chars[0], '█'); // window max
        assert_eq!(chars[3], '▁'); // window min
        // log scale → equal decades step evenly, so strictly decreasing
        for w in chars.windows(2) {
            assert!(w[0] > w[1], "not decreasing: {s}");
        }
        assert_eq!(spark(&[]), "");
        // constant window must not divide by zero
        assert_eq!(spark(&[0.5, 0.5]).chars().count(), 2);
    }

    #[test]
    fn human_bytes_picks_sane_units() {
        assert_eq!(human_bytes(0), "0 B");
        assert_eq!(human_bytes(1023), "1023 B");
        assert_eq!(human_bytes(1536), "1.5 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.0 MiB");
    }

    #[test]
    fn frames_track_records_and_done_prints_a_summary() {
        let buf = Arc::new(Mutex::new(Vec::new()));
        let mut w = WatchObserver::to_sink(Box::new(SharedBuf(buf.clone())));
        for r in 1..=3 {
            assert_eq!(
                w.on_round(&rec(r, 10f64.powi(-(r as i32)))),
                ObserverControl::Continue
            );
        }
        assert_eq!(w.frames_drawn(), 3);
        w.on_done(&result(3));
        let text = String::from_utf8_lossy(&buf.lock().unwrap()).to_string();
        assert!(text.contains("round 3"), "missing last round: {text}");
        assert!(text.contains("residual 1.000e-3"), "residual: {text}");
        assert!(text.contains("240 B measured"), "bytes row: {text}");
        assert!(text.contains("reached target"), "summary: {text}");
        assert!(text.contains("\x1b[2K"), "no erase-line redraw: {text}");
    }

    #[test]
    fn registry_adds_a_worker_liveness_row() {
        let reg = Arc::new(Registry::new(4));
        reg.set_live(0, true);
        reg.set_live(2, true);
        let buf = Arc::new(Mutex::new(Vec::new()));
        let mut w =
            WatchObserver::to_sink(Box::new(SharedBuf(buf.clone()))).registry(reg);
        w.on_round(&rec(1, 0.5));
        let text = String::from_utf8_lossy(&buf.lock().unwrap()).to_string();
        assert!(text.contains("workers 2/4 live"), "{text}");
        assert!(text.contains("[#.#.]"), "{text}");
    }
}
