//! Minimal HTTP endpoint serving `GET /metrics` (Prometheus text
//! format) and `GET /healthz`.
//!
//! Two hosting modes, one implementation:
//!
//! * **Multiplexed** — the elastic server registers the listener in its
//!   own [`Poller`] under [`METRICS_LISTENER_TOKEN`] and forwards
//!   readiness tokens to [`HttpEndpoint::on_token`]. The token space is
//!   partitioned so HTTP traffic can never be mistaken for a worker
//!   connection: worker slots are small indices, the wire listener is
//!   `u64::MAX`, the metrics listener `u64::MAX - 1`, and HTTP
//!   connections live at [`HTTP_CONN_TOKEN_BASE`]` + slot`.
//! * **Standalone** — [`HttpEndpoint::spawn`] runs the same endpoint on
//!   a dedicated thread with its own poller, for loopback/sim runs and
//!   tests that have no server event loop to piggyback on.
//!
//! Everything is nonblocking reads + WouldBlock absorption, so the
//! fallback poll backend (`SMX_NO_EPOLL=1`), which reports every token
//! as may-be-ready, is handled by construction. Responses are small and
//! written with a short blocking write timeout — this is a diagnostics
//! endpoint, not a general web server.

use crate::obs::registry::Registry;
use crate::wire::poll::Poller;
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Poller token for the metrics listening socket. The wire runtime's
/// worker listener owns `u64::MAX`; this sits just below it.
pub const METRICS_LISTENER_TOKEN: u64 = u64::MAX - 1;

/// Base for HTTP connection tokens: far above any worker slot index the
/// elastic server will ever allocate.
pub const HTTP_CONN_TOKEN_BASE: u64 = 1 << 48;

/// Request-header cap; anything longer gets a 400 and a closed socket.
const MAX_REQUEST_BYTES: usize = 8 * 1024;

struct HttpConn {
    stream: TcpStream,
    buf: Vec<u8>,
}

enum Step {
    /// no complete request yet; keep the connection registered
    Wait,
    /// peer hung up or errored
    Close,
    /// a complete request-head arrived
    Respond { status: u32, content_type: &'static str, body: String },
}

pub struct HttpEndpoint {
    listener: TcpListener,
    registry: Arc<Registry>,
    conns: Vec<Option<HttpConn>>,
}

fn fd_of(stream: &TcpStream) -> i32 {
    #[cfg(unix)]
    {
        use std::os::unix::io::AsRawFd;
        stream.as_raw_fd()
    }
    #[cfg(not(unix))]
    {
        -1
    }
}

impl HttpEndpoint {
    /// Bind the listener (nonblocking) without registering it anywhere.
    pub fn bind(addr: &str, registry: Arc<Registry>) -> io::Result<HttpEndpoint> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        Ok(HttpEndpoint {
            listener,
            registry,
            conns: Vec::new(),
        })
    }

    pub fn local_addr(&self) -> io::Result<SocketAddr> {
        self.listener.local_addr()
    }

    /// Register the listening socket with `poller` under
    /// [`METRICS_LISTENER_TOKEN`].
    pub fn register(&self, poller: &mut Poller) -> io::Result<()> {
        #[cfg(unix)]
        {
            use std::os::unix::io::AsRawFd;
            poller.register(self.listener.as_raw_fd(), METRICS_LISTENER_TOKEN)
        }
        #[cfg(not(unix))]
        {
            poller.register(-1, METRICS_LISTENER_TOKEN)
        }
    }

    /// Does `token` belong to this endpoint (listener or connection)?
    pub fn owns(token: u64) -> bool {
        token == METRICS_LISTENER_TOKEN || token >= HTTP_CONN_TOKEN_BASE
    }

    /// Dispatch one readiness token owned by this endpoint. Spurious
    /// tokens (fallback backend, already-closed slots) are no-ops.
    pub fn on_token(&mut self, token: u64, poller: &mut Poller) {
        if token == METRICS_LISTENER_TOKEN {
            self.accept_pending(poller);
        } else if token >= HTTP_CONN_TOKEN_BASE {
            self.drive_conn((token - HTTP_CONN_TOKEN_BASE) as usize, poller);
        }
    }

    /// Accept every pending HTTP connection and register it.
    pub fn accept_pending(&mut self, poller: &mut Poller) {
        loop {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    if stream.set_nonblocking(true).is_err() {
                        continue; // drop it; diagnostics must never kill the run
                    }
                    let slot = self
                        .conns
                        .iter()
                        .position(|c| c.is_none())
                        .unwrap_or_else(|| {
                            self.conns.push(None);
                            self.conns.len() - 1
                        });
                    if poller
                        .register(fd_of(&stream), HTTP_CONN_TOKEN_BASE + slot as u64)
                        .is_err()
                    {
                        continue;
                    }
                    self.conns[slot] = Some(HttpConn {
                        stream,
                        buf: Vec::new(),
                    });
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => return,
            }
        }
    }

    fn drive_conn(&mut self, slot: usize, poller: &mut Poller) {
        // Read phase: Some(step) decides immediately (close / overflow /
        // would-block), None means the request head is complete and gets
        // routed once the mutable borrow of the connection has ended.
        let read_step = {
            let Some(conn) = self.conns.get_mut(slot).and_then(|c| c.as_mut()) else {
                return;
            };
            let mut tmp = [0u8; 1024];
            loop {
                match conn.stream.read(&mut tmp) {
                    Ok(0) => break Some(Step::Close),
                    Ok(n) => {
                        conn.buf.extend_from_slice(&tmp[..n]);
                        if conn.buf.len() > MAX_REQUEST_BYTES {
                            break Some(Step::Respond {
                                status: 400,
                                content_type: "text/plain; charset=utf-8",
                                body: "request too large\n".to_string(),
                            });
                        }
                        if conn.buf.windows(4).any(|w| w == b"\r\n\r\n") {
                            break None;
                        }
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => break Some(Step::Wait),
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(_) => break Some(Step::Close),
                }
            }
        };
        let step = match read_step {
            Some(s) => s,
            None => match self.conns.get(slot).and_then(|c| c.as_ref()) {
                Some(conn) => self.route(&conn.buf).unwrap_or(Step::Wait),
                None => return,
            },
        };
        match step {
            Step::Wait => {}
            Step::Close => self.close(slot, poller),
            Step::Respond {
                status,
                content_type,
                body,
            } => {
                self.write_response(slot, status, content_type, &body);
                self.close(slot, poller);
            }
        }
    }

    /// Route a buffered request once its head is complete. `None` while
    /// the head is still partial.
    fn route(&self, buf: &[u8]) -> Option<Step> {
        let head_end = buf.windows(4).position(|w| w == b"\r\n\r\n")?;
        let head = String::from_utf8_lossy(&buf[..head_end]);
        let mut parts = head.lines().next().unwrap_or("").split_whitespace();
        let method = parts.next().unwrap_or("");
        let path = parts.next().unwrap_or("");
        Some(if method != "GET" {
            Step::Respond {
                status: 405,
                content_type: "text/plain; charset=utf-8",
                body: "method not allowed\n".to_string(),
            }
        } else {
            match path {
                "/metrics" => {
                    self.registry.scrapes.inc();
                    Step::Respond {
                        status: 200,
                        content_type: "text/plain; version=0.0.4; charset=utf-8",
                        body: self.registry.render(),
                    }
                }
                "/healthz" => Step::Respond {
                    status: 200,
                    content_type: "text/plain; charset=utf-8",
                    body: "ok\n".to_string(),
                },
                _ => Step::Respond {
                    status: 404,
                    content_type: "text/plain; charset=utf-8",
                    body: "not found (try /metrics or /healthz)\n".to_string(),
                },
            }
        })
    }

    /// Write the full response with a short blocking write timeout.
    /// Responses are a few KiB; a stuck scraper costs at most the
    /// timeout, never a hung run.
    fn write_response(&mut self, slot: usize, status: u32, content_type: &str, body: &str) {
        let Some(conn) = self.conns.get_mut(slot).and_then(|c| c.as_mut()) else {
            return;
        };
        let reason = match status {
            200 => "OK",
            400 => "Bad Request",
            404 => "Not Found",
            405 => "Method Not Allowed",
            _ => "Error",
        };
        let head = format!(
            "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n",
            body.len()
        );
        let _ = conn.stream.set_nonblocking(false);
        let _ = conn.stream.set_write_timeout(Some(Duration::from_secs(2)));
        let _ = conn
            .stream
            .write_all(head.as_bytes())
            .and_then(|_| conn.stream.write_all(body.as_bytes()))
            .and_then(|_| conn.stream.flush());
    }

    fn close(&mut self, slot: usize, poller: &mut Poller) {
        if let Some(conn) = self.conns.get_mut(slot).and_then(|c| c.take()) {
            let _ = poller.deregister(fd_of(&conn.stream), HTTP_CONN_TOKEN_BASE + slot as u64);
            // conn drops here, closing the socket
        }
    }

    /// Run this endpoint standalone on a dedicated thread with its own
    /// poller, until the returned handle is stopped or dropped. For
    /// runs that have no server event loop to multiplex onto (loopback
    /// drivers, tests).
    pub fn spawn(addr: &str, registry: Arc<Registry>) -> io::Result<HttpServerHandle> {
        let mut ep = HttpEndpoint::bind(addr, registry)?;
        let local = ep.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::Builder::new()
            .name("smx-metrics-http".to_string())
            .spawn(move || {
                let Ok(mut poller) = Poller::new() else {
                    return;
                };
                if ep.register(&mut poller).is_err() {
                    return;
                }
                let mut events = Vec::new();
                while !stop2.load(Ordering::Relaxed) {
                    if poller.wait(Duration::from_millis(25), &mut events).is_err() {
                        return;
                    }
                    // accept opportunistically every slice: one cheap
                    // nonblocking syscall, and it makes the fallback
                    // backend (which reports everything) uniform with
                    // the kernel ones
                    ep.accept_pending(&mut poller);
                    for i in 0..events.len() {
                        let tok = events[i];
                        if tok != METRICS_LISTENER_TOKEN {
                            ep.on_token(tok, &mut poller);
                        }
                    }
                }
            })?;
        Ok(HttpServerHandle {
            addr: local,
            stop,
            handle: Some(handle),
        })
    }
}

/// Handle on a standalone endpoint thread; stops and joins it on
/// [`HttpServerHandle::stop`] or drop.
pub struct HttpServerHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl HttpServerHandle {
    /// The bound address (useful after binding port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for HttpServerHandle {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Blocking one-shot HTTP GET against `addr`; returns `(head, body)`.
/// Test/scripting helper — the CLI and tests use it to scrape a live
/// endpoint without external tooling.
pub fn http_get(addr: SocketAddr, path: &str) -> io::Result<(String, String)> {
    let mut s = TcpStream::connect(addr)?;
    s.set_read_timeout(Some(Duration::from_secs(10)))?;
    write!(
        s,
        "GET {path} HTTP/1.1\r\nHost: smx\r\nConnection: close\r\n\r\n"
    )?;
    s.flush()?;
    let mut raw = String::new();
    s.read_to_string(&mut raw)?;
    match raw.split_once("\r\n\r\n") {
        Some((head, body)) => Ok((head.to_string(), body.to_string())),
        None => Err(io::Error::new(
            io::ErrorKind::InvalidData,
            "malformed HTTP response (no header terminator)",
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standalone_endpoint_serves_metrics_healthz_and_404() {
        let reg = Arc::new(Registry::new(2));
        reg.rounds.add(5);
        reg.set_live(1, true);
        let srv = HttpEndpoint::spawn("127.0.0.1:0", reg.clone()).unwrap();
        let addr = srv.addr();

        let (head, body) = http_get(addr, "/healthz").unwrap();
        assert!(head.starts_with("HTTP/1.1 200"), "head: {head}");
        assert_eq!(body, "ok\n");

        let (head, body) = http_get(addr, "/metrics").unwrap();
        assert!(head.starts_with("HTTP/1.1 200"));
        assert!(head.contains("text/plain; version=0.0.4"));
        assert!(body.contains("smx_rounds_total 5"));
        assert!(body.contains("smx_worker_live{shard=\"1\"} 1"));

        let (head, _) = http_get(addr, "/nope").unwrap();
        assert!(head.starts_with("HTTP/1.1 404"));

        // scrapes counted exactly once per /metrics hit
        assert_eq!(reg.scrapes.get(), 1);
        let _ = http_get(addr, "/metrics").unwrap();
        assert_eq!(reg.scrapes.get(), 2);
        srv.stop();
    }

    #[test]
    fn non_get_is_rejected() {
        let reg = Arc::new(Registry::new(0));
        let srv = HttpEndpoint::spawn("127.0.0.1:0", reg).unwrap();
        let mut s = TcpStream::connect(srv.addr()).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        write!(s, "POST /metrics HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut raw = String::new();
        s.read_to_string(&mut raw).unwrap();
        assert!(raw.starts_with("HTTP/1.1 405"), "got: {raw}");
        srv.stop();
    }

    #[test]
    fn token_space_partition() {
        assert!(HttpEndpoint::owns(METRICS_LISTENER_TOKEN));
        assert!(HttpEndpoint::owns(HTTP_CONN_TOKEN_BASE));
        assert!(HttpEndpoint::owns(HTTP_CONN_TOKEN_BASE + 17));
        assert!(!HttpEndpoint::owns(0));
        assert!(!HttpEndpoint::owns(1024));
        // the wire listener token is u64::MAX, which owns() must also
        // claim nothing about here — the server checks it first
        assert_ne!(METRICS_LISTENER_TOKEN, u64::MAX);
    }
}
