//! Lock-free metrics registry with Prometheus text rendering.
//!
//! Every instrument is a plain `AtomicU64` (or a fixed, preallocated
//! array of them), so producers — the elastic server's round loop, the
//! wire runtime's connection state machine, observers — never allocate
//! or lock. The only multi-word value, the latest-round block, is
//! guarded by a seqlock: the single writer bumps a sequence number to
//! odd, stores the fields, bumps back to even; readers retry while the
//! sequence is odd or changed underfoot. Since the fields themselves
//! are atomics with `Relaxed` ordering, the retry loop is fully defined
//! behavior (no data races), and the `Acquire`/`Release` pairs on the
//! sequence number make a stable read a consistent snapshot.
//!
//! Rendering ([`Registry::render`]) produces Prometheus text exposition
//! format (version 0.0.4) and allocates only at scrape time. Metric
//! names are prefixed `smx_`.

use crate::coordinator::{ObserverControl, RoundObserver, RoundRecord};
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Monotonically increasing counter (rendered with a `_total` name).
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins gauge.
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Upper bounds (seconds) of the round-duration histogram buckets; the
/// final implicit bucket is `+Inf`. Exponential-ish ladder spanning the
/// sub-millisecond loopback rounds and multi-second WAN rounds alike.
pub const DURATION_BUCKETS: [f64; 14] = [
    0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5,
];

/// Fixed-bucket histogram of seconds. Bucket counts are stored
/// per-bucket and accumulated to the cumulative form Prometheus expects
/// at render time; the sum is kept in integer nanoseconds so producers
/// need no compare-and-swap loop.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; DURATION_BUCKETS.len() + 1],
    sum_nanos: AtomicU64,
    count: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_nanos: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn observe(&self, secs: f64) {
        let idx = DURATION_BUCKETS
            .iter()
            .position(|&b| secs <= b)
            .unwrap_or(DURATION_BUCKETS.len());
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.sum_nanos
            .fetch_add((secs.max(0.0) * 1e9) as u64, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    fn render(&self, out: &mut String, name: &str, help: &str) {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} histogram");
        let mut cum = 0u64;
        for (i, bound) in DURATION_BUCKETS.iter().enumerate() {
            cum += self.buckets[i].load(Ordering::Relaxed);
            let _ = writeln!(out, "{name}_bucket{{le=\"{bound}\"}} {cum}");
        }
        cum += self.buckets[DURATION_BUCKETS.len()].load(Ordering::Relaxed);
        let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {cum}");
        let _ = writeln!(
            out,
            "{name}_sum {:.9}",
            self.sum_nanos.load(Ordering::Relaxed) as f64 / 1e9
        );
        let _ = writeln!(out, "{name}_count {}", self.count.load(Ordering::Relaxed));
    }
}

/// Seqlock-guarded copy of the most recent [`RoundRecord`]. One writer
/// (the driving loop), any number of scraping readers.
#[derive(Debug, Default)]
pub struct RoundBlock {
    /// even = stable, odd = write in progress; 0 = never written
    seq: AtomicU64,
    round: AtomicU64,
    residual_bits: AtomicU64,
    coords_up: AtomicU64,
    bits_up: AtomicU64,
    coords_down: AtomicU64,
    bytes_up: AtomicU64,
    bytes_down: AtomicU64,
    wall_bits: AtomicU64,
    compute_bits: AtomicU64,
    encode_bits: AtomicU64,
    wire_bits: AtomicU64,
}

impl RoundBlock {
    /// Publish `rec` as the latest round. Single-writer only.
    pub fn write(&self, rec: &RoundRecord) {
        let s = self.seq.load(Ordering::Relaxed);
        self.seq.store(s + 1, Ordering::Release);
        self.round.store(rec.round as u64, Ordering::Relaxed);
        self.residual_bits
            .store(rec.residual.to_bits(), Ordering::Relaxed);
        self.coords_up.store(rec.coords_up, Ordering::Relaxed);
        self.bits_up.store(rec.bits_up, Ordering::Relaxed);
        self.coords_down.store(rec.coords_down, Ordering::Relaxed);
        self.bytes_up.store(rec.bytes_up, Ordering::Relaxed);
        self.bytes_down.store(rec.bytes_down, Ordering::Relaxed);
        self.wall_bits
            .store(rec.wall_secs.to_bits(), Ordering::Relaxed);
        self.compute_bits
            .store(rec.compute_secs.to_bits(), Ordering::Relaxed);
        self.encode_bits
            .store(rec.encode_secs.to_bits(), Ordering::Relaxed);
        self.wire_bits
            .store(rec.wire_secs.to_bits(), Ordering::Relaxed);
        self.seq.store(s + 2, Ordering::Release);
    }

    /// A consistent snapshot of the latest round, or `None` if nothing
    /// was ever published. Retries while a write is in flight.
    pub fn snapshot(&self) -> Option<RoundRecord> {
        loop {
            let s1 = self.seq.load(Ordering::Acquire);
            if s1 == 0 {
                return None;
            }
            if s1 % 2 == 1 {
                std::hint::spin_loop();
                continue;
            }
            let rec = RoundRecord {
                round: self.round.load(Ordering::Relaxed) as usize,
                residual: f64::from_bits(self.residual_bits.load(Ordering::Relaxed)),
                coords_up: self.coords_up.load(Ordering::Relaxed),
                bits_up: self.bits_up.load(Ordering::Relaxed),
                coords_down: self.coords_down.load(Ordering::Relaxed),
                bytes_up: self.bytes_up.load(Ordering::Relaxed),
                bytes_down: self.bytes_down.load(Ordering::Relaxed),
                wall_secs: f64::from_bits(self.wall_bits.load(Ordering::Relaxed)),
                compute_secs: f64::from_bits(self.compute_bits.load(Ordering::Relaxed)),
                encode_secs: f64::from_bits(self.encode_bits.load(Ordering::Relaxed)),
                wire_secs: f64::from_bits(self.wire_bits.load(Ordering::Relaxed)),
            };
            let s2 = self.seq.load(Ordering::Acquire);
            if s1 == s2 {
                return Some(rec);
            }
        }
    }
}

/// Label values of the `smx_members{state=...}` gauge family, in slot
/// order. They mirror `coordinator::membership::MemberState::name`.
pub const MEMBER_STATES: [&str; 5] = ["joined", "active", "sampled_out", "suspected", "evicted"];

/// The process-wide metrics registry. All fields are preallocated at
/// construction — producers never allocate. Share it as an
/// `Arc<Registry>` between the driving loop, the HTTP endpoint and any
/// observers.
#[derive(Debug)]
pub struct Registry {
    // counters (rendered with a `_total` suffix)
    /// optimization rounds completed
    pub rounds: Counter,
    /// snapshots committed (journal truncations)
    pub snapshots_committed: Counter,
    /// worker connections accepted
    pub worker_connects: Counter,
    /// workers declared dead (timeout, connection error, CRC failure)
    pub worker_deaths: Counter,
    /// rejoin/adoption catch-ups sent (replay announcements)
    pub worker_rejoins: Counter,
    /// connection errors whose kind was `InvalidData` — CRC mismatches
    /// and frame-decode failures
    pub crc_errors: Counter,
    /// all other connection errors (resets, EOFs, timeouts)
    pub conn_errors: Counter,
    /// journal frames retransmitted to catch workers up
    pub journal_replays: Counter,
    /// snapshot-state restores shipped to rejoiners/adopters
    pub state_restores: Counter,
    /// relay tier: merged `TAG_AGG_UPLINK` frames received
    pub relay_merged_frames: Counter,
    /// relay tier: constituent per-shard uplinks carried inside merged
    /// frames (merged ÷ fan-in ≈ branch factor)
    pub relay_fan_in: Counter,
    /// relay tier: total bytes of merged uplink frames (prefix included)
    pub relay_forwarded_bytes: Counter,
    /// `/metrics` scrapes served
    pub scrapes: Counter,
    // gauges
    /// rounds currently held by the in-memory replay journal
    pub journal_rounds: Gauge,
    /// bytes currently held by the in-memory replay journal
    pub journal_bytes: Gauge,
    /// current membership epoch (0 until the membership machine
    /// activates; the whole membership family renders only once it has)
    pub epoch: Gauge,
    /// cohort size τ of the latest round (n when every member is in)
    pub cohort_size: Gauge,
    /// member counts per membership state, indexed like [`MEMBER_STATES`]
    members: [Gauge; MEMBER_STATES.len()],
    /// latest recorded round (seqlock-guarded multi-field block)
    pub round: RoundBlock,
    /// wall-clock duration of each completed round
    pub round_duration: Histogram,
    /// per-shard liveness slots (1 = hosted by a live worker); sized at
    /// construction so membership churn never reallocates
    live: Box<[AtomicU64]>,
}

impl Registry {
    /// A registry with `n_shards` preallocated liveness slots (0 is fine
    /// for non-distributed runs: the per-shard series just vanish).
    pub fn new(n_shards: usize) -> Registry {
        Registry {
            rounds: Counter::default(),
            snapshots_committed: Counter::default(),
            worker_connects: Counter::default(),
            worker_deaths: Counter::default(),
            worker_rejoins: Counter::default(),
            crc_errors: Counter::default(),
            conn_errors: Counter::default(),
            journal_replays: Counter::default(),
            state_restores: Counter::default(),
            relay_merged_frames: Counter::default(),
            relay_fan_in: Counter::default(),
            relay_forwarded_bytes: Counter::default(),
            scrapes: Counter::default(),
            journal_rounds: Gauge::default(),
            journal_bytes: Gauge::default(),
            epoch: Gauge::default(),
            cohort_size: Gauge::default(),
            members: std::array::from_fn(|_| Gauge::default()),
            round: RoundBlock::default(),
            round_duration: Histogram::default(),
            live: (0..n_shards).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    pub fn n_shards(&self) -> usize {
        self.live.len()
    }

    /// Mark shard `s` as hosted by a live worker (or not). Out-of-range
    /// shards are ignored (defensive: the registry may be sized 0).
    pub fn set_live(&self, shard: usize, live: bool) {
        if let Some(slot) = self.live.get(shard) {
            slot.store(live as u64, Ordering::Relaxed);
        }
    }

    pub fn is_live(&self, shard: usize) -> bool {
        self.live
            .get(shard)
            .map(|s| s.load(Ordering::Relaxed) == 1)
            .unwrap_or(false)
    }

    /// Number of shards currently hosted by live workers.
    pub fn live_count(&self) -> usize {
        self.live
            .iter()
            .filter(|s| s.load(Ordering::Relaxed) == 1)
            .count()
    }

    /// Publish `rec` as the latest round block. Alloc-free.
    pub fn observe_record(&self, rec: &RoundRecord) {
        self.round.write(rec);
    }

    /// Set the member count for `state` (a [`MEMBER_STATES`] label
    /// value; unknown states are ignored, like out-of-range shards).
    pub fn set_members(&self, state: &str, count: u64) {
        if let Some(i) = MEMBER_STATES.iter().position(|s| *s == state) {
            self.members[i].set(count);
        }
    }

    /// Render the whole registry in Prometheus text exposition format
    /// (one allocation per scrape; producers are untouched).
    pub fn render(&self) -> String {
        let mut out = String::with_capacity(4096);
        let counter = |out: &mut String, name: &str, help: &str, v: u64| {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} counter");
            let _ = writeln!(out, "{name} {v}");
        };
        let gauge = |out: &mut String, name: &str, help: &str, v: &dyn std::fmt::Display| {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} gauge");
            let _ = writeln!(out, "{name} {v}");
        };

        counter(
            &mut out,
            "smx_rounds_total",
            "Optimization rounds completed.",
            self.rounds.get(),
        );
        counter(
            &mut out,
            "smx_snapshots_committed_total",
            "Checkpoint snapshots committed (journal truncations).",
            self.snapshots_committed.get(),
        );
        counter(
            &mut out,
            "smx_worker_connects_total",
            "Worker connections accepted.",
            self.worker_connects.get(),
        );
        counter(
            &mut out,
            "smx_worker_deaths_total",
            "Workers declared dead (timeout or connection error).",
            self.worker_deaths.get(),
        );
        counter(
            &mut out,
            "smx_worker_rejoins_total",
            "Rejoin/adoption catch-ups sent.",
            self.worker_rejoins.get(),
        );
        counter(
            &mut out,
            "smx_crc_errors_total",
            "Connection errors from CRC mismatches or malformed frames.",
            self.crc_errors.get(),
        );
        counter(
            &mut out,
            "smx_conn_errors_total",
            "Connection errors other than CRC/frame failures.",
            self.conn_errors.get(),
        );
        counter(
            &mut out,
            "smx_journal_replays_total",
            "Journal frames retransmitted to catch workers up.",
            self.journal_replays.get(),
        );
        counter(
            &mut out,
            "smx_state_restores_total",
            "Snapshot-state restores shipped to rejoiners/adopters.",
            self.state_restores.get(),
        );
        counter(
            &mut out,
            "smx_relay_merged_frames_total",
            "Merged (relay-aggregated) uplink frames received.",
            self.relay_merged_frames.get(),
        );
        counter(
            &mut out,
            "smx_relay_fan_in_total",
            "Per-shard uplinks carried inside merged relay frames.",
            self.relay_fan_in.get(),
        );
        counter(
            &mut out,
            "smx_relay_forwarded_bytes_total",
            "Bytes of merged relay uplink frames, length prefix included.",
            self.relay_forwarded_bytes.get(),
        );
        counter(
            &mut out,
            "smx_scrapes_total",
            "Scrapes served by this /metrics endpoint.",
            self.scrapes.get(),
        );
        gauge(
            &mut out,
            "smx_journal_rounds",
            "Rounds held by the in-memory replay journal.",
            &self.journal_rounds.get(),
        );
        gauge(
            &mut out,
            "smx_journal_bytes",
            "Bytes held by the in-memory replay journal.",
            &self.journal_bytes.get(),
        );

        if self.epoch.get() > 0 {
            gauge(
                &mut out,
                "smx_epoch",
                "Current membership epoch.",
                &self.epoch.get(),
            );
            gauge(
                &mut out,
                "smx_cohort_size",
                "Cohort size (tau) of the latest round.",
                &self.cohort_size.get(),
            );
            let _ = writeln!(out, "# HELP smx_members Members per membership state.");
            let _ = writeln!(out, "# TYPE smx_members gauge");
            for (name, slot) in MEMBER_STATES.iter().zip(self.members.iter()) {
                let _ = writeln!(out, "smx_members{{state=\"{name}\"}} {}", slot.get());
            }
        }

        if let Some(rec) = self.round.snapshot() {
            gauge(
                &mut out,
                "smx_round",
                "Latest recorded round.",
                &rec.round,
            );
            gauge(
                &mut out,
                "smx_residual",
                "Relative residual at the latest recorded round.",
                &format_args!("{:e}", rec.residual),
            );
            counter(
                &mut out,
                "smx_coords_up_total",
                "Cumulative coordinates sent worker to server.",
                rec.coords_up,
            );
            counter(
                &mut out,
                "smx_bits_up_total",
                "Cumulative modeled uplink bits.",
                rec.bits_up,
            );
            counter(
                &mut out,
                "smx_coords_down_total",
                "Cumulative coordinates sent server to workers.",
                rec.coords_down,
            );
            counter(
                &mut out,
                "smx_bytes_up_total",
                "Cumulative measured uplink bytes (exact frame sizes).",
                rec.bytes_up,
            );
            counter(
                &mut out,
                "smx_bytes_down_total",
                "Cumulative measured downlink bytes (exact frame sizes).",
                rec.bytes_down,
            );
            gauge(
                &mut out,
                "smx_wall_seconds",
                "Wall-clock seconds at the latest recorded round.",
                &format_args!("{:.6}", rec.wall_secs),
            );
            gauge(
                &mut out,
                "smx_compute_seconds",
                "Cumulative seconds in compute phases.",
                &format_args!("{:.6}", rec.compute_secs),
            );
            gauge(
                &mut out,
                "smx_encode_seconds",
                "Cumulative seconds encoding messages.",
                &format_args!("{:.6}", rec.encode_secs),
            );
            gauge(
                &mut out,
                "smx_wire_seconds",
                "Cumulative seconds on the wire.",
                &format_args!("{:.6}", rec.wire_secs),
            );
        }

        if !self.live.is_empty() {
            gauge(
                &mut out,
                "smx_workers_live",
                "Shards currently hosted by live workers.",
                &self.live_count(),
            );
            let _ = writeln!(out, "# HELP smx_worker_live Per-shard liveness (1 = hosted).");
            let _ = writeln!(out, "# TYPE smx_worker_live gauge");
            for (s, slot) in self.live.iter().enumerate() {
                let _ = writeln!(
                    out,
                    "smx_worker_live{{shard=\"{s}\"}} {}",
                    slot.load(Ordering::Relaxed)
                );
            }
        }

        self.round_duration.render(
            &mut out,
            "smx_round_duration_seconds",
            "Wall-clock duration of each completed round.",
        );
        out
    }
}

/// [`RoundObserver`] that mirrors every record into a shared
/// [`Registry`]: the round block tracks the latest record, the `rounds`
/// counter advances by the round delta between consecutive records.
/// Used by the loopback drivers and tests; the elastic TCP server feeds
/// its registry directly from the round loop instead.
pub struct MetricsObserver {
    registry: Arc<Registry>,
    last_round: u64,
}

impl MetricsObserver {
    pub fn new(registry: Arc<Registry>) -> MetricsObserver {
        MetricsObserver {
            registry,
            last_round: 0,
        }
    }
}

impl RoundObserver for MetricsObserver {
    fn on_round(&mut self, rec: &RoundRecord) -> ObserverControl {
        let r = rec.round as u64;
        if r > self.last_round {
            self.registry.rounds.add(r - self.last_round);
            self.last_round = r;
        }
        self.registry.observe_record(rec);
        ObserverControl::Continue
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(round: usize) -> RoundRecord {
        RoundRecord {
            round,
            residual: 0.5_f64.powi(round as i32),
            coords_up: round as u64 * 10,
            bits_up: round as u64 * 640,
            coords_down: round as u64 * 100,
            bytes_up: round as u64 * 90,
            bytes_down: round as u64 * 800,
            wall_secs: round as f64 * 0.1,
            compute_secs: round as f64 * 0.05,
            encode_secs: round as f64 * 0.01,
            wire_secs: round as f64 * 0.02,
        }
    }

    #[test]
    fn round_block_roundtrips_bitwise() {
        let b = RoundBlock::default();
        assert!(b.snapshot().is_none(), "unwritten block must read None");
        b.write(&rec(7));
        let s = b.snapshot().unwrap();
        assert_eq!(s.round, 7);
        assert_eq!(s.residual.to_bits(), rec(7).residual.to_bits());
        assert_eq!(s.bytes_up, 630);
        assert_eq!(s.wire_secs.to_bits(), rec(7).wire_secs.to_bits());
    }

    #[test]
    fn round_block_survives_concurrent_scrapes() {
        let reg = Arc::new(Registry::new(0));
        let r2 = reg.clone();
        let reader = std::thread::spawn(move || {
            // every observed snapshot must be internally consistent:
            // all fields from the same write (round k ⇒ bytes_up = 90k)
            for _ in 0..20_000 {
                if let Some(s) = r2.round.snapshot() {
                    assert_eq!(s.bytes_up, s.round as u64 * 90, "torn read at {}", s.round);
                }
            }
        });
        for i in 0..20_000 {
            reg.round.write(&rec(i % 999));
        }
        reader.join().unwrap();
    }

    #[test]
    fn histogram_buckets_and_sum() {
        let h = Histogram::default();
        h.observe(0.0002); // bucket le=0.00025
        h.observe(0.003); // bucket le=0.005
        h.observe(100.0); // +Inf
        assert_eq!(h.count(), 3);
        let mut out = String::new();
        h.render(&mut out, "t_seconds", "test");
        assert!(out.contains("t_seconds_bucket{le=\"0.00025\"} 1"));
        assert!(out.contains("t_seconds_bucket{le=\"0.005\"} 2"));
        assert!(out.contains("t_seconds_bucket{le=\"+Inf\"} 3"));
        assert!(out.contains("t_seconds_count 3"));
    }

    #[test]
    fn liveness_slots_are_fixed_size() {
        let reg = Registry::new(3);
        assert_eq!(reg.live_count(), 0);
        reg.set_live(0, true);
        reg.set_live(2, true);
        reg.set_live(99, true); // out of range: ignored, no growth
        assert_eq!(reg.live_count(), 2);
        assert!(reg.is_live(0) && !reg.is_live(1) && reg.is_live(2));
        assert_eq!(reg.n_shards(), 3);
        reg.set_live(0, false);
        assert_eq!(reg.live_count(), 1);
    }

    #[test]
    fn render_exposes_expected_series() {
        let reg = Registry::new(2);
        reg.rounds.add(30);
        reg.worker_connects.inc();
        reg.set_live(1, true);
        reg.relay_merged_frames.inc();
        reg.relay_fan_in.add(4);
        reg.relay_forwarded_bytes.add(512);
        reg.observe_record(&rec(30));
        reg.round_duration.observe(0.002);
        // the membership family renders only once the machine activated
        assert!(!reg.render().contains("smx_members"));
        reg.epoch.set(2);
        reg.cohort_size.set(3);
        reg.set_members("active", 3);
        reg.set_members("sampled_out", 1);
        reg.set_members("no-such-state", 9); // ignored, like bad shards
        let text = reg.render();
        assert!(text.contains("smx_epoch 2"));
        assert!(text.contains("smx_cohort_size 3"));
        assert!(text.contains("smx_members{state=\"active\"} 3"));
        assert!(text.contains("smx_members{state=\"sampled_out\"} 1"));
        assert!(text.contains("smx_members{state=\"evicted\"} 0"));
        assert!(text.contains("smx_rounds_total 30"));
        assert!(text.contains("smx_worker_connects_total 1"));
        assert!(text.contains("smx_relay_merged_frames_total 1"));
        assert!(text.contains("smx_relay_fan_in_total 4"));
        assert!(text.contains("smx_relay_forwarded_bytes_total 512"));
        assert!(text.contains("smx_bytes_up_total 2700"));
        assert!(text.contains("smx_worker_live{shard=\"0\"} 0"));
        assert!(text.contains("smx_worker_live{shard=\"1\"} 1"));
        assert!(text.contains("smx_workers_live 1"));
        assert!(text.contains("smx_round 30"));
        assert!(text.contains("# TYPE smx_round_duration_seconds histogram"));
        // a registry with no shards renders no per-shard series
        assert!(!Registry::new(0).render().contains("smx_worker_live"));
    }

    #[test]
    fn metrics_observer_tracks_round_deltas() {
        let reg = Arc::new(Registry::new(0));
        let mut obs = MetricsObserver::new(reg.clone());
        for r in [0usize, 10, 20, 30] {
            assert_eq!(obs.on_round(&rec(r)), ObserverControl::Continue);
        }
        assert_eq!(reg.rounds.get(), 30);
        assert_eq!(reg.round.snapshot().unwrap().round, 30);
    }
}
