//! `smx runs` — treat `--run-dir` run logs as a managed artifact store.
//!
//! A run directory ([`crate::wire::runlog`]) carries everything needed
//! to understand a run after the fact: config hash + full config JSON,
//! seed, the durable record stream, the latest server snapshot, the
//! downlink journal, and (since runlog v2) a completion marker. This
//! module turns that into a small artifact-store CLI:
//!
//! * `smx runs list [root]` — enumerate run dirs under `root` (or
//!   `root` itself when it is one) with seed / progress / status.
//! * `smx runs show <dir>` — one run in detail, including its stored
//!   config JSON pretty-printed.
//! * `smx runs diff <a> <b>` — compare two record streams on the
//!   *deterministic* columns only (round, residual bits, coordinate and
//!   byte counters). Wall/phase timings always differ between runs and
//!   are deliberately excluded, so two runs of the same config + seed
//!   report `identical` — the golden test in `tests/obs_endpoint.rs`
//!   relies on exactly this.
//! * `smx runs resume <dir>` — rebuild the [`ExperimentConfig`] from
//!   the stored config JSON and hand it back to `main` to re-enter
//!   `smx serve` against the same directory; refuses finished runs.

use crate::config::ExperimentConfig;
use crate::coordinator::RoundRecord;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::wire::runlog::{LoadedRun, RunLog, BASE_FILE};
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// One line of `smx runs list` / header of `show`.
#[derive(Clone, Debug)]
pub struct RunSummary {
    pub dir: PathBuf,
    pub config_hash: u64,
    pub seed: u64,
    pub finished: bool,
    pub records: usize,
    pub last_round: Option<usize>,
    pub last_residual: Option<f64>,
    pub snapshot_round: Option<u64>,
    pub journal_rounds: usize,
    pub has_config: bool,
}

impl RunSummary {
    fn from_loaded(dir: &Path, l: &LoadedRun) -> RunSummary {
        RunSummary {
            dir: dir.to_path_buf(),
            config_hash: l.config_hash,
            seed: l.seed,
            finished: l.finished,
            records: l.records.len(),
            last_round: l.records.last().map(|r| r.round),
            last_residual: l.records.last().map(|r| r.residual),
            snapshot_round: l.snapshot.as_ref().map(|s| s.round),
            journal_rounds: l.journal.len(),
            has_config: l.config_json.is_some(),
        }
    }

    fn status(&self) -> &'static str {
        if self.finished {
            "finished"
        } else {
            "in-progress"
        }
    }
}

fn load(dir: &Path) -> Result<LoadedRun> {
    RunLog::load(dir)
        .with_context(|| format!("reading run dir {}", dir.display()))?
        .with_context(|| format!("{} is not a run dir (no {BASE_FILE})", dir.display()))
}

/// Summarize one run directory.
pub fn summarize(dir: &Path) -> Result<RunSummary> {
    Ok(RunSummary::from_loaded(dir, &load(dir)?))
}

/// Enumerate run dirs: `root` itself if it holds a `base.bin`,
/// otherwise its immediate subdirectories that do (sorted by name).
/// Unreadable entries are skipped, not fatal — listing an artifact
/// store must survive one corrupt member.
pub fn list(root: &Path) -> Result<Vec<RunSummary>> {
    if root.join(BASE_FILE).is_file() {
        return Ok(vec![summarize(root)?]);
    }
    let rd = std::fs::read_dir(root)
        .with_context(|| format!("listing {} (expected a run dir or a directory of run dirs)", root.display()))?;
    let mut dirs: Vec<PathBuf> = rd
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.join(BASE_FILE).is_file())
        .collect();
    dirs.sort();
    Ok(dirs.iter().filter_map(|d| summarize(d).ok()).collect())
}

/// Result of comparing two record streams on deterministic fields.
#[derive(Clone, Debug, PartialEq)]
pub enum DiffOutcome {
    /// Same length, every deterministic field bitwise equal.
    Identical { records: usize },
    /// First index where a deterministic field differs.
    Diverged {
        index: usize,
        round: usize,
        field: &'static str,
        a: String,
        b: String,
    },
    /// Common prefix identical, but one stream is longer.
    Truncated { shorter: usize, longer: usize },
}

/// Deterministic fields only: timings (`wall_secs` and the phase
/// columns) always differ between runs and never gate equality.
fn det_fields(r: &RoundRecord) -> [(&'static str, String); 7] {
    [
        ("round", r.round.to_string()),
        ("residual", format!("{:.17e} ({:#x})", r.residual, r.residual.to_bits())),
        ("coords_up", r.coords_up.to_string()),
        ("bits_up", r.bits_up.to_string()),
        ("coords_down", r.coords_down.to_string()),
        ("bytes_up", r.bytes_up.to_string()),
        ("bytes_down", r.bytes_down.to_string()),
    ]
}

/// Compare two record streams; see [`DiffOutcome`].
pub fn diff_records(a: &[RoundRecord], b: &[RoundRecord]) -> DiffOutcome {
    let n = a.len().min(b.len());
    for i in 0..n {
        let (fa, fb) = (det_fields(&a[i]), det_fields(&b[i]));
        for (x, y) in fa.iter().zip(fb.iter()) {
            if x.1 != y.1 {
                return DiffOutcome::Diverged {
                    index: i,
                    round: a[i].round,
                    field: x.0,
                    a: x.1.clone(),
                    b: y.1.clone(),
                };
            }
        }
    }
    if a.len() != b.len() {
        DiffOutcome::Truncated {
            shorter: n,
            longer: a.len().max(b.len()),
        }
    } else {
        DiffOutcome::Identical { records: n }
    }
}

/// Load and compare two run dirs.
pub fn diff_runs(a: &Path, b: &Path) -> Result<DiffOutcome> {
    Ok(diff_records(&load(a)?.records, &load(b)?.records))
}

fn print_summary_line(s: &RunSummary) {
    let progress = match (s.last_round, s.last_residual) {
        (Some(r), Some(res)) => format!("round {r} residual {res:.3e}"),
        _ => "no records".to_string(),
    };
    println!(
        "{:<28} seed {:<6} cfg {:016x}  {:<11} {} ({} records, snapshot {})",
        s.dir.display(),
        s.seed,
        s.config_hash,
        s.status(),
        progress,
        s.records,
        s.snapshot_round
            .map(|r| r.to_string())
            .unwrap_or_else(|| "none".to_string()),
    );
}

/// CLI entry for the `runs` subcommand. Returns `Some(config)` only for
/// `resume`, in which case `main` re-enters the serve path with it —
/// this module never starts a run itself.
pub fn cmd(args: &Args) -> Result<Option<ExperimentConfig>> {
    let action = args
        .positional
        .first()
        .map(String::as_str)
        .context("usage: smx runs <list|show|diff|resume> [paths...]")?;
    match action {
        "list" => {
            let root = PathBuf::from(args.positional.get(1).map(String::as_str).unwrap_or("."));
            let runs = list(&root)?;
            if runs.is_empty() {
                println!("no run dirs under {}", root.display());
            }
            for s in &runs {
                print_summary_line(s);
            }
            Ok(None)
        }
        "show" => {
            let dir = PathBuf::from(
                args.positional
                    .get(1)
                    .context("usage: smx runs show <dir>")?,
            );
            let loaded = load(&dir)?;
            let s = RunSummary::from_loaded(&dir, &loaded);
            print_summary_line(&s);
            println!(
                "journal: {} buffered downlink round(s) past the snapshot",
                s.journal_rounds
            );
            if !loaded.membership.is_empty() {
                println!("membership: {} event(s)", loaded.membership.len());
                for m in &loaded.membership {
                    println!(
                        "  round {:>6}  epoch {:>4}  {:<12} member {}",
                        m.round,
                        m.epoch,
                        crate::coordinator::membership::MembershipEvent::kind_name(m.kind),
                        m.member
                    );
                }
            }
            match &loaded.config_json {
                Some(raw) if !raw.is_empty() => match Json::parse(raw) {
                    Ok(j) => print!("config:\n{}", j.to_string_pretty()),
                    Err(_) => println!("config (unparsed): {raw}"),
                },
                _ => println!("config: not stored (pre-v2 run dir)"),
            }
            Ok(None)
        }
        "diff" => {
            let a = PathBuf::from(args.positional.get(1).context("usage: smx runs diff <a> <b>")?);
            let b = PathBuf::from(args.positional.get(2).context("usage: smx runs diff <a> <b>")?);
            match diff_runs(&a, &b)? {
                DiffOutcome::Identical { records } => {
                    println!("identical: {records} records agree on all deterministic fields");
                    Ok(None)
                }
                DiffOutcome::Diverged {
                    index,
                    round,
                    field,
                    a: va,
                    b: vb,
                } => bail!(
                    "diverged at record {index} (round {round}): {field} {va} vs {vb}"
                ),
                DiffOutcome::Truncated { shorter, longer } => bail!(
                    "prefix identical for {shorter} records, but lengths differ ({shorter} vs {longer})"
                ),
            }
        }
        "resume" => {
            let dir = PathBuf::from(
                args.positional
                    .get(1)
                    .context("usage: smx runs resume <dir>")?,
            );
            let loaded = load(&dir)?;
            if loaded.finished {
                bail!(
                    "{} is a finished run; refusing to resume (use `smx runs show` to inspect it)",
                    dir.display()
                );
            }
            let raw = loaded.config_json.as_deref().filter(|s| !s.is_empty()).with_context(|| {
                format!(
                    "{} stores no config JSON (pre-v2 run dir); resume it with the original command line instead",
                    dir.display()
                )
            })?;
            let j = Json::parse(raw)
                .with_context(|| format!("parsing stored config of {}", dir.display()))?;
            let mut cfg = ExperimentConfig::from_json(&j)
                .with_context(|| format!("stored config of {}", dir.display()))?;
            cfg.wire.run_dir = Some(dir.display().to_string());
            Ok(Some(cfg))
        }
        other => bail!("unknown runs action '{other}' (expected list|show|diff|resume)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmp_dir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("smx_runs_{name}"));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn rec(round: usize, seed: u64, wall_bias: f64) -> RoundRecord {
        // deterministic pseudo-content so two equal-seed dirs agree on
        // every deterministic column and different seeds split at
        // round 1; wall_bias perturbs the timing columns only
        let jitter = if round == 0 { 0 } else { seed };
        RoundRecord {
            round,
            residual: 1.0 / (round as f64 + 1.0 + jitter as f64 * 1e-3),
            coords_up: 10 + round as u64 + jitter,
            bits_up: 640,
            coords_down: 5,
            bytes_up: 80 + jitter,
            bytes_down: 40,
            wall_secs: 0.1 * round as f64 + wall_bias, // never compared
            compute_secs: wall_bias,                   // never compared
            encode_secs: 0.0,
            wire_secs: wall_bias * 0.5,
        }
    }

    fn synth(dir: &Path, seed: u64, rounds: usize, finish: bool, config: &str) {
        synth_biased(dir, seed, rounds, finish, config, 0.0)
    }

    fn synth_biased(dir: &Path, seed: u64, rounds: usize, finish: bool, config: &str, wall_bias: f64) {
        let mut log = RunLog::create(dir, 0xC0FFEE, seed, config).unwrap();
        for r in 0..rounds {
            log.record(&rec(r, seed, wall_bias));
        }
        if finish {
            log.finish().unwrap();
        }
    }

    #[test]
    fn summarize_and_list_see_the_store() {
        let root = tmp_dir("store");
        synth(&root.join("a"), 1, 3, true, "{\"seed\": 1}");
        synth(&root.join("b"), 2, 5, false, "{\"seed\": 2}");
        std::fs::create_dir_all(root.join("not_a_run")).unwrap();

        let runs = list(&root).unwrap();
        assert_eq!(runs.len(), 2);
        assert_eq!(runs[0].seed, 1);
        assert!(runs[0].finished && runs[0].records == 3);
        assert_eq!(runs[1].seed, 2);
        assert!(!runs[1].finished && runs[1].records == 5);
        assert_eq!(runs[1].last_round, Some(4));

        // a run dir passed directly lists itself
        let one = list(&root.join("a")).unwrap();
        assert_eq!(one.len(), 1);
        assert_eq!(one[0].config_hash, 0xC0FFEE);
    }

    #[test]
    fn diff_is_deterministic_fields_only() {
        let root = tmp_dir("diff");
        synth_biased(&root.join("s42a"), 42, 4, true, "", 0.0);
        synth_biased(&root.join("s42b"), 42, 4, true, "", 7.5);
        synth(&root.join("s43"), 43, 4, true, "");
        synth(&root.join("s42short"), 42, 2, false, "");

        // same seed but very different wall/compute timings: the
        // deterministic columns agree → identical
        match diff_runs(&root.join("s42a"), &root.join("s42b")).unwrap() {
            DiffOutcome::Identical { records } => assert_eq!(records, 4),
            other => panic!("expected identical, got {other:?}"),
        }
        // different seed: fixture makes round 0 agree, round 1 split
        match diff_runs(&root.join("s42a"), &root.join("s43")).unwrap() {
            DiffOutcome::Diverged { index, round, field, .. } => {
                assert_eq!((index, round), (1, 1));
                assert_eq!(field, "residual");
            }
            other => panic!("expected divergence, got {other:?}"),
        }
        // prefix of itself: truncated, not diverged
        match diff_runs(&root.join("s42a"), &root.join("s42short")).unwrap() {
            DiffOutcome::Truncated { shorter, longer } => assert_eq!((shorter, longer), (2, 4)),
            other => panic!("expected truncation, got {other:?}"),
        }
    }

    #[test]
    fn resume_rebuilds_config_and_refuses_finished_runs() {
        let root = tmp_dir("resume");
        let cfg_json = "{\"seed\": 9, \"max_rounds\": 50}";
        synth(&root.join("open"), 9, 2, false, cfg_json);
        synth(&root.join("done"), 9, 2, true, cfg_json);
        synth(&root.join("bare"), 9, 2, false, "");

        let args = |v: &[&str]| {
            Args::parse(
                std::iter::once("runs".to_string()).chain(v.iter().map(|s| s.to_string())),
                true,
            )
        };

        let cfg = cmd(&args(&["resume", root.join("open").to_str().unwrap()]))
            .unwrap()
            .expect("resume returns a config");
        assert_eq!(cfg.seed, 9);
        assert_eq!(cfg.max_rounds, 50);
        assert_eq!(
            cfg.wire.run_dir.as_deref(),
            root.join("open").to_str(),
            "resume must point the config back at the run dir"
        );

        let err = cmd(&args(&["resume", root.join("done").to_str().unwrap()])).unwrap_err();
        assert!(err.to_string().contains("finished"), "{err}");
        let err = cmd(&args(&["resume", root.join("bare").to_str().unwrap()])).unwrap_err();
        assert!(err.to_string().contains("no config JSON"), "{err}");
        let err = cmd(&args(&["bogus"])).unwrap_err();
        assert!(err.to_string().contains("unknown runs action"), "{err}");
    }
}
