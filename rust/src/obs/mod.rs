//! Production observability for long-running serve/train sessions.
//!
//! Four connected pieces, all dependency-free:
//!
//! * [`registry`] — a lock-free metrics [`Registry`] (atomic counters,
//!   gauges, a seqlock-guarded per-round block mirroring the latest
//!   [`RoundRecord`](crate::coordinator::RoundRecord), a fixed-bucket
//!   round-duration histogram, and preallocated per-shard liveness
//!   slots). Writers touch only atomics — the hot round loop stays
//!   alloc-free; rendering to Prometheus text allocates at scrape time
//!   only.
//! * [`http`] — a minimal `GET /metrics` + `GET /healthz` HTTP listener
//!   ([`HttpEndpoint`]) that multiplexes onto the elastic server's
//!   existing [`Poller`](crate::wire::poll::Poller) loop (token-space
//!   partitioned; see [`METRICS_LISTENER_TOKEN`]) or runs standalone on
//!   its own thread ([`HttpEndpoint::spawn`]) for non-serve runs and
//!   tests.
//! * [`watch`] — [`WatchObserver`], a live terminal dashboard
//!   implemented as a plain
//!   [`RoundObserver`](crate::coordinator::RoundObserver): round rate,
//!   residual sparkline, measured-vs-modeled bytes, per-worker
//!   liveness. Observers receive shared references post-apply, so the
//!   dashboard cannot perturb the trajectory by construction (and
//!   `tests/obs_endpoint.rs` asserts it bitwise).
//! * [`runs`] — the `smx runs` subcommand family (`list` / `show` /
//!   `diff` / `resume`) that treats `--run-dir` run logs
//!   ([`crate::wire::runlog`]) as a managed artifact store: every run
//!   dir carries its config JSON, seed, records and completion marker,
//!   so finished runs can be enumerated, inspected, compared
//!   record-by-record and resumed without the original command line.
//!
//! The byte counters exposed at `/metrics` come from the same
//! cumulative [`RoundTotals`](crate::coordinator::RoundTotals) the
//! record stream is cut from, so `smx_bytes_up_total` agrees *exactly*
//! with the `bytes_up` column of the CSV/JSONL output at every recorded
//! round — asserted by `tests/obs_endpoint.rs`.

pub mod http;
pub mod registry;
pub mod runs;
pub mod watch;

pub use http::{HttpEndpoint, HttpServerHandle, HTTP_CONN_TOKEN_BASE, METRICS_LISTENER_TOKEN};
pub use registry::{Counter, Gauge, Histogram, MetricsObserver, Registry};
pub use watch::WatchObserver;
