//! Fixed-capacity SPSC ring channel.
//!
//! `std::sync::mpsc` allocates a heap block per send (its internal linked
//! segments), which made the channels the last per-round allocation source
//! in [`run_threaded_observed`](crate::coordinator::run_threaded_observed)
//! (§Perf backlog).
//! This ring preallocates every slot at construction: `send`/`recv` move
//! the value in and out of a fixed `Vec<Option<T>>` under a mutex, so the
//! steady state makes **zero allocator calls** — asserted for the whole
//! threaded round pipeline in `tests/alloc_free.rs`.
//!
//! Single-producer single-consumer by construction: the two endpoints are
//! not `Clone`, so each ring connects exactly one sender to one receiver
//! (the coordinator holds one ring per direction per worker). Both ends
//! block on a `Condvar` when full/empty and observe the peer's drop as a
//! disconnect, mirroring mpsc's error contract.

use std::sync::{Arc, Condvar, Mutex};

/// Sending half died before the queue drained.
#[derive(Debug, PartialEq, Eq)]
pub struct RecvError;

/// Receiving half is gone; the unsent value is returned.
#[derive(Debug, PartialEq, Eq)]
pub struct SendError<T>(pub T);

struct State<T> {
    /// fixed ring storage; `None` slots are empty
    slots: Vec<Option<T>>,
    /// index of the oldest element (next `recv`)
    head: usize,
    /// elements currently queued
    len: usize,
    tx_alive: bool,
    rx_alive: bool,
}

struct Shared<T> {
    state: Mutex<State<T>>,
    not_empty: Condvar,
    not_full: Condvar,
}

/// Producer endpoint of [`ring`]. Not `Clone` (single producer).
pub struct RingSender<T> {
    shared: Arc<Shared<T>>,
}

/// Consumer endpoint of [`ring`]. Not `Clone` (single consumer).
pub struct RingReceiver<T> {
    shared: Arc<Shared<T>>,
}

/// A connected `(sender, receiver)` pair over `capacity` preallocated
/// slots. `capacity` must be at least 1.
pub fn ring<T>(capacity: usize) -> (RingSender<T>, RingReceiver<T>) {
    assert!(capacity > 0, "ring capacity must be positive");
    let shared = Arc::new(Shared {
        state: Mutex::new(State {
            slots: (0..capacity).map(|_| None).collect(),
            head: 0,
            len: 0,
            tx_alive: true,
            rx_alive: true,
        }),
        not_empty: Condvar::new(),
        not_full: Condvar::new(),
    });
    (
        RingSender {
            shared: shared.clone(),
        },
        RingReceiver { shared },
    )
}

impl<T> RingSender<T> {
    /// Move `value` into the ring, blocking while it is full. Errors (and
    /// hands the value back) once the receiver is gone.
    pub fn send(&self, value: T) -> Result<(), SendError<T>> {
        let mut st = self.shared.state.lock().unwrap();
        loop {
            if !st.rx_alive {
                return Err(SendError(value));
            }
            if st.len < st.slots.len() {
                let cap = st.slots.len();
                let tail = (st.head + st.len) % cap;
                debug_assert!(st.slots[tail].is_none());
                st.slots[tail] = Some(value);
                st.len += 1;
                self.shared.not_empty.notify_one();
                return Ok(());
            }
            st = self.shared.not_full.wait(st).unwrap();
        }
    }
}

impl<T> RingReceiver<T> {
    /// Take the oldest value, blocking while the ring is empty. Errors
    /// once the sender is gone *and* the queue has drained.
    pub fn recv(&self) -> Result<T, RecvError> {
        let mut st = self.shared.state.lock().unwrap();
        loop {
            if st.len > 0 {
                let v = st.slots[st.head].take().expect("occupied ring slot");
                st.head = (st.head + 1) % st.slots.len();
                st.len -= 1;
                self.shared.not_full.notify_one();
                return Ok(v);
            }
            if !st.tx_alive {
                return Err(RecvError);
            }
            st = self.shared.not_empty.wait(st).unwrap();
        }
    }
}

impl<T> Drop for RingSender<T> {
    fn drop(&mut self) {
        let mut st = self.shared.state.lock().unwrap();
        st.tx_alive = false;
        self.shared.not_empty.notify_one();
    }
}

impl<T> Drop for RingReceiver<T> {
    fn drop(&mut self) {
        let mut st = self.shared.state.lock().unwrap();
        st.rx_alive = false;
        self.shared.not_full.notify_one();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_roundtrip() {
        let (tx, rx) = ring::<u32>(3);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        tx.send(3).unwrap();
        assert_eq!(rx.recv(), Ok(1));
        tx.send(4).unwrap(); // slot freed above
        assert_eq!(rx.recv(), Ok(2));
        assert_eq!(rx.recv(), Ok(3));
        assert_eq!(rx.recv(), Ok(4));
    }

    #[test]
    fn disconnect_contract() {
        let (tx, rx) = ring::<u8>(2);
        tx.send(7).unwrap();
        drop(tx);
        // queued values drain before the disconnect surfaces
        assert_eq!(rx.recv(), Ok(7));
        assert_eq!(rx.recv(), Err(RecvError));

        let (tx, rx) = ring::<u8>(1);
        drop(rx);
        assert_eq!(tx.send(9), Err(SendError(9)));
    }

    #[test]
    fn full_ring_blocks_until_pop() {
        let (tx, rx) = ring::<usize>(2);
        let producer = std::thread::spawn(move || {
            for i in 0..100 {
                tx.send(i).unwrap();
            }
        });
        for i in 0..100 {
            assert_eq!(rx.recv(), Ok(i));
        }
        producer.join().unwrap();
    }

    #[test]
    fn wraparound_many_cycles() {
        let (tx, rx) = ring::<Vec<u8>>(3);
        let mut buf = vec![0u8; 16];
        for round in 0..50u8 {
            buf[0] = round;
            tx.send(std::mem::take(&mut buf)).unwrap();
            let got = rx.recv().unwrap();
            assert_eq!(got[0], round);
            buf = got; // recycle the buffer like the coordinator does
        }
    }
}
