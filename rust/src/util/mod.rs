//! Self-contained substrates: RNG, JSON, CLI parsing, logging, timing and
//! a mini property-test harness (the image is offline, so `rand`, `serde`,
//! `clap`, `proptest` and friends are unavailable; see DESIGN.md §6).

pub mod affinity;
pub mod bench;
pub mod cli;
pub mod json;
pub mod log;
pub mod prop;
pub mod ring;
pub mod rng;
pub mod timer;

/// Write a CSV file from a header and rows of f64-renderable cells.
pub fn write_csv(path: &std::path::Path, header: &[&str], rows: &[Vec<String>]) -> std::io::Result<()> {
    use std::io::Write;
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "{}", header.join(","))?;
    for row in rows {
        writeln!(f, "{}", row.join(","))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    #[test]
    fn csv_writes_and_reads_back() {
        let dir = std::env::temp_dir().join("smx_csv_test");
        let path = dir.join("t.csv");
        super::write_csv(
            &path,
            &["a", "b"],
            &[vec!["1".into(), "2".into()], vec!["3".into(), "4".into()]],
        )
        .unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        assert_eq!(body, "a,b\n1,2\n3,4\n");
        std::fs::remove_dir_all(&dir).ok();
    }
}
