//! Tiny command-line argument parser (no `clap` offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args and
//! subcommands. Collects unknown flags so callers can error with a usage
//! string.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    flags: BTreeMap<String, Vec<String>>,
}

impl Args {
    /// Parse from an iterator of raw argv strings (excluding argv[0]).
    /// If `with_subcommand` is true, the first non-flag token becomes the
    /// subcommand.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I, with_subcommand: bool) -> Args {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some(eq) = stripped.find('=') {
                    let (k, v) = stripped.split_at(eq);
                    out.flags
                        .entry(k.to_string())
                        .or_default()
                        .push(v[1..].to_string());
                } else {
                    // "--key value" if the next token is not a flag; else boolean.
                    let is_val = iter
                        .peek()
                        .map(|n| !n.starts_with("--"))
                        .unwrap_or(false);
                    if is_val {
                        let v = iter.next().unwrap();
                        out.flags.entry(stripped.to_string()).or_default().push(v);
                    } else {
                        out.flags
                            .entry(stripped.to_string())
                            .or_default()
                            .push("true".to_string());
                    }
                }
            } else if with_subcommand && out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    pub fn from_env(with_subcommand: bool) -> Args {
        Args::parse(std::env::args().skip(1), with_subcommand)
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).and_then(|v| v.last()).map(|s| s.as_str())
    }

    pub fn get_all(&self, key: &str) -> Vec<&str> {
        self.flags
            .get(key)
            .map(|v| v.iter().map(|s| s.as_str()).collect())
            .unwrap_or_default()
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|s| {
                s.parse::<usize>()
                    .unwrap_or_else(|_| panic!("--{key} expects an integer, got '{s}'"))
            })
            .unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|s| {
                s.parse::<u64>()
                    .unwrap_or_else(|_| panic!("--{key} expects an integer, got '{s}'"))
            })
            .unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|s| {
                s.parse::<f64>()
                    .unwrap_or_else(|_| panic!("--{key} expects a number, got '{s}'"))
            })
            .unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        match self.get(key) {
            None => default,
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            Some(s) => panic!("--{key} expects a boolean, got '{s}'"),
        }
    }

    /// Comma-separated list.
    pub fn list_or(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.get(key) {
            Some(v) => v.split(',').map(|s| s.trim().to_string()).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|t| t.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let a = Args::parse(argv("train --dataset a1a --iters 100 --verbose"), true);
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("dataset"), Some("a1a"));
        assert_eq!(a.usize_or("iters", 0), 100);
        assert!(a.bool_or("verbose", false));
    }

    #[test]
    fn equals_form() {
        let a = Args::parse(argv("--tau=4 --mu=1e-3"), false);
        assert_eq!(a.usize_or("tau", 0), 4);
        assert_eq!(a.f64_or("mu", 0.0), 1e-3);
    }

    #[test]
    fn boolean_flag_before_flag() {
        let a = Args::parse(argv("--flag --other 3"), false);
        assert!(a.bool_or("flag", false));
        assert_eq!(a.usize_or("other", 0), 3);
    }

    #[test]
    fn positional_args() {
        let a = Args::parse(argv("run file1 file2 --x 1"), true);
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.positional, vec!["file1", "file2"]);
    }

    #[test]
    fn list_parsing() {
        let a = Args::parse(argv("--datasets a1a,mushrooms , madelon"), false);
        // note: value is a single token "a1a,mushrooms" here
        assert_eq!(a.list_or("datasets", &[]), vec!["a1a", "mushrooms"]);
        let b = Args::parse(vec!["--datasets".into(), "a1a, duke".into()], false);
        assert_eq!(b.list_or("datasets", &[]), vec!["a1a", "duke"]);
    }

    #[test]
    fn defaults() {
        let a = Args::parse(argv(""), false);
        assert_eq!(a.str_or("name", "x"), "x");
        assert_eq!(a.usize_or("n", 5), 5);
        assert_eq!(a.f64_or("f", 2.5), 2.5);
        assert!(!a.bool_or("b", false));
    }

    #[test]
    fn repeated_flags_last_wins_get() {
        let a = Args::parse(argv("--k 1 --k 2"), false);
        assert_eq!(a.get("k"), Some("2"));
        assert_eq!(a.get_all("k"), vec!["1", "2"]);
    }
}
