//! Mini property-testing harness (no `proptest` offline).
//!
//! Runs a property over many seeded random cases and reports the first
//! failing seed, so failures are reproducible by construction. Generators
//! are plain closures over [`Rng`]; there is no shrinking — instead every
//! case prints its seed on failure, which in practice is enough because
//! all our generators are parameterized by small size bounds.

use crate::util::rng::Rng;

pub struct PropConfig {
    pub cases: usize,
    pub base_seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        Self {
            cases: 64,
            base_seed: 0x5EED,
        }
    }
}

impl PropConfig {
    /// `cases` shrunk under Miri (it interprets every instruction, so
    /// full case counts are intractable) — the one shared shrink policy
    /// for every property suite.
    pub fn cases(cases: usize, base_seed: u64) -> PropConfig {
        PropConfig {
            cases: if cfg!(miri) { cases.min(4) } else { cases },
            base_seed,
        }
    }
}

/// Run `prop` for `cfg.cases` seeded cases. The property receives a fresh
/// `Rng` per case and returns `Result<(), String>`; the first failure
/// panics with the seed and message.
pub fn forall(cfg: PropConfig, name: &str, mut prop: impl FnMut(&mut Rng) -> Result<(), String>) {
    for case in 0..cfg.cases {
        let seed = cfg.base_seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed at case {case} (seed={seed:#x}): {msg}");
        }
    }
}

/// Convenience wrapper with defaults.
pub fn check(name: &str, prop: impl FnMut(&mut Rng) -> Result<(), String>) {
    forall(PropConfig::default(), name, prop);
}

/// Assert helper producing a property-friendly Result.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err(format!($($arg)*));
        }
    };
}

/// Assert two f64s are within tolerance.
#[macro_export]
macro_rules! prop_assert_close {
    ($a:expr, $b:expr, $tol:expr) => {{
        let (a, b, tol) = ($a, $b, $tol);
        if (a - b).abs() > tol * (1.0 + a.abs().max(b.abs())) {
            return Err(format!(
                "{} = {a} differs from {} = {b} by {} (> tol {tol})",
                stringify!($a),
                stringify!($b),
                (a - b).abs()
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("addition commutes", |rng| {
            let a = rng.uniform();
            let b = rng.uniform();
            prop_assert!((a + b - (b + a)).abs() < 1e-15, "not commutative");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_seed() {
        check("always fails", |_rng| Err("nope".to_string()));
    }

    #[test]
    fn cases_are_deterministic() {
        let mut first_vals = Vec::new();
        forall(
            PropConfig {
                cases: 5,
                base_seed: 1,
            },
            "record",
            |rng| {
                first_vals.push(rng.next_u64());
                Ok(())
            },
        );
        let mut second_vals = Vec::new();
        forall(
            PropConfig {
                cases: 5,
                base_seed: 1,
            },
            "record2",
            |rng| {
                second_vals.push(rng.next_u64());
                Ok(())
            },
        );
        assert_eq!(first_vals, second_vals);
    }
}
