//! Lightweight leveled logger with monotonic timestamps.
//!
//! The coordinator and experiment drivers log through this; level is
//! controlled by `SMX_LOG` (error|warn|info|debug|trace) or
//! programmatically via [`set_level`].

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn from_str(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(2); // Info

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Initialize from the SMX_LOG environment variable (call once from main).
pub fn init_from_env() {
    if let Ok(v) = std::env::var("SMX_LOG") {
        if let Some(l) = Level::from_str(&v) {
            set_level(l);
        }
    }
}

pub fn enabled(l: Level) -> bool {
    l <= level()
}

fn start_instant() -> Instant {
    use std::sync::OnceLock;
    static START: OnceLock<Instant> = OnceLock::new();
    *START.get_or_init(Instant::now)
}

pub fn log(l: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let dt = start_instant().elapsed();
    eprintln!(
        "[{:>9.3}s {} {}] {}",
        dt.as_secs_f64(),
        l.tag(),
        target,
        msg
    );
}

#[macro_export]
macro_rules! info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warnlog {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debuglog {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug, $target, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing() {
        assert_eq!(Level::from_str("info"), Some(Level::Info));
        assert_eq!(Level::from_str("TRACE"), Some(Level::Trace));
        assert_eq!(Level::from_str("nope"), None);
    }

    #[test]
    fn level_ordering_gates() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
