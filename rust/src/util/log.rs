//! Lightweight leveled logger with monotonic timestamps.
//!
//! The coordinator and experiment drivers log through this; level is
//! controlled by `SMX_LOG` (error|warn|info|debug|trace) or
//! programmatically via [`set_level`]. Output format is controlled by
//! `SMX_LOG_FORMAT` (`text`, the default, or `json` — one JSON object
//! per line with `ts`/`level`/`target`/`msg` keys, so serve logs are
//! machine-ingestable next to the `/metrics` endpoint) or via
//! [`set_format`].

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn from_str(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }

    /// Lowercase name without padding, used by the JSON format.
    pub fn name(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
            Level::Trace => "trace",
        }
    }
}

/// Line format for emitted log records.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum Format {
    /// `[  12.345s INFO  wire] message` (the default).
    Text = 0,
    /// `{"ts":12.345,"level":"info","target":"wire","msg":"message"}`.
    Json = 1,
}

static LEVEL: AtomicU8 = AtomicU8::new(2); // Info
static FORMAT: AtomicU8 = AtomicU8::new(0); // Text

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn set_format(format: Format) {
    FORMAT.store(format as u8, Ordering::Relaxed);
}

pub fn format() -> Format {
    match FORMAT.load(Ordering::Relaxed) {
        1 => Format::Json,
        _ => Format::Text,
    }
}

pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Initialize from the `SMX_LOG` / `SMX_LOG_FORMAT` environment
/// variables (call once from main).
pub fn init_from_env() {
    if let Ok(v) = std::env::var("SMX_LOG") {
        if let Some(l) = Level::from_str(&v) {
            set_level(l);
        }
    }
    if let Ok(v) = std::env::var("SMX_LOG_FORMAT") {
        match v.to_ascii_lowercase().as_str() {
            "json" => set_format(Format::Json),
            "text" => set_format(Format::Text),
            _ => {}
        }
    }
}

pub fn enabled(l: Level) -> bool {
    l <= level()
}

fn start_instant() -> Instant {
    use std::sync::OnceLock;
    static START: OnceLock<Instant> = OnceLock::new();
    *START.get_or_init(Instant::now)
}

/// Escape `s` for embedding inside a JSON string literal. Covers the
/// characters our log lines can produce (quotes, backslashes, control
/// characters); everything else passes through verbatim.
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

pub fn log(l: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let dt = start_instant().elapsed();
    match format() {
        Format::Text => eprintln!(
            "[{:>9.3}s {} {}] {}",
            dt.as_secs_f64(),
            l.tag(),
            target,
            msg
        ),
        Format::Json => eprintln!(
            "{{\"ts\":{:.3},\"level\":\"{}\",\"target\":\"{}\",\"msg\":\"{}\"}}",
            dt.as_secs_f64(),
            l.name(),
            json_escape(target),
            json_escape(&msg.to_string())
        ),
    }
}

#[macro_export]
macro_rules! info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warnlog {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debuglog {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug, $target, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing() {
        assert_eq!(Level::from_str("info"), Some(Level::Info));
        assert_eq!(Level::from_str("TRACE"), Some(Level::Trace));
        assert_eq!(Level::from_str("nope"), None);
    }

    #[test]
    fn level_ordering_gates() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }

    #[test]
    fn json_escape_covers_quotes_and_controls() {
        assert_eq!(json_escape("plain"), "plain");
        assert_eq!(json_escape("a\"b"), "a\\\"b");
        assert_eq!(json_escape("back\\slash"), "back\\\\slash");
        assert_eq!(json_escape("nl\ntab\t"), "nl\\ntab\\t");
        assert_eq!(json_escape("\u{1}"), "\\u0001");
    }

    #[test]
    fn json_log_lines_parse_back() {
        // Render the same line the Json format branch would emit and
        // confirm it is valid JSON carrying the escaped message through.
        let msg = "worker 3 \"died\"\nreplaying";
        let line = format!(
            "{{\"ts\":{:.3},\"level\":\"{}\",\"target\":\"{}\",\"msg\":\"{}\"}}",
            1.25,
            Level::Warn.name(),
            json_escape("wire"),
            json_escape(msg)
        );
        let j = crate::util::json::Json::parse(&line).unwrap();
        assert_eq!(j.get("level").as_str(), Some("warn"));
        assert_eq!(j.get("target").as_str(), Some("wire"));
        assert_eq!(j.get("msg").as_str(), Some(msg));
        assert_eq!(j.get("ts").as_f64(), Some(1.25));
    }

    #[test]
    fn format_parsing_roundtrip() {
        set_format(Format::Json);
        assert_eq!(format(), Format::Json);
        set_format(Format::Text);
        assert_eq!(format(), Format::Text);
    }
}
