//! Timing helpers: scoped stopwatches and accumulating phase timers used
//! by the coordinator metrics and the §Perf profiling pass.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Simple stopwatch.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self {
            start: Instant::now(),
        }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Maps a fine-grained phase name onto one of the three coarse buckets
/// the per-round records expose (`compute` / `encode` / `wire`), or
/// `None` for phases that must not be attributed (whole-round umbrella
/// spans like `dist_round` would double-count their children).
pub fn phase_bucket(phase: &str) -> Option<&'static str> {
    match phase {
        "worker_round" | "server_apply" => Some("compute"),
        "server_downlink" | "encode" => Some("encode"),
        "scatter" | "gather" | "wire_wait" => Some("wire"),
        _ => None,
    }
}

/// Accumulates durations per named phase; used to break down where a
/// coordinator round spends its time (grad / compress / network / server).
#[derive(Debug, Default, Clone)]
pub struct PhaseTimer {
    acc: BTreeMap<&'static str, (Duration, u64)>,
}

impl PhaseTimer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, phase: &'static str, d: Duration) {
        let e = self.acc.entry(phase).or_insert((Duration::ZERO, 0));
        e.0 += d;
        e.1 += 1;
    }

    /// Time a closure and attribute it to `phase`.
    pub fn time<T>(&mut self, phase: &'static str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(phase, t0.elapsed());
        out
    }

    pub fn total(&self, phase: &str) -> Duration {
        self.acc
            .get(phase)
            .map(|(d, _)| *d)
            .unwrap_or(Duration::ZERO)
    }

    pub fn count(&self, phase: &str) -> u64 {
        self.acc.get(phase).map(|(_, c)| *c).unwrap_or(0)
    }

    /// Cumulative seconds folded into the three coarse buckets of
    /// [`phase_bucket`], in `(compute, encode, wire)` order. Phases
    /// mapping to `None` are excluded.
    pub fn bucket_totals(&self) -> (f64, f64, f64) {
        let (mut compute, mut encode, mut wire) = (0.0, 0.0, 0.0);
        for (phase, (d, _)) in &self.acc {
            match phase_bucket(phase) {
                Some("compute") => compute += d.as_secs_f64(),
                Some("encode") => encode += d.as_secs_f64(),
                Some("wire") => wire += d.as_secs_f64(),
                _ => {}
            }
        }
        (compute, encode, wire)
    }

    pub fn merge(&mut self, other: &PhaseTimer) {
        for (k, (d, c)) in &other.acc {
            let e = self.acc.entry(k).or_insert((Duration::ZERO, 0));
            e.0 += *d;
            e.1 += *c;
        }
    }

    pub fn report(&self) -> String {
        let mut entries: Vec<_> = self.acc.iter().collect();
        entries.sort_by(|a, b| b.1 .0.cmp(&a.1 .0));
        let mut s = String::new();
        for (k, (d, c)) in entries {
            s.push_str(&format!(
                "{:<18} total={:>10.3}ms calls={:>8} avg={:>8.3}us\n",
                k,
                d.as_secs_f64() * 1e3,
                c,
                d.as_secs_f64() * 1e6 / (*c).max(1) as f64,
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn stopwatch_measures() {
        let sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(5));
        assert!(sw.elapsed_secs() >= 0.004);
    }

    #[test]
    fn phase_timer_accumulates() {
        let mut pt = PhaseTimer::new();
        pt.add("a", Duration::from_millis(2));
        pt.add("a", Duration::from_millis(3));
        pt.add("b", Duration::from_millis(1));
        assert_eq!(pt.count("a"), 2);
        assert_eq!(pt.total("a"), Duration::from_millis(5));
        assert_eq!(pt.count("missing"), 0);
    }

    #[test]
    fn phase_timer_time_closure() {
        let mut pt = PhaseTimer::new();
        let x = pt.time("work", || 21 * 2);
        assert_eq!(x, 42);
        assert_eq!(pt.count("work"), 1);
    }

    #[test]
    fn bucket_totals_fold_known_phases_and_skip_umbrellas() {
        let mut pt = PhaseTimer::new();
        pt.add("worker_round", Duration::from_millis(10));
        pt.add("server_apply", Duration::from_millis(5));
        pt.add("server_downlink", Duration::from_millis(2));
        pt.add("gather", Duration::from_millis(7));
        pt.add("wire_wait", Duration::from_millis(3));
        pt.add("dist_round", Duration::from_millis(100)); // umbrella: excluded
        let (c, e, w) = pt.bucket_totals();
        assert!((c - 0.015).abs() < 1e-9, "compute {c}");
        assert!((e - 0.002).abs() < 1e-9, "encode {e}");
        assert!((w - 0.010).abs() < 1e-9, "wire {w}");
        assert_eq!(phase_bucket("dist_round"), None);
        assert_eq!(phase_bucket("scatter"), Some("wire"));
    }

    #[test]
    fn merge_combines() {
        let mut a = PhaseTimer::new();
        a.add("x", Duration::from_millis(1));
        let mut b = PhaseTimer::new();
        b.add("x", Duration::from_millis(2));
        b.add("y", Duration::from_millis(3));
        a.merge(&b);
        assert_eq!(a.total("x"), Duration::from_millis(3));
        assert_eq!(a.count("y"), 1);
        assert!(a.report().contains("x"));
    }
}
