//! Timing helpers: scoped stopwatches and accumulating phase timers used
//! by the coordinator metrics and the §Perf profiling pass.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Simple stopwatch.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self {
            start: Instant::now(),
        }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Accumulates durations per named phase; used to break down where a
/// coordinator round spends its time (grad / compress / network / server).
#[derive(Debug, Default, Clone)]
pub struct PhaseTimer {
    acc: BTreeMap<&'static str, (Duration, u64)>,
}

impl PhaseTimer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, phase: &'static str, d: Duration) {
        let e = self.acc.entry(phase).or_insert((Duration::ZERO, 0));
        e.0 += d;
        e.1 += 1;
    }

    /// Time a closure and attribute it to `phase`.
    pub fn time<T>(&mut self, phase: &'static str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(phase, t0.elapsed());
        out
    }

    pub fn total(&self, phase: &str) -> Duration {
        self.acc
            .get(phase)
            .map(|(d, _)| *d)
            .unwrap_or(Duration::ZERO)
    }

    pub fn count(&self, phase: &str) -> u64 {
        self.acc.get(phase).map(|(_, c)| *c).unwrap_or(0)
    }

    pub fn merge(&mut self, other: &PhaseTimer) {
        for (k, (d, c)) in &other.acc {
            let e = self.acc.entry(k).or_insert((Duration::ZERO, 0));
            e.0 += *d;
            e.1 += *c;
        }
    }

    pub fn report(&self) -> String {
        let mut entries: Vec<_> = self.acc.iter().collect();
        entries.sort_by(|a, b| b.1 .0.cmp(&a.1 .0));
        let mut s = String::new();
        for (k, (d, c)) in entries {
            s.push_str(&format!(
                "{:<18} total={:>10.3}ms calls={:>8} avg={:>8.3}us\n",
                k,
                d.as_secs_f64() * 1e3,
                c,
                d.as_secs_f64() * 1e6 / (*c).max(1) as f64,
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn stopwatch_measures() {
        let sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(5));
        assert!(sw.elapsed_secs() >= 0.004);
    }

    #[test]
    fn phase_timer_accumulates() {
        let mut pt = PhaseTimer::new();
        pt.add("a", Duration::from_millis(2));
        pt.add("a", Duration::from_millis(3));
        pt.add("b", Duration::from_millis(1));
        assert_eq!(pt.count("a"), 2);
        assert_eq!(pt.total("a"), Duration::from_millis(5));
        assert_eq!(pt.count("missing"), 0);
    }

    #[test]
    fn phase_timer_time_closure() {
        let mut pt = PhaseTimer::new();
        let x = pt.time("work", || 21 * 2);
        assert_eq!(x, 42);
        assert_eq!(pt.count("work"), 1);
    }

    #[test]
    fn merge_combines() {
        let mut a = PhaseTimer::new();
        a.add("x", Duration::from_millis(1));
        let mut b = PhaseTimer::new();
        b.add("x", Duration::from_millis(2));
        b.add("y", Duration::from_millis(3));
        a.merge(&b);
        assert_eq!(a.total("x"), Duration::from_millis(3));
        assert_eq!(a.count("y"), 1);
        assert!(a.report().contains("x"));
    }
}
