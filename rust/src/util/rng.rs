//! Deterministic pseudo-random number generation.
//!
//! The image has no network access to crates.io, so `rand` is unavailable;
//! this module provides the subset the library needs: a SplitMix64 seeder,
//! a xoshiro256++ core generator, uniform/normal/Bernoulli sampling,
//! Fisher–Yates shuffling and subset sampling. All experiments are seeded
//! so every figure/table is exactly reproducible.

/// SplitMix64: used to expand a single `u64` seed into the xoshiro state.
/// Passes BigCrush when used as a standalone generator; here it only seeds.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — the library's workhorse generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal deviate from Box–Muller
    gauss_spare: Option<f64>,
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

impl Rng {
    /// Seed from a single u64 via SplitMix64 (the reference seeding scheme).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            gauss_spare: None,
        }
    }

    /// Derive an independent stream (e.g. one per worker) from this seed
    /// without advancing `self` identically: mixes the label into the seed.
    pub fn derive(&self, label: u64) -> Rng {
        // Hash the current state with the label through SplitMix64.
        let mut sm = SplitMix64::new(
            self.s[0] ^ self.s[1].rotate_left(17) ^ label.wrapping_mul(0x9E3779B97F4A7C15),
        );
        Rng::new(sm.next_u64())
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }

    /// Uniform f64 in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Bernoulli trial with success probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Standard normal via Box–Muller (with caching of the spare deviate).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // Rejection-free polar-less Box–Muller; u1 in (0,1] to avoid ln(0).
        let u1 = 1.0 - self.uniform();
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        if xs.len() < 2 {
            return;
        }
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (Floyd's algorithm), sorted.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut chosen = std::collections::BTreeSet::new();
        for j in (n - k)..n {
            let t = self.below(j + 1);
            if !chosen.insert(t) {
                chosen.insert(j);
            }
        }
        chosen.into_iter().collect()
    }

    /// A random permutation of [0, n).
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Serialized size of [`Rng::save_state`]: 4×u64 core state, a
    /// presence flag, and the cached Box–Muller spare.
    pub const STATE_BYTES: usize = 4 * 8 + 1 + 8;

    /// Append the full generator state (including the cached Box–Muller
    /// spare) to `out`. [`Rng::load_state`] restores a generator that
    /// continues the stream bit-for-bit — the wire runtime's checkpoint
    /// snapshots rely on this to resume a shard mid-run.
    pub fn save_state(&self, out: &mut Vec<u8>) {
        for w in self.s {
            out.extend_from_slice(&w.to_le_bytes());
        }
        match self.gauss_spare {
            Some(z) => {
                out.push(1);
                out.extend_from_slice(&z.to_bits().to_le_bytes());
            }
            None => {
                out.push(0);
                out.extend_from_slice(&[0u8; 8]);
            }
        }
    }

    /// Rebuild a generator from the first [`Rng::STATE_BYTES`] bytes of
    /// `buf` (written by [`Rng::save_state`]). Returns `None` on a short
    /// or malformed buffer.
    pub fn load_state(buf: &[u8]) -> Option<Rng> {
        if buf.len() < Self::STATE_BYTES {
            return None;
        }
        let mut s = [0u64; 4];
        for (i, w) in s.iter_mut().enumerate() {
            *w = u64::from_le_bytes(buf[i * 8..(i + 1) * 8].try_into().ok()?);
        }
        let gauss_spare = match buf[32] {
            0 => None,
            1 => Some(f64::from_bits(u64::from_le_bytes(
                buf[33..41].try_into().ok()?,
            ))),
            _ => return None,
        };
        Some(Rng { s, gauss_spare })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_values() {
        // Reference values for seed 1234567 (computed from the canonical
        // Vigna implementation).
        let mut sm = SplitMix64::new(0);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // determinism
        let mut sm2 = SplitMix64::new(0);
        assert_eq!(sm2.next_u64(), a);
        assert_eq!(sm2.next_u64(), b);
    }

    #[test]
    fn deterministic_streams() {
        let mut r1 = Rng::new(42);
        let mut r2 = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(r1.next_u64(), r2.next_u64());
        }
    }

    #[test]
    fn distinct_seeds_distinct_streams() {
        let mut r1 = Rng::new(1);
        let mut r2 = Rng::new(2);
        let same = (0..64).filter(|_| r1.next_u64() == r2.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_and_variance() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let mut sum = 0.0;
        let mut sum2 = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            sum += u;
            sum2 += u * u;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 5e-3, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 5e-3, "var={var}");
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(3);
        let n = 7;
        let trials = 70_000;
        let mut counts = vec![0usize; n];
        for _ in 0..trials {
            counts[r.below(n)] += 1;
        }
        let expected = trials as f64 / n as f64;
        for c in counts {
            assert!((c as f64 - expected).abs() < 0.08 * expected);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            s += z;
            s2 += z * z;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn bernoulli_rate() {
        let mut r = Rng::new(9);
        let p = 0.3;
        let n = 100_000;
        let hits = (0..n).filter(|_| r.bernoulli(p)).count();
        assert!((hits as f64 / n as f64 - p).abs() < 0.01);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = Rng::new(17);
        for _ in 0..100 {
            let s = r.sample_indices(50, 10);
            assert_eq!(s.len(), 10);
            for w in s.windows(2) {
                assert!(w[0] < w[1]);
            }
            assert!(s.iter().all(|&i| i < 50));
        }
    }

    #[test]
    fn derive_gives_independent_streams() {
        let base = Rng::new(99);
        let mut a = base.derive(0);
        let mut b = base.derive(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn save_load_state_resumes_stream_bitwise() {
        let mut r = Rng::new(31);
        // advance through an odd number of normal() calls so the Box–Muller
        // spare is populated — the snapshot must carry it
        for _ in 0..7 {
            r.normal();
        }
        let mut blob = Vec::new();
        r.save_state(&mut blob);
        assert_eq!(blob.len(), Rng::STATE_BYTES);
        let mut restored = Rng::load_state(&blob).unwrap();
        for _ in 0..100 {
            assert_eq!(r.next_u64(), restored.next_u64());
        }
        assert_eq!(r.normal().to_bits(), restored.normal().to_bits());
        // truncated and corrupted flags are rejected
        assert!(Rng::load_state(&blob[..Rng::STATE_BYTES - 1]).is_none());
        let mut bad = blob.clone();
        bad[32] = 7;
        assert!(Rng::load_state(&bad).is_none());
    }

    #[test]
    fn permutation_covers_all() {
        let mut r = Rng::new(21);
        let p = r.permutation(64);
        let mut seen = vec![false; 64];
        for i in p {
            seen[i] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }
}
