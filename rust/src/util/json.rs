//! Minimal JSON parser + serializer.
//!
//! Used for experiment configs, the AOT artifact `manifest.json`, and
//! results output. `serde_json` is unavailable offline, so this is a
//! small, strict, self-contained implementation covering the JSON we
//! produce and consume (objects, arrays, strings with escapes, numbers,
//! booleans, null).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]`-style access; returns Null for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // ---- builders --------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_str(xs: &[&str]) -> Json {
        Json::Arr(xs.iter().map(|s| Json::Str(s.to_string())).collect())
    }

    // ---- serialization ---------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(*x, out),
            Json::Str(s) => write_str(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(a) if !a.is_empty() => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    v.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push(']');
            }
            Json::Obj(o) if !o.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    write_str(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

fn write_num(x: f64, out: &mut String) {
    if x.is_finite() {
        if x.fract() == 0.0 && x.abs() < 1e15 {
            out.push_str(&format!("{}", x as i64));
        } else {
            // Round-trippable shortest representation.
            out.push_str(&format!("{x:?}"));
        }
    } else {
        // JSON has no Inf/NaN — encode as null (documented limitation).
        out.push_str("null");
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.pos,
        }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000C}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // BMP only (sufficient for our manifests).
                            s.push(char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?);
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let txt = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(j.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(j.get("a").as_arr().unwrap()[0].as_f64(), Some(1.0));
        assert!(j.get("a").as_arr().unwrap()[2].get("b").is_null());
        assert_eq!(j.get("c").as_str(), Some("x\ny"));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\":}").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"alpha":1.5,"arr":[true,false,null,"s"],"n":-7}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string();
        let j2 = Json::parse(&out).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn roundtrip_floats() {
        for &x in &[0.1, 1e-17, 123456.789, -2.5e300, 1.0 / 3.0] {
            let s = Json::Num(x).to_string();
            let back = Json::parse(&s).unwrap().as_f64().unwrap();
            assert_eq!(back, x, "failed for {x}: {s}");
        }
    }

    #[test]
    fn string_escapes_roundtrip() {
        let s = "line1\nline2\t\"quoted\" \\ slash";
        let j = Json::Str(s.to_string());
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back.as_str(), Some(s));
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str(), Some("Aé"));
    }

    #[test]
    fn pretty_parses_back() {
        let j = Json::obj(vec![
            ("x", Json::arr_f64(&[1.0, 2.0])),
            ("name", Json::Str("t".into())),
            ("empty", Json::Arr(vec![])),
        ]);
        let pretty = j.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), j);
    }

    #[test]
    fn as_usize_guards() {
        assert_eq!(Json::Num(3.0).as_usize(), Some(3));
        assert_eq!(Json::Num(3.5).as_usize(), None);
        assert_eq!(Json::Num(-1.0).as_usize(), None);
    }

    #[test]
    fn get_on_non_object_is_null() {
        assert!(Json::Num(1.0).get("k").is_null());
    }
}
