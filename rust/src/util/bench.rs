//! Minimal benchmarking harness (criterion is unavailable offline):
//! warmup + timed iterations, reporting min/median/p95/mean. Used by the
//! `benches/` targets (`cargo bench`, harness = false).

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub min_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub mean_ns: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10} iters  min {:>12}  med {:>12}  p95 {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.min_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.p95_ns),
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.2}s", ns / 1e9)
    }
}

/// Time `f` with automatic iteration count targeting ~`budget_ms` total.
pub fn bench(name: &str, budget_ms: u64, mut f: impl FnMut()) -> BenchResult {
    // warmup + calibration
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_nanos().max(1) as f64;
    let budget_ns = budget_ms as f64 * 1e6;
    let iters = ((budget_ns / once) as usize).clamp(3, 10_000);

    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let result = BenchResult {
        name: name.to_string(),
        iters,
        min_ns: samples[0],
        median_ns: samples[samples.len() / 2],
        p95_ns: samples[(samples.len() * 95 / 100).min(samples.len() - 1)],
        mean_ns: samples.iter().sum::<f64>() / samples.len() as f64,
    };
    println!("{}", result.report());
    result
}

/// Time a single long-running closure (end-to-end benches) and report.
pub fn bench_once<T>(name: &str, f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    let secs = t0.elapsed().as_secs_f64();
    println!("{name:<44} {secs:>10.3}s");
    (out, secs)
}

/// Prevent the optimizer from discarding a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_ordered_stats() {
        let mut acc = 0u64;
        let r = bench("noop-ish", 5, || {
            acc = acc.wrapping_add(black_box(1));
        });
        assert!(r.min_ns <= r.median_ns && r.median_ns <= r.p95_ns);
        assert!(r.iters >= 3);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5e4).ends_with("us"));
        assert!(fmt_ns(5e7).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with('s'));
    }

    #[test]
    fn bench_once_returns_value() {
        let (v, secs) = bench_once("compute", || 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}
