//! Opt-in CPU core pinning (`--pin`).
//!
//! On Linux this calls `sched_setaffinity(2)` directly (declared here —
//! the offline image has no `libc` crate; the symbol lives in the same
//! libc that `std` already links). Everywhere else pinning is a no-op
//! that reports `false`, so `--pin` degrades gracefully instead of
//! failing the run.
//!
//! Pinning is *per calling thread*: `pid = 0` addresses the current
//! thread's scheduling entity, which is exactly what
//! [`run_threaded_observed`](crate::coordinator::run_threaded_observed)
//! wants (worker `i`
//! pins itself from inside its own thread) and what a single-threaded
//! `smx worker` process wants (pin the whole round loop).

/// Pin the calling thread to `core` (modulo the online core count, so
/// over-subscribed worker grids wrap instead of erroring). Returns whether
/// the affinity call succeeded; callers treat `false` as "run unpinned".
pub fn pin_to_core(core: usize) -> bool {
    imp::pin_to_core(core % available_cores().max(1))
}

/// Online cores, as reported by the standard library (1 if unknown).
pub fn available_cores() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

#[cfg(target_os = "linux")]
mod imp {
    extern "C" {
        /// glibc/musl prototype: `int sched_setaffinity(pid_t, size_t,
        /// const cpu_set_t *)`. `cpu_set_t` is an opaque 1024-bit mask; a
        /// `[u64; 16]` has the same size and layout (little-endian bit
        /// order per word matches the kernel ABI on every Linux target we
        /// build for).
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    }

    pub fn pin_to_core(core: usize) -> bool {
        const MASK_WORDS: usize = 16; // 1024 CPUs, the glibc cpu_set_t size
        if core >= MASK_WORDS * 64 {
            return false;
        }
        let mut mask = [0u64; MASK_WORDS];
        mask[core / 64] |= 1u64 << (core % 64);
        // SAFETY: `mask` outlives the call, its size is passed alongside,
        // and pid 0 = the calling thread (no aliasing of foreign state).
        unsafe { sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr()) == 0 }
    }
}

#[cfg(not(target_os = "linux"))]
mod imp {
    pub fn pin_to_core(_core: usize) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pin_is_safe_to_call_anywhere() {
        // On Linux this actually pins the test thread (harmless: the
        // thread ends with the test); elsewhere it must return false
        // without side effects. Either way: no panic, and wrapped cores
        // behave like their representative.
        let a = pin_to_core(0);
        let b = pin_to_core(available_cores()); // wraps to core 0
        assert_eq!(a, b);
        if !cfg!(target_os = "linux") {
            assert!(!a);
        }
    }
}
