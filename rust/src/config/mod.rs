//! Experiment configuration: JSON files + CLI overrides, shared by the
//! `smx` binary, the examples and the bench harness.
//!
//! Example config (see `configs/` at the repo root):
//!
//! ```json
//! {
//!   "dataset": "a1a",
//!   "workers": 0,
//!   "mu": 1e-3,
//!   "tau": 1.0,
//!   "methods": ["diana", "diana+"],
//!   "sampling": "importance-diana",
//!   "max_rounds": 20000,
//!   "target_residual": 1e-12,
//!   "seed": 42,
//!   "engine": "native",
//!   "wire": {
//!     "payload": "f32",
//!     "listen": "127.0.0.1:4950",
//!     "workers": 2,
//!     "float_bits": 32
//!   }
//! }
//! ```
//!
//! `workers: 0` means "use the dataset's Table-3 default".
//!
//! The `driver` key (`--driver auto|sim|threaded|distributed`) selects
//! the execution regime through the
//! [`Session`](crate::coordinator::Session) front door, and
//! `checkpoint_every` (`--checkpoint-every`) the checkpoint cadence —
//! see [`crate::coordinator::session`].
//!
//! The `wire` section configures the [`crate::wire`] subsystem:
//! `payload` is the value encoding (`f64`/`f32`/`q16`/`q8`/`q4`),
//! `listen` the `smx serve` address, `workers` the number of worker
//! *processes* a serve run waits for (0 ⇒ one per shard), `float_bits`
//! optionally overrides the modeled bit account (it defaults to the
//! payload's width, so `"payload": "f32"` reproduces Appendix C.5's
//! 32-bit accounting with no further flags), `worker_timeout` is the
//! fault-tolerance grace window in seconds (`--worker-timeout`; 0
//! disables fault handling), `run_dir` (`--run-dir`) points `smx serve`
//! at a durable run-log directory for crash-restart resume, `crc`
//! (default on; `--no-crc` disables) appends a CRC32 trailer to every
//! frame, and `fault_plan` (`--fault-plan`) schedules server-side fault
//! injection (see [`crate::wire::fault`]). The top-level `pin` key
//! (`--pin`) opts into per-worker core pinning in the threaded driver.

use crate::compress::{CompressorKind, QuantWeighting};
use crate::coordinator::DriverKind;
use crate::data::{spec_by_name, synth};
use crate::runtime::EngineKind;
use crate::sampling::SamplingKind;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::wire::Payload;
use anyhow::{bail, Context, Result};

/// Wire-subsystem settings (`"wire": {…}` in configs).
#[derive(Clone, Debug)]
pub struct WireConfig {
    /// value payload for every encoded message
    pub payload: Payload,
    /// `smx serve` listen address
    pub listen: String,
    /// worker processes a serve run expects; 0 ⇒ one per shard
    pub workers: usize,
    /// override the modeled bit account's float width (None ⇒ payload width)
    pub float_bits: Option<u32>,
    /// fault-tolerance grace window in seconds: how long a worker may
    /// stay silent mid-gather before its shards are orphaned, and how
    /// long the server waits for a rejoining replacement before
    /// reassigning them to survivors. 0 disables fault handling (any
    /// worker failure aborts the run). Must exceed the slowest
    /// single-shard round computation — workers cannot heartbeat
    /// mid-gradient.
    pub worker_timeout: f64,
    /// durable run-log directory (`--run-dir`): `smx serve` persists the
    /// downlink journal and committed snapshots there and, on restart,
    /// resumes the interrupted run bitwise identically (see
    /// [`crate::wire::runlog`]). None ⇒ in-memory journal only.
    pub run_dir: Option<String>,
    /// CRC32-guard every wire frame and run-log record (`--no-crc`
    /// disables the trailer on the socket; the run log always checks)
    pub crc: bool,
    /// scriptable fault-injection schedule (`--fault-plan`; grammar in
    /// [`crate::wire::fault`]). Server-side events only — workers take
    /// their plans on their own command line.
    pub fault_plan: Option<String>,
    /// observability HTTP listener address (`--metrics-addr`): `smx
    /// serve` multiplexes a Prometheus-text `GET /metrics` + `GET
    /// /healthz` endpoint onto its epoll loop there (see
    /// [`crate::obs`]). None ⇒ no listener. Pure plumbing — cannot
    /// affect the trajectory and is excluded from
    /// [`ExperimentConfig::canonical_identity`].
    pub metrics_addr: Option<String>,
    /// relay-tier topology spec (`--relay`): comma-separated branch
    /// factors per tier below the server, e.g. `"2"` (server talks to 2
    /// relays, workers hang off them) or `"2,2"` (two relay tiers). When
    /// set, `smx serve` expects `tier-1` direct connections instead of
    /// `effective_procs` — each a `smx relay` process that fans the rest
    /// of the tree out. Pure plumbing: relays merge uplink frames
    /// verbatim ([`crate::wire::codec::merge_uplinks`]) so the topology
    /// cannot affect the trajectory, and this field is excluded from
    /// [`ExperimentConfig::canonical_identity`]. None ⇒ flat topology.
    pub relays: Option<String>,
    /// partial-participation spec (`--participation tau=K`): every round
    /// the coordinator samples an unbiased cohort of K shards and only
    /// they compute/uplink, reweighted by n/K before aggregation (see
    /// [`crate::coordinator::membership`]). The cohort sequence is a
    /// pure function of the run seed, so all three drivers stay bitwise
    /// identical; `tau=n` (or None) is exactly full participation. A
    /// **trajectory** field — included in
    /// [`ExperimentConfig::canonical_identity`].
    pub participation: Option<String>,
    /// member floor for `smx serve` (`--min-clients`): start rounds once
    /// this many worker processes are live instead of waiting for the
    /// full complement; stragglers late-join mid-run through the
    /// snapshot/replay handshake. 0 ⇒ wait for everyone (today's
    /// behavior). Operational — excluded from
    /// [`ExperimentConfig::canonical_identity`] (the trajectory is
    /// membership-invariant: a gather simply waits on shards whose host
    /// has not arrived yet).
    pub min_clients: usize,
}

impl Default for WireConfig {
    fn default() -> Self {
        WireConfig {
            payload: Payload::F64,
            listen: "127.0.0.1:4950".to_string(),
            workers: 0,
            float_bits: None,
            worker_timeout: 30.0,
            run_dir: None,
            crc: true,
            fault_plan: None,
            metrics_addr: None,
            relays: None,
            participation: None,
            min_clients: 0,
        }
    }
}

impl WireConfig {
    /// Float width for the modeled bit account: explicit override or the
    /// payload's width (f64→64, f32→32, qb→b).
    pub fn effective_float_bits(&self) -> u32 {
        self.float_bits.unwrap_or(self.payload.bits())
    }

    /// Worker processes for an n-shard serve run.
    pub fn effective_procs(&self, n_shards: usize) -> usize {
        if self.workers == 0 {
            n_shards
        } else {
            self.workers.min(n_shards)
        }
    }

    /// Parsed relay topology: branch factors per tier below the server,
    /// or None for the flat topology. Errors on empty/zero/non-numeric
    /// tiers (`"2"` and `"2,2"` are valid; `"2,0"` is not).
    pub fn relay_tiers(&self) -> Result<Option<Vec<usize>>> {
        let Some(spec) = &self.relays else {
            return Ok(None);
        };
        let mut tiers = Vec::new();
        for part in spec.split(',') {
            let n: usize = part
                .trim()
                .parse()
                .ok()
                .filter(|&n| n > 0)
                .with_context(|| {
                    format!(
                        "bad relay topology '{spec}': tiers are comma-separated \
                         positive branch factors (e.g. '2' or '2,2')"
                    )
                })?;
            tiers.push(n);
        }
        Ok(Some(tiers))
    }

    /// Parsed participation spec: the per-round cohort size τ, or None
    /// for full participation. Accepts `tau=K` (K ≥ 1) or the explicit
    /// sentinel `full`.
    pub fn participation_tau(&self) -> Result<Option<usize>> {
        let Some(spec) = &self.participation else {
            return Ok(None);
        };
        let s = spec.trim();
        if s.eq_ignore_ascii_case("full") {
            return Ok(None);
        }
        let tau = s
            .strip_prefix("tau=")
            .and_then(|v| v.trim().parse::<usize>().ok())
            .filter(|&k| k > 0)
            .with_context(|| {
                format!(
                    "bad participation spec '{spec}': expected 'tau=K' with K >= 1 \
                     (or 'full')"
                )
            })?;
        Ok(Some(tau))
    }

    /// Direct connections `smx serve` should accept: the first relay
    /// tier's width when a relay topology is set, else one per worker
    /// process.
    pub fn direct_peers(&self, n_shards: usize) -> Result<usize> {
        Ok(match self.relay_tiers()? {
            Some(tiers) => tiers[0].min(n_shards),
            None => self.effective_procs(n_shards),
        })
    }

    fn from_json(j: &Json) -> Result<WireConfig> {
        let mut w = WireConfig::default();
        let obj = j.as_obj().context("wire section must be a JSON object")?;
        for (k, v) in obj {
            match k.as_str() {
                "payload" => {
                    let s = v.as_str().context("wire.payload")?;
                    w.payload = Payload::parse(s)
                        .with_context(|| format!("bad wire payload '{s}'"))?;
                }
                "listen" => w.listen = v.as_str().context("wire.listen")?.to_string(),
                "workers" => w.workers = v.as_usize().context("wire.workers")?,
                "float_bits" => {
                    w.float_bits = Some(v.as_usize().context("wire.float_bits")? as u32)
                }
                "worker_timeout" => {
                    w.worker_timeout = v.as_f64().context("wire.worker_timeout")?
                }
                "run_dir" => w.run_dir = Some(v.as_str().context("wire.run_dir")?.to_string()),
                "crc" => w.crc = v.as_bool().context("wire.crc")?,
                "fault_plan" => {
                    w.fault_plan = Some(v.as_str().context("wire.fault_plan")?.to_string())
                }
                "metrics_addr" => {
                    w.metrics_addr = Some(v.as_str().context("wire.metrics_addr")?.to_string())
                }
                "relays" => w.relays = Some(v.as_str().context("wire.relays")?.to_string()),
                "participation" => {
                    w.participation = Some(v.as_str().context("wire.participation")?.to_string())
                }
                "min_clients" => w.min_clients = v.as_usize().context("wire.min_clients")?,
                other => bail!("unknown wire config key '{other}'"),
            }
        }
        Ok(w)
    }

    fn to_json(&self) -> Json {
        let mut fields = vec![
            ("payload", Json::Str(self.payload.name().to_string())),
            ("listen", Json::Str(self.listen.clone())),
            ("workers", Json::Num(self.workers as f64)),
            ("worker_timeout", Json::Num(self.worker_timeout)),
            ("crc", Json::Bool(self.crc)),
        ];
        if let Some(b) = self.float_bits {
            fields.push(("float_bits", Json::Num(b as f64)));
        }
        if let Some(d) = &self.run_dir {
            fields.push(("run_dir", Json::Str(d.clone())));
        }
        if let Some(p) = &self.fault_plan {
            fields.push(("fault_plan", Json::Str(p.clone())));
        }
        if let Some(a) = &self.metrics_addr {
            fields.push(("metrics_addr", Json::Str(a.clone())));
        }
        if let Some(r) = &self.relays {
            fields.push(("relays", Json::Str(r.clone())));
        }
        if let Some(p) = &self.participation {
            fields.push(("participation", Json::Str(p.clone())));
        }
        if self.min_clients != 0 {
            fields.push(("min_clients", Json::Num(self.min_clients as f64)));
        }
        Json::obj(fields)
    }
}

#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub dataset: String,
    /// 0 ⇒ dataset default (Table 3)
    pub workers: usize,
    pub mu: f64,
    pub tau: f64,
    pub methods: Vec<String>,
    pub sampling: SamplingKind,
    pub max_rounds: usize,
    pub target_residual: f64,
    pub record_every: usize,
    pub seed: u64,
    pub engine: EngineKind,
    /// execution regime (`--driver auto|sim|threaded|distributed`):
    /// `auto` keeps the historical mapping (native engine → sim driver,
    /// PJRT → threaded); `distributed` runs the full wire protocol over
    /// loopback with `wire.workers` worker threads. Resolved by
    /// [`Session::run`](crate::coordinator::Session::run).
    pub driver: DriverKind,
    /// checkpoint cadence in rounds (`--checkpoint-every`, 0 = off):
    /// fires observer checkpoints on every driver, and drives the wire
    /// runtime's journal snapshot + truncation under `smx serve`
    pub checkpoint_every: usize,
    pub data_dir: Option<std::path::PathBuf>,
    pub out_dir: std::path::PathBuf,
    /// start near the optimum (Figure 2's setup)
    pub start_near_opt: bool,
    pub practical_adiana: bool,
    /// uplink compressor family (`--compressor
    /// default|sketch|matrix-aware|sa-quant|topk`): `default` keeps each
    /// method's theory-prescribed compressor; the rest override it where
    /// applicable (enforced at build time by
    /// [`crate::methods::MethodSpec::build`])
    pub compressor: CompressorKind,
    /// quantization levels s for `sa-quant` (`--sa-levels`; 0 = exact
    /// passthrough sentinel, ω_q = 0)
    pub sa_levels: u32,
    /// `sa-quant` whitening matrix (`--sa-weighting diag|root`)
    pub sa_weighting: QuantWeighting,
    /// sweep-cell parallelism: 0 ⇒ all cores, 1 ⇒ sequential, k ⇒ k threads.
    /// Output is bitwise identical for every value (deterministic per-cell
    /// seeds; see `experiments::pool`).
    pub jobs: usize,
    /// pin threaded-driver worker `i` to core `i mod cores`
    /// (`sched_setaffinity`; no-op off Linux). Cannot affect results —
    /// asserted by the pinned column in `tests/driver_matrix.rs`.
    pub pin: bool,
    /// live terminal dashboard (`--watch`): attach a
    /// [`WatchObserver`](crate::obs::WatchObserver) that redraws round
    /// rate, residual sparkline, measured-vs-modeled bytes, and worker
    /// liveness on stderr. A plain observer — cannot perturb the
    /// trajectory (asserted by `tests/obs_endpoint.rs`) and is excluded
    /// from [`ExperimentConfig::canonical_identity`].
    pub watch: bool,
    /// wire subsystem: payload encoding, serve address, process count,
    /// fault-tolerance grace window
    pub wire: WireConfig,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            dataset: "a1a".to_string(),
            workers: 0,
            mu: 1e-3,
            tau: 1.0,
            methods: vec!["diana".into(), "diana+".into()],
            sampling: SamplingKind::Uniform,
            max_rounds: 10_000,
            target_residual: 1e-12,
            record_every: 10,
            seed: 42,
            engine: EngineKind::Native,
            driver: DriverKind::Auto,
            checkpoint_every: 0,
            data_dir: None,
            out_dir: std::path::PathBuf::from("results"),
            start_near_opt: false,
            practical_adiana: true,
            compressor: CompressorKind::Default,
            sa_levels: 4,
            sa_weighting: QuantWeighting::Diag,
            jobs: 0,
            pin: false,
            watch: false,
            wire: WireConfig::default(),
        }
    }
}

impl ExperimentConfig {
    /// Effective worker count: explicit or the dataset's Table-3 default.
    pub fn effective_workers(&self) -> usize {
        if self.workers > 0 {
            return self.workers;
        }
        spec_by_name(&self.dataset)
            .map(|s| s.n)
            .unwrap_or(synth::tiny_spec().n)
    }

    /// Effective sweep parallelism: explicit `jobs`, or all cores when 0.
    pub fn effective_jobs(&self) -> usize {
        if self.jobs == 0 {
            crate::experiments::pool::default_threads()
        } else {
            self.jobs
        }
    }

    pub fn from_json(j: &Json) -> Result<ExperimentConfig> {
        let mut c = ExperimentConfig::default();
        let obj = j.as_obj().context("config must be a JSON object")?;
        for (k, v) in obj {
            match k.as_str() {
                "dataset" => c.dataset = v.as_str().context("dataset")?.to_string(),
                "workers" => c.workers = v.as_usize().context("workers")?,
                "mu" => c.mu = v.as_f64().context("mu")?,
                "tau" => c.tau = v.as_f64().context("tau")?,
                "methods" => {
                    c.methods = v
                        .as_arr()
                        .context("methods")?
                        .iter()
                        .map(|m| m.as_str().map(|s| s.to_string()))
                        .collect::<Option<Vec<_>>>()
                        .context("methods must be strings")?
                }
                "sampling" => {
                    let s = v.as_str().context("sampling")?;
                    c.sampling =
                        SamplingKind::parse(s).with_context(|| format!("bad sampling '{s}'"))?
                }
                "max_rounds" => c.max_rounds = v.as_usize().context("max_rounds")?,
                "target_residual" => c.target_residual = v.as_f64().context("target_residual")?,
                "record_every" => c.record_every = v.as_usize().context("record_every")?,
                "seed" => c.seed = v.as_f64().context("seed")? as u64,
                "engine" => {
                    let s = v.as_str().context("engine")?;
                    c.engine = EngineKind::parse(s).with_context(|| format!("bad engine '{s}'"))?
                }
                "driver" => {
                    let s = v.as_str().context("driver")?;
                    c.driver = DriverKind::parse(s)
                        .with_context(|| format!("bad driver '{s}' (auto|sim|threaded|distributed)"))?
                }
                "checkpoint_every" => {
                    c.checkpoint_every = v.as_usize().context("checkpoint_every")?
                }
                "data_dir" => c.data_dir = Some(v.as_str().context("data_dir")?.into()),
                "out_dir" => c.out_dir = v.as_str().context("out_dir")?.into(),
                "start_near_opt" => c.start_near_opt = v.as_bool().context("start_near_opt")?,
                "practical_adiana" => {
                    c.practical_adiana = v.as_bool().context("practical_adiana")?
                }
                "compressor" => {
                    let s = v.as_str().context("compressor")?;
                    c.compressor = CompressorKind::parse(s).with_context(|| {
                        format!("bad compressor '{s}' (default|sketch|matrix-aware|sa-quant|topk)")
                    })?
                }
                "sa_levels" => c.sa_levels = v.as_usize().context("sa_levels")? as u32,
                "sa_weighting" => {
                    let s = v.as_str().context("sa_weighting")?;
                    c.sa_weighting = QuantWeighting::parse(s)
                        .with_context(|| format!("bad sa_weighting '{s}' (diag|root)"))?
                }
                "jobs" => c.jobs = v.as_usize().context("jobs")?,
                "pin" => c.pin = v.as_bool().context("pin")?,
                "watch" => c.watch = v.as_bool().context("watch")?,
                "wire" => c.wire = WireConfig::from_json(v).context("wire")?,
                other => bail!("unknown config key '{other}'"),
            }
        }
        c.validate()?;
        Ok(c)
    }

    pub fn from_file(path: &std::path::Path) -> Result<ExperimentConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        Self::from_json(&j)
    }

    /// Apply CLI overrides on top (flags win over file values).
    pub fn apply_args(&mut self, args: &Args) -> Result<()> {
        if let Some(v) = args.get("dataset") {
            self.dataset = v.to_string();
        }
        if args.has("workers") {
            self.workers = args.usize_or("workers", self.workers);
        }
        if args.has("mu") {
            self.mu = args.f64_or("mu", self.mu);
        }
        if args.has("tau") {
            self.tau = args.f64_or("tau", self.tau);
        }
        if args.has("methods") {
            self.methods = args.list_or("methods", &[]);
        }
        if let Some(s) = args.get("sampling") {
            self.sampling = SamplingKind::parse(s).with_context(|| format!("bad sampling '{s}'"))?;
        }
        if args.has("max-rounds") {
            self.max_rounds = args.usize_or("max-rounds", self.max_rounds);
        }
        if args.has("target-residual") {
            self.target_residual = args.f64_or("target-residual", self.target_residual);
        }
        if args.has("record-every") {
            self.record_every = args.usize_or("record-every", self.record_every);
        }
        if args.has("seed") {
            self.seed = args.u64_or("seed", self.seed);
        }
        if let Some(s) = args.get("engine") {
            self.engine = EngineKind::parse(s).with_context(|| format!("bad engine '{s}'"))?;
        }
        if let Some(s) = args.get("driver") {
            self.driver = DriverKind::parse(s)
                .with_context(|| format!("bad driver '{s}' (auto|sim|threaded|distributed)"))?;
        }
        if args.has("checkpoint-every") {
            self.checkpoint_every = args.usize_or("checkpoint-every", self.checkpoint_every);
        }
        if let Some(s) = args.get("data-dir") {
            self.data_dir = Some(s.into());
        }
        if let Some(s) = args.get("out-dir") {
            self.out_dir = s.into();
        }
        if args.has("start-near-opt") {
            self.start_near_opt = args.bool_or("start-near-opt", self.start_near_opt);
        }
        if let Some(s) = args.get("compressor") {
            self.compressor = CompressorKind::parse(s).with_context(|| {
                format!("bad compressor '{s}' (default|sketch|matrix-aware|sa-quant|topk)")
            })?;
        }
        if args.has("sa-levels") {
            self.sa_levels = args.usize_or("sa-levels", self.sa_levels as usize) as u32;
        }
        if let Some(s) = args.get("sa-weighting") {
            self.sa_weighting = QuantWeighting::parse(s)
                .with_context(|| format!("bad sa_weighting '{s}' (diag|root)"))?;
        }
        if args.has("jobs") {
            self.jobs = args.usize_or("jobs", self.jobs);
        }
        if args.has("pin") {
            self.pin = args.bool_or("pin", self.pin);
        }
        if args.has("watch") {
            self.watch = args.bool_or("watch", self.watch);
        }
        if args.has("worker-timeout") {
            self.wire.worker_timeout =
                args.f64_or("worker-timeout", self.wire.worker_timeout);
        }
        if let Some(s) = args.get("payload") {
            self.wire.payload =
                Payload::parse(s).with_context(|| format!("bad wire payload '{s}'"))?;
        }
        if let Some(s) = args.get("listen") {
            self.wire.listen = s.to_string();
        }
        if args.has("wire-workers") {
            self.wire.workers = args.usize_or("wire-workers", self.wire.workers);
        }
        if args.has("float-bits") {
            self.wire.float_bits = Some(args.usize_or(
                "float-bits",
                self.wire.effective_float_bits() as usize,
            ) as u32);
        }
        if let Some(d) = args.get("run-dir") {
            self.wire.run_dir = Some(d.to_string());
        }
        if args.has("no-crc") {
            self.wire.crc = !args.bool_or("no-crc", false);
        }
        if let Some(p) = args.get("fault-plan") {
            self.wire.fault_plan = Some(p.to_string());
        }
        if let Some(a) = args.get("metrics-addr") {
            self.wire.metrics_addr = Some(a.to_string());
        }
        if let Some(r) = args.get("relay") {
            self.wire.relays = Some(r.to_string());
        }
        if let Some(p) = args.get("participation") {
            self.wire.participation = Some(p.to_string());
        }
        if args.has("min-clients") {
            self.wire.min_clients = args.usize_or("min-clients", self.wire.min_clients);
        }
        self.validate()
    }

    pub fn validate(&self) -> Result<()> {
        if self.mu <= 0.0 {
            bail!("mu must be positive (strong convexity)");
        }
        if self.tau <= 0.0 {
            bail!("tau must be positive");
        }
        if self.methods.is_empty() {
            bail!("at least one method required");
        }
        if let Some(b) = self.wire.float_bits {
            if b == 0 || b > 64 {
                bail!("wire.float_bits must be in 1..=64 (got {b})");
            }
        }
        if !self.wire.worker_timeout.is_finite() || self.wire.worker_timeout < 0.0 {
            bail!(
                "wire.worker_timeout must be a non-negative number of seconds \
                 (got {}; 0 disables fault handling)",
                self.wire.worker_timeout
            );
        }
        for m in &self.methods {
            if !crate::methods::METHOD_NAMES.contains(&m.as_str()) {
                bail!(
                    "unknown method '{m}' (expected one of {:?})",
                    crate::methods::METHOD_NAMES
                );
            }
        }
        if let Some(spec) = &self.wire.fault_plan {
            let plan = crate::wire::FaultPlan::parse(spec, self.seed)
                .with_context(|| format!("bad fault plan '{spec}'"))?;
            let corrupts = plan
                .events
                .iter()
                .any(|e| e.action == crate::wire::FaultAction::CorruptDownlink);
            if corrupts && !self.wire.crc {
                bail!(
                    "fault plan '{spec}' injects frame corruption, which is only \
                     detectable with frame CRCs — drop --no-crc"
                );
            }
        }
        self.wire.relay_tiers()?;
        if let Some(tau) = self.wire.participation_tau()? {
            let n = self.effective_workers();
            if tau > n {
                bail!(
                    "participation tau={tau} exceeds the worker count {n}; \
                     use tau<={n} (tau={n} is full participation)"
                );
            }
            if self.methods.iter().any(|m| m == "diana++") {
                bail!(
                    "diana++ is incompatible with partial participation: its \
                     incremental sparse downlinks require every worker to apply \
                     every round (sampled-out replicas would diverge)"
                );
            }
        }
        Ok(())
    }

    /// Canonical string identifying *the run* for the durable run log's
    /// config hash: exactly the fields that determine the trajectory.
    /// Operational knobs a restart may legitimately change — listen
    /// address, worker/process counts (the elastic runtime is
    /// process-count-invariant), timeouts, CRC framing, checkpoint
    /// cadence, fault plan, directories — are deliberately excluded, so
    /// a crashed `--fault-plan kill-server@rN` run can be resumed
    /// without re-arming the kill.
    pub fn canonical_identity(&self) -> String {
        format!(
            "dataset={};shards={};mu={:e};tau={:e};methods={};sampling={};max_rounds={};\
             target_residual={:e};record_every={};seed={};engine={};payload={};float_bits={};\
             start_near_opt={};practical_adiana={};compressor={};sa_levels={};sa_weighting={};\
             participation={}",
            self.dataset,
            self.effective_workers(),
            self.mu,
            self.tau,
            self.methods.join(","),
            self.sampling.name(),
            self.max_rounds,
            self.target_residual,
            self.record_every,
            self.seed,
            self.engine.name(),
            self.wire.payload.name(),
            self.wire.effective_float_bits(),
            self.start_near_opt,
            self.practical_adiana,
            self.compressor.name(),
            self.sa_levels,
            self.sa_weighting.name(),
            // participation changes which workers speak each round — a
            // trajectory field (validate() already proved the spec parses)
            match self.wire.participation_tau().ok().flatten() {
                Some(tau) => tau.to_string(),
                None => "full".to_string(),
            },
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("dataset", Json::Str(self.dataset.clone())),
            ("workers", Json::Num(self.workers as f64)),
            ("mu", Json::Num(self.mu)),
            ("tau", Json::Num(self.tau)),
            (
                "methods",
                Json::Arr(self.methods.iter().map(|m| Json::Str(m.clone())).collect()),
            ),
            ("sampling", Json::Str(self.sampling.name().to_string())),
            ("max_rounds", Json::Num(self.max_rounds as f64)),
            ("target_residual", Json::Num(self.target_residual)),
            ("record_every", Json::Num(self.record_every as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("engine", Json::Str(self.engine.name().to_string())),
            ("driver", Json::Str(self.driver.name().to_string())),
            ("checkpoint_every", Json::Num(self.checkpoint_every as f64)),
            ("start_near_opt", Json::Bool(self.start_near_opt)),
            ("practical_adiana", Json::Bool(self.practical_adiana)),
            ("compressor", Json::Str(self.compressor.name().to_string())),
            ("sa_levels", Json::Num(self.sa_levels as f64)),
            ("sa_weighting", Json::Str(self.sa_weighting.name().to_string())),
            ("jobs", Json::Num(self.jobs as f64)),
            ("pin", Json::Bool(self.pin)),
            ("watch", Json::Bool(self.watch)),
            ("wire", self.wire.to_json()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        ExperimentConfig::default().validate().unwrap();
    }

    #[test]
    fn json_roundtrip() {
        let c = ExperimentConfig {
            wire: WireConfig {
                payload: Payload::Q16,
                workers: 3,
                float_bits: Some(32),
                ..Default::default()
            },
            ..Default::default()
        };
        let j = c.to_json();
        let c2 = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c2.dataset, c.dataset);
        assert_eq!(c2.methods, c.methods);
        assert_eq!(c2.tau, c.tau);
        assert_eq!(c2.wire.payload, Payload::Q16);
        assert_eq!(c2.wire.workers, 3);
        assert_eq!(c2.wire.float_bits, Some(32));
    }

    #[test]
    fn wire_section_parses_and_overrides() {
        let j = Json::parse(
            r#"{"wire": {"payload": "q8", "listen": "0.0.0.0:9", "workers": 3}}"#,
        )
        .unwrap();
        let c = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c.wire.payload, Payload::Q8);
        assert_eq!(c.wire.listen, "0.0.0.0:9");
        assert_eq!(c.wire.effective_float_bits(), 8);
        assert_eq!(c.wire.effective_procs(10), 3);
        assert_eq!(c.wire.effective_procs(2), 2);
        // defaults: f64 payload, one process per shard
        let d = ExperimentConfig::default();
        assert_eq!(d.wire.effective_float_bits(), 64);
        assert_eq!(d.wire.effective_procs(7), 7);

        let mut c2 = ExperimentConfig::default();
        let args = Args::parse(
            "--payload f32 --float-bits 64 --wire-workers 2 --listen 127.0.0.1:5000 \
             --worker-timeout 2.5 --pin"
                .split_whitespace()
                .map(String::from),
            false,
        );
        c2.apply_args(&args).unwrap();
        assert_eq!(c2.wire.payload, Payload::F32);
        assert_eq!(c2.wire.effective_float_bits(), 64); // override wins
        assert_eq!(c2.wire.workers, 2);
        assert_eq!(c2.wire.listen, "127.0.0.1:5000");
        assert_eq!(c2.wire.worker_timeout, 2.5);
        assert!(c2.pin);
        // defaults: fault tolerance on with a generous window, no pinning
        assert_eq!(ExperimentConfig::default().wire.worker_timeout, 30.0);
        assert!(!ExperimentConfig::default().pin);
        // negative grace windows are rejected
        assert!(ExperimentConfig::from_json(
            &Json::parse(r#"{"wire": {"worker_timeout": -1}}"#).unwrap()
        )
        .is_err());

        assert!(ExperimentConfig::from_json(
            &Json::parse(r#"{"wire": {"payload": "f16"}}"#).unwrap()
        )
        .is_err());
        assert!(ExperimentConfig::from_json(
            &Json::parse(r#"{"wire": {"float_bits": 65}}"#).unwrap()
        )
        .is_err());
    }

    #[test]
    fn durability_and_fault_keys_parse() {
        let j = Json::parse(
            r#"{"watch": true, "wire": {"run_dir": "/tmp/r", "crc": false,
                "fault_plan": "kill@r3", "metrics_addr": "127.0.0.1:9090"}}"#,
        )
        .unwrap();
        let c = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c.wire.run_dir.as_deref(), Some("/tmp/r"));
        assert!(!c.wire.crc);
        assert_eq!(c.wire.fault_plan.as_deref(), Some("kill@r3"));
        assert_eq!(c.wire.metrics_addr.as_deref(), Some("127.0.0.1:9090"));
        assert!(c.watch);
        // JSON roundtrip keeps all of them
        let c2 = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.wire.run_dir, c.wire.run_dir);
        assert!(!c2.wire.crc);
        assert_eq!(c2.wire.fault_plan, c.wire.fault_plan);
        assert_eq!(c2.wire.metrics_addr, c.wire.metrics_addr);
        assert!(c2.watch);
        // defaults: CRC on, no run dir, no plan, no metrics listener
        let d = ExperimentConfig::default();
        assert!(d.wire.crc && d.wire.run_dir.is_none() && d.wire.fault_plan.is_none());
        assert!(d.wire.metrics_addr.is_none() && !d.watch);

        // CLI overrides
        let mut c3 = ExperimentConfig::default();
        let args = Args::parse(
            "--run-dir runs/x --no-crc --fault-plan kill-server@r10 \
             --metrics-addr 127.0.0.1:9091 --watch"
                .split_whitespace()
                .map(String::from),
            false,
        );
        c3.apply_args(&args).unwrap();
        assert_eq!(c3.wire.run_dir.as_deref(), Some("runs/x"));
        assert!(!c3.wire.crc);
        assert_eq!(c3.wire.fault_plan.as_deref(), Some("kill-server@r10"));
        assert_eq!(c3.wire.metrics_addr.as_deref(), Some("127.0.0.1:9091"));
        assert!(c3.watch);

        // a malformed plan is rejected at validation, not at fire time
        assert!(ExperimentConfig::from_json(
            &Json::parse(r#"{"wire": {"fault_plan": "explode@r3"}}"#).unwrap()
        )
        .is_err());
        // corruption injection without CRCs is undetectable → rejected
        assert!(ExperimentConfig::from_json(
            &Json::parse(r#"{"wire": {"fault_plan": "corrupt-downlink@r3", "crc": false}}"#)
                .unwrap()
        )
        .is_err());
    }

    #[test]
    fn relay_topology_parses_roundtrips_and_rejects_bad_tiers() {
        let c = ExperimentConfig::from_json(
            &Json::parse(r#"{"wire": {"relays": "2,3"}}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(c.wire.relays.as_deref(), Some("2,3"));
        assert_eq!(c.wire.relay_tiers().unwrap(), Some(vec![2, 3]));
        // serve's direct-peer count follows tier 1, capped by the shard count
        assert_eq!(c.wire.direct_peers(10).unwrap(), 2);
        assert_eq!(c.wire.direct_peers(1).unwrap(), 1);
        // JSON roundtrip keeps the spec
        let c2 = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.wire.relays, c.wire.relays);
        // CLI override
        let mut c3 = ExperimentConfig::default();
        let args = Args::parse(
            "--relay 2".split_whitespace().map(String::from),
            false,
        );
        c3.apply_args(&args).unwrap();
        assert_eq!(c3.wire.relay_tiers().unwrap(), Some(vec![2]));
        // flat default: no relays, direct peers = effective procs
        let d = ExperimentConfig::default();
        assert_eq!(d.wire.relay_tiers().unwrap(), None);
        assert_eq!(d.wire.direct_peers(7).unwrap(), 7);
        // zero / non-numeric / empty tiers are rejected at validation
        for bad in ["0", "2,0", "two", "", "2,,2"] {
            let j = Json::parse(&format!(r#"{{"wire": {{"relays": "{bad}"}}}}"#)).unwrap();
            assert!(ExperimentConfig::from_json(&j).is_err(), "accepted '{bad}'");
        }
    }

    #[test]
    fn participation_keys_parse_roundtrip_and_reject_bad_values() {
        let c = ExperimentConfig::from_json(
            &Json::parse(r#"{"wire": {"participation": "tau=3", "min_clients": 2}}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(c.wire.participation.as_deref(), Some("tau=3"));
        assert_eq!(c.wire.participation_tau().unwrap(), Some(3));
        assert_eq!(c.wire.min_clients, 2);
        // JSON roundtrip keeps both
        let c2 = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.wire.participation, c.wire.participation);
        assert_eq!(c2.wire.min_clients, 2);
        // CLI overrides
        let mut c3 = ExperimentConfig::default();
        let args = Args::parse(
            "--participation tau=2 --min-clients 1"
                .split_whitespace()
                .map(String::from),
            false,
        );
        c3.apply_args(&args).unwrap();
        assert_eq!(c3.wire.participation_tau().unwrap(), Some(2));
        assert_eq!(c3.wire.min_clients, 1);
        // defaults: full participation, wait for everyone
        let d = ExperimentConfig::default();
        assert_eq!(d.wire.participation_tau().unwrap(), None);
        assert_eq!(d.wire.min_clients, 0);
        // the explicit sentinel means full participation
        let mut f = ExperimentConfig::default();
        f.wire.participation = Some("full".into());
        assert_eq!(f.wire.participation_tau().unwrap(), None);
        // malformed specs are rejected at validation
        for bad in ["tau=0", "tau=", "3", "tau=x", ""] {
            let j =
                Json::parse(&format!(r#"{{"wire": {{"participation": "{bad}"}}}}"#)).unwrap();
            assert!(ExperimentConfig::from_json(&j).is_err(), "accepted '{bad}'");
        }
        // tau beyond the worker count is rejected
        let j = Json::parse(r#"{"workers": 4, "wire": {"participation": "tau=9"}}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
        // diana++'s incremental downlinks cannot skip rounds
        let j = Json::parse(
            r#"{"methods": ["diana++"], "wire": {"participation": "tau=1"}}"#,
        )
        .unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
    }

    #[test]
    fn canonical_identity_pins_the_trajectory_not_the_plumbing() {
        let a = ExperimentConfig::default();
        let mut b = ExperimentConfig::default();
        // operational knobs a restart may change leave the identity alone
        b.wire.listen = "0.0.0.0:1".into();
        b.wire.run_dir = Some("/tmp/x".into());
        b.wire.fault_plan = Some("kill-server@r5".into());
        b.wire.crc = false;
        b.wire.worker_timeout = 1.0;
        b.checkpoint_every = 7;
        b.wire.metrics_addr = Some("127.0.0.1:9090".into());
        b.watch = true;
        // the relay tier is exact partial aggregation — pure plumbing
        b.wire.relays = Some("2,2".into());
        // the member floor only delays who hosts which shard — plumbing too
        b.wire.min_clients = 2;
        assert_eq!(a.canonical_identity(), b.canonical_identity());
        // trajectory-determining fields do not
        b.seed = 43;
        assert_ne!(a.canonical_identity(), b.canonical_identity());
        // which workers speak each round is the trajectory
        let mut p = ExperimentConfig::default();
        p.wire.participation = Some("tau=2".into());
        assert_ne!(a.canonical_identity(), p.canonical_identity());
        let mut c = ExperimentConfig::default();
        c.wire.payload = Payload::Q8;
        assert_ne!(a.canonical_identity(), c.canonical_identity());
        // the compressor family and its knobs pick the trajectory too
        let mut q = ExperimentConfig::default();
        q.compressor = CompressorKind::SaQuant;
        assert_ne!(a.canonical_identity(), q.canonical_identity());
        let mut q2 = q.clone();
        q2.sa_levels = 8;
        assert_ne!(q.canonical_identity(), q2.canonical_identity());
        let mut q3 = q.clone();
        q3.sa_weighting = QuantWeighting::Root;
        assert_ne!(q.canonical_identity(), q3.canonical_identity());
    }

    #[test]
    fn compressor_keys_parse_roundtrip_and_reject_bad_values() {
        let j = Json::parse(
            r#"{"compressor": "sa-quant", "sa_levels": 8, "sa_weighting": "root"}"#,
        )
        .unwrap();
        let c = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c.compressor, CompressorKind::SaQuant);
        assert_eq!(c.sa_levels, 8);
        assert_eq!(c.sa_weighting, QuantWeighting::Root);
        // JSON roundtrip keeps all three
        let c2 = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.compressor, CompressorKind::SaQuant);
        assert_eq!(c2.sa_levels, 8);
        assert_eq!(c2.sa_weighting, QuantWeighting::Root);
        // defaults: theory-prescribed compressor, s = 4, diagonal weights
        let d = ExperimentConfig::default();
        assert_eq!(d.compressor, CompressorKind::Default);
        assert_eq!(d.sa_levels, 4);
        assert_eq!(d.sa_weighting, QuantWeighting::Diag);
        // CLI overrides
        let mut c3 = ExperimentConfig::default();
        let args = Args::parse(
            "--compressor topk --sa-levels 2 --sa-weighting root"
                .split_whitespace()
                .map(String::from),
            false,
        );
        c3.apply_args(&args).unwrap();
        assert_eq!(c3.compressor, CompressorKind::TopK);
        assert_eq!(c3.sa_levels, 2);
        assert_eq!(c3.sa_weighting, QuantWeighting::Root);
        // bad names are rejected at parse time
        assert!(ExperimentConfig::from_json(
            &Json::parse(r#"{"compressor": "gzip"}"#).unwrap()
        )
        .is_err());
        assert!(ExperimentConfig::from_json(
            &Json::parse(r#"{"sa_weighting": "dense"}"#).unwrap()
        )
        .is_err());
    }

    #[test]
    fn driver_and_checkpoint_keys_parse() {
        let j = Json::parse(r#"{"driver": "distributed", "checkpoint_every": 25}"#).unwrap();
        let c = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c.driver, DriverKind::Distributed);
        assert_eq!(c.checkpoint_every, 25);
        // defaults: the historical auto mapping, checkpointing off
        let d = ExperimentConfig::default();
        assert_eq!(d.driver, DriverKind::Auto);
        assert_eq!(d.checkpoint_every, 0);
        // JSON roundtrip keeps both
        let c2 = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.driver, DriverKind::Distributed);
        assert_eq!(c2.checkpoint_every, 25);
        // CLI overrides
        let mut c3 = ExperimentConfig::default();
        let args = Args::parse(
            "--driver sim --checkpoint-every 10".split_whitespace().map(String::from),
            false,
        );
        c3.apply_args(&args).unwrap();
        assert_eq!(c3.driver, DriverKind::Sim);
        assert_eq!(c3.checkpoint_every, 10);
        // unknown driver names are rejected
        assert!(
            ExperimentConfig::from_json(&Json::parse(r#"{"driver": "gpu"}"#).unwrap()).is_err()
        );
    }

    #[test]
    fn rejects_unknown_keys_and_bad_values() {
        assert!(ExperimentConfig::from_json(&Json::parse(r#"{"nope": 1}"#).unwrap()).is_err());
        assert!(
            ExperimentConfig::from_json(&Json::parse(r#"{"methods": ["bogus"]}"#).unwrap())
                .is_err()
        );
        assert!(ExperimentConfig::from_json(&Json::parse(r#"{"mu": -1}"#).unwrap()).is_err());
    }

    #[test]
    fn cli_overrides() {
        let mut c = ExperimentConfig::default();
        let args = Args::parse(
            "--dataset mushrooms --tau 4 --methods dcgd,dcgd+ --sampling importance-dcgd"
                .split_whitespace()
                .map(String::from),
            false,
        );
        c.apply_args(&args).unwrap();
        assert_eq!(c.dataset, "mushrooms");
        assert_eq!(c.tau, 4.0);
        assert_eq!(c.methods, vec!["dcgd", "dcgd+"]);
        assert_eq!(c.sampling, SamplingKind::ImportanceDcgd);
    }

    #[test]
    fn effective_workers_uses_table3() {
        let mut c = ExperimentConfig::default();
        c.dataset = "a1a".into();
        assert_eq!(c.effective_workers(), 107);
        c.workers = 5;
        assert_eq!(c.effective_workers(), 5);
    }
}
