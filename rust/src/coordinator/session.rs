//! The one front door for running a method: a [`Session`] builder that
//! dispatches to any of the three drivers, streams metrics through
//! [`RoundObserver`]s, and configures checkpointing.
//!
//! Historically each driver had its own incompatible entry point
//! (`run_sim` wanted `&mut Method` + engines, `run_threaded` consumed the
//! method and wanted a factory, `wire::run_distributed` wanted transports)
//! so the figure sweeps, the PJRT path and the wire runtime each hard-coded
//! one driver. `Session` composes the same run from named parts:
//!
//! ```no_run
//! use smx::coordinator::{Driver, Session, RunConfig};
//! use smx::methods::MethodSpec;
//! use smx::sampling::SamplingKind;
//! # fn demo(sm: &smx::objective::Smoothness, x_star: &[f64],
//! #         factory: smx::coordinator::EngineFactory) -> anyhow::Result<()> {
//! let spec = MethodSpec::new("diana+", 2.0, SamplingKind::Uniform, 1e-3,
//!                            vec![0.0; sm.dim]);
//! let result = Session::new(spec)
//!     .smoothness(sm)
//!     .x_star(x_star)
//!     .driver(Driver::Threaded)
//!     .engine_factory(factory)
//!     .run_config(RunConfig::new(500))
//!     .run()?;
//! # let _ = result; Ok(()) }
//! ```
//!
//! or, config-driven (the CLI's `--driver` flag lands here):
//!
//! ```no_run
//! # use smx::config::ExperimentConfig;
//! # use smx::coordinator::Session;
//! # fn demo(cfg: &ExperimentConfig) -> anyhow::Result<()> {
//! let result = Session::from_config(cfg).run()?; // prepares, builds, runs
//! # let _ = result; Ok(()) }
//! ```
//!
//! # Observers
//!
//! A [`RoundObserver`] receives every *recorded* round (round 0, every
//! `record_every`-th round, and the final/target round — exactly the rows
//! the old implicit collection kept), an optional checkpoint callback, and
//! the finished [`RunResult`]. Observers only ever see `&`-references
//! taken *after* the server applied the round, so they cannot perturb the
//! trajectory — the driver-identity tests run a streaming observer next
//! to the collector and assert bitwise-equal iterates. Returning
//! [`ObserverControl::Stop`] ends the run after the current round.
//!
//! Provided observers: the in-memory [`CollectObserver`] (always installed
//! by [`Session::run`]; its records become [`RunResult::records`]),
//! streaming [`JsonlObserver`]/[`CsvObserver`] sinks, and a
//! [`CheckpointObserver`] that atomically rewrites a model-snapshot file
//! every [`Session::checkpoint_every`] rounds (reload it with
//! [`load_checkpoint`] to warm-start a new run via [`MethodSpec::x0`]).
//! Under the distributed TCP driver, `checkpoint_every` additionally
//! drives the wire runtime's worker-state snapshot + journal truncation,
//! so a worker that dies and rejoins resumes from the snapshot instead of
//! replaying from round 0 — bitwise identically (see
//! [`crate::wire::runtime`]).

use crate::config::ExperimentConfig;
use crate::coordinator::{
    run_sim_observed, run_threaded_observed, EngineFactory, RoundRecord, RoundTotals, RunConfig,
    RunOutcome, RunResult,
};
use crate::experiments::runner::{self, Prepared};
use crate::methods::{build, MethodSpec};
use crate::objective::Smoothness;
use crate::runtime::{EngineKind, GradEngine};
use crate::util::timer::PhaseTimer;
use anyhow::{bail, ensure, Context, Result};
use std::io::Write;
use std::net::TcpListener;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

// ---- observers ---------------------------------------------------------

/// Returned by [`RoundObserver::on_round`]: keep going, or end the run
/// after the current round (the result reports `rounds_run` up to here
/// and `reached_target = false`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ObserverControl {
    Continue,
    Stop,
}

/// Streaming view of a run: one call per *recorded* round. The single
/// metrics seam shared by all three drivers — the in-memory records of
/// [`RunResult`] are produced by an observer too ([`CollectObserver`]).
///
/// Observers receive shared references after the server has applied the
/// round, so they can stream, aggregate or early-stop but cannot perturb
/// the trajectory.
pub trait RoundObserver {
    /// A recorded round (round 0, every `record_every`-th, the final and
    /// the target-hitting round). Return [`ObserverControl::Stop`] to end
    /// the run here.
    fn on_round(&mut self, _rec: &RoundRecord) -> ObserverControl {
        ObserverControl::Continue
    }

    /// Fired every [`RunConfig::checkpoint_every`] rounds with the
    /// current model iterate (never at round 0; disabled when 0).
    fn on_checkpoint(&mut self, _round: usize, _x: &[f64]) {}

    /// The finished run, records included.
    fn on_done(&mut self, _result: &RunResult) {}
}

/// In-memory collection — the behavior every run had before observers
/// existed. [`Session::run`] always installs one internally and returns
/// its records as [`RunResult::records`].
#[derive(Debug, Default)]
pub struct CollectObserver {
    records: Vec<RoundRecord>,
}

impl CollectObserver {
    pub fn new() -> CollectObserver {
        CollectObserver::default()
    }

    /// Pre-reserve for a run under `cfg` so steady-state pushes never
    /// reallocate (the alloc-free driver contract counts on this).
    pub fn for_cfg(cfg: &RunConfig) -> CollectObserver {
        CollectObserver {
            records: Vec::with_capacity(cfg.max_rounds / cfg.record_every.max(1) + 3),
        }
    }

    pub fn into_records(self) -> Vec<RoundRecord> {
        self.records
    }
}

impl RoundObserver for CollectObserver {
    fn on_round(&mut self, rec: &RoundRecord) -> ObserverControl {
        self.records.push(rec.clone());
        ObserverControl::Continue
    }
}

/// Streams each recorded round as one JSON object per line. Write errors
/// do not interrupt the run (the sink is an observer, not a participant);
/// the first failure is logged and the stream goes quiet.
pub struct JsonlObserver {
    w: std::io::BufWriter<std::fs::File>,
    failed: bool,
}

impl JsonlObserver {
    pub fn create(path: impl AsRef<Path>) -> std::io::Result<JsonlObserver> {
        Ok(JsonlObserver {
            w: std::io::BufWriter::new(std::fs::File::create(path)?),
            failed: false,
        })
    }
}

impl RoundObserver for JsonlObserver {
    fn on_round(&mut self, rec: &RoundRecord) -> ObserverControl {
        if !self.failed {
            let res = writeln!(
                self.w,
                "{{\"round\":{},\"residual\":{:e},\"coords_up\":{},\"bits_up\":{},\
                 \"coords_down\":{},\"bytes_up\":{},\"bytes_down\":{},\"wall_secs\":{:.6},\
                 \"compute_secs\":{:.6},\"encode_secs\":{:.6},\"wire_secs\":{:.6}}}",
                rec.round,
                rec.residual,
                rec.coords_up,
                rec.bits_up,
                rec.coords_down,
                rec.bytes_up,
                rec.bytes_down,
                rec.wall_secs,
                rec.compute_secs,
                rec.encode_secs,
                rec.wire_secs
            );
            if let Err(e) = res {
                crate::info!("session", "jsonl observer write failed ({e}); stream stops");
                self.failed = true;
            }
        }
        ObserverControl::Continue
    }

    fn on_done(&mut self, _result: &RunResult) {
        let _ = self.w.flush();
    }
}

/// Streams each recorded round as a CSV row (same columns as
/// [`RunResult::csv_rows`] minus the method label, which an observer does
/// not know). Same error policy as [`JsonlObserver`].
pub struct CsvObserver {
    w: std::io::BufWriter<std::fs::File>,
    failed: bool,
}

impl CsvObserver {
    pub fn create(path: impl AsRef<Path>) -> std::io::Result<CsvObserver> {
        let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(
            w,
            "round,residual,coords_up,bits_up,coords_down,bytes_up,bytes_down,wall_secs,\
             compute_secs,encode_secs,wire_secs"
        )?;
        Ok(CsvObserver { w, failed: false })
    }
}

impl RoundObserver for CsvObserver {
    fn on_round(&mut self, rec: &RoundRecord) -> ObserverControl {
        if !self.failed {
            let res = writeln!(
                self.w,
                "{},{:.6e},{},{},{},{},{},{:.6},{:.6},{:.6},{:.6}",
                rec.round,
                rec.residual,
                rec.coords_up,
                rec.bits_up,
                rec.coords_down,
                rec.bytes_up,
                rec.bytes_down,
                rec.wall_secs,
                rec.compute_secs,
                rec.encode_secs,
                rec.wire_secs
            );
            if let Err(e) = res {
                crate::info!("session", "csv observer write failed ({e}); stream stops");
                self.failed = true;
            }
        }
        ObserverControl::Continue
    }

    fn on_done(&mut self, _result: &RunResult) {
        let _ = self.w.flush();
    }
}

const CKPT_MAGIC: &[u8; 8] = b"SMXCKPT1";

/// Atomically rewrites a model-snapshot file at every checkpoint (write
/// to a sibling `.tmp`, then rename). The file always holds the *latest*
/// checkpoint; reload it with [`load_checkpoint`] and pass the iterate as
/// [`MethodSpec::x0`] to warm-start a new run. (Bitwise checkpoint-resume
/// — including worker-local state — is the distributed TCP driver's
/// journal-snapshot mechanism; see [`crate::wire::runtime`].)
pub struct CheckpointObserver {
    path: PathBuf,
}

impl CheckpointObserver {
    pub fn new(path: impl Into<PathBuf>) -> CheckpointObserver {
        CheckpointObserver { path: path.into() }
    }
}

impl RoundObserver for CheckpointObserver {
    fn on_checkpoint(&mut self, round: usize, x: &[f64]) {
        if let Err(e) = write_checkpoint(&self.path, round, x) {
            crate::info!(
                "session",
                "checkpoint write to {} failed ({e}); keeping the previous snapshot",
                self.path.display()
            );
        }
    }
}

/// Write a `(round, x)` model snapshot: magic, `u64` round, `u64` length,
/// raw little-endian f64 bits (exact). Atomic via tmp-file + rename.
pub fn write_checkpoint(path: &Path, round: usize, x: &[f64]) -> std::io::Result<()> {
    let mut buf = Vec::with_capacity(CKPT_MAGIC.len() + 16 + 8 * x.len());
    buf.extend_from_slice(CKPT_MAGIC);
    buf.extend_from_slice(&(round as u64).to_le_bytes());
    buf.extend_from_slice(&(x.len() as u64).to_le_bytes());
    for &v in x {
        buf.extend_from_slice(&v.to_bits().to_le_bytes());
    }
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, &buf)?;
    std::fs::rename(&tmp, path)
}

/// Read a snapshot written by [`write_checkpoint`] back as `(round, x)`,
/// bit-exact.
pub fn load_checkpoint(path: &Path) -> std::io::Result<(usize, Vec<f64>)> {
    let bad = |msg: &str| std::io::Error::new(std::io::ErrorKind::InvalidData, msg.to_string());
    let buf = std::fs::read(path)?;
    if buf.len() < CKPT_MAGIC.len() + 16 || &buf[..8] != CKPT_MAGIC {
        return Err(bad("not a smx checkpoint file"));
    }
    let round = u64::from_le_bytes(buf[8..16].try_into().unwrap()) as usize;
    let n = u64::from_le_bytes(buf[16..24].try_into().unwrap()) as usize;
    if buf.len() != 24 + 8 * n {
        return Err(bad("checkpoint length mismatch"));
    }
    let x = buf[24..]
        .chunks_exact(8)
        .map(|c| f64::from_bits(u64::from_le_bytes(c.try_into().unwrap())))
        .collect();
    Ok((round, x))
}

/// Fan a driver's observer calls out to the collector plus any user
/// observers. `Stop` wins if any observer asks for it.
pub(crate) struct Fanout<'a, 'b> {
    pub collect: &'a mut CollectObserver,
    pub rest: &'a mut [Box<dyn RoundObserver + 'b>],
}

impl RoundObserver for Fanout<'_, '_> {
    fn on_round(&mut self, rec: &RoundRecord) -> ObserverControl {
        let mut stop = self.collect.on_round(rec) == ObserverControl::Stop;
        for o in self.rest.iter_mut() {
            stop |= o.on_round(rec) == ObserverControl::Stop;
        }
        if stop {
            ObserverControl::Stop
        } else {
            ObserverControl::Continue
        }
    }

    fn on_checkpoint(&mut self, round: usize, x: &[f64]) {
        self.collect.on_checkpoint(round, x);
        for o in self.rest.iter_mut() {
            o.on_checkpoint(round, x);
        }
    }
}

// ---- shared per-round bookkeeping --------------------------------------

/// Outcome of one [`Ticker::tick`].
pub(crate) enum Tick {
    Continue,
    ReachedTarget,
    Stopped,
}

/// The stopping/recording policy every driver shares: round 0 plus every
/// `record_every`-th/final/target round goes to the observer, checkpoints
/// fire on their own cadence, and the target/stop decision comes back as
/// a [`Tick`]. Extracted so the four driver loops cannot drift apart.
pub(crate) struct Ticker {
    record_every: usize,
    max_rounds: usize,
    target_residual: f64,
    checkpoint_every: usize,
    t0: Instant,
}

impl Ticker {
    pub fn new(cfg: &RunConfig) -> Ticker {
        Ticker {
            record_every: cfg.record_every.max(1),
            max_rounds: cfg.max_rounds,
            target_residual: cfg.target_residual,
            checkpoint_every: cfg.checkpoint_every,
            t0: Instant::now(),
        }
    }

    /// Emit the round-0 record. Returns `true` if an observer stopped the
    /// run before it began.
    pub fn start(&self, obs: &mut dyn RoundObserver) -> bool {
        self.start_with_record(obs).0
    }

    /// [`Ticker::start`], also handing back the emitted round-0 record so
    /// the wire runtime's durable run log can persist it (a resumed run
    /// must replay the identical record stream, round 0 included).
    pub fn start_with_record(&self, obs: &mut dyn RoundObserver) -> (bool, RoundRecord) {
        let rec = RoundRecord {
            round: 0,
            residual: 1.0,
            coords_up: 0,
            bits_up: 0,
            coords_down: 0,
            bytes_up: 0,
            bytes_down: 0,
            wall_secs: 0.0,
            compute_secs: 0.0,
            encode_secs: 0.0,
            wire_secs: 0.0,
        };
        (obs.on_round(&rec) == ObserverControl::Stop, rec)
    }

    /// Resume path: feed records recovered from a durable run log back
    /// through the observer stream, exactly as the crashed process emitted
    /// them (in place of [`Ticker::start`]). Returns `true` if an observer
    /// stopped the run.
    pub fn replay(&self, records: &[RoundRecord], obs: &mut dyn RoundObserver) -> bool {
        let mut stop = false;
        for rec in records {
            stop |= obs.on_round(rec) == ObserverControl::Stop;
        }
        stop
    }

    /// Post-apply bookkeeping for `round`. `phases` is the driver's
    /// cumulative phase timer; its bucket totals become the record's
    /// `compute_secs`/`encode_secs`/`wire_secs` columns.
    pub fn tick(
        &self,
        round: usize,
        residual: f64,
        acc: &RoundTotals,
        x: &[f64],
        phases: &PhaseTimer,
        obs: &mut dyn RoundObserver,
    ) -> Tick {
        self.tick_with_record(round, residual, acc, x, phases, obs).0
    }

    /// [`Ticker::tick`], also handing back the record it emitted (`None`
    /// when `round` was not a recorded one) for the durable run log.
    pub fn tick_with_record(
        &self,
        round: usize,
        residual: f64,
        acc: &RoundTotals,
        x: &[f64],
        phases: &PhaseTimer,
        obs: &mut dyn RoundObserver,
    ) -> (Tick, Option<RoundRecord>) {
        let hit_target = self.target_residual > 0.0 && residual <= self.target_residual;
        let mut stop = false;
        let mut emitted = None;
        if round % self.record_every == 0 || round == self.max_rounds || hit_target {
            let (compute_secs, encode_secs, wire_secs) = phases.bucket_totals();
            let rec = RoundRecord {
                round,
                residual,
                coords_up: acc.coords_up,
                bits_up: acc.bits_up,
                coords_down: acc.coords_down,
                bytes_up: acc.bytes_up,
                bytes_down: acc.bytes_down,
                wall_secs: self.t0.elapsed().as_secs_f64(),
                compute_secs,
                encode_secs,
                wire_secs,
            };
            stop = obs.on_round(&rec) == ObserverControl::Stop;
            emitted = Some(rec);
        }
        if self.checkpoint_every > 0 && round % self.checkpoint_every == 0 {
            obs.on_checkpoint(round, x);
        }
        let tick = if hit_target {
            Tick::ReachedTarget
        } else if stop {
            Tick::Stopped
        } else {
            Tick::Continue
        };
        (tick, emitted)
    }
}

// ---- drivers -----------------------------------------------------------

/// Execution regime of a [`Session`].
#[derive(Clone, Debug)]
pub enum Driver {
    /// Deterministic in-process loop (workers run sequentially on the
    /// calling thread). The reference driver.
    Sim,
    /// One OS thread per worker over SPSC ring buffers; engines are built
    /// inside the worker threads via an [`EngineFactory`].
    Threaded,
    /// Multi-process protocol through the wire codec.
    Distributed { transport: DistTransport },
}

/// How a distributed run moves its bytes.
#[derive(Clone, Debug)]
pub enum DistTransport {
    /// In-process loopback transports: `procs` worker threads (0 = one
    /// per shard) speaking the full wire codec. Deterministic, bitwise
    /// identical to [`Driver::Sim`] under the lossless `f64` payload.
    Loopback { procs: usize },
    /// The elastic TCP server (`smx serve`): bind `listen`, wait for
    /// `workers` worker processes (0 = one per shard), survive their
    /// deaths. Requires [`Session::from_config`] — the handshake ships
    /// the dataset recipe to the worker processes. `relays` is the
    /// optional aggregation-tier spec (comma-separated branch factors,
    /// see [`crate::config::WireConfig::relay_tiers`]): when set the
    /// server expects that many `smx relay` peers instead of direct
    /// workers. Topology is pure plumbing — the result is bitwise
    /// identical either way.
    Tcp {
        listen: String,
        workers: usize,
        relays: Option<String>,
    },
}

/// Config-file / CLI driver selection (`--driver`, `"driver"` key);
/// resolved to a concrete [`Driver`] by [`Session::run`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DriverKind {
    /// Historical behavior: native engine → [`Driver::Sim`], PJRT engine
    /// → [`Driver::Threaded`].
    Auto,
    Sim,
    Threaded,
    /// Loopback distributed with `wire.workers` processes (the TCP path
    /// has its own subcommands, `smx serve` / `smx worker`).
    Distributed,
}

impl DriverKind {
    pub fn parse(s: &str) -> Option<DriverKind> {
        match s {
            "auto" => Some(DriverKind::Auto),
            "sim" => Some(DriverKind::Sim),
            "threaded" => Some(DriverKind::Threaded),
            "distributed" => Some(DriverKind::Distributed),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DriverKind::Auto => "auto",
            DriverKind::Sim => "sim",
            DriverKind::Threaded => "threaded",
            DriverKind::Distributed => "distributed",
        }
    }
}

// ---- the builder -------------------------------------------------------

/// Builder for one run: method × driver × engines × run policy ×
/// observers. See the [module docs](self) for examples.
///
/// Two entry points: [`Session::new`] with an explicit [`MethodSpec`]
/// (supply [`Session::smoothness`], and [`Session::x_star`] unless the
/// residual reference is zero), or [`Session::from_config`], which can
/// prepare the whole problem (dataset, x*, smoothness) by itself —
/// [`Session::prepared`] shares one [`Prepared`] across many runs.
pub struct Session<'a> {
    spec: Option<MethodSpec>,
    cfg: Option<&'a ExperimentConfig>,
    prep: Option<&'a Prepared>,
    sm: Option<&'a Smoothness>,
    x_star: Option<&'a [f64]>,
    driver: Option<Driver>,
    run_cfg: Option<RunConfig>,
    checkpoint_every: Option<usize>,
    engines: Option<Vec<Box<dyn GradEngine>>>,
    factory: Option<EngineFactory>,
    observers: Vec<Box<dyn RoundObserver + 'a>>,
    listener: Option<TcpListener>,
    metrics: Option<Arc<crate::obs::Registry>>,
}

impl<'a> Session<'a> {
    /// Start from an explicit method spec (library use; tests).
    pub fn new(spec: MethodSpec) -> Session<'a> {
        Session {
            spec: Some(spec),
            cfg: None,
            prep: None,
            sm: None,
            x_star: None,
            driver: None,
            run_cfg: None,
            checkpoint_every: None,
            engines: None,
            factory: None,
            observers: Vec::new(),
            listener: None,
            metrics: None,
        }
    }

    /// Start from an experiment config: the method comes from
    /// `cfg.methods` (exactly one, unless overridden via
    /// [`Session::method`]), the run policy from
    /// [`runner::run_config`], the driver from `cfg.driver`, and the
    /// problem is prepared on demand (share one with
    /// [`Session::prepared`]).
    pub fn from_config(cfg: &'a ExperimentConfig) -> Session<'a> {
        Session {
            spec: None,
            cfg: Some(cfg),
            prep: None,
            sm: None,
            x_star: None,
            driver: None,
            run_cfg: None,
            checkpoint_every: None,
            engines: None,
            factory: None,
            observers: Vec::new(),
            listener: None,
            metrics: None,
        }
    }

    /// Reuse an already-prepared problem (smoothness, x*, shards) instead
    /// of preparing from the config inside [`Session::run`] — what the
    /// sweep runner does for every cell of a figure.
    pub fn prepared(mut self, prep: &'a Prepared) -> Session<'a> {
        self.prep = Some(prep);
        self
    }

    /// Override the method (spec wins over `cfg.methods`).
    pub fn method(mut self, spec: MethodSpec) -> Session<'a> {
        self.spec = Some(spec);
        self
    }

    /// Problem smoothness to build the method against (implied by
    /// [`Session::prepared`] / [`Session::from_config`]).
    pub fn smoothness(mut self, sm: &'a Smoothness) -> Session<'a> {
        self.sm = Some(sm);
        self
    }

    /// Residual reference point x*. Defaults to the prepared problem's
    /// solution, or to the origin (identity is a trajectory property;
    /// the reference only scales the reported residual).
    pub fn x_star(mut self, x_star: &'a [f64]) -> Session<'a> {
        self.x_star = Some(x_star);
        self
    }

    /// Select the execution regime. Defaults to the config's `driver`
    /// key (`Auto` maps native → Sim, PJRT → Threaded), or [`Driver::Sim`].
    pub fn driver(mut self, driver: Driver) -> Session<'a> {
        self.driver = Some(driver);
        self
    }

    /// Stopping/recording policy. Defaults to
    /// [`runner::run_config`]`(cfg)` under [`Session::from_config`], else
    /// [`RunConfig::default`].
    pub fn run_config(mut self, cfg: RunConfig) -> Session<'a> {
        self.run_cfg = Some(cfg);
        self
    }

    /// Checkpoint cadence in rounds (0 disables). Fires
    /// [`RoundObserver::on_checkpoint`] on every driver; under the
    /// distributed TCP driver it additionally snapshots worker state and
    /// truncates the replay journal (see [`crate::wire::runtime`]).
    /// Overrides the value in [`Session::run_config`].
    pub fn checkpoint_every(mut self, rounds: usize) -> Session<'a> {
        self.checkpoint_every = Some(rounds);
        self
    }

    /// Per-worker gradient engines for [`Driver::Sim`] (the threaded and
    /// distributed drivers build engines inside their workers — give them
    /// an [`Session::engine_factory`] instead).
    pub fn engines(mut self, engines: Vec<Box<dyn GradEngine>>) -> Session<'a> {
        self.engines = Some(engines);
        self
    }

    /// Engine factory, called with the shard index inside each worker
    /// thread. Works for every driver; required for [`Driver::Threaded`]
    /// and loopback-distributed unless the problem is prepared (which
    /// supplies a native/PJRT factory per `cfg.engine`).
    pub fn engine_factory(mut self, factory: EngineFactory) -> Session<'a> {
        self.factory = Some(factory);
        self
    }

    /// Attach a streaming observer (repeatable; all observers see every
    /// recorded round, and any of them can stop the run).
    pub fn observer(mut self, obs: impl RoundObserver + 'a) -> Session<'a> {
        self.observers.push(Box::new(obs));
        self
    }

    /// Use an already-bound listener for the TCP transport (tests bind
    /// port 0 and hand the ephemeral address to their workers).
    pub fn tcp_listener(mut self, listener: TcpListener) -> Session<'a> {
        self.listener = Some(listener);
        self
    }

    /// Feed the run's live counters/gauges into a shared
    /// [`Registry`](crate::obs::Registry). Under the distributed TCP
    /// driver the elastic server instruments worker liveness, journal
    /// depth, CRC errors and the per-round totals into it; the `/metrics`
    /// HTTP endpoint and the `--watch` dashboard read from the same
    /// registry. Updates are plain atomic stores — the registry cannot
    /// perturb the trajectory.
    pub fn metrics_registry(mut self, registry: Arc<crate::obs::Registry>) -> Session<'a> {
        self.metrics = Some(registry);
        self
    }

    /// Resolve every part, dispatch to the selected driver, and return
    /// the classic [`RunResult`]. Bitwise contract: for a fixed method,
    /// engines and [`RunConfig`], the trajectory is identical across
    /// `Sim`, `Threaded`, and `Distributed` (lossless `f64` payload),
    /// with or without observers — asserted by `tests/driver_matrix.rs`.
    pub fn run(mut self) -> Result<RunResult> {
        // -- driver (needed early: TCP forces preparation) --------------
        let driver = match self.driver.take() {
            Some(d) => d,
            None => match self.cfg {
                Some(cfg) => match cfg.driver {
                    DriverKind::Auto => match cfg.engine {
                        EngineKind::Native => Driver::Sim,
                        EngineKind::Pjrt => Driver::Threaded,
                    },
                    DriverKind::Sim => Driver::Sim,
                    DriverKind::Threaded => Driver::Threaded,
                    DriverKind::Distributed => Driver::Distributed {
                        transport: DistTransport::Loopback {
                            procs: cfg.wire.workers,
                        },
                    },
                },
                None => Driver::Sim,
            },
        };
        let is_tcp = matches!(
            &driver,
            Driver::Distributed {
                transport: DistTransport::Tcp { .. }
            }
        );

        // -- problem preparation (config source only, on demand) --------
        let mut owned_prep: Option<Prepared> = None;
        if self.prep.is_none() {
            if let Some(cfg) = self.cfg {
                let need = self.spec.is_none()
                    || self.sm.is_none()
                    || (self.engines.is_none() && self.factory.is_none())
                    || is_tcp;
                if need {
                    let need_global = match &self.spec {
                        Some(s) => s.name == "diana++",
                        None => cfg.methods.iter().any(|m| m == "diana++"),
                    };
                    owned_prep = Some(runner::prepare_with(cfg, need_global)?);
                }
            }
        }
        let prep: Option<&Prepared> = self.prep.or(owned_prep.as_ref());

        // -- method spec ------------------------------------------------
        let spec: MethodSpec = match self.spec.take() {
            Some(s) => s,
            None => {
                let cfg = self.cfg.context(
                    "Session needs a MethodSpec (Session::new / .method) or an \
                     ExperimentConfig (Session::from_config)",
                )?;
                ensure!(
                    cfg.methods.len() == 1,
                    "Session::from_config drives exactly one method; got {:?} \
                     (override with .method(..) or trim cfg.methods)",
                    cfg.methods
                );
                let prep = prep.expect("prepared above when no spec is given");
                let mut s =
                    MethodSpec::new(&cfg.methods[0], cfg.tau, cfg.sampling, cfg.mu, prep.x0(cfg));
                s.practical_adiana = cfg.practical_adiana;
                s.compressor = cfg.compressor;
                s.sa_levels = cfg.sa_levels;
                s.sa_weighting = cfg.sa_weighting;
                s
            }
        };

        // -- smoothness + residual reference ----------------------------
        let sm: &Smoothness = match self.sm {
            Some(s) => s,
            None => {
                &prep
                    .context("Session needs .smoothness(..) or a prepared problem")?
                    .sm
            }
        };
        let zeros: Vec<f64>;
        let x_star: &[f64] = match self.x_star {
            Some(x) => x,
            None => match prep {
                Some(p) => &p.x_star,
                None => {
                    zeros = vec![0.0; sm.dim];
                    &zeros
                }
            },
        };

        // -- run policy -------------------------------------------------
        let mut run_cfg = match self.run_cfg.take() {
            Some(rc) => rc,
            None => match self.cfg {
                Some(cfg) => runner::run_config(cfg),
                None => RunConfig::default(),
            },
        };
        if let Some(k) = self.checkpoint_every {
            run_cfg.checkpoint_every = k;
        }

        // -- engines ----------------------------------------------------
        // Resolved lazily per driver: an explicit factory wins; otherwise
        // a prepared problem supplies engines per the config's engine
        // kind (native when config-less).
        let engine_kind = self.cfg.map(|c| c.engine).unwrap_or(EngineKind::Native);

        // -- dispatch ---------------------------------------------------
        let mut observers = std::mem::take(&mut self.observers);
        let mut collector = CollectObserver::for_cfg(&run_cfg);
        let outcome: RunOutcome = {
            let mut fan = Fanout {
                collect: &mut collector,
                rest: &mut observers[..],
            };
            match driver {
                Driver::Sim => {
                    let mut method = build(&spec, sm)?;
                    let n = method.workers.len();
                    let mut engines = match (self.engines.take(), &self.factory, prep) {
                        (Some(e), _, _) => e,
                        (None, Some(f), _) => (0..n).map(|i| f(i)).collect(),
                        // native engines straight off the borrowed shards —
                        // no factory (and no shard clone) on the sweep path
                        (None, None, Some(p)) => match engine_kind {
                            EngineKind::Native => p.native_engines(spec.mu),
                            EngineKind::Pjrt => {
                                let f = p.engine_factory(EngineKind::Pjrt, spec.mu)?;
                                (0..n).map(|i| f(i)).collect()
                            }
                        },
                        (None, None, None) => bail!(
                            "Driver::Sim needs .engines(..), .engine_factory(..), \
                             or a prepared problem"
                        ),
                    };
                    ensure!(
                        engines.len() == method.workers.len(),
                        "engine count {} != worker count {}",
                        engines.len(),
                        method.workers.len()
                    );
                    run_sim_observed(&mut method, &mut engines, x_star, &run_cfg, &mut fan)
                }
                Driver::Threaded => {
                    ensure!(
                        self.engines.is_none(),
                        "Driver::Threaded builds engines inside its worker threads; \
                         pass .engine_factory(..) instead of .engines(..)"
                    );
                    let method = build(&spec, sm)?;
                    let factory = match self.factory.clone() {
                        Some(f) => f,
                        None => prep
                            .context(
                                "Driver::Threaded needs .engine_factory(..) or a \
                                 prepared problem",
                            )?
                            .engine_factory(engine_kind, spec.mu)?,
                    };
                    run_threaded_observed(method, factory, x_star, &run_cfg, &mut fan)
                }
                Driver::Distributed {
                    transport: DistTransport::Loopback { procs },
                } => {
                    ensure!(
                        self.engines.is_none(),
                        "the distributed driver builds engines inside its workers; \
                         pass .engine_factory(..) instead of .engines(..)"
                    );
                    let method = build(&spec, sm)?;
                    let factory = match self.factory.clone() {
                        Some(f) => f,
                        None => prep
                            .context(
                                "the loopback-distributed driver needs \
                                 .engine_factory(..) or a prepared problem",
                            )?
                            .engine_factory(engine_kind, spec.mu)?,
                    };
                    crate::wire::runtime::run_distributed_loopback_observed(
                        method, factory, x_star, &run_cfg, procs, &mut fan,
                    )?
                }
                Driver::Distributed {
                    transport:
                        DistTransport::Tcp {
                            listen,
                            workers,
                            relays,
                        },
                } => {
                    let cfg = self.cfg.context(
                        "the TCP transport needs Session::from_config (the worker \
                         handshake ships the dataset recipe)",
                    )?;
                    ensure!(
                        cfg.engine == EngineKind::Native,
                        "the TCP driver supports the native engine only"
                    );
                    ensure!(
                        self.engines.is_none() && self.factory.is_none(),
                        "the TCP driver builds engines in its worker processes; \
                         drop .engines()/.engine_factory()"
                    );
                    let prep = prep.expect("prepared above for the TCP transport");
                    let mut wire_cfg = cfg.clone();
                    wire_cfg.wire.listen = listen;
                    wire_cfg.wire.workers = workers;
                    wire_cfg.wire.relays = relays;
                    let listener = match self.listener.take() {
                        Some(l) => l,
                        None => TcpListener::bind(&wire_cfg.wire.listen)
                            .with_context(|| format!("binding {}", wire_cfg.wire.listen))?,
                    };
                    crate::wire::runtime::serve_observed(
                        listener,
                        &wire_cfg,
                        &spec,
                        prep,
                        &run_cfg,
                        self.metrics.take(),
                        &mut fan,
                    )?
                }
            }
        };

        let result = outcome.into_result(collector.into_records());
        for obs in observers.iter_mut() {
            obs.on_done(&result);
        }
        Ok(result)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn driver_kind_parses() {
        for k in [
            DriverKind::Auto,
            DriverKind::Sim,
            DriverKind::Threaded,
            DriverKind::Distributed,
        ] {
            assert_eq!(DriverKind::parse(k.name()), Some(k));
        }
        assert_eq!(DriverKind::parse("gpu"), None);
    }

    #[test]
    fn checkpoint_file_roundtrip_bit_exact() {
        let path = std::env::temp_dir().join("smx_session_ckpt_test.ckpt");
        let x = vec![1.5, -0.0, 3.5e-310, f64::MAX];
        write_checkpoint(&path, 40, &x).unwrap();
        let (round, got) = load_checkpoint(&path).unwrap();
        assert_eq!(round, 40);
        let bits = |v: &[f64]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&x), bits(&got));
        // corrupting the magic is rejected
        let mut raw = std::fs::read(&path).unwrap();
        raw[0] ^= 0xFF;
        std::fs::write(&path, &raw).unwrap();
        assert!(load_checkpoint(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
